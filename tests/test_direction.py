"""Direction-optimised traversal coverage (DESIGN.md sec. 11).

  * BFS / CC / SSSP / multi-source BFS through the session are bit-identical
    between direction=False, "adaptive" and "bottomup" under every fold
    codec (levels, preds, labels, dists, sources and n_levels; NOT
    edges_scanned -- bottom-up legitimately scans a different edge set);
  * the fused bottom-up chunk kernels (plain + value-carrying) agree
    BIT-EXACTLY with the frontier.py references on random inputs, including
    empty/full frontier bitmaps and a block size not divisible by 32;
  * a hypothesis property drives whole searches on random n=37 graphs
    (S % 32 != 0) through all three modes -- plus deterministic star /
    path / isolated-root versions so the gate holds without hypothesis;
  * the adaptive switch lives INSIDE the compiled loop: one trace for a
    64-root sweep, and the per-level direction trace shows both a top-down
    and a bottom-up level on RMAT (the alpha/beta crossover);
  * the selection rules: "auto" resolution, the REPRO_BOTTOMUP override,
    and engine-cache keying by the RESOLVED path + direction mode;
  * the deprecated `BFS2DDirection` shim warns and matches the session.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import BFSConfig, DistGraph
from repro.api.session import GraphSession, build_engine
from repro.core import Grid2D, bfs_reference_py, validate_bfs
from repro.core.frontier import (exclusive_cumsum, reference_bottomup_chunk,
                                 reference_bottomup_values_chunk)
from repro.core.partition import partition_2d, partition_2d_csr
from repro.core.types import LocalGraph2D
from repro.dist.topology import Topology
from repro.graphgen import rmat_edges, build_csc
from repro.kernels import bottomup_chunk, bottomup_chunk_values
from repro.kernels.select import BOTTOMUP_ENV, resolve_bottomup_path

SCALE, EF = 8, 8
N = 1 << SCALE
CODECS = ("list", "bitmap", "delta")


@pytest.fixture(scope="module")
def graph_data():
    edges = rmat_edges(jax.random.key(7), SCALE, EF)
    edges_np = np.asarray(edges)
    co, ri = build_csc(edges, N)
    w = np.random.default_rng(3).integers(
        1, 256, size=edges_np.shape[1]).astype(np.uint8)
    deg = np.bincount(edges_np[0], minlength=N)
    roots = np.random.default_rng(4).choice(np.flatnonzero(deg > 0), 64,
                                            replace=False)
    return edges_np, co, ri, w, roots


def _graph(edges_np, w, codec="list", direction=False):
    cfg = BFSConfig(grid=(1, 1), fold_codec=codec, edge_chunk=512,
                    direction=direction)
    return DistGraph.from_edges(edges_np, cfg, n=N, weights=w)


# ----------------------------------------------------------------------------
# Session-level bit-identity: every program x codec x mode
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_all_programs_bitexact_across_modes(graph_data, codec):
    """Per-level direction choice must be an implementation detail: levels,
    preds, labels, dists and sources identical to pure top-down.  (Edge
    counts are NOT compared -- bottom-up scans unvisited rows' in-edges.)"""
    edges_np, co, ri, w, roots = graph_data
    root = int(roots[0])
    base = _graph(edges_np, w, codec=codec).session()
    ref_bfs = base.bfs(root)
    ref_cc = base.connected_components()
    ref_sssp = base.sssp(root)
    ref_mb = base.multi_bfs(roots[:3])
    for mode in ("adaptive", "bottomup"):
        sess = _graph(edges_np, w, codec=codec, direction=mode).session()
        out = sess.bfs(root)
        np.testing.assert_array_equal(np.asarray(out.level),
                                      np.asarray(ref_bfs.level))
        np.testing.assert_array_equal(np.asarray(out.pred),
                                      np.asarray(ref_bfs.pred))
        assert int(out.n_levels) == int(ref_bfs.n_levels)
        validate_bfs(edges_np, np.asarray(out.level)[:N],
                     np.asarray(out.pred)[:N], root)
        cc = sess.connected_components()
        np.testing.assert_array_equal(np.asarray(cc.labels),
                                      np.asarray(ref_cc.labels))
        sp = sess.sssp(root)
        np.testing.assert_array_equal(np.asarray(sp.dist),
                                      np.asarray(ref_sssp.dist))
        mb = sess.multi_bfs(roots[:3])
        np.testing.assert_array_equal(np.asarray(mb.level),
                                      np.asarray(ref_mb.level))
        np.testing.assert_array_equal(np.asarray(mb.src),
                                      np.asarray(ref_mb.src))


def test_adaptive_switch_in_loop_one_trace(graph_data):
    """The alpha/beta switch is a lax.cond INSIDE the while_loop: a 64-root
    sweep traces once, and on dense RMAT the trace records at least one
    top-down AND one bottom-up level (the crossover actually fires)."""
    edges_np, _, _, w, roots = graph_data
    sess = _graph(edges_np, w, direction=True).session()
    assert sess.engine.trace_count == 0
    out = sess.bfs(roots)
    assert sess.engine.trace_count == 1, "sweep must trace exactly once"
    sess.bfs(roots[::-1].copy())
    assert sess.engine.trace_count == 1, "second sweep must hit the cache"
    dirs = np.asarray(out.directions)
    assert dirs.shape == (64, sess.config.max_levels)
    d0 = dirs[0][dirs[0] >= 0]
    assert (d0 == 0).any() and (d0 == 1).any(), \
        f"adaptive must use both directions on RMAT, got {d0}"
    # one live entry per executed step (n_levels - 1 of them), tail stays -1
    assert (dirs[0][:int(out.n_levels[0]) - 1] >= 0).all()
    assert (dirs[0][int(out.n_levels[0]) - 1:] == -1).all()


def test_directions_trace_per_mode(graph_data):
    edges_np, _, _, w, roots = graph_data
    root = int(roots[0])
    td = _graph(edges_np, w).session().bfs(root)
    assert td.directions is None, "top-down engine reports no direction trace"
    bu = _graph(edges_np, w, direction="bottomup").session().bfs(root)
    d = np.asarray(bu.directions)
    live = d[d >= 0]
    # st.lvl exits one past the executed steps: live entries = n_levels - 1
    assert live.size == int(bu.n_levels) - 1 and (live == 1).all(), \
        "mode='bottomup' must run every level bottom-up"


# ----------------------------------------------------------------------------
# Kernel-level: fused chunk vs frontier.py reference
# ----------------------------------------------------------------------------

def _bottomup_inputs(rng, nrl, ncl, block, e_max, frontier_frac):
    """Random CSR + frontier bitmap + a MASKED-degree workload (some rows
    'visited', their degree zeroed -- so cumul genuinely diverges from
    row_off and the addr arithmetic is exercised)."""
    deg = rng.integers(0, 6, size=nrl)
    row_off = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    col_idx = rng.integers(0, ncl, size=max(e_max, 1)).astype(np.int32)
    mask = rng.random(ncl) < frontier_frac
    W = (block + 31) // 32
    words = np.zeros(((ncl + block - 1) // block) * W, np.uint32)
    for c in np.flatnonzero(mask):
        blk, off = c // block, c % block
        words[blk * W + (off >> 5)] |= np.uint32(1) << np.uint32(off & 31)
    visited = rng.random(nrl) < 0.3
    cumul = np.asarray(exclusive_cumsum(
        jnp.asarray(np.where(visited, 0, deg).astype(np.int32))))
    total = np.int32(cumul[-1])
    return (jnp.asarray(row_off), jnp.asarray(col_idx), jnp.asarray(words),
            jnp.asarray(cumul), total)


@pytest.mark.parametrize("block", [37, 64])
@pytest.mark.parametrize("frontier_frac", [0.0, 0.4, 1.0])
def test_bottomup_chunk_paths_agree(block, frontier_frac):
    """reference vs pallas-interpret bit-exact, incl. empty and full
    bitmaps and S % 32 != 0 (the ragged last word of each block)."""
    rng = np.random.default_rng(block * 10 + int(frontier_frac * 10))
    nrl = ncl = 2 * block
    row_off, col_idx, words, cumul, total = _bottomup_inputs(
        rng, nrl, ncl, block, e_max=6 * nrl, frontier_frac=frontier_frac)
    gids = jnp.arange(128, dtype=jnp.int32)
    a = reference_bottomup_chunk(gids, cumul, total, row_off, col_idx,
                                 words, block=block)
    b = bottomup_chunk(gids, cumul, jnp.int32(total), row_off, col_idx,
                       words, block=block, interpret=True)
    _assert_chunks_match(gids, total, a, b)


def _assert_chunks_match(gids, total, a, b):
    """hit must match lane-for-lane; the other outputs are only specified on
    live lanes (gid < total) -- out-of-workload lanes carry don't-care row
    indices in both paths."""
    live = np.asarray(gids) < int(total)
    np.testing.assert_array_equal(np.asarray(a[-1]), np.asarray(b[-1]))
    for x, y in zip(a[:-1], b[:-1]):
        np.testing.assert_array_equal(np.where(live, np.asarray(x), 0),
                                      np.where(live, np.asarray(y), 0))


def test_bottomup_values_chunk_paths_agree():
    rng = np.random.default_rng(11)
    block = 37
    nrl = ncl = 74
    row_off, col_idx, words, cumul, total = _bottomup_inputs(
        rng, nrl, ncl, block, e_max=6 * nrl, frontier_frac=0.5)
    pay = jnp.asarray(rng.integers(0, 1000, size=ncl).astype(np.int32))
    gids = jnp.arange(96, dtype=jnp.int32)
    a = reference_bottomup_values_chunk(gids, cumul, total, row_off, col_idx,
                                        words, pay, block=block)
    b = bottomup_chunk_values(gids, cumul, jnp.int32(total), row_off,
                              col_idx, words, pay, block=block,
                              interpret=True)
    _assert_chunks_match(gids, total, a, b)


# ----------------------------------------------------------------------------
# Whole-search property: random n=37 graphs, all three modes agree
# ----------------------------------------------------------------------------

N_SMALL = 37           # 1x1 grid -> S = 37, so S % 32 != 0
E_HALF = 96            # fixed shape: AOT caches absorb repeat examples


class _ModeRunner:
    """One engine + one AOT cache per mode, shared across examples."""

    def __init__(self):
        self.grid = Grid2D.for_vertices(N_SMALL, 1, 1)
        self.topo = Topology.for_grid(self.grid)
        self.compiled = {}
        self.sessions = {}
        for mode in (False, "adaptive", "bottomup"):
            cfg = BFSConfig(grid=self.grid, edge_chunk=64, max_levels=40,
                            direction=mode)
            self.sessions[mode] = (cfg, build_engine(self.topo, cfg), {})

    def run(self, edges_np, root):
        lg = partition_2d(edges_np, self.grid, pad_to=2 * E_HALF)
        csc = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                          jnp.asarray(lg.nnz))
        csr = {k: jnp.asarray(v) for k, v in partition_2d_csr(
            edges_np, self.grid, pad_to=2 * E_HALF).items()}
        outs = {}
        for mode, (cfg, engine, cache) in self.sessions.items():
            dg = DistGraph(self.topo, csc, csr=csr, config=cfg)
            dg._compiled = cache
            outs[mode] = GraphSession(dg, cfg, engine=engine).bfs(root)
        return outs


@pytest.fixture(scope="module")
def mode_runner():
    return _ModeRunner()


def _assert_modes_agree(mode_runner, edges_np, root):
    outs = mode_runner.run(edges_np, root)
    ref = outs[False]
    co, ri = build_csc(jnp.asarray(edges_np), N_SMALL)
    lvl_ref, _ = bfs_reference_py(co, ri, root, N_SMALL)
    assert (np.asarray(ref.level)[:N_SMALL] == lvl_ref).all()
    for mode in ("adaptive", "bottomup"):
        out = outs[mode]
        np.testing.assert_array_equal(np.asarray(out.level),
                                      np.asarray(ref.level), err_msg=mode)
        np.testing.assert_array_equal(np.asarray(out.pred),
                                      np.asarray(ref.pred), err_msg=mode)
        assert int(out.n_levels) == int(ref.n_levels), mode


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_modes_agree_random_graphs(mode_runner, data):
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    half = rng.integers(0, N_SMALL, size=(2, E_HALF))
    edges_np = np.concatenate([half, half[::-1]], axis=1)
    root = int(rng.integers(0, N_SMALL))
    _assert_modes_agree(mode_runner, edges_np, root)


def test_modes_agree_edge_cases(mode_runner):
    """Deterministic versions of the hypothesis edge cases: an isolated
    root (empty frontier after level 0), a star (full frontier -> bottom-up
    with everything visited next level), and a long path (many tiny
    frontiers; adaptive must stay top-down and still agree)."""
    # star centred at 0, vertex 36 isolated; pad with self-loops at 0
    hub = np.stack([np.zeros(36, np.int64), np.arange(36, dtype=np.int64)])
    pad = np.zeros((2, E_HALF - hub.shape[1]), np.int64)
    star = np.concatenate([hub, pad], axis=1)
    star = np.concatenate([star, star[::-1]], axis=1)
    _assert_modes_agree(mode_runner, star, 0)       # full-frontier level
    _assert_modes_agree(mode_runner, star, 5)       # leaf root
    # root 36 isolated: BFS is a single vertex, empty frontier immediately
    _assert_modes_agree(mode_runner, star, 36)
    # path 0-1-...-36
    u = np.arange(36, dtype=np.int64)
    path = np.stack([u, u + 1])
    pad = np.zeros((2, E_HALF - path.shape[1]), np.int64)
    path = np.concatenate([path, pad], axis=1)
    path = np.concatenate([path, path[::-1]], axis=1)
    _assert_modes_agree(mode_runner, path, 0)
    _assert_modes_agree(mode_runner, path, 18)


# ----------------------------------------------------------------------------
# Selection rules + cache keying + the deprecated shim
# ----------------------------------------------------------------------------

def test_resolve_bottomup_path_rules(monkeypatch):
    monkeypatch.delenv(BOTTOMUP_ENV, raising=False)
    assert resolve_bottomup_path("reference") == "reference"
    assert resolve_bottomup_path("pallas-interpret") == "pallas-interpret"
    assert resolve_bottomup_path("auto", platform="cpu") == "reference"
    assert resolve_bottomup_path("auto", platform="tpu") == "pallas"
    assert resolve_bottomup_path(None, platform="gpu") == "pallas"
    monkeypatch.setenv(BOTTOMUP_ENV, "pallas-interpret")
    assert resolve_bottomup_path("auto", platform="tpu") == "pallas-interpret"
    # explicit spellings are NOT overridden by the environment
    assert resolve_bottomup_path("reference") == "reference"
    monkeypatch.setenv(BOTTOMUP_ENV, "nonsense")
    with pytest.raises(ValueError, match=BOTTOMUP_ENV):
        resolve_bottomup_path("auto")
    monkeypatch.delenv(BOTTOMUP_ENV)
    with pytest.raises(ValueError, match="bottomup="):
        resolve_bottomup_path("metal")


def test_engine_keys_cover_direction_knobs(monkeypatch):
    monkeypatch.delenv(BOTTOMUP_ENV, raising=False)
    td = BFSConfig()
    ad = BFSConfig(direction=True)
    assert td.engine_key != ad.engine_key
    assert ad.engine_key == BFSConfig(direction="adaptive").engine_key
    assert ad.engine_key != BFSConfig(direction="bottomup").engine_key
    assert ad.engine_key != BFSConfig(direction=True, alpha=12).engine_key
    assert ad.engine_key != BFSConfig(direction=True, beta=128).engine_key
    ref = BFSConfig(direction=True, bottomup="reference")
    pal = BFSConfig(direction=True, bottomup="pallas-interpret")
    assert ref.engine_key != pal.engine_key
    # "auto" re-keys when the environment override changes
    expected = resolve_bottomup_path("auto")
    assert ad.bottomup_path == expected
    monkeypatch.setenv(BOTTOMUP_ENV, "pallas-interpret")
    assert ad.bottomup_path == "pallas-interpret"
    assert ad.engine_key == pal.engine_key
    k1 = ad.algo_engine_key(("dir",), "bitmap", 10)
    monkeypatch.delenv(BOTTOMUP_ENV)
    assert ad.algo_engine_key(("dir",), "bitmap", 10) != k1
    with pytest.raises(ValueError, match="direction="):
        BFSConfig(direction="sideways").direction_mode


def test_direction_program_key_distinguishes_inner():
    from repro.algos import BFSLevelsProgram, DirectionProgram
    from repro.algos.cc import ConnectedComponentsProgram

    a = DirectionProgram(BFSLevelsProgram())
    b = DirectionProgram(ConnectedComponentsProgram())
    assert a.key != b.key
    assert a.n_extra == 2            # inner 0 + CSR (row_off, col_idx)
    assert DirectionProgram(BFSLevelsProgram(), mode="bottomup").key != a.key
    with pytest.raises(ValueError, match="mode"):
        DirectionProgram(BFSLevelsProgram(), mode="downhill")


def test_bfs2d_direction_shim_warns_and_matches(graph_data):
    """The deprecated driver is a veneer over BFSConfig(direction=True)."""
    from repro.core.direction import BFS2DDirection
    from repro.dist.compat import make_mesh

    edges_np, co, ri, _, roots = graph_data
    root = int(roots[1])
    grid = Grid2D.for_vertices(N, 1, 1)
    lg = partition_2d(edges_np, grid)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    csr = {k: jnp.asarray(v)
           for k, v in partition_2d_csr(edges_np, grid).items()}
    mesh = make_mesh((1, 1), ("r", "c"))
    with pytest.warns(DeprecationWarning, match="BFS2DDirection"):
        drv = BFS2DDirection(grid, mesh, edge_chunk=512)
    out = drv.run(g, csr, root)
    ref, _ = bfs_reference_py(co, ri, root, N)
    assert (np.asarray(out.level)[:N] == ref).all()
    dirs = np.asarray(out.directions)
    assert dirs[dirs >= 0].size == int(out.n_levels) - 1
    drv.run(g, csr, root)
    assert drv.engine.trace_count == 1, "shim reruns must hit the AOT cache"
