"""Frontier-program subsystem coverage (DESIGN.md sec. 8).

  * CC / SSSP / multi-source BFS through the session match the NumPy host
    references on R-MAT, ring and star graphs, under every fold codec,
    bit-identically;
  * BFS through the refactored engine is unchanged (covered by
    tests/test_api_session.py); here we additionally pin SSSP with unit
    weights == BFS levels (the semiring degeneration) and multi_bfs from a
    single source == bfs levels;
  * batched SSSP == per-root SSSP, and sweeps trace the level loop once;
  * engine/AOT caches are shared across sessions on one DistGraph;
  * `partition_edge_vals` lays values out in exactly `partition_2d`'s order;
  * weight-less graphs reject sssp with a clear error.

Multi-device equivalents run in tests/dist/run_algos.py.
"""
import jax
import numpy as np
import pytest

from repro.algos import (ConnectedComponentsProgram, SSSPProgram,
                         cc_reference, k_hop_neighborhood,
                         multi_bfs_reference, sssp_reference)
from repro.api import BFSConfig, DistGraph
from repro.core import Grid2D, partition_2d
from repro.core.partition import partition_edge_vals
from repro.graphgen import rmat_edges

SCALE, EF = 8, 8
N = 1 << SCALE
CODECS = ("list", "bitmap", "delta")


def ring_edges(n):
    u = np.arange(n, dtype=np.int64)
    fwd = np.stack([u, (u + 1) % n])
    return np.concatenate([fwd, fwd[::-1]], axis=1)


def star_edges(n):
    """Hub 0 joined to every spoke, both directions."""
    spokes = np.arange(1, n, dtype=np.int64)
    hub = np.zeros_like(spokes)
    return np.stack([np.concatenate([hub, spokes]),
                     np.concatenate([spokes, hub])])


@pytest.fixture(scope="module")
def rmat_graph():
    edges = np.asarray(rmat_edges(jax.random.key(0), SCALE, EF))
    rng = np.random.default_rng(1)
    w = rng.integers(1, 256, size=edges.shape[1]).astype(np.uint8)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=512), n=N, weights=w)
    return edges, w, graph


# ----------------------------------------------------------------------------
# Connected components
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_cc_rmat_matches_reference(rmat_graph, codec):
    edges, _, graph = rmat_graph
    out = graph.session().connected_components(fold_codec=codec)
    assert (np.asarray(out.labels)[:N] == cc_reference(edges, N)).all()
    assert int(out.n_iters) >= 1 and out.edges_scanned > 0


def test_cc_codecs_bit_identical(rmat_graph):
    _, _, graph = rmat_graph
    outs = [graph.session().connected_components(fold_codec=c)
            for c in CODECS]
    for out in outs[1:]:
        assert (np.asarray(out.labels) == np.asarray(outs[0].labels)).all()
        assert out.edges_scanned == outs[0].edges_scanned


@pytest.mark.parametrize("edges_fn,n", [(ring_edges, 64), (star_edges, 65)])
def test_cc_ring_and_star(edges_fn, n):
    """One component -> all labels 0; the ring needs ~n/2 propagation
    levels (the deep-diameter case `max_levels = n + 1` must cover)."""
    edges = edges_fn(n)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=256), n=n)
    out = graph.session().connected_components()
    assert (np.asarray(out.labels)[:n] == 0).all()
    assert (np.asarray(out.labels)[:n] == cc_reference(edges, n)).all()


def test_cc_two_components():
    """Two disjoint rings -> two labels (each ring's min id)."""
    n = 32
    a = ring_edges(n)
    b = ring_edges(n) + n
    edges = np.concatenate([a, b], axis=1)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=256), n=2 * n)
    lab = np.asarray(graph.session().connected_components().labels)[:2 * n]
    assert (lab[:n] == 0).all() and (lab[n:] == n).all()


# ----------------------------------------------------------------------------
# SSSP
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_sssp_rmat_matches_dijkstra(rmat_graph, codec):
    edges, w, graph = rmat_graph
    root = int(np.flatnonzero(np.bincount(edges[0], minlength=N) > 0)[0])
    out = graph.session().sssp(root, fold_codec=codec)
    assert (np.asarray(out.dist)[:N] == sssp_reference(edges, w, N,
                                                       root)).all()


def test_sssp_unit_weights_equal_bfs_levels(rmat_graph):
    """min-plus with w == 1 degenerates to BFS hop counts."""
    edges, _, _ = rmat_graph
    ones = np.ones(edges.shape[1], np.uint8)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=512), n=N, weights=ones)
    sess = graph.session()
    root = int(np.flatnonzero(np.bincount(edges[0], minlength=N) > 0)[3])
    bfs = sess.bfs(root)
    sp = sess.sssp(root)
    assert (np.asarray(sp.dist) == np.asarray(bfs.level)).all()


def test_sssp_batched_bitexact_and_traces_once(rmat_graph):
    edges, w, graph = rmat_graph
    sess = graph.session()
    deg = np.bincount(edges[0], minlength=N)
    roots = np.random.default_rng(2).choice(np.flatnonzero(deg > 0), 8,
                                            replace=False)
    eng, _ = sess._algo_engine(SSSPProgram(), None, graph.grid.n + 1)
    t0 = eng.trace_count
    bout = sess.sssp(roots)
    assert eng.trace_count <= t0 + 1, "sweep must trace at most once"
    t1 = eng.trace_count
    sess.sssp(roots[::-1].copy())
    assert eng.trace_count == t1, "second sweep must hit the AOT cache"
    for b, root in enumerate(roots):
        sout = sess.sssp(int(root))
        assert (np.asarray(bout.dist[b]) == np.asarray(sout.dist)).all()
        assert bout.edges_scanned[b] == sout.edges_scanned
        assert (np.asarray(bout.dist[b])[:N] ==
                sssp_reference(edges, w, N, int(root))).all()


def test_sssp_ring_weighted():
    n = 64
    edges = ring_edges(n)
    rng = np.random.default_rng(3)
    w = rng.integers(1, 256, size=edges.shape[1]).astype(np.uint8)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=256), n=n, weights=w)
    out = graph.session().sssp(5)
    assert (np.asarray(out.dist)[:n] == sssp_reference(edges, w, n, 5)).all()


def test_sssp_requires_weights(rmat_graph):
    edges, _, _ = rmat_graph
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=512), n=N)
    with pytest.raises(ValueError, match="weights"):
        graph.session().sssp(0)


def test_partition_edge_vals_aligns_with_partition_2d():
    """vals[i, j, k] must describe the edge at row_idx[i, j, k]: encode each
    edge's identity into its value and check against the CSC layout."""
    edges = np.asarray(rmat_edges(jax.random.key(5), 6, 4))
    n = 1 << 6
    grid = Grid2D.for_vertices(n, 2, 2)
    lg = partition_2d(edges, grid)
    # value = global dst id (mod 251) -- recoverable from the local row
    vals = (edges[1] % 251).astype(np.int32)
    out = partition_edge_vals(edges, vals, grid)
    assert out.shape == lg.row_idx.shape
    S, ncl = grid.S, grid.n_cols_local
    for i in range(2):
        for j in range(2):
            nnz = int(lg.nnz[i, j])
            lr = lg.row_idx[i, j, :nnz]
            gdst = ((lr // S) * grid.R + i) * S + lr % S
            assert (out[i, j, :nnz] == gdst % 251).all()
            assert (out[i, j, nnz:] == 0).all()


# ----------------------------------------------------------------------------
# Multi-source BFS
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_multi_bfs_matches_reference(rmat_graph, codec):
    edges, _, graph = rmat_graph
    deg = np.bincount(edges[0], minlength=N)
    sources = np.flatnonzero(deg > 0)[[0, 7, 19, 40]]
    out = graph.session().multi_bfs(sources, fold_codec=codec)
    lref, sref = multi_bfs_reference(edges, N, sources)
    assert (np.asarray(out.level)[:N] == lref).all()
    assert (np.asarray(out.src)[:N] == sref).all()


def test_multi_bfs_single_source_equals_bfs(rmat_graph):
    edges, _, graph = rmat_graph
    sess = graph.session()
    root = int(np.flatnonzero(np.bincount(edges[0], minlength=N) > 0)[2])
    mb = sess.multi_bfs(np.array([root]))
    bfs = sess.bfs(root)
    assert (np.asarray(mb.level) == np.asarray(bfs.level)).all()
    reached = np.asarray(mb.level) >= 0
    assert (np.asarray(mb.src)[reached] == 0).all()


def test_multi_bfs_k_hop_truncation(rmat_graph):
    edges, _, graph = rmat_graph
    deg = np.bincount(edges[0], minlength=N)
    sources = np.flatnonzero(deg > 0)[[1, 9]]
    out = graph.session().multi_bfs(sources, k=2)
    lref, sref = multi_bfs_reference(edges, N, sources, max_levels=2)
    assert (np.asarray(out.level)[:N] == lref).all()
    assert (np.asarray(out.src)[:N] == sref).all()
    hood = k_hop_neighborhood(edges, N, sources, 2)
    assert (np.flatnonzero(np.asarray(out.level)[:N] >= 0) == hood).all()
    assert int(out.n_levels) <= 3


def test_multi_bfs_star_tie_break():
    """Every spoke adjacent to two sources in one wave -> min index wins."""
    n = 17
    edges = star_edges(n)
    graph = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=64), n=n)
    # sources: two spokes; the hub is hit by both in wave 1 -> index 0
    out = graph.session().multi_bfs(np.array([5, 3]))
    lref, sref = multi_bfs_reference(edges, n, [5, 3])
    assert (np.asarray(out.level)[:n] == lref).all()
    assert (np.asarray(out.src)[:n] == sref).all()
    assert int(np.asarray(out.src)[0]) == 0      # hub claimed by index 0


def test_multi_bfs_rejects_empty_sources(rmat_graph):
    _, _, graph = rmat_graph
    with pytest.raises(ValueError, match="non-empty"):
        graph.session().multi_bfs(np.array([], np.int32))


# ----------------------------------------------------------------------------
# Cache discipline across programs
# ----------------------------------------------------------------------------

def test_algo_engines_cached_on_graph(rmat_graph):
    _, _, graph = rmat_graph
    s1, s2 = graph.session(), graph.session()
    e1, k1 = s1._algo_engine(ConnectedComponentsProgram(), None,
                             graph.grid.n + 1)
    e2, k2 = s2._algo_engine(ConnectedComponentsProgram(), None,
                             graph.grid.n + 1)
    assert e1 is e2 and k1 == k2, "sessions must share algo engines"
    # distinct codec -> distinct engine; repeat CC calls hit the AOT cache
    e3, _ = s1._algo_engine(ConnectedComponentsProgram(), "delta",
                            graph.grid.n + 1)
    assert e3 is not e1
    before = e1.trace_count
    s1.connected_components()
    s2.connected_components()
    assert e1.trace_count == max(before, 1), "repeat CC must hit the cache"


def test_validate_flag_runs_graph500_rules(rmat_graph):
    """bfs(validate=...) runs the Graph500 rules (satellite: session-level
    validation); a released edge list raises a clear error."""
    edges, _, graph = rmat_graph
    sess = graph.session()
    deg = np.bincount(edges[0], minlength=N)
    roots = np.flatnonzero(deg > 0)[:3]
    sess.bfs(int(roots[0]), validate=True)         # retained host edges
    sess.bfs(roots, validate=edges)                # explicit edge array
    graph.release_edges()
    sess.bfs(int(roots[0]), validate=edges)        # still fine explicitly
    with pytest.raises(ValueError, match="released"):
        sess.bfs(int(roots[0]), validate=True)
