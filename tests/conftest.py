# NOTE: do NOT set XLA_FLAGS/device-count overrides here -- smoke tests and
# benches must see the single real CPU device.  Multi-device integration tests
# spawn subprocesses (see tests/dist/).
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
