"""Subprocess integration check: 2D BFS on an R x C forced-host-device grid.

Usage: run_bfs2d.py R C [scale=9] [ef=8] [fold=list]

Runs a few searches, compares levels against the python reference, validates
the predecessor tree, and prints OK.
"""
import os
import sys

R, C = int(sys.argv[1]), int(sys.argv[2])
SCALE = int(sys.argv[3]) if len(sys.argv) > 3 else 9
EF = int(sys.argv[4]) if len(sys.argv) > 4 else 8
FOLD = sys.argv[5] if len(sys.argv) > 5 else "list"

os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc

n = 1 << SCALE
edges = rmat_edges(jax.random.key(0), SCALE, EF)
edges_np = np.asarray(edges)
co, ri = build_csc(edges, n)

mesh = make_mesh((R, C), ("r", "c"))
grid = Grid2D.for_vertices(n, R, C)
lg = partition_2d(edges_np, grid)
graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
bfs = BFS2D(grid, mesh, edge_chunk=2048, fold_codec=FOLD)

deg = np.bincount(edges_np[0], minlength=n)
roots = np.random.default_rng(3).choice(np.flatnonzero(deg > 0), 3,
                                        replace=False)
for root in roots:
    out = bfs.run(graph, int(root))
    ref, _ = bfs_reference_py(co, ri, int(root), n)
    lvl = np.asarray(out.level)[:n]
    assert (lvl == ref).all(), f"levels mismatch at root {root}"
    validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], int(root))
    assert out.edges_scanned > 0
print("OK")
