"""Subprocess integration check for the rest of the distributed stack:

  * BFS1D on the degenerate 1 x (R*C) grid of the shared engine;
  * BFS2DDirection on the R x C grid;
  * fold-codec equality (list vs bitmap vs delta) on R x C, bit-exact;
  * spmm2d against a dense reference.

Usage: run_dist_suite.py R C
"""
import os
import sys

R, C = int(sys.argv[1]), int(sys.argv[2])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.partition import partition_2d_csr
from repro.core.bfs1d import BFS1D
from repro.core.bfs2d import BFS2D
from repro.core.direction import BFS2DDirection
from repro.core.spmm2d import make_spmm2d
from repro.core.types import LocalGraph2D
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc

SCALE, EF, ROOT = 9, 8, 3
n = 1 << SCALE
edges = rmat_edges(jax.random.key(0), SCALE, EF)
edges_np = np.asarray(edges)
co, ri = build_csc(edges, n)
ref, _ = bfs_reference_py(co, ri, ROOT, n)


def as_graph(lg):
    return LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                        jnp.asarray(lg.nnz))


def check(out, what):
    lvl = np.asarray(out.level)[:n]
    assert (lvl == ref).all(), f"{what}: levels mismatch"
    validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], ROOT)


# --- 1D baseline (degenerate grid, O(P) fold all_to_all) -------------------
mesh1 = make_mesh((R * C,), ("p",))
bfs1 = BFS1D(n, mesh1, axes=("p",), edge_chunk=2048)
check(bfs1.run(as_graph(partition_2d(edges_np, bfs1.grid)), ROOT), "1d")

# --- direction-optimising 2D ----------------------------------------------
mesh = make_mesh((R, C), ("r", "c"))
grid = Grid2D.for_vertices(n, R, C)
graph = as_graph(partition_2d(edges_np, grid))
csr = {k: jnp.asarray(v) for k, v in partition_2d_csr(edges_np, grid).items()}
check(BFS2DDirection(grid, mesh, edge_chunk=2048).run(graph, csr, ROOT),
      "direction")

# --- fold codecs agree bit-exactly on a multi-device grid ------------------
outs = {c: BFS2D(grid, mesh, edge_chunk=2048, fold_codec=c).run(graph, ROOT)
        for c in ("list", "bitmap", "delta")}
for c in ("bitmap", "delta"):
    check(outs[c], c)
    assert (np.asarray(outs[c].pred) == np.asarray(outs["list"].pred)).all(), c
    assert outs[c].edges_scanned == outs["list"].edges_scanned, c

# --- spmm2d vs dense reference --------------------------------------------
d = 4
x = np.asarray(jax.random.normal(jax.random.key(1), (grid.n, d)), np.float32)
y = make_spmm2d(grid, mesh)(graph.col_off, graph.row_idx, graph.nnz,
                            jnp.asarray(x))
A = np.zeros((grid.n, grid.n), np.float32)
np.add.at(A, (edges_np[1], edges_np[0]), 1.0)   # duplicates accumulate
np.testing.assert_allclose(np.asarray(y), A @ x, rtol=2e-4, atol=2e-4)

print("OK")
