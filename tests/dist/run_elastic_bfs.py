"""Subprocess elastic-BFS check: lose devices mid-service, shrink the grid,
re-partition from the edge list, and keep answering searches correctly.

The BFS partition is a pure function of (edge list, R, C) -- elasticity for
the paper's workload is re-partition + re-bind to a smaller mesh (see
repro/ckpt/elastic.py).  Also exercises reshard_state's axis-dropping on the
search outputs, and MID-TRAVERSAL elasticity (DESIGN.md sec. 15): a
persistent device loss at level 2 escalates through UnrecoverableLoss, the
ElasticCoordinator re-plans onto the survivor grid and resumes from the
snapshot -- levels / level counts / edge counters bit-identical to the
uninterrupted run, predecessors Graph500-valid.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.ckpt.elastic import reshard_state, shrink_grid
from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc

SCALE, EF, ROOT = 9, 8, 3
n = 1 << SCALE
edges = rmat_edges(jax.random.key(0), SCALE, EF)
edges_np = np.asarray(edges)
co, ri = build_csc(edges, n)
ref, _ = bfs_reference_py(co, ri, ROOT, n)


def search(R, C, devices=None):
    mesh = make_mesh((R, C), ("r", "c"), devices=devices)
    grid = Grid2D.for_vertices(n, R, C)
    lg = partition_2d(edges_np, grid)
    graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                         jnp.asarray(lg.nnz))
    out = BFS2D(grid, mesh, edge_chunk=2048).run(graph, ROOT)
    lvl = np.asarray(out.level)[:n]
    assert (lvl == ref).all(), f"{R}x{C}: levels mismatch"
    validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], ROOT)
    return mesh, out


mesh8, out8 = search(2, 4)                       # full 2x4 service

failed = 2                                       # "lose" two devices
R2, C2 = shrink_grid(2, 4, failed)
assert R2 * C2 <= 8 - failed
mesh6, out6 = search(R2, C2, devices=jax.devices()[:R2 * C2])

# prior outputs re-placed onto the shrunk mesh (missing axes dropped)
re = reshard_state({"level": np.asarray(out8.level)},
                   {"level": P(("missing",))}, mesh6)
assert (np.asarray(re["level"]) == np.asarray(out8.level)).all()

# ---- mid-traversal shrink-and-resume -----------------------------------
from repro.api import BFSConfig, DistGraph
from repro.runtime.fault import RetryPolicy
from repro.runtime.recovery import (DeviceLossInjector, ElasticCoordinator,
                                    RecoveryPlan)

config = BFSConfig(grid=(2, 4), edge_chunk=2048, fault_tolerance=True,
                   ckpt_every=1)
roots = np.asarray([ROOT, 5], np.int32)
base = DistGraph.from_edges(edges_np, config, n=n).session().bfs(roots)

plan = RecoveryPlan(
    injector=DeviceLossInjector(2, devices=failed, fires=3),
    policy=RetryPolicy(max_retries=2, backoff_s=1e-4, jitter_s=1e-4, seed=1))
coord = ElasticCoordinator(edges_np, config, n=n)
out = coord.run("bfs", roots, plan=plan)

assert coord.shrinks == 1 and coord.grids[0] == (2, 4), coord.grids
assert coord.grids[-1][0] * coord.grids[-1][1] <= 8 - failed, coord.grids
assert (np.asarray(out.level)[:, :n] == np.asarray(base.level)[:, :n]).all()
assert (np.asarray(out.n_levels) == np.asarray(base.n_levels)).all()
assert tuple(out.edges_scanned) == tuple(base.edges_scanned)
for b, r in enumerate(roots):        # preds are grid-dependent: re-validate
    validate_bfs(edges_np, np.asarray(out.level)[b][:n],
                 np.asarray(out.pred)[b][:n], int(r))
assert plan.stats["resumes"] == 1
assert plan.stats["resumed_from_level"] is not None
assert plan.stats["time_to_first_resumed_level_s"] > 0
print(f"ELASTIC,{coord.grids[0]}->{coord.grids[-1]},"
      f"resumed_from={plan.stats['resumed_from_level']},"
      f"t_first={plan.stats['time_to_first_resumed_level_s']:.3f}")
print("OK")
