"""Subprocess integration check for the session API on a real device grid:

  * `DistGraph.from_edges` plans once on an R x C forced-host-device mesh
    (CSR twin only when direction is on);
  * batched `GraphSession.bfs` is bit-exact vs per-root queries AND the
    python reference, for the list codec and for direction optimisation;
  * every sweep runs with `validate=` on, so multi-device CI checks the
    Graph500 rules (tree/level/edge consistency), not just bit-equality;
  * a multi-root sweep traces the level loop exactly once (AOT cache);
  * the degenerate 1 x P topology works through the same session API.

Usage: run_session.py R C
"""
import os
import sys

R, C = int(sys.argv[1]), int(sys.argv[2])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core import bfs_reference_py
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc

SCALE, EF = 9, 8
n = 1 << SCALE
edges_np = np.asarray(rmat_edges(jax.random.key(0), SCALE, EF))
co, ri = build_csc(edges_np, n)
deg = np.bincount(edges_np[0], minlength=n)
roots = np.random.default_rng(3).choice(np.flatnonzero(deg > 0), 8,
                                        replace=False)


def check_batch(sess, what):
    # validate= runs the Graph500 rules on every root inside the session
    # (explicit edge array: the direction session has released the host
    # copy to plan its CSR twin)
    bout = sess.bfs(roots, validate=edges_np)
    assert sess.engine.trace_count == 1, f"{what}: sweep traced more than once"
    for b, root in enumerate(roots):
        ref, _ = bfs_reference_py(co, ri, int(root), n)
        lvl = np.asarray(bout.level[b])[:n]
        assert (lvl == ref).all(), f"{what}: levels mismatch at root {root}"
    # batched == sequential, bit-exact (scalar goes through the B=1 program)
    sout = sess.bfs(int(roots[0]))
    assert (np.asarray(bout.level[0]) == np.asarray(sout.level)).all(), what
    assert (np.asarray(bout.pred[0]) == np.asarray(sout.pred)).all(), what
    assert bout.edges_scanned[0] == sout.edges_scanned, what
    return bout


# --- 2D grid, top-down (CSR must NOT be planned) ---------------------------
graph = DistGraph.from_edges(
    edges_np, BFSConfig(grid=(R, C), edge_chunk=2048), n=n)
assert graph.csr is None, "CSR twin built without direction"
check_batch(graph.session(), "2d")
# validate=True resolves to the retained host edges while CSR is unplanned
graph.session().bfs(int(roots[0]), validate=True)

# --- direction optimisation over the SAME resident graph (lazy CSR) --------
dsess = graph.session(BFSConfig(grid=(R, C), edge_chunk=2048,
                                direction=True))
assert graph.csr is not None
dout = check_batch(dsess, "direction")
dirs = np.asarray(dout.directions)
assert dirs.shape == (len(roots), dsess.config.max_levels), "directions shape"
live = dirs[0][dirs[0] >= 0]
assert live.size == int(dout.n_levels[0]) - 1, "one decision per level"
assert (live == 0).any() and (live == 1).any(), \
    f"adaptive must exercise both directions on RMAT, got {live}"

# --- forced bottom-up: every level pulls, still bit-identical ---------------
bsess = graph.session(BFSConfig(grid=(R, C), edge_chunk=2048,
                                direction="bottomup"))
bout = bsess.bfs(roots)
assert (np.asarray(bout.level) == np.asarray(dout.level)).all(), "bottomup"
assert (np.asarray(bout.pred) == np.asarray(dout.pred)).all(), "bottomup"
bdirs = np.asarray(bout.directions)
assert (bdirs[bdirs >= 0] == 1).all(), "bottomup mode must never push"

# --- fold codecs agree through the session, bit-exact ----------------------
base = graph.session().bfs(roots)
for codec in ("bitmap", "delta"):
    out = graph.session(BFSConfig(grid=(R, C), edge_chunk=2048,
                                  fold_codec=codec)).bfs(roots)
    assert (np.asarray(out.level) == np.asarray(base.level)).all(), codec
    assert (np.asarray(out.pred) == np.asarray(base.pred)).all(), codec
    assert out.edges_scanned == base.edges_scanned, codec

# --- degenerate 1 x P topology through the same API ------------------------
mesh1 = make_mesh((R * C,), ("p",))
g1 = DistGraph.from_edges(
    edges_np,
    BFSConfig(grid=(1, R * C), row_axes=(), col_axes=("p",),
              edge_chunk=2048),
    mesh=mesh1, n=n)
check_batch(g1.session(), "1d")

print("OK")
