"""Two-host scale-out smoke: the session API across a REAL process group.

Simulates NPROC hosts with DEVS local CPU devices each (gloo collectives
over localhost), plans one DistGraph over the global mesh, runs BFS, CC and
SSSP through `GraphSession`, and asserts every output is BIT-IDENTICAL to a
single-process reference of the same graph -- for the requested exchange
strategy ("flat" or "butterfly"; the tentpole contract is that multi-host
and strategy are orthogonal to results).

Usage:  run_multihost.py NPROC DEVS [EXCHANGE]

The script is its own orchestrator: invoked with no REPRO_MH_ROLE it first
computes the single-process reference in a child, then spawns NPROC worker
children that join a `jax.distributed` process group; worker 0 writes its
outputs and the parent compares.  Workers place inputs / read outputs only
through `repro.dist.multihost`, so this exercises the whole placement
surface (sharded graph arrays, replicated args, process_allgather fetch).

Prints "OK" on success (the CI multihost-smoke job greps for it).
"""
import os
import subprocess
import sys
import tempfile

NPROC = int(sys.argv[1]) if len(sys.argv) > 1 else 2
DEVS = int(sys.argv[2]) if len(sys.argv) > 2 else 2
EXCHANGE = sys.argv[3] if len(sys.argv) > 3 else "flat"
SCALE, EF, ROOT = 9, 8, 3
PORT = int(os.environ.get("REPRO_MH_PORT", "12123"))

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

ROLE = os.environ.get("REPRO_MH_ROLE")


def make_inputs():
    import numpy as np
    rng = np.random.default_rng(7)
    n = 1 << SCALE
    m = EF * n
    edges = np.stack([rng.integers(0, n, m), rng.integers(0, n, m)])
    edges = np.concatenate([edges, edges[::-1]], axis=1)   # symmetrised
    w = rng.integers(1, 64, edges.shape[1]).astype(np.uint8)
    return n, edges, w


def run_queries(mesh):
    """Plan + query through the session API; returns host numpy outputs."""
    import numpy as np
    from repro.api import BFSConfig, DistGraph

    n, edges, w = make_inputs()
    cfg = BFSConfig(grid=(1, NPROC * DEVS), exchange=EXCHANGE)
    g = DistGraph.from_edges(edges, cfg, weights=w, mesh=mesh)
    s = g.session()
    bfs = s.bfs(ROOT)
    batch = s.bfs(np.array([1, 5, ROOT], np.int32))
    cc = s.connected_components()
    sp = s.sssp(ROOT)
    return {"level": np.asarray(bfs.level), "pred": np.asarray(bfs.pred),
            "blevel": np.asarray(batch.level),
            "bpred": np.asarray(batch.pred),
            "labels": np.asarray(cc.labels), "dist": np.asarray(sp.dist),
            "scanned": np.asarray(bfs.edges_scanned, np.int64)}


if ROLE == "ref":
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={NPROC * DEVS}")
    import numpy as np
    np.savez(sys.argv[4], **run_queries(None))
    print("REF DONE")

elif ROLE == "worker":
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={DEVS}")
    pid = int(os.environ["REPRO_MH_ID"])
    import numpy as np
    from repro.dist import multihost

    multihost.initialize(coordinator_address=f"localhost:{PORT}",
                         num_processes=NPROC, process_id=pid)
    import jax
    assert jax.process_count() == NPROC, jax.process_count()
    assert jax.device_count() == NPROC * DEVS, jax.device_count()
    mesh = multihost.global_mesh((1, NPROC * DEVS), ("r", "c"))
    outs = run_queries(mesh)
    if pid == 0:
        np.savez(sys.argv[4], **outs)
    print(f"WORKER {pid} DONE")

else:
    # orchestrator: reference child, then the process group
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    with tempfile.TemporaryDirectory() as td:
        ref_npz = os.path.join(td, "ref.npz")
        out_npz = os.path.join(td, "out.npz")
        base = [sys.executable, os.path.abspath(__file__),
                str(NPROC), str(DEVS), EXCHANGE]
        r = subprocess.run(base + [ref_npz],
                           env={**env, "REPRO_MH_ROLE": "ref"})
        assert r.returncode == 0, "reference child failed"
        procs = [subprocess.Popen(
                     base + [out_npz],
                     env={**env, "REPRO_MH_ROLE": "worker",
                          "REPRO_MH_ID": str(pid)})
                 for pid in range(NPROC)]
        codes = [p.wait(timeout=900) for p in procs]
        assert codes == [0] * NPROC, f"worker exit codes {codes}"

        import numpy as np
        ref = np.load(ref_npz)
        out = np.load(out_npz)
        for k in ref.files:
            assert (ref[k] == out[k]).all(), \
                f"{k}: multi-host != single-process (exchange={EXCHANGE})"
        print(f"multihost {NPROC}x{DEVS} exchange={EXCHANGE}: "
              f"{len(ref.files)} outputs bit-identical")
        print("OK")
