"""Subprocess integration check for the frontier-program subsystem on a real
device grid (DESIGN.md sec. 8):

  * CC / SSSP / multi-source BFS through `GraphSession` on an R x C
    forced-host-device mesh match the NumPy host references on an R-MAT
    graph AND on a ring (worst-case propagation depth) -- under every fold
    codec, bit-identically;
  * batched SSSP equals per-root SSSP and traces its level loop once;
  * weights planned by `DistGraph.from_edges(..., weights=)` align with the
    partition on a multi-device grid;
  * the degenerate 1 x P topology runs the same programs.

Usage: run_algos.py R C
"""
import os
import sys

R, C = int(sys.argv[1]), int(sys.argv[2])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.algos import (SSSPProgram, cc_reference, multi_bfs_reference,
                         sssp_reference)
from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges

CODECS = ("list", "bitmap", "delta")


def ring_edges(n):
    u = np.arange(n, dtype=np.int64)
    fwd = np.stack([u, (u + 1) % n])
    return np.concatenate([fwd, fwd[::-1]], axis=1)


def check_graph(edges_np, n, config, what, mesh=None, sssp_roots=2):
    rng = np.random.default_rng(7)
    w = rng.integers(1, 256, size=edges_np.shape[1]).astype(np.uint8)
    graph = DistGraph.from_edges(edges_np, config, n=n, weights=w,
                                 mesh=mesh)
    sess = graph.session()

    cc_ref = cc_reference(edges_np, n)
    deg = np.bincount(edges_np[0], minlength=n)
    roots = rng.choice(np.flatnonzero(deg > 0), sssp_roots, replace=False)
    sp_refs = [sssp_reference(edges_np, w, n, int(r)) for r in roots]
    sources = rng.choice(np.flatnonzero(deg > 0), 4, replace=False)
    mb_ref = multi_bfs_reference(edges_np, n, sources)

    for codec in CODECS:
        cc = sess.connected_components(fold_codec=codec)
        assert (np.asarray(cc.labels)[:n] == cc_ref).all(), (what, codec,
                                                             "cc")
        sp = sess.sssp(roots, fold_codec=codec)
        for b in range(len(roots)):
            assert (np.asarray(sp.dist[b])[:n] == sp_refs[b]).all(), \
                (what, codec, "sssp", roots[b])
        mb = sess.multi_bfs(sources, fold_codec=codec)
        assert (np.asarray(mb.level)[:n] == mb_ref[0]).all(), (what, codec,
                                                               "mb level")
        assert (np.asarray(mb.src)[:n] == mb_ref[1]).all(), (what, codec,
                                                             "mb src")

    # batched == per-root, bit-exact, and the sweep traces once
    eng, _ = sess._algo_engine(SSSPProgram(), None, graph.grid.n + 1)
    assert eng.trace_count == 1, f"{what}: SSSP sweep traced more than once"
    s0 = sess.sssp(int(roots[0]))
    sp = sess.sssp(roots)
    assert (np.asarray(sp.dist[0]) == np.asarray(s0.dist)).all(), what
    assert sp.edges_scanned[0] == s0.edges_scanned, what

    # k-hop truncation
    mb2 = sess.multi_bfs(sources, k=2)
    ref2 = multi_bfs_reference(edges_np, n, sources, max_levels=2)
    assert (np.asarray(mb2.level)[:n] == ref2[0]).all(), (what, "k-hop")
    print(f"  {what}: OK")


SCALE, EF = 9, 8
n = 1 << SCALE
rmat = np.asarray(rmat_edges(jax.random.key(0), SCALE, EF))

check_graph(rmat, n, BFSConfig(grid=(R, C), edge_chunk=2048), "rmat 2d")
check_graph(ring_edges(64), 64, BFSConfig(grid=(R, C), edge_chunk=256),
            "ring 2d", sssp_roots=1)

# degenerate 1 x P topology through the same programs
from repro.dist.compat import make_mesh
mesh1 = make_mesh((R * C,), ("p",))
check_graph(rmat, n,
            BFSConfig(grid=(1, R * C), row_axes=(), col_axes=("p",),
                      edge_chunk=2048),
            "rmat 1d", mesh=mesh1, sssp_roots=1)

print("OK")
