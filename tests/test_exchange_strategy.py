"""Exchange-strategy layer coverage (DESIGN.md sec. 14).

  * flat vs butterfly routing delivers byte-identical received arrays for
    random payloads (property-tested over power-of-two C), hence identical
    fold outputs for EVERY codec (the wire arrays routed here are exactly
    the codecs' encoded messages);
  * "auto" resolution + validation rules (butterfly on power-of-two C >= 4
    over one column axis; explicit butterfly on an invalid grid raises a
    ValueError naming flat);
  * `BFSConfig.resolve_exchange` normalises "auto" at session construction
    and the resolved name participates in every engine/AOT cache key (no
    cross-strategy executable reuse, no retrace within a strategy);
  * the accounting formulas (msgs_per_exchange / wire_bytes /
    value_extra_bytes) behind the BENCH flat-vs-butterfly crossover.

The staged ppermute program itself is collective-counted in
tests/test_fold_codecs.py and EXECUTED (with cross-strategy bit-identity)
in tests/dist/run_multihost.py and the multi-device CI smokes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.api import BFSConfig, DistGraph
from repro.core.types import Grid2D
from repro.dist import exchange as X
from repro.dist import strategy as ES
from repro.graphgen import rmat_edges


# ----------------------------------------------------------------------------
# Routing equality (the bit-identity contract, mesh-less)
# ----------------------------------------------------------------------------

def _route_both(x_all):
    return (ES.emulate_exchange(x_all, "flat"),
            ES.emulate_exchange(x_all, "butterfly"))


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 4), st.integers(1, 33), st.integers(0, 10_000))
def test_butterfly_routes_like_flat_property(logc, K, seed):
    """recv[j, m] = sent[m, j] for both strategies, byte for byte, at every
    power-of-two C (including the degenerate C=1 and C=2 single-stage)."""
    C = 1 << logc
    rng = np.random.default_rng(seed)
    x_all = rng.integers(-(1 << 31), 1 << 31, (C, C, K), np.int64) \
        .astype(np.int32)
    flat, bfly = _route_both(x_all)
    want = np.swapaxes(x_all, 0, 1)
    assert (flat == want).all()
    assert (bfly == want).all()
    assert flat.dtype == bfly.dtype == x_all.dtype


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2), st.integers(1, 64), st.integers(0, 10_000))
def test_codec_wire_messages_route_identically(logc, S, seed):
    """For every fold codec: encode each column's buckets, route the
    encoded wire arrays through both strategies, and the received messages
    are byte-identical -- so decode (hence the whole fold) cannot differ.
    The butterfly is store-and-forward: codec payloads are re-fused into
    stage messages but never re-encoded."""
    C = 1 << logc
    rng = np.random.default_rng(seed)
    wires = {"list": [], "bitmap": [], "delta": []}
    for j in range(C):
        dst = np.full((C, S), -1, np.int32)
        cnts = []
        for m in range(C):
            k = int(rng.integers(0, S + 1))
            dst[m, :k] = np.sort(rng.choice(S, size=k, replace=False)) \
                + m * S
            cnts.append(k)
        ids, cnt = jnp.asarray(dst), jnp.asarray(cnts, jnp.int32)
        wires["list"].append(np.asarray(ids))
        wires["bitmap"].append(np.asarray(X.BitmapFold.encode(ids, cnt, S)))
        wires["delta"].append(np.asarray(X.DeltaFold.encode(ids, cnt, S)))
    for name, per_col in wires.items():
        x_all = np.stack(per_col)                # (C, C, ...) encoded wire
        x_flat = x_all.reshape(C, C, -1)
        flat, bfly = _route_both(x_flat)
        assert (flat == bfly).all(), name
        assert flat.dtype == bfly.dtype, name


# ----------------------------------------------------------------------------
# Resolution + validation rules
# ----------------------------------------------------------------------------

def _grid(R, C):
    return Grid2D.for_vertices(R * C * 8, R, C)


@pytest.mark.parametrize("C,want", [(1, "flat"), (2, "flat"), (3, "flat"),
                                    (4, "butterfly"), (6, "flat"),
                                    (8, "butterfly"), (16, "butterfly")])
def test_auto_resolution_rule(C, want):
    """auto = butterfly exactly when it strictly reduces message count:
    power-of-two C >= 4 (log2(C) < C-1) over a single column axis."""
    assert ES.resolve_exchange_name("auto", _grid(1, C), ("c",)) == want
    # multi-axis columns force flat regardless of C
    assert ES.resolve_exchange_name("auto", _grid(1, C),
                                    ("c1", "c2")) == "flat"


def test_explicit_butterfly_validation_errors_name_flat():
    with pytest.raises(ValueError, match="power-of-two.*flat"):
        ES.get_exchange("butterfly", _grid(1, 3), ("c",))
    with pytest.raises(ValueError, match="ONE column.*flat"):
        ES.get_exchange("butterfly", _grid(1, 4), ("c1", "c2"))
    with pytest.raises(ValueError, match="unknown exchange"):
        ES.get_exchange("hypercube", _grid(1, 4), ("c",))
    # instances validate too
    with pytest.raises(ValueError, match="flat"):
        ES.get_exchange(ES.ButterflyExchange(), _grid(1, 6), ("c",))
    assert ES.get_exchange("butterfly", _grid(1, 4), ("c",)).name \
        == "butterfly"
    assert ES.get_exchange("flat", _grid(1, 3), ("c",)).name == "flat"


def test_config_resolves_auto_and_keys_on_exchange():
    cfg = BFSConfig(exchange="auto")
    assert cfg.exchange_name == "auto"
    assert cfg.resolve_exchange(_grid(1, 4)).exchange == "butterfly"
    assert cfg.resolve_exchange(_grid(1, 2)).exchange == "flat"
    # a pinned strategy is validated (not rewritten) by resolve_exchange
    pinned = BFSConfig(exchange="butterfly")
    assert pinned.resolve_exchange(_grid(1, 4)).exchange == "butterfly"
    with pytest.raises(ValueError, match="flat"):
        pinned.resolve_exchange(_grid(1, 3))
    # the exchange name is part of both engine cache keys
    flat, bfly = BFSConfig(exchange="flat"), BFSConfig(exchange="butterfly")
    assert flat.engine_key != bfly.engine_key
    assert flat.algo_engine_key(("cc",), "bitmap", 10) \
        != bfly.algo_engine_key(("cc",), "bitmap", 10)


# ----------------------------------------------------------------------------
# Accounting (the BENCH crossover numbers)
# ----------------------------------------------------------------------------

def test_message_and_byte_accounting():
    flat, bfly = ES.FlatExchange(), ES.ButterflyExchange()
    # message counts: C-1 vs log2(C) -- equal at C=2, strictly fewer from 4
    assert [flat.msgs_per_exchange(c) for c in (1, 2, 4, 8)] == [0, 1, 3, 7]
    assert [bfly.msgs_per_exchange(c) for c in (1, 2, 4, 8)] == [0, 1, 2, 3]
    # set-fold bytes: flat ships C-1 of C buckets once; butterfly ships C/2
    # buckets log2(C) times -- equal at C=4, more volume from C=8
    fb = 800                                     # 8 buckets x 100 bytes
    assert flat.wire_bytes(fb, 8) == fb
    assert bfly.wire_bytes(fb, 8) == (fb // 8) * 4 * 3      # 1200 > 800
    assert bfly.wire_bytes(400, 4) == (400 // 4) * 2 * 2 == 400
    # value-channel bytes: flat = 4 per entry; butterfly = 4 per entry per
    # hop, hops = popcount(j ^ d) (own bucket never travels)
    cnt = jnp.asarray([5, 3, 2, 7], jnp.int32)
    assert int(flat.value_extra_bytes(cnt, jnp.int32(1), 4)) == 4 * 17
    hops = [bin(1 ^ d).count("1") for d in range(4)]        # j = 1
    want = 4 * sum(c * h for c, h in zip([5, 3, 2, 7], hops))
    assert int(bfly.value_extra_bytes(cnt, jnp.int32(1), 4)) == want
    assert hops[1] == 0                          # own bucket: zero hops


# ----------------------------------------------------------------------------
# AOT cache-key participation (no cross-strategy reuse, no retrace within)
# ----------------------------------------------------------------------------

def test_exchange_keys_aot_cache_no_cross_reuse():
    """Two sessions over ONE resident graph differing only in `exchange`
    get separate engines and separate compiled executables; within one
    strategy a repeat query hits the cache without retracing; outputs are
    bit-identical across strategies."""
    edges = np.asarray(rmat_edges(jax.random.key(2), 8, 8))
    g = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=256, expand="reference"),
        n=256)
    s_flat = g.session(BFSConfig(grid=(1, 1), edge_chunk=256,
                                 expand="reference", exchange="flat"))
    s_bfly = g.session(BFSConfig(grid=(1, 1), edge_chunk=256,
                                 expand="reference", exchange="butterfly"))
    assert s_flat.engine is not s_bfly.engine
    assert s_flat.engine.exchange.name == "flat"
    assert s_bfly.engine.exchange.name == "butterfly"

    out_f = s_flat.bfs(3)
    misses = g.cache_stats()["misses"]
    traces = s_bfly.engine.trace_count
    out_b = s_bfly.bfs(3)
    # the butterfly query could NOT reuse the flat executable
    assert g.cache_stats()["misses"] == misses + 1
    # ... and a repeat butterfly query hits without retracing
    out_b2 = s_bfly.bfs(3)
    assert g.cache_stats()["misses"] == misses + 1
    assert s_bfly.engine.trace_count == traces + 1
    for a, b in ((out_f, out_b), (out_b, out_b2)):
        assert (np.asarray(a.level) == np.asarray(b.level)).all()
        assert (np.asarray(a.pred) == np.asarray(b.pred)).all()
        assert a.edges_scanned == b.edges_scanned
