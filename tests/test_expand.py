"""Fused local-expand pipeline coverage (DESIGN.md sec. 9).

  * `local_expand` reference vs pallas-interpret agree BIT-EXACTLY on random
    CSC graphs (hypothesis), including empty frontiers, isolated vertices
    and full-frontier levels -- plus deterministic versions of those edge
    cases so the gate holds where hypothesis is not installed;
  * the value-carrying chunk kernel matches `scan_relax`'s inline formulas;
  * BFS / CC / SSSP / multi-source BFS through the session are bit-identical
    between expand="reference" and expand="pallas-interpret" under every
    fold codec (the acceptance gate of the pallas-interpret CI leg);
  * the selection rules: "auto" resolution, the REPRO_EXPAND override, and
    engine-cache keying by the RESOLVED path;
  * `import repro.kernels` stays lazy (no Pallas modules loaded until a
    kernel symbol is touched).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges
from repro.kernels import expand_chunk_values, local_expand
from repro.kernels.select import EXPAND_ENV, resolve_expand_path

SCALE, EF = 7, 8
N = 1 << SCALE
CODECS = ("list", "bitmap", "delta")
OUT_FIELDS = ("verts", "parents", "count", "visited", "edges_scanned")


def _random_csc(rng, n, max_deg):
    deg = rng.integers(0, max_deg + 1, size=n)
    col_off = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    row_idx = rng.integers(0, n, size=max(int(col_off[-1]), 1)) \
        .astype(np.int32)
    return col_off, row_idx


def _assert_paths_agree(front, cnt, csc, visited, **kw):
    a = local_expand((front, cnt), csc, visited, path="reference", **kw)
    b = local_expand((front, cnt), csc, visited, path="pallas-interpret",
                     **kw)
    for f in OUT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    return a


# ----------------------------------------------------------------------------
# local_expand: reference vs pallas-interpret, property + deterministic
# ----------------------------------------------------------------------------

@given(st.data())
@settings(max_examples=12, deadline=None)
def test_local_expand_paths_agree_property(data):
    """Random CSC graphs, random visited sets, random frontier sizes from
    empty to full -- isolated (zero-degree) vertices arise naturally from
    the degree draw and are also forced into the frontier."""
    n = data.draw(st.integers(8, 48))
    degs = data.draw(st.lists(st.integers(0, 7), min_size=n, max_size=n))
    col_off = np.concatenate([[0], np.cumsum(degs)]).astype(np.int32)
    nnz = max(int(col_off[-1]), 1)
    row_idx = np.asarray(
        data.draw(st.lists(st.integers(0, n - 1), min_size=nnz,
                           max_size=nnz)), np.int32)
    cnt = data.draw(st.integers(0, n))           # empty ... full frontier
    ids = np.sort(np.random.default_rng(
        data.draw(st.integers(0, 2**31))).permutation(n)[:cnt]) \
        .astype(np.int32)
    front = np.full(n, -1, np.int32)
    front[:cnt] = ids
    visited = np.zeros(n, bool)
    visited[np.random.default_rng(
        data.draw(st.integers(0, 2**31))).random(n) < 0.3] = True
    _assert_paths_agree(front, cnt, (col_off, row_idx), visited,
                        edge_chunk=32, tile=16, window=8)


@pytest.mark.parametrize("kind", ["empty", "isolated", "full"])
def test_local_expand_paths_agree_edges(kind, rng):
    """Deterministic pins of the property's edge cases: an empty frontier, a
    frontier of only isolated vertices, and a full-frontier level."""
    n = 64
    col_off, row_idx = _random_csc(rng, n, 4)
    if kind == "isolated":
        col_off = np.zeros(n + 1, np.int32)      # every vertex degree 0
        row_idx = np.zeros(1, np.int32)
    cnt = 0 if kind == "empty" else n
    front = np.full(n, -1, np.int32)
    if cnt:
        front[:] = np.arange(n, dtype=np.int32)
    visited = np.zeros(n, bool)
    out = _assert_paths_agree(front, cnt, (col_off, row_idx), visited,
                              edge_chunk=64, tile=32, window=16)
    if kind in ("empty", "isolated"):
        assert int(out.count) == 0 and int(out.edges_scanned) == 0


def test_local_expand_against_host_reference(rng):
    """Winners = first unvisited occurrence in CSC scan order, compacted
    ascending: check against a plain-python scan."""
    n = 96
    col_off, row_idx = _random_csc(rng, n, 5)
    cnt = 17
    ids = np.sort(rng.choice(n, cnt, replace=False)).astype(np.int32)
    front = np.full(n, -1, np.int32)
    front[:cnt] = ids
    visited = np.zeros(n, bool)
    visited[rng.choice(n, 10, replace=False)] = True
    out = _assert_paths_agree(front, cnt, (col_off, row_idx), visited,
                              edge_chunk=32, tile=16, window=8)
    seen, host = set(), {}
    for u in ids:
        for e in range(col_off[u], col_off[u + 1]):
            v = int(row_idx[e])
            if not visited[v] and v not in seen:
                seen.add(v)
                host[v] = int(u)
    verts = sorted(host)
    np.testing.assert_array_equal(np.asarray(out.verts)[:len(verts)], verts)
    np.testing.assert_array_equal(
        np.asarray(out.parents)[:len(verts)], [host[v] for v in verts])
    assert int(out.count) == len(verts)
    assert int(out.edges_scanned) == sum(
        int(col_off[u + 1] - col_off[u]) for u in ids)


def test_value_chunk_matches_inline(rng):
    """The value-carrying kernel must reproduce scan_relax's inline
    map/gather on every valid lane."""
    n = 80
    col_off, row_idx = _random_csc(rng, n, 6)
    cnt = 23
    ids = np.sort(rng.choice(n, cnt, replace=False)).astype(np.int32)
    front = np.full(n, -1, np.int32)
    front[:cnt] = ids
    payload = rng.integers(0, 1000, size=n).astype(np.int32)
    u_safe = np.clip(front, 0, n - 1)
    deg = col_off[u_safe + 1] - col_off[u_safe]
    deg = np.where(np.arange(n) < cnt, deg, 0)
    cumul = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    total = int(cumul[cnt])
    e = 128
    gids = jnp.arange(e, dtype=jnp.int32)
    v, pay, addr, valid = expand_chunk_values(
        gids, jnp.asarray(cumul), jnp.asarray(front), jnp.asarray(payload),
        jnp.int32(cnt), jnp.asarray(col_off), jnp.asarray(row_idx),
        tile=32, window=16)
    k = np.clip(np.searchsorted(cumul, np.arange(e), side="right") - 1,
                0, n - 1)
    a_ref = np.clip(col_off[u_safe[k]] + np.arange(e) - cumul[k],
                    0, row_idx.shape[0] - 1)
    ok = np.arange(e) < total
    np.testing.assert_array_equal(np.asarray(valid), ok)
    np.testing.assert_array_equal(np.asarray(v)[ok], row_idx[a_ref][ok])
    np.testing.assert_array_equal(np.asarray(pay)[ok], payload[k][ok])
    np.testing.assert_array_equal(np.asarray(addr)[ok], a_ref[ok])


# ----------------------------------------------------------------------------
# Engine-level parity: every program, every codec (the CI-leg gate)
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graphs():
    edges = np.asarray(rmat_edges(jax.random.key(3), SCALE, EF))
    w = np.random.default_rng(1).integers(1, 256, size=edges.shape[1]) \
        .astype(np.uint8)
    out = {}
    for path in ("reference", "pallas-interpret"):
        out[path] = DistGraph.from_edges(
            edges, BFSConfig(grid=(1, 1), edge_chunk=256, expand=path),
            n=N, weights=w)
    return edges, out


@pytest.mark.parametrize("codec", CODECS)
def test_engine_parity_all_programs(graphs, codec):
    edges, gs = graphs
    deg = np.bincount(edges[0], minlength=N)
    roots = np.flatnonzero(deg > 0)[[0, 3, 11]]
    sr = gs["reference"].session(
        BFSConfig(grid=(1, 1), edge_chunk=256, fold_codec=codec,
                  expand="reference"))
    sp = gs["pallas-interpret"].session(
        BFSConfig(grid=(1, 1), edge_chunk=256, fold_codec=codec,
                  expand="pallas-interpret"))
    a, b = sr.bfs(roots), sp.bfs(roots)           # batched sweep parity
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.pred), np.asarray(b.pred))
    assert a.edges_scanned == b.edges_scanned
    ca, cb = (s.connected_components(fold_codec=codec) for s in (sr, sp))
    np.testing.assert_array_equal(np.asarray(ca.labels),
                                  np.asarray(cb.labels))
    assert ca.edges_scanned == cb.edges_scanned
    da, db = (s.sssp(int(roots[1]), fold_codec=codec) for s in (sr, sp))
    np.testing.assert_array_equal(np.asarray(da.dist), np.asarray(db.dist))
    assert da.edges_scanned == db.edges_scanned
    ma, mb = (s.multi_bfs(roots, fold_codec=codec) for s in (sr, sp))
    np.testing.assert_array_equal(np.asarray(ma.level), np.asarray(mb.level))
    np.testing.assert_array_equal(np.asarray(ma.src), np.asarray(mb.src))
    assert ma.edges_scanned == mb.edges_scanned


# ----------------------------------------------------------------------------
# Selection rules + cache keying + lazy import
# ----------------------------------------------------------------------------

def test_resolve_expand_path_rules(monkeypatch):
    monkeypatch.delenv(EXPAND_ENV, raising=False)
    assert resolve_expand_path("reference") == "reference"
    assert resolve_expand_path("pallas-interpret") == "pallas-interpret"
    assert resolve_expand_path("auto", platform="cpu") == "reference"
    assert resolve_expand_path("auto", platform="tpu") == "pallas"
    assert resolve_expand_path(None, platform="gpu") == "pallas"
    monkeypatch.setenv(EXPAND_ENV, "pallas-interpret")
    assert resolve_expand_path("auto", platform="tpu") == "pallas-interpret"
    # explicit spellings are NOT overridden by the environment
    assert resolve_expand_path("reference") == "reference"
    monkeypatch.setenv(EXPAND_ENV, "nonsense")
    with pytest.raises(ValueError, match="REPRO_EXPAND"):
        resolve_expand_path("auto")
    monkeypatch.delenv(EXPAND_ENV)
    with pytest.raises(ValueError, match="expand="):
        resolve_expand_path("cuda-graphs")


def test_config_keys_use_resolved_path(monkeypatch):
    monkeypatch.delenv(EXPAND_ENV, raising=False)
    ref = BFSConfig(expand="reference")
    pal = BFSConfig(expand="pallas-interpret")
    auto = BFSConfig()
    assert ref.engine_key != pal.engine_key
    # "auto" resolves against the ambient backend (cpu -> reference, an
    # accelerator -> pallas); the key must equal the matching explicit one
    expected = resolve_expand_path("auto")
    assert auto.expand_path == expected
    if expected == "reference":
        assert auto.engine_key == ref.engine_key  # same resolved engine
    monkeypatch.setenv(EXPAND_ENV, "pallas-interpret")
    assert auto.expand_path == "pallas-interpret"
    assert auto.engine_key == pal.engine_key      # env re-keys "auto"
    k1 = auto.algo_engine_key(("cc",), "bitmap", 10)
    monkeypatch.delenv(EXPAND_ENV)
    assert auto.algo_engine_key(("cc",), "bitmap", 10) != k1


def test_pick_tile_always_divides_chunk():
    """The kernel grid needs tile | chunk; the fallback must shrink to a
    divisor, never widen to one e-wide tile (the stage-3 dedup is a dense
    (tile, tile) compare -- e-wide would be quadratic in the chunk)."""
    from repro.kernels.expand import _pick_tile

    for e, tile in [(8192, 512), (100_000, 512), (64, 512), (97, 64),
                    (513, 512)]:
        t = _pick_tile(e, tile)
        assert e % t == 0 and t <= max(tile, 1) and t >= 1
    assert _pick_tile(8192, 512) == 512
    assert _pick_tile(100_000, 512) == 500


def test_algo_engines_honor_custom_expand_fn(graphs):
    """config.expand_fn wins over `expand` for ALGO engines too (the
    documented precedence); value scans then fall back to reference."""
    from repro.algos import ConnectedComponentsProgram

    _, gs = graphs

    def marker(*a, **k):                          # never called
        raise AssertionError

    cfg = BFSConfig(grid=(1, 1), edge_chunk=256, expand_fn=marker)
    sess = gs["reference"].session(cfg)
    eng, key = sess._algo_engine(ConnectedComponentsProgram(), None, 10)
    assert eng.expand_path == "custom" and eng.expand_fn is marker
    assert eng.value_expand_fn is None
    # and the cache key must distinguish custom-fn configs
    k2 = BFSConfig(grid=(1, 1), edge_chunk=256) \
        .algo_engine_key(("cc",), "bitmap", 10)
    assert cfg.algo_engine_key(("cc",), "bitmap", 10) != k2


def test_engine_uses_fused_path(graphs):
    _, gs = graphs
    eng_p = gs["pallas-interpret"].engine_for(
        BFSConfig(grid=(1, 1), edge_chunk=256, expand="pallas-interpret"))
    assert eng_p.expand_path == "pallas-interpret"
    assert eng_p.expand_fn is not None and eng_p.value_expand_fn is not None
    eng_r = gs["reference"].engine_for(
        BFSConfig(grid=(1, 1), edge_chunk=256, expand="reference"))
    assert eng_r.expand_path == "reference"
    assert eng_r.expand_fn is None and eng_r.value_expand_fn is None


def test_kernels_import_is_lazy():
    """`import repro.kernels` must not pull Pallas; only touching a kernel
    symbol may (the guard that keeps `import repro` working without it)."""
    code = (
        "import sys, repro, repro.kernels\n"
        "assert 'repro.kernels.expand' not in sys.modules\n"
        "assert 'jax.experimental.pallas' not in sys.modules\n"
        "from repro.kernels import resolve_expand_path\n"
        "assert resolve_expand_path('reference') == 'reference'\n"
        "assert 'jax.experimental.pallas' not in sys.modules\n"
        "from repro.kernels import local_expand\n"
        "assert 'repro.kernels.expand' in sys.modules\n")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
