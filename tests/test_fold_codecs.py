"""Fold wire-format coverage (DESIGN.md sec. 4).

  * pack/unpack bitmap round-trip at non-multiple-of-32 block sizes;
  * delta encode/decode round-trip (pure, no mesh);
  * level/pred equality across fold_codec in {list, bitmap, delta} on the
    same R-MAT graph (multi-device equality runs in tests/dist/);
  * wire-size ordering: bitmap < delta < list for one fold exchange;
  * the compat shim is the only module touching the version-specific
    shard_map / AxisType jax API surface.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier as F
from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist import exchange as X
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc


@pytest.mark.parametrize("S", [1, 7, 31, 32, 33, 63, 64, 65, 96, 127])
def test_pack_bitmap_roundtrip_odd_sizes(S):
    rng = np.random.default_rng(S)
    m = rng.random((4, S)) < 0.4
    packed = F.pack_bitmap(jnp.asarray(m))
    assert packed.dtype == jnp.uint32
    assert packed.shape == (4, (S + 31) // 32)
    got = np.asarray(F.unpack_bitmap(packed, S))
    assert got.shape == m.shape
    assert (got == m).all()


def test_pack_bitmap_pad_bits_are_zero():
    m = jnp.ones((1, 33), bool)                  # 31 pad bits in word 2
    packed = np.asarray(F.pack_bitmap(m))
    assert packed[0, 0] == 0xFFFFFFFF and packed[0, 1] == 1


def test_delta_codec_pure_roundtrip():
    """encode -> decode recovers each bucket's id set, sorted ascending."""
    S, C, j = 64, 4, 2
    rng = np.random.default_rng(0)
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = rng.integers(0, S + 1)
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = m * S + t                   # unsorted local-row ids
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    gaps = X.DeltaFold.encode(jnp.asarray(dst), cnt, S)
    assert gaps.dtype == jnp.uint16
    # pretend every bucket was received by column j (sender-agnostic wire)
    verts, out_cnt = X.DeltaFold.decode(gaps, cnt, jnp.int32(j), S)
    verts = np.asarray(verts)
    for m in range(C):
        want = np.sort(dst[m, :cnts[m]] % S) + j * S
        assert (verts[m, :cnts[m]] == want).all()
        assert (verts[m, cnts[m]:] == -1).all()
    assert (np.asarray(out_cnt) == np.asarray(cnt)).all()


def test_delta_codec_rejects_wide_blocks():
    with pytest.raises(ValueError):
        X.get_fold_codec("delta", Grid2D(1, 1, 1 << 17))


def test_wire_bytes_ordering():
    grid = Grid2D(2, 4, 1 << 12)
    b = {name: X.get_fold_codec(name, grid).wire_bytes(grid)
         for name in X.FOLD_CODECS}
    assert b["bitmap"] < b["delta"] < b["list"]
    assert b["delta"] <= b["list"] // 2 + 4 * grid.C   # 16- vs 32-bit payload


def test_fold_codecs_identical_levels_and_preds():
    """Acceptance: delta == list (== bitmap) on an R-MAT graph, bit-exact."""
    scale, ef, root = 10, 8, 3
    edges = rmat_edges(jax.random.key(1), scale, ef)
    n = 1 << scale
    co, ri = build_csc(edges, n)
    ref, _ = bfs_reference_py(co, ri, root, n)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(np.asarray(edges), grid)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    outs = {}
    for codec in ("list", "bitmap", "delta"):
        out = BFS2D(grid, mesh, edge_chunk=4096, fold_codec=codec).run(g, root)
        assert (np.asarray(out.level)[:n] == ref).all(), codec
        validate_bfs(np.asarray(edges), np.asarray(out.level)[:n],
                     np.asarray(out.pred)[:n], root)
        outs[codec] = out
    for codec in ("bitmap", "delta"):
        assert (np.asarray(outs[codec].level) ==
                np.asarray(outs["list"].level)).all(), codec
        assert (np.asarray(outs[codec].pred) ==
                np.asarray(outs["list"].pred)).all(), codec
        assert outs[codec].edges_scanned == outs["list"].edges_scanned


def test_compat_is_only_direct_importer():
    """No module outside dist/compat.py may touch the version-specific API."""
    root = os.path.join(os.path.dirname(__file__), "..")
    bad = re.compile(r"jax\.shard_map|jax\.experimental\.shard_map"
                     r"|from jax\.sharding import [^\n]*AxisType"
                     r"|jax\.sharding\.AxisType")
    offenders = []
    for base, _, files in os.walk(root):
        if any(part in base for part in
               (".git", ".pytest_cache", "__pycache__", "bench_out")):
            continue
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(base, fn)
            if path.endswith(os.path.join("dist", "compat.py")):
                continue
            with open(path) as f:
                if bad.search(f.read()):
                    offenders.append(os.path.relpath(path, root))
    assert not offenders, f"direct jax API use outside compat: {offenders}"
