"""Fold wire-format coverage (DESIGN.md sec. 4).

  * pack/unpack bitmap round-trip at non-multiple-of-32 block sizes;
  * delta encode/decode round-trip (pure, no mesh);
  * level/pred equality across fold_codec in {list, bitmap, delta} on the
    same R-MAT graph (multi-device equality runs in tests/dist/);
  * wire-size ordering: bitmap < delta < list for one fold exchange;
  * the compat shim is the only module touching the version-specific
    shard_map / AxisType jax API surface.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import frontier as F
from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist import exchange as X
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc


@pytest.mark.parametrize("S", [1, 7, 31, 32, 33, 63, 64, 65, 96, 127])
def test_pack_bitmap_roundtrip_odd_sizes(S):
    rng = np.random.default_rng(S)
    m = rng.random((4, S)) < 0.4
    packed = F.pack_bitmap(jnp.asarray(m))
    assert packed.dtype == jnp.uint32
    assert packed.shape == (4, (S + 31) // 32)
    got = np.asarray(F.unpack_bitmap(packed, S))
    assert got.shape == m.shape
    assert (got == m).all()


def test_pack_bitmap_pad_bits_are_zero():
    m = jnp.ones((1, 33), bool)                  # 31 pad bits in word 2
    packed = np.asarray(F.pack_bitmap(m))
    assert packed[0, 0] == 0xFFFFFFFF and packed[0, 1] == 1


def test_delta_codec_pure_roundtrip():
    """encode -> decode recovers each bucket's id set, sorted ascending."""
    S, C, j = 64, 4, 2
    rng = np.random.default_rng(0)
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = rng.integers(0, S + 1)
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = m * S + t                   # unsorted local-row ids
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    gaps = X.DeltaFold.encode(jnp.asarray(dst), cnt, S)
    assert gaps.dtype == jnp.uint16
    # pretend every bucket was received by column j (sender-agnostic wire)
    verts, out_cnt = X.DeltaFold.decode(gaps, cnt, jnp.int32(j), S)
    verts = np.asarray(verts)
    for m in range(C):
        want = np.sort(dst[m, :cnts[m]] % S) + j * S
        assert (verts[m, :cnts[m]] == want).all()
        assert (verts[m, cnts[m]:] == -1).all()
    assert (np.asarray(out_cnt) == np.asarray(cnt)).all()


def test_delta_codec_rejects_wide_blocks():
    with pytest.raises(ValueError):
        X.get_fold_codec("delta", Grid2D(1, 1, 1 << 17))


def test_wire_bytes_ordering():
    grid = Grid2D(2, 4, 1 << 12)
    b = {name: X.get_fold_codec(name, grid).wire_bytes(grid)
         for name in X.FOLD_CODECS}
    assert b["bitmap"] < b["delta"] < b["list"]
    assert b["delta"] <= b["list"] // 2 + 4 * grid.C   # 16- vs 32-bit payload


def test_fold_codecs_identical_levels_and_preds():
    """Acceptance: delta == list (== bitmap) on an R-MAT graph, bit-exact."""
    scale, ef, root = 10, 8, 3
    edges = rmat_edges(jax.random.key(1), scale, ef)
    n = 1 << scale
    co, ri = build_csc(edges, n)
    ref, _ = bfs_reference_py(co, ri, root, n)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(np.asarray(edges), grid)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    outs = {}
    for codec in ("list", "bitmap", "delta"):
        out = BFS2D(grid, mesh, edge_chunk=4096, fold_codec=codec).run(g, root)
        assert (np.asarray(out.level)[:n] == ref).all(), codec
        validate_bfs(np.asarray(edges), np.asarray(out.level)[:n],
                     np.asarray(out.pred)[:n], root)
        outs[codec] = out
    for codec in ("bitmap", "delta"):
        assert (np.asarray(outs[codec].level) ==
                np.asarray(outs["list"].level)).all(), codec
        assert (np.asarray(outs[codec].pred) ==
                np.asarray(outs["list"].pred)).all(), codec
        assert outs[codec].edges_scanned == outs["list"].edges_scanned


# ----------------------------------------------------------------------------
# Wire-format roundtrips at the frontier-density extremes (satellite: empty,
# full and single-vertex frontiers across list/bitmap/delta).  The exchange
# is emulated without a mesh: each row of the canonical bucket array plays
# the part of one sender's bucket for column j, exactly what the receiver
# sees after the all_to_all.
# ----------------------------------------------------------------------------

I32_MAX = int(np.iinfo(np.int32).max)


def _canonical_buckets(subsets, vals_rng, S, j):
    """Per-sender subsets of [0, S) -> canonical (ids, cnt, vals) arrays
    (ascending, front-packed, id = j*S + t) as `algos.program.pack_blocks`
    produces them."""
    C = len(subsets)
    ids = np.full((C, S), -1, np.int32)
    vals = np.full((C, S), I32_MAX, np.int32)
    cnt = np.zeros((C,), np.int32)
    for m, T in enumerate(subsets):
        T = np.sort(np.asarray(sorted(T), dtype=np.int32))
        ids[m, :len(T)] = j * S + T
        vals[m, :len(T)] = vals_rng.integers(0, 1 << 30, size=len(T))
        cnt[m] = len(T)
    return jnp.asarray(ids), jnp.asarray(cnt), jnp.asarray(vals)


def _emulate_fold_values(codec_name, ids, cnt, vals, S, j):
    """Receiver-side (ids, cnt, vals) for one emulated fold exchange."""
    if codec_name == "list":
        return np.asarray(ids), np.asarray(cnt), np.asarray(vals)
    if codec_name == "bitmap":
        words = X.BitmapFold.encode(ids, cnt, S)
        ri, rc = X.BitmapFold.decode(words, jnp.int32(j), S)
        return np.asarray(ri), np.asarray(rc), np.asarray(vals)
    gaps = X.DeltaFold.encode(ids, cnt, S)
    assert gaps.dtype == jnp.uint16
    ri, rc = X.DeltaFold.decode(gaps, cnt, jnp.int32(j), S)
    return np.asarray(ri), np.asarray(rc), np.asarray(vals)


def _assert_roundtrip(subsets, S, j, seed=0):
    ids, cnt, vals = _canonical_buckets(subsets, np.random.default_rng(seed),
                                        S, j)
    got = {c: _emulate_fold_values(c, ids, cnt, vals, S, j)
           for c in X.FOLD_CODECS}
    for name, (ri, rc, rv) in got.items():
        assert (rc == np.asarray(cnt)).all(), name
        for m, T in enumerate(subsets):
            want = j * S + np.sort(np.asarray(sorted(T), dtype=np.int32))
            k = len(T)
            assert (ri[m, :k] == want).all(), (name, m)
            assert (ri[m, k:] == -1).all(), (name, m)
        # the values channel stays aligned with the delivered id order
        assert (rv == np.asarray(vals)).all(), name


@pytest.mark.parametrize("S", [1, 32, 33, 64])
@pytest.mark.parametrize("kind", ["empty", "single", "full", "mixed"])
def test_fold_values_roundtrip_extremes(S, kind):
    """Deterministic coverage of the density extremes (runs with or without
    hypothesis): empty frontier, single-vertex frontier, full frontier."""
    C, j = 4, 2
    rng = np.random.default_rng(S)
    if kind == "empty":
        subsets = [set() for _ in range(C)]
    elif kind == "single":
        subsets = [{int(rng.integers(0, S))} for _ in range(C)]
    elif kind == "full":
        subsets = [set(range(S)) for _ in range(C)]
    else:   # one empty, one single, one full, one random
        subsets = [set(), {int(rng.integers(0, S))}, set(range(S)),
                   set(rng.choice(S, size=int(rng.integers(0, S + 1)),
                                  replace=False).tolist())]
    _assert_roundtrip(subsets, S, j, seed=S)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 96), st.integers(0, 3), st.integers(0, 10_000))
def test_fold_values_roundtrip_property(S, j, seed):
    """Random per-sender subsets: every codec delivers the identical
    canonical (ids, cnt) set and keeps the values channel aligned."""
    rng = np.random.default_rng(seed)
    C = j + 1 + int(rng.integers(0, 3))
    subsets = [set(rng.choice(S, size=int(rng.integers(0, S + 1)),
                              replace=False).tolist()) for _ in range(C)]
    _assert_roundtrip(subsets, S, j, seed=seed)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 80), st.integers(0, 10_000))
def test_set_fold_encode_decode_property(S, seed):
    """The plain (set-only) bitmap/delta encode/decode pair recovers each
    bucket's id set sorted ascending, at any density including 0 and S."""
    rng = np.random.default_rng(seed)
    C, j = 3, 1
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = int(rng.integers(0, S + 1))
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = j * S + t       # unsorted, as expand produces them
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    for name in ("bitmap", "delta"):
        if name == "bitmap":
            ri, rc = X.BitmapFold.decode(
                X.BitmapFold.encode(jnp.asarray(dst), cnt, S), jnp.int32(j),
                S)
        else:
            ri, rc = X.DeltaFold.decode(
                X.DeltaFold.encode(jnp.asarray(dst), cnt, S), cnt,
                jnp.int32(j), S)
        ri = np.asarray(ri)
        assert (np.asarray(rc) == np.asarray(cnt)).all(), name
        for m in range(C):
            want = np.sort(dst[m, :cnts[m]])
            assert (ri[m, :cnts[m]] == want).all(), (name, m)
            assert (ri[m, cnts[m]:] == -1).all(), (name, m)


def test_compat_is_only_direct_importer():
    """No module outside dist/compat.py may touch the version-specific API."""
    root = os.path.join(os.path.dirname(__file__), "..")
    bad = re.compile(r"jax\.shard_map|jax\.experimental\.shard_map"
                     r"|from jax\.sharding import [^\n]*AxisType"
                     r"|jax\.sharding\.AxisType")
    offenders = []
    for base, _, files in os.walk(root):
        if any(part in base for part in
               (".git", ".pytest_cache", "__pycache__", "bench_out")):
            continue
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(base, fn)
            if path.endswith(os.path.join("dist", "compat.py")):
                continue
            with open(path) as f:
                if bad.search(f.read()):
                    offenders.append(os.path.relpath(path, root))
    assert not offenders, f"direct jax API use outside compat: {offenders}"
