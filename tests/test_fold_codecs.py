"""Fold wire-format coverage (DESIGN.md sec. 4 + 10).

  * pack/unpack bitmap round-trip at non-multiple-of-32 block sizes;
  * delta encode/decode round-trip (pure, no mesh);
  * level/pred equality across fold_codec in {list, bitmap, delta} on the
    same R-MAT graph (multi-device equality runs in tests/dist/);
  * wire-size ordering: bitmap < delta < list for one fold exchange;
  * ONE col_all_to_all per fold (and per value-fold) per level, counted on
    the traced jaxpr of every program x codec (the single-message gate);
  * the Pallas fold kernels (prefix-sum compaction, bitmap pack/unpack,
    delta encode/decode) bit-identical to the reference jnp formulas,
    property-tested incl. S not divisible by 32 and empty/full buckets;
  * fold-path selection rules (REPRO_FOLD, resolved engine cache keys) and
    the delta S > 65536 error surfacing through GraphSession/BFSConfig;
  * the compat shim is the only module touching the version-specific
    shard_map / AxisType jax API surface.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import BFSConfig, DistGraph
from repro.core import frontier as F
from repro.core import Grid2D, partition_2d, bfs_reference_py, validate_bfs
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist import exchange as X
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc
from repro.kernels.select import FOLD_ENV, resolve_fold_path


@pytest.fixture(scope="module")
def fold_ops():
    """The Pallas fold-kernel bundle in interpret mode (CPU-runnable)."""
    from repro.kernels import make_fold_ops

    return make_fold_ops(path="pallas-interpret")


@pytest.mark.parametrize("S", [1, 7, 31, 32, 33, 63, 64, 65, 96, 127])
def test_pack_bitmap_roundtrip_odd_sizes(S):
    rng = np.random.default_rng(S)
    m = rng.random((4, S)) < 0.4
    packed = F.pack_bitmap(jnp.asarray(m))
    assert packed.dtype == jnp.uint32
    assert packed.shape == (4, (S + 31) // 32)
    got = np.asarray(F.unpack_bitmap(packed, S))
    assert got.shape == m.shape
    assert (got == m).all()


def test_pack_bitmap_pad_bits_are_zero():
    m = jnp.ones((1, 33), bool)                  # 31 pad bits in word 2
    packed = np.asarray(F.pack_bitmap(m))
    assert packed[0, 0] == 0xFFFFFFFF and packed[0, 1] == 1


def test_delta_codec_pure_roundtrip():
    """encode -> decode recovers each bucket's id set, sorted ascending."""
    S, C, j = 64, 4, 2
    rng = np.random.default_rng(0)
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = rng.integers(0, S + 1)
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = m * S + t                   # unsorted local-row ids
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    gaps = X.DeltaFold.encode(jnp.asarray(dst), cnt, S)
    assert gaps.dtype == jnp.uint16
    # pretend every bucket was received by column j (sender-agnostic wire)
    verts, out_cnt = X.DeltaFold.decode(gaps, cnt, jnp.int32(j), S)
    verts = np.asarray(verts)
    for m in range(C):
        want = np.sort(dst[m, :cnts[m]] % S) + j * S
        assert (verts[m, :cnts[m]] == want).all()
        assert (verts[m, cnts[m]:] == -1).all()
    assert (np.asarray(out_cnt) == np.asarray(cnt)).all()


def test_delta_codec_rejects_wide_blocks():
    with pytest.raises(ValueError):
        X.get_fold_codec("delta", Grid2D(1, 1, 1 << 17))


def test_wire_bytes_ordering():
    grid = Grid2D(2, 4, 1 << 12)
    b = {name: X.get_fold_codec(name, grid).wire_bytes(grid)
         for name in X.FOLD_CODECS}
    assert b["bitmap"] < b["delta"] < b["list"]
    assert b["delta"] <= b["list"] // 2 + 4 * grid.C   # 16- vs 32-bit payload


def test_fold_codecs_identical_levels_and_preds():
    """Acceptance: delta == list (== bitmap) on an R-MAT graph, bit-exact."""
    scale, ef, root = 10, 8, 3
    edges = rmat_edges(jax.random.key(1), scale, ef)
    n = 1 << scale
    co, ri = build_csc(edges, n)
    ref, _ = bfs_reference_py(co, ri, root, n)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(np.asarray(edges), grid)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    outs = {}
    for codec in ("list", "bitmap", "delta"):
        out = BFS2D(grid, mesh, edge_chunk=4096, fold_codec=codec).run(g, root)
        assert (np.asarray(out.level)[:n] == ref).all(), codec
        validate_bfs(np.asarray(edges), np.asarray(out.level)[:n],
                     np.asarray(out.pred)[:n], root)
        outs[codec] = out
    for codec in ("bitmap", "delta"):
        assert (np.asarray(outs[codec].level) ==
                np.asarray(outs["list"].level)).all(), codec
        assert (np.asarray(outs[codec].pred) ==
                np.asarray(outs["list"].pred)).all(), codec
        assert outs[codec].edges_scanned == outs["list"].edges_scanned


# ----------------------------------------------------------------------------
# Wire-format roundtrips at the frontier-density extremes (satellite: empty,
# full and single-vertex frontiers across list/bitmap/delta).  The exchange
# is emulated without a mesh: each row of the canonical bucket array plays
# the part of one sender's bucket for column j, exactly what the receiver
# sees after the all_to_all.
# ----------------------------------------------------------------------------

I32_MAX = int(np.iinfo(np.int32).max)


def _canonical_buckets(subsets, vals_rng, S, j):
    """Per-sender subsets of [0, S) -> canonical (ids, cnt, vals) arrays
    (ascending, front-packed, id = j*S + t) as `algos.program.pack_blocks`
    produces them."""
    C = len(subsets)
    ids = np.full((C, S), -1, np.int32)
    vals = np.full((C, S), I32_MAX, np.int32)
    cnt = np.zeros((C,), np.int32)
    for m, T in enumerate(subsets):
        T = np.sort(np.asarray(sorted(T), dtype=np.int32))
        ids[m, :len(T)] = j * S + T
        vals[m, :len(T)] = vals_rng.integers(0, 1 << 30, size=len(T))
        cnt[m] = len(T)
    return jnp.asarray(ids), jnp.asarray(cnt), jnp.asarray(vals)


def _emulate_fold_values(codec_name, ids, cnt, vals, S, j, ops=None):
    """Receiver-side (ids, cnt, vals) for one emulated fold exchange."""
    if codec_name == "list":
        return np.asarray(ids), np.asarray(cnt), np.asarray(vals)
    if codec_name == "bitmap":
        words = X.BitmapFold.encode(ids, cnt, S, ops)
        ri, rc = X.BitmapFold.decode(words, jnp.int32(j), S, ops)
        return np.asarray(ri), np.asarray(rc), np.asarray(vals)
    gaps = X.DeltaFold.encode(ids, cnt, S, ops)
    assert gaps.dtype == jnp.uint16
    ri, rc = X.DeltaFold.decode(gaps, cnt, jnp.int32(j), S, ops)
    return np.asarray(ri), np.asarray(rc), np.asarray(vals)


def _assert_roundtrip(subsets, S, j, seed=0, ops=None):
    ids, cnt, vals = _canonical_buckets(subsets, np.random.default_rng(seed),
                                        S, j)
    got = {c: _emulate_fold_values(c, ids, cnt, vals, S, j, ops)
           for c in X.FOLD_CODECS}
    for name, (ri, rc, rv) in got.items():
        assert (rc == np.asarray(cnt)).all(), name
        for m, T in enumerate(subsets):
            want = j * S + np.sort(np.asarray(sorted(T), dtype=np.int32))
            k = len(T)
            assert (ri[m, :k] == want).all(), (name, m)
            assert (ri[m, k:] == -1).all(), (name, m)
        # the values channel stays aligned with the delivered id order
        assert (rv == np.asarray(vals)).all(), name


@pytest.mark.parametrize("path", ["reference", "pallas-interpret"])
@pytest.mark.parametrize("S", [1, 32, 33, 64])
@pytest.mark.parametrize("kind", ["empty", "single", "full", "mixed"])
def test_fold_values_roundtrip_extremes(S, kind, path, request):
    """Deterministic coverage of the density extremes (runs with or without
    hypothesis): empty frontier, single-vertex frontier, full frontier --
    on both the reference formulas and the Pallas fold kernels."""
    ops = request.getfixturevalue("fold_ops") if path != "reference" else None
    C, j = 4, 2
    rng = np.random.default_rng(S)
    if kind == "empty":
        subsets = [set() for _ in range(C)]
    elif kind == "single":
        subsets = [{int(rng.integers(0, S))} for _ in range(C)]
    elif kind == "full":
        subsets = [set(range(S)) for _ in range(C)]
    else:   # one empty, one single, one full, one random
        subsets = [set(), {int(rng.integers(0, S))}, set(range(S)),
                   set(rng.choice(S, size=int(rng.integers(0, S + 1)),
                                  replace=False).tolist())]
    _assert_roundtrip(subsets, S, j, seed=S, ops=ops)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 96), st.integers(0, 3), st.integers(0, 10_000))
def test_fold_values_roundtrip_property(S, j, seed):
    """Random per-sender subsets: every codec delivers the identical
    canonical (ids, cnt) set and keeps the values channel aligned."""
    rng = np.random.default_rng(seed)
    C = j + 1 + int(rng.integers(0, 3))
    subsets = [set(rng.choice(S, size=int(rng.integers(0, S + 1)),
                              replace=False).tolist()) for _ in range(C)]
    _assert_roundtrip(subsets, S, j, seed=seed)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 80), st.integers(0, 10_000))
def test_set_fold_encode_decode_property(S, seed):
    """The plain (set-only) bitmap/delta encode/decode pair recovers each
    bucket's id set sorted ascending, at any density including 0 and S."""
    rng = np.random.default_rng(seed)
    C, j = 3, 1
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = int(rng.integers(0, S + 1))
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = j * S + t       # unsorted, as expand produces them
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    for name in ("bitmap", "delta"):
        if name == "bitmap":
            ri, rc = X.BitmapFold.decode(
                X.BitmapFold.encode(jnp.asarray(dst), cnt, S), jnp.int32(j),
                S)
        else:
            ri, rc = X.DeltaFold.decode(
                X.DeltaFold.encode(jnp.asarray(dst), cnt, S), cnt,
                jnp.int32(j), S)
        ri = np.asarray(ri)
        assert (np.asarray(rc) == np.asarray(cnt)).all(), name
        for m in range(C):
            want = np.sort(dst[m, :cnts[m]])
            assert (ri[m, :cnts[m]] == want).all(), (name, m)
            assert (ri[m, cnts[m]:] == -1).all(), (name, m)


# ----------------------------------------------------------------------------
# Pallas fold kernels (DESIGN.md sec. 10): property-tested bit-identity of
# the prefix-sum compaction, bitmap pack/unpack and delta encode/decode
# against the reference jnp formulas, incl. S not divisible by 32 and
# empty/full buckets.
# ----------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 97), st.integers(0, 10_000))
def test_compact_rows_matches_argsort_property(N, S, seed):
    """The rank-select compaction kernel front-packs exactly like the
    reference stable-argsort path, at any density including 0 and S."""
    from repro.kernels import make_fold_ops

    ops = make_fold_ops(path="pallas-interpret")
    rng = np.random.default_rng(seed)
    density = rng.choice([0.0, 0.25, 0.75, 1.0])
    mask = rng.random((N, S)) < density
    a = rng.integers(-5, 1 << 30, (N, S)).astype(np.int32)
    b = rng.integers(-5, 1 << 30, (N, S)).astype(np.int32)
    (pa, pb), cnt = ops.compact_rows(mask, (a, b), (-1, 7))
    pa, pb, cnt = np.asarray(pa), np.asarray(pb), np.asarray(cnt)
    for r in range(N):
        va, vb = a[r][mask[r]], b[r][mask[r]]
        k = len(va)
        assert cnt[r] == k
        assert (pa[r, :k] == va).all() and (pa[r, k:] == -1).all()
        assert (pb[r, :k] == vb).all() and (pb[r, k:] == 7).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 97), st.integers(0, 10_000))
def test_fold_kernel_bitmap_roundtrip_property(S, seed):
    """pack_bits/unpack_bits == pack_bitmap/unpack_bitmap bit for bit at
    any S (incl. not divisible by 32); roundtrip recovers the mask."""
    from repro.kernels import make_fold_ops

    ops = make_fold_ops(path="pallas-interpret")
    rng = np.random.default_rng(seed)
    mask = rng.random((3, S)) < rng.choice([0.0, 0.3, 1.0])
    words = ops.pack_bits(jnp.asarray(mask))
    ref = F.pack_bitmap(jnp.asarray(mask))
    assert words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(words), np.asarray(ref))
    back = ops.unpack_bits(words, S)
    np.testing.assert_array_equal(np.asarray(back), mask)
    np.testing.assert_array_equal(np.asarray(F.unpack_bitmap(ref, S)), mask)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 80), st.integers(0, 10_000))
def test_fold_kernel_delta_roundtrip_property(S, seed):
    """Kernel delta encode/decode == the reference formulas on random
    buckets at any density (empty and full included), and the decode
    recovers each bucket's sorted id set."""
    from repro.kernels import make_fold_ops

    ops = make_fold_ops(path="pallas-interpret")
    rng = np.random.default_rng(seed)
    C, j = 3, 1
    dst = np.full((C, S), -1, np.int32)
    cnts = []
    for m in range(C):
        k = int(rng.integers(0, S + 1)) if m else rng.choice([0, S])
        t = rng.choice(S, size=k, replace=False)
        dst[m, :k] = j * S + t
        cnts.append(k)
    cnt = jnp.asarray(cnts, jnp.int32)
    g_ref = X.DeltaFold.encode(jnp.asarray(dst), cnt, S)
    g_ker = X.DeltaFold.encode(jnp.asarray(dst), cnt, S, ops)
    assert g_ker.dtype == jnp.uint16
    np.testing.assert_array_equal(np.asarray(g_ker), np.asarray(g_ref))
    r_ref, _ = X.DeltaFold.decode(g_ref, cnt, jnp.int32(j), S)
    r_ker, _ = X.DeltaFold.decode(g_ker, cnt, jnp.int32(j), S, ops)
    np.testing.assert_array_equal(np.asarray(r_ker), np.asarray(r_ref))
    for m in range(C):
        want = np.sort(dst[m, :cnts[m]])
        assert (np.asarray(r_ker)[m, :cnts[m]] == want).all()


def test_fold_kernel_program_helpers_match(fold_ops, rng):
    """pack_blocks / owned_to_front / compact_blocks / expand_exchange_values
    compaction: kernel path == reference path on the same inputs."""
    from repro.algos import program as PR

    grid = Grid2D(1, 4, 4 * 33)                 # S = 33: not a word multiple
    S, C = grid.S, grid.C
    improved = rng.random(C * S) < 0.3
    vals = rng.integers(0, 1 << 20, C * S).astype(np.int32)
    a = PR.pack_blocks(jnp.asarray(improved), jnp.asarray(vals), grid)
    b = PR.pack_blocks(jnp.asarray(improved), jnp.asarray(vals), grid,
                       ops=fold_ops)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    changed = rng.random(S) < 0.4
    ov = rng.integers(0, 1 << 20, S).astype(np.int32)
    a = PR.owned_to_front(jnp.asarray(changed), jnp.asarray(ov), 2, S)
    b = PR.owned_to_front(jnp.asarray(changed), jnp.asarray(ov), 2, S,
                          ops=fold_ops)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    blocks = rng.integers(0, 100, (3, 7)).astype(np.int32)
    cnts = rng.integers(0, 8, 3).astype(np.int32)
    a = F.compact_blocks(jnp.asarray(blocks), jnp.asarray(cnts))
    b = F.compact_blocks(jnp.asarray(blocks), jnp.asarray(cnts),
                         ops=fold_ops)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert int(a[1]) == int(b[1])


# ----------------------------------------------------------------------------
# The single-message gate: ONE col_all_to_all per fold per level, counted on
# the traced jaxpr of the whole engine program (acceptance criterion).
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def _collectives_graph():
    edges = np.asarray(rmat_edges(jax.random.key(5), 8, 8))
    w = np.random.default_rng(0).integers(1, 256, size=edges.shape[1]) \
        .astype(np.uint8)
    return DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), edge_chunk=256, expand="reference",
                         fold="reference"), n=256, weights=w)


@pytest.mark.parametrize("codec", ["list", "bitmap", "delta"])
def test_one_all_to_all_per_fold(_collectives_graph, codec):
    """A whole BFS program contains exactly TWO all_to_all collectives (one
    fused fold in the level loop + the final resolve_preds), and each value
    program exactly ONE -- for every codec.  The pre-overhaul layouts (a
    separate count collective, a dense value-channel collective) would show
    3-4 here."""
    from repro.algos import (ConnectedComponentsProgram,
                             MultiSourceBFSProgram, SSSPProgram)

    g = _collectives_graph
    cs = g.csc
    sess = g.session(BFSConfig(grid=(1, 1), edge_chunk=256, fold_codec=codec,
                               expand="reference", fold="reference"))
    jx = str(jax.make_jaxpr(sess.engine._run.__wrapped__)(
        cs.col_off, cs.row_idx, cs.nnz, jnp.int32(0)))
    assert jx.count("all_to_all") == 2, codec
    for program, extra in ((ConnectedComponentsProgram(), ()),
                           (SSSPProgram(), (g.weights,)),
                           (MultiSourceBFSProgram(), ())):
        eng, _ = sess._algo_engine(program, codec, 8)
        arg = jnp.zeros((3,), jnp.int32) \
            if program.name == "multi_bfs" else jnp.int32(0)
        jx = str(jax.make_jaxpr(eng._run.__wrapped__)(
            cs.col_off, cs.row_idx, cs.nnz, *extra, arg))
        assert jx.count("all_to_all") == 1, (codec, program.name)


@pytest.fixture(scope="module")
def _butterfly_graph():
    """A 1x4 grid on a DUPLICATE-device mesh: the same single CPU device in
    every slot traces shard_map collectives fine (the program is only ever
    `make_jaxpr`-traced here, never executed), which lets the C=4 butterfly
    lower without --xla_force_host_platform_device_count."""
    from repro.dist.compat import make_mesh as mk

    dev = jax.devices()[0]
    fake = mk((1, 4), ("r", "c"), devices=[dev] * 4)
    edges = np.asarray(rmat_edges(jax.random.key(5), 8, 8))
    w = np.random.default_rng(0).integers(1, 256, size=edges.shape[1]) \
        .astype(np.uint8)
    return DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 4), edge_chunk=256, expand="reference",
                         fold="reference"), n=256, weights=w, mesh=fake)


@pytest.mark.parametrize("codec", ["list", "bitmap", "delta"])
@pytest.mark.parametrize("exchange", ["flat", "butterfly"])
def test_exchange_collective_counts(_butterfly_graph, codec, exchange):
    """The exchange-strategy gate on the traced jaxpr at C=4: the flat
    route keeps exactly one all_to_all per fold (two for BFS: the level
    loop + resolve_preds) and zero ppermutes; the butterfly route replaces
    EVERY all_to_all with log2(C)=2 ppermute stages -- for every codec and
    every program."""
    from repro.algos import (ConnectedComponentsProgram,
                             MultiSourceBFSProgram, SSSPProgram)

    g = _butterfly_graph
    cs = g.csc
    sess = g.session(BFSConfig(grid=(1, 4), edge_chunk=256, fold_codec=codec,
                               expand="reference", fold="reference",
                               exchange=exchange))
    stages = 2                                   # log2(C) at C = 4
    jx = str(jax.make_jaxpr(sess.engine._run.__wrapped__)(
        cs.col_off, cs.row_idx, cs.nnz, jnp.int32(0)))
    want_a2a, want_pp = (2, 0) if exchange == "flat" else (0, 2 * stages)
    assert jx.count("all_to_all") == want_a2a, (exchange, codec)
    assert jx.count("ppermute") == want_pp, (exchange, codec)
    for program, extra in ((ConnectedComponentsProgram(), ()),
                           (SSSPProgram(), (g.weights,)),
                           (MultiSourceBFSProgram(), ())):
        eng, _ = sess._algo_engine(program, codec, 8)
        arg = jnp.zeros((3,), jnp.int32) \
            if program.name == "multi_bfs" else jnp.int32(0)
        jx = str(jax.make_jaxpr(eng._run.__wrapped__)(
            cs.col_off, cs.row_idx, cs.nnz, *extra, arg))
        want_a2a, want_pp = (1, 0) if exchange == "flat" else (0, stages)
        assert jx.count("all_to_all") == want_a2a, (exchange, codec,
                                                    program.name)
        assert jx.count("ppermute") == want_pp, (exchange, codec,
                                                 program.name)


# ----------------------------------------------------------------------------
# Fold-path selection rules, cache keys, engine parity, delta block-size
# error surfacing (DESIGN.md sec. 10)
# ----------------------------------------------------------------------------

def test_resolve_fold_path_rules(monkeypatch):
    monkeypatch.delenv(FOLD_ENV, raising=False)
    assert resolve_fold_path("reference") == "reference"
    assert resolve_fold_path("pallas-interpret") == "pallas-interpret"
    assert resolve_fold_path("auto", platform="cpu") == "reference"
    assert resolve_fold_path("auto", platform="tpu") == "pallas"
    assert resolve_fold_path(None, platform="gpu") == "pallas"
    monkeypatch.setenv(FOLD_ENV, "pallas-interpret")
    assert resolve_fold_path("auto", platform="tpu") == "pallas-interpret"
    # explicit spellings are NOT overridden by the environment
    assert resolve_fold_path("reference") == "reference"
    monkeypatch.setenv(FOLD_ENV, "nonsense")
    with pytest.raises(ValueError, match="REPRO_FOLD"):
        resolve_fold_path("auto")
    monkeypatch.delenv(FOLD_ENV)
    with pytest.raises(ValueError, match="fold="):
        resolve_fold_path("zstd")


def test_config_keys_use_resolved_fold_path(monkeypatch):
    monkeypatch.delenv(FOLD_ENV, raising=False)
    ref = BFSConfig(fold="reference")
    pal = BFSConfig(fold="pallas-interpret")
    auto = BFSConfig()
    assert ref.engine_key != pal.engine_key
    expected = resolve_fold_path("auto")
    assert auto.fold_path == expected
    if expected == "reference":
        assert auto.engine_key == ref.engine_key
    monkeypatch.setenv(FOLD_ENV, "pallas-interpret")
    assert auto.fold_path == "pallas-interpret"
    assert auto.engine_key == pal.engine_key      # env re-keys "auto"
    k1 = auto.algo_engine_key(("cc",), "bitmap", 10)
    monkeypatch.delenv(FOLD_ENV)
    assert auto.algo_engine_key(("cc",), "bitmap", 10) != k1


@pytest.mark.parametrize("codec", ["list", "bitmap", "delta"])
def test_fold_paths_bit_identical_through_session(_collectives_graph, codec):
    """BFS + CC through the session: fold="pallas-interpret" ==
    fold="reference", bit for bit (levels, preds, labels, exact counters).
    The full program x codec x path matrix runs in the REPRO_FOLD CI leg."""
    g = _collectives_graph
    outs = {}
    for path in ("reference", "pallas-interpret"):
        s = g.session(BFSConfig(grid=(1, 1), edge_chunk=256,
                                fold_codec=codec, expand="reference",
                                fold=path))
        assert s.engine.fold_path == path
        assert (s.engine.fold_ops is None) == (path == "reference")
        out = s.bfs(jnp.asarray([3, 11], jnp.int32))
        cc = s.connected_components(fold_codec=codec)
        outs[path] = (np.asarray(out.level), np.asarray(out.pred),
                      out.edges_scanned, np.asarray(cc.labels),
                      cc.edges_scanned)
    a, b = outs["reference"], outs["pallas-interpret"]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_delta_block_size_error_names_working_codecs():
    """S > 65536 with fold_codec="delta" must fail at session/engine build
    with an error naming the codecs that DO work at that block size."""
    edges = np.array([[0, 1], [1, 2]])
    n = 1 << 17                                  # 1x1 grid -> S = 131072
    g = DistGraph.from_edges(
        edges, BFSConfig(grid=(1, 1), expand="reference"), n=n)
    with pytest.raises(ValueError) as ei:
        g.session(BFSConfig(grid=(1, 1), fold_codec="delta",
                            expand="reference"))
    msg = str(ei.value)
    assert "delta" in msg and "65536" in msg
    assert "bitmap" in msg and "list" in msg     # the codecs that DO work
    # and the working codecs really do build at this block size
    g.session(BFSConfig(grid=(1, 1), fold_codec="bitmap",
                        expand="reference"))


def test_compat_is_only_direct_importer():
    """No module outside dist/compat.py may touch the version-specific API."""
    root = os.path.join(os.path.dirname(__file__), "..")
    bad = re.compile(r"jax\.shard_map|jax\.experimental\.shard_map"
                     r"|from jax\.sharding import [^\n]*AxisType"
                     r"|jax\.sharding\.AxisType")
    offenders = []
    for base, _, files in os.walk(root):
        if any(part in base for part in
               (".git", ".pytest_cache", "__pycache__", "bench_out")):
            continue
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(base, fn)
            if path.endswith(os.path.join("dist", "compat.py")):
                continue
            with open(path) as f:
                if bad.search(f.read()):
                    offenders.append(os.path.relpath(path, root))
    assert not offenders, f"direct jax API use outside compat: {offenders}"
