import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Grid2D, partition_2d, partition_1d
from repro.core.partition import (local_row, local_col, owner_of, row2col,
                                  global_from_row, global_from_col,
                                  partition_2d_csr)
from repro.graphgen import rmat_edges


@given(R=st.integers(1, 4), C=st.integers(1, 4), logS=st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_index_maps_roundtrip(R, C, logS):
    grid = Grid2D(R, C, R * C * (1 << logS))
    g = np.arange(grid.n)
    i, j = owner_of(g, grid)
    assert ((0 <= i) & (i < R)).all() and ((0 <= j) & (j < C)).all()
    lr = local_row(g, grid)
    assert (global_from_row(lr, i, grid) == g).all()
    lc = local_col(g, grid)
    assert (global_from_col(lc, j, grid) == g).all()
    # ROW2COL on the owner matches LOCAL_COL
    assert (row2col(lr, i, j, grid) == lc).all()


def test_partition_2d_properties():
    """Paper sec 2.2 properties (i) and (ii)."""
    edges = np.asarray(rmat_edges(jax.random.key(0), 9, 8))
    grid = Grid2D.for_vertices(1 << 9, 2, 4)
    lg = partition_2d(edges, grid)
    assert int(lg.nnz.sum()) == edges.shape[1]
    S, ncl = grid.S, grid.n_cols_local
    # reconstruct and check each edge landed at the right processor
    for i in range(grid.R):
        for j in range(grid.C):
            co, ri = lg.col_off[i, j], lg.row_idx[i, j]
            nnz = int(lg.nnz[i, j])
            src_lc = np.repeat(np.arange(ncl), np.diff(co))
            v_lr = ri[:nnz]
            g_u = global_from_col(src_lc, j, grid)            # property (i)
            # every local row block m*S.. maps to a vertex owned in grid row i
            m = v_lr // S
            g_v = (m * grid.R + i) * S + v_lr % S             # property (ii)
            oi, oj = owner_of(g_v, grid)
            assert (oi == i).all(), "dst owner must be in same grid row"
            assert (g_u // ncl == j).all(), "src col must be in column block"


def test_partition_2d_csr_matches_csc():
    edges = np.asarray(rmat_edges(jax.random.key(2), 8, 6))
    grid = Grid2D.for_vertices(1 << 8, 2, 2)
    lg = partition_2d(edges, grid)
    csr = partition_2d_csr(edges, grid)
    assert (csr["nnz"] == np.asarray(lg.nnz)).all()
    for i in range(2):
        for j in range(2):
            nnz = int(lg.nnz[i, j])
            src = np.repeat(np.arange(grid.n_cols_local),
                            np.diff(lg.col_off[i, j]))
            a = set(zip(src.tolist(), lg.row_idx[i, j][:nnz].tolist()))
            dst = np.repeat(np.arange(grid.n_rows_local),
                            np.diff(csr["row_off"][i, j]))
            b = set(zip(csr["col_idx"][i, j][:nnz].tolist(), dst.tolist()))
            assert a == b


def test_partition_1d_modulo():
    edges = np.asarray(rmat_edges(jax.random.key(1), 8, 4))
    n, Pn = 1 << 8, 4
    p = partition_1d(edges, n, Pn)
    assert int(p["nnz"].sum()) == edges.shape[1]
    for proc in range(Pn):
        src_lc = np.repeat(np.arange(n // Pn), np.diff(p["col_off"][proc]))
        g_u = src_lc * Pn + proc
        assert (g_u % Pn == proc).all()


def test_partition_overflow_raises():
    edges = np.asarray(rmat_edges(jax.random.key(1), 8, 4))
    grid = Grid2D.for_vertices(1 << 8, 2, 2)
    with pytest.raises(ValueError):
        partition_2d(edges, grid, pad_to=1)
