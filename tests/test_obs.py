"""Telemetry subsystem coverage (DESIGN.md sec. 13).

  * registry units: get-or-create, kind/label mismatch, counter
    monotonicity, gauge/histogram semantics;
  * Prometheus exposition pinned GOLDEN (the text format is the contract a
    scraper parses) + collector samples + the JSONL event log;
  * LevelTrace: telemetry on/off BIT-IDENTITY per program x codec, every
    trace channel cross-checked against an independent recomputation
    (np.bincount of the output levels, the codec's static wire formulas,
    the 64-bit edges_scanned total, the engine's own directions output);
  * trace discipline: telemetry costs no retrace on repeat sweeps;
  * request tracing: span lifecycle order + tiling, per-tenant retry
    attribution, reset-safety across GraphServer restarts, and the
    deprecated stats surfaces warning + agreeing with the new ones.
"""
import json
import warnings

import jax
import numpy as np
import pytest

from repro.api import BFSConfig, DistGraph
from repro.obs import (PHASES, EventLog, LevelTrace, MetricsRegistry,
                       request_trace, to_prometheus)
from repro.runtime.fault import FaultInjector, StepRunner
from repro.serve import GraphServer, ServeConfig

SCALE, EF = 7, 8
N = 1 << SCALE
CODECS = ("list", "bitmap", "delta")


@pytest.fixture(scope="module")
def graph_data():
    from repro.graphgen import rmat_edges

    edges = np.asarray(rmat_edges(jax.random.key(0), SCALE, EF))
    w = (np.abs(edges[0] * 31 + edges[1]) % 255 + 1).astype(np.uint8)
    cfg = BFSConfig(grid=(1, 1), edge_chunk=256)
    g = DistGraph.from_edges(edges, cfg, n=N, weights=w)
    deg = np.bincount(edges[0], minlength=N)
    roots = np.random.default_rng(1).choice(np.flatnonzero(deg > 0), 8,
                                            replace=False).astype(np.int32)
    return g, roots


def _cfg(codec="list", telemetry=True, direction=False):
    return BFSConfig(grid=(1, 1), fold_codec=codec, edge_chunk=256,
                     telemetry=telemetry, direction=direction)


# ---------------------------------------------------------------------------
# Registry units
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_mismatches():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "help", labelnames=("tenant",))
    assert reg.counter("x_total", labelnames=("tenant",)) is c1
    with pytest.raises(ValueError):        # kind changed
        reg.gauge("x_total", labelnames=("tenant",))
    with pytest.raises(ValueError):        # label set changed
        reg.counter("x_total", labelnames=("graph",))
    with pytest.raises(ValueError):        # wrong labels at bind time
        c1.labels(graph="g").inc()


def test_counter_monotone_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c_total")
    c.inc(); c.inc(2)
    assert c.value == 3 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(5); g.dec()
    assert g.value == 4
    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    plain = h.series()[()]
    assert plain["count"] == 3 and plain["sum"] == pytest.approx(5.55)
    assert list(plain["buckets"].values()) == [1, 2, 3]  # cumulative


def test_prometheus_golden():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Total requests",
                    labelnames=("tenant",))
    c.labels(tenant="alice").inc()
    c.labels(tenant="bob").inc(2)
    reg.gauge("pending", "Pending").set(3)
    h = reg.histogram("lat_seconds", "Latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert to_prometheus(reg) == """\
# HELP lat_seconds Latency
# TYPE lat_seconds histogram
lat_seconds_bucket{le="0.1"} 1
lat_seconds_bucket{le="1.0"} 2
lat_seconds_bucket{le="+Inf"} 3
lat_seconds_sum 5.55
lat_seconds_count 3
# HELP pending Pending
# TYPE pending gauge
pending 3
# HELP requests_total Total requests
# TYPE requests_total counter
requests_total{tenant="alice"} 1
requests_total{tenant="bob"} 2
"""


def test_collector_samples_in_exposition_and_snapshot():
    reg = MetricsRegistry()
    reg.register_collector(
        lambda: [("cache_size", "gauge", "AOT cache", {"graph": "g"}, 7)])
    assert 'cache_size{graph="g"} 7' in to_prometheus(reg)
    assert reg.snapshot()["cache_size"]["series"] == {"graph=g": 7}


def test_event_log_jsonl(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(path)
    log.emit("batch", live=3)
    log.emit("retry", tenants=["a"])
    assert len(log) == 2
    rows = [json.loads(l) for l in path.read_text().splitlines()]
    assert [r["kind"] for r in rows] == ["batch", "retry"]
    assert [r["seq"] for r in rows] == [0, 1] and rows[0]["live"] == 3
    log.close()


def test_request_trace_builder_tiles():
    tr = request_trace(3, "g", "bfs", t_admit=1.0, t_dispatch=1.5,
                      t_exec_start=1.6, t_exec_end=2.0, t_done=2.1, live=4)
    assert [s.name for s in tr.spans] == list(PHASES)
    for a, b in zip(tr.spans, tr.spans[1:]):
        assert a.t1 == b.t0
    assert tr.total_s == pytest.approx(1.1)
    assert tr.span("execute").attrs == {"live": 4}


# ---------------------------------------------------------------------------
# LevelTrace: bit-identity, agreement, trace discipline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODECS)
def test_bitexact_on_off(graph_data, codec):
    g, roots = graph_data
    on, off = g.session(_cfg(codec)), g.session(_cfg(codec, telemetry=False))
    for arg in (int(roots[0]), roots[:4]):
        a, b = on.bfs(arg), off.bfs(arg)
        assert (np.asarray(a.level) == np.asarray(b.level)).all()
        assert (np.asarray(a.pred) == np.asarray(b.pred)).all()
        assert b.trace is None
    assert off.last_trace() is None


@pytest.mark.parametrize("codec", CODECS)
def test_trace_agrees_with_recomputation(graph_data, codec):
    g, roots = graph_data
    sess = g.session(_cfg(codec))
    out = sess.bfs(int(roots[0]))
    tr = sess.last_trace()
    assert isinstance(tr, LevelTrace) and out.trace is tr
    assert tr.codec == codec and tr.grid == (1, 1)
    level = np.asarray(out.level)[:N]
    bc = np.bincount(level[level >= 0])
    assert tr.n_levels == len(bc)
    assert [int(f) for f in tr.frontier] == [int(x) for x in bc]
    assert tr.total_scanned == out.edges_scanned
    wb = sess.engine.codec.wire_bytes(g.grid)   # P = 1: global == per-device
    assert all(int(w) == wb for w in tr.wire_bytes)
    assert (tr.direction == 0).all()            # pure top-down session
    assert tr.frontier_dev.shape == (1, tr.n_levels)
    assert (tr.folded >= 0).all() and tr.folded_dev.shape == \
        (1, tr.n_levels)


def test_batched_trace_per_root(graph_data):
    g, roots = graph_data
    sess = g.session(_cfg())
    out = sess.bfs(roots[:4])
    traces = sess.last_trace()
    assert isinstance(traces, tuple) and len(traces) == 4
    assert out.trace is traces
    levels = np.asarray(out.level)
    for b, tr in enumerate(traces):
        lv = levels[b][:N]
        bc = np.bincount(lv[lv >= 0])
        assert [int(f) for f in tr.frontier] == [int(x) for x in bc]


def test_no_retrace_on_repeat_sweeps(graph_data):
    g, roots = graph_data
    sess = g.session(_cfg())
    sess.bfs(roots[:4])
    count = sess.engine.trace_count
    sess.bfs(roots[4:])                # same B: AOT cache hit
    sess.bfs(roots[:4])
    assert sess.engine.trace_count == count


def test_direction_trace_matches_directions_output(graph_data):
    g, roots = graph_data
    sess = g.session(_cfg(direction=True))
    out = sess.bfs(int(roots[0]))
    tr = sess.last_trace()
    dirs = np.asarray(out.directions)
    assert [int(d) for d in tr.direction] == \
        [int(d) for d in dirs[:tr.n_levels]]


def test_value_fold_traces_for_algos(graph_data):
    """cc / sssp / multi_bfs fold VALUES: per-level wire bytes follow the
    count-proportional formula wb + 4*folded (P = 1)."""
    from repro.dist.exchange import FOLD_CODECS

    g, roots = graph_data
    sess = g.session(_cfg())
    for out in (sess.connected_components(),   # NB: cc hints codec "bitmap"
                sess.sssp(int(roots[0])),
                sess.multi_bfs(roots[:3], k=2)):
        tr = out.trace
        assert isinstance(tr, LevelTrace) and tr.n_levels >= 1
        wb = FOLD_CODECS[tr.codec](g.grid).wire_bytes(g.grid)
        assert all(int(w) == wb + 4 * int(f)
                   for w, f in zip(tr.wire_bytes, tr.folded))


# ---------------------------------------------------------------------------
# Serve layer: spans, per-tenant fault attribution, reset-safety, shims
# ---------------------------------------------------------------------------

def _server(g, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("window_s", 0.01)
    return GraphServer({"g": g}, ServeConfig(**kw))


def test_serve_request_trace_spans(graph_data):
    g, roots = graph_data
    with _server(g) as srv:
        tickets = [srv.bfs("g", int(r), tenant="alice") for r in roots[:3]]
        results = [t.result(timeout=120) for t in tickets]
    for res in results:
        assert res.ok
        tr = res.trace
        assert [s.name for s in tr.spans] == list(PHASES)
        for a, b in zip(tr.spans, tr.spans[1:]):
            assert a.t1 == b.t0                  # spans tile wall-to-wall
        assert res.queued_s == pytest.approx(
            tr.dur_s("queue") + tr.dur_s("coalesce"))
        assert tr.span("execute").attrs["live"] >= 1


def test_serve_per_tenant_retry_attribution(graph_data):
    g, roots = graph_data
    with _server(g) as srv:
        t = srv.bfs("g", int(roots[0]), tenant="alice",
                    injector=FaultInjector({0: RuntimeError}))
        assert t.result(timeout=120).ok        # transient: retry absorbed
        runner = srv._workers["g"].runner
        assert runner.retries_by.get("alice", 0) >= 1
        retry_c = srv.metrics.counter("fault_retries_total",
                                      labelnames=("graph", "tenant"))
        assert retry_c.value_for(("g", "alice")) >= 1
        snap = srv.metrics_snapshot()
        assert snap["runners"]["g"]["retries_by_tenant"]["alice"] >= 1
        assert any(e["kind"] == "retry" for e in srv.events.to_list())


def test_serve_metrics_reset_safe_across_restarts(graph_data):
    """A new GraphServer over the same resident graph starts with clean
    counters (per-server registry), and reset_metrics() re-zeroes a live
    one -- including the runner's retry attribution."""
    g, roots = graph_data
    with _server(g) as srv:
        srv.bfs("g", int(roots[0]), tenant="alice",
                injector=FaultInjector({0: RuntimeError})).result(timeout=120)
        assert srv.accounting.tenants["alice"].queries == 1
        srv.reset_metrics()
        assert srv.accounting.tenants == {}
        assert srv._workers["g"].runner.retries_by == {}
        assert "serve_admitted_total" not in srv.prometheus()
    with _server(g) as srv2:
        assert srv2.accounting.tenants == {}
        assert srv2.metrics_snapshot()["runners"]["g"]["retries"] == 0
        t = srv2.bfs("g", int(roots[1]), tenant="bob")
        assert t.result(timeout=120).ok
        assert set(srv2.accounting.tenants) == {"bob"}
        assert 'serve_admitted_total{tenant="bob"} 1' in srv2.prometheus()


def test_deprecated_stats_surfaces_warn_and_agree(graph_data):
    g, roots = graph_data
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # no warning at call-free use
        with _server(g) as srv:
            srv.bfs("g", int(roots[0])).result(timeout=120)
            srv.drain()
    with pytest.warns(DeprecationWarning, match="metrics_snapshot"):
        legacy = srv.stats()
    assert legacy == srv.metrics_snapshot()
    with pytest.warns(DeprecationWarning, match="cache_stats"):
        legacy_cache = g.aot_cache_stats()
    assert legacy_cache == g.cache_stats()


def test_step_runner_reset_stats():
    runner = StepRunner(lambda st, b: (st, None),
                        injector=FaultInjector({0: RuntimeError}))
    runner.run(0, [None, None], labels=("alice",))
    assert runner.retries == 1 and runner.retries_by == {"alice": 1}
    runner.reset_stats()
    assert runner.retries == 0 and runner.retries_by == {}
    assert runner.watchdog.lat == []
