import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import frontier as F


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_bucket_append_matches_python(data):
    n = data.draw(st.integers(1, 64))
    nb = data.draw(st.integers(1, 6))
    cap = 64
    vals = data.draw(st.lists(st.integers(0, 1000), min_size=n, max_size=n))
    tgt = data.draw(st.lists(st.integers(0, nb - 1), min_size=n, max_size=n))
    take = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    dst = jnp.full((nb, cap), -1, jnp.int32)
    cnt = jnp.zeros((nb,), jnp.int32)
    dst, cnt = F.bucket_append(dst, cnt, jnp.asarray(vals, jnp.int32),
                               jnp.asarray(tgt, jnp.int32),
                               jnp.asarray(take), nb)
    for b in range(nb):
        want = [v for v, t, k in zip(vals, tgt, take) if k and t == b]
        got = np.asarray(dst[b])[:int(cnt[b])].tolist()
        assert got == want


def test_bucket_append_appends_at_offset():
    dst = jnp.full((2, 8), -1, jnp.int32)
    cnt = jnp.zeros((2,), jnp.int32)
    v1 = jnp.asarray([10, 11, 12], jnp.int32)
    t1 = jnp.asarray([0, 1, 0], jnp.int32)
    dst, cnt = F.bucket_append(dst, cnt, v1, t1, jnp.ones(3, bool), 2)
    v2 = jnp.asarray([20, 21], jnp.int32)
    t2 = jnp.asarray([0, 1], jnp.int32)
    dst, cnt = F.bucket_append(dst, cnt, v2, t2, jnp.ones(2, bool), 2)
    assert np.asarray(dst[0])[:3].tolist() == [10, 12, 20]
    assert np.asarray(dst[1])[:2].tolist() == [11, 21]
    assert np.asarray(cnt).tolist() == [3, 2]


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_winner_dedup_first_wins(data):
    n = data.draw(st.integers(1, 64))
    nr = 32
    v = data.draw(st.lists(st.integers(0, nr - 1), min_size=n, max_size=n))
    elig = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    win = np.asarray(F.winner_dedup(jnp.asarray(v, jnp.int32),
                                    jnp.asarray(elig), nr))
    seen = set()
    for s in range(n):
        expect = elig[s] and v[s] not in seen
        if elig[s]:
            seen.add(v[s])
        assert win[s] == expect


@given(S=st.integers(1, 130))
@settings(max_examples=20, deadline=None)
def test_bitmap_roundtrip(S):
    rng = np.random.default_rng(S)
    m = rng.random((3, S)) < 0.3
    packed = F.pack_bitmap(jnp.asarray(m))
    assert packed.dtype == jnp.uint32
    got = np.asarray(F.unpack_bitmap(packed, S))
    assert (got == m).all()


def test_compact_blocks():
    vals = jnp.asarray([[1, 2, -1], [3, -1, -1]], jnp.int32)
    cnts = jnp.asarray([2, 1], jnp.int32)
    out, total = F.compact_blocks(vals, cnts)
    assert int(total) == 3
    assert np.asarray(out)[:3].tolist() == [1, 2, 3]
    assert (np.asarray(out)[3:] == -1).all()


def test_exclusive_cumsum():
    x = jnp.asarray([3, 0, 2], jnp.int32)
    assert np.asarray(F.exclusive_cumsum(x)).tolist() == [0, 3, 3, 5]
