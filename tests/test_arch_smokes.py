"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement f)."""
import pytest

from repro.configs import ARCHS, get_arch

ALL = sorted(ARCHS)


def test_registry_has_all_assigned():
    want = {"kimi-k2-1t-a32b", "qwen2-moe-a2.7b", "glm4-9b", "gemma2-2b",
            "h2o-danube-1.8b", "nequip", "mace", "graphsage-reddit", "egnn",
            "deepfm", "bfs-rmat"}
    assert want <= set(ARCHS)


def test_cells_count():
    """40 assigned cells: 5 LM x 4 + 4 GNN x 4 + 1 recsys x 4."""
    cells = sum(len(a.shapes) for a in ARCHS.values() if a.family != "bfs")
    assert cells == 40


@pytest.mark.parametrize("arch_id", ALL)
def test_smoke(arch_id):
    get_arch(arch_id).smoke()
