"""Session API coverage (DESIGN.md sec. 7).

  * scalar + batched `GraphSession.bfs` vs the python reference;
  * batched-vs-sequential bit-exactness (levels AND preds AND edge counts)
    across all three fold codecs, and for direction optimisation;
  * AOT trace discipline: a 64-root sweep traces/compiles the level loop at
    most once per (codec, direction) pair, and repeat sweeps hit the cache;
  * planning: CSR twin only partitioned when direction is on (lazily on a
    later direction session);
  * config spellings + the deprecated `fold_bitmap` kwarg and driver shims.

Multi-device session checks run in tests/dist/run_session.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import BFSConfig, DistGraph
from repro.core import (Grid2D, bfs_reference_py, partition_2d, validate_bfs)
from repro.core.types import LocalGraph2D
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc

SCALE, EF = 8, 8
N = 1 << SCALE


@pytest.fixture(scope="module")
def graph_data():
    edges = rmat_edges(jax.random.key(0), SCALE, EF)
    edges_np = np.asarray(edges)
    co, ri = build_csc(edges, N)
    deg = np.bincount(edges_np[0], minlength=N)
    roots = np.random.default_rng(1).choice(np.flatnonzero(deg > 0), 64,
                                            replace=False)
    return edges_np, co, ri, roots


def _session(edges_np, codec="list", direction=False):
    cfg = BFSConfig(grid=(1, 1), fold_codec=codec, edge_chunk=512,
                    direction=direction)
    return DistGraph.from_edges(edges_np, cfg, n=N).session()


def test_scalar_bfs_matches_reference(graph_data):
    edges_np, co, ri, roots = graph_data
    sess = _session(edges_np)
    root = int(roots[0])
    out = sess.bfs(root)
    ref, _ = bfs_reference_py(co, ri, root, N)
    assert (np.asarray(out.level)[:N] == ref).all()
    validate_bfs(edges_np, np.asarray(out.level)[:N],
                 np.asarray(out.pred)[:N], root)
    assert isinstance(out.edges_scanned, int) and out.edges_scanned > 0


@pytest.mark.parametrize("codec", ["list", "bitmap", "delta"])
def test_batched_bitexact_vs_sequential(graph_data, codec):
    """session.bfs(batch) levels AND preds identical to looping session.bfs
    per root, for every fold codec."""
    edges_np, co, ri, roots = graph_data
    sess = _session(edges_np, codec=codec)
    batch = roots[:8]
    bout = sess.bfs(batch)
    assert bout.level.shape == (8, sess.graph.grid.n)
    for b, root in enumerate(batch):
        sout = sess.bfs(int(root))
        assert (np.asarray(bout.level[b]) == np.asarray(sout.level)).all()
        assert (np.asarray(bout.pred[b]) == np.asarray(sout.pred)).all()
        assert int(bout.n_levels[b]) == int(sout.n_levels)
        assert bout.edges_scanned[b] == sout.edges_scanned
        ref, _ = bfs_reference_py(co, ri, int(root), N)
        assert (np.asarray(bout.level[b])[:N] == ref).all()


def test_batched_bitexact_direction(graph_data):
    edges_np, co, ri, roots = graph_data
    sess = _session(edges_np, direction=True)
    batch = roots[:6]
    bout = sess.bfs(batch)
    for b, root in enumerate(batch):
        sout = sess.bfs(int(root))
        assert (np.asarray(bout.level[b]) == np.asarray(sout.level)).all()
        assert (np.asarray(bout.pred[b]) == np.asarray(sout.pred)).all()
        ref, _ = bfs_reference_py(co, ri, int(root), N)
        assert (np.asarray(bout.level[b])[:N] == ref).all()
        validate_bfs(edges_np, np.asarray(bout.level[b])[:N],
                     np.asarray(bout.pred[b])[:N], int(root))


@pytest.mark.parametrize("codec,direction",
                         [("list", False), ("bitmap", False),
                          ("delta", False), ("list", True)])
def test_64_root_sweep_traces_once(graph_data, codec, direction):
    """Acceptance: a 64-root sweep traces/compiles the level loop at most
    once per (codec, direction) pair; repeat sweeps are cache hits."""
    edges_np, _, _, roots = graph_data
    sess = _session(edges_np, codec=codec, direction=direction)
    assert sess.engine.trace_count == 0
    out1 = sess.bfs(roots)
    assert sess.engine.trace_count == 1, "sweep must trace exactly once"
    out2 = sess.bfs(roots[::-1].copy())
    assert sess.engine.trace_count == 1, "second sweep must hit the cache"
    assert (np.asarray(out1.level[0]) == np.asarray(out2.level[63])).all()


def test_compiled_cache_shared_across_sessions(graph_data):
    edges_np, _, _, roots = graph_data
    cfg = BFSConfig(grid=(1, 1), fold_codec="list", edge_chunk=512)
    graph = DistGraph.from_edges(edges_np, cfg, n=N)
    s1, s2 = graph.session(), graph.session()
    assert s1.engine is s2.engine, "same engine_key must share the engine"
    s1.bfs(roots[:4])
    s2.bfs(roots[:4])
    assert s1.engine.trace_count == 1, "sessions must share the AOT cache"


def test_csr_only_planned_when_direction_on(graph_data):
    edges_np, co, ri, roots = graph_data
    graph = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(1, 1), edge_chunk=512), n=N)
    assert graph.csr is None, "CSR twin must not be built for top-down only"
    # a later direction session plans it lazily from the retained edges
    dsess = graph.session(BFSConfig(grid=(1, 1), edge_chunk=512,
                                    direction=True))
    assert graph.csr is not None
    root = int(roots[0])
    ref, _ = bfs_reference_py(co, ri, root, N)
    assert (np.asarray(dsess.bfs(root).level)[:N] == ref).all()


def test_csr_required_when_graph_has_no_edges(graph_data):
    edges_np, _, _, _ = graph_data
    grid = Grid2D.for_vertices(N, 1, 1)
    lg = partition_2d(edges_np, grid)
    csc = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                       jnp.asarray(lg.nnz))
    from repro.dist.topology import Topology
    graph = DistGraph(Topology.for_grid(grid), csc)
    with pytest.raises(ValueError, match="CSR"):
        graph.session(BFSConfig(direction=True))


def test_csr_planning_releases_host_edges(graph_data):
    """The retained host edge copy exists only to plan the CSR twin lazily:
    gone once CSR is resident, and `from_edges` never plans CSR eagerly --
    even for a direction config it waits for the first bottom-up consumer."""
    edges_np = graph_data[0]
    lazy_dir = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(1, 1), edge_chunk=512, direction=True),
        n=N)
    assert lazy_dir.csr is None and lazy_dir._edges is not None
    lazy_dir.session()                 # first direction session plans it
    assert lazy_dir.csr is not None and lazy_dir._edges is None
    lazy = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(1, 1), edge_chunk=512), n=N)
    assert lazy._edges is not None
    lazy.ensure_csr()
    assert lazy.csr is not None and lazy._edges is None
    rel = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(1, 1), edge_chunk=512), n=N)
    rel.release_edges()
    with pytest.raises(ValueError, match="CSR"):
        rel.session(BFSConfig(direction=True))


def test_aot_cache_bounded_with_stats(graph_data):
    """Satellite (DESIGN.md sec. 12): a sweep over many batch sizes B stays
    under the AOT-cache cap (LRU eviction), with hit/miss/eviction counters
    surfaced for serve accounting; eviction costs a recompile, never
    correctness."""
    edges_np, co, ri, roots = graph_data
    cfg = BFSConfig(grid=(1, 1), fold_codec="list", edge_chunk=512)
    graph = DistGraph.from_edges(edges_np, cfg, n=N, aot_cache_size=3)
    sess = graph.session()
    for B in range(1, 7):                    # 6 distinct capacity classes
        sess.bfs(roots[:B])
    stats = graph.cache_stats()
    assert len(graph._compiled) <= 3, "cache exceeded its cap"
    assert stats["size"] <= 3 and stats["maxsize"] == 3
    assert stats["misses"] == 6 and stats["evictions"] == 3
    # resident entry -> hit, no retrace; evicted entry -> miss + recompile,
    # and the recompiled sweep is still bit-identical
    traces = sess.engine.trace_count
    out6 = sess.bfs(roots[:6])
    assert graph.cache_stats()["hits"] == stats["hits"] + 1
    assert sess.engine.trace_count == traces
    out1 = sess.bfs(roots[:1])               # B=1 was evicted
    assert graph.cache_stats()["misses"] == stats["misses"] + 1
    assert (np.asarray(out1.level[0]) == np.asarray(out6.level[0])).all()


def test_roots_validated_at_session_boundary(graph_data):
    """Satellite (DESIGN.md sec. 12): bad roots/sources raise clear
    ValueErrors naming n and the expected dtype instead of opaque JAX
    errors mid-trace (serving rejects bad requests before they reach a
    compiled program)."""
    edges_np = graph_data[0]
    sess = _session(edges_np)
    with pytest.raises(ValueError, match=f"n = {N}"):
        sess.bfs(N)
    with pytest.raises(ValueError, match="out-of-range"):
        sess.bfs(np.array([0, -3]))
    with pytest.raises(ValueError, match="integer"):
        sess.bfs(1.5)
    with pytest.raises(ValueError, match="int32"):
        sess.bfs(np.array([0.0, 1.0]))
    with pytest.raises(ValueError, match=f"n = {N}"):
        sess.multi_bfs([0, N + 7])
    with pytest.raises(ValueError, match="integer"):
        sess.multi_bfs(np.array([0.5]))
    cfg = BFSConfig(grid=(1, 1), edge_chunk=512)
    w = (np.arange(edges_np.shape[1]) % 200 + 1).astype(np.uint8)
    wsess = DistGraph.from_edges(edges_np, cfg, n=N, weights=w).session()
    with pytest.raises(ValueError, match=f"n = {N}"):
        wsess.sssp(np.array([1, N]))


def test_session_rejects_mismatched_grid(graph_data):
    edges_np = graph_data[0]
    graph = DistGraph.from_edges(
        edges_np, BFSConfig(grid=(1, 1), edge_chunk=512), n=N)
    with pytest.raises(ValueError, match="re-plan"):
        graph.session(BFSConfig(grid=(2, 2)))
    graph.session(BFSConfig())     # grid=None defers to the resident plan


def test_for_grid_honors_requested_axes(graph_data):
    """Planning without a mesh must build the mesh over the REQUESTED axis
    names (e.g. the degenerate 1 x P spelling with row_axes=())."""
    from repro.dist.topology import Topology

    edges_np = graph_data[0]
    g = DistGraph.from_edges(
        edges_np,
        BFSConfig(grid=(1, 1), row_axes=(), col_axes=("p",), edge_chunk=512),
        n=N)
    assert g.topology.row_axes == () and g.topology.col_axes == ("p",)
    assert g.mesh.axis_names == ("p",)
    out = g.session().bfs(3)
    assert out.level.shape == (g.grid.n,)
    with pytest.raises(ValueError, match="multiple axes"):
        Topology.for_grid(Grid2D.for_vertices(N, 1, 1),
                          row_axes=("a", "b"), col_axes=("c",))


def test_config_grid_spellings(graph_data):
    edges_np, _, _, _ = graph_data
    for spec in [Grid2D.for_vertices(N, 1, 1), (1, 1), "1x1", None]:
        cfg = BFSConfig(grid=spec)
        assert cfg.resolve_grid(N) == Grid2D.for_vertices(N, 1, 1), spec


def test_config_is_hashable_cache_key():
    a = BFSConfig(fold_codec="delta", direction=True)
    b = BFSConfig(fold_codec="delta", direction=True)
    assert a == b and hash(a) == hash(b)
    assert a.engine_key == b.engine_key
    assert a.engine_key != BFSConfig(fold_codec="delta").engine_key


def test_fold_bitmap_kwarg_deprecated(graph_data):
    from repro.api.config import resolve_fold_codec
    from repro.core.bfs2d import BFS2D

    with pytest.warns(DeprecationWarning, match="fold_bitmap"):
        assert resolve_fold_codec(None, True) == "bitmap"
    with pytest.warns(DeprecationWarning, match="fold_bitmap"):
        assert resolve_fold_codec(None, False) == "list"

    edges_np = graph_data[0]
    grid = Grid2D.for_vertices(N, 1, 1)
    mesh = make_mesh((1, 1), ("r", "c"))
    with pytest.warns(DeprecationWarning):
        bfs = BFS2D(grid, mesh, edge_chunk=512, fold_bitmap=True)
    assert bfs.engine.codec.name == "bitmap"   # behaviour kept


def test_driver_shims_deprecated_but_working(graph_data):
    """BFS2D shim warns, runs through the session, and matches it."""
    from repro.core.bfs2d import BFS2D

    edges_np, co, ri, roots = graph_data
    root = int(roots[0])
    grid = Grid2D.for_vertices(N, 1, 1)
    mesh = make_mesh((1, 1), ("r", "c"))
    lg = partition_2d(edges_np, grid)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    with pytest.warns(DeprecationWarning, match="BFS2D"):
        bfs = BFS2D(grid, mesh, edge_chunk=512)
    out = bfs.run(g, root)
    ref, _ = bfs_reference_py(co, ri, root, N)
    assert (np.asarray(out.level)[:N] == ref).all()
    assert bfs.engine.trace_count == 1
    bfs.run(g, root + 0)   # same session + program, no retrace
    assert bfs.engine.trace_count == 1
