import jax
import jax.numpy as jnp
import numpy as np

from repro.graphgen import rmat_edges, build_csc, build_csr, degrees
from repro.graphgen.build import build_csc_np


def test_rmat_shape_and_range():
    e = rmat_edges(jax.random.key(0), 10, 16)
    n = 1 << 10
    assert e.shape == (2, 2 * 16 * n)  # undirected doubling
    assert e.dtype == jnp.int32
    assert int(e.min()) >= 0 and int(e.max()) < n


def test_rmat_deterministic():
    a = rmat_edges(jax.random.key(3), 8, 8)
    b = rmat_edges(jax.random.key(3), 8, 8)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_rmat_symmetric():
    e = np.asarray(rmat_edges(jax.random.key(1), 8, 4))
    half = e.shape[1] // 2
    assert (e[0, :half] == e[1, half:]).all()
    assert (e[1, :half] == e[0, half:]).all()


def test_rmat_degree_skew():
    """R-MAT graphs are heavy-tailed: max degree >> mean degree."""
    n = 1 << 12
    e = rmat_edges(jax.random.key(0), 12, 16)
    deg = np.asarray(degrees(e[0], n))
    assert deg.max() > 8 * deg.mean()


def test_build_csc_roundtrip():
    rng = np.random.default_rng(0)
    n, E = 50, 400
    edges = jnp.asarray(rng.integers(0, n, size=(2, E)), jnp.int32)
    co, ri = build_csc(edges, n)
    assert int(co[-1]) == E
    # every edge recoverable
    src = np.repeat(np.arange(n), np.diff(np.asarray(co)))
    got = set(zip(src.tolist(), np.asarray(ri).tolist()))
    want = set(zip(np.asarray(edges[0]).tolist(), np.asarray(edges[1]).tolist()))
    assert got == want

    co2, ri2 = build_csc_np(np.asarray(edges), n)
    assert (np.asarray(co) == co2).all()
    # same column contents (order within a column may differ across sorts)
    for u in range(n):
        a = sorted(np.asarray(ri)[int(co[u]):int(co[u + 1])].tolist())
        b = sorted(ri2[co2[u]:co2[u + 1]].tolist())
        assert a == b


def test_build_csr_is_transpose():
    rng = np.random.default_rng(1)
    n, E = 30, 200
    edges = jnp.asarray(rng.integers(0, n, size=(2, E)), jnp.int32)
    ro, ci = build_csr(edges, n)
    dst = np.repeat(np.arange(n), np.diff(np.asarray(ro)))
    got = set(zip(np.asarray(ci).tolist(), dst.tolist()))
    want = set(zip(np.asarray(edges[0]).tolist(), np.asarray(edges[1]).tolist()))
    assert got == want
