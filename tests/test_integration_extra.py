"""Extra integration coverage: compressed training end-to-end, MoE quantised
dispatch numerics, spmm2d edge weights, checkpoint+runner integration,
elastic BFS (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

HERE = os.path.dirname(__file__)


def test_train_step_with_gradient_compression():
    """compress_frac path inside make_train_step converges on a quadratic."""
    from repro.train import TrainConfig, make_train_step
    from repro.train.train_step import init_state
    from repro.optim.adamw import AdamWConfig

    target = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    loss = lambda p, b: jnp.sum((p["w"] - target) ** 2)
    tc = TrainConfig(optimizer=AdamWConfig(lr=0.1, weight_decay=0.0,
                                           warmup_steps=0),
                     compress_frac=0.5)
    step = jax.jit(make_train_step(loss, tc))
    st = init_state(tc, {"w": jnp.zeros(4)}).tree()
    assert st["err"] is not None
    for _ in range(300):
        st, info = step(st, None)
    np.testing.assert_allclose(np.asarray(st["params"]["w"]),
                               np.asarray(target), atol=0.1)


def test_moe_quant_dispatch_close_to_exact():
    """int8 dispatch quantisation: same routing, small numeric error."""
    from repro.models import moe as M

    class Cfg:
        n_experts = 8
        top_k = 2
        capacity_factor = 8.0
        cap_e_mult = 64

    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (16, 12))
    mp = {"router": jax.random.normal(ks[1], (12, 8)) * 0.3,
          "w1": jax.random.normal(ks[2], (8, 12, 16)) * 0.2,
          "w3": jax.random.normal(ks[3], (8, 12, 16)) * 0.2,
          "w2": jax.random.normal(ks[4], (8, 16, 12)) * 0.2}
    y_exact, _ = M._moe_local(x, mp["router"], mp["w1"], mp["w3"], mp["w2"],
                              top_k=2, ep=1, capacity_factor=8.0,
                              cap_e_mult=64, n_real=8)
    # quantise the input as the EP path would (ep=1 skips the a2a, so apply
    # the codec manually to bound its error)
    sc = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    xq = jnp.round(x / sc).astype(jnp.int8).astype(jnp.float32) * sc
    y_q, _ = M._moe_local(xq, mp["router"], mp["w1"], mp["w3"], mp["w2"],
                          top_k=2, ep=1, capacity_factor=8.0,
                          cap_e_mult=64, n_real=8)
    rel = float(jnp.linalg.norm(y_q - y_exact) /
                jnp.maximum(jnp.linalg.norm(y_exact), 1e-9))
    assert rel < 0.02, rel


def test_spmm2d_edge_weights_single_cell():
    from jax.sharding import PartitionSpec as P
    from repro.core.spmm2d import spmm2d_device
    from repro.core import Grid2D, partition_2d
    from repro.core.types import LocalGraph2D
    from repro.dist.compat import make_mesh, shard_map
    from repro.graphgen import rmat_edges

    n = 1 << 7
    edges = np.asarray(rmat_edges(jax.random.key(0), 7, 4))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(edges, grid)
    mesh = make_mesh((1, 1), ("r", "c"))
    x = jax.random.normal(jax.random.key(1), (grid.n, 4))
    w = jnp.arange(lg.row_idx.shape[-1], dtype=jnp.float32) % 3

    def f(co, ri, nnz, x, w):
        g = LocalGraph2D(col_off=co[0, 0], row_idx=ri[0, 0], nnz=nnz[0, 0])
        return spmm2d_device(g, x, grid=grid, row_axes=("r",),
                             col_axes=("c",), edge_weight=w)

    dev = P(("r",), ("c",))
    y = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(dev, dev, dev, P(), P()),
        out_specs=P(), check_vma=False))(
            jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
            jnp.asarray(lg.nnz), x, w)
    # dense reference with the same per-edge weights
    A = np.zeros((grid.n, grid.n), np.float32)
    wnp = np.asarray(w)
    nnz = int(lg.nnz[0, 0])
    src = np.repeat(np.arange(grid.n), np.diff(lg.col_off[0, 0]))
    dst = lg.row_idx[0, 0][:nnz]
    for e in range(nnz):
        A[dst[e], src[e]] += wnp[e]
    np.testing.assert_allclose(np.asarray(y), A @ np.asarray(x), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.slow
def test_elastic_bfs_shrink():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist", "run_elastic_bfs.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip().endswith("OK"), r.stdout
