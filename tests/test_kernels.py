"""Per-stage shape/dtype sweeps + property tests vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU).  The FUSED pipeline the
stages compose into is covered by tests/test_expand.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import binsearch_map, clip_cumul, make_expand_fn, \
    visited_filter
from repro.kernels import ref as R


def _cumul(rng, n_seg, max_deg):
    deg = rng.integers(0, max_deg, size=n_seg).astype(np.int32)
    return np.concatenate([[0], np.cumsum(deg)]).astype(np.int32), deg


@pytest.mark.parametrize("tile,window", [(128, 32), (256, 128), (512, 256),
                                         (128, 512)])
@pytest.mark.parametrize("n_seg", [1, 7, 100, 1000])
def test_binsearch_map_sweep(tile, window, n_seg, rng):
    cumul, _ = _cumul(rng, n_seg, 17)
    total = int(cumul[-1])
    e = max(tile, ((total + tile - 1) // tile) * tile)
    gids = jnp.arange(e, dtype=jnp.int32)
    cc = clip_cumul(jnp.asarray(cumul), jnp.int32(n_seg))
    k = np.asarray(binsearch_map(cc, gids, tile=tile, window=window))
    k_ref = np.asarray(R.binsearch_map_ref(jnp.asarray(cumul), gids))
    ok = np.asarray(gids) < total
    np.testing.assert_array_equal(k[ok], k_ref[ok])


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_binsearch_map_property(data):
    """Monotonicity + correctness on arbitrary degree sequences, incl. runs
    of zero-degree frontier vertices (empty CSC columns)."""
    degs = data.draw(st.lists(st.integers(0, 9), min_size=1, max_size=60))
    cumul = np.concatenate([[0], np.cumsum(degs)]).astype(np.int32)
    total = int(cumul[-1])
    if total == 0:
        return
    gids = jnp.arange(128, dtype=jnp.int32)
    cc = clip_cumul(jnp.asarray(cumul), jnp.int32(len(degs)))
    k = np.asarray(binsearch_map(cc, gids, tile=64, window=16))
    valid = np.arange(128) < total
    k_ref = np.asarray(R.binsearch_map_ref(jnp.asarray(cumul), gids))
    np.testing.assert_array_equal(k[valid], k_ref[valid])
    assert (np.diff(k[valid]) >= 0).all()


@pytest.mark.parametrize("tile,window", [(64, 16), (128, 64)])
@pytest.mark.parametrize("n_seg", [1, 13, 64])
def test_fused_gather_stage_sweep(tile, window, n_seg, rng):
    """Stage 2 of the fused pipeline (the old gather_segments role): the
    kernel's v must equal row_idx[col_off[u] + gid - cumul[k]] -- i.e. the
    concatenation of the frontier's CSC columns -- on every valid lane."""
    from repro.kernels import expand_chunk

    ncl = n_seg
    deg = rng.integers(0, 3 * tile // n_seg + 2, size=ncl).astype(np.int32)
    col_off = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    row_idx = rng.integers(0, 10_000, size=max(int(col_off[-1]), 1)) \
        .astype(np.int32)
    front = np.arange(ncl, dtype=np.int32)          # full frontier
    cumul = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    total = int(cumul[-1])
    e = max(tile, ((total + tile - 1) // tile) * tile)
    gids = jnp.arange(e, dtype=jnp.int32)
    v, won, u = expand_chunk(
        gids, jnp.asarray(cumul), jnp.asarray(front), jnp.int32(ncl),
        jnp.asarray(col_off), jnp.asarray(row_idx),
        jnp.zeros((10_000,), bool), tile=tile, window=window)
    concat = np.concatenate(
        [row_idx[col_off[c]:col_off[c + 1]] for c in front] or
        [np.zeros(0, np.int32)])
    np.testing.assert_array_equal(np.asarray(v)[:total], concat)
    assert (np.asarray(v)[total:] == 0).all()       # masked lanes


@pytest.mark.parametrize("tile", [64, 128, 512])
@pytest.mark.parametrize("n_rows", [33, 256, 4096])
def test_visited_filter_sweep(tile, n_rows, rng):
    e = 4 * tile
    v = rng.integers(0, n_rows, size=e).astype(np.int32)
    valid = rng.random(e) < 0.7
    words = rng.integers(0, 2**32, size=(n_rows + 31) // 32,
                         dtype=np.uint64).astype(np.uint32)
    won = np.asarray(visited_filter(jnp.asarray(v), jnp.asarray(valid),
                                    jnp.asarray(words), tile=tile))
    for t in range(4):
        s = slice(t * tile, (t + 1) * tile)
        ref = np.asarray(R.visited_filter_ref(
            jnp.asarray(v[s]), jnp.asarray(valid[s]), jnp.asarray(words)))
        np.testing.assert_array_equal(won[s], ref)


def test_visited_filter_semantics():
    """Paper Alg. 3: only the first slot of a duplicate vertex wins, and
    already-visited vertices never win."""
    words = jnp.asarray(np.array([0b100], np.uint32))  # vertex 2 visited
    v = jnp.asarray([2, 5, 5, 7], jnp.int32)
    valid = jnp.ones(4, bool)
    won = np.asarray(visited_filter(v, valid, words, tile=4))
    assert won.tolist() == [False, True, False, True]


def test_expand_fn_matches_inline(rng):
    """The fused kernel-backed expand_fn must reproduce the inline jnp
    path through `expand_frontier` (the engines' integration point)."""
    from repro.core.frontier import expand_frontier
    from repro.core.types import Grid2D
    from repro.graphgen import rmat_edges
    from repro.core import partition_2d

    n = 1 << 8
    edges = np.asarray(rmat_edges(jax.random.key(2), 8, 6))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(edges, grid)
    co = jnp.asarray(lg.col_off[0, 0])
    ri = jnp.asarray(lg.row_idx[0, 0])
    visited = jnp.zeros((grid.n_rows_local,), bool)
    level = jnp.full((grid.n_rows_local,), -1, jnp.int32)
    pred = jnp.full((grid.n_rows_local,), -1, jnp.int32)
    front = jnp.full((grid.n_cols_local,), -1, jnp.int32).at[0].set(5)

    kw = dict(grid=grid, i=jnp.int32(0), j=jnp.int32(0), edge_chunk=256)
    a = expand_frontier(co, ri, visited, level, pred, front, jnp.int32(1),
                        jnp.int32(1), **kw)
    b = expand_frontier(co, ri, visited, level, pred, front, jnp.int32(1),
                        jnp.int32(1), expand_fn=make_expand_fn(
                            path="pallas-interpret", tile=128, window=64),
                        **kw)
    np.testing.assert_array_equal(np.asarray(a.visited), np.asarray(b.visited))
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.dst_cnt), np.asarray(b.dst_cnt))
