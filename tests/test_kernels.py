"""Per-kernel shape/dtype sweeps + property tests vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import binsearch_map, gather_segments, visited_filter, \
    make_expand_fn
from repro.kernels import ref as R
from repro.kernels.ops import clip_cumul


def _cumul(rng, n_seg, max_deg):
    deg = rng.integers(0, max_deg, size=n_seg).astype(np.int32)
    return np.concatenate([[0], np.cumsum(deg)]).astype(np.int32), deg


@pytest.mark.parametrize("tile,window", [(128, 32), (256, 128), (512, 256),
                                         (128, 512)])
@pytest.mark.parametrize("n_seg", [1, 7, 100, 1000])
def test_binsearch_map_sweep(tile, window, n_seg, rng):
    cumul, _ = _cumul(rng, n_seg, 17)
    total = int(cumul[-1])
    e = max(tile, ((total + tile - 1) // tile) * tile)
    gids = jnp.arange(e, dtype=jnp.int32)
    cc = clip_cumul(jnp.asarray(cumul), jnp.int32(n_seg))
    k = np.asarray(binsearch_map(cc, gids, tile=tile, window=window))
    k_ref = np.asarray(R.binsearch_map_ref(jnp.asarray(cumul), gids))
    ok = np.asarray(gids) < total
    np.testing.assert_array_equal(k[ok], k_ref[ok])


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_binsearch_map_property(data):
    """Monotonicity + correctness on arbitrary degree sequences, incl. runs
    of zero-degree frontier vertices (empty CSC columns)."""
    degs = data.draw(st.lists(st.integers(0, 9), min_size=1, max_size=60))
    cumul = np.concatenate([[0], np.cumsum(degs)]).astype(np.int32)
    total = int(cumul[-1])
    if total == 0:
        return
    gids = jnp.arange(128, dtype=jnp.int32)
    cc = clip_cumul(jnp.asarray(cumul), jnp.int32(len(degs)))
    k = np.asarray(binsearch_map(cc, gids, tile=64, window=16))
    valid = np.arange(128) < total
    k_ref = np.asarray(R.binsearch_map_ref(jnp.asarray(cumul), gids))
    np.testing.assert_array_equal(k[valid], k_ref[valid])
    assert (np.diff(k[valid]) >= 0).all()


@pytest.mark.parametrize("chunk", [4, 32, 128])
@pytest.mark.parametrize("n_seg", [1, 13, 64])
def test_gather_segments_sweep(chunk, n_seg, rng):
    seglen = rng.integers(0, 3 * chunk, size=n_seg).astype(np.int32)
    cum = np.concatenate([[0], np.cumsum(seglen)]).astype(np.int32)
    pool = rng.integers(0, 10_000, size=4096).astype(np.int32)
    off = rng.integers(0, pool.size - 3 * chunk, size=n_seg).astype(np.int32)
    out = gather_segments(jnp.asarray(off), jnp.asarray(cum),
                          jnp.asarray(pool), out_size=int(cum[-1]),
                          chunk=chunk)
    ref = np.asarray(R.gather_segments_ref(
        jnp.asarray(off), jnp.asarray(cum), jnp.asarray(pool),
        int(cum[-1])) if cum[-1] else np.zeros(0, np.int32))
    np.testing.assert_array_equal(np.asarray(out)[:int(cum[-1])],
                                  ref[:int(cum[-1])])


@pytest.mark.parametrize("tile", [64, 128, 512])
@pytest.mark.parametrize("n_rows", [33, 256, 4096])
def test_visited_filter_sweep(tile, n_rows, rng):
    e = 4 * tile
    v = rng.integers(0, n_rows, size=e).astype(np.int32)
    valid = rng.random(e) < 0.7
    words = rng.integers(0, 2**32, size=(n_rows + 31) // 32,
                         dtype=np.uint64).astype(np.uint32)
    won = np.asarray(visited_filter(jnp.asarray(v), jnp.asarray(valid),
                                    jnp.asarray(words), tile=tile))
    for t in range(4):
        s = slice(t * tile, (t + 1) * tile)
        ref = np.asarray(R.visited_filter_ref(
            jnp.asarray(v[s]), jnp.asarray(valid[s]), jnp.asarray(words)))
        np.testing.assert_array_equal(won[s], ref)


def test_visited_filter_semantics():
    """Paper Alg. 3: only the first slot of a duplicate vertex wins, and
    already-visited vertices never win."""
    words = jnp.asarray(np.array([0b100], np.uint32))  # vertex 2 visited
    v = jnp.asarray([2, 5, 5, 7], jnp.int32)
    valid = jnp.ones(4, bool)
    won = np.asarray(visited_filter(v, valid, words, tile=4))
    assert won.tolist() == [False, True, False, True]


def test_expand_fn_matches_inline(rng):
    """The kernel-backed expand_fn must reproduce the inline jnp path."""
    from repro.core.frontier import expand_frontier
    from repro.core.types import Grid2D
    from repro.graphgen import rmat_edges
    from repro.core import partition_2d

    n = 1 << 8
    edges = np.asarray(rmat_edges(jax.random.key(2), 8, 6))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(edges, grid)
    co = jnp.asarray(lg.col_off[0, 0])
    ri = jnp.asarray(lg.row_idx[0, 0])
    visited = jnp.zeros((grid.n_rows_local,), bool)
    level = jnp.full((grid.n_rows_local,), -1, jnp.int32)
    pred = jnp.full((grid.n_rows_local,), -1, jnp.int32)
    front = jnp.full((grid.n_cols_local,), -1, jnp.int32).at[0].set(5)

    kw = dict(grid=grid, i=jnp.int32(0), j=jnp.int32(0), edge_chunk=256)
    a = expand_frontier(co, ri, visited, level, pred, front, jnp.int32(1),
                        jnp.int32(1), **kw)
    b = expand_frontier(co, ri, visited, level, pred, front, jnp.int32(1),
                        jnp.int32(1), expand_fn=make_expand_fn(
                            tile=128, window=64), **kw)
    np.testing.assert_array_equal(np.asarray(a.visited), np.asarray(b.visited))
    np.testing.assert_array_equal(np.asarray(a.level), np.asarray(b.level))
    np.testing.assert_array_equal(np.asarray(a.dst_cnt), np.asarray(b.dst_cnt))
