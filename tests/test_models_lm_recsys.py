import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm as L
from repro.models.recsys import deepfm as D


@pytest.fixture(scope="module")
def dense_cfg():
    return L.LMConfig(name="tiny", n_layers=4, d_model=64, n_heads=4,
                      n_kv_heads=2, d_head=16, d_ff=128, vocab=97,
                      window_pattern=(8, 0), attn_softcap=50.,
                      logit_softcap=30., post_norms=True, tie_embeddings=True,
                      dtype=jnp.float32, remat=False)


def test_lm_forward_shapes_nonan(dense_cfg):
    p = L.init_params(dense_cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    logits, aux = L.forward(dense_cfg, p, toks)
    assert logits.shape == (2, 16, 97)
    assert np.isfinite(np.asarray(logits)).all()


def test_lm_decode_matches_forward(dense_cfg):
    p = L.init_params(dense_cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    logits, _ = L.forward(dense_cfg, p, toks)
    cache = L.init_cache(dense_cfg, 2, 32)
    step = jax.jit(lambda c, t, i: L.decode_step(dense_cfg, p, c, t, i))
    for t in range(16):
        nxt, cache = step(cache, toks[:, t], jnp.int32(t))
    assert (np.asarray(nxt) == np.asarray(jnp.argmax(logits[:, -1], -1))).all()


def test_lm_swa_ring_buffer_decode():
    """Pure-SWA model: cache smaller than the sequence; decode must still
    match the (windowed) forward pass."""
    cfg = L.LMConfig(name="swa", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_head=16, d_ff=64, vocab=31,
                     window_pattern=(4,), dtype=jnp.float32, remat=False)
    p = L.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, 31)
    logits, _ = L.forward(cfg, p, toks)
    cache = L.init_cache(cfg, 1, 64)
    assert cache["k"].shape[2] == 4  # ring buffer = window
    for t in range(12):
        nxt, cache = L.decode_step(cfg, p, cache, toks[:, t], jnp.int32(t))
    assert (np.asarray(nxt) == np.asarray(jnp.argmax(logits[:, -1], -1))).all()


def test_lm_moe_train_grads():
    cfg = L.LMConfig(name="tmoe", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_head=16, d_ff=64, vocab=61,
                     moe=L.MoESettings(n_experts=8, top_k=2, d_ff_expert=32,
                                       n_shared=1),
                     dtype=jnp.float32, remat=False)
    p = L.init_params(cfg, jax.random.key(2))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, 61)
    g = jax.grad(lambda p: L.loss_fn(cfg, p, toks, toks))(p)
    assert float(jnp.abs(g["mlp"]["w1"]).sum()) > 0
    assert float(jnp.abs(g["mlp"]["router"]).sum()) > 0
    assert float(jnp.abs(g["mlp"]["sw1"]).sum()) > 0


def test_lm_param_count_sanity(dense_cfg):
    p = L.init_params(dense_cfg, jax.random.key(0))
    n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(p))
    norms = 2 * 4 * 64 + 2 * 4 * 64 + 64  # pre-norms + post-norms + ln_f
    assert n == dense_cfg.param_count() + norms


def test_deepfm_forward_and_loss():
    cfg = D.DeepFMConfig(name="t", embed_dim=4, mlp=(16, 16),
                         vocabs=(8, 8, 16, 32))
    p = D.init_params(cfg, jax.random.key(0))
    idx = jnp.asarray(np.random.default_rng(0).integers(
        0, 8, size=(6, 4)), jnp.int32)
    logits = D.forward(cfg, p, idx)
    assert logits.shape == (6,)
    y = jnp.asarray([0., 1., 0., 1., 1., 0.])
    loss = D.loss_fn(cfg, p, idx, y)
    g = jax.grad(lambda p: D.loss_fn(cfg, p, idx, y))(p)
    assert np.isfinite(float(loss))
    assert float(jnp.abs(g["table"]).sum()) > 0


def test_deepfm_fm_matches_pairwise():
    """FM identity: 0.5((Σv)² - Σv²) == Σ_{i<j} <v_i, v_j>."""
    cfg = D.DeepFMConfig(name="t", embed_dim=3, mlp=(4,), vocabs=(5, 5, 5))
    p = D.init_params(cfg, jax.random.key(0))
    # zero out mlp + linear + bias to isolate the FM term
    p["mlp"] = [jnp.zeros_like(w) for w in p["mlp"]]
    p["linear"] = jnp.zeros_like(p["linear"])
    idx = jnp.asarray([[1, 2, 3]], jnp.int32)
    got = float(D.forward(cfg, p, idx)[0])
    rows = np.asarray(idx[0]) + np.asarray(D.field_offsets(cfg))
    v = np.asarray(p["table"])[rows]
    want = sum(float(v[i] @ v[j]) for i in range(3) for j in range(i + 1, 3))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_deepfm_retrieval_scoring():
    cfg = D.DeepFMConfig(name="t", embed_dim=4, mlp=(8,), vocabs=(8, 8, 16, 32))
    p = D.init_params(cfg, jax.random.key(0))
    user = jnp.asarray([1, 2], jnp.int32)
    cands = jnp.asarray(np.random.default_rng(1).integers(
        0, 16, size=(100, 2)), jnp.int32)
    s = D.score_candidates(cfg, p, user, cands)
    assert s.shape == (100,) and np.isfinite(np.asarray(s)).all()
