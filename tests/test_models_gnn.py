"""GNN model tests incl. E(3)-equivariance property tests (the Cartesian
l<=2 algebra makes rotation equivariance exact up to float error)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.gnn import e3
from repro.models.gnn.equivariant import (EquivConfig, init_params, apply,
                                          energy_and_forces)
from repro.models.gnn import egnn, graphsage
from repro.sparse import NeighborSampler, embedding_bag
from repro.graphgen import rmat_edges, build_csr


def _rot(key):
    """Random rotation matrix via QR."""
    a = jax.random.normal(key, (3, 3))
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diag(r))[None, :]
    return q * jnp.linalg.det(q)  # det +1


def _mol(key, n=12, cutoff=2.5):
    pos = jax.random.normal(key, (n, 3)) * 1.2
    d = jnp.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    adj = (d < cutoff) & ~jnp.eye(n, dtype=bool)
    src, dst = jnp.nonzero(adj, size=n * n, fill_value=0)
    valid = adj[src, dst]
    return pos, src.astype(jnp.int32), dst.astype(jnp.int32), valid


@pytest.mark.parametrize("corr", [1, 3])  # 1=NequIP-style, 3=MACE-style
def test_equivariant_energy_invariance(corr):
    cfg = EquivConfig(name="t", n_layers=2, d_hidden=8, n_rbf=4, cutoff=2.5,
                      correlation_order=corr)
    key = jax.random.key(0)
    params = init_params(cfg, key)
    pos, src, dst, valid = _mol(jax.random.key(1))
    spec = jax.random.randint(jax.random.key(2), (12,), 0, cfg.n_species)
    e0, _ = apply(cfg, params, spec, pos, src, dst, valid)
    for i in range(3):
        R = _rot(jax.random.key(10 + i))
        t = jax.random.normal(jax.random.key(20 + i), (3,))
        e1, _ = apply(cfg, params, spec, pos @ R.T + t, src, dst, valid)
        np.testing.assert_allclose(float(e0), float(e1), rtol=2e-4)


def test_equivariant_force_covariance():
    """F(Rx) = R F(x): forces rotate with the frame."""
    cfg = EquivConfig(name="t", n_layers=2, d_hidden=8, n_rbf=4, cutoff=2.5,
                      correlation_order=3)
    params = init_params(cfg, jax.random.key(0))
    pos, src, dst, valid = _mol(jax.random.key(1))
    spec = jax.random.randint(jax.random.key(2), (12,), 0, cfg.n_species)
    _, f0 = energy_and_forces(cfg, params, spec, pos, src, dst, valid)
    R = _rot(jax.random.key(5))
    _, f1 = energy_and_forces(cfg, params, spec, pos @ R.T, src, dst, valid)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f0 @ R.T),
                               rtol=5e-3, atol=1e-5)


def test_traceless_sym_projects():
    m = jax.random.normal(jax.random.key(0), (4, 3, 3))
    t = e3.traceless_sym(m)
    np.testing.assert_allclose(np.asarray(jnp.trace(t, axis1=-2, axis2=-1)),
                               0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(t), np.asarray(
        jnp.swapaxes(t, -1, -2)), atol=1e-6)


def test_egnn_equivariance():
    cfg = egnn.EGNNConfig(name="t", n_layers=3, d_hidden=16, d_in=4)
    params = egnn.init_params(cfg, jax.random.key(0))
    pos, src, dst, valid = _mol(jax.random.key(1))
    feats = jax.random.normal(jax.random.key(2), (12, 4))
    e0, h0, x0 = egnn.apply(cfg, params, feats, pos, src, dst, valid)
    R = _rot(jax.random.key(3))
    t = jnp.asarray([1., -2., 0.5])
    e1, h1, x1 = egnn.apply(cfg, params, feats, pos @ R.T + t, src, dst, valid)
    np.testing.assert_allclose(float(e0), float(e1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x0 @ R.T + t),
                               rtol=1e-3, atol=1e-4)


def test_graphsage_fullgraph_and_grad():
    n = 1 << 8
    edges = rmat_edges(jax.random.key(0), 8, 4)
    cfg = graphsage.SAGEConfig(name="t", n_layers=2, d_hidden=16, d_in=8,
                               n_classes=5)
    params = graphsage.init_params(cfg, jax.random.key(1))
    feats = jax.random.normal(jax.random.key(2), (n, 8))
    labels = jax.random.randint(jax.random.key(3), (n,), 0, 5)
    loss = graphsage.loss_fn(cfg, params, feats, edges[0], edges[1], labels)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: graphsage.loss_fn(cfg, p, feats, edges[0],
                                             edges[1], labels))(params)
    assert float(jnp.abs(g["layers"][0]["w_neigh"]).sum()) > 0


def test_graphsage_sampled_block():
    n = 1 << 8
    edges = np.asarray(rmat_edges(jax.random.key(0), 8, 4))
    ro, ci = build_csr(jnp.asarray(edges), n)
    sampler = NeighborSampler(np.asarray(ro), np.asarray(ci), seed=0)
    seeds = np.arange(16)
    block = sampler.sample_block(seeds, [5, 3])
    assert block["nodes"][1].shape == (16 * 5,)
    assert block["nodes"][2].shape == (16 * 5 * 3,)
    cfg = graphsage.SAGEConfig(name="t", n_layers=2, d_hidden=16, d_in=8,
                               n_classes=5)
    params = graphsage.init_params(cfg, jax.random.key(1))
    feats = jax.random.normal(jax.random.key(2), (n, 8))
    bf = [feats[jnp.asarray(nd)] for nd in block["nodes"]]
    logits = graphsage.apply_block(cfg, params, bf, [5, 3])
    assert logits.shape == (16, 5)
    assert np.isfinite(np.asarray(logits)).all()


@given(mode=st.sampled_from(["sum", "mean"]))
@settings(max_examples=10, deadline=None)
def test_embedding_bag_matches_manual(mode):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 4)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-1, 50, size=(6, 5)), jnp.int32)
    out = embedding_bag(table, idx, mode=mode)
    for b in range(6):
        sel = [int(i) for i in np.asarray(idx[b]) if i >= 0]
        if not sel:
            continue
        man = np.asarray(table)[sel].sum(0)
        if mode == "mean":
            man = man / len(sel)
        np.testing.assert_allclose(np.asarray(out[b]), man, rtol=1e-5)
