"""Optimizer / compression / train step / checkpoint / elastic / fault tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.optim import (adamw_init, adamw_update, AdamWConfig,
                         topk_compress_init, topk_compress, int8_compress,
                         int8_decompress)
from repro.train import TrainConfig, make_train_step
from repro.train.train_step import init_state, state_shardings
from repro.ckpt import CheckpointManager, reshard_state
from repro.ckpt.elastic import shrink_grid
from repro.runtime import StepRunner, RetryPolicy, FaultInjector, \
    StragglerWatchdog
from repro.data import synthetic_lm_batches
from jax.sharding import PartitionSpec as P


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros((3,))}

    def loss(p, batch):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


def test_adamw_converges():
    params, loss, target = _quad_problem()
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10_000)
    for _ in range(300):
        g = jax.grad(loss)(params, None)
        params, opt, info = adamw_update(cfg, params, g, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=2e-2)


def test_topk_error_feedback_converges():
    """With error feedback, even top-1-of-3 sparsification converges (SGD;
    EF is the standard companion of SGD-style updates)."""
    params, loss, target = _quad_problem()
    err = topk_compress_init(params)
    for _ in range(400):
        g = jax.grad(loss)(params, None)
        comp, err, densify = topk_compress(g, err, frac=0.34)
        g = densify(comp, params)
        params = jax.tree.map(lambda p, g: p - 0.2 * g, params, g)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=5e-2)


def test_topk_error_feedback_preserves_mass():
    """Dropped coordinates reappear via the residual (nothing is lost)."""
    g = {"w": jnp.asarray([3.0, 1.0, 0.1])}
    err = topk_compress_init(g)
    comp, err, densify = topk_compress(g, err, frac=0.34)
    dense = densify(comp, g)
    np.testing.assert_allclose(np.asarray(dense["w"] + err["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


def test_int8_roundtrip():
    g = jax.random.normal(jax.random.key(0), (128,)) * 3
    q, s = int8_compress(g)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(int8_decompress(q, s)),
                               np.asarray(g), atol=float(s) * 0.51)


def test_train_step_microbatching():
    params, loss, target = _quad_problem()
    tc = TrainConfig(optimizer=AdamWConfig(lr=0.05, weight_decay=0.0,
                                           warmup_steps=0),
                     microbatches=4)
    step = jax.jit(make_train_step(lambda p, b: loss(p, b), tc))
    st = init_state(tc, params).tree()
    batch = jnp.zeros((4, 1))  # leading microbatch axis
    for _ in range(200):
        st, info = step(st, batch)
    np.testing.assert_allclose(np.asarray(st["params"]["w"]),
                               np.asarray(target), atol=5e-2)


def test_state_shardings_zero():
    specs = {"w": P(None, "model"), "b": P(None)}
    ss = state_shardings(specs, data_axes=("data",))
    assert ss["mu"]["w"] == P(("data",), "model")
    assert ss["mu"]["b"] == P(("data",))
    assert ss["step"] == P()


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    cm.save(10, tree, extra_meta={"mesh": [2, 4]})
    cm.save(20, tree)
    cm.save(30, tree)
    assert cm.steps() == [20, 30]  # keep=2 garbage-collected step 10
    got, mani = cm.restore(tree)
    assert mani["step"] == 30
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=True)
    tree = {"a": jnp.zeros(1000)}
    cm.save(1, tree)
    cm.wait()
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_reshard_drops_missing_axes():
    from repro.dist.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    tree = {"w": np.ones((4, 4), np.float32)}
    spec = {"w": P(("pod", "data"), "model")}  # pod/model don't exist now
    out = reshard_state(tree, spec, mesh)
    assert out["w"].shape == (4, 4)


def test_shrink_grid():
    assert shrink_grid(4, 4, 1) in [(3, 5), (5, 3)]
    r, c = shrink_grid(16, 16, 3)
    assert r * c <= 253


def test_shrink_grid_prefers_original_aspect():
    # wide 2x4 losing 2 devices: 2x3 and 3x2 both use all 6 survivors; the
    # aspect tie-break keeps the wide shape
    assert shrink_grid(2, 4, 2) == (2, 3)
    assert shrink_grid(4, 2, 2) == (3, 2)
    # square 2x2 losing 1: 1x3 vs 3x1 equidistant -> lower row count
    assert shrink_grid(2, 2, 1) == (1, 3)
    with pytest.raises(ValueError):
        shrink_grid(1, 2, 2)


@given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 64))
@settings(max_examples=200, deadline=None)
def test_shrink_grid_maximal_and_valid(R, C, failed):
    survivors = R * C - failed
    if survivors < 1:
        with pytest.raises(ValueError):
            shrink_grid(R, C, failed)
        return
    r, c = shrink_grid(R, C, failed)
    assert r >= 1 and c >= 1 and r * c <= survivors
    # maximality: no factor pair fits more devices
    best = max(rr * (survivors // rr) for rr in range(1, survivors + 1))
    assert r * c == best


def test_retry_policy_jitter_deterministic():
    p = RetryPolicy(backoff_s=0.01, backoff_mult=2.0, jitter_s=0.005, seed=3)
    d = [p.delay_for(step=4, attempt=a) for a in range(3)]
    # pure function of (seed, step, attempt): replays identically
    assert d == [p.delay_for(step=4, attempt=a) for a in range(3)]
    for a, di in enumerate(d):
        base = 0.01 * 2.0 ** a
        assert base <= di < base + 0.005
    # a different seed de-correlates (workers must not stampede in lockstep)
    q = RetryPolicy(backoff_s=0.01, backoff_mult=2.0, jitter_s=0.005, seed=4)
    assert [q.delay_for(4, a) for a in range(3)] != d
    # jitter off: exact exponential backoff
    assert RetryPolicy(backoff_s=0.01, jitter_s=0.0).delay_for(0, 2) \
        == pytest.approx(0.04)


def test_step_runner_records_delays():
    def step(state, batch):
        return state + 1, {}

    inj = FaultInjector({1: RuntimeError, 2: RuntimeError})
    policy = RetryPolicy(max_retries=2, backoff_s=1e-4, jitter_s=1e-4,
                         seed=11)
    runner = StepRunner(step, policy=policy, injector=inj)
    runner.run(0, range(4))
    assert runner.delays == [policy.delay_for(1, 0), policy.delay_for(2, 0)]
    runner.reset_stats()
    assert runner.delays == []


def test_checkpoint_async_error_reraised(tmp_path):
    """A failed background write surfaces on the next wait()/save() instead
    of silently dropping the checkpoint."""
    cm = CheckpointManager(str(tmp_path), async_write=True)
    # pre-create the staging path as a FILE: the writer thread's makedirs
    # blows up in the background
    open(os.path.join(str(tmp_path), "step_5.tmp"), "w").close()
    cm.save(5, {"a": jnp.zeros(4)})
    with pytest.raises(FileExistsError):
        cm.wait()
    # the error is consumed; the manager keeps working afterwards
    cm.save(6, {"a": jnp.zeros(4)})
    cm.wait()
    assert cm.steps() == [6]


def test_checkpoint_async_error_reraised_on_next_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=True)
    open(os.path.join(str(tmp_path), "step_1.tmp"), "w").close()
    cm.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(FileExistsError):
        cm.save(2, {"a": jnp.zeros(2)})


def test_step_runner_retry_and_straggler():
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        return state + 1, {"loss": 0.0}

    inj = FaultInjector({2: RuntimeError, 5: RuntimeError})
    runner = StepRunner(step, policy=RetryPolicy(max_retries=2,
                                                 backoff_s=0.001),
                        injector=inj)
    state, infos = runner.run(0, range(8))
    assert state == 8            # every step eventually succeeded
    assert runner.retries == 2   # one retry per injected failure
    assert inj.calls == 2

    wd = StragglerWatchdog(factor=2.0)
    for i in range(40):
        wd.record(i, 0.01)
    assert wd.record(40, 0.2)    # 20x slower -> flagged


def test_step_runner_restore_path(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)

    class Always(Exception):
        pass

    crash_at = {"step": 3}

    def step(state, batch):
        if batch == crash_at["step"]:
            raise Always("hard failure")
        return state + 1, {}

    runner = StepRunner(step, policy=RetryPolicy(max_retries=1,
                                                 backoff_s=0.001),
                        ckpt=cm, ckpt_every=1)
    state, _ = runner.run(jnp.zeros(()), range(6))
    assert runner.restores == 1  # restored from checkpoint instead of dying


def test_synthetic_data_shapes():
    it = synthetic_lm_batches(101, 4, 16, n_batches=3)
    batches = list(it)
    assert len(batches) == 3
    t, l = batches[0]
    assert t.shape == (4, 16) and l.shape == (4, 16)
    assert (t[:, 1:] == l[:, :-1]).all()  # labels are next tokens
    assert t.max() < 101 and t.min() >= 0
