import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Grid2D, bfs_reference_py, bfs_single, partition_2d,
                        validate_bfs, count_component_edges)
from repro.core.bfs2d import BFS2D
from repro.core.types import LocalGraph2D
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges, build_csc


def _graph(scale=8, ef=8, seed=0):
    edges = rmat_edges(jax.random.key(seed), scale, ef)
    n = 1 << scale
    co, ri = build_csc(edges, n)
    return edges, n, co, ri


def test_bfs_single_matches_python():
    edges, n, co, ri = _graph()
    for root in (0, 7, 200):
        lr, pr = bfs_reference_py(co, ri, root, n)
        lvl, pred = bfs_single(co, ri, root)
        assert (np.asarray(lvl) == lr).all()
        validate_bfs(np.asarray(edges), np.asarray(lvl), np.asarray(pred), root)


def test_bfs_single_ring():
    n = 16
    src = np.arange(n)
    edges = jnp.asarray(np.stack([np.concatenate([src, (src + 1) % n]),
                                  np.concatenate([(src + 1) % n, src])]),
                        jnp.int32)
    co, ri = build_csc(edges, n)
    lvl, _ = bfs_single(co, ri, 0)
    want = np.minimum(np.arange(n), n - np.arange(n))
    assert (np.asarray(lvl) == want).all()


def test_bfs_single_disconnected():
    # two components: 0-1, 2-3
    edges = jnp.asarray([[0, 1, 2, 3], [1, 0, 3, 2]], jnp.int32)
    co, ri = build_csc(edges, 4)
    lvl, _ = bfs_single(co, ri, 0)
    assert np.asarray(lvl).tolist() == [0, 1, -1, -1]


def test_validate_catches_corruption():
    edges, n, co, ri = _graph()
    # root must have a non-trivial component so there is a level to corrupt
    root = int(np.flatnonzero(np.diff(np.asarray(co)) > 0)[0])
    lvl, pred = bfs_reference_py(co, ri, root, n)
    bad = lvl.copy()
    vis = np.flatnonzero(bad > 0)
    bad[vis[0]] += 1
    with pytest.raises(AssertionError):
        validate_bfs(np.asarray(edges), bad, pred, root)


def test_component_edge_count():
    edges = jnp.asarray([[0, 1, 2, 3], [1, 0, 3, 2]], jnp.int32)
    co, ri = build_csc(edges, 4)
    lvl, _ = bfs_reference_py(co, ri, 0, 4)
    assert count_component_edges(np.asarray(edges), lvl) == 1


@pytest.mark.parametrize("fold_codec", ["list", "bitmap", "delta"])
def test_bfs2d_single_cell_mesh(fold_codec):
    edges, n, co, ri = _graph(scale=7, ef=6, seed=4)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    lg = partition_2d(np.asarray(edges), grid)
    bfs = BFS2D(grid, mesh, edge_chunk=512, fold_codec=fold_codec)
    g = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                     jnp.asarray(lg.nnz))
    out = bfs.run(g, 9)
    ref, _ = bfs_reference_py(co, ri, 9, n)
    assert (np.asarray(out.level)[:n] == ref).all()
    validate_bfs(np.asarray(edges), np.asarray(out.level)[:n],
                 np.asarray(out.pred)[:n], 9)
    assert out.edges_scanned > 0


def test_bfs2d_legacy_fold_bitmap_kwarg():
    """fold_bitmap=True must keep selecting the bitmap codec."""
    edges, n, co, ri = _graph(scale=7, ef=6, seed=4)
    mesh = make_mesh((1, 1), ("r", "c"))
    grid = Grid2D.for_vertices(n, 1, 1)
    bfs = BFS2D(grid, mesh, edge_chunk=512, fold_bitmap=True)
    assert bfs.engine.codec.name == "bitmap"
