"""Serve-layer coverage (DESIGN.md sec. 12).

  * coalescing correctness: interleaved requests across programs/codecs,
    served through the continuous-batching scheduler with padding, return
    bit-identical results to direct `GraphSession` calls (deterministic
    matrix + a hypothesis property over random interleavings);
  * trace discipline: engine `trace_count` proves no recompiles beyond the
    first batch per (program, padded capacity class);
  * fault path: a transient fault is absorbed by StepRunner retries; a
    poisoned request fails ALONE via the isolation replay while the server
    keeps serving;
  * admission: validation rejects bad requests before they reach a
    compiled program; `max_pending` backpressure raises ServerSaturated;
  * CC dedup-coalescing: concurrent CC callers share ONE execution;
  * scheduler unit behavior (window dispatch, pad classes).

Multi-device serving runs in the bench harness (`benchmarks/run.py
--serve`, CI serve-smoke).
"""
import threading
import time

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import BFSConfig, DistGraph
from repro.runtime.fault import FaultInjector, RetryPolicy
from repro.serve import (BatchKey, ContinuousBatcher, Entry, GraphServer,
                         QueryRequest, QueryTicket, ServeConfig,
                         ServerSaturated, pad_class, pad_classes)

SCALE, EF = 7, 8
N = 1 << SCALE


@pytest.fixture(scope="module")
def graphs():
    """Two resident graphs: 'a' unweighted, 'b' weighted (SSSP-capable)."""
    from repro.graphgen import rmat_edges

    edges = np.asarray(rmat_edges(jax.random.key(0), SCALE, EF))
    w = (np.abs(edges[0] * 31 + edges[1]) % 255 + 1).astype(np.uint8)
    cfg = BFSConfig(grid=(1, 1), edge_chunk=256)
    ga = DistGraph.from_edges(edges, cfg, n=N)
    gb = DistGraph.from_edges(edges, cfg, n=N, weights=w)
    deg = np.bincount(edges[0], minlength=N)
    roots = np.random.default_rng(1).choice(np.flatnonzero(deg > 0), 16,
                                            replace=False)
    return ga, gb, roots


def _server(ga, gb, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("window_s", 0.01)
    return GraphServer({"a": ga, "b": gb}, ServeConfig(**kw))


def _value(ticket, timeout=120):
    res = ticket.result(timeout)
    assert res.ok, f"query failed: {res.error}"
    return res


# ---------------------------------------------------------------------------
# Coalescing correctness: served == direct GraphSession, bit-identical
# ---------------------------------------------------------------------------

def test_mixed_programs_bitexact(graphs):
    """BFS / CC / SSSP / multi-BFS on two resident graphs, interleaved,
    each result bit-identical to the direct session call."""
    ga, gb, roots = graphs
    with _server(ga, gb) as srv:
        tickets = []
        for i, r in enumerate(roots[:6]):
            tickets.append(("bfs", r, srv.bfs("a", int(r), tenant=f"t{i % 2}")))
            if i % 2 == 0:
                tickets.append(("sssp", r, srv.sssp("b", int(r))))
            if i % 3 == 0:
                tickets.append(("cc", None, srv.connected_components("a")))
        tickets.append(("mb", None,
                        srv.multi_bfs("a", roots[:3].astype(int), k=2)))
        srv.drain()
    sa, sb = ga.session(), gb.session()
    cc = sa.connected_components()
    mb = sa.multi_bfs(roots[:3].astype(int), k=2)
    for kind, r, t in tickets:
        out = _value(t).value
        if kind == "bfs":
            direct = sa.bfs(int(r))
            assert (np.asarray(out.level) == np.asarray(direct.level)).all()
            assert (np.asarray(out.pred) == np.asarray(direct.pred)).all()
            assert int(out.n_levels) == int(direct.n_levels)
            assert out.edges_scanned == direct.edges_scanned
        elif kind == "sssp":
            direct = sb.sssp(int(r))
            assert (np.asarray(out.dist) == np.asarray(direct.dist)).all()
            assert out.edges_scanned == direct.edges_scanned
        elif kind == "cc":
            assert (np.asarray(out.labels) == np.asarray(cc.labels)).all()
        else:
            assert (np.asarray(out.level) == np.asarray(mb.level)).all()
            assert (np.asarray(out.src) == np.asarray(mb.src)).all()


def test_full_batch_coalesces_and_traces_once(graphs):
    """max_batch pre-queued BFS roots run as ONE padded batch through ONE
    trace; a second identical wave recompiles nothing."""
    ga, gb, roots = graphs
    srv = _server(ga, gb)                      # NOT started: queue fills
    tickets = [srv.bfs("a", int(r)) for r in roots[:4]]
    srv.start()
    srv.drain()
    engine = ga.session().engine
    first_traces = engine.trace_count
    for t in tickets:
        res = _value(t)
        assert res.batch_size == 4 and res.padded_to == 4
    occ = srv.accounting.occupancy()
    assert occ == 4.0, f"expected full occupancy, got {occ}"
    # second wave: same (program, B class) -> zero new traces
    tickets = [srv.bfs("a", int(r)) for r in roots[4:8]]
    srv.drain()
    for t in tickets:
        _value(t)
    assert engine.trace_count == first_traces, \
        "repeat batch of the same capacity class must not retrace"
    srv.stop()


def test_padding_demux_discards_pad_slots(graphs):
    """A 3-live batch pads to class 4; every live slot demuxes to its own
    root's result (padding repeats root 0 and is discarded)."""
    ga, gb, roots = graphs
    srv = _server(ga, gb)
    tickets = [srv.bfs("a", int(r)) for r in roots[:3]]
    srv.start()
    srv.drain()
    sess = ga.session()
    for t, r in zip(tickets, roots[:3]):
        res = _value(t)
        assert res.batch_size == 3 and res.padded_to == 4
        assert (np.asarray(res.value.level)
                == np.asarray(sess.bfs(int(r)).level)).all()
    srv.stop()


def test_cc_requests_share_one_run(graphs):
    """Argument-free CC coalesces by dedup: K callers, ONE execution."""
    ga, gb, roots = graphs
    srv = _server(ga, gb)
    tickets = [srv.connected_components("a", tenant=f"t{i}")
               for i in range(3)]
    srv.start()
    srv.drain()
    direct = ga.session().connected_components()
    for t in tickets:
        res = _value(t)
        assert res.batch_size == 3
        assert (np.asarray(res.value.labels)
                == np.asarray(direct.labels)).all()
    batches = [b for b in srv.accounting.batches if b.program == "cc"]
    assert len(batches) == 1 and batches[0].live == 3
    srv.stop()


@settings(max_examples=4, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["bfs-list", "bfs-bitmap", "cc"]),
                          st.integers(0, 15)),
                min_size=1, max_size=10))
def test_interleaved_requests_property(graphs, reqs):
    """Property (satellite): K interleaved requests across programs AND
    codecs, served with padding, are bit-identical to direct session
    calls, and trace counts prove no recompiles beyond the first batch
    per (program, padded B)."""
    ga, gb, roots = graphs
    cfg_bitmap = BFSConfig(grid=(1, 1), edge_chunk=256, fold_codec="bitmap")
    srv = _server(ga, gb)
    tickets = []
    for kind, ridx in reqs:
        root = int(roots[ridx])
        if kind == "bfs-list":
            tickets.append((kind, root, srv.bfs("a", root)))
        elif kind == "bfs-bitmap":
            tickets.append((kind, root,
                            srv.bfs("a", root, config=cfg_bitmap)))
        else:
            tickets.append((kind, None, srv.connected_components("a")))
    srv.start()
    srv.drain()
    srv.stop()
    sess_list = ga.session()
    sess_bitmap = ga.session(cfg_bitmap)
    cc = sess_list.connected_components()
    for kind, root, t in tickets:
        out = _value(t).value
        if kind == "cc":
            assert (np.asarray(out.labels) == np.asarray(cc.labels)).all()
        else:
            sess = sess_list if kind == "bfs-list" else sess_bitmap
            direct = sess.bfs(root)
            assert (np.asarray(out.level) == np.asarray(direct.level)).all()
            assert (np.asarray(out.pred) == np.asarray(direct.pred)).all()
            assert out.edges_scanned == direct.edges_scanned
    # no recompiles beyond the first batch per (program, padded B): every
    # engine's trace count is bounded by its distinct padded capacity
    # classes (the direct comparison sessions share these engines/caches)
    classes = set(pad_classes(srv.config.max_batch)) | {1}
    for key, eng in ga._engines.items():
        assert eng.trace_count <= len(classes) + 1, \
            f"engine {key} traced {eng.trace_count}x"


# ---------------------------------------------------------------------------
# Fault path
# ---------------------------------------------------------------------------

def test_transient_fault_retried_invisibly(graphs):
    """A fault on the first attempt is absorbed by StepRunner retries; the
    request succeeds and the retry is visible in runner stats."""
    ga, gb, roots = graphs
    with _server(ga, gb, retry=RetryPolicy(max_retries=2,
                                           backoff_s=0.001)) as srv:
        t = srv.bfs("a", int(roots[0]),
                    injector=FaultInjector({0: RuntimeError}))
        res = _value(t)
        assert (np.asarray(res.value.level)
                == np.asarray(ga.session().bfs(int(roots[0])).level)).all()
        assert srv.metrics_snapshot()["runners"]["a"]["retries"] >= 1


def test_poisoned_request_fails_alone(graphs):
    """Acceptance: an injected mid-query fault fails ONLY its own request
    (isolation replay); batchmates succeed and the server keeps serving."""
    ga, gb, roots = graphs
    srv = _server(ga, gb, retry=RetryPolicy(max_retries=1, backoff_s=0.001))
    poisoned = FaultInjector({i: RuntimeError for i in range(16)})
    good = [srv.bfs("a", int(r)) for r in roots[:2]]
    bad = srv.bfs("a", int(roots[2]), injector=poisoned)
    more = [srv.bfs("a", int(r)) for r in roots[3:4]]
    srv.start()
    srv.drain()
    sess = ga.session()
    for t, r in zip(good + more, list(roots[:2]) + list(roots[3:4])):
        res = _value(t)
        assert (np.asarray(res.value.level)
                == np.asarray(sess.bfs(int(r)).level)).all()
    res = bad.result(120)
    assert not res.ok and "RuntimeError" in res.error
    assert "injected" in res.error
    # the server keeps serving after the fault
    after = srv.bfs("a", int(roots[5]))
    srv.drain()
    assert _value(after).ok
    stats = srv.metrics_snapshot()
    assert stats["tenants"]["default"]["failed"] == 1
    assert stats["n_isolated"] >= 1
    srv.stop()


# ---------------------------------------------------------------------------
# Admission: validation + backpressure
# ---------------------------------------------------------------------------

def test_submit_validates_before_compiled_program(graphs):
    ga, gb, roots = graphs
    srv = _server(ga, gb)
    with pytest.raises(ValueError, match="no resident graph"):
        srv.bfs("nope", 0)
    with pytest.raises(ValueError, match="unknown program"):
        srv.submit("a", "pagerank", 0)
    with pytest.raises(ValueError, match=f"n = {N}"):
        srv.bfs("a", N + 3)
    with pytest.raises(ValueError, match="integer"):
        srv.bfs("a", 1.5)
    with pytest.raises(ValueError, match="one root per request"):
        srv.bfs("a", np.array([1, 2]))
    with pytest.raises(ValueError, match="weights"):
        srv.sssp("a", 0)              # graph 'a' is weightless
    with pytest.raises(ValueError, match=f"n = {N}"):
        srv.multi_bfs("a", [0, N])
    with pytest.raises(ValueError, match="argument-free"):
        srv.submit("a", "cc", 5)
    assert srv.accounting.snapshot()["tenants"] == {}, \
        "rejected requests must not be admitted"


def test_backpressure_raises_server_saturated(graphs):
    ga, gb, roots = graphs
    srv = _server(ga, gb, max_pending=2)     # not started: queue holds
    srv.bfs("a", int(roots[0]))
    srv.bfs("a", int(roots[1]))
    with pytest.raises(ServerSaturated, match="max_pending"):
        srv.bfs("a", int(roots[2]))
    assert srv.metrics_snapshot()["tenants"]["default"]["rejected"] == 1
    srv.start()
    srv.drain()
    srv.stop()


def test_stop_flushes_pending_requests(graphs):
    """stop() on a started server serves what was admitted, then exits."""
    ga, gb, roots = graphs
    srv = _server(ga, gb)
    tickets = [srv.bfs("a", int(r)) for r in roots[:2]]
    srv.start()
    srv.stop()
    for t in tickets:
        assert _value(t, timeout=10).ok


# ---------------------------------------------------------------------------
# Scheduler / protocol units (no device work)
# ---------------------------------------------------------------------------

def _entry(key, seq=0):
    req = QueryRequest(seq=seq, tenant="t", graph=key.graph,
                       program=key.program, arg=0, config=key.config)
    return Entry(key=key, req=req, ticket=QueryTicket(req))


def test_pad_classes():
    assert pad_class(1, 8) == 1 and pad_class(3, 8) == 4
    assert pad_class(5, 8) == 8 and pad_class(5, 6) == 6
    assert pad_classes(8) == (1, 2, 4, 8)
    assert pad_classes(6) == (1, 2, 4, 6)
    with pytest.raises(ValueError):
        pad_class(0, 8)


def test_batcher_dispatches_full_batch_immediately():
    b = ContinuousBatcher(window_s=60.0, max_pending=16)
    key = BatchKey("g", "bfs", None, (), cap=2)
    for i in range(3):
        b.put(_entry(key, i))
    t0 = time.perf_counter()
    got_key, entries = b.next_batch()
    assert time.perf_counter() - t0 < 1.0, "full batch must not wait window"
    assert got_key == key and len(entries) == 2
    assert [e.req.seq for e in entries] == [0, 1], "FIFO order"
    b.close()
    _, rest = b.next_batch()            # flush: window not waited out
    assert [e.req.seq for e in rest] == [2]
    assert b.next_batch() is None


def test_batcher_window_dispatches_partial_batch():
    b = ContinuousBatcher(window_s=0.05, max_pending=16)
    key = BatchKey("g", "bfs", None, (), cap=8)
    b.put(_entry(key))
    t0 = time.perf_counter()
    _, entries = b.next_batch()
    waited = time.perf_counter() - t0
    assert len(entries) == 1
    assert waited >= 0.03, f"partial batch dispatched too early ({waited})"
    b.close()


def test_batcher_wakes_blocked_consumer():
    b = ContinuousBatcher(window_s=0.01, max_pending=16)
    key = BatchKey("g", "bfs", None, (), cap=8)
    out = []
    consumer = threading.Thread(target=lambda: out.append(b.next_batch()))
    consumer.start()
    time.sleep(0.05)
    b.put(_entry(key, 7))
    consumer.join(timeout=5)
    assert not consumer.is_alive() and out[0][1][0].req.seq == 7
    b.close()
