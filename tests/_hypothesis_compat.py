"""Soft dependency on hypothesis: property tests SKIP (rather than the whole
module failing collection) where it is not installed -- this container has no
network access; CI installs it and runs them for real."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _StrategyStub:
        """Accepts any strategy construction; the test is skipped anyway."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (CI runs property tests)")(f)

    def settings(*_a, **_k):
        return lambda f: f
