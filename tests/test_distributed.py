"""Multi-device integration tests.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps seeing exactly one device (required by the
smoke tests and benches).
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


def _run(script, *args, timeout=600):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist", script), *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert r.stdout.strip().endswith("OK"), r.stdout


@pytest.mark.slow
def test_bfs2d_grid_2x4():
    _run("run_bfs2d.py", 2, 4)


@pytest.mark.slow
def test_bfs2d_grid_4x2_bitmap_fold():
    _run("run_bfs2d.py", 4, 2, 9, 8, "bitmap")


@pytest.mark.slow
def test_bfs2d_grid_2x2_delta_fold():
    _run("run_bfs2d.py", 2, 2, 9, 8, "delta")


@pytest.mark.slow
def test_dist_suite_1d_direction_spmm():
    _run("run_dist_suite.py", 2, 4)


@pytest.mark.slow
def test_session_api_grid_2x2():
    _run("run_session.py", 2, 2)
