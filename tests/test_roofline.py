"""Roofline extractor tests: collective parsing + loop-aware costing."""
import jax
import jax.numpy as jnp

from repro.launch.roofline import (analyze_hlo, cost_analysis_dict,
                                   parse_collective_bytes, _shape_bytes,
                                   _group_size)


def test_shape_bytes():
    assert _shape_bytes("f32", "128,128") == 128 * 128 * 4
    assert _shape_bytes("bf16", "2,3") == 12
    assert _shape_bytes("pred", "8") == 8


def test_group_size_parsing():
    assert _group_size("all-reduce(...), replica_groups={{0,1,2,3}}, x") == 4
    assert _group_size("all-gather(...), replica_groups=[8,64]<=[512]") == 64


def test_collective_wire_factors():
    txt = """
  %ag = f32[64,256]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dims={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1}}, to_apply=%add
"""
    det = parse_collective_bytes(txt)
    assert det["all-gather"] == 64 * 256 * 4 * 3 / 4
    assert det["all-reduce"] == 1024 * 4 * 2 * 1 / 2


def test_loop_aware_flops_matches_unrolled():
    def scan_f(x, w):
        x, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return x

    L = 6
    c = jax.jit(scan_f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)).compile()
    la = analyze_hlo(c.as_text())
    want = 2 * 64**3 * L
    assert abs(la["flops"] - want) / want < 0.01
    # XLA's own counter sees the body once -> must be ~L x smaller
    assert cost_analysis_dict(c)["flops"] < la["flops"]


def test_loop_aware_collectives_weighted():
    """A psum inside a scan must count trip_count times."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("d",))

    def f(x, w):
        def body(c, wi):
            c = c @ wi
            return jax.lax.psum(c, "d"), None
        x, _ = jax.lax.scan(body, x, w)
        return x

    L = 5
    sm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)
    comp = jax.jit(sm).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)).compile()
    la = analyze_hlo(comp.as_text())
    # group size 1 -> ring factor 0, so check the counting via flops instead
    assert abs(la["flops"] - 2 * 32**3 * L) / (2 * 32**3 * L) < 0.01
