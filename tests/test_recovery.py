"""Mid-traversal fault tolerance (DESIGN.md sec. 15), single-device half.

  * segmented-loop bit-identity: a fault-tolerant session (checkpoint-
    bounded segments of K levels) returns outputs bit-identical to the
    single-while_loop program for K in {1, 2, 5}, for BFS / CC / SSSP
    across fold codecs (preds / labels / dists / counters included);
  * transient device loss absorbed by the segment retry (jittered delays
    recorded), persistent loss escalated to UnrecoverableLoss carrying a
    snapshot that resumes bit-identically in a fresh session;
  * TraversalCheckpointer persistence + query-key mismatch guard;
  * DeviceLossInjector crossing semantics;
  * the no-retrace contract: `fault_tolerance=False` builds NO segmented
    programs and its trace counts are untouched by the feature.

Multi-device shrink-and-resume runs in tests/dist/run_elastic_bfs.py and
the drill matrix (benchmarks/fault_drill.py).
"""
import jax
import numpy as np
import pytest

from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges
from repro.runtime.fault import RetryPolicy
from repro.runtime.recovery import (DeviceLoss, DeviceLossInjector,
                                    RecoveryPlan, TraversalCheckpointer,
                                    UnrecoverableLoss)

SCALE, EF = 7, 8
N = 1 << SCALE


@pytest.fixture(scope="module")
def gdata():
    edges = np.asarray(rmat_edges(jax.random.key(3), SCALE, EF))
    w = ((np.abs(edges[0] * 31 + edges[1]) % 254) + 1).astype(np.uint8)
    deg = np.bincount(edges[0], minlength=N)
    roots = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 4,
                                            replace=False).astype(np.int32)
    return edges, w, roots


def _session(edges, w, codec="list", ft=False, K=1):
    cfg = BFSConfig(grid=(1, 1), fold_codec=codec, edge_chunk=512,
                    fault_tolerance=ft, ckpt_every=K)
    return DistGraph.from_edges(edges, cfg, n=N, weights=w).session()


def _query(sess, program, roots, **kw):
    if program == "bfs":
        return sess.bfs(roots[:2], **kw)
    if program == "sssp":
        return sess.sssp(roots[:2], **kw)
    return sess.connected_components(**kw)


def _assert_same(program, out, base):
    if program == "bfs":
        assert (np.asarray(out.level) == np.asarray(base.level)).all()
        assert (np.asarray(out.pred) == np.asarray(base.pred)).all()
        assert (np.asarray(out.n_levels) == np.asarray(base.n_levels)).all()
        assert tuple(out.edges_scanned) == tuple(base.edges_scanned)
    elif program == "sssp":
        assert (np.asarray(out.dist) == np.asarray(base.dist)).all()
        assert tuple(out.edges_scanned) == tuple(base.edges_scanned)
    else:
        assert (np.asarray(out.labels) == np.asarray(base.labels)).all()
        assert int(out.n_iters) == int(base.n_iters)
        assert out.edges_scanned == base.edges_scanned


@pytest.mark.parametrize("program,codec", [
    ("bfs", "list"), ("bfs", "bitmap"),
    ("cc", "list"), ("cc", "bitmap"),
    ("sssp", "list"), ("sssp", "bitmap"),
])
def test_segmented_bit_identity(gdata, program, codec):
    """FT session output == unsegmented output for every checkpoint
    cadence: segment boundaries add no arithmetic."""
    edges, w, roots = gdata
    base = _query(_session(edges, w, codec=codec), program, roots)
    for K in (1, 2, 5):
        out = _query(_session(edges, w, codec=codec, ft=True, K=K),
                     program, roots)
        _assert_same(program, out, base)


def test_multi_bfs_segmented(gdata):
    edges, w, roots = gdata
    base = _session(edges, w).multi_bfs(roots)
    out = _session(edges, w, ft=True, K=2).multi_bfs(roots)
    assert (np.asarray(out.level) == np.asarray(base.level)).all()
    assert (np.asarray(out.src) == np.asarray(base.src)).all()
    assert out.edges_scanned == base.edges_scanned


def test_transient_loss_absorbed_by_retry(gdata):
    """One injected loss crossing level 2: the segment retries, the query
    completes bit-identically, and the jittered backoff is recorded."""
    edges, w, roots = gdata
    base = _query(_session(edges, w), "bfs", roots)
    plan = RecoveryPlan(
        injector=DeviceLossInjector(2, transient=True),
        policy=RetryPolicy(max_retries=2, backoff_s=1e-4, jitter_s=1e-4,
                           seed=7))
    out = _query(_session(edges, w, ft=True), "bfs", roots, recovery=plan)
    _assert_same("bfs", out, base)
    assert plan.stats["retries"] == 1
    assert len(plan.stats["delays"]) == 1
    assert 1e-4 <= plan.stats["delays"][0] < 2e-4  # backoff + jitter in [0,1)
    assert plan.stats["resumes"] == 0


def test_persistent_loss_snapshot_resumes_bit_identical(gdata):
    """Retries exhaust -> UnrecoverableLoss carries the pre-failure carry;
    importing it into a FRESH session resumes to bit-identical output
    (preds included -- same grid)."""
    edges, w, roots = gdata
    base = _query(_session(edges, w), "bfs", roots)
    policy = RetryPolicy(max_retries=1, backoff_s=1e-5)
    plan = RecoveryPlan(injector=DeviceLossInjector(2, fires=2),
                        policy=policy)
    with pytest.raises(UnrecoverableLoss) as ei:
        _query(_session(edges, w, ft=True), "bfs", roots, recovery=plan)
    assert ei.value.level == 1   # K=1 segments: failed crossing into lvl 2
    assert plan.stats["retries"] == 1

    plan2 = RecoveryPlan(resume=ei.value.snapshot, policy=policy)
    out = _query(_session(edges, w, ft=True), "bfs", roots, recovery=plan2)
    _assert_same("bfs", out, base)
    assert plan2.stats["resumes"] == 1
    assert plan2.stats["resumed_from_level"] == ei.value.level
    assert plan2.stats["time_to_first_resumed_level_s"] > 0


def test_checkpointer_resume_and_key_guard(gdata, tmp_path):
    """Disk checkpoints written every segment; a fresh plan over the same
    directory resumes past an exhausted injector; a DIFFERENT query key
    over the same directory refuses to load."""
    edges, w, roots = gdata
    base = _query(_session(edges, w), "sssp", roots)
    policy = RetryPolicy(max_retries=0, backoff_s=0.0)
    plan = RecoveryPlan(
        checkpointer=TraversalCheckpointer(str(tmp_path), "q1"),
        injector=DeviceLossInjector(2, fires=1), policy=policy)
    with pytest.raises(UnrecoverableLoss):
        _query(_session(edges, w, ft=True), "sssp", roots, recovery=plan)

    plan2 = RecoveryPlan(
        checkpointer=TraversalCheckpointer(str(tmp_path), "q1"),
        policy=policy)
    out = _query(_session(edges, w, ft=True), "sssp", roots, recovery=plan2)
    _assert_same("sssp", out, base)
    assert plan2.stats["resumes"] == 1

    with pytest.raises(ValueError, match="query_key"):
        TraversalCheckpointer(str(tmp_path), "OTHER").load()


def test_injector_crossing_semantics():
    inj = DeviceLossInjector(3, transient=True)
    inj.check(0, 1)                      # below: quiet
    inj.check(3, 4)                      # already past: quiet
    with pytest.raises(DeviceLoss):
        inj.check(2, 3)                  # crossing fires
    inj.check(2, 3)                      # transient: budget spent
    assert inj.count == 1

    unbounded = DeviceLossInjector(1, devices=2)
    for _ in range(4):                   # fires=None: every crossing
        with pytest.raises(DeviceLoss) as ei:
            unbounded.check(0, 5)
        assert ei.value.devices == 2
    assert unbounded.count == 4

    with pytest.raises(ValueError, match="phase"):
        DeviceLossInjector(1, phase="warp")


def test_recovery_kwarg_requires_ft_session(gdata):
    edges, w, roots = gdata
    sess = _session(edges, w)
    with pytest.raises(ValueError, match="fault-tolerant"):
        sess.bfs(int(roots[0]), recovery=RecoveryPlan())


def test_ft_off_builds_nothing_and_never_retraces(gdata):
    """fault_tolerance=False is exactly the existing engine: no segmented
    programs exist, and repeat sweeps stay on the AOT cache."""
    edges, w, roots = gdata
    sess = _session(edges, w)
    assert sess.engine._ft_progs == {}
    out1 = sess.bfs(roots[:2])
    traces = sess.engine.trace_count
    out2 = sess.bfs(roots[:2])
    assert sess.engine.trace_count == traces, "repeat sweep retraced"
    assert sess.engine._ft_progs == {}, "FT programs built without opt-in"
    _assert_same("bfs", out2, out1)


def test_ft_engine_trace_discipline(gdata):
    """The segmented engine traces its three programs once; repeat queries
    (and a later resume) hit the cache."""
    edges, w, roots = gdata
    sess = _session(edges, w, ft=True, K=2)
    out1 = sess.bfs(roots[:2])
    traces = sess.engine.trace_count
    out2 = sess.bfs(roots[:2])
    assert sess.engine.trace_count == traces, "repeat FT sweep retraced"
    _assert_same("bfs", out2, out1)
