"""Generate EXPERIMENTS.md from dryrun_results.json + hillclimb_results.json
+ bench_out/*.csv.  Hand-written narrative sections are kept in this script
so the document regenerates deterministically."""
import json
import os

DR = json.load(open("dryrun_results.json"))
HC = json.load(open("hillclimb_results.json")) if os.path.exists(
    "hillclimb_results.json") else {}


def fmt_cell(v):
    rl = v["roofline"]
    m = v["memory"]
    frac = ""
    if rl.get("useful_ratio"):
        frac = f"{rl['useful_ratio']:.2f}"
    return (f"{rl['compute_s']:.2e} | {rl['memory_s']:.2e} | "
            f"{rl['collective_s']:.2e} | {rl['dominant'][:4]} | "
            f"{m['argument_bytes'] / 2**30:.1f} | "
            f"{m['temp_bytes'] / 2**30:.1f} | {frac}")


def csv_block(name):
    p = f"bench_out/{name}.csv"
    if not os.path.exists(p):
        return "(missing)"
    return "```\n" + open(p).read().strip() + "\n```"


lines = []
A = lines.append
A("# EXPERIMENTS — Distributed 2D BFS (Bisson et al. 2014) on TPU pods\n")
A("Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, "
  "~50 GB/s/link ICI.  Container is CPU-only: all roofline terms are derived "
  "from `.lower().compile()` artifacts (memory_analysis + loop-aware HLO "
  "costing, see §Method); wall-clock numbers are CPU host-device "
  "measurements of the REAL distributed code at reduced scale.\n")

# ---------------------------------------------------------------- dry-run --
A("## §Dry-run (deliverable e)\n")
okc = sum(1 for v in DR.values() if v["status"] == "ok")
skc = sum(1 for v in DR.values() if v["status"] == "skipped")
A(f"Every (architecture × shape) cell lowers AND compiles on BOTH production "
  f"meshes — single-pod `(16,16) ('data','model')` and multi-pod "
  f"`(2,16,16) ('pod','data','model')` (512 placeholder host devices): "
  f"**{okc} compiled ok, {skc} documented skips, 0 failures**.\n")
A("Skips (per assignment note: `long_500k` only for sub-quadratic archs): "
  "kimi-k2, qwen2-moe, glm4-9b × long_500k × both meshes — all three use "
  "full attention at every layer.  gemma2-2b (alternating local/global) and "
  "h2o-danube (SWA everywhere, ring-buffer cache) DO run long_500k.\n")
A("Multi-pod cells prove the `pod` axis shards: batch/dp collectives span "
  "pods while fold/EP all-to-alls stay inside a pod (BFS fold stays within "
  "a grid row = intra-pod by construction; DESIGN.md §5).  Per-cell compile "
  "time 1–25 s; the BFS cell lowers the ENTIRE while-loop search program.\n")

# --------------------------------------------------------------- roofline --
A("## §Roofline (deliverable g) — single-pod 16×16, per chip\n")
A("### Method")
A("`compiled.cost_analysis()` counts a `lax.scan`/while body ONCE regardless "
  "of trip count (verified: a 2-layer and an 8-layer scanned matmul report "
  "identical FLOPs).  We therefore re-derive all three terms from the "
  "optimized HLO with computation multipliers (ENTRY ×1, while bodies × "
  "`known_trip_count`, fusions inherit): dot FLOPs, HBM bytes from top-level "
  "operand/result sizes (fusion internals excluded), collective wire bytes "
  "with ring factors — all-gather/reduce-scatter/all-to-all (n−1)/n·S, "
  "all-reduce 2(n−1)/n·S, permute 1·S (`repro/launch/roofline.py`, unit "
  "tests in `tests/test_roofline.py`).  Known limitation: data-dependent "
  "while loops (the BFS level loop) have no static trip count and are "
  "weighted ×1 — BFS rows are per-LEVEL costs; measured wall-times in "
  "§Paper-claims back the BFS story.\n")
A("`MODEL_FLOPS` = 6·N·D (dense) / 6·N_active·D (MoE) for training, 2·N·D "
  "for inference; `useful` = MODEL_FLOPS / (HLO_FLOPs × chips).\n")
A("| arch × shape | compute s | memory s | collective s | dom | arg GiB/chip | temp GiB/chip | useful |")
A("|---|---|---|---|---|---|---|---|")
for k in sorted(DR):
    v = DR[k]
    if not k.endswith("|single"):
        continue
    cell = k[:-7].replace("|", " \u00d7 ")
    if v["status"] == "skipped":
        A(f"| {cell} | — | — | — | skip | — | — | — |")
    elif v["status"] == "ok":
        A(f"| {cell} | {fmt_cell(v)} |")
A("")
A("### Reading the table (dominant bottleneck + what would move it)\n")
A("* **kimi-k2 train_4k** — memory-dominated (109 s/step of HBM traffic!) "
  "and 153 GiB/chip of arguments: the 1T expert weights sharded over the "
  "16-wide model axis alone do not fit a 16 GiB v5e. Fix = FSDP the experts "
  "over `data` (→ §Perf cell A). Useful-FLOP ratio 0.22 (remat ×~2 + "
  "capacity-padded expert GEMMs).")
A("* **LM decode cells** — all collective-dominated at baseline via a "
  "54 GB/step cache all-gather (batch-sharded cache vs TP weights forces a "
  "reshard every layer). Fix = sequence-sharded KV cache (→ §Perf cell B, "
  "413× wire reduction).")
A("* **LM train cells (dense)** — collective: Megatron-TP activation "
  "all-reduces (~330 GB/step/chip at glm4-9b) — the classic "
  "sequence-parallel (reduce-scatter) target.")
A("* **GNN full-graph cells** — expand (all-gather of the feature block "
  "along grid rows) vs fold (psum_scatter along grid columns) are within 2× "
  "of each other, exactly the paper's expand/fold balance; memory term is "
  "the edge-gather traffic.")
A("* **BFS** — memory-dominant per level (bitmap + CSC scan traffic ≫ "
  "collective bytes): matches the paper's 'memory bandwidth bound with "
  "irregular access' (§3.4). Collective term is all-gather-heavy (expand) "
  "rather than fold, because fold sends only unvisited-vertex lists "
  "(the paper's single-send bitmap guarantee).\n")

# ------------------------------------------------------------------- perf --
A("## §Perf — hypothesis → change → measure log (deliverable g/perf)\n")
A("Paper-faithful BASELINE first, then beyond-paper optimisation. Three "
  "hillclimbed arch-cells (worst fraction / most collective-bound / most "
  "paper-representative) + the paper's own workload.\n")


def hrow(name):
    v = HC.get(name)
    if not v or v.get("status") != "ok":
        return f"| {name} | (failed) |||||"
    return (f"| {name} | {v['compute_s']:.2e} | {v['memory_s']:.2e} | "
            f"{v['collective_s']:.2e} | {v['dominant'][:4]} | "
            f"{v['arg_gib']:.1f} | {v['temp_gib']:.1f} |")


A("### Cell A: kimi-k2-1t-a32b × train_4k (1T MoE; memory-dominant, "
  "does not fit HBM at baseline)\n")
A("| experiment | compute s | memory s | collective s | dom | arg GiB | temp GiB |")
A("|---|---|---|---|---|---|---|")
for n in ["kimi_train/base", "kimi_train/fsdp", "kimi_train/fsdp+cap1.0",
          "kimi_train/fsdp+cap1.0+quant", "kimi_train/fsdp+cap1.0+quant+mb4",
          "kimi_train/fsdp+cap1.0+quant@2pods"]:
    A(hrow(n))
A("""
1. **H1 (fit)**: expert weights (1.03T params) sharded only over the 16-wide
   model axis → 153 GiB/chip of arguments; FSDP over `data` (weights gathered
   just-in-time inside the MoE shard_map, freed per layer) should cut
   arguments ~16× on the expert tensors at the price of per-layer
   all-gathers. → see `fsdp` row.
2. **H2 (wire)**: dispatch all-to-alls carry bf16 activations ∝
   capacity_factor; capacity 1.25→1.0 should cut dispatch wire 20%;
   int8-quantised dispatch (per-copy scales, error <0.4%) another 2×.
3. **H3 (temp)**: 4-way microbatching divides activation temps ~4× at
   equal total FLOPs (scan over microbatches).

Measured (loop-aware roofline, per chip): **FSDP confirms** — memory
108.6 s → 34.5 s (3.1×), arguments 152.7 → 40.1 GiB (the experts shrink
16×; the remaining 40.1 GiB is fp32 Adam moments, see below), at +17%
collective (the per-layer weight gathers).  **capacity 1.25→1.0 confirms**
(compute −18%, memory −4%).  **int8 dispatch confirms small** (w −3%:
dispatch a2a is minor next to the FSDP gathers at this scale).
**Microbatching REFUTED at ×4**: memory 31.4 → 63.9 s and wire ×4 —
gradient accumulation re-gathers the FSDP-sharded experts per microbatch
(classic FSDP × grad-accum interaction); lesson: with FSDP experts, prefer
a single large microbatch per step, or gather once per step outside the
microbatch scan.  **2-pod run**: per-chip compute/memory halve (weak
scaling works), and the pod axis is where the Adam moments must shard
next: 1T params × 8 B fp32 moments = 32 GiB/chip on one pod — a 256-chip
v5e pod CANNOT train kimi-k2 with fp32 Adam regardless of sharding; the
multi-pod mesh (or 8-bit moments) is a hard requirement, which the
dry-run's memory analysis makes visible before any hardware is burned.
Net on dominant term: **108.6 s → 31.4 s (3.46×) single-pod, 22.0 s on
2 pods**; step-time at the memory roofline now sits within 1.9× of the
weight-read floor (2 TB of bf16 params + remat re-reads ÷ 819 GB/s).
""")
A("### Cell B: gemma2-2b × decode_32k (worst useful ratio, "
  "collective-dominant)\n")
A("| experiment | compute s | memory s | collective s | dom | arg GiB | temp GiB |")
A("|---|---|---|---|---|---|---|")
for n in ["gemma_decode/base", "gemma_decode/seqshard"]:
    A(hrow(n))
A("""
**H (confirmed, 413×)**: the baseline shards the KV cache on batch over
`data` while weights are TP over `model`; every layer XLA all-gathers the
full 8.6 GB/layer cache (54 GB/step wire, w=1.12 s/token).  Sequence-sharding
the cache (flash-decoding's split-KV expressed as a sharding) keeps the
cache local and turns the softmax into partial-reduction psums:
w 1.118 s → 0.0027 s (**413× less wire**), args 26.3 → 1.9 GiB/chip, temp
52 → 5.4 GiB (now fits), dominant term becomes the unavoidable cache READ
(memory 0.46 s/token ≈ 26L × 8.6 GB ÷ 819 GB/s — within 1.25× of the
decode memory roofline).  Applied as default for all decode shapes.
""")
A("### Cell C: graphsage-reddit × ogb_products (the paper's expand/fold as "
  "SpMM)\n")
A("| experiment | compute s | memory s | collective s | dom | arg GiB | temp GiB |")
A("|---|---|---|---|---|---|---|")
for n in ["sage_products/base", "sage_products/bf16"]:
    A(hrow(n))
A("""
**H (partially refuted)**: bf16 features should halve expand/fold wire.
Lowering shows wire UNCHANGED: the first layer's gather shrinks but
h = relu(h@W) promotes back to f32 (params stayed f32), so layers ≥2 and the
backward pass dominate.  Lesson recorded: mixed-precision wins for the 2D
SpMM require the whole layer pipeline in bf16, not just inputs — matching
the paper's insistence on 32-bit LOCAL indices everywhere (§3.3): the wire
format must be consistent end-to-end.
""")
A("### The paper's workload: BFS (2D, 16×16 grid, scale-29 R-MAT)\n")
A("| experiment | compute s/level | memory s/level | collective s/level | dom | note |")
A("|---|---|---|---|---|---|")
for n, note in [("bfs/base", "paper-faithful"),
                ("bfs/sort_dedup", "sort-dedup replaces scatter-claim"),
                ("bfs/fold_bitmap", "bitmap fold (32× fold wire)"),
                ("bfs/sort+bitmap", "both"),
                ("bfs/chunk_256k", "smaller edge chunk")]:
    v = HC.get(n)
    if v and v.get("status") == "ok":
        A(f"| {n} | {v['compute_s']:.2e} | {v['memory_s']:.2e} | "
          f"{v['collective_s']:.2e} | {v['dominant'][:4]} | {note} |")
A("""
Measured wall-clock (REAL distributed runs, 2×4 host devices, scale-16
R-MAT, harmonic TEPS over 4 roots — `benchmarks/workers/bfs_worker.py`):

| variant | harmonic TEPS | mean s/search | vs paper-faithful |
|---|---|---|---|
| 2D paper-faithful (scatter dedup, list fold) | 1.09e6 | 0.959 | 1.00× |
| 2D + bitmap fold | 8.89e5 | 1.179 | 0.81× (CPU: pack cost > free wire) |
| **2D + direction-optimising (beyond-paper)** | **1.99e6** | **0.528** | **1.82×** |

Hypothesis log:
1. **sort-dedup** (replace the O(n_rows) scatter-claim temp with an
   O(chunk log chunk) sort): memory term 4.21e-2 → 4.18e-2 per level —
   confirmed direction but small at this scale (the visited/pred arrays
   dominate); kept as an option (`dedup="sort"`).
2. **bitmap fold** (beyond-paper, 32× smaller fold messages): collective
   term ↓5% only — the dry-run shows expand (all-gather) already dominates
   the BFS wire, NOT fold, so the 32× on fold barely moves the sum;
   measured CPU wall-time REGRESSES 19% (pack/unpack is local work, CPU
   'links' are free).  Refuted as a default; retained for genuinely
   link-bound deployments (the paper's 4096-GPU regime where transfers are
   60% of time).
3. **direction-optimising switch** (beyond-paper, Beamer-style bottom-up
   with the fold becoming a min-reduce of encoded parents): measured
   **1.82× end-to-end** — consistent with the literature and with the
   paper's own observation that bottom-up 'does not traverse all edges'.
4. **edge_chunk 1M→256k**: memory/level ↓3% (smaller claim temps),
   confirmed mild win; kept 1M for TPU (fewer loop iterations).

Stopping rule hit for the BFS cell: three consecutive <5% changes on the
dominant (memory) term — the remaining memory traffic is the CSC scan +
visited bitmap itself, i.e. the algorithm's intrinsic working set.
""")

# ------------------------------------------------------- paper validation --
A("## §Paper-claims validation (faithful reproduction)\n")
A("Reduced scale (CPU container; paper used 4096 K20X GPUs) — directions and "
  "ratios are the reproducible quantities:\n")
A("* **Weak scaling (Fig. 3)**: " + csv_block("fig3_weak_scaling"))
A("* **Strong scaling (Fig. 4)**: " + csv_block("fig4_strong_scaling"))
A("* **Compute/transfer split + 4-phase breakdown (Fig. 5/6)**: "
  + csv_block("fig5_6_breakdown"))
A("  Paper: frontier update ≪ frontier expansion (<10% of total); "
  "transfers grow with device count.  Reproduced: update is the smallest "
  "phase; transfer fraction grows 2×2 → 2×4.")
A("* **1D vs 2D (Fig. 7)**: " + csv_block("fig7_1d_vs_2d"))
A("  The 2D code beats the 1D modulo code at equal device count; the gap is "
  "bounded on 8 CPU devices (the paper's 8× comm gap appears at ≥1024 GPUs "
  "where O(P) vs O(√P) partner counts dominate — our dry-run wire model "
  "shows a2a partners 256 (1D) vs 16+16 (2D) on the production mesh).")
A("* **Atomic vs scatter/compact expansion (Table 2/Fig. 8)**: "
  + csv_block("table2_fig8_expansion_variants"))
A("  Paper: Kepler atomics ≈2× over compact on GPUs; our deterministic "
  "scatter-winner beats sort/compact ~10× under XLA-CPU (no atomics "
  "needed at all — the TPU adaptation wins MORE than the paper's).")
A("* **Real-world graphs (Table 3)**: " + csv_block("table3_realworld"))
A("* **Graph500 validation**: every BFS output in tests/examples passes the "
  "5-rule validator (tree structure, level consistency, edge levels ≤1, "
  "full component coverage); TEPS counts input edges in the traversed "
  "component with harmonic means over random roots, as in the paper.")
A("* **Kernel parity (§3.4.1)**: " + csv_block("kernel_bench"))
A("")
with open("EXPERIMENTS.md", "w") as f:
    f.write("\n".join(lines))
print(f"wrote EXPERIMENTS.md ({len(lines)} lines)")
