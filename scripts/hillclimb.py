import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimb driver (EXPERIMENTS.md sec. Perf).

Each experiment = (cell, variant dict) -> lower + compile on the single-pod
mesh -> loop-aware roofline terms.  Results append to hillclimb_results.json.

    PYTHONPATH=src python scripts/hillclimb.py [exp_name ...]
"""
import json
import sys
import time


def _lower(spec, mesh):
    import jax
    with mesh:
        j = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                    out_shardings=spec.out_shardings,
                    donate_argnums=spec.donate_argnums)
        return j.lower(*spec.args).compile()


def run(name, build, results):
    import jax
    from repro.launch import roofline
    if name in results:
        print(f"[skip] {name}")
        return
    t0 = time.time()
    try:
        compiled, mesh, extra = build()
        mem = compiled.memory_analysis()
        rl = roofline.analyze(compiled, n_chips=mesh.devices.size)
        rec = dict(status="ok", compile_s=round(time.time() - t0, 1),
                   compute_s=rl.compute_s, memory_s=rl.memory_s,
                   collective_s=rl.collective_s, dominant=rl.dominant,
                   flops=rl.flops, hbm_bytes=rl.hbm_bytes,
                   wire_bytes=rl.wire_bytes,
                   arg_gib=mem.argument_size_in_bytes / 2**30,
                   temp_gib=mem.temp_size_in_bytes / 2**30,
                   detail={k: v for k, v in rl.collective_detail.items()
                           if not k.startswith("_")}, **(extra or {}))
        print(f"[ok] {name}: dom={rl.dominant} c={rl.compute_s:.3e} "
              f"m={rl.memory_s:.3e} w={rl.collective_s:.3e} "
              f"arg={rec['arg_gib']:.1f}GiB tmp={rec['temp_gib']:.1f}GiB")
    except Exception as e:
        import traceback
        rec = dict(status="error", error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-1500:])
        print(f"[FAIL] {name}: {rec['error'][:200]}")
    results[name] = rec
    with open("hillclimb_results.json", "w") as f:
        json.dump(results, f, indent=1)


def main():
    from repro.launch.mesh import make_production_mesh, mesh_axes
    from repro.configs.lm_common import build_lm_dryrun
    import importlib

    mesh = make_production_mesh(multi_pod=False)
    axes = mesh_axes(mesh)
    mesh2 = make_production_mesh(multi_pod=True)
    axes2 = mesh_axes(mesh2)

    def lm_cell(arch_mod, shape, variant=None, multi=False):
        m, a = (mesh2, axes2) if multi else (mesh, axes)
        cfg = importlib.import_module(f"repro.configs.{arch_mod}").CONFIG
        spec = build_lm_dryrun(cfg, shape, m, a, variant=variant)
        return _lower(spec, m), m, {"variant": variant}

    def bfs_cell(**kw):
        import jax, jax.numpy as jnp
        from repro.core.bfs2d import BFS2D
        from repro.core.types import Grid2D
        from repro.configs.bfs_rmat import TABLE1, EDGE_FACTOR
        _, scale = TABLE1[mesh.devices.size]
        R = 16 if "pod" not in mesh.axis_names else 32
        C = 16
        grid = Grid2D.for_vertices(1 << scale, R, C)
        e_max = int(2 * EDGE_FACTOR * (1 << scale) / (R * C) * 1.5)
        bfs = BFS2D(grid, mesh, row_axes=axes.dp, col_axes=(axes.tp,),
                    edge_chunk=kw.pop("edge_chunk", 1 << 20), **kw)
        args = (jax.ShapeDtypeStruct((R, C, grid.n_cols_local + 1), jnp.int32),
                jax.ShapeDtypeStruct((R, C, e_max), jnp.int32),
                jax.ShapeDtypeStruct((R, C), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        import jax as _j
        with mesh:
            c = _j.jit(bfs._run).lower(*args).compile()
        return c, mesh, {"variant": kw}

    def sage_cell(dtype="f32"):
        import jax
        from repro.configs.gnn_common import build_sage_dryrun
        import repro.configs.graphsage_reddit as gs
        spec = build_sage_dryrun(gs.CONFIG, "ogb_products", mesh, axes)
        if dtype == "bf16":
            import jax.numpy as jnp

            def cast(x):
                if hasattr(x, "dtype") and x.dtype == jnp.float32:
                    return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                return x
            # features AND params in bf16 (otherwise layer outputs promote
            # back to f32 and only the first gather shrinks)
            spec.args = jax.tree.map(cast, spec.args)
        return _lower(spec, mesh), mesh, {"variant": {"dtype": dtype}}

    EXPS = {
        # --- cell A: kimi-k2 x train_4k (memory-dominant, 1T MoE) ---------
        "kimi_train/base": lambda: lm_cell("kimi_k2_1t_a32b", "train_4k"),
        "kimi_train/fsdp": lambda: lm_cell(
            "kimi_k2_1t_a32b", "train_4k", {"moe_fsdp_axis": "data"}),
        "kimi_train/fsdp+cap1.0": lambda: lm_cell(
            "kimi_k2_1t_a32b", "train_4k",
            {"moe_fsdp_axis": "data", "capacity_factor": 1.0}),
        "kimi_train/fsdp+cap1.0+quant": lambda: lm_cell(
            "kimi_k2_1t_a32b", "train_4k",
            {"moe_fsdp_axis": "data", "capacity_factor": 1.0,
             "moe_quant": True}),
        "kimi_train/fsdp+cap1.0+quant+mb4": lambda: lm_cell(
            "kimi_k2_1t_a32b", "train_4k",
            {"moe_fsdp_axis": "data", "capacity_factor": 1.0,
             "moe_quant": True, "microbatches": 4}),
        "kimi_train/fsdp+cap1.0+quant@2pods": lambda: lm_cell(
            "kimi_k2_1t_a32b", "train_4k",
            {"moe_fsdp_axis": "data", "capacity_factor": 1.0,
             "moe_quant": True}, multi=True),
        # --- cell B: gemma2-2b x decode_32k (collective-dominant) ---------
        "gemma_decode/base": lambda: lm_cell("gemma2_2b", "decode_32k"),
        "gemma_decode/seqshard": lambda: lm_cell(
            "gemma2_2b", "decode_32k", {"cache_seq_shard": True}),
        # --- cell C: graphsage x ogb_products (paper-technique SpMM) ------
        "sage_products/base": lambda: sage_cell("f32"),
        "sage_products/bf16": lambda: sage_cell("bf16"),
        # --- the paper's own workload ---------------------------------------
        "bfs/base": lambda: bfs_cell(),
        "bfs/sort_dedup": lambda: bfs_cell(dedup="sort"),
        "bfs/fold_bitmap": lambda: bfs_cell(fold_bitmap=True),
        "bfs/sort+bitmap": lambda: bfs_cell(dedup="sort", fold_bitmap=True),
        "bfs/chunk_256k": lambda: bfs_cell(edge_chunk=1 << 18),
    }

    results = {}
    if os.path.exists("hillclimb_results.json"):
        results = json.load(open("hillclimb_results.json"))
    wanted = sys.argv[1:] or list(EXPS)
    for name in wanted:
        run(name, EXPS[name], results)


if __name__ == "__main__":
    main()
