"""Benchmark orchestrator: one module per paper table/figure.
Each prints CSV rows (also written to bench_out/<name>.csv).

  fig3   weak scaling (TEPS vs devices, scale/device fixed)
  fig4   strong scaling (fixed graph)
  fig5/6 compute-vs-transfer + four-phase breakdown
  fig7   1D (original code) vs 2D comparison
  fig8/t2 atomic-style vs sort/compact expansion
  table3 real-world graph analogs
  kernels Pallas-kernel parity + oracle timings
"""
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bfs_weak_scaling, bfs_strong_scaling,
                            bfs_breakdown, bfs_1d_vs_2d,
                            bfs_expansion_variants, bfs_realworld,
                            kernel_bench)
    suites = [
        ("fig3_weak_scaling", bfs_weak_scaling.main),
        ("fig4_strong_scaling", bfs_strong_scaling.main),
        ("fig5_6_breakdown", bfs_breakdown.main),
        ("fig7_1d_vs_2d", bfs_1d_vs_2d.main),
        ("table2_fig8_expansion", bfs_expansion_variants.main),
        ("table3_realworld", bfs_realworld.main),
        ("kernel_bench", kernel_bench.main),
    ]
    failures = 0
    for name, fn in suites:
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn()
            print(f"--- {name} done in {time.time() - t0:.0f}s")
        except Exception:
            failures += 1
            print(f"--- {name} FAILED:\n{traceback.format_exc()[-1500:]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
