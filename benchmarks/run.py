"""Benchmark orchestrator: one module per paper table/figure.
Each prints CSV rows (also written to bench_out/<name>.csv); a final pass
folds everything into machine-readable bench_out/BENCH_bfs.json so the perf
trajectory (TEPS, bytes-per-edge per fold codec, per-phase times) is
trackable across PRs.

  fig3   weak scaling (TEPS vs devices, scale/device fixed)
  fig4   strong scaling (fixed graph)
  fig5/6 compute-vs-transfer + four-phase breakdown
  fig7   1D baseline (degenerate 1xP grid of the shared engine) vs 2D
  fold   list/bitmap/delta fold codec head-to-head (+ equality check)
  fig8/t2 atomic-style vs sort/compact expansion
  table3 real-world graph analogs
  kernels Pallas-kernel parity + oracle timings
"""
import os
import sys
import time
import traceback

from benchmarks import common


def _f(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


def write_bench_json() -> None:
    """Aggregate whatever CSVs exist into bench_out/BENCH_bfs.json."""
    from benchmarks.common import emit_json, read_csv

    def teps_rows(name):
        return [
            {"variant": r.get("variant"), "grid": f'{r.get("R")}x{r.get("C")}',
             "scale": _f(r.get("scale")), "ef": _f(r.get("ef")),
             "harmonic_TEPS": _f(r.get("harmonic_TEPS")),
             "mean_s": _f(r.get("mean_s")), "levels": _f(r.get("levels")),
             "fold": r.get("fold"),
             "fold_bytes_per_edge": _f(r.get("fold_bytes_per_edge")),
             # the session API's amortised view: all roots in ONE compiled
             # program (GraphSession.bfs(roots_batch)); batched_harmonic is
             # the harmonic mean over the SAME count_component_edges
             # numerators as harmonic_TEPS, over sweep_s / n_roots
             "batched_sweep_s": _f(r.get("batched_sweep_s")),
             "amortised_TEPS": _f(r.get("amortised_TEPS")),
             "batched_harmonic_TEPS": _f(r.get("batched_harmonic_TEPS"))}
            for r in read_csv(name)]

    codecs = {}
    for r in read_csv("fold_codecs"):
        codecs[r["fold"]] = {
            "harmonic_TEPS": _f(r.get("harmonic_TEPS")),
            "bytes_per_edge": _f(r.get("fold_bytes_per_edge")),
            "batched_sweep_s": _f(r.get("batched_sweep_s")),
            "amortised_TEPS": _f(r.get("amortised_TEPS")),
            "batched_harmonic_TEPS": _f(r.get("batched_harmonic_TEPS")),
            "lvl_sum": r.get("lvl_sum"), "pred_sum": r.get("pred_sum"),
            "scale": _f(r.get("scale")), "grid": f'{r.get("R")}x{r.get("C")}'}

    phases = [
        {"scale": _f(r.get("scale")), "grid": f'{r.get("R")}x{r.get("C")}',
         "expand_s": _f(r.get("expand_s")), "scan_s": _f(r.get("scan_s")),
         "fold_s": _f(r.get("fold_s")), "update_s": _f(r.get("update_s")),
         "transfer_frac": _f(r.get("transfer_frac"))}
        for r in read_csv("fig5_6_breakdown")]

    out = {
        "schema": "BENCH_bfs/v3",   # v3: + batched_harmonic_TEPS (harmonic
                                    # mean with count_component_edges
                                    # numerators for the batched sweep too)
        "teps": {
            "weak_scaling": teps_rows("fig3_weak_scaling"),
            "strong_scaling": teps_rows("fig4_strong_scaling"),
            "one_d_vs_two_d": teps_rows("fig7_1d_vs_2d"),
        },
        "fold_codecs": codecs,
        # null (not true) when no comparison ran -- an absent suite must not
        # read as a passed bit-exactness gate
        "codecs_agree": (len({(v["lvl_sum"], v["pred_sum"])
                              for v in codecs.values()}) == 1
                         if codecs else None),
        "phases": phases,
    }
    path = emit_json(out, "BENCH_bfs")
    print(f"\nwrote {path}")


def main() -> None:
    from benchmarks import (bfs_weak_scaling, bfs_strong_scaling,
                            bfs_breakdown, bfs_1d_vs_2d, bfs_fold_codecs,
                            bfs_expansion_variants, bfs_realworld,
                            algos_sweep, kernel_bench)
    # (suite label, entry point, CSV name the suite emits)
    suites = [
        ("algos_sweep", algos_sweep.main, "algos_sweep"),
        ("fig3_weak_scaling", bfs_weak_scaling.main, "fig3_weak_scaling"),
        ("fig4_strong_scaling", bfs_strong_scaling.main,
         "fig4_strong_scaling"),
        ("fig5_6_breakdown", bfs_breakdown.main, "fig5_6_breakdown"),
        ("fig7_1d_vs_2d", bfs_1d_vs_2d.main, "fig7_1d_vs_2d"),
        ("fold_codecs", bfs_fold_codecs.main, "fold_codecs"),
        ("table2_fig8_expansion", bfs_expansion_variants.main,
         "table2_fig8_expansion_variants"),
        ("table3_realworld", bfs_realworld.main, "table3_realworld"),
        ("kernel_bench", kernel_bench.main, "kernel_bench"),
    ]
    failures = 0
    for name, fn, csv_name in suites:
        print(f"\n=== {name} ===")
        # drop the previous run's CSV first: a failing suite must leave a
        # GAP in BENCH_bfs.json, not silently contribute stale numbers
        stale = os.path.join(common.OUT_DIR, f"{csv_name}.csv")
        if os.path.exists(stale):
            os.remove(stale)
        t0 = time.time()
        try:
            fn()
            print(f"--- {name} done in {time.time() - t0:.0f}s")
        except Exception:
            failures += 1
            print(f"--- {name} FAILED:\n{traceback.format_exc()[-1500:]}")
    write_bench_json()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
