"""Benchmark orchestrator: one module per paper table/figure.
Each prints CSV rows (also written to bench_out/<name>.csv); a final pass
folds everything into machine-readable bench_out/BENCH_bfs.json so the perf
trajectory (TEPS, bytes-per-edge per fold codec, per-phase times, per-level
expand times per expand path) is trackable across PRs.

  fig3   weak scaling (TEPS vs devices, scale/device fixed)
  fig4   strong scaling (fixed graph; minimal 1x1-vs-2x2 sweep in smoke)
  fig5/6 per-level traversal counters from the in-program telemetry trace
         (frontier/scanned/folded/wire/direction; DESIGN.md sec. 13) + fold
         wire bytes before/after the single-message overhaul per codec
         (DESIGN.md sec. 10)
  fig7   1D baseline (degenerate 1xP grid of the shared engine) vs 2D
  fold   list/bitmap/delta fold codec head-to-head (+ equality check)
  fig8/t2 atomic-style vs sort/compact expansion
  table3 real-world graph analogs
  expand reference vs fused-Pallas(-interpret) per-level expand times
  direction top-down vs bottom-up vs adaptive sweep + per-level alpha/beta
         decisions and bottom-up phase times (DESIGN.md sec. 11)
  exchange flat vs butterfly fold routes on a 1x4 column grid: per-level
         message/byte totals from the LevelTrace msgs channel, the
         log2(C)-vs-(C-1) message crossover, bit-identity across
         strategies (DESIGN.md sec. 14)
  kernels Pallas-kernel parity + oracle timings

CLI:
  --serve     run ONLY the serve-load suite (benchmarks/serve_load.py: a
              GraphServer under an offered-load sweep with mixed
              BFS/CC/SSSP/multi-BFS traffic on 2x2 simulated devices) and
              gate its bench_out/BENCH_serve.json: schema, >= 3 load
              points, all bit-exact, zero failed queries, mean batch
              occupancy > 1 at the highest offered load, and the fault
              drill failing exactly the poisoned request -- never
              wall-clock
  --obs       run ONLY the telemetry contract suite (benchmarks/obs_bench.py)
              and gate its bench_out/BENCH_obs.json: schema, trace-vs-
              recomputation agreement per codec, telemetry on/off
              bit-identity, no-retrace trace counts, serve spans + events,
              and traced-sweep overhead <= 5% (a same-host ratio, the only
              timing-derived gate; never a wall-clock floor)
  --fault     run ONLY the fault-drill suite (benchmarks/fault_drill.py:
              the device-loss drill matrix of DESIGN.md sec. 15 on 2x2
              simulated devices) and gate its bench_out/BENCH_fault.json:
              every drill completes, zero lost queries, recovered outputs
              bit-identical (Graph500-valid preds after a shrink), at
              least one drill actually shrank the grid, recovery latency
              recorded as a number, and the no-retrace proof that
              fault_tolerance=False builds nothing -- never wall-clock
  --scale N   force every honoring suite to graph scale N (REPRO_BENCH_SCALE)
  --smoke     reduced CI suite list (fold codecs on 2x2 simulated devices,
              strong-scaling mini sweep, per-level breakdown + fold wire
              bytes, algos sweep, expand paths, exchange crossover, kernel
              parity) with fewer roots/iters; the bit-exactness and schema
              gates still run in full and a violation exits non-zero (the
              regression gates are on correctness counters and wire-byte
              accounting, never on wall-clock)
"""
import argparse
import json
import os
import sys
import time
import traceback

# runnable as `python benchmarks/run.py` from anywhere: the suites import
# each other through the `benchmarks` namespace package at the repo root,
# and the in-process suites (algos_sweep, kernel_bench) import repro
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import common


def _f(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


def write_bench_json() -> None:
    """Aggregate whatever CSVs exist into bench_out/BENCH_bfs.json."""
    from benchmarks.common import emit_json, read_csv

    def teps_rows(name):
        return [
            {"variant": r.get("variant"), "grid": f'{r.get("R")}x{r.get("C")}',
             "scale": _f(r.get("scale")), "ef": _f(r.get("ef")),
             "harmonic_TEPS": _f(r.get("harmonic_TEPS")),
             "mean_s": _f(r.get("mean_s")), "levels": _f(r.get("levels")),
             "fold": r.get("fold"),
             "fold_bytes_per_edge": _f(r.get("fold_bytes_per_edge")),
             # the session API's amortised view: all roots in ONE compiled
             # program (GraphSession.bfs(roots_batch)); batched_harmonic is
             # the harmonic mean over the SAME count_component_edges
             # numerators as harmonic_TEPS, over sweep_s / n_roots
             "batched_sweep_s": _f(r.get("batched_sweep_s")),
             "amortised_TEPS": _f(r.get("amortised_TEPS")),
             "batched_harmonic_TEPS": _f(r.get("batched_harmonic_TEPS"))}
            for r in read_csv(name)]

    codecs = {}
    for r in read_csv("fold_codecs"):
        codecs[r["fold"]] = {
            "harmonic_TEPS": _f(r.get("harmonic_TEPS")),
            "bytes_per_edge": _f(r.get("fold_bytes_per_edge")),
            "batched_sweep_s": _f(r.get("batched_sweep_s")),
            "amortised_TEPS": _f(r.get("amortised_TEPS")),
            "batched_harmonic_TEPS": _f(r.get("batched_harmonic_TEPS")),
            "lvl_sum": r.get("lvl_sum"), "pred_sum": r.get("pred_sum"),
            "scale": _f(r.get("scale")), "grid": f'{r.get("R")}x{r.get("C")}'}

    # per-LEVEL traversal counters of a real search (v7: read from the
    # in-program LevelTrace -- work counters, not wall times; fed by
    # benchmarks/bfs_breakdown.py through workers/trace_worker.py)
    phases = [
        {"scale": _f(r.get("scale")), "grid": f'{r.get("R")}x{r.get("C")}',
         "level": _f(r.get("level")), "frontier": _f(r.get("frontier")),
         "scanned": _f(r.get("scanned")), "folded": _f(r.get("folded")),
         "wire_bytes": _f(r.get("wire_bytes")), "msgs": _f(r.get("msgs")),
         "dir": _f(r.get("dir"))}
        for r in read_csv("fig5_6_breakdown")]

    # fold wire-byte accounting per codec, summed over the measured levels:
    # PR-4 layout (separate count collective + dense value channel) vs the
    # fused single message (header word + count-proportional value prefix)
    fold_wire = {}
    for r in read_csv("fold_wire"):
        key = (r["codec"], f'{r.get("R")}x{r.get("C")}')
        agg = fold_wire.setdefault(key, {
            "codec": r["codec"], "grid": key[1], "scale": _f(r.get("scale")),
            "levels": 0, "folded": 0,
            "set_msgs_before": int(r["set_msgs_before"]),
            "value_msgs_before": int(r["value_msgs_before"]),
            "msgs_after": int(r["msgs_after"]),
            "set_bytes_before": 0, "set_bytes_after": 0,
            "value_bytes_dense": 0, "value_bytes_sent": 0,
            "edges": int(r["edges"])})
        agg["levels"] += 1
        agg["folded"] += int(r["folded"])
        for k in ("set_bytes_before", "set_bytes_after", "value_bytes_dense",
                  "value_bytes_sent"):
            agg[k] += int(r[k])
    for agg in fold_wire.values():
        e = max(agg["edges"], 1)
        agg["value_bytes_per_edge_dense"] = agg["value_bytes_dense"] / e
        agg["value_bytes_per_edge_sent"] = agg["value_bytes_sent"] / e
    fold_wire = [fold_wire[k] for k in sorted(fold_wire)]

    # the expand-path dimension (v4): per-level expand wall times for the
    # reference scan vs the fused Pallas(-interpret) kernel, same search
    exp_rows = read_csv("expand_paths")
    expand_paths = {}
    for r in exp_rows:
        expand_paths.setdefault(r["path"], []).append({
            "level": _f(r.get("level")), "frontier": _f(r.get("frontier")),
            "edges": _f(r.get("edges")),
            "expand_s": _f(r.get("expand_s"))})

    # the direction dimension (v6): per-mode whole-search times with
    # bit-equality checksums, the adaptive per-level decision trace, and the
    # replayed bottom-up phase time per level (bfs_expansion_variants.
    # direction_sweep; DESIGN.md sec. 11)
    dir_rows = read_csv("direction_sweep")
    direction = {}
    for r in dir_rows:
        direction[r["mode"]] = {
            "scale": _f(r.get("scale")), "grid": f'{r.get("R")}x{r.get("C")}',
            "mean_s": _f(r.get("mean_s")), "levels": _f(r.get("levels")),
            "lvl_sum": r.get("lvl_sum"), "pred_sum": r.get("pred_sum"),
            "dirs": [int(x) for x in r.get("dirs", "").split("|")
                     if x not in ("", "-1")]}
    direction_levels = [
        {"level": _f(r.get("level")), "frontier": _f(r.get("frontier")),
         "dir": _f(r.get("dir")), "bottomup_s": _f(r.get("bottomup_s"))}
        for r in read_csv("direction_levels")]

    # the exchange dimension (v8): flat vs butterfly fold routes on a 1xC
    # column grid -- per-level msgs/bytes from the LevelTrace, aggregated
    # to per-strategy totals so the message crossover (log2(C) vs C-1) is
    # trackable across PRs (benchmarks/bfs_exchange.py; DESIGN.md sec. 14)
    ex_rows = read_csv("exchange")
    exchange = {}
    for r in ex_rows:
        key = (r["strategy"], r["codec"])
        agg = exchange.setdefault(key, {
            "strategy": r["strategy"], "codec": r["codec"],
            "C": int(r["C"]), "scale": _f(r.get("scale")),
            "levels": 0, "total_msgs": 0, "total_wire_bytes": 0,
            "folded": 0})
        agg["levels"] += 1
        agg["total_msgs"] += int(r["msgs"])
        agg["total_wire_bytes"] += int(r["wire_bytes"])
        agg["folded"] += int(r["folded"])
    exchange = [exchange[k] for k in sorted(exchange)]
    # bit-identity across strategies is asserted INSIDE bfs_exchange.py on
    # the raw checksums; the JSON records whether the comparison ran and
    # whether every (codec, level) row pair agreed on frontier/folded
    by_cell = {}
    for r in ex_rows:
        by_cell.setdefault((r["codec"], r["level"]), {})[r["strategy"]] = \
            (r.get("frontier"), r.get("folded"))
    exchange_agree = (all(len(set(cell.values())) == 1
                          for cell in by_cell.values())
                      if ex_rows else None)

    out = {
        "schema": "BENCH_bfs/v8",   # v8: + exchange (flat-vs-butterfly
                                    # message/byte totals + agreement) and
                                    # the msgs trace channel in phases;
                                    # v7: phases = in-program LevelTrace
                                    # counters instead of host-replay times
        "teps": {
            "weak_scaling": teps_rows("fig3_weak_scaling"),
            "strong_scaling": teps_rows("fig4_strong_scaling"),
            "one_d_vs_two_d": teps_rows("fig7_1d_vs_2d"),
        },
        "fold_codecs": codecs,
        # null (not true) when no comparison ran -- an absent suite must not
        # read as a passed bit-exactness gate
        "codecs_agree": (len({(v["lvl_sum"], v["pred_sum"])
                              for v in codecs.values()}) == 1
                         if codecs else None),
        "phases": phases,
        "fold_wire": fold_wire,
        "expand_paths": expand_paths,
        "expand_paths_agree": (len({r.get("lvl_sum") for r in exp_rows}) == 1
                               if exp_rows else None),
        "direction": direction,
        "direction_levels": direction_levels,
        # null (not true) when the sweep did not run: an absent suite must
        # not read as a passed bit-equality gate
        "direction_agree": (
            len({(v["lvl_sum"], v["pred_sum"]) for v in direction.values()})
            == 1 if direction else None),
        "exchange": exchange,
        "exchange_agree": exchange_agree,
    }
    path = emit_json(out, "BENCH_bfs")
    print(f"\nwrote {path}")


def validate_serve() -> list:
    """Gates over bench_out/BENCH_serve.json (the --serve mode artifact).

    Correctness and coalescing-shape gates only -- zero failed queries,
    every point bit-identical to direct GraphSession calls, the highest
    offered-load point actually batching (mean occupancy > 1), and the
    fault drill failing exactly its one poisoned request -- NEVER
    wall-clock (the p50/p99 columns are trajectory data, not gates).
    """
    errors = []
    p = os.path.join(common.OUT_DIR, "BENCH_serve.json")
    if not os.path.exists(p):
        return ["BENCH_serve.json missing"]
    try:
        with open(p) as f:
            serve = json.load(f)
    except json.JSONDecodeError as e:
        return [f"BENCH_serve.json: invalid JSON ({e})"]
    if serve.get("schema") != "BENCH_serve/v1":
        errors.append(f"BENCH_serve schema {serve.get('schema')!r} != "
                      f"'BENCH_serve/v1'")
    for key in ("load", "fault", "aot_cache", "tenants"):
        if key not in serve:
            errors.append(f"BENCH_serve missing key {key!r}")
    load = serve.get("load") or []
    if len(load) < 3:
        errors.append(f"BENCH_serve: {len(load)} offered-load points < 3")
    for p_ in load:
        if p_.get("bitexact") is not True:
            errors.append(f"BENCH_serve: point offered_qps="
                          f"{p_.get('offered_qps')} not bit-exact")
        if p_.get("n_failed"):
            errors.append(f"BENCH_serve: {p_['n_failed']} failed queries at "
                          f"offered_qps={p_.get('offered_qps')}")
    if load:
        top = max(load, key=lambda p_: p_.get("offered_qps") or 0)
        if not ((top.get("mean_occupancy") or 0) > 1):
            errors.append(
                f"BENCH_serve: highest offered load did not coalesce "
                f"(mean_occupancy={top.get('mean_occupancy')} <= 1)")
    drill = serve.get("fault")
    if not drill:
        errors.append("BENCH_serve: fault drill missing")
    else:
        if drill.get("injected") != 1 or drill.get("failed") != 1:
            errors.append(f"BENCH_serve: fault drill must fail exactly the "
                          f"poisoned request, got {drill}")
        if not drill.get("ok_after"):
            errors.append(f"BENCH_serve: no queries served after the fault "
                          f"({drill})")
    if not serve.get("aot_cache"):
        errors.append("BENCH_serve: aot_cache section empty")
    if len(serve.get("tenants") or {}) < 2:
        errors.append("BENCH_serve: expected >= 2 tenants in accounting")
    return errors


def validate_obs() -> list:
    """Gates over bench_out/BENCH_obs.json (the --obs mode artifact).

    Correctness gates: trace-vs-recomputation agreement for every codec,
    telemetry on/off bit-identity, the no-retrace trace-count proof, serve
    spans + a non-empty event log, a rendering Prometheus endpoint -- plus
    the one timing-DERIVED gate in CI: the traced batched sweep may cost at
    most 5% over the untraced one (medians of alternating repeats, with a
    10ms absolute epsilon for timer noise).  That is a same-host ratio of
    the same program, not a wall-clock floor.
    """
    errors = []
    p = os.path.join(common.OUT_DIR, "BENCH_obs.json")
    if not os.path.exists(p):
        return ["BENCH_obs.json missing"]
    try:
        with open(p) as f:
            obs = json.load(f)
    except json.JSONDecodeError as e:
        return [f"BENCH_obs.json: invalid JSON ({e})"]
    if obs.get("schema") != "BENCH_obs/v1":
        errors.append(f"BENCH_obs schema {obs.get('schema')!r} != "
                      f"'BENCH_obs/v1'")
    agreement = obs.get("agreement") or {}
    if len(agreement) < 3:
        errors.append(f"BENCH_obs: agreement covers {len(agreement)} codecs "
                      f"< 3")
    for codec, checks in agreement.items():
        for name, ok in checks.items():
            if ok is not True:
                errors.append(f"BENCH_obs: {codec} trace {name} != true "
                              f"(trace disagrees with recomputation)")
    if obs.get("direction_agreement") is not True:
        errors.append("BENCH_obs: trace.direction disagrees with the "
                      "engine's directions output")
    bitexact = obs.get("bitexact") or {}
    if len(bitexact) < 3:
        errors.append(f"BENCH_obs: bitexact covers {len(bitexact)} codecs "
                      f"< 3")
    for codec, ok in bitexact.items():
        if ok is not True:
            errors.append(f"BENCH_obs: telemetry on/off NOT bit-identical "
                          f"for codec {codec}")
    for codec, tc in (obs.get("trace_counts") or {}).items():
        if tc.get("after_first_sweep") != tc.get("after_second_sweep"):
            errors.append(f"BENCH_obs: {codec} retraced on a repeat sweep "
                          f"({tc})")
    if not obs.get("trace_counts"):
        errors.append("BENCH_obs: trace_counts section empty")
    ov = obs.get("overhead") or {}
    frac, on, off = (ov.get("overhead_frac"), ov.get("on_median_s"),
                     ov.get("off_median_s"))
    if frac is None or on is None or off is None:
        errors.append(f"BENCH_obs: overhead section incomplete ({ov})")
    elif frac > 0.05 and (on - off) > 0.010:
        errors.append(f"BENCH_obs: traced sweep overhead {frac:.1%} > 5% "
                      f"(on={on:.4f}s off={off:.4f}s)")
    spans = obs.get("spans") or {}
    if spans.get("ok") is not True:
        errors.append("BENCH_obs: serve request-trace spans malformed")
    if not spans.get("n_events"):
        errors.append("BENCH_obs: serve event log recorded no events")
    if spans.get("prometheus_ok") is not True:
        errors.append("BENCH_obs: Prometheus exposition missing expected "
                      "series")
    return errors


def validate_fault() -> list:
    """Gates over bench_out/BENCH_fault.json (the --fault mode artifact).

    Correctness gates only: every drill in the matrix completes ok with
    zero lost queries, recovered outputs bit-identical where that is the
    contract (and Graph500-valid preds where it is not -- BFS after a
    shrink), at least one drill actually moved to a smaller grid, elastic
    drills RECORD their recovery latency (a number, never gated), and the
    no-retrace section proves `fault_tolerance=False` builds zero
    segmented programs and stays bit-identical / cache-resident.
    """
    errors = []
    p = os.path.join(common.OUT_DIR, "BENCH_fault.json")
    if not os.path.exists(p):
        return ["BENCH_fault.json missing"]
    try:
        with open(p) as f:
            fault = json.load(f)
    except json.JSONDecodeError as e:
        return [f"BENCH_fault.json: invalid JSON ({e})"]
    if fault.get("schema") != "BENCH_fault/v1":
        errors.append(f"BENCH_fault schema {fault.get('schema')!r} != "
                      f"'BENCH_fault/v1'")
    drills = fault.get("drills") or []
    if len(drills) < 20:
        errors.append(f"BENCH_fault: {len(drills)} drills < 20 (the "
                      "standard matrix)")
    runners = {d.get("runner") for d in drills}
    for need in ("session", "elastic", "serve"):
        if need not in runners:
            errors.append(f"BENCH_fault: no {need!r}-runner drill ran")
    shrunk = 0
    for d in drills:
        name = d.get("name", "?")
        if d.get("ok") is not True:
            errors.append(f"BENCH_fault[{name}]: ok != true "
                          f"(error={d.get('error')})")
        if d.get("lost_queries"):
            errors.append(f"BENCH_fault[{name}]: lost "
                          f"{d['lost_queries']} queries")
        if d.get("bit_identical") is False:
            errors.append(f"BENCH_fault[{name}]: recovered output NOT "
                          "bit-identical")
        if d.get("pred_valid") is False:
            errors.append(f"BENCH_fault[{name}]: recovered BFS preds "
                          "fail Graph500 validation")
        if d.get("grid_after") != d.get("grid_before"):
            shrunk += 1
        if d.get("runner") == "elastic" and not isinstance(
                d.get("time_to_first_resumed_level_s"), (int, float)):
            errors.append(f"BENCH_fault[{name}]: recovery latency not "
                          "recorded")
    if not shrunk:
        errors.append("BENCH_fault: no drill actually shrank the grid")
    nr = fault.get("no_retrace") or {}
    if nr.get("ft_off_segmented_programs") != 0:
        errors.append(f"BENCH_fault: fault_tolerance=False built "
                      f"{nr.get('ft_off_segmented_programs')} segmented "
                      "programs (expected 0)")
    if nr.get("after_first_sweep") != nr.get("after_second_sweep"):
        errors.append(f"BENCH_fault: repeat sweep retraced ({nr})")
    if nr.get("ft_on_off_bitexact") is not True:
        errors.append("BENCH_fault: FT on/off outputs NOT bit-identical")
    return errors


def validate_bench(smoke: bool) -> list:
    """Schema + correctness-counter gates over the emitted JSON artifacts.

    Returns a list of violation strings (empty = pass).  Gates correctness
    (codec / expand-path bit-exactness, schema shape), NEVER wall-clock.
    In smoke mode the smoke suites' sections are additionally REQUIRED, so
    a silently-skipped suite cannot read as a pass.
    """
    errors = []

    def load(name):
        p = os.path.join(common.OUT_DIR, f"{name}.json")
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                return json.load(f)
        except json.JSONDecodeError as e:
            errors.append(f"{name}.json: invalid JSON ({e})")
            return None

    bfs = load("BENCH_bfs")
    if bfs is None:
        errors.append("BENCH_bfs.json missing")
    else:
        if bfs.get("schema") != "BENCH_bfs/v8":
            errors.append(f"BENCH_bfs schema {bfs.get('schema')!r} != "
                          f"'BENCH_bfs/v8'")
        for key in ("teps", "fold_codecs", "codecs_agree", "phases",
                    "fold_wire", "expand_paths", "expand_paths_agree",
                    "direction", "direction_levels", "direction_agree",
                    "exchange", "exchange_agree"):
            if key not in bfs:
                errors.append(f"BENCH_bfs missing key {key!r}")
        if bfs.get("codecs_agree") is False:
            errors.append("fold codecs disagree on levels/preds "
                          "(codecs_agree = false)")
        if bfs.get("exchange_agree") is False:
            errors.append("flat vs butterfly per-level counters disagree "
                          "(exchange_agree = false)")
        # the butterfly must strictly undercut flat on per-level message
        # count whenever the exchange suite ran (log2(C) < C-1 at C >= 4);
        # wire-byte totals are trajectory data, never gated on magnitude
        ex = bfs.get("exchange") or []
        ex_msgs = {}
        for agg in ex:
            ex_msgs.setdefault(agg.get("codec"), {})[agg.get("strategy")] \
                = agg.get("total_msgs")
        for codec, per in ex_msgs.items():
            mf, mb = per.get("flat"), per.get("butterfly")
            if mf is not None and mb is not None and not (mb < mf):
                errors.append(f"exchange[{codec}]: butterfly msgs {mb} !< "
                              f"flat msgs {mf}")
        if bfs.get("expand_paths_agree") is False:
            errors.append("expand paths disagree on levels "
                          "(expand_paths_agree = false)")
        if bfs.get("direction_agree") is False:
            errors.append("direction modes disagree on levels/preds "
                          "(direction_agree = false)")
        # the compressed value channel must never exceed the PR-4
        # dense-channel baseline, and must STRICTLY undercut it for bitmap
        # (the codec the dense channel defeated hardest) whenever the
        # fold-wire suite ran
        for agg in bfs.get("fold_wire") or []:
            sent = agg.get("value_bytes_sent", 0)
            dense = agg.get("value_bytes_dense", 0)
            strict = agg.get("codec") == "bitmap"
            if (sent >= dense) if strict else (sent > dense):
                errors.append(
                    f"{agg.get('codec')} value-fold bytes not "
                    f"{'below' if strict else 'within'} the dense-channel "
                    f"baseline: sent={sent} vs dense={dense} "
                    f"(grid {agg.get('grid')})")
        if smoke:
            if not bfs.get("fold_codecs"):
                errors.append("smoke: fold_codecs section empty")
            if not bfs.get("phases"):
                errors.append("smoke: phases section empty")
            for row in bfs.get("phases") or []:
                if not (row.get("wire_bytes") or 0) > 0:
                    errors.append(f"smoke: phases row without trace wire "
                                  f"bytes: {row}")
                    break
            if not bfs.get("fold_wire"):
                errors.append("smoke: fold_wire section empty")
            if not any(c.get("codec") == "bitmap"
                       for c in bfs.get("fold_wire") or []):
                errors.append("smoke: fold_wire has no bitmap entry")
            if not (bfs.get("teps") or {}).get("strong_scaling"):
                errors.append("smoke: teps.strong_scaling empty")
            ep = bfs.get("expand_paths") or {}
            for path in ("reference", "pallas-interpret"):
                if not ep.get(path):
                    errors.append(f"smoke: expand_paths[{path!r}] empty")
            dr = bfs.get("direction") or {}
            for mode in ("False", "adaptive", "bottomup"):
                if mode not in dr:
                    errors.append(f"smoke: direction[{mode!r}] missing")
            if not bfs.get("direction_levels"):
                errors.append("smoke: direction_levels section empty")
            if not bfs.get("exchange"):
                errors.append("smoke: exchange section empty")
            if not any(a.get("strategy") == "butterfly"
                       for a in bfs.get("exchange") or []):
                errors.append("smoke: exchange has no butterfly entry")
            # the adaptive heuristic must actually flip at the smoke scale:
            # at least one top-down AND one bottom-up level
            ad = (dr.get("adaptive") or {}).get("dirs") or []
            if not (0 in ad and 1 in ad):
                errors.append(f"smoke: adaptive sweep exercised only one "
                              f"direction (dirs={ad})")

    algos = load("BENCH_algos")
    if algos is None:
        if smoke:
            errors.append("smoke: BENCH_algos.json missing")
    else:
        if algos.get("schema") != "BENCH_algos/v1":
            errors.append(f"BENCH_algos schema {algos.get('schema')!r} != "
                          f"'BENCH_algos/v1'")
        for name, res in (algos.get("algos") or {}).items():
            if res.get("codecs_agree") is not True:
                errors.append(f"BENCH_algos[{name!r}]: codecs_agree != true")
        if smoke and not algos.get("algos"):
            errors.append("smoke: BENCH_algos has no algos")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scale", type=int, default=None,
                    help="force graph scale for suites that honor it")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI suite list; correctness gates in full")
    ap.add_argument("--serve", action="store_true",
                    help="run only the serve-load suite and gate "
                         "BENCH_serve.json")
    ap.add_argument("--obs", action="store_true",
                    help="run only the telemetry contract suite and gate "
                         "BENCH_obs.json")
    ap.add_argument("--fault", action="store_true",
                    help="run only the fault-drill matrix and gate "
                         "BENCH_fault.json")
    args = ap.parse_args(argv)
    if args.scale is not None:
        os.environ["REPRO_BENCH_SCALE"] = str(args.scale)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    if args.fault:
        from benchmarks import fault_drill
        print("\n=== fault_drill ===")
        t0 = time.time()
        try:
            fault_drill.main()
            print(f"--- fault_drill done in {time.time() - t0:.0f}s")
        except Exception:
            print(f"--- fault_drill FAILED:"
                  f"\n{traceback.format_exc()[-1500:]}")
            sys.exit(1)
        errors = validate_fault()
        for e in errors:
            print(f"VALIDATION: {e}")
        if errors:
            sys.exit(1)
        print("fault validation OK")
        return

    if args.obs:
        from benchmarks import obs_bench
        print("\n=== obs_bench ===")
        t0 = time.time()
        try:
            obs_bench.main()
            print(f"--- obs_bench done in {time.time() - t0:.0f}s")
        except Exception:
            print(f"--- obs_bench FAILED:\n{traceback.format_exc()[-1500:]}")
            sys.exit(1)
        errors = validate_obs()
        for e in errors:
            print(f"VALIDATION: {e}")
        if errors:
            sys.exit(1)
        print("obs validation OK")
        return

    if args.serve:
        from benchmarks import serve_load
        print("\n=== serve_load ===")
        t0 = time.time()
        try:
            serve_load.main()
            print(f"--- serve_load done in {time.time() - t0:.0f}s")
        except Exception:
            print(f"--- serve_load FAILED:\n{traceback.format_exc()[-1500:]}")
            sys.exit(1)
        errors = validate_serve()
        for e in errors:
            print(f"VALIDATION: {e}")
        if errors:
            sys.exit(1)
        print("serve validation OK")
        return

    from benchmarks import (bfs_weak_scaling, bfs_strong_scaling,
                            bfs_breakdown, bfs_1d_vs_2d, bfs_fold_codecs,
                            bfs_expand_paths, bfs_expansion_variants,
                            bfs_exchange, bfs_realworld, algos_sweep,
                            kernel_bench)
    # (suite label, entry point, CSV name(s) the suite emits)
    suites = [
        ("algos_sweep", algos_sweep.main, "algos_sweep"),
        ("fig3_weak_scaling", bfs_weak_scaling.main, "fig3_weak_scaling"),
        ("fig4_strong_scaling", bfs_strong_scaling.main,
         "fig4_strong_scaling"),
        ("fig5_6_breakdown", bfs_breakdown.main,
         ("fig5_6_breakdown", "fold_wire")),
        ("fig7_1d_vs_2d", bfs_1d_vs_2d.main, "fig7_1d_vs_2d"),
        ("fold_codecs", bfs_fold_codecs.main, "fold_codecs"),
        ("expand_paths", bfs_expand_paths.main, "expand_paths"),
        ("table2_fig8_expansion", bfs_expansion_variants.main,
         "table2_fig8_expansion_variants"),
        ("direction_sweep", bfs_expansion_variants.direction_sweep,
         ("direction_sweep", "direction_levels")),
        ("exchange", bfs_exchange.main, "exchange"),
        ("table3_realworld", bfs_realworld.main, "table3_realworld"),
        ("kernel_bench", kernel_bench.main, "kernel_bench"),
    ]
    if args.smoke:
        keep = {"algos_sweep", "fig4_strong_scaling", "fig5_6_breakdown",
                "fold_codecs", "expand_paths", "direction_sweep",
                "exchange", "kernel_bench"}
        suites = [s for s in suites if s[0] in keep]
    failures = 0
    for name, fn, csv_names in suites:
        print(f"\n=== {name} ===")
        # drop the previous run's CSVs first: a failing suite must leave a
        # GAP in BENCH_bfs.json, not silently contribute stale numbers
        if isinstance(csv_names, str):
            csv_names = (csv_names,)
        for csv_name in csv_names:
            stale = os.path.join(common.OUT_DIR, f"{csv_name}.csv")
            if os.path.exists(stale):
                os.remove(stale)
        t0 = time.time()
        try:
            fn()
            print(f"--- {name} done in {time.time() - t0:.0f}s")
        except Exception:
            failures += 1
            print(f"--- {name} FAILED:\n{traceback.format_exc()[-1500:]}")
    write_bench_json()
    errors = validate_bench(args.smoke)
    for e in errors:
        print(f"VALIDATION: {e}")
    if failures or errors:
        sys.exit(1)
    print("validation OK")


if __name__ == "__main__":
    main()
