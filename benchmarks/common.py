"""Shared benchmark helpers: timing, CSV/JSON emission, subprocess workers."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "bench_out")


def bench_scale(default: int) -> int:
    """Suite graph scale: the REPRO_BENCH_SCALE env override (set by
    `benchmarks/run.py --scale N`, e.g. the CI smoke job) or the suite's
    full-run default."""
    v = os.environ.get("REPRO_BENCH_SCALE", "").strip()
    return int(v) if v else default


def smoke_mode() -> bool:
    """True under `benchmarks/run.py --smoke` (CI: fewer roots/iters; the
    correctness gates still run in full)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

# One header for every suite driving workers/bfs_worker.py -- the worker's
# print order and the suites' CSVs must agree, so it lives here once.
# batched_harmonic_TEPS: harmonic mean over roots of
#   component_edges(root) / (sweep_s / n_roots)
# -- the same count_component_edges numerator as the per-root harmonic_TEPS
# column, applied to the amortised per-root time of the batched sweep.
BFS_WORKER_HEADER = (
    "variant", "R", "C", "scale", "ef", "roots", "harmonic_TEPS", "mean_s",
    "levels", "fold", "fold_bytes_per_edge", "batched_sweep_s",
    "amortised_TEPS", "batched_harmonic_TEPS", "lvl_sum", "pred_sum")


def emit(rows, name):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        for r in rows:
            line = ",".join(str(x) for x in r)
            print(line)
            f.write(line + "\n")
    return path


def emit_json(obj, name):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def read_csv(name):
    """Rows of bench_out/<name>.csv as dicts (header = first row), or []."""
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        lines = [l.strip().split(",") for l in f if l.strip()]
    if len(lines) < 2:
        return []
    hdr = lines[0]
    return [dict(zip(hdr, row)) for row in lines[1:]]


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters


def run_worker(script_rel: str, *args, timeout=900):
    """Run benchmarks/workers/<script> in a subprocess (own device count)."""
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    r = subprocess.run(
        [sys.executable, os.path.join(here, "workers", script_rel),
         *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"{script_rel} failed:\n{r.stderr[-2000:]}")
    return r.stdout
