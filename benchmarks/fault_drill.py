"""Fault-drill suite: the standard device-loss matrix on 2x2 simulated
devices (benchmarks/workers/fault_worker.py) -> CSV + bench_out/
BENCH_fault.json.

Emits:
  fault_drills.csv  one row per drill (verdict, bit-identity, grids,
                    recovery latency)
  BENCH_fault.json  schema BENCH_fault/v1 -- the artifact
                    `benchmarks/run.py --fault` gates on: every drill ok,
                    zero lost queries, bit-identical recovered outputs,
                    at least one real shrink, and the no-retrace proof.
                    Recovery latency is RECORDED, never wall-clock-gated.
"""
import json

from benchmarks.common import bench_scale, emit, emit_json, run_worker

DRILL_HEADER = ("name", "runner", "ok", "bit_identical", "pred_valid",
                "lost_queries", "grid_before", "grid_after",
                "resumed_from_level", "time_to_first_resumed_level_s",
                "retries", "resumes", "error")


def main() -> None:
    scale = bench_scale(10)
    out = run_worker("fault_worker.py", scale, 8, 2, 2, timeout=3600)
    drills, no_retrace = [], None
    for line in out.splitlines():
        tag, _, rest = line.partition(",")
        if tag == "DRILL":
            drills.append(json.loads(rest))
        elif tag == "NORETRACE":
            no_retrace = json.loads(rest)
    emit([DRILL_HEADER] + [[d.get(k) for k in DRILL_HEADER]
                           for d in drills], "fault_drills")
    path = emit_json({
        "schema": "BENCH_fault/v1",
        "scale": scale,
        "grid": "2x2",
        "drills": drills,
        "no_retrace": no_retrace,
    }, "BENCH_fault")
    print(f"wrote {path}")
