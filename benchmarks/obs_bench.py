"""Telemetry contract suite -> bench_out/BENCH_obs.json (DESIGN.md sec. 13).

Drives `workers/trace_worker.py` in obs mode and aggregates the evidence
the obs-smoke CI job gates on:

  agreement    every LevelTrace channel matches an independent
               recomputation (frontier vs np.bincount of the output levels,
               wire bytes vs the codec's static formula x P, scanned vs the
               64-bit edges_scanned total, msgs vs the exchange strategy's
               per-exchange count x P, trace.direction vs the engine's own
               directions output)
  bitexact     telemetry on vs off produce bit-identical level/pred arrays
               per codec (checksummed in the worker)
  trace_counts per codec: engine.trace_count after the first batched sweep
               vs after a repeat -- equal counts prove telemetry costs no
               retrace on cache hits
  overhead     median over alternating traced/untraced batched sweeps;
               `overhead_frac` = (on - off) / off clipped at 0.  The gate
               allows 5% plus a small absolute epsilon for timer noise --
               the ONLY timing-derived gate in CI, and it is a ratio of
               the same program on the same host, not a wall-clock floor.
  spans        serve request traces tile queue/coalesce/execute/demux in
               lifecycle order, the JSONL event log recorded the batches
               (uploaded as a CI artifact), the Prometheus text renders.
"""
import os

from benchmarks import common
from benchmarks.common import bench_scale, emit_json, run_worker, smoke_mode

EVENTS_NAME = "obs_events.jsonl"


def main():
    r, c = 2, 2
    scale = bench_scale(10 if smoke_mode() else 12)
    events_path = os.path.join(common.OUT_DIR, EVENTS_NAME)
    if os.path.exists(events_path):
        os.remove(events_path)
    out = run_worker("trace_worker.py", r, c, scale, 16, "obs",
                     events_path).strip()

    agreement, checksums, trace_counts, reps, spans = {}, {}, {}, [], None
    dir_ok = None
    for line in out.splitlines():
        parts = line.strip().split(",")
        if parts[0] == "A":
            agreement[parts[1]] = {
                "frontier_ok": parts[2] == "True",
                "wire_ok": parts[3] == "True",
                "scanned_ok": parts[4] == "True",
                "msgs_ok": parts[5] == "True"}
        elif parts[0] == "D":
            dir_ok = parts[1] == "True"
        elif parts[0] == "E":
            checksums.setdefault(parts[1], {})[parts[2]] = \
                (int(parts[3]), int(parts[4]))
        elif parts[0] == "C":
            trace_counts[parts[1]] = {
                "after_first_sweep": int(parts[2]),
                "after_second_sweep": int(parts[3])}
        elif parts[0] == "O":
            reps.append((float(parts[2]), float(parts[3])))
        elif parts[0] == "S":
            spans = {"ok": parts[1] == "True", "n_events": int(parts[2]),
                     "prometheus_ok": parts[3] == "True"}
    if not (agreement and checksums and trace_counts and reps and spans):
        raise AssertionError("trace_worker obs mode produced an incomplete "
                             f"row set:\n{out}")

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    on_med = median([t for t, _ in reps])
    off_med = median([t for _, t in reps])
    result = {
        "schema": "BENCH_obs/v1",
        "grid": f"{r}x{c}",
        "scale": scale,
        "agreement": agreement,
        "direction_agreement": dir_ok,
        "bitexact": {codec: cs.get("on") == cs.get("off")
                     for codec, cs in checksums.items()},
        "trace_counts": trace_counts,
        "overhead": {
            "reps": len(reps),
            "on_median_s": on_med,
            "off_median_s": off_med,
            "overhead_frac": max(0.0, on_med / off_med - 1.0)
            if off_med else None,
        },
        "spans": spans,
        "events_artifact": EVENTS_NAME,
    }
    path = emit_json(result, "BENCH_obs")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
