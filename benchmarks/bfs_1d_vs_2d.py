"""Fig. 7 analog: the paper's ORIGINAL 1D modulo-partition code vs the 2D
code on the same graphs + devices.  Reports measured TEPS/time and (the
paper's key claim) the communication-volume ratio."""
from benchmarks.common import BFS_WORKER_HEADER, emit, run_worker

SCALE, EF, ROOTS = 14, 16, 3


def main():
    rows = [BFS_WORKER_HEADER]
    for variant, (r, c) in [("1d", (1, 8)), ("2d", (2, 4)),
                            ("1d", (1, 4)), ("2d", (2, 2))]:
        out = run_worker("bfs_worker.py", variant, r, c, SCALE, EF, ROOTS)
        rows.append(tuple(out.strip().split(",")))
    emit(rows, "fig7_1d_vs_2d")


if __name__ == "__main__":
    main()
