"""Serve-load suite: offered-load sweep against a GraphServer
(benchmarks/workers/serve_worker.py on 2x2 simulated devices) -> CSVs +
bench_out/BENCH_serve.json.

Emits:
  serve_load.csv    one row per offered-load point (latency percentiles,
                    achieved qps, occupancy, bit-exactness)
  serve_fault.csv   the fault-drill outcome (one poisoned request must fail
                    alone while the server keeps serving)
  BENCH_serve.json  schema BENCH_serve/v1 -- the machine-readable artifact
                    `benchmarks/run.py --serve` gates on (zero failed
                    queries, all points bit-exact, mean batch occupancy > 1
                    at the highest offered load; never wall-clock)
"""
from benchmarks.common import bench_scale, emit, emit_json, run_worker, \
    smoke_mode

LOAD_HEADER = ("offered_qps", "qps", "p50_ms", "p99_ms", "n_ok", "n_failed",
               "mean_occupancy", "bitexact")
FAULT_HEADER = ("injected", "failed", "ok_after", "retries")


def _f(x):
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


def main() -> None:
    scale = bench_scale(12)
    n_req = 24 if smoke_mode() else 96
    out = run_worker("serve_worker.py", scale, 8, 2, 2, n_req, timeout=1800)
    load, fault, cache, tenants = [], [], {}, {}
    for line in out.splitlines():
        tag, _, rest = line.partition(",")
        cells = rest.split(",")
        if tag == "LOAD":
            load.append(cells)
        elif tag == "FAULT":
            fault.append(cells)
        elif tag == "CACHE":
            cache[cells[0]] = {
                "size": _f(cells[1]), "maxsize": _f(cells[2]),
                "hits": _f(cells[3]), "misses": _f(cells[4]),
                "evictions": _f(cells[5])}
        elif tag == "TENANT":
            tenants[cells[0]] = {
                "queries": int(cells[1]), "ok": int(cells[2]),
                "failed": int(cells[3]), "rejected": int(cells[4]),
                "edges_scanned": int(cells[5])}
    emit([LOAD_HEADER] + load, "serve_load")
    emit([FAULT_HEADER] + fault, "serve_fault")

    points = [dict(zip(LOAD_HEADER, row)) for row in load]
    for p in points:
        for k in ("offered_qps", "qps", "p50_ms", "p99_ms",
                  "mean_occupancy"):
            p[k] = _f(p[k])
        for k in ("n_ok", "n_failed"):
            p[k] = int(p[k])
        p["bitexact"] = p["bitexact"] == "true"
    drill = dict(zip(FAULT_HEADER, map(int, fault[0]))) if fault else None
    path = emit_json({
        "schema": "BENCH_serve/v1",
        "scale": scale,
        "grid": "2x2",
        "n_requests_per_point": n_req,
        "load": points,            # offered-load sweep, low -> high
        "fault": drill,            # injected / failed / ok_after / retries
        "aot_cache": cache,        # per resident graph
        "tenants": tenants,        # accumulated over the whole run
    }, "BENCH_serve")
    print(f"wrote {path}")
