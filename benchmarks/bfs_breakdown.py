"""Fig. 5/6 analog: per-LEVEL four-phase breakdown of a real BFS (expand
exchange, frontier expansion, fold exchange, frontier update) plus the fold
wire-byte accounting per codec, before/after the single-message fold
overhaul (DESIGN.md sec. 10).

Emits two CSVs:
  fig5_6_breakdown  scale,R,C,level,frontier,expand_s,scan_s,fold_s,
                    update_s,transfer_frac     (one row per level)
  fold_wire         scale,R,C,codec,level,folded,msgs_before,msgs_after,
                    set_bytes_before,set_bytes_after,value_bytes_dense,
                    value_bytes_sent,edges     (one row per codec x level)

`*_before` / `*_dense` price the PR-4 layout (payload + separate count
collective, dense (C, S) int32 value channel); `*_after` / `*_sent` the
fused single message (header-word counts, front-packed count-proportional
value channel) using each level's measured fold counts.
"""
from benchmarks.common import bench_scale, emit, run_worker, smoke_mode

# collectives per fold exchange in the PR-4 layout (the fused path is
# always ONE); value-folds shipped a third dense-channel collective
MSGS_BEFORE = {"list": 2, "bitmap": 1, "delta": 2}
MSGS_VALUE_BEFORE = {"list": 3, "bitmap": 2, "delta": 3}


def main():
    grids = [(2, 2, bench_scale(10))] if smoke_mode() \
        else [(2, 2, bench_scale(14)), (2, 4, bench_scale(15))]
    phase_rows = [("scale", "R", "C", "level", "frontier", "expand_s",
                   "scan_s", "fold_s", "update_s", "transfer_frac")]
    wire_rows = [("scale", "R", "C", "codec", "level", "folded",
                  "set_msgs_before", "value_msgs_before", "msgs_after",
                  "set_bytes_before", "set_bytes_after", "value_bytes_dense",
                  "value_bytes_sent", "edges")]
    for (r, c, scale) in grids:
        out = run_worker("phases_worker.py", r, c, scale, 16).strip()
        levels, wires, edges = [], [], None
        for line in out.splitlines():
            parts = line.strip().split(",")
            if parts[0] == "P":
                levels.append(parts[1:])
            elif parts[0] == "B":
                wires.append(parts[1:])
            elif parts[0] == "M":
                edges = int(parts[2])
        if not levels or edges is None:
            raise AssertionError(
                f"phases_worker {r}x{c} produced no parseable rows")
        for s, R, C, lvl, frontier, e, sc, f, u in levels:
            comp = float(sc) + float(u)
            tr = float(e) + float(f)
            phase_rows.append(
                (s, R, C, lvl, frontier, e, sc, f, u,
                 f"{tr / (comp + tr):.3f}"))
        for codec, lvl, folded, sb, sa, vb, va in wires:
            wire_rows.append(
                (scale, r, c, codec, lvl, folded, MSGS_BEFORE[codec],
                 MSGS_VALUE_BEFORE[codec], 1, sb, sa, vb, va, edges))
    emit(phase_rows, "fig5_6_breakdown")
    emit(wire_rows, "fold_wire")
    # the fused value channel must undercut the dense baseline (the BENCH
    # gate re-checks this on the aggregated JSON)
    for row in wire_rows[1:]:
        if int(row[12]) > int(row[11]):
            raise AssertionError(f"fused value-fold bytes above dense "
                                 f"baseline: {row}")


if __name__ == "__main__":
    main()
