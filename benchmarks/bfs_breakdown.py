"""Fig. 5/6 analog: compute vs transfer split + four-phase breakdown of one
representative BFS level (expand exchange, frontier expansion, fold
exchange, frontier update) on 2x2 and 2x4 grids."""
from benchmarks.common import emit, run_worker


def main():
    rows = [("scale", "R", "C", "expand_s", "scan_s", "fold_s", "update_s",
             "compute_s", "transfer_s", "transfer_frac")]
    for (r, c, scale) in [(2, 2, 14), (2, 4, 15)]:
        out = run_worker("phases_worker.py", r, c, scale, 16).strip()
        s, R, C, e, sc, f, u = out.split(",")
        comp = float(sc) + float(u)
        tr = float(e) + float(f)
        rows.append((s, R, C, e, sc, f, u, f"{comp:.5f}", f"{tr:.5f}",
                     f"{tr / (comp + tr):.3f}"))
    emit(rows, "fig5_6_breakdown")


if __name__ == "__main__":
    main()
