"""Fig. 5/6 analog: per-LEVEL traversal breakdown of a real BFS from the
in-program telemetry channel (DESIGN.md sec. 13) plus the fold wire-byte
accounting per codec, before/after the single-message fold overhaul
(DESIGN.md sec. 10).

Since the telemetry subsystem the per-level numbers are read from ONE
traced production search (`LevelTrace`: frontier, scanned edges, folded
entries, fold wire bytes, direction) instead of a host-side phase replay --
the worker cross-checks every channel against an independent recomputation
(np.bincount of the output levels, the codec's static wire formula, the
64-bit edges_scanned total) and this suite asserts those agreement rows.

Emits two CSVs:
  fig5_6_breakdown  scale,R,C,level,frontier,scanned,folded,wire_bytes,
                    msgs,dir   (one row per level, list codec)
  fold_wire         scale,R,C,codec,level,folded,msgs_before,msgs_after,
                    set_bytes_before,set_bytes_after,value_bytes_dense,
                    value_bytes_sent,edges     (one row per codec x level)

`*_before` / `*_dense` price the PR-4 layout (payload + separate count
collective, dense (C, S) int32 value channel); `*_after` / `*_sent` the
fused single message (header-word counts, front-packed count-proportional
value channel).  `set_bytes_after` is the trace's OWN wire channel (P x the
codec's static frame -- the worker asserts the equality); `value_bytes_sent`
follows from the per-level folded counts by linearity of
`wire_bytes_values_sent`: sum over P devices of (wb + 4*folded_dev)
= P*wb + 4*folded_global.
"""
from benchmarks.common import bench_scale, emit, run_worker, smoke_mode

# collectives per fold exchange in the PR-4 layout (the fused path is
# always ONE); value-folds shipped a third dense-channel collective
MSGS_BEFORE = {"list": 2, "bitmap": 1, "delta": 2}
MSGS_VALUE_BEFORE = {"list": 3, "bitmap": 2, "delta": 3}


def main():
    grids = [(2, 2, bench_scale(10))] if smoke_mode() \
        else [(2, 2, bench_scale(14)), (2, 4, bench_scale(15))]
    phase_rows = [("scale", "R", "C", "level", "frontier", "scanned",
                   "folded", "wire_bytes", "msgs", "dir")]
    wire_rows = [("scale", "R", "C", "codec", "level", "folded",
                  "set_msgs_before", "value_msgs_before", "msgs_after",
                  "set_bytes_before", "set_bytes_after", "value_bytes_dense",
                  "value_bytes_sent", "edges")]
    for (r, c, scale) in grids:
        out = run_worker("trace_worker.py", r, c, scale, 16).strip()
        P = r * c
        traces, static, agree, edges = {}, {}, {}, None
        for line in out.splitlines():
            parts = line.strip().split(",")
            if parts[0] == "T":
                traces.setdefault(parts[1], []).append(
                    [int(x) for x in parts[2:]])
            elif parts[0] == "W":
                static[parts[1]] = (int(parts[2]), int(parts[3]))
            elif parts[0] == "A":
                agree[parts[1]] = parts[2:]
            elif parts[0] == "D":
                agree["direction"] = parts[1:]
            elif parts[0] == "M":
                edges = int(parts[2])
        if not traces or edges is None:
            raise AssertionError(
                f"trace_worker {r}x{c} produced no parseable rows")
        # the worker's trace-vs-recomputation agreement rows are a gate
        bad = {k: v for k, v in agree.items() if not all(
            x == "True" for x in v)}
        if bad:
            raise AssertionError(f"trace disagrees with independent "
                                 f"recomputation at {r}x{c}: {bad}")
        for lvl, frontier, scanned, folded, wire, msgs, d in traces["list"]:
            phase_rows.append(
                (scale, r, c, lvl, frontier, scanned, folded, wire, msgs, d))
        for codec, rows in traces.items():
            wb, wbv = static[codec]
            for lvl, frontier, scanned, folded, wire, msgs, d in rows:
                wire_rows.append(
                    (scale, r, c, codec, lvl, folded, MSGS_BEFORE[codec],
                     MSGS_VALUE_BEFORE[codec], 1, wb * P, wire, wbv * P,
                     wb * P + 4 * folded, edges))
    emit(phase_rows, "fig5_6_breakdown")
    emit(wire_rows, "fold_wire")
    # the fused value channel must undercut the dense baseline (the BENCH
    # gate re-checks this on the aggregated JSON)
    for row in wire_rows[1:]:
        if int(row[12]) > int(row[11]):
            raise AssertionError(f"fused value-fold bytes above dense "
                                 f"baseline: {row}")


if __name__ == "__main__":
    main()
