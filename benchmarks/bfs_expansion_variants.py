"""Fig. 8 / Table 2 analog: 'atomic-style' scatter-based frontier expansion
(Kepler path: deterministic scatter-min winner, our default) vs the
'scatter/compact' pre-Kepler path (sort-based dedup supporting benign races,
the paper's original).  Single device, one realistic level.

Also hosts the direction sweep (`direction_sweep`, DESIGN.md sec. 11):
top-down vs bottom-up vs adaptive whole searches plus the per-level
bottom-up phase times and alpha/beta decisions, so the crossover the
adaptive heuristic exploits is tracked across PRs."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, emit, run_worker, timeit


def _setup(scale=16, ef=16, frontier_frac=0.05):
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.graphgen import rmat_edges, build_csc
    n = 1 << scale
    edges = rmat_edges(jax.random.key(0), scale, ef)
    co, ri = build_csc(edges, n)
    rng = np.random.default_rng(0)
    f = rng.choice(n, int(n * frontier_frac), replace=False).astype(np.int32)
    return n, co, ri, jnp.asarray(f)


def main():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core import frontier as F

    n, co, ri, front = _setup()
    deg = co[front + 1] - co[front]
    cumul = F.exclusive_cumsum(
        jnp.where(jnp.arange(front.shape[0]) >= 0, deg, 0))
    total = int(cumul[-1])
    e_pad = ((total + 8191) // 8192) * 8192
    gids = jnp.arange(e_pad, dtype=jnp.int32)

    @jax.jit
    def candidates(visited):
        k = jnp.clip(jnp.searchsorted(cumul, gids, "right") - 1, 0,
                     front.shape[0] - 1).astype(jnp.int32)
        u = front[k]
        addr = co[u] + gids - cumul[k]
        valid = gids < total
        v = jnp.where(valid, ri[jnp.clip(addr, 0, ri.shape[0] - 1)], 0)
        return v, valid & ~visited[v]

    @jax.jit
    def atomic_style(visited):
        """scatter-min winner dedup (our Kepler-atomicOr analog)."""
        v, elig = candidates(visited)
        win = F.winner_dedup(v, elig, n)
        return visited.at[jnp.where(win, v, n)].set(True, mode="drop"), win

    @jax.jit
    def scatter_compact(visited):
        """pre-Kepler: sort by v, keep first of each run, then compact
        (the benign-race + compact primitive path of the original code)."""
        v, elig = candidates(visited)
        key = jnp.where(elig, v, n)
        order = jnp.argsort(key)
        vs = key[order]
        first = jnp.concatenate([jnp.ones((1,), bool), vs[1:] != vs[:-1]])
        win_sorted = first & (vs < n)
        win = jnp.zeros_like(win_sorted).at[order].set(win_sorted)
        return visited.at[jnp.where(win, v, n)].set(True, mode="drop"), win

    visited = jnp.zeros((n,), bool)
    va, wa = atomic_style(visited)
    vb, wb = scatter_compact(visited)
    assert (np.asarray(va) == np.asarray(vb)).all(), "variants disagree"

    t_a = timeit(lambda: jax.block_until_ready(atomic_style(visited)))
    t_b = timeit(lambda: jax.block_until_ready(scatter_compact(visited)))
    rows = [("variant", "edges", "us_per_call", "MTEPS_level"),
            ("atomic_scatter", total, f"{t_a * 1e6:.0f}",
             f"{total / t_a / 1e6:.1f}"),
            ("sort_compact", total, f"{t_b * 1e6:.0f}",
             f"{total / t_b / 1e6:.1f}"),
            ("speedup", "", f"{t_b / t_a:.2f}x", "")]
    emit(rows, "table2_fig8_expansion_variants")


DIR_SCALE_DEFAULT, DIR_EF = 14, 16
DIR_MODES = ("False", "adaptive", "bottomup")


def direction_sweep():
    """Direction-optimised traversal head-to-head on a 1x1 grid: per-mode
    whole-search times (bit-equality gated on the lvl/pred checksums) and
    the per-level bottom-up phase times + adaptive decisions.

    Emits two CSVs:
      direction_sweep   scale,R,C,mode,roots,mean_s,levels,lvl_sum,pred_sum,
                        dirs           (one row per mode; dirs "0|1|...")
      direction_levels  scale,level,frontier,dir,bottomup_s
                        (one row per BFS level of the replayed search)
    """
    scale = bench_scale(DIR_SCALE_DEFAULT)
    out = run_worker("direction_worker.py", scale, DIR_EF).strip()
    mode_rows = [("scale", "R", "C", "mode", "roots", "mean_s", "levels",
                  "lvl_sum", "pred_sum", "dirs")]
    level_rows = [("scale", "level", "frontier", "dir", "bottomup_s")]
    sums = {}
    for line in out.splitlines():
        parts = line.strip().split(",")
        if parts[0] == "M" and len(parts) == 8:
            mode_rows.append((scale, 1, 1, *parts[1:]))
            sums[parts[1]] = (parts[5], parts[6])
        elif parts[0] == "L" and len(parts) == 5:
            level_rows.append((scale, *parts[1:]))
    # emit BEFORE the gates: the rows are the diagnostic when one fires
    emit(mode_rows, "direction_sweep")
    emit(level_rows, "direction_levels")
    missing = [m for m in DIR_MODES if m not in sums]
    if missing:
        raise AssertionError(f"direction_worker produced no rows for "
                             f"{missing}")
    if len(level_rows) < 2:
        raise AssertionError("direction_worker produced no per-level rows")
    if len(set(sums.values())) != 1:
        raise AssertionError(
            f"direction modes disagree on levels/preds: {sums}")
    print(f"# direction modes agree: lvl_sum,pred_sum = "
          f"{sums['False']}")


if __name__ == "__main__":
    main()
    direction_sweep()
