"""Worker: per-phase timing breakdown of the 2D BFS (paper Fig. 5/6).

Runs the four phases (expand exchange, frontier expansion, fold exchange,
frontier update) as separately-jitted stages on a host-driven level loop so
each can be wall-clocked.  CSV: scale,R,C,expand_s,scan_s,fold_s,update_s.

Usage: phases_worker.py R C SCALE EF
"""
import os
import sys
import time

R, C, SCALE, EF = (int(a) for a in sys.argv[1:5])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compat
from repro.graphgen import rmat_edges
from repro.core import Grid2D, partition_2d
from repro.core import frontier as F

n = 1 << SCALE
edges = rmat_edges(jax.random.key(42), SCALE, EF)
mesh = compat.make_mesh((R, C), ("r", "c"))
grid = Grid2D.for_vertices(n, R, C)
lg = partition_2d(np.asarray(edges), grid)
S = grid.S

dev = P(("r",), ("c",))


def sm(f, in_specs, out_specs):
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, check_vma=False))


# phase 1: expand exchange (all_gather along rows)
expand = sm(lambda fr, cnt: F.compact_blocks(
    jax.lax.all_gather(fr[0, 0], "r").reshape(R, S),
    jax.lax.all_gather(cnt[0, 0], "r").reshape(R))[0][None, None],
    (dev, dev), dev)

# phase 2: frontier expansion (local scan)
def scan_fn(co, ri, vis, lvl_a, pr, af, tot):
    i = jax.lax.axis_index("r").astype(jnp.int32)
    j = jax.lax.axis_index("c").astype(jnp.int32)
    ex = F.expand_frontier(co[0, 0], ri[0, 0], vis[0, 0], lvl_a[0, 0],
                           pr[0, 0], af[0, 0], tot[0, 0], jnp.int32(1),
                           grid=grid, i=i, j=j, edge_chunk=16384)
    return (ex.visited[None, None], ex.dst[None, None],
            ex.dst_cnt[None, None])


scan = sm(scan_fn, (dev,) * 7, (dev, dev, dev))

# phase 3: fold exchange (all_to_all along cols)
fold = sm(lambda d, c: (
    jax.lax.all_to_all(d[0, 0], "c", 0, 0)[None, None],
    jax.lax.all_to_all(c[0, 0], "c", 0, 0)[None, None]),
    (dev, dev), (dev, dev))

# phase 4: frontier update
def upd_fn(iv, ic, vis, lvl_a, pr):
    i = jax.lax.axis_index("r").astype(jnp.int32)
    j = jax.lax.axis_index("c").astype(jnp.int32)
    up = F.update_frontier(iv[0, 0], ic[0, 0], vis[0, 0], lvl_a[0, 0],
                           pr[0, 0], jnp.int32(1), grid=grid, i=i, j=j)
    return up.new_front[None, None], up.new_cnt[None, None]


update = sm(upd_fn, (dev,) * 5, (dev, dev))

# drive a realistic mid-search level: frontier = a random 10% of each block
rng = np.random.default_rng(0)
front = np.full((R, C, S), -1, np.int32)
cnt = np.full((R, C), S // 10, np.int32)
for i in range(R):
    for j in range(C):
        front[i, j, :S // 10] = rng.choice(grid.n_cols_local, S // 10,
                                           replace=False)
vis = np.zeros((R, C, grid.n_rows_local), bool)
lvl_a = np.full((R, C, grid.n_rows_local), -1, np.int32)
pr = np.full((R, C, grid.n_rows_local), -1, np.int32)


def t(fn, *args):
    o = fn(*args)
    jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(3):
        o = fn(*args)
        jax.block_until_ready(o)
    return (time.perf_counter() - t0) / 3


af = expand(jnp.asarray(front), jnp.asarray(cnt))
tot = jnp.full((R, C), int((af[0, 0] >= 0).sum()), jnp.int32)
t_expand = t(expand, jnp.asarray(front), jnp.asarray(cnt))
vis_j, dst, dcnt = scan(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                        jnp.asarray(vis), jnp.asarray(lvl_a), jnp.asarray(pr),
                        af, tot)
t_scan = t(scan, jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
           jnp.asarray(vis), jnp.asarray(lvl_a), jnp.asarray(pr), af, tot)
iv, ic = fold(dst, dcnt)
t_fold = t(fold, dst, dcnt)
t_update = t(update, iv, ic, vis_j, jnp.asarray(lvl_a), jnp.asarray(pr))

print(f"{SCALE},{R},{C},{t_expand:.5f},{t_scan:.5f},{t_fold:.5f},"
      f"{t_update:.5f}")
