"""Worker: per-LEVEL phase timing breakdown of the 2D BFS (paper Fig. 5/6)
plus fold wire-byte accounting per codec.

Runs a real BFS through the session API to obtain the level structure, then
re-drives every level's four phases (expand exchange, frontier expansion,
fold exchange, frontier update) as separately-jitted stages on the REAL
per-level frontier/visited state, wall-clocking each.  The fold stage and
the expand exchange go through the same `repro.dist` exchange/codec code the
engines use, so the timings track the fused single-message fold path
(DESIGN.md sec. 10).

For each codec and level it also reports the fold-exchange byte accounting
before/after the single-message overhaul: the PR-4 layout (separate count
collective, dense (C, S) int32 value channel) vs the fused message
(header-word counts, front-packed count-proportional value channel), using
the level's ACTUAL fold counts for the sent-bytes figure.

Output lines (parsed by benchmarks/bfs_breakdown.py):
  P,scale,R,C,level,frontier,expand_s,scan_s,fold_s,update_s
  B,codec,level,folded,set_before,set_after,val_before,val_after
  M,edges,<component edges>,n_levels,<levels>

Usage: phases_worker.py R C SCALE EF [MAX_LEVELS]
"""
import os
import sys
import time

R, C, SCALE, EF = (int(a) for a in sys.argv[1:5])
MAX_LEVELS = int(sys.argv[5]) if len(sys.argv) > 5 else 8
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core import frontier as F
from repro.core.validate import count_component_edges
from repro.dist import exchange as X
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges

n = 1 << SCALE
edges_np = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
mesh = make_mesh((R, C), ("r", "c"))
config = BFSConfig(grid=(R, C), edge_chunk=16384)
graph = DistGraph.from_edges(edges_np, config, mesh=mesh, n=n)
grid, topo = graph.grid, graph.topology
S, nrl = grid.S, grid.n_rows_local
dev = topo.dev_spec

# the real level structure: one session BFS from the first non-isolated root
deg = np.bincount(edges_np[0], minlength=n)
root = int(np.flatnonzero(deg > 0)[0])
out = graph.session().bfs(root)
level_g = np.asarray(out.level)[: grid.n]       # padded global level array
n_levels = int(out.n_levels)
comp_edges = count_component_edges(edges_np, level_g[:n])

# ---------------------------------------------------------------------------
# host-side reconstruction of per-device state entering each level
# ---------------------------------------------------------------------------
v_all = np.arange(grid.n, dtype=np.int64)
blk = v_all // S                      # vertex block b = j*R + i
own_i, own_j, t_in = blk % R, blk // R, v_all % S


def device_state(lvl: int):
    """(R, C, ...) frontier/visited/level arrays entering level `lvl`."""
    front = np.full((R, C, S), -1, np.int32)
    cnt = np.zeros((R, C), np.int32)
    in_front = level_g == lvl - 1
    for i in range(R):
        for j in range(C):
            mine = in_front & (own_i == i) & (own_j == j)
            cols = np.sort(i * S + t_in[mine]).astype(np.int32)
            front[i, j, : len(cols)] = cols
            cnt[i, j] = len(cols)
    visited = np.zeros((R, C, nrl), bool)
    lvl_arr = np.full((R, C, nrl), -1, np.int32)
    seen = (level_g >= 0) & (level_g <= lvl - 1)
    for i in range(R):
        # local row m*S + t on grid-row i holds vertex (m*R + i)*S + t
        rows_i = np.where(blk % R == i)[0]
        lr = (blk[rows_i] // R) * S + t_in[rows_i]
        visited[i, :, lr] = seen[rows_i, None]
        lvl_arr[i, :, lr] = np.where(seen[rows_i], level_g[rows_i], -1)[:, None]
    return (jnp.asarray(front), jnp.asarray(cnt), jnp.asarray(visited),
            jnp.asarray(lvl_arr))


# ---------------------------------------------------------------------------
# the four phases as separately-jitted shard_map stages
# ---------------------------------------------------------------------------
def sm(f, n_in, n_out):
    return jax.jit(topo.shard_map(f, in_specs=(dev,) * n_in,
                                  out_specs=(dev,) * n_out if n_out > 1
                                  else dev))


expand = sm(lambda fr, cnt: X.expand_exchange(
    fr[0, 0], cnt[0, 0], topo=topo)[0][None, None], 2, 1)


def scan_fn(co, ri, vis, la, pr, af, tot, lvl):
    i, j = topo.device_coords()
    ex = F.expand_frontier(co[0, 0], ri[0, 0], vis[0, 0], la[0, 0], pr[0, 0],
                           af[0, 0], tot[0, 0], lvl[0, 0], grid=grid, i=i,
                           j=j, edge_chunk=16384)
    return (ex.visited[None, None], ex.dst[None, None],
            ex.dst_cnt[None, None])


scan = sm(scan_fn, 8, 3)

CODECS = ("list", "bitmap", "delta")
folds = {}
for name in CODECS:
    codec = X.get_fold_codec(name, grid)

    def fold_fn(d, c, codec=codec):
        _, j = topo.device_coords()
        iv, ic = codec.fold(d[0, 0], c[0, 0], topo=topo, j=j)
        return iv[None, None], ic[None, None]

    folds[name] = sm(fold_fn, 2, 2)


def upd_fn(iv, ic, vis, la, pr, lvl):
    i, j = topo.device_coords()
    up = F.update_frontier(iv[0, 0], ic[0, 0], vis[0, 0], la[0, 0], pr[0, 0],
                           lvl[0, 0], grid=grid, i=i, j=j)
    return up.new_front[None, None], up.new_cnt[None, None]


update = sm(upd_fn, 6, 2)


def t(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# fold wire-byte accounting: PR-4 layout vs the fused single message
# ---------------------------------------------------------------------------


def fold_bytes(codec, dev_counts):
    """(set_before, set_after, val_before, val_after) bytes, ALL devices.

    The PR-4 "before" layout shipped the same payload+count bytes split
    across separate collectives (so set_before == set_after; the win there
    is message COUNT, tracked by bfs_breakdown's msgs columns) plus a dense
    (C, S) int32 value channel (`wire_bytes_values`, the static capacity);
    "after" is the fused single message with the count-proportional value
    prefix (`wire_bytes_values_sent` over each device's actual counts)."""
    set_bytes = codec.wire_bytes(grid) * grid.P
    val_before = codec.wire_bytes_values(grid) * grid.P
    val_after = sum(codec.wire_bytes_values_sent(grid, int(c))
                    for c in dev_counts)
    return set_bytes, set_bytes, val_before, val_after


# ---------------------------------------------------------------------------
# drive the levels
# ---------------------------------------------------------------------------
csc = graph.csc
pred0 = jnp.full((R, C, nrl), -1, jnp.int32)
for lvl in range(1, min(n_levels, MAX_LEVELS) + 1):
    front, cnt, vis, la = device_state(lvl)
    frontier = int((level_g == lvl - 1).sum())
    if frontier == 0:
        break
    lvl_in = jnp.full((R, C), lvl, jnp.int32)
    af = expand(front, cnt)
    tot = jnp.asarray((np.asarray(af) >= 0).sum(axis=2).astype(np.int32))
    t_expand = t(expand, front, cnt)
    vis2, dst, dcnt = scan(csc.col_off, csc.row_idx, vis, la, pred0, af, tot,
                           lvl_in)
    t_scan = t(scan, csc.col_off, csc.row_idx, vis, la, pred0, af, tot,
               lvl_in)
    t_fold = t(folds["list"], dst, dcnt)
    iv, ic = folds["list"](dst, dcnt)
    t_update = t(update, iv, ic, vis2, la, pred0, lvl_in)
    print(f"P,{SCALE},{R},{C},{lvl},{frontier},{t_expand:.5f},{t_scan:.5f},"
          f"{t_fold:.5f},{t_update:.5f}")
    dev_counts = np.asarray(dcnt).sum(axis=2).reshape(-1)   # per device
    folded = int(dev_counts.sum())
    for name in CODECS:
        sb, sa, vb, va = fold_bytes(X.get_fold_codec(name, grid), dev_counts)
        print(f"B,{name},{lvl},{folded},{sb},{sa},{vb},{va}")

print(f"M,edges,{comp_edges},n_levels,{n_levels}")
