"""Worker: drive a GraphServer with open-loop mixed traffic on forced host
devices and print tagged result lines (parsed by benchmarks/serve_load.py):

  LOAD,offered_qps,qps,p50_ms,p99_ms,n_ok,n_failed,mean_occupancy,bitexact
  FAULT,injected,failed,ok_after,retries
  CACHE,graph,size,maxsize,hits,misses,evictions
  TENANT,tenant,queries,ok,failed,rejected,edges_scanned

Two resident graphs (scale S and S-1, both weighted so SSSP serves), one
server on an R x C simulated-device mesh.  The offered-load points are
derived from the measured single-query time t1: [0.25, 1, 4] / t1 -- below,
at, and far beyond what sequential dispatch could sustain, so the highest
point MUST coalesce (mean batch occupancy > 1) to keep up.  Traffic mixes
BFS / CC / SSSP / multi-BFS across both graphs and two tenants; every
response is checked bit-identical against direct GraphSession references
computed before the server starts.  After the load sweep, a fault drill
injects one poisoned request (a FaultInjector covering every retry attempt)
into a batch of good ones and verifies the server keeps serving.

Latency is end-to-end: ticket submission -> QueryResult.t_done (admission
wait + batching window + execution), reported as p50/p99 per offered-load
point.  The gates downstream are on correctness counters and occupancy,
never wall-clock.

Usage: serve_worker.py SCALE EF R C N_REQ
"""
import os
import sys
import time

SCALE, EF = int(sys.argv[1]), int(sys.argv[2])
R, C = int(sys.argv[3]), int(sys.argv[4])
N_REQ = int(sys.argv[5])

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={R * C}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges
from repro.runtime.fault import FaultInjector, RetryPolicy
from repro.serve import GraphServer, ServeConfig

mesh = make_mesh((R, C), ("r", "c"))
config = BFSConfig(grid=(R, C), edge_chunk=16384, fold_codec="list")


def plan(scale, seed):
    n = 1 << scale
    edges = np.asarray(rmat_edges(jax.random.key(seed), scale, EF))
    w = ((np.abs(edges[0] * 31 + edges[1]) % 254) + 1).astype(np.uint8)
    g = DistGraph.from_edges(edges, config, mesh=mesh, n=n, weights=w)
    deg = np.bincount(edges[0], minlength=n)
    roots = np.flatnonzero(deg > 0)[:64:8].astype(np.int32)  # 8-root pool
    return g, roots


GRAPHS = {"web": plan(SCALE, 42), "road": plan(SCALE - 1, 7)}
K_SOURCES = {name: roots[:4] for name, (_, roots) in GRAPHS.items()}

server = GraphServer(
    {name: g for name, (g, _) in GRAPHS.items()},
    ServeConfig(max_batch=8, window_s=0.005,
                retry=RetryPolicy(max_retries=1, backoff_s=0.01)))
server.warm(("bfs", "sssp", "cc"))

# direct-session references for every (graph, program, root) the traffic can
# emit -- computed BEFORE the executors start, so the bit-exactness check
# compares against an untouched session-layer run
REF = {}
for name, (g, roots) in GRAPHS.items():
    sess = server._workers[name].session_for(config)
    for r in roots:
        ob = sess.bfs(int(r))
        REF[(name, "bfs", int(r))] = (np.asarray(ob.level),
                                      np.asarray(ob.pred))
        REF[(name, "sssp", int(r))] = np.asarray(sess.sssp(int(r)).dist)
    REF[(name, "cc")] = np.asarray(sess.connected_components().labels)
    om = sess.multi_bfs(K_SOURCES[name])
    REF[(name, "multi_bfs")] = (np.asarray(om.level), np.asarray(om.src))

# measured single-query time anchors the offered-load sweep
sess0 = server._workers["web"].session_for(config)
_times = []
for _ in range(3):
    _t0 = time.perf_counter()
    jax.block_until_ready(sess0.bfs(int(GRAPHS["web"][1][0])).level)
    _times.append(time.perf_counter() - _t0)
t1 = min(_times)

server.start()

# request mixture: bfs-heavy with cc/sssp/multi_bfs riders, two tenants,
# alternating graphs (i -> (program, graph, tenant))
MIX = ("bfs", "bfs", "sssp", "bfs", "cc", "bfs", "sssp", "multi_bfs")


def check(name, program, root, value) -> bool:
    if program == "bfs":
        lvl, pred = REF[(name, "bfs", root)]
        return (np.array_equal(np.asarray(value.level), lvl)
                and np.array_equal(np.asarray(value.pred), pred))
    if program == "sssp":
        return np.array_equal(np.asarray(value.dist),
                              REF[(name, "sssp", root)])
    if program == "cc":
        return np.array_equal(np.asarray(value.labels), REF[(name, "cc")])
    lvl, src = REF[(name, "multi_bfs")]
    return (np.array_equal(np.asarray(value.level), lvl)
            and np.array_equal(np.asarray(value.src), src))


tenant_totals = {}


def fold_tenants():
    for t, s in server.accounting.snapshot()["tenants"].items():
        agg = tenant_totals.setdefault(t, dict.fromkeys(s, 0))
        for k, v in s.items():
            agg[k] += v


def run_point(offered_qps: float):
    server.accounting.reset()
    gap = 1.0 / offered_qps
    inflight = []               # (ticket, t_submit, graph, program, root)
    t_first = time.perf_counter()
    for i in range(N_REQ):
        target = t_first + i * gap          # open loop: fixed schedule
        while time.perf_counter() < target:
            time.sleep(min(gap / 4, 1e-3))
        program = MIX[i % len(MIX)]
        name = ("web", "road")[i % 2]
        roots = GRAPHS[name][1]
        tenant = ("alice", "bob")[i % 3 == 0]
        root = int(roots[i % len(roots)])
        if program == "cc":
            ticket = server.connected_components(name, tenant=tenant)
        elif program == "multi_bfs":
            ticket = server.multi_bfs(name, K_SOURCES[name], tenant=tenant)
        else:
            ticket = server.submit(name, program, root, tenant=tenant)
        inflight.append((ticket, time.perf_counter(), name, program, root))
    server.drain()
    lat, n_ok, n_failed, bitexact = [], 0, 0, True
    t_last = t_first
    for ticket, t_submit, name, program, root in inflight:
        res = ticket.result(timeout=60)
        lat.append(res.t_done - t_submit)
        t_last = max(t_last, res.t_done)
        if res.ok:
            n_ok += 1
            bitexact &= check(name, program, root, res.value)
        else:
            n_failed += 1
    occ = server.accounting.occupancy()
    fold_tenants()
    print(f"LOAD,{offered_qps:.3f},{n_ok / (t_last - t_first):.3f},"
          f"{np.percentile(lat, 50) * 1e3:.3f},"
          f"{np.percentile(lat, 99) * 1e3:.3f},{n_ok},{n_failed},"
          f"{occ:.3f},{str(bool(bitexact)).lower()}")


for mult in (0.25, 1.0, 4.0):
    run_point(mult / t1)

# fault drill: one poisoned request (injector fires on EVERY attempt, so
# batch retries exhaust and the isolation replay fails it alone) coalesced
# with good batchmates; the server must keep serving afterwards
server.accounting.reset()
roots = GRAPHS["web"][1]
good = [server.bfs("web", int(roots[i]), tenant="alice") for i in range(2)]
poisoned = server.bfs(
    "web", int(roots[2]), tenant="bob",
    injector=FaultInjector({i: RuntimeError for i in range(64)}))
good.append(server.bfs("web", int(roots[3]), tenant="alice"))
server.drain()
after = [server.bfs("web", int(roots[i]), tenant="alice") for i in range(4)]
server.drain()
pres = poisoned.result(timeout=60)
assert not pres.ok and "injected" in pres.error, pres
n_failed = sum(0 if t.result(timeout=60).ok else 1 for t in good + after)
ok_after = sum(1 for i, t in enumerate(after)
               if t.result(timeout=60).ok
               and check("web", "bfs", int(roots[i]),
                         t.result(timeout=60).value))
stats = server.metrics_snapshot()
fold_tenants()
print(f"FAULT,1,{n_failed + 1},{ok_after},"
      f"{stats['runners']['web']['retries']}")
for name, cache in stats["aot_cache"].items():
    print(f"CACHE,{name},{cache.get('size', '')},{cache.get('maxsize', '')},"
          f"{cache.get('hits', '')},{cache.get('misses', '')},"
          f"{cache.get('evictions', '')}")
for tenant in sorted(tenant_totals):
    s = tenant_totals[tenant]
    print(f"TENANT,{tenant},{s['queries']},{s['ok']},{s['failed']},"
          f"{s['rejected']},{s['edges_scanned']}")
server.stop()
