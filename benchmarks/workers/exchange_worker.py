"""Worker: flat vs butterfly fold-exchange head-to-head on a 1 x C column
grid (DESIGN.md sec. 14) -- the BENCH crossover evidence.

Runs the SAME telemetry-enabled BFS once per exchange strategy x fold
codec on C simulated devices and prints, from the in-program LevelTrace,
the per-level message and wire-byte totals plus bit-identity checksums.
The flat strategy ships one fused all_to_all (C-1 messages per device per
level); the butterfly ships log2(C) staged ppermutes (each C/2 of the C
buckets), so at C = 4 the message count drops 3 -> 2 per device while the
set-fold wire volume is EQUAL -- the crossover bfs_exchange.py asserts.

Output lines (parsed by benchmarks/bfs_exchange.py):
  X,strategy,codec,level,frontier,folded,wire_bytes,msgs   per level
  G,codec,lvl_sum,pred_sum,scanned   one row per strategy x codec; equal
                                     checksums across strategies = the
                                     bit-identity gate
  S,strategy,codec,levels,total_msgs,total_wire            totals

Usage: exchange_worker.py C SCALE EF
"""
import os
import sys

C, SCALE, EF = (int(a) for a in sys.argv[1:4])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges

STRATEGIES = ("flat", "butterfly")
CODECS = ("list", "bitmap", "delta")

n = 1 << SCALE
edges_np = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
mesh = make_mesh((1, C), ("r", "c"))
graph = DistGraph.from_edges(
    edges_np, BFSConfig(grid=(1, C), edge_chunk=16384), mesh=mesh, n=n)

deg = np.bincount(edges_np[0], minlength=n)
root = int(np.flatnonzero(deg > 0)[0])

for strategy in STRATEGIES:
    for codec in CODECS:
        sess = graph.session(BFSConfig(
            grid=(1, C), fold_codec=codec, edge_chunk=16384,
            telemetry=True, exchange=strategy))
        assert sess.engine.exchange.name == strategy
        out = sess.bfs(root)
        tr = sess.last_trace()
        for row in tr.levels():
            print(f"X,{strategy},{codec},{row['level']},{row['frontier']},"
                  f"{row['folded']},{row['wire_bytes']},{row['msgs']}")
        lvl_sum = int(np.asarray(out.level, np.int64).sum())
        pred_sum = int(np.asarray(out.pred, np.int64).sum())
        print(f"G,{strategy},{codec},{lvl_sum},{pred_sum},"
              f"{out.edges_scanned}")
        print(f"S,{strategy},{codec},{tr.n_levels},{tr.total_msgs},"
              f"{tr.total_wire_bytes}")
