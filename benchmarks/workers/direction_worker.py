"""Worker: direction-optimised traversal sweep on a 1x1 grid (DESIGN.md
sec. 11).

Times whole searches through the session API in all three modes --
direction=False (pure top-down), "adaptive" (alpha/beta switch) and
"bottomup" (every level pulls) -- over the same RMAT graph and root set,
plus a per-level replay of the bottom-up pull so the alpha/beta crossover
is visible level by level (which levels the adaptive heuristic flips, and
what the bottom-up phase costs at each frontier size).

Output lines (parsed by benchmarks/bfs_expansion_variants.direction_sweep):
  M,mode,roots,mean_s,levels,lvl_sum,pred_sum,dirs
     one per mode; `dirs` is the adaptive/bottomup per-level decision trace
     "0|1|1|0..." ("" for top-down); lvl_sum/pred_sum are the bit-equality
     checksums the suite gates on
  L,level,frontier,dir,bottomup_s
     one per BFS level: frontier size entering the level, the adaptive
     decision for it, and the measured wall time of the jitted bottom-up
     pull for that level

Usage: direction_worker.py SCALE EF
"""
import os
import sys
import time

SCALE, EF = int(sys.argv[1]), int(sys.argv[2])
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core import frontier as F
from repro.core.partition import partition_2d_csr
from repro.core.types import Grid2D
from repro.graphgen import rmat_edges

n = 1 << SCALE
edges = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
deg_out = np.bincount(edges[0], minlength=n)
roots = np.random.default_rng(5).choice(np.flatnonzero(deg_out > 0), 4,
                                        replace=False)
EDGE_CHUNK = 16384
N_ITERS = 2


def checksums(out):
    lvl = np.asarray(out.level).astype(np.int64)
    pred = np.asarray(out.pred).astype(np.int64)
    return int(lvl.sum()), int(pred.sum())


# --- whole-search sweep per mode -------------------------------------------
adaptive_dirs = None
for mode in (False, "adaptive", "bottomup"):
    cfg = BFSConfig(grid=(1, 1), edge_chunk=EDGE_CHUNK, direction=mode)
    sess = DistGraph.from_edges(edges, cfg, n=n).session()
    out = sess.bfs(roots)                       # warm the AOT cache
    t0 = time.perf_counter()
    for _ in range(N_ITERS):
        jax.block_until_ready(sess.bfs(roots).level)
    mean_s = (time.perf_counter() - t0) / (N_ITERS * len(roots))
    lvl_sum, pred_sum = checksums(out)
    dirs = ""
    if out.directions is not None:
        d = np.asarray(out.directions[0])
        dirs = "|".join(str(int(x)) for x in d[d >= 0])
        if mode == "adaptive":
            adaptive_dirs = d[d >= 0]
    print(f"M,{mode},{len(roots)},{mean_s:.6f},"
          f"{int(out.n_levels[0])},{lvl_sum},{pred_sum},{dirs}")

# --- per-level bottom-up replay (root 0 of the sweep) ----------------------
grid = Grid2D.for_vertices(n, 1, 1)
csr = partition_2d_csr(edges, grid)
row_off = jnp.asarray(csr["row_off"][0, 0])
col_idx = jnp.asarray(csr["col_idx"][0, 0])
row_deg = jnp.diff(row_off)
S = grid.S


@jax.jit
def bu_level(visited, front_mask, lvl):
    """One full bottom-up level on the 1x1 grid: every unvisited row scans
    its in-edges against the frontier bitmap (the engine's pull phase,
    un-distributed)."""
    words = F.pack_bitmap(front_mask)
    deg = jnp.where(visited, 0, row_deg)
    cumul = F.exclusive_cumsum(deg)
    total = cumul[-1]
    gids = jnp.arange(col_idx.shape[0], dtype=jnp.int32)
    r, c, hit = F.reference_bottomup_chunk(gids, cumul, total, row_off,
                                           col_idx, words, block=S)
    cand = jnp.full((S + 1,), F.I32_MAX, jnp.int32).at[
        jnp.where(hit, r, S)].min(jnp.where(hit, c, F.I32_MAX),
                                  mode="drop")[:S]
    found = ~visited & (cand < F.I32_MAX)
    return visited | found, found, found.sum()


root = int(roots[0])
visited = jnp.zeros((S,), bool).at[root].set(True)
front = jnp.zeros((S,), bool).at[root].set(True)
fcnt, lvl = 1, 1
while fcnt:
    jax.block_until_ready(bu_level(visited, front, lvl))   # per-level warmup
    t0 = time.perf_counter()
    visited2, found, cnt = jax.block_until_ready(bu_level(visited, front,
                                                          lvl))
    bu_s = time.perf_counter() - t0
    d = (int(adaptive_dirs[lvl - 1])
         if adaptive_dirs is not None and lvl - 1 < len(adaptive_dirs)
         else -1)
    print(f"L,{lvl},{fcnt},{d},{bu_s:.6f}")
    visited, front = visited2, found
    fcnt, lvl = int(cnt), lvl + 1
