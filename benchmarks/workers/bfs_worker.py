"""Worker: run distributed BFS (2D / 1D / direction-optimised) on forced host
devices through the session API and print one CSV row:

  variant,R,C,scale,ef,roots,harmonic_TEPS,mean_s,levels,fold,
  fold_bytes_per_edge,batched_sweep_s,amortised_TEPS,
  batched_harmonic_TEPS,lvl_sum,pred_sum

  (the column order is benchmarks/common.py BFS_WORKER_HEADER)

The graph is planned ONCE (`DistGraph.from_edges`); the roots then run twice:
sequentially (per-root wall times -> harmonic TEPS, the paper's metric) and
as ONE batched compiled program (`session.bfs(roots)` -> batched_sweep_s,
amortised_TEPS = component edges summed over roots / sweep wall time, and
batched_harmonic_TEPS = the harmonic mean of per-root TEPS with the SAME
count_component_edges numerators as the sequential column over the
amortised per-root time sweep_s / n_roots -- the Graph500 amortised view
the session API exists for, in the paper's headline metric shape).

fold_bytes_per_edge = measured fold-exchange traffic (codec wire bytes *
devices * fold exchanges, summed over roots) / input edges in the searched
components -- the paper's bytes-per-edge communication metric.  Blank for
the `dir` variant: bottom-up levels exchange raw int32 parents instead of
the fold codec and the per-level split is not visible host-side.  lvl_sum /
pred_sum checksum the LAST root's output so benchmarks/bfs_fold_codecs.py
can assert codec equivalence across separate worker processes.

Usage: bfs_worker.py VARIANT R C SCALE EF N_ROOTS [fold]
  VARIANT in {2d, 1d, dir};  fold in {list, bitmap, delta}
"""
import os
import sys
import time

VARIANT, R, C = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
SCALE, EF, N_ROOTS = int(sys.argv[4]), int(sys.argv[5]), int(sys.argv[6])
FOLD = sys.argv[7] if len(sys.argv) > 7 else "list"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={R * C}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core.validate import count_component_edges, harmonic_mean
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges

n = 1 << SCALE
edges_np = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))

if VARIANT == "1d":
    mesh = make_mesh((R * C,), ("p",))
    config = BFSConfig(grid=(1, R * C), row_axes=(), col_axes=("p",),
                       edge_chunk=16384, fold_codec=FOLD)
else:
    mesh = make_mesh((R, C), ("r", "c"))
    config = BFSConfig(grid=(R, C), edge_chunk=16384, fold_codec=FOLD,
                       direction=(VARIANT == "dir"))

graph = DistGraph.from_edges(edges_np, config, mesh=mesh, n=n)
session = graph.session()

fold_wire = session.engine.codec.wire_bytes(graph.grid)  # per dev per level

rng = np.random.default_rng(0)
# pick roots from non-isolated vertices
deg = np.bincount(edges_np[0], minlength=n)
cand = np.flatnonzero(deg > 0)
roots = rng.choice(cand, size=N_ROOTS, replace=False)

out = session.bfs(int(roots[0]))  # compile warmup (B=1 program)
jax.block_until_ready(out.level)

teps, times, levels, comp_m = [], [], [], []
fold_bytes, comp_edges = 0, 0
for root in roots:
    t0 = time.perf_counter()
    out = session.bfs(int(root))
    jax.block_until_ready(out.level)
    dt = time.perf_counter() - t0
    m = count_component_edges(edges_np, np.asarray(out.level)[:n])
    comp_m.append(m)
    teps.append(m / dt)
    times.append(dt)
    levels.append(int(out.n_levels))
    # the engine exits with lvl = iterations + 1 -> n_levels - 1 folds/search
    # (dir is excluded: its bottom-up levels bypass the fold codec entirely)
    if VARIANT != "dir":
        fold_bytes += fold_wire * graph.grid.P * (int(out.n_levels) - 1)
    comp_edges += m

# the same roots as ONE compiled program (amortised Graph500 sweep)
jax.block_until_ready(session.bfs(roots).level)           # compile warmup
t0 = time.perf_counter()
bout = session.bfs(roots)
jax.block_until_ready(bout.level)
sweep_s = time.perf_counter() - t0
# harmonic-mean TEPS of the sweep: same per-root numerators as above, over
# the amortised per-root time (the batch has ONE wall time)
batched_hm = harmonic_mean([m / (sweep_s / len(roots)) for m in comp_m])

lvl_sum = int(np.asarray(out.level)[:n].astype(np.int64).sum())
pred_sum = int(np.asarray(out.pred)[:n].astype(np.int64).sum())
# direction-optimised levels that run bottom-up exchange raw int32 parents,
# not the fold codec, and the split is not visible host-side -- leave the
# bytes column blank rather than report a codec-scaled fiction
bpe = ("" if VARIANT == "dir"
       else f"{fold_bytes / max(comp_edges, 1):.3f}")
print(f"{VARIANT},{R},{C},{SCALE},{EF},{N_ROOTS},"
      f"{harmonic_mean(teps):.3e},{np.mean(times):.4f},{max(levels)},"
      f"{FOLD},{bpe},{sweep_s:.4f},{comp_edges / sweep_s:.3e},"
      f"{batched_hm:.3e},{lvl_sum},{pred_sum}")
