"""Worker: run distributed BFS (2D / 1D / direction-optimised) on forced host
devices and print one CSV row:

  variant,R,C,scale,ef,roots,harmonic_TEPS,mean_s,levels,fold,
  fold_bytes_per_edge,lvl_sum,pred_sum

fold_bytes_per_edge = measured fold-exchange traffic (codec wire bytes *
devices * fold exchanges, summed over roots) / input edges in the searched
components -- the paper's bytes-per-edge communication metric.  Blank for
the `dir` variant: bottom-up levels exchange raw int32 parents instead of
the fold codec and the per-level split is not visible host-side.  lvl_sum /
pred_sum checksum the LAST root's output so benchmarks/bfs_fold_codecs.py
can assert codec equivalence across separate worker processes.

Usage: bfs_worker.py VARIANT R C SCALE EF N_ROOTS [fold]
  VARIANT in {2d, 1d, dir};  fold in {list, bitmap, delta}
"""
import os
import sys
import time

VARIANT, R, C = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
SCALE, EF, N_ROOTS = int(sys.argv[4]), int(sys.argv[5]), int(sys.argv[6])
FOLD = sys.argv[7] if len(sys.argv) > 7 else "list"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={R * C}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges
from repro.core import Grid2D, partition_2d
from repro.core.partition import partition_2d_csr
from repro.core.bfs2d import BFS2D
from repro.core.bfs1d import BFS1D
from repro.core.direction import BFS2DDirection
from repro.core.types import LocalGraph2D
from repro.core.validate import count_component_edges, harmonic_mean

n = 1 << SCALE
edges = rmat_edges(jax.random.key(42), SCALE, EF)
edges_np = np.asarray(edges)


def as_graph(lg):
    return LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                        jnp.asarray(lg.nnz))


if VARIANT == "1d":
    mesh = make_mesh((R * C,), ("p",))
    bfs = BFS1D(n, mesh, axes=("p",), edge_chunk=16384, fold_codec=FOLD)
    graph = as_graph(partition_2d(edges_np, bfs.grid))
    runner = lambda root: bfs.run(graph, root)
else:
    mesh = make_mesh((R, C), ("r", "c"))
    grid = Grid2D.for_vertices(n, R, C)
    graph = as_graph(partition_2d(edges_np, grid))
    if VARIANT == "dir":
        csr = {k: jnp.asarray(v) for k, v in
               partition_2d_csr(edges_np, grid).items()}
        bfs = BFS2DDirection(grid, mesh, edge_chunk=16384, fold_codec=FOLD)
        runner = lambda root: bfs.run(graph, csr, root)
    else:
        bfs = BFS2D(grid, mesh, edge_chunk=16384, fold_codec=FOLD)
        runner = lambda root: bfs.run(graph, root)

fold_wire = bfs.engine.codec.wire_bytes(bfs.grid)   # per device per level

rng = np.random.default_rng(0)
# pick roots from non-isolated vertices
deg = np.bincount(edges_np[0], minlength=n)
cand = np.flatnonzero(deg > 0)
roots = rng.choice(cand, size=N_ROOTS, replace=False)

out = runner(int(roots[0]))  # compile warmup
jax.block_until_ready(out.level)

teps, times, levels = [], [], []
fold_bytes, comp_edges = 0, 0
for root in roots:
    t0 = time.perf_counter()
    out = runner(int(root))
    jax.block_until_ready(out.level)
    dt = time.perf_counter() - t0
    m = count_component_edges(edges_np, np.asarray(out.level)[:n])
    teps.append(m / dt)
    times.append(dt)
    levels.append(int(out.n_levels))
    # the engine exits with lvl = iterations + 1 -> n_levels - 1 folds/search
    # (dir is excluded: its bottom-up levels bypass the fold codec entirely)
    if VARIANT != "dir":
        fold_bytes += fold_wire * bfs.grid.P * (int(out.n_levels) - 1)
    comp_edges += m

lvl_sum = int(np.asarray(out.level)[:n].astype(np.int64).sum())
pred_sum = int(np.asarray(out.pred)[:n].astype(np.int64).sum())
# direction-optimised levels that run bottom-up exchange raw int32 parents,
# not the fold codec, and the split is not visible host-side -- leave the
# bytes column blank rather than report a codec-scaled fiction
bpe = ("" if VARIANT == "dir"
       else f"{fold_bytes / max(comp_edges, 1):.3f}")
print(f"{VARIANT},{R},{C},{SCALE},{EF},{N_ROOTS},"
      f"{harmonic_mean(teps):.3e},{np.mean(times):.4f},{max(levels)},"
      f"{FOLD},{bpe},{lvl_sum},{pred_sum}")
