"""Worker: run distributed BFS (2D / 1D / direction-optimised) on forced host
devices and print CSV: variant,R,C,scale,ef,roots,harmonic_TEPS,mean_s,
levels, plus per-phase breakdown columns when --phases.

Usage: bfs_worker.py VARIANT R C SCALE EF N_ROOTS [fold]
  VARIANT in {2d, 1d, dir}
"""
import os
import sys
import time

VARIANT, R, C = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
SCALE, EF, N_ROOTS = int(sys.argv[4]), int(sys.argv[5]), int(sys.argv[6])
FOLD = sys.argv[7] if len(sys.argv) > 7 else "list"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={R * C}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.graphgen import rmat_edges
from repro.core import Grid2D, partition_2d, partition_1d
from repro.core.partition import partition_2d_csr
from repro.core.bfs2d import BFS2D
from repro.core.bfs1d import BFS1D
from repro.core.direction import BFS2DDirection
from repro.core.types import LocalGraph2D
from repro.core.validate import count_component_edges, harmonic_mean

n = 1 << SCALE
edges = rmat_edges(jax.random.key(42), SCALE, EF)
edges_np = np.asarray(edges)

if VARIANT == "1d":
    mesh = jax.make_mesh((R * C,), ("p",), axis_types=(AxisType.Auto,))
    part = partition_1d(edges_np, n, R * C)
    bfs = BFS1D(n, mesh, axes=("p",), edge_chunk=16384)
    runner = lambda root: bfs.run(jnp.asarray(part["col_off"]),
                                  jnp.asarray(part["row_idx"]), root)
else:
    mesh = jax.make_mesh((R, C), ("r", "c"), axis_types=(AxisType.Auto,) * 2)
    grid = Grid2D.for_vertices(n, R, C)
    lg = partition_2d(edges_np, grid)
    graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                         jnp.asarray(lg.nnz))
    if VARIANT == "dir":
        csr = {k: jnp.asarray(v) for k, v in
               partition_2d_csr(edges_np, grid).items()}
        bfs = BFS2DDirection(grid, mesh, edge_chunk=16384)
        runner = lambda root: bfs.run(graph, csr, root)
    else:
        bfs = BFS2D(grid, mesh, edge_chunk=16384,
                    fold_bitmap=(FOLD == "bitmap"))
        runner = lambda root: bfs.run(graph, root)

rng = np.random.default_rng(0)
# pick roots from non-isolated vertices
deg = np.bincount(edges_np[0], minlength=n)
cand = np.flatnonzero(deg > 0)
roots = rng.choice(cand, size=N_ROOTS, replace=False)

out = runner(int(roots[0]))  # compile warmup
jax.block_until_ready(out.level)

teps, times, levels = [], [], []
for root in roots:
    t0 = time.perf_counter()
    out = runner(int(root))
    jax.block_until_ready(out.level)
    dt = time.perf_counter() - t0
    m = count_component_edges(edges_np, np.asarray(out.level)[:n])
    teps.append(m / dt)
    times.append(dt)
    levels.append(int(out.n_levels))

print(f"{VARIANT},{R},{C},{SCALE},{EF},{N_ROOTS},"
      f"{harmonic_mean(teps):.3e},{np.mean(times):.4f},{max(levels)}")
