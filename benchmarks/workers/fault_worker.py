"""Worker: run the standard fault-drill matrix (repro.scenarios) on forced
host devices and print tagged result lines (parsed by
benchmarks/fault_drill.py):

  DRILL,{json DrillResult row}
  NORETRACE,{json no-retrace proof}

The drill matrix is the acceptance grid of DESIGN.md sec. 15: transient
loss absorbed by the segment retry (every program x codec, plus the
fold-phase variant), persistent loss -> elastic shrink-and-resume (every
program x codec), repeated loss (two shrinks), and a GraphServer batch
draining through recovery.  The NORETRACE line proves the feature is free
when off: a `fault_tolerance=False` session builds ZERO segmented programs,
its outputs are bit-identical to the FT session's, and repeat sweeps leave
its trace count untouched.

Usage: fault_worker.py SCALE EF R C
"""
import json
import os
import sys

SCALE, EF = int(sys.argv[1]), int(sys.argv[2])
R, C = int(sys.argv[3]), int(sys.argv[4])

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={R * C}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.graphgen import rmat_edges
from repro.scenarios import run_matrix, standard_matrix

N = 1 << SCALE
edges = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
weights = ((np.abs(edges[0] * 31 + edges[1]) % 254) + 1).astype(np.uint8)
config = BFSConfig(grid=(R, C), edge_chunk=4096, ckpt_every=1)

for res in run_matrix(edges, config, weights=weights, n=N,
                      scenarios=standard_matrix()):
    print(f"DRILL,{json.dumps(res.to_row(), sort_keys=True)}", flush=True)

# ---- no-retrace proof ---------------------------------------------------
roots = np.random.default_rng(0).choice(
    np.flatnonzero(np.bincount(edges[0], minlength=N) > 0), 4,
    replace=False).astype(np.int32)

off = DistGraph.from_edges(edges, config, n=N, weights=weights).session()
out_off1 = off.bfs(roots)
traces_after_first = off.engine.trace_count
out_off2 = off.bfs(roots)
traces_after_second = off.engine.trace_count

ft_cfg = BFSConfig(grid=(R, C), edge_chunk=4096, ckpt_every=1,
                   fault_tolerance=True)
on = DistGraph.from_edges(edges, ft_cfg, n=N, weights=weights).session()
out_on = on.bfs(roots)

bitexact = ((np.asarray(out_on.level) == np.asarray(out_off1.level)).all()
            and (np.asarray(out_on.pred) == np.asarray(out_off1.pred)).all()
            and tuple(out_on.edges_scanned)
            == tuple(out_off1.edges_scanned))
repeat_ok = ((np.asarray(out_off2.level)
              == np.asarray(out_off1.level)).all()
             and (np.asarray(out_off2.pred)
                  == np.asarray(out_off1.pred)).all())
print("NORETRACE," + json.dumps({
    "ft_off_segmented_programs": len(off.engine._ft_progs),
    "after_first_sweep": traces_after_first,
    "after_second_sweep": traces_after_second,
    "ft_on_off_bitexact": bool(bitexact and repeat_ok),
}, sort_keys=True), flush=True)
