"""Worker: per-level expand-phase timing for ONE expand path (DESIGN.md
sec. 9).

Drives a real BFS level sequence on a 1x1 grid -- the device-local frontier
expansion `repro.core.frontier.expand_frontier` with the path's expand_fn, no
exchanges -- and wall-clocks the jitted expand per level, so the
reference-vs-pallas(-interpret) split is visible level by level (the paper's
per-level column-scan cost).  The final level-array checksum lets the suite
assert the paths are bit-identical across worker processes.

CSV rows: path,level,frontier,edges,expand_s,lvl_sum
  (lvl_sum repeated on every row; one row per BFS level that expanded)

Usage: expand_worker.py SCALE EF PATH
  PATH in {reference, pallas, pallas-interpret}
"""
import os
import sys
import time

SCALE, EF, PATH = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Grid2D, partition_2d
from repro.core import frontier as F
from repro.graphgen import rmat_edges

n = 1 << SCALE
edges = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
grid = Grid2D.for_vertices(n, 1, 1)
lg = partition_2d(edges, grid)
co = jnp.asarray(lg.col_off[0, 0])
ri = jnp.asarray(lg.row_idx[0, 0])
ncl, nrl = grid.n_cols_local, grid.n_rows_local

if PATH == "reference":
    expand_fn = None
else:
    from repro.kernels import make_expand_fn
    expand_fn = make_expand_fn(path=PATH)

EDGE_CHUNK = 16384


@jax.jit
def scan(co, ri, vis, lvl_arr, pr, front, ftot, lvl):
    return F.expand_frontier(co, ri, vis, lvl_arr, pr, front, ftot, lvl,
                             grid=grid, i=jnp.int32(0), j=jnp.int32(0),
                             edge_chunk=EDGE_CHUNK, expand_fn=expand_fn)


root = int(np.flatnonzero(np.bincount(edges[0], minlength=n) > 0)[0])
vis = jnp.zeros((nrl,), bool).at[root].set(True)
lvl_arr = jnp.full((nrl,), -1, jnp.int32).at[root].set(0)
pr = jnp.full((nrl,), -1, jnp.int32).at[root].set(root)
front = jnp.full((ncl,), -1, jnp.int32).at[0].set(root)
ftot = jnp.int32(1)

rows, lvl = [], 1
while int(ftot) > 0 and lvl <= 64:
    args = (co, ri, vis, lvl_arr, pr, front, ftot, jnp.int32(lvl))
    ex = scan(*args)                        # compile (level 1) / warm
    jax.block_until_ready(ex.visited)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(scan(*args).visited)
    dt = (time.perf_counter() - t0) / 3
    rows.append((lvl, int(ftot), int(ex.edges_scanned), dt))
    # next frontier: on a 1x1 grid every discovery is own-column (row == col
    # local id); keep the canonical ascending order the engines use
    cnt = int(ex.dst_cnt[0])
    nxt = np.sort(np.asarray(ex.dst[0])[:cnt]).astype(np.int32)
    front = jnp.full((ncl,), -1, jnp.int32).at[:cnt].set(jnp.asarray(nxt))
    ftot = jnp.int32(cnt)
    vis, lvl_arr, pr = ex.visited, ex.level, ex.pred
    lvl += 1

lvl_sum = int(np.asarray(lvl_arr).astype(np.int64).sum())
for (level, frontier, edges_scanned, dt) in rows:
    print(f"{PATH},{level},{frontier},{edges_scanned},{dt:.6f},{lvl_sum}")
