"""Worker: per-LEVEL traversal counters from the in-program telemetry
channel (DESIGN.md sec. 13) -- the consolidated replacement for the
phase-replay worker: instead of re-driving each level's phases host-side,
ONE telemetry-enabled search returns every per-level counter (frontier,
scanned edges, folded entries, fold wire bytes, direction) from inside the
compiled while_loop, and each counter is cross-checked against an
independent recomputation (np.bincount of the output levels, the codec's
static wire formula, the 64-bit edges_scanned total).

Output lines (parsed by benchmarks/bfs_breakdown.py / obs_bench.py):
  T,codec,level,frontier,scanned,folded,wire_bytes,msgs,dir  per codec/level
  W,codec,wire_bytes,wire_bytes_values                   static, per device
  A,codec,frontier_ok,wire_ok,scanned_ok,msgs_ok         trace agreement
  D,dir_ok                                               trace.direction vs
                                                         out.directions
  M,edges,<component edges>,n_levels,<levels>

MODE=obs additionally emits (telemetry-overhead + serve-span evidence):
  E,codec,on|off,lvl_sum,pred_sum        bit-identity checksums
  C,codec,traces_first,traces_second     AOT no-retrace proof
  O,rep,on_s,off_s                       alternating batched-sweep repeats
  S,spans_ok,n_events,prom_ok            serve request-trace smoke

Usage: trace_worker.py R C SCALE EF [MODE] [EVENTS_PATH]
"""
import os
import sys

R, C, SCALE, EF = (int(a) for a in sys.argv[1:5])
MODE = sys.argv[5] if len(sys.argv) > 5 else "trace"
EVENTS_PATH = sys.argv[6] if len(sys.argv) > 6 else None
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={R * C}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import time

import jax
import numpy as np

from repro.api import BFSConfig, DistGraph
from repro.core.validate import count_component_edges
from repro.dist.compat import make_mesh
from repro.graphgen import rmat_edges

CODECS = ("list", "bitmap", "delta")

n = 1 << SCALE
edges_np = np.asarray(rmat_edges(jax.random.key(42), SCALE, EF))
mesh = make_mesh((R, C), ("r", "c"))
graph = DistGraph.from_edges(
    edges_np, BFSConfig(grid=(R, C), edge_chunk=16384), mesh=mesh, n=n)
grid = graph.grid

deg = np.bincount(edges_np[0], minlength=n)
root = int(np.flatnonzero(deg > 0)[0])


def cfg(codec, telemetry, direction=False):
    return BFSConfig(grid=(R, C), fold_codec=codec, edge_chunk=16384,
                     telemetry=telemetry, direction=direction)


# ---------------------------------------------------------------------------
# per-codec traced search + agreement checks
# ---------------------------------------------------------------------------
comp_edges = None
n_levels = None
for codec in CODECS:
    sess = graph.session(cfg(codec, telemetry=True))
    out = sess.bfs(root)
    tr = sess.last_trace()
    level = np.asarray(out.level)[:n]
    if comp_edges is None:
        comp_edges = count_component_edges(edges_np, level)
        n_levels = tr.n_levels
    bc = np.bincount(level[level >= 0])
    wb = sess.engine.codec.wire_bytes(grid)          # static, per device
    wbv = sess.engine.codec.wire_bytes_values(grid)
    frontier_ok = tr.n_levels == len(bc) and all(
        int(tr.frontier[k]) == int(bc[k]) for k in range(tr.n_levels))
    # BFS folds are SET folds: every level ships the codec's static frame
    # on each of the P devices (trace wire sums over devices)
    wire_ok = all(int(tr.wire_bytes[k]) == wb * grid.P
                  for k in range(tr.n_levels))
    scanned_ok = tr.total_scanned == out.edges_scanned
    # every device sends the strategy's per-exchange message count per level
    mpx = sess.engine.exchange.msgs_per_exchange(grid.C)
    msgs_ok = all(int(tr.msgs[k]) == mpx * grid.P
                  for k in range(tr.n_levels))
    for row in tr.levels():
        print(f"T,{codec},{row['level']},{row['frontier']},{row['scanned']},"
              f"{row['folded']},{row['wire_bytes']},{row['msgs']},"
              f"{row['dir']}")
    print(f"W,{codec},{wb},{wbv}")
    print(f"A,{codec},{frontier_ok},{wire_ok},{scanned_ok},{msgs_ok}")

# trace.direction must match the engine's own directions output
dsess = graph.session(cfg("list", telemetry=True, direction=True))
dout = dsess.bfs(root)
dtr = dsess.last_trace()
dirs = np.asarray(dout.directions)
dir_ok = all(int(dtr.direction[k]) == int(dirs[k])
             for k in range(dtr.n_levels))
print(f"D,{dir_ok}")
print(f"M,edges,{comp_edges},n_levels,{n_levels}")

if MODE != "obs":
    sys.exit(0)

# ---------------------------------------------------------------------------
# obs mode: bit-identity, no-retrace proof, overhead, serve spans
# ---------------------------------------------------------------------------
rng = np.random.default_rng(7)
alive = np.flatnonzero(deg > 0)
roots = np.asarray(rng.choice(alive, size=8), np.int32)

for codec in CODECS:
    on = graph.session(cfg(codec, telemetry=True))
    off = graph.session(cfg(codec, telemetry=False))
    out_on = on.bfs(roots)
    out_off = off.bfs(roots)
    for tag, o in (("on", out_on), ("off", out_off)):
        lvl_sum = int(np.asarray(o.level, np.int64).sum())
        pred_sum = int(np.asarray(o.pred, np.int64).sum())
        print(f"E,{codec},{tag},{lvl_sum},{pred_sum}")
    # no off-path (or on-path) retrace across repeated sweeps: the level
    # loop compiled once per (engine, B); a second sweep is a cache hit
    first = on.engine.trace_count
    on.bfs(roots)
    off.bfs(roots)
    print(f"C,{codec},{first},{on.engine.trace_count}")

# telemetry overhead: alternating timed batched sweeps, list codec
on = graph.session(cfg("list", telemetry=True))
off = graph.session(cfg("list", telemetry=False))
reps = 3 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else 5


def sweep(sess):
    jax.block_until_ready(sess.bfs(roots).level)


sweep(on), sweep(off)                    # warm both executables
for rep in range(reps):
    t0 = time.perf_counter()
    sweep(on)
    t_on = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep(off)
    t_off = time.perf_counter() - t0
    print(f"O,{rep},{t_on:.5f},{t_off:.5f}")

# serve request-trace smoke: spans tile admit -> done in lifecycle order,
# the event log records the batches, and the Prometheus text renders
from repro.obs import PHASES
from repro.serve import GraphServer, ServeConfig

with GraphServer({"g": graph},
                 ServeConfig(max_batch=4, event_log_path=EVENTS_PATH)) as srv:
    tickets = [srv.bfs("g", int(r), tenant=("alice", "bob")[i % 2])
               for i, r in enumerate(roots[:6])]
    results = [t.result(timeout=300) for t in tickets]
    spans_ok = True
    for res in results:
        names = [s.name for s in res.trace.spans]
        ends = [s.t1 for s in res.trace.spans]
        spans_ok &= (res.ok and names == list(PHASES)
                     and all(s.t1 >= s.t0 for s in res.trace.spans)
                     and ends == sorted(ends)
                     and res.trace.spans[0].t0 <= res.trace.spans[-1].t1)
    prom = srv.prometheus()
    prom_ok = ("serve_admitted_total" in prom and "serve_pending" in prom
               and "serve_queue_wait_seconds_bucket" in prom)
    print(f"S,{spans_ok},{len(srv.events)},{prom_ok}")
