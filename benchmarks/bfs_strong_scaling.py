"""Fig. 4 analog: strong scaling on a fixed graph (reduced: scale 15, the
paper uses 25), devices 1..8."""
from benchmarks.common import BFS_WORKER_HEADER, emit, run_worker

GRIDS = [(1, 1), (1, 2), (2, 2), (2, 4)]
SCALE, EF, ROOTS = 15, 16, 4


def main():
    rows = [BFS_WORKER_HEADER]
    for r, c in GRIDS:
        out = run_worker("bfs_worker.py", "2d", r, c, SCALE, EF, ROOTS)
        rows.append(tuple(out.strip().split(",")))
    emit(rows, "fig4_strong_scaling")


if __name__ == "__main__":
    main()
