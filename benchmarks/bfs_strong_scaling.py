"""Fig. 4 analog: strong scaling on a fixed graph (reduced: scale 15, the
paper uses 25), devices 1..8.  In smoke mode (CI) a minimal 1x1-vs-2x2
sweep at the forced scale keeps `teps.strong_scaling` populated in
BENCH_bfs without the full grid ladder."""
from benchmarks.common import (BFS_WORKER_HEADER, bench_scale, emit,
                               run_worker, smoke_mode)

GRIDS = [(1, 1), (1, 2), (2, 2), (2, 4)]
SCALE, EF, ROOTS = 15, 16, 4


def main():
    smoke = smoke_mode()
    grids = [(1, 1), (2, 2)] if smoke else GRIDS
    scale = bench_scale(SCALE)
    roots = 2 if smoke else ROOTS
    rows = [BFS_WORKER_HEADER]
    for r, c in grids:
        out = run_worker("bfs_worker.py", "2d", r, c, scale, EF, roots)
        rows.append(tuple(out.strip().split(",")))
    emit(rows, "fig4_strong_scaling")


if __name__ == "__main__":
    main()
