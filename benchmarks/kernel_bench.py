"""Kernel microbenches (sec. 3.4.1 analog).

CPU container caveat: Pallas interpret mode executes the kernel body in
Python, so absolute times are NOT TPU times.  What we measure here:
  * correctness parity kernel-vs-oracle at bench shapes (gate),
  * the ORACLE path timings (XLA-compiled jnp) for the CPU baseline,
  * the work-model ratio for the TPU adaptation (broadcast-compare search:
    vector ops per edge vs log2(F) scalar gathers per edge).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit


def main():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.kernels import binsearch_map, clip_cumul, local_expand, \
        visited_filter
    from repro.kernels import ref as R

    rng = np.random.default_rng(0)
    rows = [("name", "us_per_call", "derived")]

    for F_SZ, E in [(1024, 1 << 15), (8192, 1 << 18)]:
        deg = rng.integers(0, 64, size=F_SZ).astype(np.int32)
        cumul = jnp.asarray(np.concatenate([[0], np.cumsum(deg)]),
                            jnp.int32)
        gids = jnp.arange(E, dtype=jnp.int32)
        cc = clip_cumul(cumul, jnp.int32(F_SZ))
        k_kernel = binsearch_map(cc, gids, tile=512, window=256)
        k_ref = R.binsearch_map_ref(cumul, gids)
        ok = np.asarray(gids) < int(cumul[-1])
        assert (np.asarray(k_kernel)[ok] == np.asarray(k_ref)[ok]).all()
        f = jax.jit(lambda c, g: R.binsearch_map_ref(c, g))
        t = timeit(lambda: jax.block_until_ready(f(cumul, gids)))
        rows.append((f"binsearch_map_ref_F{F_SZ}_E{E}",
                     f"{t * 1e6:.0f}", "parity_ok"))

    v = jnp.asarray(rng.integers(0, 1 << 16, size=1 << 15), jnp.int32)
    valid = jnp.asarray(rng.random(1 << 15) < 0.8)
    words = jnp.asarray(
        rng.integers(0, 2**32, size=(1 << 16) // 32, dtype=np.uint64)
        .astype(np.uint32))
    won = visited_filter(v, valid, words, tile=256)
    wref = [R.visited_filter_ref(v[i:i + 256], valid[i:i + 256], words)
            for i in range(0, 1 << 15, 256)]
    assert (np.asarray(won) == np.concatenate([np.asarray(w) for w in wref])).all()
    f2 = jax.jit(lambda v, val, w: R.visited_filter_ref(v[:256], val[:256], w))
    t2 = timeit(lambda: jax.block_until_ready(f2(v, valid, words)))
    rows.append(("visited_filter_ref_tile256", f"{t2 * 1e6:.0f}", "parity_ok"))

    # the FUSED op (DESIGN.md sec. 9): reference-path timing + cross-path
    # parity gate at a bench shape
    n = 1 << 12
    fdeg = rng.integers(0, 16, size=n).astype(np.int32)
    col_off = jnp.asarray(np.concatenate([[0], np.cumsum(fdeg)]), jnp.int32)
    row_idx = jnp.asarray(rng.integers(0, n, size=int(fdeg.sum())), jnp.int32)
    front = jnp.arange(n, dtype=jnp.int32)
    vis = jnp.zeros((n,), bool)
    ref = local_expand((front, n), (col_off, row_idx), vis, path="reference",
                       edge_chunk=4096)
    pal = local_expand((front, n), (col_off, row_idx), vis,
                       path="pallas-interpret", edge_chunk=4096)
    assert (np.asarray(ref.verts) == np.asarray(pal.verts)).all()
    assert (np.asarray(ref.parents) == np.asarray(pal.parents)).all()
    t3 = timeit(lambda: jax.block_until_ready(local_expand(
        (front, n), (col_off, row_idx), vis, path="reference",
        edge_chunk=4096).verts))
    rows.append((f"local_expand_ref_n{n}", f"{t3 * 1e6:.0f}", "parity_ok"))
    emit(rows, "kernel_bench")


if __name__ == "__main__":
    main()
