"""Fold-codec head-to-head (DESIGN.md sec. 4; Romera & Froning 2017 analog):
the SAME scale-14 searches under each fold wire format, reporting TEPS and
measured bytes-per-edge, and asserting the outputs are bit-identical (the
lvl_sum/pred_sum checksums must agree across the worker processes)."""
from benchmarks.common import (BFS_WORKER_HEADER, bench_scale, emit,
                               run_worker, smoke_mode)

R, C, EF = 2, 2, 16
CODECS = ("list", "bitmap", "delta")


def main():
    scale = bench_scale(14)
    roots = 2 if smoke_mode() else 3
    header = BFS_WORKER_HEADER
    rows = [header]
    sums = {}
    for codec in CODECS:
        out = run_worker("bfs_worker.py", "2d", R, C, scale, EF, roots, codec)
        row = tuple(out.strip().split(","))
        rows.append(row)
        d = dict(zip(header, row))
        sums[codec] = (d["lvl_sum"], d["pred_sum"])
    # emit BEFORE the equality gate: the rows are the diagnostic when it fires
    emit(rows, "fold_codecs")
    if len(set(sums.values())) != 1:
        raise AssertionError(f"fold codecs disagree on levels/preds: {sums}")
    print(f"# codecs agree: lvl_sum,pred_sum = {sums['list']}")


if __name__ == "__main__":
    main()
