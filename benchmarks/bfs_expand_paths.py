"""Expand-path head-to-head (DESIGN.md sec. 9): the SAME BFS level sequence
under the reference jnp scan and the fused Pallas kernel (interpret mode on
this CPU container), reporting per-level expand times and asserting the two
paths stay bit-identical (the lvl_sum checksums must agree across the worker
processes).  This is the expand-path dimension of BENCH_bfs (schema v4)."""
from benchmarks.common import bench_scale, emit, run_worker

SCALE_DEFAULT, EF = 14, 16
PATHS = ("reference", "pallas-interpret")
HEADER = ("path", "level", "frontier", "edges", "expand_s", "lvl_sum")


def main():
    scale = bench_scale(SCALE_DEFAULT)
    rows, sums = [HEADER], {}
    for path in PATHS:
        out = run_worker("expand_worker.py", scale, EF, path).strip()
        for line in out.splitlines():
            row = tuple(line.strip().split(","))
            if len(row) != len(HEADER):
                continue                    # tolerate stray worker chatter
            rows.append(row)
            sums[path] = row[-1]
    # emit BEFORE the equality gates: the rows are the diagnostic when one
    # fires.  A path with no parseable rows is a FAILURE, not a vacuous pass.
    emit(rows, "expand_paths")
    missing = [p for p in PATHS if p not in sums]
    if missing:
        raise AssertionError(f"no parseable rows from worker(s): {missing}")
    if len(set(sums.values())) != 1:
        raise AssertionError(f"expand paths disagree on levels: {sums}")
    print(f"# expand paths agree: lvl_sum = {sums['reference']}")


if __name__ == "__main__":
    main()
