"""Table 3 analog: real-world graphs (reduced R-MAT analogs matched to the
paper's scale/edge-factor per graph; no network access in this container)."""

from benchmarks.common import emit, run_worker


def main():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.graphgen.datasets import REALWORLD_SPECS

    rows = [("dataset", "paper_scale", "scale_used", "ef", "R", "C",
             "harmonic_TEPS", "mean_s")]
    for name, (pscale, ef) in REALWORLD_SPECS.items():
        scale = max(10, pscale - 9)
        out = run_worker("bfs_worker.py", "2d", 2, 2, scale, ef, 3).strip()
        parts = out.split(",")
        rows.append((name, pscale, scale, ef, 2, 2, parts[6], parts[7]))
    emit(rows, "table3_realworld")


if __name__ == "__main__":
    main()
