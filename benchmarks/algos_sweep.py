"""Frontier-program sweep: CC / SSSP / multi-source BFS wall time and
traversal rate per fold codec (DESIGN.md sec. 8), emitted as
bench_out/algos_sweep.csv + bench_out/BENCH_algos.json so the subsystem's
perf trajectory is trackable across PRs alongside BENCH_bfs.

edges/s uses each program's own exact `edges_scanned` accounting (64-bit
safe) over the best-of-iters wall time -- a traversal rate in the program's
native work unit, NOT Graph500 TEPS (which counts input component edges and
applies to BFS only).  A cross-codec checksum per algorithm asserts the wire
formats stay bit-identical.
"""
import time

import numpy as np

from benchmarks.common import bench_scale, emit, emit_json, smoke_mode

SCALE, EF = 13, 8
CODECS = ("list", "bitmap", "delta")
ITERS = 3


def _time(fn, field, iters=ITERS):
    """Best-of-iters wall time of fn(); field(out) forces the result."""
    field(fn())                          # warm/compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        field(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    import jax

    from repro.api import BFSConfig, DistGraph
    from repro.graphgen import rmat_edges

    scale = bench_scale(SCALE)
    iters = 1 if smoke_mode() else ITERS
    n = 1 << scale
    edges = np.asarray(rmat_edges(jax.random.key(11), scale, EF))
    w = np.random.default_rng(0).integers(1, 256, size=edges.shape[1]) \
        .astype(np.uint8)
    graph = DistGraph.from_edges(
        edges, BFSConfig(edge_chunk=16384), n=n, weights=w)
    sess = graph.session()
    deg = np.bincount(edges[0], minlength=n)
    roots = np.random.default_rng(1).choice(np.flatnonzero(deg > 0), 8,
                                            replace=False)
    sources = roots[:4]

    algos = {
        "cc": (lambda codec: sess.connected_components(fold_codec=codec),
               lambda o: np.asarray(o.labels)),
        "sssp": (lambda codec: sess.sssp(int(roots[0]), fold_codec=codec),
                 lambda o: np.asarray(o.dist)),
        "multi_bfs": (lambda codec: sess.multi_bfs(sources,
                                                   fold_codec=codec),
                      lambda o: np.asarray(o.src)),
    }

    rows = [("algo", "codec", "scale", "ef", "wall_s", "edges_scanned",
             "edges_per_s", "checksum")]
    result = {}
    for name, (run, field) in algos.items():
        sums = {}
        for codec in CODECS:
            out = run(codec)
            wall = _time(lambda: run(codec), field, iters=iters)
            scanned = int(out.edges_scanned)
            checksum = int(field(out).astype(np.int64).sum())
            sums[codec] = checksum
            rows.append((name, codec, scale, EF, f"{wall:.4f}", scanned,
                         f"{scanned / wall:.3e}", checksum))
            result.setdefault(name, {})[codec] = {
                "wall_s": wall, "edges_scanned": scanned,
                "edges_per_s": scanned / wall}
        if len(set(sums.values())) != 1:
            raise AssertionError(f"{name}: codecs disagree: {sums}")
        result[name]["codecs_agree"] = True

    emit(rows, "algos_sweep")
    path = emit_json({"schema": "BENCH_algos/v1", "scale": scale, "ef": EF,
                      "algos": result}, "BENCH_algos")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
