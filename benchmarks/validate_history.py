"""Bench-trajectory gate: a fresh BENCH_*.json vs the committed baseline.

The bench artifacts are trajectory data -- wall-clock numbers move with
the host and are NEVER gated here.  What must not regress across PRs is
the correctness surface:

  * the schema may only move FORWARD ("BENCH_bfs/v8" -> v9 is fine, -> v7
    is a regression);
  * every agreement flag that was true in the baseline stays true
    (codecs_agree / expand_paths_agree / direction_agree /
    exchange_agree, BENCH_algos per-algo codecs_agree) -- a suite that
    silently stopped running reads as null and FAILS the gate;
  * every fold codec / algo / exchange strategy covered by the baseline
    is still covered;
  * when the fresh run used the same graph scale and grid as the
    baseline, the deterministic correctness counters must match EXACTLY:
    the fold-codec lvl_sum/pred_sum checksums (the generator is seeded,
    the engine is bit-reproducible) and the per-strategy exchange message
    totals (pure functions of C and the level count).

CI stashes the committed bench_out/BENCH_*.json before the fresh smoke
run overwrites them, then calls:

    python benchmarks/validate_history.py --baseline <stash> [--fresh bench_out]

Exit 0 = trajectory OK; non-zero prints one line per violation.
"""
import argparse
import json
import os
import sys

AGREE_FLAGS = ("codecs_agree", "expand_paths_agree", "direction_agree",
               "exchange_agree")


def _load(d, name, errors):
    p = os.path.join(d, f"{name}.json")
    if not os.path.exists(p):
        errors.append(f"{p} missing")
        return None
    try:
        with open(p) as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        errors.append(f"{p}: invalid JSON ({e})")
        return None


def _schema_version(doc, prefix, errors, who):
    s = (doc or {}).get("schema") or ""
    if not s.startswith(prefix + "/v"):
        errors.append(f"{who}: schema {s!r} does not match {prefix}/vN")
        return None
    try:
        return int(s.split("/v", 1)[1])
    except ValueError:
        errors.append(f"{who}: unparseable schema version {s!r}")
        return None


def compare_bfs(base, fresh) -> list:
    errors = []
    bv = _schema_version(base, "BENCH_bfs", errors, "baseline")
    fv = _schema_version(fresh, "BENCH_bfs", errors, "fresh")
    if bv is not None and fv is not None and fv < bv:
        errors.append(f"BENCH_bfs schema went BACKWARD: v{fv} < baseline "
                      f"v{bv}")
    for flag in AGREE_FLAGS:
        if base.get(flag) is True and fresh.get(flag) is not True:
            errors.append(f"BENCH_bfs.{flag} regressed: baseline true, "
                          f"fresh {fresh.get(flag)!r} (a suite that "
                          f"stopped running reads as null and fails)")
    b_codecs, f_codecs = base.get("fold_codecs") or {}, \
        fresh.get("fold_codecs") or {}
    for codec, bc in b_codecs.items():
        fc = f_codecs.get(codec)
        if fc is None:
            errors.append(f"BENCH_bfs.fold_codecs lost codec {codec!r}")
            continue
        # deterministic checksums: seeded generator + bit-reproducible
        # engine => same scale + grid must reproduce the same outputs
        if (bc.get("scale"), bc.get("grid")) == (fc.get("scale"),
                                                 fc.get("grid")):
            for k in ("lvl_sum", "pred_sum"):
                if bc.get(k) != fc.get(k):
                    errors.append(
                        f"BENCH_bfs.fold_codecs[{codec}].{k} changed at "
                        f"unchanged scale/grid: {bc.get(k)} -> {fc.get(k)}")
    b_ex = {(a.get("strategy"), a.get("codec")): a
            for a in base.get("exchange") or []}
    f_ex = {(a.get("strategy"), a.get("codec")): a
            for a in fresh.get("exchange") or []}
    for key, ba in b_ex.items():
        fa = f_ex.get(key)
        if fa is None:
            errors.append(f"BENCH_bfs.exchange lost entry {key}")
            continue
        if (ba.get("scale"), ba.get("C")) == (fa.get("scale"),
                                              fa.get("C")):
            for k in ("levels", "total_msgs"):
                if ba.get(k) != fa.get(k):
                    errors.append(
                        f"BENCH_bfs.exchange[{key}].{k} changed at "
                        f"unchanged scale/C: {ba.get(k)} -> {fa.get(k)}")
    return errors


def compare_algos(base, fresh) -> list:
    errors = []
    bv = _schema_version(base, "BENCH_algos", errors, "baseline")
    fv = _schema_version(fresh, "BENCH_algos", errors, "fresh")
    if bv is not None and fv is not None and fv < bv:
        errors.append(f"BENCH_algos schema went BACKWARD: v{fv} < "
                      f"baseline v{bv}")
    b_algos, f_algos = base.get("algos") or {}, fresh.get("algos") or {}
    for name, ba in b_algos.items():
        fa = f_algos.get(name)
        if fa is None:
            errors.append(f"BENCH_algos lost algo {name!r}")
            continue
        if ba.get("codecs_agree") is True and fa.get("codecs_agree") \
                is not True:
            errors.append(f"BENCH_algos[{name}].codecs_agree regressed: "
                          f"baseline true, fresh "
                          f"{fa.get('codecs_agree')!r}")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", default="bench_out",
                    help="directory holding the just-produced BENCH_*.json")
    args = ap.parse_args(argv)

    errors = []
    base_bfs = _load(args.baseline, "BENCH_bfs", errors)
    fresh_bfs = _load(args.fresh, "BENCH_bfs", errors)
    if base_bfs is not None and fresh_bfs is not None:
        errors += compare_bfs(base_bfs, fresh_bfs)
    base_algos = _load(args.baseline, "BENCH_algos", errors)
    fresh_algos = _load(args.fresh, "BENCH_algos", errors)
    if base_algos is not None and fresh_algos is not None:
        errors += compare_algos(base_algos, fresh_algos)

    for e in errors:
        print(f"HISTORY: {e}")
    if errors:
        sys.exit(1)
    print("bench trajectory OK")


if __name__ == "__main__":
    main()
