"""Exchange-strategy crossover: flat vs butterfly fold routes on a 1 x C
column grid (DESIGN.md sec. 14), from the in-program telemetry channel.

Drives workers/exchange_worker.py (C simulated devices) once per strategy
x fold codec and asserts, in-process, the contracts the BENCH gate
re-checks on the aggregated JSON:

  * bit-identity: level/pred checksums + edges_scanned are EQUAL across
    strategies for every codec (the butterfly is store-and-forward over
    the codecs' encoded wire arrays, so outputs cannot differ);
  * the message crossover: at power-of-two C >= 4 the butterfly's
    log2(C) staged ppermutes per device per level STRICTLY undercut the
    flat single all_to_all's C-1 messages;
  * the set-fold volume identity at C = 4: (fb/C) * (C/2) * log2(C) = fb,
    so the butterfly wins messages without paying extra set-fold bytes
    (value folds pay popcount(j ^ d) hops per entry -- reported, not
    gated, since the sign depends on the frontier shape).

Emits one CSV:
  exchange  C,scale,strategy,codec,level,frontier,folded,wire_bytes,msgs
            (one row per strategy x codec x level)
"""
from benchmarks.common import bench_scale, emit, run_worker, smoke_mode

EXPECTED_MSGS = {"flat": lambda c: c - 1,
                 "butterfly": lambda c: (c - 1).bit_length()}


def main():
    c = 4
    scale = bench_scale(10 if smoke_mode() else 13)
    out = run_worker("exchange_worker.py", c, scale, 16).strip()
    levels, sums, totals = [], {}, {}
    for line in out.splitlines():
        parts = line.strip().split(",")
        if parts[0] == "X":
            levels.append((parts[1], parts[2], *[int(x) for x in parts[3:]]))
        elif parts[0] == "G":
            sums[(parts[1], parts[2])] = tuple(int(x) for x in parts[3:])
        elif parts[0] == "S":
            totals[(parts[1], parts[2])] = tuple(int(x) for x in parts[3:])
    if not levels or len(sums) != 6 or len(totals) != 6:
        raise AssertionError(f"exchange_worker produced an incomplete row "
                             f"set:\n{out}")

    # bit-identity across strategies, per codec
    for codec in ("list", "bitmap", "delta"):
        if sums[("flat", codec)] != sums[("butterfly", codec)]:
            raise AssertionError(
                f"flat vs butterfly outputs differ for codec {codec}: "
                f"{sums[('flat', codec)]} vs {sums[('butterfly', codec)]}")

    # per-level message counts match the strategy formula (x C devices),
    # and the butterfly strictly undercuts flat at C >= 4
    for strategy, codec, _lvl, _f, _fold, _wire, msgs in levels:
        want = EXPECTED_MSGS[strategy](c) * c
        if msgs != want:
            raise AssertionError(f"{strategy}/{codec}: per-level msgs "
                                 f"{msgs} != {want}")
    for codec in ("list", "bitmap", "delta"):
        mf, mb = totals[("flat", codec)][1], totals[("butterfly", codec)][1]
        if not mb < mf:
            raise AssertionError(f"butterfly msgs {mb} !< flat msgs {mf} "
                                 f"at C={c} ({codec})")
        # equal level counts -> set-fold volume identity holds at C=4 for
        # the SET-fold levels; totals differ only by the value-channel
        # hop term, which BFS set folds do not have
        wf, wb = totals[("flat", codec)][2], totals[("butterfly", codec)][2]
        if wf != wb:
            raise AssertionError(f"set-fold wire volume differs at C=4 "
                                 f"({codec}): flat={wf} butterfly={wb}")

    rows = [("C", "scale", "strategy", "codec", "level", "frontier",
             "folded", "wire_bytes", "msgs")]
    for strategy, codec, lvl, frontier, folded, wire, msgs in levels:
        rows.append((c, scale, strategy, codec, lvl, frontier, folded,
                     wire, msgs))
    emit(rows, "exchange")


if __name__ == "__main__":
    main()
