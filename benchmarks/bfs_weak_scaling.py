"""Fig. 3 analog: weak scaling -- scale grows with device count (reduced:
scale 13 + log2 P at edge factor 16, devices 1..8 forced host devices)."""
from benchmarks.common import BFS_WORKER_HEADER, emit, run_worker

GRIDS = [(1, 1), (1, 2), (2, 2), (2, 4)]
BASE_SCALE = 13
EF = 16
ROOTS = 4


def main():
    rows = [BFS_WORKER_HEADER]
    for i, (r, c) in enumerate(GRIDS):
        out = run_worker("bfs_worker.py", "2d", r, c, BASE_SCALE + i, EF,
                         ROOTS)
        rows.append(tuple(out.strip().split(",")))
    emit(rows, "fig3_weak_scaling")


if __name__ == "__main__":
    main()
