import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   Set here only -- smoke tests and benches see the single real device.

"""Multi-pod dry-run driver (deliverable e + the roofline sources for g).

For every (architecture x input shape) cell and each production mesh
(single-pod 16x16, multi-pod 2x16x16):
    jax.jit(step, in_shardings, out_shardings).lower(*abstract_args).compile()
then record memory_analysis() (proves per-chip fit), cost_analysis()
(FLOPs/bytes for the roofline) and the parsed collective wire bytes.

Results append to a JSON file (resumable: done cells are skipped), one
record per (arch, shape, mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
"""
import argparse
import json
import time
import traceback


def model_flops_global(arch, shape: str) -> float | None:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for training;
    2 N D for inference passes (prefill/decode); None where ill-defined."""
    if arch.family == "lm":
        import importlib
        mod = importlib.import_module(
            "repro.configs." + arch.arch_id.replace("-", "_").replace(".", "_"))
        cfg = mod.CONFIG
        n = cfg.active_param_count() if cfg.moe else cfg.param_count()
        from repro.configs.lm_common import SHAPES
        sh = SHAPES[shape]
        if sh["kind"] == "train":
            return 6.0 * n * sh["batch"] * sh["seq"]
        if sh["kind"] == "prefill":
            return 2.0 * n * sh["batch"] * sh["seq"]
        return 2.0 * n * sh["batch"]          # decode: one token per seq
    return None


def run_cell(arch_id: str, shape: str, mesh_kind: str, results: dict,
             out_path: str):
    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, mesh_axes
    from repro.launch import roofline

    key = f"{arch_id}|{shape}|{mesh_kind}"
    if key in results and results[key].get("status") == "ok":
        print(f"[skip] {key} (done)")
        return
    arch = get_arch(arch_id)
    rec = {"arch": arch_id, "shape": shape, "mesh": mesh_kind,
           "status": "running"}
    if shape in arch.skip_shapes:
        rec.update(status="skipped", reason=arch.skip_shapes[shape])
        results[key] = rec
        _flush(results, out_path)
        print(f"[skip] {key}: {rec['reason']}")
        return

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = mesh_axes(mesh)
    t0 = time.time()
    try:
        spec = arch.build_dryrun(shape, mesh, axes)
        with mesh:
            jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                             out_shardings=spec.out_shardings,
                             donate_argnums=spec.donate_argnums)
            lowered = jitted.lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            mf = model_flops_global(arch, shape)
            rl = roofline.analyze(compiled, model_flops=mf,
                                  n_chips=mesh.devices.size)
        rec.update(
            status="ok", note=spec.note,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                code_bytes=mem.generated_code_size_in_bytes),
            roofline=rl.as_dict())
        print(f"[ok]   {key}: compile={t_compile:.0f}s "
              f"dom={rl.dominant} c={rl.compute_s:.3e} m={rl.memory_s:.3e} "
              f"w={rl.collective_s:.3e}")
    except Exception as e:  # noqa: BLE001 -- record and continue
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[FAIL] {key}: {type(e).__name__}: {e}")
    results[key] = rec
    _flush(results, out_path)


def _flush(results, path):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    from repro.configs import ARCHS
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch_id in archs:
        arch = ARCHS[arch_id]
        shapes = arch.shapes if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mk in meshes:
                run_cell(arch_id, shape, mk, results, args.out)
    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
