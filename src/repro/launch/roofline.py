"""Roofline-term extraction from a compiled dry-run artifact.

Three terms (per device = per chip; the SPMD module is per-device):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = wire_bytes / ICI_link_bw        (~50 GB/s per link)

cost_analysis() provides FLOPs/bytes; collective bytes are parsed from the
post-partitioning HLO text with ring-algorithm wire factors:
  all-gather / reduce-scatter / all-to-all : (n-1)/n x full size
  all-reduce                               : 2 (n-1)/n x size
  collective-permute                       : 1 x size
`n` comes from replica_groups (explicit or iota form).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _group_size(line: str) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))   # [n_groups, group_size]<=[total]
    return 2


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (ring factors applied)."""
    out = {k: 0.0 for k in COLLECTIVES}
    count = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            token = f" {kind}("
            token_start = f" {kind}-start("
            if token not in line and token_start not in line:
                continue
            shapes = _SHAPE_RE.findall(line.split("=", 1)[0]) or \
                _SHAPE_RE.findall(line)
            # full logical size: the largest shape on the line (result for
            # all-gather, operand for reduce-scatter)
            allshapes = _SHAPE_RE.findall(line)
            size = max((_shape_bytes(d, s) for d, s in allshapes),
                       default=0)
            n = _group_size(line)
            if kind == "all-reduce":
                wire = 2 * (n - 1) / n * size
            elif kind == "collective-permute":
                wire = size
            else:
                wire = (n - 1) / n * size
            out[kind] += wire
            count[kind] += 1
            break
    out["_counts"] = count
    return out


# ----------------------------------------------------------------------------
# Loop-aware HLO cost analyzer.
#
# XLA's compiled.cost_analysis() counts a while/scan BODY ONCE regardless of
# trip count (verified empirically), which silently undercounts every scanned
# transformer by ~n_layers x.  We therefore re-derive the three terms from
# the HLO text with computation multipliers: ENTRY x1, while bodies x
# known_trip_count (backend_config), fusions inherit the caller's weight.
#   flops: dot instructions (2 * prod(result) * prod(contracting)) -- matmul
#          dominated, matching XLA's own convention;
#   bytes: operand + result sizes of top-level (non-fused) instructions --
#          fusion internals don't touch HBM;
#   wire:  collective ops with ring factors (parse_collective_bytes) x weight.
# ----------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w\.\-]+)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _split_computations(text: str) -> dict:
    comps, cur, name = {}, None, None
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            name = m.group(2)
            cur = []
            comps[name] = cur
            continue
        if line.strip() == "}":
            name, cur = None, None
            continue
        if cur is not None:
            cur.append(line)
    return comps


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z]+\d*)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _symbol_table(lines) -> dict:
    tbl = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            tbl[m.group(1)] = [int(d) for d in m.group(3).split(",") if d]
    return tbl


def _inst_flops(line: str, tbl: dict) -> float:
    if " dot(" not in line:
        return 0.0
    shapes = _SHAPE_RE.findall(line.split(" dot(")[0])
    if not shapes:
        return 0.0
    res_elems = 1
    for d in shapes[0][1].split(","):
        if d:
            res_elems *= int(d)
    k = 1
    mc = _DOT_CONTRACT_RE.search(line)
    args = line.split(" dot(", 1)[1].split(")", 1)[0]
    ops = _OPERAND_RE.findall(args)
    if mc and ops:
        lhs_dims = tbl.get(ops[0])
        if lhs_dims:
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    k *= lhs_dims[int(ci)]
    return 2.0 * res_elems * k


def _inst_bytes(line: str) -> float:
    return float(sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)))


def analyze_hlo(text: str) -> dict:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            entry = m.group(2) if m else None
            break
    weights = {entry: 1.0} if entry else {}
    order = [entry] if entry else []
    # propagate weights breadth-first through while/fusion/call edges
    seen = set(order)
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        w = weights[cname]
        for line in comps.get(cname, ()):
            trip = 1.0
            if " while(" in line:
                mt = _TRIP_RE.search(line)
                trip = float(mt.group(1)) if mt else 1.0
            for callee in _CALLS_RE.findall(line):
                if callee in comps:
                    weights[callee] = weights.get(callee, 0.0) + w * trip
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
    flops = bytes_ = 0.0
    wire = {k: 0.0 for k in COLLECTIVES}
    fused = set()
    for cname, lines in comps.items():
        for line in lines:
            if " fusion(" in line:
                for callee in _CALLS_RE.findall(line):
                    fused.add(callee)
    for cname, lines in comps.items():
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        in_fusion = cname in fused
        tbl = _symbol_table(lines)
        for line in lines:
            flops += w * _inst_flops(line, tbl)
            if not in_fusion and "=" in line and " parameter(" not in line:
                bytes_ += w * _inst_bytes(line)
            for kind in COLLECTIVES:
                if f" {kind}(" in line or f" {kind}-start(" in line:
                    allshapes = _SHAPE_RE.findall(line)
                    size = max((_shape_bytes(d, s) for d, s in allshapes),
                               default=0)
                    n = _group_size(line)
                    if kind == "all-reduce":
                        wire[kind] += w * 2 * (n - 1) / n * size
                    elif kind == "collective-permute":
                        wire[kind] += w * size
                    else:
                        wire[kind] += w * (n - 1) / n * size
                    break
    return {"flops": flops, "bytes": bytes_, "wire": wire,
            "wire_total": sum(wire.values())}


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collective_detail: dict
    model_flops: float | None = None
    useful_ratio: float | None = None

    def as_dict(self):
        return dataclasses.asdict(self)


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict: JAX 0.4.x returns a
    one-element list of dicts, >= 0.5 the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze(compiled, *, model_flops: float | None = None,
            n_chips: int = 1) -> Roofline:
    text = compiled.as_text()
    la = analyze_hlo(text)                      # loop-aware (trip-weighted)
    cost = cost_analysis_dict(compiled)
    flops = max(la["flops"], float(cost.get("flops", 0.0)))
    hbm = max(la["bytes"], float(cost.get("bytes accessed", 0.0)))
    det = la["wire"]
    det["_xla_flops_once"] = float(cost.get("flops", 0.0))
    det["_xla_bytes_once"] = float(cost.get("bytes accessed", 0.0))
    wire = la["wire_total"]
    c, m, w = flops / PEAK_FLOPS, hbm / HBM_BW, wire / ICI_BW
    dom = max((("compute", c), ("memory", m), ("collective", w)),
              key=lambda t: t[1])[0]
    ratio = None
    if model_flops:
        # model_flops is GLOBAL; flops is per-device
        ratio = model_flops / max(flops * n_chips, 1.0)
    return Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                    compute_s=c, memory_s=m, collective_s=w, dominant=dom,
                    collective_detail=det, model_flops=model_flops,
                    useful_ratio=ratio)
