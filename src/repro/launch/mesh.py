"""Production mesh definition (a FUNCTION: importing this module never
touches jax device state)."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Works under --xla_force_host_platform_device_count=512 for either mesh
    (the single-pod mesh takes the first 256 placeholder devices)."""
    import jax
    from repro.dist.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax -- dryrun.py does this)")
    if len(devs) == need:
        return make_mesh(shape, axes)
    return make_mesh(shape, axes, devices=devs[:need])


def mesh_axes(mesh):
    """MeshAxes descriptor for a production mesh."""
    from repro.configs.common import MeshAxes
    if "pod" in mesh.axis_names:
        return MeshAxes(dp=("pod", "data"), tp="model")
    return MeshAxes(dp=("data",), tp="model")
