"""Distributed-BFS driver CLI (the paper's workload as a service).

    PYTHONPATH=src python -m repro.launch.bfs_run --devices 8 --grid 2x4 \
        --scale 14 --ef 16 --roots 64 [--fold bitmap] [--direction]

Forces host devices when asked for more than physically available (CPU
container); on a TPU pod, drop --devices and bind --row-axes/--col-axes to
the pod mesh."""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--grid", default="2x4")
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--ef", type=int, default=16)
    ap.add_argument("--roots", type=int, default=64)
    ap.add_argument("--fold", default="list",
                    choices=["list", "bitmap", "delta"])
    ap.add_argument("--direction", action="store_true")
    ap.add_argument("--validate", type=int, default=4)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.dist.compat import make_mesh
    from repro.graphgen import rmat_edges
    from repro.core import Grid2D, partition_2d, validate_bfs
    from repro.core.partition import partition_2d_csr
    from repro.core.bfs2d import BFS2D
    from repro.core.direction import BFS2DDirection
    from repro.core.types import LocalGraph2D
    from repro.core.validate import count_component_edges, harmonic_mean

    R, C = (int(x) for x in args.grid.split("x"))
    n = 1 << args.scale
    edges = rmat_edges(jax.random.key(1), args.scale, args.ef)
    edges_np = np.asarray(edges)
    mesh = make_mesh((R, C), ("r", "c"))
    grid = Grid2D.for_vertices(n, R, C)
    lg = partition_2d(edges_np, grid)
    graph = LocalGraph2D(jnp.asarray(lg.col_off), jnp.asarray(lg.row_idx),
                         jnp.asarray(lg.nnz))
    if args.direction:
        csr = {k: jnp.asarray(v) for k, v in
               partition_2d_csr(edges_np, grid).items()}
        bfs = BFS2DDirection(grid, mesh, edge_chunk=16384,
                             fold_codec=args.fold)
        run = lambda r: bfs.run(graph, csr, r)
    else:
        bfs = BFS2D(grid, mesh, edge_chunk=16384, fold_codec=args.fold)
        run = lambda r: bfs.run(graph, r)

    deg = np.bincount(edges_np[0], minlength=n)
    roots = np.random.default_rng(7).choice(np.flatnonzero(deg > 0),
                                            args.roots, replace=False)
    jax.block_until_ready(run(int(roots[0])).level)
    teps = []
    for i, root in enumerate(roots):
        t0 = time.perf_counter()
        out = run(int(root))
        jax.block_until_ready(out.level)
        dt = time.perf_counter() - t0
        lvl = np.asarray(out.level)[:n]
        teps.append(count_component_edges(edges_np, lvl) / dt)
        if i < args.validate:
            validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], int(root))
    print(f"grid={R}x{C} scale={args.scale} ef={args.ef} fold={args.fold} "
          f"dir={args.direction}: harmonic TEPS {harmonic_mean(teps):.3e} "
          f"({min(args.validate, len(roots))} validated)")


if __name__ == "__main__":
    main()
