"""Distributed-BFS driver CLI (the paper's workload as a service).

    PYTHONPATH=src python -m repro.launch.bfs_run --devices 8 --grid 2x4 \
        --scale 14 --ef 16 --roots 64 [--fold bitmap] [--direction]

Built on the session API (DESIGN.md sec. 7): the graph is planned and made
resident ONCE (`DistGraph.from_edges`; the CSR twin is only partitioned when
--direction is on), then the root sweep runs through `GraphSession.bfs` --
per-root for harmonic TEPS, plus the whole batch as one compiled program for
the amortised Graph500-style number.

Forces host devices when asked for more than physically available (CPU
container); on a TPU pod, drop --devices and bind --row-axes/--col-axes to
the pod mesh."""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--grid", default="2x4")
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--ef", type=int, default=16)
    ap.add_argument("--roots", type=int, default=64)
    ap.add_argument("--fold", default="list",
                    choices=["list", "bitmap", "delta"])
    ap.add_argument("--direction", action="store_true")
    ap.add_argument("--validate", type=int, default=4)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import numpy as np

    from repro.api import BFSConfig, DistGraph
    from repro.core.validate import (count_component_edges, harmonic_mean,
                                     validate_bfs)
    from repro.graphgen import rmat_edges

    n = 1 << args.scale
    edges_np = np.asarray(rmat_edges(jax.random.key(1), args.scale, args.ef))

    config = BFSConfig(grid=args.grid, fold_codec=args.fold,
                       edge_chunk=16384, direction=args.direction)
    graph = DistGraph.from_edges(edges_np, config, n=n)
    session = graph.session()

    deg = np.bincount(edges_np[0], minlength=n)
    roots = np.random.default_rng(7).choice(np.flatnonzero(deg > 0),
                                            args.roots, replace=False)

    # per-root queries (harmonic-mean TEPS, the paper's headline metric);
    # the first --validate roots run the Graph500 rules AFTER the timing
    # window (the O(E) host-side check must not skew the reported TEPS)
    jax.block_until_ready(session.bfs(int(roots[0])).level)   # warm B=1
    teps, comp_m = [], []
    for i, root in enumerate(roots):
        t0 = time.perf_counter()
        out = session.bfs(int(root))
        jax.block_until_ready(out.level)
        dt = time.perf_counter() - t0
        lvl = np.asarray(out.level)[:n]
        m = count_component_edges(edges_np, lvl)
        comp_m.append(m)
        teps.append(m / dt)
        if i < args.validate:
            validate_bfs(edges_np, lvl, np.asarray(out.pred)[:n], int(root))

    # the whole sweep as ONE compiled program; harmonic-mean TEPS uses the
    # SAME count_component_edges numerators as the per-root path, over the
    # amortised per-root time sweep_s / n_roots (the batch has ONE wall
    # time), alongside the aggregate amortised number
    jax.block_until_ready(session.bfs(roots).level)           # warm B=roots
    t0 = time.perf_counter()
    bout = session.bfs(roots)
    jax.block_until_ready(bout.level)
    sweep_s = time.perf_counter() - t0
    swept = sum(comp_m)
    batched_hm = harmonic_mean([m / (sweep_s / len(roots)) for m in comp_m])

    R, C = graph.grid.R, graph.grid.C
    print(f"grid={R}x{C} scale={args.scale} ef={args.ef} fold={args.fold} "
          f"dir={args.direction}: harmonic TEPS {harmonic_mean(teps):.3e} "
          f"({min(args.validate, len(roots))} validated) | "
          f"{len(roots)}-root sweep {sweep_s:.3f}s, "
          f"amortised {swept / sweep_s:.3e} TEPS, "
          f"harmonic {batched_hm:.3e} TEPS")


if __name__ == "__main__":
    main()
