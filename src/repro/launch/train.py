"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50 \
        [--reduced] [--ckpt-dir ckpts]

On this CPU container only --reduced is practical (full configs are for the
production mesh); the driver wires the full stack either way: config ->
params -> sharded train step -> data pipeline -> fault-tolerant runner ->
checkpoints.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="ckpts")
    args = ap.parse_args()

    import importlib

    import jax
    import jax.numpy as jnp

    from repro.models import lm as L
    from repro.optim.adamw import AdamWConfig
    from repro.train import TrainConfig, make_train_step
    from repro.train.train_step import init_state
    from repro.data import synthetic_lm_batches
    from repro.ckpt import CheckpointManager
    from repro.runtime import StepRunner, RetryPolicy

    mod = importlib.import_module(
        "repro.configs." + args.arch.replace("-", "_").replace(".", "_"))
    full = mod.CONFIG
    cfg = L.LMConfig(
        name=full.name + "-reduced", n_layers=2, d_model=128,
        n_heads=min(4, full.n_heads), n_kv_heads=min(2, full.n_kv_heads),
        d_head=32, d_ff=256, vocab=512,
        attn_softcap=full.attn_softcap, logit_softcap=full.logit_softcap,
        window_pattern=tuple(min(w, 32) for w in full.window_pattern),
        post_norms=full.post_norms, tie_embeddings=full.tie_embeddings,
        moe=None if full.moe is None else L.MoESettings(8, 2, 64, 1),
        dtype=jnp.float32, remat=False)

    params = L.init_params(cfg, jax.random.key(0))
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-4, warmup_steps=10,
                                           total_steps=args.steps))
    step = jax.jit(make_train_step(
        lambda p, b: L.loss_fn(cfg, p, b[0], b[1]), tc))
    state = init_state(tc, params).tree()
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    runner = StepRunner(step, policy=RetryPolicy(), ckpt=ckpt, ckpt_every=25)

    data = ((jnp.asarray(t), jnp.asarray(l)) for t, l in
            synthetic_lm_batches(cfg.vocab, args.batch, args.seq,
                                 n_batches=args.steps))
    for i, batch in enumerate(data):
        state, info = step(state, batch)
        if i % 10 == 0:
            print(f"step {i:4d} loss={float(info['loss']):.4f}")
        if i % 25 == 0:
            ckpt.save(i, state)
    ckpt.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
