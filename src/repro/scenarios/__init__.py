from repro.scenarios.base import DrillResult, Scenario, run_drill
from repro.scenarios.fault_drills import run_matrix, standard_matrix
