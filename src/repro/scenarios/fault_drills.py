"""The standard fault-drill matrix (DESIGN.md sec. 15).

Three drill families over the program x codec grid:

  loss-at-level-L   transient loss crossing level L, absorbed by the
                    segment retry -- every program x codec, session runner;
                    plus the "fold"-phase variant for BFS (the loss lands
                    while the fold exchange is in flight; segments are
                    atomic, so recovery is identical -- the drill proves
                    the phase makes no difference).
  loss-then-shrink  persistent loss exhausts the retries; the
                    ElasticCoordinator re-plans onto the survivor grid and
                    resumes -- every program x codec (the acceptance
                    matrix), plus one repeated-loss drill (two shrinks).
  serve-drain       a GraphServer batch interrupted mid-traversal drains
                    through recovery: zero lost queries, bit-identical
                    answers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.scenarios.base import Scenario, run_drill

PROGRAMS = ("bfs", "cc", "sssp", "multi_bfs")
CODECS = ("list", "bitmap")


def standard_matrix(*, programs=PROGRAMS, codecs=CODECS,
                    at_level: int = 2) -> list:
    """The drill list the CI fault-smoke runs."""
    out = []
    for p in programs:
        for c in codecs:
            out.append(Scenario(name=f"loss-at-level/{p}/{c}", program=p,
                                codec=c, at_level=at_level,
                                kind="transient", runner="session"))
    for c in codecs:
        out.append(Scenario(name=f"loss-during-fold/bfs/{c}", program="bfs",
                            codec=c, at_level=at_level, phase="fold",
                            kind="transient", runner="session"))
    for p in programs:
        for c in codecs:
            out.append(Scenario(name=f"loss-then-shrink/{p}/{c}", program=p,
                                codec=c, at_level=at_level,
                                kind="persistent", runner="elastic"))
    out.append(Scenario(name="repeated-loss-then-shrink/bfs/list",
                        program="bfs", codec="list", at_level=at_level,
                        kind="repeated", runner="elastic"))
    out.append(Scenario(name="serve-drain/bfs/list", program="bfs",
                        codec="list", at_level=at_level, kind="persistent",
                        runner="serve"))
    return out


def run_matrix(edges, config, *, weights=None, n=None, scenarios=None,
               log=None) -> list:
    """Run the matrix, sharing one uninterrupted baseline per
    (program, codec) across its drills.  Returns the DrillResult list."""
    from repro.api.session import DistGraph
    from repro.scenarios.base import _query_args

    edges = np.asarray(edges)
    if n is None:
        n = int(edges.max()) + 1
    scenarios = scenarios if scenarios is not None else standard_matrix()
    baselines: dict = {}
    results = []
    for sc in scenarios:
        bkey = (sc.program, sc.codec)
        if bkey not in baselines:
            bcfg = dataclasses.replace(config, fold_codec=sc.codec)
            sess = DistGraph.from_edges(edges, bcfg, n=n,
                                        weights=weights).session()
            method, arg = _query_args(sc, edges, n)
            baselines[bkey] = getattr(sess, method)(
                *(() if arg is None else (arg,)))
        res = run_drill(sc, edges=edges, config=config, weights=weights,
                        n=n, baseline=baselines[bkey])
        results.append(res)
        if log is not None:
            log(f"drill {res.name}: ok={res.ok} "
                f"bit_identical={res.bit_identical} "
                f"grid={res.grid_before}->{res.grid_after} "
                f"lost={res.lost_queries}"
                + (f" error={res.error}" if res.error else ""))
    return results
