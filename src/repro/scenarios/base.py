"""Fault-drill harness: scenario x injector x runner (DESIGN.md sec. 15).

A drill runs ONE query under an injected device-loss schedule and judges the
recovered output against the same query run uninterrupted:

  Scenario  what breaks: the program/codec under test, the level the loss
            lands on, the phase label, and the loss kind --
            "transient" (one loss, absorbed by the segment retry),
            "persistent" (retries exhaust -> elastic shrink-and-resume), or
            "repeated" (a second loss after the first resume -> two
            shrinks).
  Runner    who recovers: "session" (RecoveryPlan on a GraphSession query),
            "elastic" (ElasticCoordinator re-plans onto the survivor grid),
            or "serve" (a GraphServer drains the in-flight batch through
            recovery).
  DrillResult  the verdict: completion, bit-identity against the
            uninterrupted baseline, Graph500 predecessor validity where
            bit-identity is not the contract (BFS preds after a SHRUNKEN
            resume are grid-dependent), lost queries, and the recovery
            latency (recorded, never gated).
"""
from __future__ import annotations

import dataclasses
import tempfile

import numpy as np

from repro.runtime.fault import RetryPolicy
from repro.runtime.recovery import (DeviceLossInjector, ElasticCoordinator,
                                    RecoveryPlan)


@dataclasses.dataclass
class Scenario:
    """One drill: which query breaks, where, and who recovers it."""
    name: str
    program: str              # "bfs" | "cc" | "sssp" | "multi_bfs"
    codec: str = "list"       # fold codec under test
    at_level: int = 2         # the level the loss schedule crosses
    phase: str = "level"      # "level" | "fold" (drill label; see injector)
    kind: str = "transient"   # "transient" | "persistent" | "repeated"
    runner: str = "session"   # "session" | "elastic" | "serve"


@dataclasses.dataclass
class DrillResult:
    """Verdict of one drill (the BENCH_fault row)."""
    name: str
    scenario: str
    injector: str
    runner: str
    ok: bool
    bit_identical: "bool | None" = None   # None = not the contract here
    pred_valid: "bool | None" = None      # BFS only
    lost_queries: int = 0
    resumed_from_level: "int | None" = None
    time_to_first_resumed_level_s: "float | None" = None
    grid_before: "tuple | None" = None
    grid_after: "tuple | None" = None
    retries: int = 0
    resumes: int = 0
    error: "str | None" = None

    def to_row(self) -> dict:
        row = dataclasses.asdict(self)
        for k in ("grid_before", "grid_after"):
            if row[k] is not None:
                row[k] = list(row[k])
        return row


def _policy(kind: str) -> RetryPolicy:
    # jittered so the drill exercises the seeded backoff; near-zero base so
    # drills stay fast
    return RetryPolicy(max_retries=2, backoff_s=1e-4, jitter_s=1e-4, seed=3)


def _injector(sc: Scenario, policy: RetryPolicy) -> DeviceLossInjector:
    if sc.kind == "transient":
        return DeviceLossInjector(sc.at_level, phase=sc.phase,
                                  transient=True)
    # persistent/repeated: enough fires to exhaust one full retry budget
    # per loss event, then quiet so the resumed traversal completes
    per_loss = policy.max_retries + 1
    n_losses = 2 if sc.kind == "repeated" else 1
    return DeviceLossInjector(sc.at_level, phase=sc.phase,
                              fires=per_loss * n_losses)


def _query_args(sc: Scenario, edges: np.ndarray, n: int):
    """(method name, positional arg) for the scenario's program."""
    deg = np.bincount(edges[0], minlength=n)
    live = np.flatnonzero(deg > 0)
    picks = np.random.default_rng(0).choice(live, 4, replace=False)
    roots = picks.astype(np.int32)
    if sc.program == "bfs":
        return "bfs", roots
    if sc.program == "sssp":
        return "sssp", roots
    if sc.program == "multi_bfs":
        return "multi_bfs", roots
    if sc.program == "cc":
        return "connected_components", None
    raise ValueError(f"unknown drill program {sc.program!r}")


def _compare(sc: Scenario, out, base, edges, arg, n: int):
    """(bit_identical, pred_valid) of a recovered output vs the baseline.

    Everything except BFS predecessors is grid-independent, so it must be
    bit-identical even after a shrink; BFS preds are only required
    bit-identical on a same-grid recovery ("session"/"serve" runners) and
    Graph500-validated otherwise.
    """
    from repro.core import validate_bfs
    same_grid = sc.runner != "elastic"
    pred_valid = None
    if sc.program == "bfs":
        bit = ((np.asarray(out.level)[:, :n]
                == np.asarray(base.level)[:, :n]).all()
               and (np.asarray(out.n_levels)
                    == np.asarray(base.n_levels)).all()
               and tuple(out.edges_scanned) == tuple(base.edges_scanned))
        if same_grid:
            bit = bit and (np.asarray(out.pred)[:, :n]
                           == np.asarray(base.pred)[:, :n]).all()
        try:
            for b, root in enumerate(arg):
                validate_bfs(edges, np.asarray(out.level)[b][:n],
                             np.asarray(out.pred)[b][:n], int(root))
            pred_valid = True
        except AssertionError:
            pred_valid = False
        return bool(bit), pred_valid
    if sc.program == "cc":
        bit = ((np.asarray(out.labels)[:n]
                == np.asarray(base.labels)[:n]).all()
               and int(out.n_iters) == int(base.n_iters)
               and out.edges_scanned == base.edges_scanned)
        return bool(bit), None
    if sc.program == "sssp":
        bit = ((np.asarray(out.dist)[:, :n]
                == np.asarray(base.dist)[:, :n]).all()
               and tuple(out.edges_scanned) == tuple(base.edges_scanned))
        return bool(bit), None
    bit = ((np.asarray(out.level)[:n] == np.asarray(base.level)[:n]).all()
           and (np.asarray(out.src)[:n] == np.asarray(base.src)[:n]).all()
           and out.edges_scanned == base.edges_scanned)
    return bool(bit), None


def _run_serve(sc: Scenario, ft_config, graph_factory, arg, stats: dict):
    """Serve-drain drill: one FT batch interrupted mid-traversal must
    drain through recovery with zero lost queries.

    The server runs with max_retries=0, so ONE fire makes the loss escape
    the segmented loop; the drain re-dispatch then resumes past it --
    that is the persistent-loss story at serve granularity."""
    from repro.serve import GraphServer, ServeConfig

    injector = DeviceLossInjector(sc.at_level, phase=sc.phase, fires=1)
    graph = graph_factory(ft_config)
    with tempfile.TemporaryDirectory() as d:
        cfg = ServeConfig(retry=RetryPolicy(max_retries=0, backoff_s=0.0),
                          recovery_dir=d, window_s=0.05,
                          max_batch=len(arg))
        with GraphServer({"drill": graph}, cfg) as srv:
            tickets = [srv.bfs("drill", int(r), tenant=f"t{i}",
                               injector=injector if i == 0 else None)
                       for i, r in enumerate(arg)]
            results = [t.result(timeout=300) for t in tickets]
            srv.drain()
            snap = srv.metrics_snapshot()["runners"]["drill"]
    stats["resumes"] = snap["recovery_resumes"]
    lost = sum(0 if r.ok else 1 for r in results)
    values = [r.value for r in results if r.ok]
    return values, lost


def run_drill(sc: Scenario, *, edges, config, weights=None, n=None,
              baseline=None) -> DrillResult:
    """Execute one scenario and judge the recovery.

    edges/weights/n/config describe the graph and base query config (grid
    included); `baseline` optionally reuses a precomputed uninterrupted
    output (keyed by program+codec -- `run_matrix` shares them across
    scenarios).
    """
    from repro.api.session import DistGraph

    edges = np.asarray(edges)
    if n is None:
        n = int(edges.max()) + 1
    method, arg = _query_args(sc, edges, n)
    ft_config = dataclasses.replace(config, fault_tolerance=True,
                                    fold_codec=sc.codec)
    base_config = dataclasses.replace(config, fold_codec=sc.codec)

    def graph_factory(cfg):
        return DistGraph.from_edges(edges, cfg, n=n, weights=weights)

    if baseline is None:
        bsess = graph_factory(base_config).session()
        baseline = getattr(bsess, method)(*(() if arg is None else (arg,)))

    policy = _policy(sc.kind)
    injector = _injector(sc, policy)
    inj_desc = (f"at_level={sc.at_level} phase={sc.phase} kind={sc.kind} "
                f"fires={injector.fires}")
    plan = RecoveryPlan(injector=injector, policy=policy)
    result = DrillResult(name=sc.name, scenario=f"{sc.program}/{sc.codec}",
                         injector=inj_desc, runner=sc.runner, ok=False,
                         grid_before=tuple(config.grid))
    try:
        if sc.runner == "serve":
            stats = {}
            values, lost = _run_serve(sc, ft_config, graph_factory, arg,
                                      stats)
            result.lost_queries = lost
            result.resumes = int(stats.get("resumes", 0))
            result.grid_after = tuple(config.grid)
            if lost == 0:
                bits = []
                for b, v in enumerate(values):
                    sb = np.asarray(baseline.level)[b][:n]
                    bits.append((np.asarray(v.level)[:n] == sb).all()
                                and (np.asarray(v.pred)[:n]
                                     == np.asarray(baseline.pred)[b][:n])
                                .all())
                result.bit_identical = bool(all(bits))
                result.ok = result.bit_identical
        elif sc.runner == "elastic":
            coord = ElasticCoordinator(edges, ft_config, weights=weights,
                                       n=n,
                                       max_shrinks=2 if sc.kind != "repeated"
                                       else 3)
            out = coord.run(method, arg, plan=plan)
            result.grid_after = coord.grids[-1]
            result.bit_identical, result.pred_valid = _compare(
                sc, out, baseline, edges, arg, n)
            result.ok = result.bit_identical and result.pred_valid in (
                None, True) and coord.shrinks >= (
                2 if sc.kind == "repeated" else 1)
        else:
            sess = graph_factory(ft_config).session()
            out = getattr(sess, method)(
                *(() if arg is None else (arg,)), recovery=plan)
            result.grid_after = tuple(config.grid)
            result.bit_identical, result.pred_valid = _compare(
                sc, out, baseline, edges, arg, n)
            result.ok = result.bit_identical and result.pred_valid in (
                None, True)
    except Exception as exc:     # noqa: BLE001 -- drills report, not raise
        result.error = f"{type(exc).__name__}: {exc}"
        result.ok = False
        return result
    result.resumed_from_level = plan.stats.get("resumed_from_level")
    result.time_to_first_resumed_level_s = plan.stats.get(
        "time_to_first_resumed_level_s")
    result.retries = int(plan.stats.get("retries", 0))
    result.resumes = result.resumes or int(plan.stats.get("resumes", 0))
    return result
