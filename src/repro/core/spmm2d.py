"""The paper's expand/fold communication pattern as distributed SpMM.

y = A @ x with A 2D-partitioned exactly as in the BFS (paper sec. 2.2) and x
row-sharded by vertex-block owner:

  expand:  all_gather x-blocks along the ROW axes -> each device holds the x
           slice matching its local CSC columns (property (i));
  local:   y_partial[row] += w_e * x[col_e]  (segment-sum over local edges);
  fold:    psum_scatter along the COL axes -> each owner receives the summed
           y for its vertex block (property (ii)).

This is what makes the paper's technique a first-class feature for the
assigned GNN architectures: full-graph neighbour aggregation IS this SpMM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import Grid2D, LocalGraph2D
from repro.dist.compat import shard_map


def _axes(a):
    return tuple(a) if isinstance(a, (tuple, list)) else (a,)


def spmm2d_device(graph: LocalGraph2D, x_own, *, grid: Grid2D, row_axes,
                  col_axes, edge_weight=None):
    """Per-device body (must run inside shard_map).

    x_own: (S, d) features of the vertices owned by this device.
    Returns (S, d) aggregated features for the owned block.
    """
    row_axes, col_axes = _axes(row_axes), _axes(col_axes)
    S, C, ncl = grid.S, grid.C, grid.n_cols_local
    e_cap = graph.row_idx.shape[0]

    xg = jax.lax.all_gather(x_own, row_axes, tiled=False)   # (R, S, d)
    xg = xg.reshape(ncl, x_own.shape[-1])                   # local-col order

    deg = jnp.diff(graph.col_off)
    edge_col = jnp.repeat(jnp.arange(ncl, dtype=jnp.int32), deg,
                          total_repeat_length=e_cap)
    valid = graph.row_idx >= 0
    w = jnp.where(valid, 1.0, 0.0) if edge_weight is None else \
        jnp.where(valid, edge_weight, 0.0)
    contrib = xg[edge_col] * w[:, None].astype(x_own.dtype)
    y_part = jnp.zeros((grid.n_rows_local, x_own.shape[-1]), x_own.dtype)
    y_part = y_part.at[jnp.where(valid, graph.row_idx, 0)].add(
        jnp.where(valid[:, None], contrib, 0))

    ca = col_axes if len(col_axes) > 1 else col_axes[0]
    # fold: sum partial rows across the processor-row, scattering block m to
    # the device at column m (psum_scatter block order == device order).
    return jax.lax.psum_scatter(y_part, ca, scatter_dimension=0, tiled=True)


def make_spmm2d(grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",)):
    """jit-ed global SpMM: (graph, x (n, d)) -> (n, d), x in vertex-block
    order (block b = j*R + i holds vertices [b*S, (b+1)*S))."""
    row_axes, col_axes = _axes(row_axes), _axes(col_axes)
    dev = P(row_axes, col_axes)
    xspec = P((*col_axes, *row_axes))

    def fn(col_off, row_idx, nnz, x):
        g = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                         nnz=nnz[0, 0])
        y = spmm2d_device(g, x, grid=grid, row_axes=row_axes,
                          col_axes=col_axes)
        return y

    sm = shard_map(fn, mesh=mesh, in_specs=(dev, dev, dev, xspec),
                   out_specs=xspec, check_vma=False)
    return jax.jit(sm)
