"""Graph partitioning (paper sec. 2.2 + 3.1, and the 1D baseline of [1]).

Host-side (numpy) construction: runs once before the search, exactly as the
paper partitions after generation.  All outputs are padded to uniform
per-device shapes so they can be dropped onto a device mesh.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import Grid2D, LocalGraph2D


# ----------------------------------------------------------------------------
# Index maps (numpy/int aware; jnp arrays also work through these).
# ----------------------------------------------------------------------------

def owner_of(g, grid: Grid2D):
    """Vertex g -> (i, j) owner coordinates.  Block b = j*R + i."""
    b = g // grid.S
    return b % grid.R, b // grid.R


def local_row(g, grid: Grid2D):
    """Global row -> local row (valid on every processor in the owner's
    processor-row)."""
    return (g // grid.S // grid.R) * grid.S + g % grid.S


def local_col(g, grid: Grid2D):
    """Global col -> local col (valid on every processor in the owner's
    processor-column)."""
    return g % grid.n_cols_local


def row2col(lr, i, j, grid: Grid2D):
    """Owner-local row index -> owner-local col index (paper ROW2COL)."""
    return i * grid.S + (lr - j * grid.S)


def global_from_row(lr, i, grid: Grid2D):
    """Local row index -> global vertex id, for a processor in grid-row i."""
    m = lr // grid.S
    return (m * grid.R + i) * grid.S + lr % grid.S


def global_from_col(lc, j, grid: Grid2D):
    """Local col index -> global vertex id for processor-column j."""
    return j * grid.n_cols_local + lc


# ----------------------------------------------------------------------------
# 2D partition
# ----------------------------------------------------------------------------

def partition_2d(edges, grid: Grid2D, pad_to: int | None = None):
    """Split a directed edge list among the R x C grid.

    edges: (2, E) [src u, dst v] -- the non-zero A[v, u].
    Edge (u, v) belongs to P_ij with i = (v // S) % R (row-block congruence)
    and j = u // (N/C) (column block).

    Returns a LocalGraph2D whose arrays have leading dims (R, C):
      col_off: (R, C, N/C + 1), row_idx: (R, C, e_max), nnz: (R, C).
    """
    R, C, S = grid.R, grid.C, grid.S
    ncl = grid.n_cols_local
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)

    pi = (v // S) % R
    pj = u // ncl
    lc = u % ncl
    lr = (v // S // R) * S + v % S

    dev = pi * C + pj
    e_max = pad_to if pad_to is not None else int(np.bincount(dev, minlength=R * C).max())

    col_off = np.zeros((R, C, ncl + 1), np.int32)
    row_idx = np.full((R, C, e_max), -1, np.int32)
    nnz = np.zeros((R, C), np.int32)

    order = np.lexsort((lc, dev))  # group by device, then by local column
    dev_s, lc_s, lr_s = dev[order], lc[order], lr[order]
    starts = np.searchsorted(dev_s, np.arange(R * C + 1))
    for i in range(R):
        for j in range(C):
            d = i * C + j
            a, b = starts[d], starts[d + 1]
            cnt = b - a
            if cnt > e_max:
                raise ValueError(f"pad_to={e_max} < local nnz {cnt} at P({i},{j})")
            deg = np.bincount(lc_s[a:b], minlength=ncl)
            np.cumsum(deg, out=col_off[i, j, 1:])
            row_idx[i, j, :cnt] = lr_s[a:b]
            nnz[i, j] = cnt
    return LocalGraph2D(col_off=col_off, row_idx=row_idx, nnz=nnz)


def partition_edge_vals(edges, vals, grid: Grid2D, pad_to: int | None = None):
    """Per-edge values laid out in `partition_2d`'s CSC order.

    vals: (E,) array aligned with `edges` columns (e.g. uint8 weights for
    SSSP).  Returns an (R, C, e_max) array of the same dtype, padding 0,
    such that entry [i, j, k] is the value of the edge `partition_2d` put at
    row_idx[i, j, k].  Alignment holds because both functions order edges
    with the same stable `np.lexsort((lc, dev))`.
    """
    R, C, S = grid.R, grid.C, grid.S
    ncl = grid.n_cols_local
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    vals = np.asarray(vals)
    if vals.shape[0] != u.shape[0]:
        raise ValueError(
            f"{vals.shape[0]} edge values for {u.shape[0]} edges")
    pi = (v // S) % R
    pj = u // ncl
    lc = u % ncl
    dev = pi * C + pj
    e_max = pad_to if pad_to is not None else int(
        np.bincount(dev, minlength=R * C).max())
    out = np.zeros((R, C, e_max), vals.dtype)
    order = np.lexsort((lc, dev))
    dev_s, vals_s = dev[order], vals[order]
    starts = np.searchsorted(dev_s, np.arange(R * C + 1))
    for i in range(R):
        for j in range(C):
            d = i * C + j
            a, b = starts[d], starts[d + 1]
            if b - a > e_max:
                raise ValueError(
                    f"pad_to={e_max} < local nnz {b - a} at P({i},{j})")
            out[i, j, :b - a] = vals_s[a:b]
    return out


def partition_2d_csr(edges, grid: Grid2D, pad_to: int | None = None):
    """Row-major (CSR) twin of partition_2d for the bottom-up direction
    (DESIGN.md: beyond-paper direction-optimising needs row access).

    Returns dict(row_off=(R, C, N/R + 1), col_idx=(R, C, e_max), nnz=(R, C))
    where col_idx holds LOCAL column indices.
    """
    R, C, S = grid.R, grid.C, grid.S
    nrl = grid.n_rows_local
    ncl = grid.n_cols_local
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    pi = (v // S) % R
    pj = u // ncl
    lc = u % ncl
    lr = (v // S // R) * S + v % S
    dev = pi * C + pj
    e_max = pad_to if pad_to is not None else int(np.bincount(dev, minlength=R * C).max())
    row_off = np.zeros((R, C, nrl + 1), np.int32)
    col_idx = np.full((R, C, e_max), -1, np.int32)
    nnz = np.zeros((R, C), np.int32)
    order = np.lexsort((lr, dev))
    dev_s, lr_s, lc_s = dev[order], lr[order], lc[order]
    starts = np.searchsorted(dev_s, np.arange(R * C + 1))
    for i in range(R):
        for j in range(C):
            d = i * C + j
            a, b = starts[d], starts[d + 1]
            deg = np.bincount(lr_s[a:b], minlength=nrl)
            np.cumsum(deg, out=row_off[i, j, 1:])
            col_idx[i, j, :b - a] = lc_s[a:b]
            nnz[i, j] = b - a
    return dict(row_off=row_off, col_idx=col_idx, nnz=nnz)


def partition_edge_vals_csr(edges, vals, grid: Grid2D,
                            pad_to: int | None = None):
    """Per-edge values laid out in `partition_2d_csr`'s CSR order.

    The CSR analog of `partition_edge_vals`: entry [i, j, k] is the value of
    the edge `partition_2d_csr` put at col_idx[i, j, k].  Alignment holds
    because both order edges with the same stable `np.lexsort((lr, dev))`.
    Direction-optimised SSSP pulls over this copy in bottom-up levels.
    """
    R, C, S = grid.R, grid.C, grid.S
    ncl = grid.n_cols_local
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    vals = np.asarray(vals)
    if vals.shape[0] != u.shape[0]:
        raise ValueError(
            f"{vals.shape[0]} edge values for {u.shape[0]} edges")
    pi = (v // S) % R
    pj = u // ncl
    lr = (v // S // R) * S + v % S
    dev = pi * C + pj
    e_max = pad_to if pad_to is not None else int(
        np.bincount(dev, minlength=R * C).max())
    out = np.zeros((R, C, e_max), vals.dtype)
    order = np.lexsort((lr, dev))
    dev_s, vals_s = dev[order], vals[order]
    starts = np.searchsorted(dev_s, np.arange(R * C + 1))
    for i in range(R):
        for j in range(C):
            d = i * C + j
            a, b = starts[d], starts[d + 1]
            if b - a > e_max:
                raise ValueError(
                    f"pad_to={e_max} < local nnz {b - a} at P({i},{j})")
            out[i, j, :b - a] = vals_s[a:b]
    return out


# ----------------------------------------------------------------------------
# 1D baseline partition (the paper's ORIGINAL code [1]: modulo rule)
# ----------------------------------------------------------------------------

def partition_1d(edges, n: int, P: int, pad_to: int | None = None):
    """Vertices assigned by modulo rule; each processor stores the full
    adjacency lists (CSC columns) of its own vertices.

    Returns dict with per-processor CSC over local columns (n/P columns,
    column k on processor p is vertex k*P + p) and global row ids.
    """
    if n % P:
        raise ValueError("n must be divisible by P (pad first)")
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    ncl = n // P
    dev = u % P
    lc = u // P
    e_max = pad_to if pad_to is not None else int(np.bincount(dev, minlength=P).max())

    col_off = np.zeros((P, ncl + 1), np.int32)
    row_idx = np.full((P, e_max), -1, np.int32)  # GLOBAL dst ids
    nnz = np.zeros((P,), np.int32)
    order = np.lexsort((lc, dev))
    dev_s, lc_s, v_s = dev[order], lc[order], v[order]
    starts = np.searchsorted(dev_s, np.arange(P + 1))
    for p in range(P):
        a, b = starts[p], starts[p + 1]
        deg = np.bincount(lc_s[a:b], minlength=ncl)
        np.cumsum(deg, out=col_off[p, 1:])
        row_idx[p, :b - a] = v_s[a:b]
        nnz[p] = b - a
    return dict(col_off=col_off, row_idx=row_idx, nnz=nnz, n=n, P=P)
