"""Direction-optimising 2D BFS (beyond-paper; Beamer et al. [7] + [20]).

The paper cites direction-optimisation as related work but does not implement
it.  We add a bottom-up step that composes with the 2D decomposition:

  * expand is unchanged (frontier gathered within the processor-column);
  * instead of scanning FRONTIER columns (CSC), each device scans its
    UNVISITED local rows (CSR) for any edge into the frontier;
  * fold becomes a min-reduce of encoded parents within the processor-row
    (an all_to_all of (C, S) int32 + local min), replacing vertex lists.

Per-level direction choice follows Beamer's heuristic on the global frontier
size.  TEPS accounting still uses input edges in the component (Graph500),
matching the paper's note that bottom-up "does not traverse all edges".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import frontier as F
from repro.core.bfs2d import _axes, _level_step, _init_state, _resolve_preds, \
    _owned_level, append_padded
from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def _bottomup_step(csr_row_off, csr_col_idx, st: BFSState, *, grid: Grid2D,
                   row_axes, col_axes, i, j):
    S, C, ncl, nrl = grid.S, grid.C, grid.n_cols_local, grid.n_rows_local
    e_cap = csr_col_idx.shape[0]

    # expand: gather frontier, build a column bitmap for this column block
    af_blocks = jax.lax.all_gather(st.front, row_axes, tiled=False).reshape(grid.R, S)
    af_cnts = jax.lax.all_gather(st.front_cnt, row_axes, tiled=False).reshape(grid.R)
    msk = jnp.arange(S, dtype=jnp.int32)[None, :] < af_cnts[:, None]
    fmask = jnp.zeros((ncl,), bool).at[
        jnp.where(msk, af_blocks, ncl).reshape(-1)].set(True, mode="drop")

    # scan unvisited local rows for any parent in the frontier (segment-min)
    deg = jnp.diff(csr_row_off)
    edge_row = jnp.repeat(jnp.arange(nrl, dtype=jnp.int32), deg,
                          total_repeat_length=e_cap)
    valid = csr_col_idx >= 0
    hit = valid & fmask[jnp.clip(csr_col_idx, 0, ncl - 1)]
    enc = jnp.where(hit, csr_col_idx, I32_MAX)
    best = jnp.full((nrl,), I32_MAX, jnp.int32).at[edge_row].min(enc)
    row_unvis = ~st.visited
    found = (best < I32_MAX) & row_unvis
    # encode GLOBAL parent id; fold = min-reduce within the processor-row
    parent_g = jnp.where(found, j * ncl + best, I32_MAX).reshape(C, S)
    ca = col_axes if len(col_axes) > 1 else col_axes[0]
    recv = jax.lax.all_to_all(parent_g, ca, 0, 0).reshape(C, S)
    best_owned = recv.min(axis=0)                    # (S,) my owned block
    rows_owned = j * S + jnp.arange(S, dtype=jnp.int32)
    vis_owned = st.visited[rows_owned]
    new = (best_owned < I32_MAX) & ~vis_owned

    visited = st.visited.at[jnp.where(new, rows_owned, nrl)].set(True, mode="drop")
    level = st.level.at[jnp.where(new, rows_owned, nrl)].set(
        jnp.where(new, st.lvl, 0), mode="drop")
    pred = st.pred.at[jnp.where(new, rows_owned, nrl)].set(
        jnp.where(new, best_owned, 0), mode="drop")

    lc = i * S + jnp.arange(S, dtype=jnp.int32)      # ROW2COL of owned rows
    nf = jnp.full((S,), -1, jnp.int32)
    nf, nc = append_padded(nf, jnp.int32(0), lc, new)

    st2 = BFSState(level=level, pred=pred, visited=visited, front=nf,
                   front_cnt=nc, lvl=st.lvl + 1)
    total = jax.lax.psum(nc, row_axes + col_axes)
    edges_scanned = jnp.sum(jnp.where(valid & row_unvis[edge_row], 1, 0),
                            dtype=jnp.int32)
    return st2, total, edges_scanned


class BFS2DDirection:
    """Direction-optimising distributed BFS (drop-in for BFS2D.run)."""

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, alpha: int = 24,
                 max_levels: int = 64):
        self.grid, self.mesh = grid, mesh
        self.row_axes, self.col_axes = _axes(row_axes), _axes(col_axes)
        self.edge_chunk, self.alpha, self.max_levels = edge_chunk, alpha, max_levels
        self._run = jax.jit(self._build())

    def _build(self):
        grid, alpha = self.grid, self.alpha
        row_axes, col_axes = self.row_axes, self.col_axes

        def device_fn(col_off, row_idx, nnz, row_off, col_idx, root):
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            row_off_, col_idx_ = row_off[0, 0], col_idx[0, 0]
            i = jax.lax.axis_index(row_axes if len(row_axes) > 1 else row_axes[0]).astype(jnp.int32)
            j = jax.lax.axis_index(col_axes if len(col_axes) > 1 else col_axes[0]).astype(jnp.int32)
            st = _init_state(root, grid=grid, i=i, j=j)

            def body(carry):
                st, total, _ = carry

                def topdown(st):
                    return _level_step(graph, st, grid=grid, row_axes=row_axes,
                                       col_axes=col_axes,
                                       edge_chunk=self.edge_chunk)

                def bottomup(st):
                    return _bottomup_step(row_off_, col_idx_, st, grid=grid,
                                          row_axes=row_axes, col_axes=col_axes,
                                          i=i, j=j)

                use_bu = total > (grid.n // alpha)
                return jax.lax.cond(use_bu, bottomup, topdown, st)

            init_total = jax.lax.psum(st.front_cnt, row_axes + col_axes)
            st, _, _ = jax.lax.while_loop(
                lambda c: (c[1] > 0) & (c[0].lvl <= self.max_levels),
                body, (st, init_total, jnp.int32(0)))
            pred = _resolve_preds(st.pred, grid=grid, j=j, col_axes=col_axes)
            level = _owned_level(st.level, grid=grid, j=j)
            return level[None, None], pred[None, None], st.lvl[None, None]

        dev = P(self.row_axes, self.col_axes)
        out_g = P((*self.col_axes, *self.row_axes))
        return jax.shard_map(device_fn, mesh=self.mesh,
                             in_specs=(dev,) * 5 + (P(),),
                             out_specs=(out_g, out_g, dev), check_vma=False)

    def run(self, graph: LocalGraph2D, csr: dict, root) -> BFSOutput:
        level, pred, lvls = self._run(graph.col_off, graph.row_idx, graph.nnz,
                                      csr["row_off"], csr["col_idx"],
                                      jnp.int32(root))
        return BFSOutput(level=level.reshape(-1), pred=pred.reshape(-1),
                         n_levels=lvls.max())
