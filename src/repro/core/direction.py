"""Direction-optimising 2D BFS (beyond-paper; Beamer et al. [7] + [20]).

The paper cites direction-optimisation as related work but does not implement
it.  We add a bottom-up step that composes with the 2D decomposition:

  * expand is unchanged (frontier gathered within the processor-column);
  * instead of scanning FRONTIER columns (CSC), each device scans its
    UNVISITED local rows (CSR) for any edge into the frontier;
  * fold becomes a min-reduce of encoded parents within the processor-row
    (an all_to_all of (C, S) int32 + local min), replacing vertex lists.

Per-level direction choice follows Beamer's heuristic on the global frontier
size.  TEPS accounting still uses input edges in the component (Graph500),
matching the paper's note that bottom-up "does not traverse all edges".

The driver is a thin config of `repro.dist.engine`: a `step_factory` that
wraps the engine's own top-down step in a `lax.cond` against the bottom-up
step below.  Top-down levels therefore inherit the engine's fold codec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frontier as F
from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput
from repro.dist.engine import canonical_front
from repro.dist.topology import Topology

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def _bottomup_step(csr_row_off, csr_col_idx, st: BFSState, *, topo: Topology,
                   i, j):
    grid = topo.grid
    S, C, ncl, nrl = grid.S, grid.C, grid.n_cols_local, grid.n_rows_local
    e_cap = csr_col_idx.shape[0]

    # expand: gather frontier, build a column bitmap for this column block
    af_blocks = topo.row_gather(st.front).reshape(grid.R, S)
    af_cnts = topo.row_gather(st.front_cnt).reshape(grid.R)
    msk = jnp.arange(S, dtype=jnp.int32)[None, :] < af_cnts[:, None]
    fmask = jnp.zeros((ncl,), bool).at[
        jnp.where(msk, af_blocks, ncl).reshape(-1)].set(True, mode="drop")

    # scan unvisited local rows for any parent in the frontier (segment-min)
    deg = jnp.diff(csr_row_off)
    edge_row = jnp.repeat(jnp.arange(nrl, dtype=jnp.int32), deg,
                          total_repeat_length=e_cap)
    valid = csr_col_idx >= 0
    hit = valid & fmask[jnp.clip(csr_col_idx, 0, ncl - 1)]
    enc = jnp.where(hit, csr_col_idx, I32_MAX)
    best = jnp.full((nrl,), I32_MAX, jnp.int32).at[edge_row].min(enc)
    row_unvis = ~st.visited
    found = (best < I32_MAX) & row_unvis
    # encode GLOBAL parent id; fold = min-reduce within the processor-row
    parent_g = jnp.where(found, j * ncl + best, I32_MAX).reshape(C, S)
    recv = topo.col_all_to_all(parent_g).reshape(C, S)
    best_owned = recv.min(axis=0)                    # (S,) my owned block
    rows_owned = j * S + jnp.arange(S, dtype=jnp.int32)
    vis_owned = st.visited[rows_owned]
    new = (best_owned < I32_MAX) & ~vis_owned

    visited = st.visited.at[jnp.where(new, rows_owned, nrl)].set(
        True, mode="drop")
    level = st.level.at[jnp.where(new, rows_owned, nrl)].set(
        jnp.where(new, st.lvl, 0), mode="drop")
    pred = st.pred.at[jnp.where(new, rows_owned, nrl)].set(
        jnp.where(new, best_owned, 0), mode="drop")

    lc = i * S + jnp.arange(S, dtype=jnp.int32)      # ROW2COL of owned rows
    nf = jnp.full((S,), -1, jnp.int32)
    nf, nc = F.append_padded(nf, jnp.int32(0), lc, new)
    nf, nc = canonical_front(nf, nc)

    st2 = BFSState(level=level, pred=pred, visited=visited, front=nf,
                   front_cnt=nc, lvl=st.lvl + 1)
    total = topo.psum_all(nc)
    edges_scanned = jnp.sum(jnp.where(valid & row_unvis[edge_row], 1, 0),
                            dtype=jnp.uint32)
    return st2, total, edges_scanned


def direction_step_factory(topo: Topology, alpha: int = 24):
    """Engine `step_factory` wrapping the top-down step in Beamer's per-level
    direction choice (bottom-up once the global frontier exceeds n/alpha).

    The two extra per-device arrays are the CSR twin (row_off, col_idx)."""
    grid = topo.grid

    def step_factory(engine, graph, extra, i, j, topdown):
        row_off, col_idx = extra

        def step(st, prev_total):
            def bottomup(st):
                return _bottomup_step(row_off, col_idx, st, topo=topo,
                                      i=i, j=j)

            use_bu = prev_total > (grid.n // alpha)
            return jax.lax.cond(use_bu, bottomup, topdown, st)

        return step

    return step_factory


class BFS2DDirection:
    """DEPRECATED shim over the session API (drop-in for BFS2D.run).

    Equivalent to `BFSConfig(direction=True)` on a `GraphSession`; kept so
    pre-session callers keep working.  Use
    `repro.api.DistGraph.from_edges(edges, BFSConfig(direction=True))`.
    """

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, alpha: int = 24,
                 max_levels: int = 64, fold_codec="list"):
        import warnings

        from repro.api.config import BFSConfig
        from repro.api.session import build_engine

        warnings.warn(
            "BFS2DDirection is deprecated; use repro.api.DistGraph/"
            "GraphSession with BFSConfig(direction=True)",
            DeprecationWarning, stacklevel=2)
        self.grid, self.mesh = grid, mesh
        self.alpha = alpha
        self.config = BFSConfig(
            grid=grid, fold_codec=fold_codec, edge_chunk=edge_chunk,
            max_levels=max_levels, direction=True, alpha=alpha,
            row_axes=tuple(row_axes), col_axes=tuple(col_axes))
        self.topology = Topology(grid, mesh, row_axes=row_axes,
                                 col_axes=col_axes)
        self.engine = build_engine(self.topology, self.config)
        self._run = self.engine._run
        self._compiled = {}            # aval-keyed AOT cache, shared across
                                       # every graph run through this shim

    def _session(self, graph: LocalGraph2D, csr: dict):
        from repro.api.session import DistGraph, GraphSession

        dg = DistGraph(self.topology, graph, csr=csr, config=self.config)
        dg._compiled = self._compiled  # executables are data-independent
        return GraphSession(dg, self.config, engine=self.engine)

    def run(self, graph: LocalGraph2D, csr: dict, root) -> BFSOutput:
        return self._session(graph, csr).bfs(root)
