"""DEPRECATED shim: direction-optimising 2D BFS moved into the engine.

Direction optimisation (Beamer et al. [7] + [20]) is now a first-class mode
of the frontier engine: `BFSConfig(direction=True | "adaptive" | "bottomup")`
routes BFS -- and CC / SSSP / multi-source BFS -- through the
`repro.algos.direction.DirectionProgram` wrapper, whose fused bottom-up
kernels live in `repro.kernels.bottomup` (DESIGN.md sec. 11).  Nothing on
the hot path imports this module any more.

`BFS2DDirection` remains as a deprecated drop-in for pre-session callers; it
is a thin veneer over `BFSConfig(direction=True)` on a `GraphSession`.
"""
from __future__ import annotations

from repro.core.types import Grid2D, LocalGraph2D, BFSOutput
from repro.dist.topology import Topology


class BFS2DDirection:
    """DEPRECATED shim over the session API (drop-in for BFS2D.run).

    Equivalent to `BFSConfig(direction=True)` on a `GraphSession`; kept so
    pre-session callers keep working.  Use
    `repro.api.DistGraph.from_edges(edges, BFSConfig(direction=True))`.
    """

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, alpha: int = 24,
                 max_levels: int = 64, fold_codec="list"):
        import warnings

        from repro.api.config import BFSConfig
        from repro.api.session import build_engine

        warnings.warn(
            "BFS2DDirection is deprecated; use repro.api.DistGraph/"
            "GraphSession with BFSConfig(direction=True)",
            DeprecationWarning, stacklevel=2)
        self.grid, self.mesh = grid, mesh
        self.alpha = alpha
        self.config = BFSConfig(
            grid=grid, fold_codec=fold_codec, edge_chunk=edge_chunk,
            max_levels=max_levels, direction=True, alpha=alpha,
            row_axes=tuple(row_axes), col_axes=tuple(col_axes))
        self.topology = Topology(grid, mesh, row_axes=row_axes,
                                 col_axes=col_axes)
        self.engine = build_engine(self.topology, self.config)
        self._run = self.engine._run
        self._compiled = {}            # aval-keyed AOT cache, shared across
                                       # every graph run through this shim

    def _session(self, graph: LocalGraph2D, csr: dict):
        from repro.api.session import DistGraph, GraphSession

        dg = DistGraph(self.topology, graph, csr=csr, config=self.config)
        dg._compiled = self._compiled  # executables are data-independent
        return GraphSession(dg, self.config, engine=self.engine)

    def run(self, graph: LocalGraph2D, csr: dict, root) -> BFSOutput:
        return self._session(graph, csr).bfs(root)
