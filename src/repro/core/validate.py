"""Graph500-style BFS output validation + TEPS accounting (paper sec. 4).

Checks (on the global (level, pred) result and the input edge list):
  1. root: level[root] == 0 and pred[root] == root;
  2. reachability consistency: level[v] >= 0  <=>  pred[v] >= 0;
  3. tree: for every visited v != root, pred[v] is visited and
     level[v] == level[pred[v]] + 1;
  4. tree edges exist in the graph;
  5. every input edge (u, v) with both endpoints visited satisfies
     |level[u] - level[v]| <= 1, and no edge joins visited to unvisited
     (the component is fully explored).

TEPS = (# input edge tuples within the traversed component) / time, with the
harmonic mean across the 64 random roots, as in the paper.
"""
from __future__ import annotations

import numpy as np


def _edge_set(edges):
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    return u, v


def validate_bfs(edges, level, pred, root: int) -> None:
    """Raise AssertionError with a message on any rule violation."""
    level = np.asarray(level)
    pred = np.asarray(pred)
    u, v = _edge_set(edges)

    assert level[root] == 0, f"level[root]={level[root]}"
    assert pred[root] == root, f"pred[root]={pred[root]}"

    vis = level >= 0
    assert ((pred >= 0) == vis).all(), "pred/level visited sets differ"

    w = np.flatnonzero(vis)
    w = w[w != root]
    p = pred[w]
    assert (level[p] >= 0).all(), "parent not visited"
    assert (level[w] == level[p] + 1).all(), "tree edge not level+1"

    # tree edges must exist in the graph (directed edge p -> w or w -> p;
    # the input is symmetrised so checking one direction suffices)
    key = u.astype(np.int64) * (level.shape[0] + 1) + v
    key.sort()
    tkey = p.astype(np.int64) * (level.shape[0] + 1) + w
    pos = np.searchsorted(key, tkey)
    pos = np.clip(pos, 0, key.shape[0] - 1)
    assert (key[pos] == tkey).all(), "tree edge not in graph"

    both = vis[u] & vis[v]
    assert (np.abs(level[u[both]] - level[v[both]]) <= 1).all(), \
        "graph edge spans > 1 level"
    cross = vis[u] ^ vis[v]
    assert not cross.any(), "edge joins visited and unvisited (incomplete BFS)"


def count_component_edges(edges, level) -> int:
    """# directed input edge tuples with endpoints inside the component.
    Graph500 counts undirected input edges; our edge list is symmetrised, so
    divide by 2."""
    level = np.asarray(level)
    u, v = _edge_set(edges)
    return int(((level[u] >= 0) & (level[v] >= 0)).sum()) // 2


def teps(edges, level, seconds: float) -> float:
    return count_component_edges(edges, level) / max(seconds, 1e-12)


def harmonic_mean(xs) -> float:
    xs = np.asarray(xs, dtype=np.float64)
    return float(len(xs) / np.sum(1.0 / np.maximum(xs, 1e-30)))
