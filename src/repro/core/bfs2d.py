"""Distributed BFS with 2D partitioning (paper Alg. 2) on the shared engine.

Mesh mapping (DESIGN.md sec. 5): the processor grid's ROWS span `row_axes`
(e.g. ("pod", "data")) and its COLUMNS span `col_axes` (e.g. ("model",)).
  expand (paper line 13)  = all_gather of frontiers along the row axes
                            (processors in the same grid column);
  fold   (paper line 17)  = all_to_all of discovered vertices along the col
                            axes (processors in the same grid row).
So one BFS level costs 2 x O(sqrt(P)) partner exchanges instead of the 1D
code's O(P) (paper sec. 2.2).

The level loop, init and deferred-predecessor resolution live in
`repro.dist.engine`; what goes on the fold wire is a pluggable codec
(`repro.dist.exchange`, DESIGN.md sec. 4): the paper's 32-bit local indices
("list", sec. 3.3), a 1-bit block bitmap ("bitmap"), or sorted 16-bit deltas
("delta", Romera & Froning 2017).
"""
from __future__ import annotations

from repro.core.types import Grid2D, LocalGraph2D, BFSOutput
from repro.dist.engine import DistBFSEngine
from repro.dist.topology import Topology


class BFS2D:
    """Distributed 2D BFS bound to a mesh.

    Arrays for the graph carry leading (R, C) device axes (as produced by
    `partition_2d`); results come back as global (n,) arrays laid out in
    vertex-block order (b = j*R + i), i.e. plain global vertex ids.

    fold_codec selects the fold wire format ("list" | "bitmap" | "delta");
    `fold_bitmap=True` is the legacy spelling of fold_codec="bitmap".
    """

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, expand_fn=None,
                 fold_bitmap: bool = False, max_levels: int = 64,
                 dedup: str = "scatter", fold_codec=None):
        if fold_codec is None:
            fold_codec = "bitmap" if fold_bitmap else "list"
        self.grid = grid
        self.mesh = mesh
        self.topology = Topology(grid, mesh, row_axes=row_axes,
                                 col_axes=col_axes)
        self.engine = DistBFSEngine(
            self.topology, fold_codec=fold_codec, edge_chunk=edge_chunk,
            max_levels=max_levels, expand_fn=expand_fn, dedup=dedup)
        self._run = self.engine._run   # (col_off, row_idx, nnz, root) -> outs

    def run(self, graph: LocalGraph2D, root) -> BFSOutput:
        return self.engine.run(graph, root)
