"""Distributed BFS with 2D partitioning (paper Alg. 2) via jax.shard_map.

Mesh mapping (DESIGN.md sec. 5): the processor grid's ROWS span `row_axes`
(e.g. ("pod", "data")) and its COLUMNS span `col_axes` (e.g. ("model",)).
  expand (paper line 13)  = all_gather of frontiers along the row axes
                            (processors in the same grid column);
  fold   (paper line 17)  = all_to_all of discovered vertices along the col
                            axes (processors in the same grid row).
So one BFS level costs 2 x O(sqrt(P)) partner exchanges instead of the 1D
code's O(P) (paper sec. 2.2).

Communication carries 32-bit LOCAL indices only (paper sec. 3.3); parents are
resolved once, at the end, with a single all_to_all of the senders' pred
arrays (the paper's deferred-predecessor scheme, sec. 3.5).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import frontier as F
from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput


def _axes(a) -> tuple:
    return tuple(a) if isinstance(a, (tuple, list)) else (a,)


def append_padded(buf, cnt, vals, valid):
    """Append vals[valid] to a padded (cap,) buffer at position cnt."""
    b, c = F.bucket_append(buf[None, :], cnt[None],
                           vals, jnp.zeros_like(vals), valid, 1)
    return b[0], c[0]


# ----------------------------------------------------------------------------
# Per-device level step (runs inside shard_map)
# ----------------------------------------------------------------------------

def _level_step(graph: LocalGraph2D, st: BFSState, *, grid: Grid2D,
                row_axes, col_axes, edge_chunk: int, expand_fn=None,
                fold_bitmap: bool = False, dedup: str = "scatter"):
    i = jax.lax.axis_index(row_axes if len(row_axes) > 1 else row_axes[0])
    j = jax.lax.axis_index(col_axes if len(col_axes) > 1 else col_axes[0])
    i = i.astype(jnp.int32)
    j = j.astype(jnp.int32)
    S, C = grid.S, grid.C

    # ---- expand exchange: gather frontiers within the processor-column ----
    af_blocks = jax.lax.all_gather(st.front, row_axes, tiled=False)   # (R, S)
    af_cnts = jax.lax.all_gather(st.front_cnt, row_axes, tiled=False)  # (R,)
    af_blocks = af_blocks.reshape(grid.R, S)
    af_cnts = af_cnts.reshape(grid.R)
    all_front, front_total = F.compact_blocks(af_blocks, af_cnts)  # (n/C,)

    # ---- frontier expansion (local CSC column scan) ----
    ex = F.expand_frontier(
        graph.col_off, graph.row_idx, st.visited, st.level, st.pred,
        all_front, front_total, st.lvl, grid=grid, i=i, j=j,
        edge_chunk=edge_chunk, expand_fn=expand_fn, dedup=dedup)

    # ---- move own-column vertices straight to the frontier (lines 15-16) ---
    own_rows = jnp.take(ex.dst, j, axis=0)          # (S,) local rows, block j
    own_cnt = jnp.take(ex.dst_cnt, j)
    own_cols = (i * S + (own_rows - j * S)).astype(jnp.int32)  # ROW2COL
    own_valid = jnp.arange(S, dtype=jnp.int32) < own_cnt
    dst = ex.dst.at[j].set(-1)
    dst_cnt = ex.dst_cnt.at[j].set(0)

    # ---- fold exchange: route discoveries to their owners (same grid row) --
    ca = col_axes if len(col_axes) > 1 else col_axes[0]
    if fold_bitmap:
        # beyond-paper: send a 1-bit-per-vertex block bitmap instead of 32-bit
        # vertex lists (32x traffic reduction at identical semantics; see
        # EXPERIMENTS.md "fold compression").  dst rows hold local-row ids of
        # block m, i.e. offsets m*S + t: send bit t to column m.
        valid = dst >= 0
        rowsel = jnp.where(valid, jnp.arange(C, dtype=jnp.int32)[:, None], C)
        onehot = jnp.zeros((C, S), bool).at[
            rowsel.reshape(-1), jnp.where(valid, dst % S, 0).reshape(-1)
        ].set(True, mode="drop")
        words = jax.lax.all_to_all(F.pack_bitmap(onehot), ca, 0, 0).reshape(C, -1)
        recv_mask = F.unpack_bitmap(words, S)         # [m, t]: from sender m
        # received offsets t are MY owned block -> local row j*S + t
        rows = j * S + jnp.arange(S, dtype=jnp.int32)[None, :]
        int_verts = jax.vmap(lambda r, m: append_padded(
            jnp.full((S,), -1, jnp.int32), jnp.int32(0), r, m)[0])(
                jnp.broadcast_to(rows, (C, S)), recv_mask)
        int_cnt = recv_mask.sum(axis=1, dtype=jnp.int32)
    else:
        int_verts = jax.lax.all_to_all(dst, ca, 0, 0).reshape(C, S)
        int_cnt = jax.lax.all_to_all(dst_cnt, ca, 0, 0).reshape(C)

    # ---- frontier update (paper sec. 3.5) ----
    up = F.update_frontier(int_verts, int_cnt, ex.visited, ex.level, ex.pred,
                           st.lvl, grid=grid, i=i, j=j)

    nf = jnp.full((S,), -1, jnp.int32)
    nc = jnp.int32(0)
    nf, nc = append_padded(nf, nc, own_cols, own_valid)
    up_valid = jnp.arange(S, dtype=jnp.int32) < up.new_cnt
    nf, nc = append_padded(nf, nc, up.new_front, up_valid)

    new_state = BFSState(level=up.level, pred=up.pred, visited=up.visited,
                         front=nf, front_cnt=nc, lvl=st.lvl + 1)
    total = jax.lax.psum(nc, row_axes + col_axes)
    return new_state, total, ex.edges_scanned


def _init_state(root, *, grid: Grid2D, i, j):
    S, C = grid.S, grid.C
    nrl = grid.n_rows_local
    b = root // S
    oi, oj = b % grid.R, b // grid.R
    mine = (oi == i) & (oj == j)
    lr = (root // S // grid.R) * S + root % S
    lc = root % grid.n_cols_local
    level = jnp.full((nrl,), -1, jnp.int32)
    pred = jnp.full((nrl,), -1, jnp.int32)
    visited = jnp.zeros((nrl,), bool)
    front = jnp.full((S,), -1, jnp.int32)
    level = jnp.where(mine, level.at[lr].set(0), level)
    pred = jnp.where(mine, pred.at[lr].set(root), pred)
    visited = jnp.where(mine, visited.at[lr].set(True), visited)
    front = jnp.where(mine, front.at[0].set(lc), front)
    cnt = jnp.where(mine, jnp.int32(1), jnp.int32(0))
    return BFSState(level=level, pred=pred, visited=visited, front=front,
                    front_cnt=cnt, lvl=jnp.int32(1))


def _resolve_preds(pred, *, grid: Grid2D, j, col_axes):
    """Final deferred-predecessor exchange (paper sec. 3.5 / contribution [2]).

    One all_to_all of the pred array (viewed as C blocks of S) within each
    grid row delivers, for every owned vertex, the parent recorded by the
    processor-column that folded it."""
    C, S = grid.C, grid.S
    ca = col_axes if len(col_axes) > 1 else col_axes[0]
    pb = pred.reshape(C, S)
    recv = jax.lax.all_to_all(pb, ca, 0, 0).reshape(C, S)
    own = jnp.take(pb, j, axis=0)                     # (S,) my owned block
    deferred = own < -1
    sender = jnp.clip(-own - 2, 0, C - 1)
    from_sender = jnp.take_along_axis(recv, sender[None, :], axis=0)[0]
    return jnp.where(deferred, from_sender, own)


def _owned_level(level, *, grid: Grid2D, j):
    return jax.lax.dynamic_slice_in_dim(level, j * grid.S, grid.S)


# ----------------------------------------------------------------------------
# Public drivers
# ----------------------------------------------------------------------------

class BFS2D:
    """Distributed 2D BFS bound to a mesh.

    Arrays for the graph carry leading (R, C) device axes (as produced by
    `partition_2d`); results come back as global (n,) arrays laid out in
    vertex-block order (b = j*R + i), i.e. plain global vertex ids.
    """

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, expand_fn=None,
                 fold_bitmap: bool = False, max_levels: int = 64,
                 dedup: str = "scatter"):
        self.grid = grid
        self.mesh = mesh
        self.row_axes = _axes(row_axes)
        self.col_axes = _axes(col_axes)
        self.edge_chunk = edge_chunk
        self.expand_fn = expand_fn
        self.fold_bitmap = fold_bitmap
        self.max_levels = max_levels
        self.dedup = dedup
        dev_spec = P(self.row_axes, self.col_axes)
        self._in_graph = LocalGraph2D(col_off=dev_spec, row_idx=dev_spec,
                                      nnz=dev_spec)
        # global outputs in vertex-block order: block b = j*R + i
        self._out_global = P((*self.col_axes, *self.row_axes))
        self._run = jax.jit(self._build_run())

    # -- whole-search program (lax.while_loop over levels; single lowering) --
    def _build_run(self):
        grid = self.grid
        row_axes, col_axes = self.row_axes, self.col_axes

        def device_fn(col_off, row_idx, nnz, root):
            col_off, row_idx = col_off[0, 0], row_idx[0, 0]
            graph = LocalGraph2D(col_off=col_off, row_idx=row_idx, nnz=nnz[0, 0])
            i = jax.lax.axis_index(row_axes if len(row_axes) > 1 else row_axes[0]).astype(jnp.int32)
            j = jax.lax.axis_index(col_axes if len(col_axes) > 1 else col_axes[0]).astype(jnp.int32)
            st = _init_state(root, grid=grid, i=i, j=j)

            def cond(carry):
                st, total, scanned = carry
                return (total > 0) & (st.lvl <= self.max_levels)

            def body(carry):
                st, _, scanned = carry
                st2, total, edges = _level_step(
                    graph, st, grid=grid, row_axes=row_axes,
                    col_axes=col_axes, edge_chunk=self.edge_chunk,
                    expand_fn=self.expand_fn, fold_bitmap=self.fold_bitmap,
                    dedup=self.dedup)
                return st2, total, scanned + edges

            init_total = jax.lax.psum(st.front_cnt, row_axes + col_axes)
            st, _, scanned = jax.lax.while_loop(
                cond, body, (st, init_total, jnp.int32(0)))

            pred = _resolve_preds(st.pred, grid=grid, j=j, col_axes=col_axes)
            level = _owned_level(st.level, grid=grid, j=j)
            return level[None, None], pred[None, None], st.lvl[None, None], scanned[None, None]

        dev = P(self.row_axes, self.col_axes)
        return jax.shard_map(
            device_fn, mesh=self.mesh,
            in_specs=(dev, dev, dev, P()),
            out_specs=(self._out_global, self._out_global, dev, dev),
            check_vma=False)

    def run(self, graph: LocalGraph2D, root) -> BFSOutput:
        level, pred, lvls, scanned = self._run(
            graph.col_off, graph.row_idx, graph.nnz, jnp.int32(root))
        return BFSOutput(level=level.reshape(-1), pred=pred.reshape(-1),
                         n_levels=lvls.max())
