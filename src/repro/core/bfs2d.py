"""Distributed BFS with 2D partitioning (paper Alg. 2) on the shared engine.

Mesh mapping (DESIGN.md sec. 5): the processor grid's ROWS span `row_axes`
(e.g. ("pod", "data")) and its COLUMNS span `col_axes` (e.g. ("model",)).
  expand (paper line 13)  = all_gather of frontiers along the row axes
                            (processors in the same grid column);
  fold   (paper line 17)  = all_to_all of discovered vertices along the col
                            axes (processors in the same grid row).
So one BFS level costs 2 x O(sqrt(P)) partner exchanges instead of the 1D
code's O(P) (paper sec. 2.2).

The level loop, init and deferred-predecessor resolution live in
`repro.dist.engine`; what goes on the fold wire is a pluggable codec
(`repro.dist.exchange`, DESIGN.md sec. 4): the paper's 32-bit local indices
("list", sec. 3.3), a 1-bit block bitmap ("bitmap"), or sorted 16-bit deltas
("delta", Romera & Froning 2017).
"""
from __future__ import annotations

from repro.core.types import Grid2D, LocalGraph2D, BFSOutput
from repro.dist.topology import Topology


class BFS2D:
    """DEPRECATED shim over the session API (repro.api).

    Equivalent to `DistGraph(...).session()` with `BFSConfig(...)`; kept so
    pre-session callers keep passing.  Arrays for the graph carry leading
    (R, C) device axes (as produced by `partition_2d`); results come back as
    global (n,) arrays laid out in vertex-block order (b = j*R + i), i.e.
    plain global vertex ids.

    fold_codec selects the fold wire format ("list" | "bitmap" | "delta");
    `fold_bitmap=True` is the deprecated legacy spelling of
    fold_codec="bitmap".
    """

    def __init__(self, grid: Grid2D, mesh, row_axes=("r",), col_axes=("c",),
                 edge_chunk: int = 8192, expand_fn=None,
                 fold_bitmap: bool = None, max_levels: int = 64,
                 dedup: str = "scatter", fold_codec=None):
        import warnings

        from repro.api.config import BFSConfig, resolve_fold_codec
        from repro.api.session import build_engine

        warnings.warn(
            "BFS2D is deprecated; use repro.api.DistGraph.from_edges(...)"
            ".session() instead", DeprecationWarning, stacklevel=2)
        fold_codec = resolve_fold_codec(fold_codec, fold_bitmap)
        self.config = BFSConfig(
            grid=grid, fold_codec=fold_codec, edge_chunk=edge_chunk,
            dedup=dedup, max_levels=max_levels, expand_fn=expand_fn,
            row_axes=tuple(row_axes), col_axes=tuple(col_axes))
        self.grid = grid
        self.mesh = mesh
        self.topology = Topology(grid, mesh, row_axes=row_axes,
                                 col_axes=col_axes)
        self.engine = build_engine(self.topology, self.config)
        self._run = self.engine._run   # (col_off, row_idx, nnz, root) -> outs
        self._compiled = {}            # aval-keyed AOT cache, shared across
                                       # every graph run through this shim

    def _session(self, graph: LocalGraph2D):
        from repro.api.session import DistGraph, GraphSession

        dg = DistGraph(self.topology, graph, config=self.config)
        dg._compiled = self._compiled  # executables are data-independent
        return GraphSession(dg, self.config, engine=self.engine)

    def run(self, graph: LocalGraph2D, root) -> BFSOutput:
        return self._session(graph).bfs(root)
