"""Device-local frontier expansion / update (paper sec. 3.4, 3.5).

Everything here is pure jnp with static shapes and is the REFERENCE path; the
fused Pallas pipeline in `repro.kernels.expand` implements the same contracts
for the hot tiles (`make_expand_fn` is the drop-in switch; engines select it
via `BFSConfig(expand=...)`, DESIGN.md sec. 9).

Adaptation notes (DESIGN.md sec. 3):
  * `atomicOr` visited dedup      -> scatter-min "winner" selection (the first
    edge slot to reach v wins, deterministically);
  * `atomicInc` bucket append     -> stable sort by destination column +
    per-segment positions (the paper's own pre-Kepler compact variant);
  * thread-per-edge scan+search   -> vectorised searchsorted over the
    exclusive-scanned degree array, processed in fixed-size chunks inside a
    `lax.while_loop` so per-level work stays O(frontier edges + chunk).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Grid2D

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def exclusive_cumsum(x):
    """Thrust exclusive_scan equivalent, returns len(x)+1 (with total)."""
    c = jnp.cumsum(x, dtype=jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), c])


def compact_blocks(vals, cnts, fill=-1, ops=None):
    """Concatenate R padded blocks (R, S) with per-block counts into one
    padded (R*S,) array (valid entries first, order preserved).

    ops: optional fold-kernel bundle (`repro.kernels.fold`) whose prefix-sum
    compaction replaces the argsort; None = the reference path.  Both are
    bit-identical (the output is fully determined by the mask)."""
    R, S = vals.shape
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] < cnts[:, None]
    total = jnp.sum(cnts, dtype=jnp.int32)
    if ops is not None:
        (out,), _ = ops.compact_rows(mask.reshape(1, -1),
                                     (vals.reshape(1, -1),), (fill,))
        return out[0], total
    flat_v = vals.reshape(-1)
    flat_m = mask.reshape(-1)
    order = jnp.argsort(~flat_m, stable=True)
    out = jnp.where(flat_m[order], flat_v[order], fill)
    return out, total


def winner_dedup(v, eligible, n_rows: int, method: str = "scatter"):
    """First-occurrence selection among eligible entries with equal v.

    Emulates the paper's `atomicOr` first-thread-wins semantics
    deterministically.  Two implementations:
      * "scatter" (default): scatter-min of slot ids into an (n_rows,) claim
        array -- the smallest slot claiming v wins.  O(chunk) scatters but
        touches an n_rows-sized temp every chunk.
      * "sort": sort by v, keep the first of each equal run -- O(chunk log
        chunk) with NO n_rows-sized temp (the memory-roofline win for large
        local partitions; winner = lowest v-then-slot, still deterministic
        and a valid first-claimant).
    Returns a bool mask of winners (subset of `eligible`).
    """
    slots = jnp.arange(v.shape[0], dtype=jnp.int32)
    if method == "sort":
        key = jnp.where(eligible, v, I32_MAX)
        order = jnp.argsort(key, stable=True)
        ks = key[order]
        first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
        first = first & (ks < I32_MAX)
        win = jnp.zeros_like(eligible).at[order].set(first)
        return win & eligible
    claim = jnp.full((n_rows,), I32_MAX, jnp.int32)
    claim = claim.at[jnp.where(eligible, v, n_rows)].min(
        jnp.where(eligible, slots, I32_MAX), mode="drop")
    return eligible & (claim[jnp.clip(v, 0, n_rows - 1)] == slots)


def bucket_append(dst, dst_cnt, v, tgt, take, n_buckets: int):
    """Append v[take] into per-target buckets (paper Alg. 3 lines 9-14).

    dst: (n_buckets, cap) padded -1; dst_cnt: (n_buckets,).
    Sort-based: stable sort by target, per-segment positions, scatter at
    dst_cnt[tgt] + position.  Entries overflowing `cap` are dropped -- callers
    size cap = S so overflow is impossible (<= S distinct owned vertices per
    target per search).
    """
    cap = dst.shape[1]
    key = jnp.where(take, tgt, n_buckets).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    ks, vs = key[order], v[order]
    seg_start = jnp.searchsorted(ks, jnp.arange(n_buckets + 1, dtype=jnp.int32))
    pos = jnp.arange(ks.shape[0], dtype=jnp.int32) - seg_start[jnp.clip(ks, 0, n_buckets)]
    ok = ks < n_buckets
    row = jnp.where(ok, ks, 0)
    col = dst_cnt[row] + pos
    ok = ok & (col < cap)
    dst = dst.at[jnp.where(ok, row, n_buckets), jnp.clip(col, 0, cap - 1)].set(
        jnp.where(ok, vs, -1), mode="drop")
    add = jnp.diff(seg_start)[:n_buckets]
    return dst, dst_cnt + jnp.minimum(add, cap - dst_cnt)


def append_padded(buf, cnt, vals, valid):
    """Append vals[valid] to a padded (cap,) buffer at position cnt."""
    b, c = bucket_append(buf[None, :], cnt[None], vals,
                         jnp.zeros_like(vals), valid, 1)
    return b[0], c[0]


def pack_bitmap(mask):
    """(..., S) bool -> (..., ceil(S/32)) uint32 little-endian bit packing."""
    S = mask.shape[-1]
    W = (S + 31) // 32
    pad = W * 32 - S
    if pad:
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), bool)], axis=-1)
    m = mask.reshape(mask.shape[:-1] + (W, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(m * weights, axis=-1, dtype=jnp.uint32)


def unpack_bitmap(words, S: int):
    """(..., W) uint32 -> (..., S) bool."""
    bits = (words[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,))[..., :S].astype(bool)


def reference_expand_chunk(gids, cumul, all_front, front_total, col_off,
                           row_idx):
    """One chunk of the paper's column scan in plain jnp -- THE reference
    map/gather formulas, single source of truth.  Shared by
    `expand_frontier`'s inline path, `repro.algos.program.scan_relax` and
    `repro.kernels.expand.local_expand(path="reference")`; the fused Pallas
    kernel mirrors these formulas lane for lane (the bit-identity contract,
    DESIGN.md sec. 9) -- edit them HERE or the paths diverge.

    Returns (v, u, k, addr, valid): candidate local rows (masked lanes
    -> 0), parent frontier cols, frontier slot index, clipped CSC edge
    address, live-lane mask.
    """
    ncl = all_front.shape[0]
    nnz_cap = row_idx.shape[0]
    k = jnp.searchsorted(cumul, gids, side="right").astype(jnp.int32) - 1
    k = jnp.clip(k, 0, ncl - 1)
    u = jnp.clip(all_front, 0, ncl - 1)[k]
    addr = jnp.clip(col_off[u] + gids - cumul[k], 0, nnz_cap - 1)
    valid = gids < cumul[front_total]
    v = jnp.where(valid, row_idx[addr], 0)
    return v, u, k, addr, valid


def test_bit_blocks(words, c, block: int):
    """Test bit `c` of a row-gathered blocked bitmap.

    words: (R * W,) uint32, R per-device blocks of W = ceil(block/32) words
    each, every block packing `block` bits (`pack_bitmap` of one owned
    frontier mask).  Blocked addressing -- NOT a flat n-bit bitmap -- so the
    layout stays exact when block % 32 != 0 (each device's pad bits are
    zero, never aliased by a neighbour's first word).
    """
    W = (block + 31) // 32
    blk, off = c // block, c % block
    w = words[blk * W + (off >> 5)]
    return ((w >> (off & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0


def reference_bottomup_chunk(gids, cumul, total, row_off, col_idx, words, *,
                             block: int):
    """One chunk of the bottom-up parent search in plain jnp -- THE
    reference formulas, single source of truth (the CSR mirror of
    `reference_expand_chunk`).  Shared by the bottom-up step's inline path
    and `repro.kernels.bottomup`; the fused Pallas kernel mirrors these
    formulas lane for lane (the bit-identity contract, DESIGN.md sec. 11)
    -- edit them HERE or the paths diverge.

    gids index the masked-degree workload: `cumul` is the exclusive cumsum
    of per-row degrees with VISITED rows zeroed, so the scan walks only
    unvisited rows' edges; `total = cumul[-1]` is the level's edge count.
    words: row-gathered frontier bitmap, blocked layout (`test_bit_blocks`).

    Returns (r, c, hit): candidate local row, its neighbour's local col
    (masked lanes -> 0), and whether that neighbour is in the frontier.
    """
    nrl = cumul.shape[0] - 1
    nnz_cap = col_idx.shape[0]
    r = jnp.searchsorted(cumul, gids, side="right").astype(jnp.int32) - 1
    r = jnp.clip(r, 0, nrl - 1)
    addr = jnp.clip(row_off[r] + gids - cumul[r], 0, nnz_cap - 1)
    valid = gids < total
    c = jnp.where(valid, col_idx[addr], 0)
    hit = valid & test_bit_blocks(words, c, block)
    return r, c, hit


def reference_bottomup_values_chunk(gids, cumul, total, row_off, col_idx,
                                    words, dense_pay, *, block: int):
    """`reference_bottomup_chunk` with an aligned payload gather (value
    programs pull the sender's label/distance from a dense per-col channel).

    Returns (r, pay, addr, hit) -- addr is the clipped CSR edge address so
    callers can gather per-edge weights (SSSP)."""
    r, c, hit = reference_bottomup_chunk(
        gids, cumul, total, row_off, col_idx, words, block=block)
    nnz_cap = col_idx.shape[0]
    addr = jnp.clip(row_off[r] + gids - cumul[r], 0, nnz_cap - 1)
    pay = dense_pay[c]
    return r, pay, addr, hit


def set_bits(words, v, take):
    """Set bit v[take] in the packed uint32 bitmap (the incremental twin of
    `pack_bitmap`): callers guarantee the taken v are DISTINCT and their
    bits currently unset (winner_dedup output on unvisited candidates), so
    a scatter-add of single-bit values is an exact atomicOr."""
    nw = words.shape[0]
    bit = jnp.uint32(1) << (v & 31).astype(jnp.uint32)
    return words.at[jnp.where(take, v >> 5, nw)].add(
        jnp.where(take, bit, jnp.uint32(0)), mode="drop")


class ExpandResult(NamedTuple):
    visited: jax.Array
    level: jax.Array
    pred: jax.Array
    dst: jax.Array        # (C, S) local-row ids grouped by owner column
    dst_cnt: jax.Array    # (C,)
    edges_scanned: jax.Array  # uint32 -- callers accumulate across levels
                              # with engine.wide_add (int32 wraps at scale 26)


def expand_frontier(col_off, row_idx, visited, level, pred, all_front,
                    front_total, lvl, *, grid: Grid2D, i, j,
                    edge_chunk: int = 8192, expand_fn=None,
                    dedup: str = "scatter") -> ExpandResult:
    """Scan the CSC columns of the gathered frontier (paper Alg. 3).

    all_front: (n_cols_local,) local col indices (valid first `front_total`).
    i, j: this device's grid coordinates (traced or static).
    expand_fn: optional kernel override mapping
        (gids, cumul, all_front, front_total, col_off, row_idx, visited)
        -> (v, unvisited_mask, u) for one chunk (the Pallas path).  A
        closure carrying `accepts_words = True` additionally receives
        `words=` -- the packed visited bitmap this loop then maintains
        INCREMENTALLY (one O(n_rows) pack per level instead of per chunk).
    """
    n_rows = visited.shape[0]
    S, C = grid.S, grid.C
    ncl = grid.n_cols_local

    u_safe = jnp.clip(all_front, 0, ncl - 1)
    deg = (col_off[u_safe + 1] - col_off[u_safe])
    deg = jnp.where(jnp.arange(ncl) < front_total, deg, 0)
    cumul = exclusive_cumsum(deg)                      # (ncl + 1,)
    total = cumul[front_total]

    dst = jnp.full((C, S), -1, jnp.int32)
    dst_cnt = jnp.zeros((C,), jnp.int32)
    use_words = bool(getattr(expand_fn, "accepts_words", False))
    words = pack_bitmap(visited) if use_words \
        else jnp.zeros((1,), jnp.uint32)               # pytree placeholder

    def chunk_body(state):
        start, visited, words, level, pred, dst, dst_cnt = state
        gids = start + jnp.arange(edge_chunk, dtype=jnp.int32)
        if expand_fn is None:
            v, u, _, _, valid = reference_expand_chunk(
                gids, cumul, all_front, front_total, col_off, row_idx)
            unvis = valid & ~visited[v]
        elif use_words:
            v, unvis, u = expand_fn(gids, cumul, all_front, front_total,
                                    col_off, row_idx, visited, words=words)
        else:
            v, unvis, u = expand_fn(gids, cumul, all_front, front_total,
                                    col_off, row_idx, visited)
        win = winner_dedup(v, unvis, n_rows, method=dedup)
        # mark visited (paper: atomicOr on the full-local-row bitmap -- this
        # is what makes every remote vertex fold at most once per search)
        visited = visited.at[jnp.where(win, v, n_rows)].set(True, mode="drop")
        if use_words:
            words = set_bits(words, v, win)
        # predecessor: global parent id, stored also for remote rows
        # (deferred resolution, paper sec. 3.5 / [2])
        pg = (j * ncl + u).astype(jnp.int32)
        pred = pred.at[jnp.where(win, v, n_rows)].set(
            jnp.where(win, pg, 0), mode="drop")
        # local rows get their level here (Alg. 3 line 15)
        m = v // S
        is_local = win & (m == j)
        level = level.at[jnp.where(is_local, v, n_rows)].set(
            jnp.where(is_local, lvl, 0), mode="drop")
        dst, dst_cnt = bucket_append(dst, dst_cnt, v, m, win, C)
        return start + edge_chunk, visited, words, level, pred, dst, dst_cnt

    def chunk_cond(state):
        return state[0] < total

    init = (jnp.int32(0), visited, words, level, pred, dst, dst_cnt)
    _, visited, _, level, pred, dst, dst_cnt = jax.lax.while_loop(
        chunk_cond, chunk_body, init)
    # per-level count reported unsigned: one level's local scan is bounded by
    # the int32-indexable local nnz, but the SUM across levels/devices is not
    return ExpandResult(visited, level, pred, dst, dst_cnt,
                        total.astype(jnp.uint32))


class UpdateResult(NamedTuple):
    visited: jax.Array
    level: jax.Array
    pred: jax.Array
    new_front: jax.Array   # (S,) local col ids of newly frontier vertices
    new_cnt: jax.Array


def update_frontier(int_verts, int_cnt, visited, level, pred, lvl, *,
                    grid: Grid2D, i, j) -> UpdateResult:
    """Process fold-received vertices (paper sec. 3.5).

    int_verts: (C, S) local-row ids received from each processor-column
    (sender m in slot m).  Received vertices are OWNED here; unvisited ones
    get level/visited set, pred <- -(sender_col + 2) (deferred), and are
    appended to the next frontier as local COL indices.
    """
    n_rows = visited.shape[0]
    C, S = int_verts.shape
    sender = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[:, None], (C, S))
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] < int_cnt[:, None]
    v = jnp.where(mask, int_verts, 0).reshape(-1)
    snd = sender.reshape(-1)
    eligible = mask.reshape(-1) & ~visited[v]
    win = winner_dedup(v, eligible, n_rows)
    visited = visited.at[jnp.where(win, v, n_rows)].set(True, mode="drop")
    level = level.at[jnp.where(win, v, n_rows)].set(
        jnp.where(win, lvl, 0), mode="drop")
    pred = pred.at[jnp.where(win, v, n_rows)].set(
        jnp.where(win, -(snd + 2), 0), mode="drop")
    # new frontier = winners, converted row -> col index (ROW2COL)
    lc = (i * S + (v - j * S)).astype(jnp.int32)
    nf = jnp.full((C * S,), -1, jnp.int32)
    nf_cnt0 = jnp.zeros((1,), jnp.int32)
    nf, cnt = bucket_append(nf[None, :], nf_cnt0, lc, jnp.zeros_like(lc), win, 1)
    return UpdateResult(visited, level, pred, nf[0, :S], cnt[0])
