"""Single-device BFS.

`bfs_reference_py` is the absolute ground truth (python deque) used by tests.
`bfs_single` is the paper's local algorithm on one device, in JAX: level-
synchronous frontier expansion over a CSC with the scan + search thread->edge
mapping (sec. 3.4), deterministic scatter-min in place of atomics.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


def bfs_reference_py(col_off, row_idx, root: int, n: int):
    """Plain python BFS; returns (level, pred) int32 numpy arrays."""
    col_off = np.asarray(col_off)
    row_idx = np.asarray(row_idx)
    level = np.full(n, -1, np.int32)
    pred = np.full(n, -1, np.int32)
    level[root] = 0
    pred[root] = root
    q = deque([root])
    while q:
        u = q.popleft()
        for e in range(col_off[u], col_off[u + 1]):
            v = row_idx[e]
            if level[v] < 0:
                level[v] = level[u] + 1
                pred[v] = u
                q.append(v)
    return level, pred


def _expand_level(col_off, row_idx, visited, front_mask):
    """One level of dense (bitmap) expansion: returns newly-reached mask and a
    parent suggestion per vertex (min edge origin, deterministic)."""
    n = visited.shape[0]
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), jnp.diff(col_off),
                     total_repeat_length=row_idx.shape[0])
    active = front_mask[src] & (row_idx >= 0)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    cand = jnp.full((n,), big, jnp.int32)
    cand = cand.at[jnp.where(active, row_idx, n)].min(
        jnp.where(active, src, big), mode="drop")
    new = (cand < big) & ~visited
    return new, cand


@jax.jit
def bfs_single(col_off, row_idx, root):
    """Level-synchronous BFS on one device.  Returns (level, pred)."""
    n = col_off.shape[0] - 1
    level = jnp.full((n,), -1, jnp.int32).at[root].set(0)
    pred = jnp.full((n,), -1, jnp.int32).at[root].set(root)
    visited = jnp.zeros((n,), bool).at[root].set(True)
    front = jnp.zeros((n,), bool).at[root].set(True)

    def cond(s):
        return s[3].any()

    def body(s):
        level, pred, visited, front, lvl = s
        new, cand = _expand_level(col_off, row_idx, visited, front)
        level = jnp.where(new, lvl, level)
        pred = jnp.where(new, cand, pred)
        visited = visited | new
        return level, pred, visited, new, lvl + 1

    level, pred, *_ = jax.lax.while_loop(
        cond, body, (level, pred, visited, front, jnp.int32(1)))
    return level, pred
