from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput
from repro.core.partition import (
    partition_2d, partition_1d, local_row, local_col, row2col, owner_of,
    global_from_row,
)
from repro.core.bfs_single import bfs_reference_py, bfs_single
from repro.core.validate import validate_bfs, count_component_edges, teps
