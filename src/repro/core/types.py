"""Core datatypes for the 2D-partitioned BFS (paper sec. 2.2 / 3.1).

Conventions (matching the paper / Fig. 1):
  * adjacency A is N x N; an edge u -> v is the non-zero A[v, u], i.e. column
    u of A is u's adjacency list;
  * the processor grid is R rows x C cols; processor P_ij handles the edge
    blocks (m*R + i, j), m = 0..C-1, each of size S x (N/C), S = N/(R*C);
  * vertex block b = j*R + i (size S) is OWNED by P_ij;
  * every P_ij stores an (N/R) x (N/C) local matrix in CSC.

Local index maps (paper sec. 3.1; derivations in DESIGN.md):
  LOCAL_ROW(g) = (g // S // R) * S + g % S      -- same for every processor in
                                                   the owner's processor-row
  LOCAL_COL(g) = g % (N/C)                      -- same for every processor in
                                                   the owner's processor-column
  ROW2COL(lr)  = i*S + (lr - j*S)               -- owner-local row -> col
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# Sentinels (paper initialises level/pred to -1).
NOT_VISITED = jnp.int32(-1)
INVALID = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class Grid2D:
    """Static description of the processor grid and padded vertex space."""
    R: int          # processor-grid rows
    C: int          # processor-grid cols
    n: int          # padded global vertex count; divisible by R*C

    def __post_init__(self):
        if self.n % (self.R * self.C) != 0:
            raise ValueError(f"n={self.n} not divisible by R*C={self.R * self.C}")

    @property
    def P(self) -> int:
        return self.R * self.C

    @property
    def S(self) -> int:
        """Vertex-block size N/(RC) (owned vertices per processor)."""
        return self.n // (self.R * self.C)

    @property
    def n_rows_local(self) -> int:
        return self.n // self.R

    @property
    def n_cols_local(self) -> int:
        return self.n // self.C

    @staticmethod
    def for_vertices(n_raw: int, R: int, C: int) -> "Grid2D":
        """Pad the vertex space up to a multiple of R*C (isolated vertices)."""
        rc = R * C
        return Grid2D(R, C, ((n_raw + rc - 1) // rc) * rc)


def _dc(cls):
    """Register a dataclass as a pytree (arrays = leaves, ints = static)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    meta = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]
    data = [f for f in fields if f not in meta]
    jax.tree_util.register_dataclass(cls, data_fields=data, meta_fields=meta)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@_dc
@dataclasses.dataclass
class LocalGraph2D:
    """Per-device local CSC block of the 2D-partitioned adjacency matrix.

    When used host-side (building), arrays carry a leading (R, C) axis; inside
    shard_map each device sees its own block.  row indices are LOCAL rows
    (int32), columns are LOCAL cols -- 32-bit on the wire as in the paper.
    """
    col_off: jax.Array   # (..., n_cols_local + 1) int32
    row_idx: jax.Array   # (..., e_max) int32, padded with -1
    nnz: jax.Array       # (...,) int32 valid entries of row_idx


@_dc
@dataclasses.dataclass
class BFSState:
    """Per-device BFS state (paper Alg. 2 requires).

    level/pred/visited span ALL local rows (n/R): the bitmap covering
    remotely-owned rows is what guarantees each remote vertex is folded at
    most once per search (paper sec. 3.4).
    """
    level: jax.Array      # (..., n_rows_local) int32, -1 = unvisited
    pred: jax.Array       # (..., n_rows_local) int32 global parent id;
                          #   -(col+2) = deferred (fold sender column); -1 = none
    visited: jax.Array    # (..., n_rows_local) bool
    front: jax.Array      # (..., S) int32 local col indices, padded -1
    front_cnt: jax.Array  # (...,) int32
    lvl: jax.Array        # (...,) int32 current level


@_dc
@dataclasses.dataclass
class BFSOutput:
    """Global (gathered) BFS result."""
    level: jax.Array   # (n,) int32
    pred: jax.Array    # (n,) int32, global parent ids
    n_levels: jax.Array
    edges_scanned: Any = None  # exact Python int (64-bit safe), or None
                               # when the producer does not account edges
    directions: Any = None     # (n_levels_cap,) int32 per-level direction
                               # trace (-1 unused / 0 top-down / 1 bottom-up)
                               # when direction optimisation ran, else None
    trace: Any = None          # repro.obs.LevelTrace when telemetry ran
                               # (scalar: one LevelTrace; batched: tuple of
                               # B), else None
