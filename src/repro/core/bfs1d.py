"""The paper's 1D comparison baseline as the DEGENERATE 1 x P grid.

The original 1D code ([1]/[2]) has the two scalability limits the 2D code
removes (paper sec. 2.1): every level is an all-to-all among ALL P
processors (O(P) partner exchanges vs the 2D code's 2 x O(sqrt P)), and
duplicate filtering needs a full-size map (O(n) per device).  Both fall out
of the shared engine at the degenerate 1 x P topology with no separate
driver code: the expand all_gather spans a single processor (identity), the
fold all_to_all spans all P, and the local row space -- hence the visited
bitmap -- is the whole vertex set.

Differences from the seed's hand-rolled 1D driver: vertices are laid out in
owner blocks (`partition_2d` on the 1 x P grid, block j = vertices
[j*S, (j+1)*S)) rather than by the modulo rule, and parents are resolved by
the engine's deferred exchange rather than travelling inline as (u, v)
pairs.  Neither changes the communication structure the 1D-vs-2D comparison
measures (benchmarks/bfs_1d_vs_2d.py): per level the fold still exchanges
O(P) messages of 4*S+4 bytes and the final pred resolution is one more
all-to-all, while the O(n) per-device map cost is unchanged.
"""
from __future__ import annotations

from repro.core.types import LocalGraph2D, BFSOutput
from repro.dist.topology import Topology


class BFS1D:
    """DEPRECATED shim: the 1 x P degenerate grid through the session API.

    Partition the edge list with `partition_2d(edges, bfs.grid)` (the 1 x P
    grid pads n up to a multiple of P); results come back as plain global
    (n,) arrays.  New code should build a `BFSConfig(grid=(1, P),
    row_axes=(), col_axes=axes)` session instead.
    """

    def __init__(self, n: int, mesh, axes=("p",), edge_chunk: int = 8192,
                 max_levels: int = 64, fold_codec="list"):
        import warnings

        from repro.api.config import BFSConfig
        from repro.api.session import build_engine

        warnings.warn(
            "BFS1D is deprecated; use repro.api.DistGraph/GraphSession with "
            "BFSConfig(grid=(1, P), row_axes=(), col_axes=axes)",
            DeprecationWarning, stacklevel=2)
        self.n = n
        self.mesh = mesh
        self.topology = Topology.one_d(n, mesh, axes)
        self.grid = self.topology.grid
        self.P = self.grid.C
        self.ncl = self.grid.n_cols_local
        self.config = BFSConfig(
            grid=self.grid, fold_codec=fold_codec, edge_chunk=edge_chunk,
            max_levels=max_levels, row_axes=self.topology.row_axes,
            col_axes=self.topology.col_axes)
        self.engine = build_engine(self.topology, self.config)
        self._run = self.engine._run
        self._compiled = {}            # aval-keyed AOT cache, shared across
                                       # every graph run through this shim

    def _session(self, graph: LocalGraph2D):
        from repro.api.session import DistGraph, GraphSession

        dg = DistGraph(self.topology, graph, config=self.config)
        dg._compiled = self._compiled  # executables are data-independent
        return GraphSession(dg, self.config, engine=self.engine)

    def run(self, graph: LocalGraph2D, root) -> BFSOutput:
        return self._session(graph).bfs(root)
