"""The paper's ORIGINAL 1D code ([1]/[2]) as the comparison baseline.

Vertices are assigned by the modulo rule (g -> processor g % P); every BFS
level requires an all-to-all among ALL P processors (O(P) exchanges vs the 2D
code's 2 x O(sqrt P)), and sender-side duplicate filtering needs a full-size
integer map (n bits per device) -- the two scalability limits the 2D code
removes (paper sec. 2.1).  Predecessors travel inline (u, v), as in [1].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import frontier as F
from repro.core.types import BFSOutput


class BFS1D:
    def __init__(self, n: int, mesh, axes=("p",), edge_chunk: int = 8192,
                 max_levels: int = 64):
        self.n = n
        self.mesh = mesh
        self.axes = tuple(axes) if isinstance(axes, (tuple, list)) else (axes,)
        self.P = 1
        for a in self.axes:
            self.P *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if n % self.P:
            raise ValueError("pad n to a multiple of P")
        self.ncl = n // self.P
        self.edge_chunk = edge_chunk
        self.max_levels = max_levels
        self._run = jax.jit(self._build())

    def _build(self):
        n, Pn, ncl, axes = self.n, self.P, self.ncl, self.axes
        ax = axes if len(axes) > 1 else axes[0]
        chunk = self.edge_chunk

        def device_fn(col_off, row_idx, root):
            col_off, row_idx = col_off[0], row_idx[0]
            p = jax.lax.axis_index(ax).astype(jnp.int32)
            e_cap = row_idx.shape[0]

            mine = (root % Pn) == p
            level = jnp.full((ncl,), -1, jnp.int32)
            pred = jnp.full((ncl,), -1, jnp.int32)
            sent = jnp.zeros((n,), bool)         # the O(n) integer map of [1]
            front = jnp.full((ncl,), -1, jnp.int32)
            lc0 = root // Pn
            level = jnp.where(mine, level.at[lc0].set(0), level)
            pred = jnp.where(mine, pred.at[lc0].set(root), pred)
            front = jnp.where(mine, front.at[0].set(lc0), front)
            cnt = jnp.where(mine, 1, 0).astype(jnp.int32)

            def level_step(state):
                level, pred, sent, front, cnt, lvl, _, scanned = state
                u_safe = jnp.clip(front, 0, ncl - 1)
                deg = col_off[u_safe + 1] - col_off[u_safe]
                deg = jnp.where(jnp.arange(ncl) < cnt, deg, 0)
                cumul = F.exclusive_cumsum(deg)
                total = cumul[cnt]

                dst_v = jnp.full((Pn, ncl), -1, jnp.int32)
                dst_u = jnp.full((Pn, ncl), -1, jnp.int32)
                dst_cnt = jnp.zeros((Pn,), jnp.int32)

                def body(s):
                    start, sent, dst_v, dst_u, dst_cnt = s
                    gids = start + jnp.arange(chunk, dtype=jnp.int32)
                    k = jnp.clip(jnp.searchsorted(cumul, gids, side="right")
                                 .astype(jnp.int32) - 1, 0, ncl - 1)
                    u = u_safe[k]
                    addr = col_off[u] + gids - cumul[k]
                    valid = gids < total
                    v = jnp.where(valid, row_idx[jnp.clip(addr, 0, e_cap - 1)], 0)
                    new = valid & ~sent[v]
                    win = F.winner_dedup(v, new, n)
                    sent = sent.at[jnp.where(win, v, n)].set(True, mode="drop")
                    ug = (u * Pn + p).astype(jnp.int32)   # global source id
                    tgt = v % Pn
                    dst_v, dc2 = F.bucket_append(dst_v, dst_cnt, v, tgt, win, Pn)
                    dst_u, _ = F.bucket_append(dst_u, dst_cnt, ug, tgt, win, Pn)
                    return start + chunk, sent, dst_v, dst_u, dc2

                _, sent, dst_v, dst_u, dst_cnt = jax.lax.while_loop(
                    lambda s: s[0] < total, body,
                    (jnp.int32(0), sent, dst_v, dst_u, dst_cnt))

                rv = jax.lax.all_to_all(dst_v, ax, 0, 0).reshape(Pn, ncl)
                ru = jax.lax.all_to_all(dst_u, ax, 0, 0).reshape(Pn, ncl)
                rc = jax.lax.all_to_all(dst_cnt, ax, 0, 0).reshape(Pn)

                mask = jnp.arange(ncl)[None, :] < rc[:, None]
                v = jnp.where(mask, rv, 0).reshape(-1)
                u = ru.reshape(-1)
                lc = v // Pn
                elig = mask.reshape(-1) & (level[lc] < 0)
                win = F.winner_dedup(lc, elig, ncl)
                level = level.at[jnp.where(win, lc, ncl)].set(
                    jnp.where(win, lvl, 0), mode="drop")
                pred = pred.at[jnp.where(win, lc, ncl)].set(
                    jnp.where(win, u, 0), mode="drop")
                nf, nc = jnp.full((ncl,), -1, jnp.int32), jnp.int32(0)
                b, c = F.bucket_append(nf[None], nc[None], lc,
                                       jnp.zeros_like(lc), win, 1)
                nf, nc = b[0], c[0]
                tot = jax.lax.psum(nc, axes)
                return (level, pred, sent, nf, nc, lvl + 1, tot,
                        scanned + total)

            init_tot = jax.lax.psum(cnt, axes)
            state = (level, pred, sent, front, cnt, jnp.int32(1), init_tot,
                     jnp.int32(0))
            state = jax.lax.while_loop(
                lambda s: (s[6] > 0) & (s[5] <= self.max_levels),
                level_step, state)
            level, pred = state[0], state[1]
            lvl, scanned = state[5], state[7]
            # output in owner-interleaved order: vertex g at (g%P, g//P)
            return level[None], pred[None], lvl[None], scanned[None]

        spec = P(self.axes)
        return jax.shard_map(
            device_fn, mesh=self.mesh,
            in_specs=(spec, spec, P()),
            out_specs=(spec, spec, spec, spec), check_vma=False)

    def run(self, col_off, row_idx, root) -> BFSOutput:
        level, pred, lvls, _ = self._run(col_off, row_idx, jnp.int32(root))
        # de-interleave: device-major blocks -> global ids g = p + P*k
        level = level.reshape(self.P, self.ncl).T.reshape(-1)
        # ^ level comes back as (P*ncl,) device-major; entry (p, k) is vertex
        #   k*P + p, so transpose restores global order.
        pred = pred.reshape(self.P, self.ncl).T.reshape(-1)
        return BFSOutput(level=jnp.asarray(level), pred=jnp.asarray(pred),
                         n_levels=lvls.max())
