"""In-program per-level traces (DESIGN.md sec. 13).

The paper's whole result is per-level numbers -- frontier sizes, exchanged
bytes, per-phase work.  `LevelTrace` makes those numbers a PRODUCT of the
production path instead of a bench-worker re-derivation: when a session's
`BFSConfig(telemetry=True)`, the `FrontierEngine` threads the per-level
carry built here through its `lax.while_loop` and appends the arrays to the
device outputs, and `assemble_traces` turns the gathered result into one
host `LevelTrace` per search.

Per level, per device, the carry records:

  frontier    global frontier count ENTERING the level (psum-replicated,
              the same total the direction heuristic consumes)
  front_dev   this device's own frontier count entering the level
  scanned     edges scanned this level on this device (the expand stamp)
  folded      entries this device folded to owners (the fold stamp)
  wire        fold wire bytes this device sent (the exchange stamp): the
              exchange strategy's scaling of the codec's static
              `wire_bytes(grid)` for set folds, plus the count-proportional
              value-channel bytes for value folds -- on the flat route this
              is exactly the PR 5 `wire_bytes_values_sent` accounting
  msgs        point-to-point fold messages this device sent (the exchange
              strategy's `msgs_per_exchange`: C-1 flat, log2(C) butterfly)
  dir         direction the level ran (0 top-down / 1 bottom-up)

The stamps are work counters, not wall times: inside one compiled program
there is no host clock, and counters are what the paper's Fig. 5/6 plot
anyway; wall-clock spans live at the serve layer (`repro.obs.spans`).
Telemetry is OFF by default and keyed into every engine/AOT cache -- the
off path compiles to exactly the untraced program, and the traced outputs
are bit-identical to it (pure extra reductions, asserted in CI).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Channel order of the trace arrays the engine appends after (hi, lo);
# plus one trailing per-device level counter `k`.
TRACE_CHANNELS = ("frontier", "front_dev", "scanned", "folded", "wire",
                  "msgs", "dir")
N_TRACE_OUTS = len(TRACE_CHANNELS) + 1


# ----------------------------------------------------------------------------
# Device side: the while_loop carry (jnp imported lazily to keep this module
# importable by host-only tooling)
# ----------------------------------------------------------------------------

def init_trace(max_levels: int) -> dict:
    """Fresh per-search trace carry (one per device, inside shard_map)."""
    import jax.numpy as jnp
    L = int(max_levels)
    return {
        "frontier": jnp.zeros((L,), jnp.int32),
        "front_dev": jnp.zeros((L,), jnp.int32),
        "scanned": jnp.zeros((L,), jnp.uint32),
        "folded": jnp.zeros((L,), jnp.int32),
        "wire": jnp.zeros((L,), jnp.uint32),
        "msgs": jnp.zeros((L,), jnp.int32),
        "dir": jnp.full((L,), -1, jnp.int32),
        "k": jnp.int32(0),
    }


def normalize_aux(aux: "dict | None") -> dict:
    """Fill the optional step-aux channel (legacy 3-tuple steps -> zeros)."""
    import jax.numpy as jnp
    aux = aux or {}
    return {
        "folded": jnp.asarray(aux.get("folded", 0), jnp.int32),
        "wire": jnp.asarray(aux.get("wire", 0), jnp.uint32),
        "msgs": jnp.asarray(aux.get("msgs", 0), jnp.int32),
        "dir": jnp.asarray(aux.get("dir", 0), jnp.int32),
    }


def record_level(tr: dict, *, frontier, front_dev, scanned, aux) -> dict:
    """Record one level at slot min(k, L-1); returns the advanced carry."""
    import jax.numpy as jnp
    L = tr["dir"].shape[0]
    k = jnp.minimum(tr["k"], L - 1)
    return {
        "frontier": tr["frontier"].at[k].set(
            jnp.asarray(frontier, jnp.int32)),
        "front_dev": tr["front_dev"].at[k].set(
            jnp.asarray(front_dev, jnp.int32)),
        "scanned": tr["scanned"].at[k].set(
            jnp.asarray(scanned, jnp.uint32)),
        "folded": tr["folded"].at[k].set(aux["folded"]),
        "wire": tr["wire"].at[k].set(aux["wire"]),
        "msgs": tr["msgs"].at[k].set(aux["msgs"]),
        "dir": tr["dir"].at[k].set(aux["dir"]),
        "k": tr["k"] + 1,
    }


def trace_outputs(tr: dict) -> tuple:
    """The carry as the engine's extra device outputs (fixed order)."""
    return tuple(tr[c] for c in TRACE_CHANNELS) + (tr["k"],)


# ----------------------------------------------------------------------------
# Host side
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class LevelTrace:
    """One search's per-level telemetry, global + per-device.

    Arrays are truncated to the levels actually run; `*_dev` arrays carry a
    leading P = R*C device axis in vertex-block device order.
    """
    program: str
    codec: str
    grid: tuple                 # (R, C)
    n_levels: int
    frontier: np.ndarray        # (n_levels,) int64 global frontier entering
    frontier_dev: np.ndarray    # (P, n_levels) int64 per-device frontier
    scanned: np.ndarray         # (n_levels,) int64 global edges scanned
    scanned_dev: np.ndarray
    folded: np.ndarray          # (n_levels,) int64 global folded entries
    folded_dev: np.ndarray
    wire_bytes: np.ndarray      # (n_levels,) int64 global fold wire bytes
    wire_dev: np.ndarray
    msgs: np.ndarray            # (n_levels,) int64 global fold messages sent
    msgs_dev: np.ndarray
    direction: np.ndarray       # (n_levels,) int32: 0 top-down / 1 bottom-up

    @property
    def total_wire_bytes(self) -> int:
        return int(self.wire_bytes.sum())

    @property
    def total_msgs(self) -> int:
        return int(self.msgs.sum())

    @property
    def total_scanned(self) -> int:
        return int(self.scanned.sum())

    def levels(self) -> list:
        """Per-level dict rows (what benches/CI serialize)."""
        return [
            {"level": k, "frontier": int(self.frontier[k]),
             "scanned": int(self.scanned[k]),
             "folded": int(self.folded[k]),
             "wire_bytes": int(self.wire_bytes[k]),
             "msgs": int(self.msgs[k]),
             "dir": int(self.direction[k])}
            for k in range(self.n_levels)]

    def to_dict(self) -> dict:
        return {"program": self.program, "codec": self.codec,
                "grid": list(self.grid), "n_levels": self.n_levels,
                "levels": self.levels()}


def _one_trace(chans, k, *, grid, program, codec) -> LevelTrace:
    L = chans["dir"].shape[-1]
    n = min(int(k), L)
    i64 = np.int64
    f_dev = chans["front_dev"][:, :n].astype(i64)
    s_dev = chans["scanned"][:, :n].astype(i64)
    c_dev = chans["folded"][:, :n].astype(i64)
    w_dev = chans["wire"][:, :n].astype(i64)
    m_dev = chans["msgs"][:, :n].astype(i64)
    return LevelTrace(
        program=program, codec=codec, grid=(grid.R, grid.C), n_levels=n,
        frontier=chans["frontier"][0, :n].astype(i64), frontier_dev=f_dev,
        scanned=s_dev.sum(axis=0), scanned_dev=s_dev,
        folded=c_dev.sum(axis=0), folded_dev=c_dev,
        wire_bytes=w_dev.sum(axis=0), wire_dev=w_dev,
        msgs=m_dev.sum(axis=0), msgs_dev=m_dev,
        direction=np.asarray(chans["dir"][0, :n], np.int32))


def assemble_traces(traw, B, *, grid, program: str, codec: str):
    """Gathered trace outputs -> LevelTrace (B=None) or a tuple of B.

    `traw` is the engine's trailing N_TRACE_OUTS device outputs; every
    channel gathers to (R, C, [B,] max_levels) and `k` to (R, C[, B]).
    `frontier`/`dir` are psum-replicated so device 0's row is global truth;
    the work channels are per-device and sum to the global figures.
    """
    arrs = [np.asarray(a) for a in traw[:-1]]
    kk = np.asarray(traw[-1])
    L = arrs[0].shape[-1]
    if B is None:
        chans = {c: a.reshape(-1, L)
                 for c, a in zip(TRACE_CHANNELS, arrs)}
        return _one_trace(chans, kk.reshape(-1)[0], grid=grid,
                          program=program, codec=codec)
    per_b = [{c: a.reshape(-1, B, L)[:, b, :]
              for c, a in zip(TRACE_CHANNELS, arrs)} for b in range(B)]
    ks = kk.reshape(-1, B)[0]
    return tuple(_one_trace(per_b[b], ks[b], grid=grid, program=program,
                            codec=codec) for b in range(B))
