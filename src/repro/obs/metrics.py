"""The unified metrics registry (DESIGN.md sec. 13).

One `MetricsRegistry` per observable component (each `GraphServer` owns
one, so counters are reset-safe across server restarts) holds every
counter / gauge / histogram the layer emits, as LABELED series:

    reg = MetricsRegistry()
    admitted = reg.counter("serve_admitted_total", "admitted queries",
                           labelnames=("tenant",))
    admitted.labels(tenant="alice").inc()

    lat = reg.histogram("serve_execute_seconds", labelnames=("graph",))
    lat.labels(graph="web").observe(0.012)

Exposition lives in `repro.obs.export` (JSON snapshot + Prometheus text);
sources that keep their own authoritative counters (the AOT cache, engine
trace counts, queue depths) join the registry through `register_collector`
-- a zero-cost pull at scrape time instead of a write on every event.

Thread-safe throughout: scheduler worker threads and any number of client
threads record concurrently (one registry-wide lock; metric mutation is a
dict update, so contention is negligible next to a graph search).
"""
from __future__ import annotations

import math
import threading

# Latency-shaped default buckets (seconds): spans queue waits in the
# hundreds of microseconds up to multi-second compiles.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labelnames, kv) -> tuple:
    if set(kv) != set(labelnames):
        raise ValueError(f"expected labels {labelnames}, got {tuple(kv)}")
    return tuple(str(kv[name]) for name in labelnames)


class _Bound:
    """One labeled series of a metric, bound for direct mutation."""

    def __init__(self, metric: "Metric", key: tuple):
        self._metric = metric
        self._key = key

    def inc(self, amount=1):
        self._metric._inc(self._key, amount)

    def dec(self, amount=1):
        self._metric._inc(self._key, -amount)

    def set(self, value):
        self._metric._set(self._key, value)

    def observe(self, value):
        self._metric._observe(self._key, value)

    @property
    def value(self):
        return self._metric.value_for(self._key)


class Metric:
    """Base labeled metric: a dict of series keyed by label-value tuples."""
    kind = "?"

    def __init__(self, name: str, help: str = "", labelnames: tuple = (),
                 lock: "threading.RLock | None" = None):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict = {}
        self._lock = lock if lock is not None else threading.RLock()

    def labels(self, **kv) -> _Bound:
        return _Bound(self, _label_key(self.labelnames, kv))

    # unlabeled ergonomic forms -------------------------------------------
    def inc(self, amount=1):
        self._inc((), amount)

    def dec(self, amount=1):
        self._inc((), -amount)

    def set(self, value):
        self._set((), value)

    def observe(self, value):
        self._observe((), value)

    @property
    def value(self):
        return self.value_for(())

    # series access --------------------------------------------------------
    def series(self) -> dict:
        """{label-values tuple: plain value} snapshot of every series."""
        with self._lock:
            return {k: self._plain(v) for k, v in self._series.items()}

    def value_for(self, key: tuple, default=0):
        with self._lock:
            if key not in self._series:
                return default
            return self._plain(self._series[key])

    def clear(self):
        with self._lock:
            self._series.clear()

    # subclass hooks -------------------------------------------------------
    def _plain(self, stored):
        return stored

    def _inc(self, key, amount):
        raise TypeError(f"{self.kind} {self.name!r} does not support inc()")

    def _set(self, key, value):
        raise TypeError(f"{self.kind} {self.name!r} does not support set()")

    def _observe(self, key, value):
        raise TypeError(
            f"{self.kind} {self.name!r} does not support observe()")


class Counter(Metric):
    """Monotone counter (ints stay ints, so query counts snapshot exact)."""
    kind = "counter"

    def _inc(self, key, amount):
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount


class Gauge(Metric):
    """Settable instantaneous value."""
    kind = "gauge"

    def _inc(self, key, amount):
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    def _set(self, key, value):
        with self._lock:
            self._series[key] = value


class Histogram(Metric):
    """Fixed-bucket histogram: cumulative bucket counts + sum + count."""
    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), buckets=None,
                 lock=None):
        super().__init__(name, help, labelnames, lock)
        bs = tuple(sorted(buckets if buckets is not None else
                          DEFAULT_BUCKETS))
        if not bs:
            raise ValueError(f"histogram {name!r} needs >= 1 finite bucket")
        self.buckets = bs

    def _observe(self, key, value):
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._series[key] = {
                    "buckets": [0] * (len(self.buckets) + 1),
                    "sum": 0.0, "count": 0}
            i = 0
            while i < len(self.buckets) and value > self.buckets[i]:
                i += 1
            st["buckets"][i] += 1
            st["sum"] += float(value)
            st["count"] += 1

    def _plain(self, stored):
        # cumulative counts per upper bound, Prometheus-style
        cum, acc = [], 0
        for c in stored["buckets"]:
            acc += c
            cum.append(acc)
        return {"buckets": dict(zip([*self.buckets, math.inf], cum)),
                "sum": stored["sum"], "count": stored["count"]}


class MetricsRegistry:
    """All metrics of one component + pull-time collectors.

    `counter` / `gauge` / `histogram` are get-or-create: asking twice with
    the same name returns the same metric (and raises if the kind or label
    set changed -- two writers disagreeing about a metric is a bug).
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, Metric] = {}
        self._collectors: list = []

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or \
                        m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind} "
                        f"with labels {m.labelnames}")
                return m
            m = cls(name, help, labelnames, lock=self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def register_collector(self, fn) -> None:
        """`fn() -> iterable of (name, kind, help, labels_dict, value)`;
        called at snapshot/exposition time.  For sources that keep their own
        authoritative counters (AOT cache, trace counts, queue depths)."""
        with self._lock:
            self._collectors.append(fn)

    def collected(self) -> list:
        """Materialize every collector's samples (scrape-time pull)."""
        with self._lock:
            collectors = list(self._collectors)
        out = []
        for fn in collectors:
            out.extend(tuple(s) for s in fn())
        return out

    def metrics(self) -> "dict[str, Metric]":
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able view: {name: {kind, help, series: {label-str: value}}}
        including collector samples (kind-prefixed under their own names)."""
        out = {}
        for name, m in sorted(self.metrics().items()):
            series = {",".join(f"{k}={v}" for k, v in
                               zip(m.labelnames, key)): val
                      for key, val in sorted(m.series().items())}
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        for name, kind, help, labels, value in self.collected():
            entry = out.setdefault(
                name, {"kind": kind, "help": help, "series": {}})
            entry["series"][",".join(
                f"{k}={v}" for k, v in sorted(labels.items()))] = value
        return out

    def reset(self) -> None:
        """Zero every series (collectors are pull-through and unaffected:
        their sources own their lifecycle)."""
        for m in self.metrics().values():
            m.clear()
