"""Serve-layer request tracing: span-per-request lifecycle
(DESIGN.md sec. 13).

A request admitted to a `GraphServer` moves through a fixed lifecycle --
admit -> queue -> coalesce -> execute -> demux -- and each fulfilled
`QueryResult` carries a `RequestTrace` whose spans cover it wall to wall:

  queue     admission until the batcher dispatched the coalesced group
            (the max-latency-window wait)
  coalesce  dispatch until execution start (batch assembly + the server's
            device-execution lock wait)
  execute   the batch's device execution (shared by every rider)
  demux     execution end until this request's slot was demuxed into its
            ticket

Spans are host wall-clock (`time.perf_counter` stamps the workers already
take); the in-program per-level counters are `repro.obs.trace`.  The
matching `jax.profiler.TraceAnnotation` names around the jitted program
executions make device profiles line up with these span names.
"""
from __future__ import annotations

import dataclasses

# Lifecycle phase order (golden in tests: spans appear in this order and
# tile the admit -> done interval).
PHASES = ("queue", "coalesce", "execute", "demux")


@dataclasses.dataclass
class Span:
    """One closed interval of a request's lifecycle."""
    name: str
    t0: float
    t1: float
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return max(self.t1 - self.t0, 0.0)

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "dur_s": self.dur_s, **({"attrs": self.attrs}
                                        if self.attrs else {})}


@dataclasses.dataclass
class RequestTrace:
    """All spans of one request, in lifecycle order."""
    seq: int
    graph: str
    program: str
    spans: list = dataclasses.field(default_factory=list)

    def add(self, name: str, t0: float, t1: float, **attrs) -> Span:
        span = Span(name=name, t0=t0, t1=max(t1, t0), attrs=attrs)
        self.spans.append(span)
        return span

    def span(self, name: str) -> "Span | None":
        for s in self.spans:
            if s.name == name:
                return s
        return None

    def dur_s(self, name: str) -> float:
        s = self.span(name)
        return s.dur_s if s is not None else 0.0

    @property
    def total_s(self) -> float:
        if not self.spans:
            return 0.0
        return max(s.t1 for s in self.spans) - min(s.t0 for s in self.spans)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "graph": self.graph,
                "program": self.program, "total_s": self.total_s,
                "spans": [s.to_dict() for s in self.spans]}


def request_trace(seq, graph, program, *, t_admit, t_dispatch, t_exec_start,
                  t_exec_end, t_done, **exec_attrs) -> RequestTrace:
    """Build the standard 4-span lifecycle trace from the worker's stamps."""
    tr = RequestTrace(seq=seq, graph=graph, program=program)
    tr.add("queue", t_admit, t_dispatch)
    tr.add("coalesce", t_dispatch, t_exec_start)
    tr.add("execute", t_exec_start, t_exec_end, **exec_attrs)
    tr.add("demux", t_exec_end, t_done)
    return tr
