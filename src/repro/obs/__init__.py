"""Telemetry subsystem (DESIGN.md sec. 13): three layers.

1. In-program per-level traces: `BFSConfig(telemetry=True)` threads a
   per-level carry through the engine's `lax.while_loop`; every search
   returns a `LevelTrace` (frontier counts, direction, fold wire bytes,
   expand/fold/exchange work stamps), also readable as
   `GraphSession.last_trace()`.  Off by default; the flag keys every
   engine/AOT cache, so the off path compiles to exactly the untraced
   program and outputs are bit-identical either way.

2. The metrics registry: thread-safe labeled counters / gauges /
   histograms (`MetricsRegistry`), JSON + Prometheus-text exposition
   (`to_prometheus`, `to_json`) and the JSONL `EventLog`.  Every
   `GraphServer` owns one registry, so counters reset with the server.

3. Request tracing in `repro.serve`: span-per-request lifecycle
   (admit -> queue -> coalesce -> execute -> demux) on each
   `QueryResult.trace`, feeding the registry's latency histograms.
"""
from repro.obs.export import EventLog, to_json, to_prometheus, write_json
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.spans import PHASES, RequestTrace, Span, request_trace
from repro.obs.trace import (N_TRACE_OUTS, TRACE_CHANNELS, LevelTrace,
                             assemble_traces, init_trace, normalize_aux,
                             record_level, trace_outputs)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "DEFAULT_BUCKETS",
    "EventLog", "to_prometheus", "to_json", "write_json",
    "LevelTrace", "assemble_traces", "init_trace", "normalize_aux",
    "record_level", "trace_outputs", "TRACE_CHANNELS", "N_TRACE_OUTS",
    "RequestTrace", "Span", "PHASES", "request_trace",
]
