"""Exposition: JSON + Prometheus text + the JSONL event log
(DESIGN.md sec. 13).

`to_prometheus` renders a `MetricsRegistry` in the Prometheus text format
(version 0.0.4): HELP/TYPE headers, one sample line per labeled series,
histograms as cumulative `_bucket{le=...}` + `_sum` + `_count`.  The format
is the contract a scraper parses, so `tests/test_obs.py` pins it golden.

`EventLog` is the discrete-event side channel: batch executions, retries,
straggler flags and isolation replays as one JSON object per line --
buffered in a bounded ring and optionally appended to a `.jsonl` file (the
artifact the CI obs-smoke job uploads).
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque

from repro.obs.metrics import Histogram, MetricsRegistry


def _escape(value) -> str:
    return str(value).replace("\\", r"\\").replace("\n", r"\n") \
                     .replace('"', r'\"')


def _labels_text(names, values) -> str:
    if not names:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in zip(names, values))
    return "{" + inner + "}"


def _num(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render every metric + collector sample as Prometheus text."""
    lines = []
    for name, m in sorted(registry.metrics().items()):
        series = m.series()
        if not series:
            continue
        if m.help:
            lines.append(f"# HELP {name} {m.help}")
        lines.append(f"# TYPE {name} {m.kind}")
        for key, val in sorted(series.items()):
            if isinstance(m, Histogram):
                for le, c in val["buckets"].items():
                    lt = _labels_text(m.labelnames + ("le",),
                                      key + (_num(le),))
                    lines.append(f"{name}_bucket{lt} {c}")
                lt = _labels_text(m.labelnames, key)
                lines.append(f"{name}_sum{lt} {_num(val['sum'])}")
                lines.append(f"{name}_count{lt} {val['count']}")
            else:
                lines.append(
                    f"{name}{_labels_text(m.labelnames, key)} {_num(val)}")
    typed = set()
    for name, kind, help, labels, value in registry.collected():
        if name not in typed:
            typed.add(name)
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")
        items = sorted(labels.items())
        lines.append(f"{name}"
                     f"{_labels_text([k for k, _ in items], [v for _, v in items])}"
                     f" {_num(value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def to_json(registry: MetricsRegistry) -> dict:
    """JSON-able snapshot (metrics + collector samples)."""
    return registry.snapshot()


def write_json(registry: MetricsRegistry, path) -> None:
    with open(path, "w") as f:
        json.dump(to_json(registry), f, indent=2, sort_keys=True)
        f.write("\n")


class EventLog:
    """Bounded ring of discrete events, optionally mirrored to a JSONL file.

    emit() stamps wall-clock time and a monotone sequence number; every
    event is one JSON object per line, so the file tails cleanly and the
    CI artifact diffs by line.  Thread-safe.
    """

    def __init__(self, path=None, maxlen: int = 4096):
        self.path = None if path is None else str(path)
        self._buf = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._seq = 0
        self._fh = open(self.path, "a") if self.path is not None else None

    def emit(self, kind: str, **fields) -> dict:
        event = {"t": time.time(), "kind": str(kind), **fields}
        with self._lock:
            event["seq"] = self._seq
            self._seq += 1
            self._buf.append(event)
            if self._fh is not None:
                self._fh.write(json.dumps(event, sort_keys=True,
                                          default=str) + "\n")
                self._fh.flush()
        return event

    def tail(self, n: int = 50) -> list:
        with self._lock:
            return list(self._buf)[-n:]

    def to_list(self) -> list:
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return self._seq

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
