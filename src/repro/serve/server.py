"""GraphServer: concurrent graph queries over resident DistGraphs
(DESIGN.md sec. 12).

One server holds N resident graphs; each graph gets one executor thread
driving a `ContinuousBatcher`.  Clients `submit()` BFS / CC / SSSP /
multi-source-BFS requests from any thread and block on the returned
`QueryTicket`; the executor coalesces compatible requests (same graph,
program, config) into the session layer's AOT-cached batched multi-root
programs, padding to the nearest capacity class, and demuxes each slot
back to its caller -- bit-identical to a direct `GraphSession` call by
construction (`lax.map` searches are independent, and padding slots repeat
a live root and are discarded).

Fault path: every batch runs through `repro.runtime.fault.StepRunner`
(retry + exponential backoff + straggler watchdog).  A batch whose retries
are exhausted is replayed one request at a time, so a poisoned query fails
alone -- the isolation replay -- while the server keeps serving; transient
faults are absorbed by the retries and the request never notices.
Admission is validated (`check_vertex_ids`) and bounded (`max_pending`
backpressure -> `ServerSaturated`), so bad or excess requests never reach
a compiled program.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import warnings

import numpy as np

import jax

from repro.api.session import DistGraph, GraphSession, check_vertex_ids
from repro.core.types import BFSOutput
from repro.obs import EventLog, MetricsRegistry, request_trace, to_prometheus
from repro.runtime.fault import RetryPolicy, StepRunner, StragglerWatchdog
from repro.serve.accounting import BatchRecord, ServeAccounting
from repro.serve.protocol import (PROGRAMS, QueryRequest, QueryResult,
                                  QueryTicket, pad_class, pad_classes)
from repro.serve.scheduler import ContinuousBatcher, Entry, batch_key


@dataclasses.dataclass
class ServeConfig:
    """Server-wide knobs (per-query knobs ride in each request's
    BFSConfig).

    max_batch:  coalescing cap = the largest compiled roots-batch capacity
                class (powers of two up to this are warmed/cached).
    window_s:   max-latency admission window: a non-full batch dispatches
                once its oldest request has waited this long.
    max_pending: admission-queue bound per graph; beyond it `submit`
                raises ServerSaturated (backpressure).
    retry:      StepRunner retry/backoff policy for batch execution.
    straggler_factor: StragglerWatchdog flag threshold (x p99).
    event_log_path: optional JSONL path the server's `repro.obs.EventLog`
                appends batch / reject / retry / straggler / failure events
                to (None = in-memory ring only).
    recovery_dir: optional directory for mid-traversal checkpoints of
                fault-tolerant batches (requests whose BFSConfig has
                fault_tolerance=True); a batch interrupted by device loss
                then DRAINS through recovery -- resumed from its last
                completed level -- instead of failing its requests.
    """
    max_batch: int = 8
    window_s: float = 0.01
    max_pending: int = 1024
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    straggler_factor: float = 3.0
    event_log_path: "str | None" = None
    recovery_dir: "str | None" = None


class _Outstanding:
    """Tickets admitted but not yet fulfilled (what `drain()` waits on)."""

    def __init__(self):
        self.n = 0
        self.cond = threading.Condition()

    def inc(self):
        with self.cond:
            self.n += 1

    def dec(self):
        with self.cond:
            self.n -= 1
            self.cond.notify_all()

    def wait_zero(self, timeout=None) -> bool:
        with self.cond:
            return self.cond.wait_for(lambda: self.n == 0, timeout)


class _GraphWorker:
    """One resident graph's executor: queue -> batch -> demux."""

    def __init__(self, name: str, graph: DistGraph, cfg: ServeConfig,
                 acct: ServeAccounting, outstanding: _Outstanding,
                 exec_lock: threading.Lock, metrics: MetricsRegistry = None,
                 events: EventLog = None):
        self.name = name
        self.graph = graph
        self.cfg = cfg
        self.acct = acct
        self.outstanding = outstanding
        self.exec_lock = exec_lock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.events = events
        self.batcher = ContinuousBatcher(window_s=cfg.window_s,
                                         max_pending=cfg.max_pending)
        # per-tenant fault attribution: every retry / straggler flag of a
        # batch counts once for each tenant riding it (DESIGN.md sec. 13)
        self._retry_c = self.metrics.counter(
            "fault_retries_total", "Batch execution retries",
            labelnames=("graph", "tenant"))
        self._straggler_c = self.metrics.counter(
            "fault_straggler_total", "Straggler-flagged batch executions",
            labelnames=("graph", "tenant"))
        self._recovery_resume_c = self.metrics.counter(
            "recovery_resumes_total",
            "Batches re-driven through mid-traversal recovery",
            labelnames=("graph",))
        self._recovery_drain_c = self.metrics.counter(
            "recovery_drained_total",
            "Requests drained through recovery instead of failing",
            labelnames=("graph",))
        self.runner = StepRunner(
            self._step, policy=cfg.retry,
            watchdog=StragglerWatchdog(factor=cfg.straggler_factor),
            on_retry=self._on_retry, on_straggler=self._on_straggler)
        # request-lifecycle latency breakdown (queue-wait vs execute)
        self._queue_h = self.metrics.histogram(
            "serve_queue_wait_seconds",
            "Admission -> execution-start wall per request",
            labelnames=("graph", "program"))
        self._exec_h = self.metrics.histogram(
            "serve_execute_seconds",
            "Batch execution wall attributed per request",
            labelnames=("graph", "program"))
        self._sessions: dict = {}        # resolved BFSConfig -> GraphSession
        self._session_lock = threading.Lock()
        self._step_no = 0
        self._thread = None

    def _on_retry(self, tenants):
        for t in tenants:
            self._retry_c.labels(graph=self.name, tenant=t).inc()
        if self.events is not None:
            self.events.emit("retry", graph=self.name, tenants=list(tenants))

    def _on_straggler(self, tenants, seconds):
        for t in tenants:
            self._straggler_c.labels(graph=self.name, tenant=t).inc()
        if self.events is not None:
            self.events.emit("straggler", graph=self.name,
                             tenants=list(tenants), seconds=seconds)

    # -- lifecycle -----------------------------------------------------------

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name=f"serve-{self.name}", daemon=True)
            self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()

    def _loop(self):
        while True:
            item = self.batcher.next_batch()
            if item is None:
                return
            self._serve_batch(*item)

    # -- execution -----------------------------------------------------------

    def session_for(self, config) -> GraphSession:
        with self._session_lock:
            sess = self._sessions.get(config)
            if sess is None:
                sess = GraphSession(self.graph, config)
                self._sessions[config] = sess
            return sess

    def _step(self, state, batch):
        """StepRunner step fn: execute ONE coalesced batch.  Raises on any
        fault (injected or real); StepRunner owns retry/backoff."""
        from repro.runtime.recovery import DeviceLossInjector
        key, entries = batch
        # per-request fault hook: a FaultInjector keyed by this request's
        # attempt counter (see repro.serve.protocol.QueryRequest.injector).
        # A DeviceLossInjector rides PAST this hook into the segmented
        # level loop instead -- it fires mid-traversal, not at admission.
        for e in entries:
            if e.req.injector is not None and \
                    not isinstance(e.req.injector, DeviceLossInjector):
                attempt = e.req.attempts
                e.req.attempts += 1
                e.req.injector.check(attempt)
        return state, self._execute(key, entries)

    def _recovery_plan(self, key, entries):
        """RecoveryPlan for one fault-tolerant batch: the first request's
        DeviceLossInjector (drills ride on requests like FaultInjectors do)
        plus, when the server has a recovery_dir, a TraversalCheckpointer
        keyed by the batch identity -- so a re-dispatch of the SAME batch
        resumes mid-flight from its last completed level."""
        import hashlib
        import os
        from repro.runtime.recovery import (DeviceLossInjector, RecoveryPlan,
                                            TraversalCheckpointer)
        injector = None
        for e in entries:
            if isinstance(e.req.injector, DeviceLossInjector):
                injector = e.req.injector
                break
        checkpointer = None
        if self.cfg.recovery_dir is not None:
            args = ",".join(str(e.req.arg) for e in entries)
            query_key = f"{self.name}:{key.program}:{args}"
            sub = hashlib.sha1(query_key.encode()).hexdigest()[:16]
            checkpointer = TraversalCheckpointer(
                os.path.join(self.cfg.recovery_dir, sub), query_key)
        return RecoveryPlan(checkpointer=checkpointer, injector=injector,
                            policy=self.cfg.retry)

    def _execute(self, key, entries):
        """Run the batch through the session layer; returns per-slot
        (values, edges) plus the padded capacity class.

        Each jitted execution runs under a `jax.profiler.TraceAnnotation`
        named serve/<program>, so device profiles line up with the span
        names on `QueryResult.trace`; telemetry-enabled sessions also
        demux their per-slot `LevelTrace` onto each value.
        """
        sess = self.session_for(key.config)
        program = key.program
        recovery = self._recovery_plan(key, entries) \
            if key.config.fault_tolerance else None
        if program == "bfs":
            roots = [int(e.req.arg) for e in entries]
            B = pad_class(len(roots), key.cap)
            padded = roots + [roots[0]] * (B - len(roots))
            with jax.profiler.TraceAnnotation("serve/bfs"):
                out = sess.bfs(np.asarray(padded, np.int32),
                               recovery=recovery)
                jax.block_until_ready(out.level)
            values = [
                BFSOutput(level=out.level[s], pred=out.pred[s],
                          n_levels=out.n_levels[s],
                          edges_scanned=out.edges_scanned[s],
                          directions=None if out.directions is None
                          else out.directions[s],
                          trace=None if out.trace is None else out.trace[s])
                for s in range(len(roots))]
            edges = [v.edges_scanned for v in values]
            return values, edges, B
        if program == "sssp":
            from repro.algos import SSSPOutput
            roots = [int(e.req.arg) for e in entries]
            B = pad_class(len(roots), key.cap)
            padded = roots + [roots[0]] * (B - len(roots))
            with jax.profiler.TraceAnnotation("serve/sssp"):
                out = sess.sssp(np.asarray(padded, np.int32),
                                recovery=recovery)
                jax.block_until_ready(out.dist)
            values = [
                SSSPOutput(dist=out.dist[s], n_iters=out.n_iters[s],
                           edges_scanned=out.edges_scanned[s],
                           directions=None if out.directions is None
                           else out.directions[s],
                           trace=None if out.trace is None else out.trace[s])
                for s in range(len(roots))]
            edges = [v.edges_scanned for v in values]
            return values, edges, B
        if program == "cc":
            # argument-free: ONE execution, every caller gets the result;
            # the whole search's edges are accounted to the first caller
            with jax.profiler.TraceAnnotation("serve/cc"):
                out = sess.connected_components(recovery=recovery)
                jax.block_until_ready(out.labels)
            values = [out] * len(entries)
            edges = [out.edges_scanned] + [0] * (len(entries) - 1)
            return values, edges, 1
        if program == "multi_bfs":
            assert len(entries) == 1, "multi_bfs requests never coalesce"
            req = entries[0].req
            with jax.profiler.TraceAnnotation("serve/multi_bfs"):
                out = sess.multi_bfs(np.asarray(req.arg, np.int32), k=req.k,
                                     recovery=recovery)
                jax.block_until_ready(out.level)
            return [out], [out.edges_scanned], 1
        raise ValueError(f"unknown program {program!r}")

    def _serve_batch(self, key, entries):
        # one multi-device program at a time across ALL resident graphs:
        # concurrent executables over one shared device set interleave
        # their collective rendezvous and deadlock, so execution
        # serializes here (lock wait counts as queued_s, not exec_s) while
        # admission and batch assembly stay concurrent
        t_dispatch = time.perf_counter()
        with self.exec_lock:
            self._serve_batch_locked(key, entries, t_dispatch)

    def _serve_batch_locked(self, key, entries, t_dispatch):
        from repro.runtime.recovery import DeviceLoss, UnrecoverableLoss
        tenants = tuple(sorted({e.req.tenant for e in entries}))
        t_start = time.perf_counter()
        try:
            _, infos = self.runner.run(None, [(key, entries)],
                                       start_step=self._step_no,
                                       labels=tenants)
            values, edges, padded = infos[0]
        except (DeviceLoss, UnrecoverableLoss) as exc:
            # device loss escaped the segmented loop's own retries: drain
            # the in-flight requests through recovery -- ONE re-dispatch
            # resumes the traversal from its last checkpointed level (the
            # injected loss schedule has spent its budget by now), so no
            # query is lost to the failure
            self._step_no += 1
            self._recovery_resume_c.labels(graph=self.name).inc()
            if self.events is not None:
                self.events.emit("recovery_resume", graph=self.name,
                                 program=key.program, tenants=list(tenants),
                                 error=f"{type(exc).__name__}: {exc}")
            try:
                _, (values, edges, padded) = self._step(None, (key, entries))
            except Exception:
                self._isolate(key, entries, t_dispatch)
                return
            for _ in entries:
                self._recovery_drain_c.labels(graph=self.name).inc()
        except Exception:
            self._step_no += 1
            self._isolate(key, entries, t_dispatch)
            return
        else:
            self._step_no += 1
        t_exec_end = time.perf_counter()
        exec_s = t_exec_end - t_start
        self.acct.record_batch(BatchRecord(
            graph=self.name, program=key.program, live=len(entries),
            padded_to=padded, exec_s=exec_s))
        for e, value, n_edges in zip(entries, values, edges):
            self._fulfil(e, ok=True, value=value, edges=n_edges,
                         exec_s=exec_s, t_start=t_start,
                         t_dispatch=t_dispatch, t_exec_end=t_exec_end,
                         live=len(entries), padded=padded)

    def _isolate(self, key, entries, t_dispatch):
        """Batch retries exhausted: replay each request alone so only the
        poisoned one fails (transient faults were already retried)."""
        for e in entries:
            t0 = time.perf_counter()
            try:
                _, (values, edges, padded) = self._step(None, (key, [e]))
            except Exception as exc:
                t1 = time.perf_counter()
                self.acct.record_batch(BatchRecord(
                    graph=self.name, program=key.program, live=1,
                    padded_to=1, exec_s=t1 - t0, isolated=True))
                self._fulfil(e, ok=False, error=f"{type(exc).__name__}: "
                             f"{exc}", exec_s=t1 - t0, t_start=t0,
                             t_dispatch=t_dispatch, t_exec_end=t1,
                             live=1, padded=1, isolated=True)
                continue
            t1 = time.perf_counter()
            exec_s = t1 - t0
            self.acct.record_batch(BatchRecord(
                graph=self.name, program=key.program, live=1,
                padded_to=padded, exec_s=exec_s, isolated=True))
            self._fulfil(e, ok=True, value=values[0], edges=edges[0],
                         exec_s=exec_s, t_start=t0, t_dispatch=t_dispatch,
                         t_exec_end=t1, live=1, padded=padded,
                         isolated=True)

    def _fulfil(self, entry, *, ok, exec_s, t_start, live, padded,
                t_dispatch=None, t_exec_end=None, value=None, edges=0,
                error=None, isolated=False):
        req = entry.req
        t_done = time.perf_counter()
        queued_s = max(t_start - entry.t_admit, 0.0)
        if t_dispatch is None:
            t_dispatch = t_start
        if t_exec_end is None:
            t_exec_end = t_start + exec_s
        trace = request_trace(
            req.seq, self.name, req.program, t_admit=entry.t_admit,
            t_dispatch=t_dispatch, t_exec_start=t_start,
            t_exec_end=t_exec_end, t_done=t_done, live=live, padded=padded,
            isolated=isolated)
        self._queue_h.labels(graph=self.name,
                             program=req.program).observe(queued_s)
        self._exec_h.labels(graph=self.name,
                            program=req.program).observe(exec_s)
        result = QueryResult(
            ok=ok, seq=req.seq, tenant=req.tenant, graph=self.name,
            program=req.program, value=value, error=error,
            queued_s=queued_s, exec_s=exec_s,
            batch_size=live, padded_to=padded, t_done=t_done, trace=trace)
        self.acct.record_result(result, edges=edges)
        entry.ticket._fulfil(result)
        self.outstanding.dec()


class GraphServer:
    """N resident graphs behind one concurrent query frontend.

        server = GraphServer({"web": graph_a, "road": graph_b}).start()
        t1 = server.bfs("web", root=17, tenant="alice")
        t2 = server.sssp("road", root=3, tenant="bob")
        out = t1.result(timeout=60).value        # BFSOutput, bit-identical
        server.stop()                            #   to session.bfs(17)

    Also usable as a context manager (`with GraphServer(...) as s:`).
    Construction does not start the executors -- tests exploit that to
    pre-fill the queue and observe full-batch coalescing.
    """

    def __init__(self, graphs: "dict[str, DistGraph] | None" = None,
                 config: "ServeConfig | None" = None):
        self.config = config if config is not None else ServeConfig()
        # one metrics registry + event log per server (DESIGN.md sec. 13):
        # every counter this server emits lives here, so a fresh server
        # over long-lived graphs starts its accounting from zero
        self.metrics = MetricsRegistry()
        self.events = EventLog(self.config.event_log_path)
        self.metrics.register_collector(self._collect)
        self.accounting = ServeAccounting(registry=self.metrics,
                                          events=self.events)
        # serializes device execution across graph workers (they share one
        # device set; see _GraphWorker._serve_batch)
        self._exec_lock = threading.Lock()
        self._outstanding = _Outstanding()
        self._workers: dict[str, _GraphWorker] = {}
        self._seq = itertools.count()
        self._started = False
        for name, graph in (graphs or {}).items():
            self.add_graph(name, graph)

    # -- residency -----------------------------------------------------------

    def add_graph(self, name: str, graph: DistGraph) -> None:
        if name in self._workers:
            raise ValueError(f"graph {name!r} already resident")
        worker = _GraphWorker(name, graph, self.config, self.accounting,
                              self._outstanding, self._exec_lock,
                              metrics=self.metrics, events=self.events)
        self._workers[name] = worker
        if self._started:
            worker.start()

    def graph(self, name: str) -> DistGraph:
        return self._worker(name).graph

    @property
    def graphs(self) -> tuple:
        return tuple(self._workers)

    def _worker(self, name: str) -> _GraphWorker:
        worker = self._workers.get(name)
        if worker is None:
            raise ValueError(f"no resident graph {name!r}; serving "
                             f"{sorted(self._workers)}")
        return worker

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "GraphServer":
        self._started = True
        for worker in self._workers.values():
            worker.start()
        return self

    def stop(self) -> None:
        """Flush the queues (remaining requests are still served), then
        stop the executors.  The server does not restart."""
        for worker in self._workers.values():
            worker.batcher.close()
        for worker in self._workers.values():
            worker.join()
        self._started = False

    def drain(self, timeout: "float | None" = 120) -> None:
        """Block until every admitted request has been fulfilled."""
        if not self._outstanding.wait_zero(timeout):
            raise TimeoutError(
                f"{self._outstanding.n} requests still outstanding after "
                f"{timeout}s")

    def __enter__(self) -> "GraphServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- admission -----------------------------------------------------------

    def submit(self, graph: str, program: str, arg=None, *,
               tenant: str = "default", config=None, k: "int | None" = None,
               injector=None) -> QueryTicket:
        """Admit one query; returns immediately with a ticket.

        Validation happens HERE, before anything reaches a compiled
        program: unknown graph/program, out-of-range or wrong-dtype ids,
        and SSSP on a weightless graph all raise ValueError at the caller;
        a full queue raises ServerSaturated (backpressure).
        """
        worker = self._worker(graph)
        if program not in PROGRAMS:
            raise ValueError(f"unknown program {program!r}; serving "
                             f"{PROGRAMS}")
        n = worker.graph.n
        if program in ("bfs", "sssp"):
            if arg is None or np.ndim(arg) != 0:
                raise ValueError(f"{program} serves one root per request; "
                                 f"got {arg!r}")
            check_vertex_ids(arg, n, "roots")
            arg = int(arg)
            if program == "sssp" and worker.graph.weights is None:
                raise ValueError(
                    f"sssp on graph {graph!r} needs resident per-edge "
                    f"weights; plan it with DistGraph.from_edges(edges, "
                    f"config, weights=w)")
        elif program == "multi_bfs":
            arg = np.asarray(arg)
            if arg.ndim != 1 or arg.shape[0] == 0:
                raise ValueError(f"multi_bfs needs a non-empty (K,) "
                                 f"sources vector, got shape {arg.shape}")
            check_vertex_ids(arg, n, "sources")
            arg = arg.astype(np.int32)
        elif arg is not None:    # cc
            raise ValueError(f"cc is argument-free, got arg={arg!r}")
        cfg = config if config is not None else worker.graph.config
        req = QueryRequest(seq=next(self._seq), tenant=tenant, graph=graph,
                           program=program, arg=arg, config=cfg, k=k,
                           injector=injector)
        key = batch_key(graph, program, cfg, arg, k, self.config.max_batch)
        entry = Entry(key=key, req=req, ticket=QueryTicket(req))
        self._outstanding.inc()
        try:
            worker.batcher.put(entry)
        except Exception:
            self._outstanding.dec()
            self.accounting.record_reject(tenant)
            raise
        self.accounting.record_admit(tenant)
        return entry.ticket

    # ergonomic per-program spellings
    def bfs(self, graph, root, **kw) -> QueryTicket:
        return self.submit(graph, "bfs", root, **kw)

    def connected_components(self, graph, **kw) -> QueryTicket:
        return self.submit(graph, "cc", **kw)

    def sssp(self, graph, root, **kw) -> QueryTicket:
        return self.submit(graph, "sssp", root, **kw)

    def multi_bfs(self, graph, sources, k=None, **kw) -> QueryTicket:
        return self.submit(graph, "multi_bfs", sources, k=k, **kw)

    # -- capacity ------------------------------------------------------------

    def warm(self, programs=("bfs",), batch_classes=None) -> None:
        """Precompile the padding capacity classes through the session
        layer's public `compiled_for` surface so the first live batch of
        each size pays no compile.  "sssp" warms by running root 0 at each
        class, "cc" by one labelling; multi_bfs depends on the request's
        (K, k) and warms on first traffic.
        """
        classes = batch_classes if batch_classes is not None \
            else pad_classes(self.config.max_batch)
        for worker in self._workers.values():
            sess = worker.session_for(worker.graph.config)
            for program in programs:
                if program == "bfs":
                    for B in classes:
                        sess.compiled_for(B)
                elif program == "sssp" and worker.graph.weights is not None:
                    for B in classes:
                        sess.sssp(np.zeros(B, np.int32))
                elif program == "cc":
                    sess.connected_components()

    # -- observability -------------------------------------------------------

    def _collect(self):
        """Registry collector: pull-time samples from sources that keep
        their own authoritative counters (queue depths, the AOT caches,
        engine trace counts, runner retry/straggler totals)."""
        for n, w in self._workers.items():
            yield ("serve_pending", "gauge",
                   "Requests admitted, not yet dispatched", {"graph": n},
                   w.batcher.pending)
            for k, v in w.graph.cache_stats().items():
                if v is not None:
                    yield (f"aot_cache_{k}", "gauge",
                           "AOT executable cache state", {"graph": n}, v)
            for key, eng in w.graph._engines.items():
                yield ("engine_trace_count", "gauge",
                       "Level-loop traces this engine has paid",
                       {"graph": n, "engine": str(key)}, eng.trace_count)
            yield ("runner_retries", "gauge", "StepRunner retries",
                   {"graph": n}, w.runner.retries)
            yield ("runner_restores", "gauge", "StepRunner restores",
                   {"graph": n}, w.runner.restores)
            yield ("runner_straggler_flagged", "gauge",
                   "Straggler-flagged steps", {"graph": n},
                   len(w.runner.watchdog.flagged))

    def metrics_snapshot(self) -> dict:
        """Accounting snapshot + per-graph cache/runner/queue state -- every
        number a view over the server's one metrics registry (plus the
        runners' own attribution dicts).  Same dict shape the deprecated
        `stats()` always returned, with per-tenant retry attribution added
        under runners.<graph>.retries_by_tenant."""
        snap = self.accounting.snapshot()
        snap["pending"] = {n: w.batcher.pending
                           for n, w in self._workers.items()}
        snap["aot_cache"] = {n: w.graph.cache_stats()
                             for n, w in self._workers.items()}
        snap["runners"] = {
            n: {"retries": w.runner.retries, "restores": w.runner.restores,
                "straggler_flagged": len(w.runner.watchdog.flagged),
                "retries_by_tenant": dict(w.runner.retries_by),
                "straggler_by_tenant": dict(w.runner.straggler_by),
                # the jittered backoff actually slept (bounded tail)
                "delays": list(w.runner.delays)[-256:],
                "recovery_resumes": w._recovery_resume_c.labels(
                    graph=n).value,
                "recovery_drained": w._recovery_drain_c.labels(
                    graph=n).value}
            for n, w in self._workers.items()}
        snap["trace_counts"] = {
            n: {str(key): eng.trace_count
                for key, eng in w.graph._engines.items()}
            for n, w in self._workers.items()}
        return snap

    def stats(self) -> dict:
        """Deprecated spelling of `metrics_snapshot()` (same dict)."""
        warnings.warn(
            "GraphServer.stats() is deprecated; use metrics_snapshot() "
            "(same dict), prometheus() for text exposition, or the "
            "server's .metrics registry directly", DeprecationWarning,
            stacklevel=2)
        return self.metrics_snapshot()

    def prometheus(self) -> str:
        """Prometheus text-format exposition of the server's registry."""
        return to_prometheus(self.metrics)

    def reset_metrics(self) -> None:
        """Zero the serve counters AND the per-graph runner attribution
        (the load generator's between-points reset; collectors pull from
        live sources and are unaffected)."""
        self.accounting.reset()
        for w in self._workers.values():
            w.runner.reset_stats()
