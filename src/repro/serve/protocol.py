"""Wire types of the serve layer (DESIGN.md sec. 12).

A query is one request for one search: a BFS/SSSP root, a CC labelling, or
a multi-source BFS over a (K,) sources vector.  Requests are admitted into
per-graph queues, coalesced by `BatchKey` -- same graph, program and config
(codec, direction mode, kernel paths all ride in `BFSConfig`, which is
frozen/hashable exactly so it can key this) -- and executed through the
resident graph's AOT-cached batched programs.  The caller holds a
`QueryTicket` and blocks on `result()`; the scheduler demuxes each batch
slot back into its ticket's `QueryResult`.

Coalescing shape per program:

  bfs / sssp   batchable along the roots axis: up to `cap` requests pad
               into one (B,)-roots compiled sweep.
  cc           argument-free, so every concurrent CC request on one
               (graph, config) shares ONE execution (dedup-coalescing).
  multi_bfs    the (K,) sources vector IS the one search argument; each
               request runs alone (cap = 1) but still flows through the
               same queue, accounting and fault path.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

PROGRAMS = ("bfs", "cc", "sssp", "multi_bfs")


class ServeError(RuntimeError):
    """Base class of serve-layer signalling errors."""


class ServerSaturated(ServeError):
    """Backpressure: the admission queue is at `max_pending`.  Open-loop
    clients should shed or retry later; closed-loop clients should block on
    outstanding tickets first."""


class ServerClosed(ServeError):
    """Submitted to a server that has been stopped."""


@dataclasses.dataclass(frozen=True)
class BatchKey:
    """What makes two requests coalescible into one compiled execution."""
    graph: str          # resident-graph name on the server
    program: str        # one of PROGRAMS
    config: Any         # resolved BFSConfig (frozen, hashable)
    arg_shape: tuple = ()   # () for root queries / cc; (K, k) for multi_bfs
    cap: int = 1        # max requests per executed batch for this key


@dataclasses.dataclass
class QueryRequest:
    """One admitted query.

    injector: optional `repro.runtime.fault.FaultInjector` checked (keyed
    by this request's attempt counter) every time the request enters an
    execution -- the test/bench hook that makes a request transiently
    faulty (schedule covers early attempts only; the batch-level retry
    recovers it) or poisoned (schedule covers every attempt; the isolation
    replay fails just this request).
    """
    seq: int
    tenant: str
    graph: str
    program: str
    arg: Any = None          # int root | (K,) sources | None for cc
    config: Any = None       # resolved BFSConfig
    k: int | None = None     # multi_bfs hop bound
    injector: Any = None
    attempts: int = 0


@dataclasses.dataclass
class QueryResult:
    """What a ticket resolves to (ok or failed; never an exception)."""
    ok: bool
    seq: int
    tenant: str
    graph: str
    program: str
    value: Any = None        # BFSOutput / CCOutput / SSSPOutput /
                             #   MultiBFSOutput slice for this request
    error: str | None = None
    queued_s: float = 0.0    # admission -> execution start
    exec_s: float = 0.0      # batch execution wall (shared by the batch)
    batch_size: int = 1      # live requests in the executed batch
    padded_to: int = 1       # compiled capacity class B the batch ran at
    t_done: float = 0.0      # perf_counter stamp at fulfilment
    trace: Any = None        # repro.obs.RequestTrace span lifecycle
                             #   (queue/coalesce/execute/demux)


class QueryTicket:
    """Caller-side handle: blocks on `result()` until the slot demuxes."""

    def __init__(self, request: QueryRequest):
        self.request = request
        self._event = threading.Event()
        self._result: QueryResult | None = None

    def _fulfil(self, result: QueryResult) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> QueryResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query seq={self.request.seq} ({self.request.program} on "
                f"{self.request.graph!r}) not served within {timeout}s")
        return self._result


def pad_class(n_live: int, cap: int) -> int:
    """Capacity class a batch of `n_live` requests pads to: the next power
    of two, clipped to `cap` -- so the AOT cache holds at most
    log2(cap)+1 executables per (engine, program) instead of one per
    observed batch size."""
    if n_live < 1:
        raise ValueError(f"batch must hold >= 1 requests, got {n_live}")
    b = 1
    while b < n_live:
        b <<= 1
    return min(b, cap)


def pad_classes(cap: int) -> tuple:
    """Every capacity class `pad_class` can produce under `cap` (what the
    server warms before admitting traffic)."""
    classes = []
    b = 1
    while b < cap:
        classes.append(b)
        b <<= 1
    classes.append(cap)
    return tuple(classes)
