"""Per-tenant and per-batch serve accounting (DESIGN.md sec. 12 + 13).

Everything the load generator, the CI gates and a capacity planner need to
read back out of a serving run: per-tenant query/edge/wall-time counters,
per-batch occupancy records (live slots vs padded capacity -- the
continuous-batching win is literally `occupancy() > 1`), and the resident
graphs' AOT-cache hit/miss/eviction counters folded into one snapshot.

Since the telemetry subsystem (DESIGN.md sec. 13) the counters themselves
live in a `repro.obs.MetricsRegistry` -- `ServeAccounting` is a writer plus
a snapshot VIEW over that registry, so the same numbers serve the legacy
`snapshot()` dict, the JSON exposition and the Prometheus text endpoint
without double bookkeeping.  A `GraphServer` passes its own registry (and
its JSONL `EventLog`); standalone construction makes a private one.

Thread-safe: the scheduler worker threads and any number of client threads
record concurrently (the registry lock; the `batches` list keeps its own).
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class TenantStats:
    """Counters for one tenant (accounting unit = one query)."""
    queries: int = 0         # admitted
    ok: int = 0
    failed: int = 0
    rejected: int = 0        # refused at admission (backpressure)
    edges_scanned: int = 0   # exact per-slot counts (CC riders count 0)
    exec_s: float = 0.0      # summed batch-execution wall per query
    queued_s: float = 0.0    # summed admission -> execution-start wall

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BatchRecord:
    """One executed batch (or isolation replay slot)."""
    graph: str
    program: str
    live: int                # real requests served
    padded_to: int           # compiled capacity class B it ran at
    exec_s: float
    isolated: bool = False   # True for a post-fault singleton replay


# the per-tenant registry counters backing TenantStats, in field order
_TENANT_COUNTERS = (
    ("queries", "serve_admitted_total", "Queries admitted"),
    ("ok", "serve_ok_total", "Queries fulfilled ok"),
    ("failed", "serve_failed_total", "Queries fulfilled failed"),
    ("rejected", "serve_rejected_total",
     "Queries refused at admission (backpressure)"),
    ("edges_scanned", "serve_edges_scanned_total",
     "Exact scanned edges attributed per request"),
    ("exec_s", "serve_exec_seconds_total",
     "Summed batch-execution wall per query"),
    ("queued_s", "serve_queued_seconds_total",
     "Summed admission -> execution-start wall"),
)


class ServeAccounting:
    """Registry-backed tenant/batch accounting for one GraphServer.

    registry: the `repro.obs.MetricsRegistry` the counters live in (the
              owning GraphServer's; a private one when None).
    events:   optional `repro.obs.EventLog`; batch executions, rejections
              and failures are emitted as JSONL events.
    """

    def __init__(self, registry=None, events=None):
        from repro.obs import MetricsRegistry

        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.events = events
        self._lock = threading.Lock()
        self.batches: list[BatchRecord] = []
        r = self.registry
        self._tenant_c = {
            field: r.counter(name, help, labelnames=("tenant",))
            for field, name, help in _TENANT_COUNTERS}
        bl = ("graph", "program")
        self._batches_c = r.counter(
            "serve_batches_total", "Executed batches", labelnames=bl)
        self._batch_live_c = r.counter(
            "serve_batch_live_total", "Live requests over executed batches",
            labelnames=bl)
        self._batch_padded_c = r.counter(
            "serve_batch_padded_total",
            "Compiled capacity slots over executed batches", labelnames=bl)
        self._isolated_c = r.counter(
            "serve_isolated_total", "Isolation-replay slots", labelnames=bl)
        self._batch_exec_h = r.histogram(
            "serve_batch_exec_seconds", "Batch device-execution wall",
            labelnames=bl)

    @property
    def tenants(self) -> "dict[str, TenantStats]":
        """Per-tenant stats reconstructed FROM the registry (a view: the
        registry's series are the authority)."""
        out: dict[str, TenantStats] = {}
        for field, counter in self._tenant_c.items():
            for key, value in counter.series().items():
                stats = out.setdefault(key[0], TenantStats())
                setattr(stats, field, value)
        return out

    def record_admit(self, tenant: str) -> None:
        self._tenant_c["queries"].labels(tenant=tenant).inc()

    def record_reject(self, tenant: str) -> None:
        self._tenant_c["rejected"].labels(tenant=tenant).inc()
        if self.events is not None:
            self.events.emit("reject", tenant=tenant)

    def record_batch(self, record: BatchRecord) -> None:
        with self._lock:
            self.batches.append(record)
        kv = {"graph": record.graph, "program": record.program}
        if record.isolated:
            self._isolated_c.labels(**kv).inc()
        else:
            self._batches_c.labels(**kv).inc()
            self._batch_live_c.labels(**kv).inc(record.live)
            self._batch_padded_c.labels(**kv).inc(record.padded_to)
        self._batch_exec_h.labels(**kv).observe(record.exec_s)
        if self.events is not None:
            self.events.emit("batch", graph=record.graph,
                             program=record.program, live=record.live,
                             padded_to=record.padded_to,
                             exec_s=record.exec_s,
                             isolated=record.isolated)

    def record_result(self, result, edges: int = 0) -> None:
        """Fold one fulfilled QueryResult into its tenant's counters.
        `edges` is the request's own scanned-edge count: the exact per-slot
        number for bfs/sssp/multi_bfs, the whole search for the first CC
        caller in a shared run and 0 for the riders."""
        tenant = result.tenant
        if result.ok:
            self._tenant_c["ok"].labels(tenant=tenant).inc()
            self._tenant_c["edges_scanned"].labels(tenant=tenant).inc(
                int(edges))
        else:
            self._tenant_c["failed"].labels(tenant=tenant).inc()
            if self.events is not None:
                self.events.emit("request_failed", tenant=tenant,
                                 graph=result.graph, program=result.program,
                                 seq=result.seq, error=result.error)
        self._tenant_c["exec_s"].labels(tenant=tenant).inc(result.exec_s)
        self._tenant_c["queued_s"].labels(tenant=tenant).inc(result.queued_s)

    def occupancy(self) -> "float | None":
        """Mean live requests per executed batch (isolation replays
        excluded -- they are the fault path, not the steady state)."""
        with self._lock:
            live = [b.live for b in self.batches if not b.isolated]
        return sum(live) / len(live) if live else None

    def reset(self) -> None:
        """Zero the serve_* series and the batch records (the load
        generator resets between offered-load points so each point's
        occupancy/latency stands alone).  Other registry metrics and
        collectors are untouched."""
        with self._lock:
            self.batches = []
        for counter in self._tenant_c.values():
            counter.clear()
        for m in (self._batches_c, self._batch_live_c, self._batch_padded_c,
                  self._isolated_c, self._batch_exec_h):
            m.clear()

    def snapshot(self) -> dict:
        with self._lock:
            batches = list(self.batches)
        tenants = {t: s.as_dict() for t, s in self.tenants.items()}
        live = [b.live for b in batches if not b.isolated]
        padded = [b.padded_to for b in batches if not b.isolated]
        return {
            "tenants": tenants,
            "n_batches": len(live),
            "n_isolated": sum(1 for b in batches if b.isolated),
            "mean_occupancy": sum(live) / len(live) if live else None,
            "mean_padded_to": sum(padded) / len(padded) if padded else None,
            # padding waste: compiled slots that carried no live request
            "pad_waste_frac": (1 - sum(live) / sum(padded)) if padded and
                              sum(padded) else None,
        }
