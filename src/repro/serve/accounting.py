"""Per-tenant and per-batch serve accounting (DESIGN.md sec. 12).

Everything the load generator, the CI gates and a capacity planner need to
read back out of a serving run: per-tenant query/edge/wall-time counters,
per-batch occupancy records (live slots vs padded capacity -- the
continuous-batching win is literally `occupancy() > 1`), and the resident
graphs' AOT-cache hit/miss/eviction counters folded into one snapshot.

Thread-safe: the scheduler worker threads and any number of client threads
record concurrently.
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class TenantStats:
    """Counters for one tenant (accounting unit = one query)."""
    queries: int = 0         # admitted
    ok: int = 0
    failed: int = 0
    rejected: int = 0        # refused at admission (backpressure)
    edges_scanned: int = 0   # exact per-slot counts (CC riders count 0)
    exec_s: float = 0.0      # summed batch-execution wall per query
    queued_s: float = 0.0    # summed admission -> execution-start wall

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BatchRecord:
    """One executed batch (or isolation replay slot)."""
    graph: str
    program: str
    live: int                # real requests served
    padded_to: int           # compiled capacity class B it ran at
    exec_s: float
    isolated: bool = False   # True for a post-fault singleton replay


class ServeAccounting:
    """Aggregates tenants, batches and cache stats for one GraphServer."""

    def __init__(self):
        self._lock = threading.Lock()
        self.tenants: dict[str, TenantStats] = {}
        self.batches: list[BatchRecord] = []

    def _tenant(self, tenant: str) -> TenantStats:
        stats = self.tenants.get(tenant)
        if stats is None:
            stats = self.tenants[tenant] = TenantStats()
        return stats

    def record_admit(self, tenant: str) -> None:
        with self._lock:
            self._tenant(tenant).queries += 1

    def record_reject(self, tenant: str) -> None:
        with self._lock:
            self._tenant(tenant).rejected += 1

    def record_batch(self, record: BatchRecord) -> None:
        with self._lock:
            self.batches.append(record)

    def record_result(self, result, edges: int = 0) -> None:
        """Fold one fulfilled QueryResult into its tenant's counters.
        `edges` is the request's own scanned-edge count: the exact per-slot
        number for bfs/sssp/multi_bfs, the whole search for the first CC
        caller in a shared run and 0 for the riders."""
        with self._lock:
            stats = self._tenant(result.tenant)
            if result.ok:
                stats.ok += 1
                stats.edges_scanned += int(edges)
            else:
                stats.failed += 1
            stats.exec_s += result.exec_s
            stats.queued_s += result.queued_s

    def occupancy(self) -> "float | None":
        """Mean live requests per executed batch (isolation replays
        excluded -- they are the fault path, not the steady state)."""
        with self._lock:
            live = [b.live for b in self.batches if not b.isolated]
        return sum(live) / len(live) if live else None

    def reset(self) -> None:
        """Zero everything (the load generator resets between offered-load
        points so each point's occupancy/latency stands alone)."""
        with self._lock:
            self.tenants = {}
            self.batches = []

    def snapshot(self) -> dict:
        with self._lock:
            batches = list(self.batches)
            tenants = {t: s.as_dict() for t, s in self.tenants.items()}
        live = [b.live for b in batches if not b.isolated]
        padded = [b.padded_to for b in batches if not b.isolated]
        return {
            "tenants": tenants,
            "n_batches": len(live),
            "n_isolated": sum(1 for b in batches if b.isolated),
            "mean_occupancy": sum(live) / len(live) if live else None,
            "mean_padded_to": sum(padded) / len(padded) if padded else None,
            # padding waste: compiled slots that carried no live request
            "pad_waste_frac": (1 - sum(live) / sum(padded)) if padded and
                              sum(padded) else None,
        }
