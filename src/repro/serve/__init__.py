"""Concurrent graph query service over resident `DistGraph`s
(DESIGN.md sec. 12) -- the millions-of-users layer above the session API.

    from repro.serve import GraphServer, ServeConfig

    server = GraphServer({"web": graph_a, "road": graph_b},
                         ServeConfig(max_batch=8, window_s=0.01)).start()
    server.warm()                                  # precompile B classes
    ticket = server.bfs("web", root=17, tenant="alice")
    out = ticket.result(timeout=60).value          # bit-identical to a
    server.stop()                                  # direct session.bfs(17)

Continuous batching: compatible requests (same graph, program, config)
coalesce into the session layer's AOT-cached batched multi-root programs
under a max-latency window; every result is demuxed from its batch slot
and is bit-identical to a direct `GraphSession` call.  Faults degrade one
request, not the server (`repro.runtime.fault` retry + isolation replay).
"""
from repro.serve.accounting import BatchRecord, ServeAccounting, TenantStats
from repro.serve.protocol import (PROGRAMS, BatchKey, QueryRequest,
                                  QueryResult, QueryTicket, ServeError,
                                  ServerClosed, ServerSaturated, pad_class,
                                  pad_classes)
from repro.serve.scheduler import ContinuousBatcher, Entry, batch_key
from repro.serve.server import GraphServer, ServeConfig

__all__ = [
    "GraphServer", "ServeConfig", "ServeAccounting", "TenantStats",
    "BatchRecord", "BatchKey", "QueryRequest", "QueryResult", "QueryTicket",
    "ServeError", "ServerClosed", "ServerSaturated", "ContinuousBatcher",
    "Entry", "batch_key", "pad_class", "pad_classes", "PROGRAMS",
]
