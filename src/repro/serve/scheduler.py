"""Continuous-batching admission scheduler (DESIGN.md sec. 12).

The same admission trick LLM inference servers use, applied to graph
queries: requests accumulate in per-`BatchKey` queues while the executor is
busy; whenever the executor asks for work the scheduler hands it the most
urgent coalescible group, dispatching early only when a group has filled
its capacity `cap`.  A group that has not filled waits at most
`window_s` past its oldest request's admission -- the max-latency window
that trades p50 latency for batch occupancy.

No wall-clock policy lives anywhere else: the executor calls `next_batch()`
in a loop and the scheduler alone decides when waiting longer could still
improve occupancy.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.serve.protocol import (BatchKey, QueryRequest, QueryTicket,
                                  ServerClosed, ServerSaturated)


@dataclass
class Entry:
    """One queued request with its ticket and admission stamp."""
    key: BatchKey
    req: QueryRequest
    ticket: QueryTicket
    t_admit: float = field(default_factory=time.perf_counter)


class ContinuousBatcher:
    """Thread-safe per-graph admission queue with window dispatch.

    put():        admit an entry (raises ServerSaturated at max_pending --
                  the server's backpressure signal -- and ServerClosed
                  after close()).
    next_batch(): block until a group is dispatchable, then return
                  (key, entries) with len(entries) <= key.cap.  Returns
                  None when closed and drained.
    """

    def __init__(self, *, window_s: float = 0.01, max_pending: int = 1024):
        self.window_s = window_s
        self.max_pending = max_pending
        self._queues: dict[BatchKey, list[Entry]] = {}
        self._pending = 0
        self._closed = False
        self._cond = threading.Condition()

    @property
    def pending(self) -> int:
        with self._cond:
            return self._pending

    def put(self, entry: Entry) -> None:
        with self._cond:
            if self._closed:
                raise ServerClosed("server is stopped; request not admitted")
            if self._pending >= self.max_pending:
                raise ServerSaturated(
                    f"admission queue full ({self._pending} pending >= "
                    f"max_pending={self.max_pending}); retry later")
            self._queues.setdefault(entry.key, []).append(entry)
            self._pending += 1
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _pick(self, now: float, flush: bool):
        """The dispatch decision under the lock: (key, soonest_deadline).
        A group dispatches when full or once its window has expired; with
        `flush` (server stopping) any nonempty group dispatches at once.
        key is None while every group should keep waiting."""
        best_key, best_deadline = None, None
        for key, entries in self._queues.items():
            if not entries:
                continue
            if len(entries) >= key.cap:
                return key, now                      # full: dispatch now
            deadline = entries[0].t_admit + self.window_s
            if best_deadline is None or deadline < best_deadline:
                best_key, best_deadline = key, deadline
        if best_key is not None and (flush or best_deadline <= now):
            return best_key, now
        return None, best_deadline

    def next_batch(self) -> "tuple[BatchKey, list[Entry]] | None":
        with self._cond:
            while True:
                now = time.perf_counter()
                key, deadline = self._pick(now, self._closed)
                if key is not None:
                    entries = self._queues[key]
                    take = min(len(entries), key.cap)
                    batch, rest = entries[:take], entries[take:]
                    if rest:
                        self._queues[key] = rest
                    else:
                        del self._queues[key]
                    self._pending -= take
                    self._cond.notify_all()
                    return key, batch
                if self._closed:
                    if self._pending == 0:
                        return None
                    continue                         # flush the remainder
                self._cond.wait(None if deadline is None
                                else max(deadline - now, 0))


def batch_key(graph_name: str, program: str, config: Any, arg: Any,
              k: "int | None", max_batch: int) -> BatchKey:
    """Coalescing key for one request (see repro.serve.protocol for the
    per-program shapes).  `config` must already be resolved (hashable)."""
    if program in ("bfs", "sssp"):
        return BatchKey(graph_name, program, config, (), cap=max_batch)
    if program == "cc":
        # argument-free: all concurrent CC callers share one execution
        return BatchKey(graph_name, "cc", config, (), cap=max_batch)
    if program == "multi_bfs":
        K = int(len(arg))
        return BatchKey(graph_name, "multi_bfs", config, (K, k), cap=1)
    raise ValueError(f"unknown program {program!r}")
