"""BFSConfig: the ONE config object of the session API (DESIGN.md sec. 7).

Every knob that used to be scattered across the `BFS1D` / `BFS2D` /
`BFS2DDirection` constructors collapses here; direction optimisation is a
flag (`direction=True`), not a separate driver class.  The config is frozen
and hashable so it can key engine and AOT-executable caches.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

from repro.core.types import Grid2D


def resolve_fold_codec(fold_codec=None, fold_bitmap=None):
    """Route the legacy `fold_bitmap` kwarg into the `fold_codec` spelling.

    `fold_bitmap` is deprecated: passing it (either value) warns and, when no
    explicit fold_codec is given, maps True -> "bitmap" / False -> "list".
    """
    if fold_bitmap is not None:
        warnings.warn(
            "fold_bitmap is deprecated; spell the wire format as "
            "BFSConfig(fold_codec='bitmap') (or fold_codec='bitmap' on the "
            "driver shims)", DeprecationWarning, stacklevel=3)
        if fold_codec is None:
            fold_codec = "bitmap" if fold_bitmap else "list"
    return "list" if fold_codec is None else fold_codec


@dataclasses.dataclass(frozen=True)
class BFSConfig:
    """All knobs of a BFS query plan.

    grid:        Grid2D | (R, C) | "RxC" | None.  None derives 1 x D from the
                 bound mesh (or all local devices) at planning time.
    fold_codec:  "list" | "bitmap" | "delta" | FoldCodec instance -- the fold
                 wire format (DESIGN.md sec. 4).
    edge_chunk:  CSC scan chunk size of the expand phase.
    dedup:       winner-selection method ("scatter" | "sort").
    max_levels:  level-loop bound.
    direction:   Beamer direction optimisation.  False = pure top-down;
                 True or "adaptive" = per-level alpha/beta switch inside the
                 compiled loop; "bottomup" = every level bottom-up (the
                 benchmark sweep's fixed arm).  Any non-False spelling plans
                 the CSR twin lazily on first use.  Outputs are
                 bit-identical to top-down in every mode.
    alpha:       adaptive switch ENTRY threshold (bottom-up when the global
                 frontier exceeds n / alpha).
    beta:        adaptive switch EXIT threshold (back to top-down once the
                 frontier falls below n / beta; beta > alpha gives the
                 hysteresis band that stops boundary thrash).
    bottomup:    bottom-up kernel implementation (DESIGN.md sec. 11): same
                 spellings and rules as `expand`, with REPRO_BOTTOMUP as
                 the environment override.  Every path is bit-identical.
    row_axes /
    col_axes:    mesh axes the processor grid's rows/columns span.
    expand_fn:   explicit chunk-expansion override for the CSC scan (wins
                 over `expand` when given).
    expand:      local-expand implementation (DESIGN.md sec. 9):
                 "pallas" (the fused kernel, compiled), "pallas-interpret"
                 (the same kernel body in interpret mode, for CPU testing),
                 "reference" (the inline jnp scan), or "auto" (Pallas on
                 GPU/TPU, reference on CPU; the REPRO_EXPAND environment
                 variable overrides, so CI can force pallas-interpret).
                 Every path is bit-identical.
    fold:        fold-pipeline implementation (DESIGN.md sec. 10): the
                 codec encode/decode kernels and the prefix-sum compaction
                 that replaces the per-level argsorts.  Same spellings and
                 rules as `expand`, with REPRO_FOLD as the environment
                 override.  Every path is bit-identical.
    exchange:    fold exchange strategy (DESIGN.md sec. 14): "flat" (one
                 all_to_all per fold -- every column sends C-1 direct
                 messages), "butterfly" (log2(C) pairwise ppermute stages
                 over the XOR hypercube -- log2(C) messages per column at
                 (C/2)*log2(C) payload volume), or "auto" (butterfly
                 whenever it strictly reduces message count: power-of-two
                 C >= 4 on a single column axis; flat otherwise).  "auto"
                 is normalised to the resolved name when a session binds
                 the config to a planned grid, so the AOT caches key on the
                 concrete strategy.  Outputs are bit-identical across
                 strategies for every codec, program and expand/fold path.
    telemetry:   per-level trace channel (DESIGN.md sec. 13).  When True,
                 every search also returns a `repro.obs.LevelTrace` (per
                 level: global + per-device frontier counts, scanned edges,
                 folded entries, fold wire bytes, direction), readable as
                 `output.trace` / `GraphSession.last_trace()`.  Static: it
                 participates in every engine/AOT cache key, so the off
                 path compiles to exactly the untraced program.  Outputs
                 are bit-identical either way.
    fault_tolerance:  mid-traversal recovery (DESIGN.md sec. 15).  When
                 True, sessions run the level loop in checkpoint-bounded
                 segments (`ckpt_every` levels per jitted segment) so a
                 traversal can snapshot its carry between segments, survive
                 injected device loss, and resume -- same grid or shrunken.
                 Static and cache-keyed like `telemetry`: the off path
                 builds exactly the single-while_loop program, and segmented
                 outputs are bit-identical to it.
    ckpt_every:  levels per resumable segment when fault_tolerance=True
                 (the K of "checkpoint every K levels").
    """
    grid: Any = None
    fold_codec: Any = "list"
    edge_chunk: int = 8192
    dedup: str = "scatter"
    max_levels: int = 64
    direction: Any = False
    alpha: int = 24
    beta: int = 64
    row_axes: tuple = ("r",)
    col_axes: tuple = ("c",)
    expand_fn: Any = None
    expand: str = "auto"
    fold: str = "auto"
    bottomup: str = "auto"
    exchange: str = "flat"
    telemetry: bool = False
    fault_tolerance: bool = False
    ckpt_every: int = 1

    def __post_init__(self):
        for f in ("row_axes", "col_axes"):
            v = getattr(self, f)
            if v is not None and not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(v))

    @property
    def codec_name(self) -> str:
        fc = self.fold_codec
        return fc if isinstance(fc, str) else getattr(fc, "name", repr(fc))

    @property
    def direction_mode(self):
        """The direction spelling normalised: None (pure top-down),
        "adaptive" or "bottomup"."""
        d = self.direction
        if d is False or d is None:
            return None
        if d is True:
            return "adaptive"
        if d in ("adaptive", "bottomup"):
            return d
        raise ValueError(
            f"direction={d!r}: expected False | True | 'adaptive' | "
            f"'bottomup'")

    @property
    def expand_path(self) -> str:
        """The concrete expand implementation this config selects NOW
        ("auto" resolves against REPRO_EXPAND and the default backend)."""
        from repro.kernels.select import resolve_expand_path

        return resolve_expand_path(self.expand)

    @property
    def fold_path(self) -> str:
        """The concrete fold implementation this config selects NOW
        ("auto" resolves against REPRO_FOLD and the default backend)."""
        from repro.kernels.select import resolve_fold_path

        return resolve_fold_path(self.fold)

    @property
    def bottomup_path(self) -> str:
        """The concrete bottom-up implementation this config selects NOW
        ("auto" resolves against REPRO_BOTTOMUP and the default backend)."""
        from repro.kernels.select import resolve_bottomup_path

        return resolve_bottomup_path(self.bottomup)

    @property
    def exchange_name(self) -> str:
        """The exchange spelling as a hashable cache-key component ("auto"
        until `resolve_exchange` normalises it against a planned grid)."""
        ex = self.exchange
        return ex if isinstance(ex, str) else getattr(ex, "name", repr(ex))

    def resolve_exchange(self, grid) -> "BFSConfig":
        """This config with exchange="auto" resolved against the planned
        grid (butterfly on power-of-two C >= 4 over one column axis, flat
        otherwise) and an explicit strategy VALIDATED against it -- a
        butterfly request on a grid it cannot route raises the ValueError
        here, at session construction, naming the strategy that works."""
        from repro.dist.strategy import get_exchange

        strat = get_exchange(self.exchange, grid, self.col_axes or ())
        if isinstance(self.exchange, str) and self.exchange != strat.name:
            return dataclasses.replace(self, exchange=strat.name)
        return self

    @property
    def engine_key(self) -> tuple:
        """What makes two configs share one DistBFSEngine (and hence one
        AOT-compile cache line, together with graph shape and batch size).

        Uses the RESOLVED expand/fold/bottomup paths and direction MODE, so
        "auto" configs re-key correctly if REPRO_EXPAND / REPRO_FOLD /
        REPRO_BOTTOMUP changes between engine builds in one process.
        `exchange` keys by name; exchange="auto" needs the planned grid to
        resolve, so `GraphSession` normalises it (via `resolve_exchange`)
        before any cache is keyed."""
        return (self.codec_name, self.direction_mode, self.edge_chunk,
                self.dedup, self.max_levels, self.alpha, self.beta,
                self.row_axes, self.col_axes, self.expand_fn,
                self.expand_path, self.fold_path, self.bottomup_path,
                self.exchange_name, self.telemetry,
                self.fault_tolerance, self.ckpt_every)

    def algo_engine_key(self, program_key: tuple, codec_name: str,
                        max_levels: int) -> tuple:
        """Cache key for a non-BFS frontier-program engine (DESIGN.md
        sec. 8): the program's identity plus the config knobs the engine
        bakes in.  `codec_name`/`max_levels` are per-call (the program's
        codec hint / iteration bound may override the BFS spellings).
        Direction mode / alpha / beta ride in via `program_key` (the
        DirectionProgram wrapper's key); the resolved bottom-up kernel path
        is an engine knob, so it keys here."""
        return ("algo", program_key, codec_name, self.edge_chunk, self.dedup,
                max_levels, self.row_axes, self.col_axes, self.expand_fn,
                self.expand_path, self.fold_path, self.bottomup_path,
                self.exchange_name, self.telemetry,
                self.fault_tolerance, self.ckpt_every)

    def resolve_grid(self, n: int, mesh=None) -> Grid2D:
        """Concretise the `grid` spelling against n vertices (padding up)."""
        g = self.grid
        if isinstance(g, Grid2D):
            return g
        if g is None:
            if mesh is not None:
                sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
                R = C = 1
                for a in (self.row_axes or ()):
                    R *= sizes[a]
                for a in (self.col_axes or ()):
                    C *= sizes[a]
            else:
                import jax
                R, C = 1, jax.device_count()
        elif isinstance(g, str):
            R, C = (int(x) for x in g.lower().split("x"))
        else:
            R, C = g
        return Grid2D.for_vertices(n, R, C)
