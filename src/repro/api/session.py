"""Two-phase session API: plan/residency vs query (DESIGN.md sec. 7).

Phase 1 -- `DistGraph.from_edges(edges, config)` does everything that is
per-GRAPH and per-LAYOUT: grid resolution, topology/mesh binding, the CSC
partition, and device placement.  The CSR twin (what bottom-up traversal
scans) is planned LAZILY by the first direction-enabled query and cached on
the graph.  The result is a resident graph that answers many queries.

Phase 2 -- `GraphSession.bfs(roots)` runs searches against the resident
graph.  A scalar root returns one `BFSOutput`; a batch of roots executes as
ONE compiled program (the engine's level loop under `lax.map` over the roots
axis) and returns batched outputs.  Executables are AOT-compiled with
`jit(...).lower().compile()` and cached on the DistGraph keyed by
(engine key = codec/direction/..., graph array shapes, batch size), so a
Graph500-style 64-root sweep traces the level loop exactly once.
"""
from __future__ import annotations

import warnings
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from repro.algos import (
    BFSLevelsProgram, CCOutput, ConnectedComponentsProgram, DirectionProgram,
    FrontierEngine, MultiBFSOutput, MultiSourceBFSProgram, SSSPOutput,
    SSSPProgram)
from repro.api.config import BFSConfig
from repro.core.partition import (partition_2d, partition_2d_csr,
                                  partition_edge_vals,
                                  partition_edge_vals_csr)
from repro.core.types import BFSOutput, LocalGraph2D
from repro.core.validate import validate_bfs
from repro.dist import multihost
from repro.dist.engine import DistBFSEngine
from repro.dist.topology import Topology


def check_vertex_ids(ids, n: int, what: str = "roots") -> None:
    """Session-boundary input validation (DESIGN.md sec. 12).

    Out-of-range or wrong-dtype vertex ids used to surface as opaque JAX
    errors mid-trace (or, worse, silently wrap once cast to int32); a
    serving layer must reject a bad request before it reaches a compiled
    program.  Raises ValueError naming the graph's n and the expected
    dtype; accepts anything integer-typed convertible to int32.
    """
    arr = np.asarray(ids)
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{what} must be integer vertex ids (int32-convertible), got "
            f"dtype {arr.dtype}")
    if arr.size:
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 0 or hi >= n:
            bad = lo if lo < 0 else hi
            raise ValueError(
                f"{what} contain out-of-range vertex id {bad}; this graph "
                f"has n = {n} vertices, valid ids are 0 <= id < {n}")


class AOTCache:
    """Bounded LRU over AOT-compiled executables, with serve-grade stats.

    One entry per (engine key, graph shapes, batch size) -- before the
    bound, a sweep over many batch sizes B (or many engine configs) grew
    the per-DistGraph executable cache without limit.  Eviction recompiles
    on next use, so the bound trades compile time for memory, never
    correctness.  `hits` / `misses` / `evictions` feed `repro.serve`
    accounting.
    """

    def __init__(self, maxsize: int = 32):
        if maxsize < 1:
            raise ValueError(f"AOTCache needs maxsize >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._entries = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        try:
            value = self._entries[key]
        except KeyError:
            self.misses += 1
            return default
        self._entries.move_to_end(key)
        self.hits += 1
        return value

    def __setitem__(self, key, value):
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1

    def __len__(self):
        return len(self._entries)

    def __contains__(self, key):          # no stats: introspection only
        return key in self._entries

    def stats(self) -> dict:
        return {"size": len(self._entries), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


def build_engine(topology: Topology, config: BFSConfig) -> DistBFSEngine:
    """One engine per (topology, engine_key): the level-loop program with the
    config's codec/chunking/direction baked in, independent of graph DATA."""
    program = None
    if config.direction_mode is not None:
        program = DirectionProgram(BFSLevelsProgram(),
                                   mode=config.direction_mode,
                                   alpha=config.alpha, beta=config.beta)
    return DistBFSEngine(
        topology, fold_codec=config.fold_codec, edge_chunk=config.edge_chunk,
        max_levels=config.max_levels, expand=config.expand,
        expand_fn=config.expand_fn, fold=config.fold, dedup=config.dedup,
        bottomup=config.bottomup, exchange=config.exchange, program=program,
        telemetry=config.telemetry, fault_tolerance=config.fault_tolerance,
        ckpt_every=config.ckpt_every)


class DistGraph:
    """A resident, partitioned graph: plan once, query many.

    Holds the device-placed CSC blocks (and CSR twin when planned), the
    topology, and the engine + AOT-executable caches every `GraphSession`
    over this graph shares.
    """

    def __init__(self, topology: Topology, csc: LocalGraph2D, *, csr=None,
                 weights=None, edges=None, n: int | None = None,
                 config: BFSConfig = None, csr_weights=None,
                 weights_host=None, aot_cache_size: int = 32):
        self.topology = topology
        self.grid = topology.grid
        self.mesh = topology.mesh
        self.csc = csc
        self.csr = csr
        self.weights = weights       # (R, C, e_max) per-edge values or None
        self.csr_weights = csr_weights   # the CSR-ordered copy (SSSP + dir)
        self.n = int(n) if n is not None else topology.grid.n
        self.config = config if config is not None else BFSConfig()
        # host edge/weight copies retained ONLY while they may still be
        # needed to plan the CSR twin lazily (dropped once CSR exists; see
        # release_edges)
        self._edges = edges if csr is None else None
        self._weights_host = weights_host if csr is None else None
        self._engines = {}           # engine key -> engine (BFS or algo)
        # (engine key, shapes, B) -> executable; bounded LRU so a sweep over
        # many batch sizes / engine configs cannot grow without limit (the
        # deprecated driver shims may swap in a plain shared dict)
        self._compiled = AOTCache(aot_cache_size)

    @classmethod
    def from_edges(cls, edges, config: BFSConfig = None, *, mesh=None,
                   n: int | None = None, weights=None,
                   aot_cache_size: int = 32) -> "DistGraph":
        """Plan a graph into residency: partition + place on the mesh.

        edges: (2, E) [src, dst] array (host or device).  n defaults to
        max vertex id + 1; the grid pads it up to a multiple of R*C.
        weights: optional (E,) per-edge values (uint8 for SSSP), laid out in
        the CSC partition order and made resident alongside the graph.
        aot_cache_size: bound of the per-graph AOT-executable LRU (one
        entry per (engine key, shapes, batch size); see `AOTCache`).
        """
        config = config if config is not None else BFSConfig()
        edges_np = np.asarray(edges)
        if n is None:
            n = int(edges_np.max()) + 1 if edges_np.size else 1
        grid = config.resolve_grid(n, mesh)
        topology = Topology.for_grid(grid, mesh, config.row_axes,
                                     config.col_axes)
        lg = partition_2d(edges_np, grid)
        # device placement: per-device (R, C, ...) arrays land sharded over
        # the grid axes -- a global jax.Array in a process group (every
        # process materialises only its addressable shards), a plain local
        # array otherwise (repro.dist.multihost)
        place = cls._placer(topology)
        csc = LocalGraph2D(place(lg.col_off), place(lg.row_idx),
                           place(lg.nnz))
        w = None
        w_host = None
        if weights is not None:
            w_host = np.asarray(weights)
            w = place(partition_edge_vals(edges_np, w_host, grid))
        # the CSR twin is planned LAZILY on the first query that needs it
        # (a direction-enabled session/algo call -> ensure_csr), so planning
        # with direction on costs nothing until bottom-up actually runs
        return cls(topology, csc, weights=w, edges=edges_np, n=n,
                   config=config, weights_host=w_host,
                   aot_cache_size=aot_cache_size)

    @staticmethod
    def _placer(topology: Topology):
        """Placement fn for per-device (R, C, ...) arrays on this topology
        (global sharded array in a process group, plain local otherwise)."""
        return lambda x: multihost.put_dev(x, topology.mesh,
                                           topology.dev_spec)

    def ensure_csr(self):
        """Plan the CSR twin on demand (the first direction-enabled query);
        also lays the per-edge weights out in CSR order when resident, so
        direction-optimised SSSP can pull over them."""
        if self.csr is None:
            if self._edges is None:
                raise ValueError(
                    "direction=True needs the CSR twin, but this DistGraph "
                    "was built without edges; pass csr= or use from_edges")
            place = self._placer(self.topology)
            self.csr = {k: place(v)
                        for k, v in partition_2d_csr(self._edges,
                                                     self.grid).items()}
            if self._weights_host is not None:
                self.csr_weights = place(partition_edge_vals_csr(
                    self._edges, self._weights_host, self.grid))
            self._edges = None       # both layouts resident -> edges done
            self._weights_host = None
        return self.csr

    def release_edges(self):
        """Drop the retained host edge/weight copies (long-lived serving
        graphs that will never open a direction-enabled session)."""
        self._edges = None
        self._weights_host = None

    def cache_stats(self) -> dict:
        """Hit/miss/eviction counters of the AOT-executable cache (surfaced
        in `repro.serve` accounting / the metrics registry).  The deprecated
        driver shims share a plain dict here; stats then degrade to
        size-only."""
        cache = self._compiled
        if isinstance(cache, AOTCache):
            return cache.stats()
        return {"size": len(cache), "maxsize": None, "hits": None,
                "misses": None, "evictions": None}

    def aot_cache_stats(self) -> dict:
        """Deprecated spelling of `cache_stats()` (same dict)."""
        warnings.warn(
            "DistGraph.aot_cache_stats() is deprecated; use "
            "DistGraph.cache_stats() (same dict)", DeprecationWarning,
            stacklevel=2)
        return self.cache_stats()

    def engine_for(self, config: BFSConfig) -> DistBFSEngine:
        key = config.engine_key
        eng = self._engines.get(key)
        if eng is None:
            eng = build_engine(self.topology, config)
            self._engines[key] = eng
        return eng

    def session(self, config: BFSConfig = None) -> "GraphSession":
        """Open a query session (defaults to the planning config)."""
        return GraphSession(self, config if config is not None
                            else self.config)


class GraphSession:
    """Query phase: many BFS searches over one resident DistGraph."""

    def __init__(self, graph: DistGraph, config: BFSConfig = None, *,
                 engine: DistBFSEngine = None):
        self.graph = graph
        self.config = config if config is not None else graph.config
        # exchange="auto" resolves against the PLANNED grid (butterfly on
        # power-of-two C >= 4, flat otherwise), and an explicit strategy is
        # validated here -- so every engine/AOT cache below keys on the
        # concrete strategy, and an impossible request fails at session
        # construction, not mid-trace
        self.config = self.config.resolve_exchange(graph.grid)
        if self.config.grid is not None:
            want = self.config.resolve_grid(graph.n, graph.mesh)
            if want != graph.grid:
                raise ValueError(
                    f"session config asks for a {want.R}x{want.C} grid but "
                    f"the resident graph is planned {graph.grid.R}x"
                    f"{graph.grid.C}; re-plan with DistGraph.from_edges")
        if self.config.direction_mode is not None:
            graph.ensure_csr()
        self.engine = engine if engine is not None \
            else graph.engine_for(self.config)
        # last LevelTrace (scalar) / tuple of traces (batched) any query of
        # THIS session produced; None until a telemetry=True query completes
        self._last_trace = None

    def last_trace(self):
        """The per-level `repro.obs.LevelTrace` of this session's most
        recent query (DESIGN.md sec. 13): a single trace for scalar queries,
        a tuple of B for batched ones.  None unless the session config has
        telemetry=True and a query has run."""
        return self._last_trace

    @property
    def _extra(self) -> tuple:
        if self.config.direction_mode is not None:
            csr = self.graph.csr
            return (csr["row_off"], csr["col_idx"])
        return ()

    def compiled_for(self, B: int):
        """AOT executable for a (B,)-roots sweep, cached on the DistGraph
        keyed by (engine key, graph array shapes, B).

        Public capacity surface: `repro.serve` warms its padding classes
        through this before admitting traffic, so the first live batch of
        each size pays no compile.  Returns the executable (callers rarely
        invoke it directly -- `bfs` is the ergonomic path)."""
        if B < 1:
            raise ValueError(f"batch capacity B must be >= 1, got {B}")
        g = self.graph.csc
        key = (self.config.engine_key, g.col_off.shape, g.row_idx.shape, B)
        compiled = self.graph._compiled.get(key)
        if compiled is None:
            roots_aval = multihost.arg_aval((B,), jnp.int32,
                                            self.graph.mesh)
            compiled = self.engine._run_batch.lower(
                g.col_off, g.row_idx, g.nnz, *self._extra,
                roots_aval).compile()
            self.graph._compiled[key] = compiled
        return compiled

    def _run_recoverable(self, eng, arg, *extra, B=None, recovery=None):
        """Fault-tolerant query path: the segmented engine loop under the
        recovery driver (DESIGN.md sec. 15) instead of one whole-search
        executable.  Bit-identical outputs; `recovery` is the RecoveryPlan
        carrying checkpointer / injector / retry policy."""
        from repro.runtime.recovery import run_segmented
        return run_segmented(eng, self.graph.csc, arg, *extra, B=B,
                             n=self.graph.n, plan=recovery)

    def _check_recovery(self, recovery) -> bool:
        if recovery is not None and not self.config.fault_tolerance:
            raise ValueError(
                "recovery= needs a fault-tolerant session; open it with "
                "BFSConfig(fault_tolerance=True)")
        return self.config.fault_tolerance

    def bfs(self, roots, validate=False, recovery=None) -> BFSOutput:
        """Search from a scalar root or a (B,) batch of roots.

        Scalar: global (n,) level/pred (vertex-block order = plain global
        vertex ids, padded to the grid), scalar n_levels, exact int
        edges_scanned.  Batch: (B, n) level/pred, (B,) n_levels, tuple of B
        edges_scanned -- bit-identical to running the roots one by one.

        validate: False (default) | True | (2, E) edge array.  Truthy runs
        the Graph500 rules (`repro.core.validate.validate_bfs`) on every
        root's output against the input edge list -- `True` uses the host
        edges the DistGraph retains while the CSR twin is unplanned; pass
        the array explicitly once they have been released.  Raises
        AssertionError on any rule violation.

        recovery: optional `repro.runtime.RecoveryPlan` (checkpointer /
        loss injector / retry policy) for a fault_tolerance=True session;
        the query then runs the segmented level loop and can resume.
        """
        scalar = np.ndim(roots) == 0
        check_vertex_ids(roots, self.graph.n, "roots")
        roots_np = np.atleast_1d(np.asarray(roots, np.int32))
        if roots_np.ndim != 1:
            raise ValueError(f"roots must be a scalar or 1D batch, got "
                             f"shape {roots_np.shape}")
        roots_arr = multihost.put_replicated(roots_np, self.graph.mesh)
        B = roots_np.shape[0]
        g = self.graph.csc
        if self._check_recovery(recovery):
            out = self._run_recoverable(self.engine, roots_arr,
                                        *self._extra, B=B,
                                        recovery=recovery)
        else:
            outs = self.compiled_for(B)(
                g.col_off, g.row_idx, g.nnz, *self._extra, roots_arr)
            out = self.engine.assemble_batch(outs, B)
        if validate is not False and validate is not None:
            self._validate(out, roots_np, validate)
        if scalar:
            out = BFSOutput(level=out.level[0], pred=out.pred[0],
                            n_levels=out.n_levels[0],
                            edges_scanned=out.edges_scanned[0],
                            directions=None if out.directions is None
                            else out.directions[0],
                            trace=None if out.trace is None
                            else out.trace[0])
        if out.trace is not None:
            self._last_trace = out.trace
        return out

    def _validate(self, out: BFSOutput, roots, validate) -> None:
        """Graph500 rule check of a batched output (see `bfs(validate=)`)."""
        edges = self.graph._edges if isinstance(validate, bool) else validate
        if edges is None:
            raise ValueError(
                "bfs(validate=True) needs the host edge list, but this "
                "DistGraph has released it (CSR planned or release_edges); "
                "pass the edge array: bfs(roots, validate=edges)")
        n = self.graph.n
        level = np.asarray(out.level)
        pred = np.asarray(out.pred)
        for b, root in enumerate(roots):
            validate_bfs(edges, level[b][:n], pred[b][:n], int(root))

    # ------------------------------------------------------------------
    # Frontier programs beyond BFS (DESIGN.md sec. 8)
    # ------------------------------------------------------------------

    def _algo_engine(self, program, fold_codec, max_levels):
        """Fetch/build the FrontierEngine for `program`, cached on the
        DistGraph like the BFS engines (config codec/chunking apply unless
        overridden per call).  A direction-enabled session wraps the program
        in the direction-optimising driver, so CC / SSSP / multi-source BFS
        inherit the per-level adaptive switch."""
        codec = fold_codec if fold_codec is not None else program.codec_hint
        codec_name = codec if isinstance(codec, str) \
            else getattr(codec, "name", repr(codec))
        if self.config.direction_mode is not None:
            self.graph.ensure_csr()
            program = DirectionProgram(program,
                                       mode=self.config.direction_mode,
                                       alpha=self.config.alpha,
                                       beta=self.config.beta)
        key = self.config.algo_engine_key(program.key, codec_name,
                                          max_levels)
        eng = self.graph._engines.get(key)
        if eng is None:
            eng = FrontierEngine(
                self.graph.topology, program, fold_codec=codec,
                edge_chunk=self.config.edge_chunk, max_levels=max_levels,
                expand=self.config.expand, expand_fn=self.config.expand_fn,
                fold=self.config.fold, dedup=self.config.dedup,
                bottomup=self.config.bottomup,
                exchange=self.config.exchange,
                telemetry=self.config.telemetry,
                fault_tolerance=self.config.fault_tolerance,
                ckpt_every=self.config.ckpt_every)
            self.graph._engines[key] = eng
        return eng, key

    def _algo_csr_extra(self, *, weights: bool = False) -> tuple:
        """The CSR-twin arrays a direction-enabled algo call appends after
        its regular extras (empty when direction is off)."""
        if self.config.direction_mode is None:
            return ()
        csr = self.graph.ensure_csr()
        if not weights:
            return (csr["row_off"], csr["col_idx"])
        if self.graph.csr_weights is None:
            raise ValueError(
                "direction-optimised sssp needs the CSR-ordered weight "
                "copy; plan the graph with DistGraph.from_edges(edges, "
                "config, weights=w) so ensure_csr can lay it out")
        return (csr["row_off"], csr["col_idx"], self.graph.csr_weights)

    def _algo_compiled(self, eng, key, arg_aval, *extra, batched=False):
        """AOT executable for one frontier program, cached on the DistGraph
        keyed by (engine key, graph array shapes, arg shape)."""
        g = self.graph.csc
        ckey = (key, g.col_off.shape, g.row_idx.shape, batched,
                arg_aval.shape)
        compiled = self.graph._compiled.get(ckey)
        if compiled is None:
            fn = eng._run_batch if batched else eng._run
            compiled = fn.lower(g.col_off, g.row_idx, g.nnz, *extra,
                                arg_aval).compile()
            self.graph._compiled[ckey] = compiled
        return compiled

    def connected_components(self, fold_codec=None,
                             recovery=None) -> CCOutput:
        """Labels of every vertex's connected component (min member id).

        Assumes the planned edge list is symmetrised (as the Graph500-style
        generator produces); on a directed list the label is the smallest
        vertex id with a directed path to each vertex.  fold_codec: None =
        the program's hint ("bitmap"); any codec gives identical labels.
        recovery: see `bfs`.
        """
        max_levels = self.graph.grid.n + 1     # diameter bound
        eng, key = self._algo_engine(ConnectedComponentsProgram(),
                                     fold_codec, max_levels)
        g = self.graph.csc
        extra = self._algo_csr_extra()
        arg = multihost.put_replicated(np.int32(0), self.graph.mesh)
        if self._check_recovery(recovery):
            out = self._run_recoverable(eng, arg, *extra, recovery=recovery)
        else:
            compiled = self._algo_compiled(
                eng, key,
                multihost.arg_aval((), jnp.int32, self.graph.mesh), *extra)
            outs = compiled(g.col_off, g.row_idx, g.nnz, *extra, arg)
            out = eng.assemble(outs, None)
        if out.trace is not None:
            self._last_trace = out.trace
        return out

    def sssp(self, roots, fold_codec=None, recovery=None) -> SSSPOutput:
        """Shortest distances over the planned per-edge uint8 weights.

        Scalar root -> (n,) int32 distances (-1 unreachable); a (B,) batch
        runs as ONE compiled program (lax.map over roots, like `bfs`) ->
        (B, n).  Requires `DistGraph.from_edges(..., weights=)`.
        recovery: see `bfs`.
        """
        if self.graph.weights is None:
            raise ValueError(
                "sssp needs resident per-edge weights; plan the graph with "
                "DistGraph.from_edges(edges, config, weights=w)")
        scalar = np.ndim(roots) == 0
        check_vertex_ids(roots, self.graph.n, "roots")
        roots_np = np.atleast_1d(np.asarray(roots, np.int32))
        if roots_np.ndim != 1:
            raise ValueError(f"roots must be a scalar or 1D batch, got "
                             f"shape {roots_np.shape}")
        roots_arr = multihost.put_replicated(roots_np, self.graph.mesh)
        B = roots_np.shape[0]
        max_levels = self.graph.grid.n + 1     # Bellman-Ford round bound
        eng, key = self._algo_engine(SSSPProgram(), fold_codec, max_levels)
        g, w = self.graph.csc, self.graph.weights
        extra = (w,) + self._algo_csr_extra(weights=True)
        if self._check_recovery(recovery):
            out = self._run_recoverable(eng, roots_arr, *extra, B=B,
                                        recovery=recovery)
        else:
            compiled = self._algo_compiled(
                eng, key,
                multihost.arg_aval((B,), jnp.int32, self.graph.mesh),
                *extra, batched=True)
            out = eng.assemble(
                compiled(g.col_off, g.row_idx, g.nnz, *extra, roots_arr), B)
        if scalar:
            out = SSSPOutput(dist=out.dist[0], n_iters=out.n_iters[0],
                             edges_scanned=out.edges_scanned[0],
                             directions=None if out.directions is None
                             else out.directions[0],
                             trace=None if out.trace is None
                             else out.trace[0])
        if out.trace is not None:
            self._last_trace = out.trace
        return out

    def multi_bfs(self, sources, k: int | None = None,
                  fold_codec=None, recovery=None) -> MultiBFSOutput:
        """Simultaneous BFS from a (K,) source set (ONE shared frontier).

        Returns per-vertex hops to the nearest source and the claiming
        source's index (same-wave ties -> minimum index).  k bounds the
        sweep to k hops: `level >= 0` is then the union k-hop neighborhood
        of the sources (the models/gnn sampling primitive).  Contrast
        `bfs(roots)`, which runs K independent full searches.
        recovery: see `bfs`.
        """
        check_vertex_ids(sources, self.graph.n, "sources")
        sources_np = np.asarray(sources, np.int32)
        if sources_np.ndim != 1 or sources_np.shape[0] == 0:
            raise ValueError(f"sources must be a non-empty 1D array, got "
                             f"shape {sources_np.shape}")
        sources_arr = multihost.put_replicated(sources_np, self.graph.mesh)
        max_levels = int(k) if k is not None else self.config.max_levels
        eng, key = self._algo_engine(MultiSourceBFSProgram(), fold_codec,
                                     max_levels)
        g = self.graph.csc
        extra = self._algo_csr_extra()
        if self._check_recovery(recovery):
            out = self._run_recoverable(eng, sources_arr, *extra,
                                        recovery=recovery)
        else:
            compiled = self._algo_compiled(
                eng, key,
                multihost.arg_aval(sources_np.shape, jnp.int32,
                                   self.graph.mesh), *extra)
            outs = compiled(g.col_off, g.row_idx, g.nnz, *extra,
                            sources_arr)
            out = eng.assemble(outs, None)
        if out.trace is not None:
            self._last_trace = out.trace
        return out
