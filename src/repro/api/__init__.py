"""Public session API (DESIGN.md sec. 7): plan once, query many.

    from repro.api import BFSConfig, DistGraph

    graph = DistGraph.from_edges(edges, BFSConfig(grid=(2, 4)))
    session = graph.session()
    out = session.bfs(roots)        # scalar root, or a batch in ONE program

Frontier programs beyond BFS (DESIGN.md sec. 8) share the residency:

    cc = session.connected_components()
    sp = session.sssp(root)         # needs from_edges(..., weights=w)
    mb = session.multi_bfs(sources, k=2)
"""
from repro.algos import CCOutput, MultiBFSOutput, SSSPOutput
from repro.api.config import BFSConfig, resolve_fold_codec
from repro.api.session import DistGraph, GraphSession, build_engine

__all__ = ["BFSConfig", "DistGraph", "GraphSession", "build_engine",
           "resolve_fold_codec", "CCOutput", "SSSPOutput", "MultiBFSOutput"]
