"""repro: distributed 2D-partitioned BFS (Bisson/Bernaschi/Mastrostefano 2014)
as a production-grade JAX framework, plus the assigned architecture pool."""

__version__ = "0.1.0"
