"""Data pipeline: deterministic synthetic LM token stream (zipfian unigrams
+ short-range induction structure so a real LM can actually fit it), sharded
placement, and a double-buffered host prefetcher."""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def synthetic_lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                         n_batches: int | None = None):
    """Yields (tokens, labels) int32 (batch, seq).  Zipf unigram marginals
    with injected copy patterns (position t repeats t - period)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    i = 0
    while n_batches is None or i < n_batches:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
        period = 1 + (i % 7)
        mask = rng.random((batch, seq + 1)) < 0.5
        idx = np.arange(seq + 1)
        src = np.clip(idx - period, 0, None)
        toks = np.where(mask, toks[:, src], toks)
        yield toks[:, :-1], toks[:, 1:]
        i += 1


def shard_batch(batch, mesh, spec=P(("pod", "data"))):
    """Place host arrays on the mesh (drops axes the mesh lacks)."""
    names = set(mesh.axis_names)
    parts = []
    for e in (spec if spec else ()):
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            parts.append(kept if kept else None)
        else:
            parts.append(e if e in names else None)
    s = NamedSharding(mesh, P(*parts))
    return jax.tree.map(lambda a: jax.device_put(a, s), batch)


class Prefetcher:
    """Host-side double buffering (the CPUs-as-coprocessors role)."""

    def __init__(self, it, depth: int = 2):
        self.q = queue.Queue(maxsize=depth)
        self.it = it
        self._done = object()
        self.t = threading.Thread(target=self._fill, daemon=True)
        self.t.start()

    def _fill(self):
        for x in self.it:
            self.q.put(x)
        self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self.q.get()
        if x is self._done:
            raise StopIteration
        return x
