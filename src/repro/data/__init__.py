from repro.data.pipeline import synthetic_lm_batches, shard_batch
