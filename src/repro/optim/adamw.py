"""AdamW with decoupled weight decay + linear-warmup cosine schedule.
Optimizer state is a pytree twin of params; the train step shards it
ZeRO-style over the data axes (see repro/train/train_step.py)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nhat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        newp = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(nhat) + cfg.eps)
            + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    newp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gn, "lr": lr}
