from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.grad_compress import (topk_compress_init, topk_compress,
                                       int8_compress, int8_decompress)
