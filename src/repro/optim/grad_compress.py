"""Gradient compression for cross-pod data parallelism.

Two codecs, composable with the train step's gradient sync:
  * top-k sparsification with ERROR FEEDBACK (memory pytree carries the
    residual; Stich et al. / Deep Gradient Compression) -- used across the
    "pod" axis where links are the scarcest;
  * int8 range quantisation (per-tensor scale) for the dense remainder.

Both are pure functions so they compose with pjit/shard_map; the all-reduce
of the compressed representation is an all_gather of (idx, val) pairs (top-k)
or an int8 psum emulation (quantise -> sum fp32 -> requantise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_compress_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _topk_one(g, err, frac):
    g = g.astype(jnp.float32) + err
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    val, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    sparse_flat = jnp.zeros_like(flat).at[idx].set(kept)
    new_err = flat - sparse_flat
    return (idx.astype(jnp.int32), kept), new_err.reshape(g.shape)


def topk_compress(grads, err_state, *, frac=0.01):
    """Returns (compressed list of (idx, val) in leaf order, new
    error-feedback pytree, densify fn)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_state)
    results = [_topk_one(g, e, frac) for g, e in zip(leaves, errs)]
    comp = [r[0] for r in results]
    err = jax.tree.unflatten(treedef, [r[1] for r in results])

    def densify(comp_list, like):
        lv, td = jax.tree.flatten(like)
        dense = [jnp.zeros((p.size,), jnp.float32).at[idx].set(val)
                 .reshape(p.shape) for (idx, val), p in zip(comp_list, lv)]
        return jax.tree.unflatten(td, dense)

    return comp, err, densify


def int8_compress(g):
    """Per-tensor symmetric int8: returns (q int8, scale f32)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127) \
        .astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale
