from repro.train.train_step import TrainConfig, make_train_step, TrainState
from repro.train.serve_step import make_serve_step
