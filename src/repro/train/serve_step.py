"""Serving-side step factories: batched decode with a persistent KV cache.

The dry-run lowers these for the decode_* / long_* shapes: the cache is an
input/output (donated), one token is produced per call."""
from __future__ import annotations

from typing import Callable


def make_serve_step(decode_fn: Callable):
    """decode_fn(params, cache, tokens, pos) -> (next_tokens, cache)."""

    def serve_step(params, cache, tokens, pos):
        nxt, cache = decode_fn(params, cache, tokens, pos)
        return nxt, cache

    return serve_step
