"""Generic pjit train step: loss -> grad -> AdamW, with

  * gradient accumulation over microbatches (lax.scan over the leading
    microbatch axis -- peak activation memory / #micro),
  * remat handled inside each model (cfg.remat),
  * optional top-k gradient compression with error feedback across the `pod`
    axis (cross-pod DP; repro/optim/grad_compress.py),
  * ZeRO-ish optimizer-state sharding: mu/nu inherit the params' model-axis
    sharding and additionally shard the largest divisible dim over `data`
    (applied via state_shardings()).

The model plugs in as loss_fn(params, batch) -> scalar.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.grad_compress import topk_compress_init


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress_frac: Optional[float] = None   # e.g. 0.01 -> top-1% + EF


@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    err: Optional[dict] = None

    def tree(self):
        return dataclasses.asdict(self)


def init_state(cfg: TrainConfig, params) -> TrainState:
    err = topk_compress_init(params) if cfg.compress_frac else None
    return TrainState(params=params, opt=adamw_init(params), err=err)


def state_shardings(param_specs, *, data_axes=("data",)) -> dict:
    """Optimizer-state PartitionSpecs: mirror the param spec, then shard the
    first unsharded dim over `data_axes` (ZeRO-1 flavour)."""

    def zero(spec):
        parts = list(spec) if spec else []
        for i, p in enumerate(parts):
            if p is None:
                parts[i] = tuple(data_axes)
                return P(*parts)
        return spec  # fully sharded already

    mu = jax.tree.map(zero, param_specs,
                      is_leaf=lambda s: isinstance(s, P))
    return {"mu": mu, "nu": mu, "step": P()}


def make_train_step(loss_fn: Callable, cfg: TrainConfig):
    """loss_fn(params, batch) -> scalar.  batch leaves have a leading
    microbatch axis when cfg.microbatches > 1."""

    def step(state: dict, batch):
        params, opt, err = state["params"], state["opt"], state["err"]

        if cfg.microbatches > 1:
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, acc,
                                    {"l": l, "g": g}), None
            zero = {"l": jnp.zeros(()),
                    "g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
            acc, _ = jax.lax.scan(micro, zero, batch)
            loss = acc["l"] / cfg.microbatches
            grads = jax.tree.map(lambda g: g / cfg.microbatches, acc["g"])
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if cfg.compress_frac:
            from repro.optim.grad_compress import topk_compress
            comp, err, densify = topk_compress(grads, err,
                                               frac=cfg.compress_frac)
            grads = densify(comp, params)

        params, opt, info = adamw_update(cfg.optimizer, params, grads, opt)
        info["loss"] = loss
        return {"params": params, "opt": opt, "err": err}, info

    return step
