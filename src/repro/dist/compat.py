"""JAX version shim for the distributed APIs (DESIGN.md sec. 6.1).

The repo targets two JAX API generations:

  * >= 0.5:  ``jax.shard_map``, ``jax.sharding.AxisType``,
    ``jax.make_mesh(..., axis_types=...)``, ``check_vma=``;
  * 0.4.x (this container ships 0.4.37): ``jax.experimental.shard_map``,
    no ``AxisType``, ``jax.make_mesh`` without ``axis_types``, ``check_rep=``.

Every module imports ``shard_map`` / ``make_mesh`` from here instead of from
``jax`` directly (enforced by tests/test_fold_codecs.py); this file is the
ONLY place allowed to probe the jax API surface.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # >= 0.5
    from jax.sharding import AxisType
except ImportError:  # 0.4.x
    AxisType = None

HAS_AXIS_TYPE = AxisType is not None

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP


def shard_map(f, mesh=None, in_specs=None, out_specs=None, check_vma=False):
    """``jax.shard_map`` on >= 0.5, ``jax.experimental.shard_map`` on 0.4.x.

    ``check_vma`` maps onto 0.4.x's ``check_rep`` -- the same replication
    checker under its earlier name (True is what makes shard_map transposes
    insert psums for replicated operands, see repro.models.moe).
    """
    if _NEW_SHARD_MAP is not None:
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)
    return _OLD_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_types_kwargs(n_axes: int) -> dict:
    """``dict(axis_types=(AxisType.Auto,) * n)`` where supported, else {}."""
    if HAS_AXIS_TYPE:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    devices: optional explicit device list (e.g. the first 256 of 512
    placeholder devices).  ``jax.make_mesh`` cannot subset the device pool,
    so that path constructs the Mesh directly.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    kw = axis_types_kwargs(len(axis_names))
    if devices is not None:
        return Mesh(np.asarray(devices).reshape(axis_shapes), axis_names, **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)
