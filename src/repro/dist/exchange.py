"""Expand/fold exchanges with pluggable fold wire formats (DESIGN.md
sec. 4 + 10).

The fold exchange routes every newly-discovered vertex to its owner column.
WHICH vertices travel is fixed by the algorithm; HOW they are encoded on the
wire is an independent, swappable concern (Buluc & Madduri 2011 separate the
exchange pattern from its payload; Romera & Froning 2017 compress it).

Every fold is ONE `col_all_to_all`: per-bucket counts ride a HEADER WORD at
the front of the payload message instead of a second collective, and
value-carrying folds append the value channel to the same message instead of
a third (the paper's "reduce the number of communications among the GPUs"
applied to our collectives).  Fused single-message costs, per fold partner
(S = owned block size, W = ceil(S/32)):

  codec   set-fold message            bytes        value-fold message
  list    [cnt | ids]   int32         4*S + 4      [cnt | ids | vals]
  bitmap  [bit words]   uint32        4*W          [words | vals]
  delta   [cnt | gaps]  uint16        2*S + 4      [cnt | gaps | vals]

(bitmap needs no header: counts are derivable from the received words.)
The value channel is FRONT-PACKED into the message in the same canonical
ascending order as the ids, so only the first `cnt` value words per bucket
carry information: `wire_bytes_values` prices the static message capacity
(+4*S per bucket), `wire_bytes_values_sent` the count-proportional bytes a
count-aware transport (all_to_allv) ships -- the honest figure BENCH_bfs
tracks, cutting the bitmap value-fold from ~4*S + S/8 per bucket toward
4*count + S/8.

Delivery order per sender differs by codec (`list` keeps discovery order,
`bitmap`/`delta` deliver ascending) -- outputs are nonetheless bit-identical
across codecs because (a) a vertex appears at most once per sender, and the
update winner is the MINIMUM sender regardless of position within a message,
and (b) the engine keeps frontiers in canonical ascending order
(`engine.canonical_front`), fixing the next level's scan order.  Do not rely
on per-sender ordering in a decoder.  `delta` requires S <= 65536 so every
gap fits in a uint16; larger blocks would need an escape word, which this
repro does not implement (`get_fold_codec` names the codecs that DO work).

The device-side encode/decode/compaction stages take an optional `ops`
bundle -- `repro.kernels.fold.make_fold_ops` when the engine resolved a
Pallas fold path (`BFSConfig(fold=...)`, DESIGN.md sec. 10), `None` for the
reference jnp formulas.  Both are bit-identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frontier as F
from repro.core.types import Grid2D


def expand_exchange(front, front_cnt, *, topo, ops=None):
    """Gather the frontiers of the processor-column (paper line 13).

    Returns (all_front (n_cols_local,), front_total) -- valid entries first,
    grid-row order preserved.  ops: optional fold-kernel bundle for the
    compaction (None = reference argsort).
    """
    R, S = topo.grid.R, topo.grid.S
    af = topo.row_gather(front).reshape(R, S)
    ac = topo.row_gather(front_cnt).reshape(R)
    return F.compact_blocks(af, ac, ops=ops)


def expand_exchange_values(front, front_cnt, payload, *, topo, fill=0,
                           ops=None):
    """`expand_exchange` with an aligned per-vertex payload channel
    (frontier programs: the vertex's label / distance / source id).

    Returns (all_front (n_cols_local,), all_payload aligned, front_total) --
    the same compaction order as `expand_exchange` (valid entries first,
    grid-row order preserved), applied to ids and payload in lockstep.
    """
    R, S = topo.grid.R, topo.grid.S
    af = topo.row_gather(front).reshape(R, S)
    ac = topo.row_gather(front_cnt).reshape(R)
    ap = topo.row_gather(payload).reshape(R, S)
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] < ac[:, None]
    total = jnp.sum(ac, dtype=jnp.int32)
    if ops is not None:
        (fr, pl), _ = ops.compact_rows(
            mask.reshape(1, -1), (af.reshape(1, -1), ap.reshape(1, -1)),
            (-1, fill))
        return fr[0], pl[0], total
    flat_m = mask.reshape(-1)
    order = jnp.argsort(~flat_m, stable=True)
    valid = flat_m[order]
    fr = jnp.where(valid, af.reshape(-1)[order], -1)
    pl = jnp.where(valid, ap.reshape(-1)[order], fill)
    return fr, pl, total


def resolve_preds(pred, *, topo, j):
    """Final deferred-predecessor exchange (paper sec. 3.5 / contribution [2]).

    One all_to_all of the pred array (viewed as C blocks of S) within each
    grid row delivers, for every owned vertex, the parent recorded by the
    processor-column that folded it."""
    C, S = topo.grid.C, topo.grid.S
    pb = pred.reshape(C, S)
    recv = topo.col_all_to_all(pb).reshape(C, S)
    own = jnp.take(pb, j, axis=0)                     # (S,) my owned block
    deferred = own < -1
    sender = jnp.clip(-own - 2, 0, C - 1)
    from_sender = jnp.take_along_axis(recv, sender[None, :], axis=0)[0]
    return jnp.where(deferred, from_sender, own)


# ----------------------------------------------------------------------------
# int32 <-> uint16 value-channel splitting (the delta value-fold rides a
# uint16 message; shifts/ors reconstruct the exact bit pattern)
# ----------------------------------------------------------------------------

def _i32_to_u16(v):
    """(C, S) int32 -> (C, 2*S) uint16 [lo, hi] pairs."""
    C, S = v.shape
    lo = (v & 0xFFFF).astype(jnp.uint16)
    hi = ((v >> 16) & 0xFFFF).astype(jnp.uint16)
    return jnp.stack([lo, hi], axis=-1).reshape(C, 2 * S)


def _u16_to_i32(u):
    """(C, 2*S) uint16 [lo, hi] pairs -> (C, S) int32, bit-exact."""
    C = u.shape[0]
    p = u.reshape(C, -1, 2).astype(jnp.int32)
    return (p[..., 1] << 16) | p[..., 0]


# ----------------------------------------------------------------------------
# Fold codecs
# ----------------------------------------------------------------------------

class FoldCodec:
    """Strategy for the fold exchange's wire format.

    fold() maps per-owner-column discovery buckets to received owned rows:
      dst:     (C, S) int32 local-row ids (bucket m holds rows of block m,
               i.e. ids m*S + t), padded -1, packed at the front;
      dst_cnt: (C,) int32;
    returns (int_verts (C, S) int32 -- MY owned rows j*S + t, one row per
    sender, padded -1 -- and int_cnt (C,)).  Order WITHIN a sender's row is
    codec-specific (see module docstring); consumers must not rely on it.

    Every fold (set or value) is ONE `col_all_to_all` of one fused message
    (counts in a header word, values appended) -- see the byte table in the
    module docstring.  `ops` is the optional fold-kernel bundle
    (`repro.kernels.fold`); None = the reference jnp formulas.
    """
    name = "?"

    def __init__(self, grid: Grid2D = None, ops=None):
        self._ops = ops

    def wire_bytes(self, grid: Grid2D) -> int:
        """Bytes this device SENDS on one fused set-fold message."""
        raise NotImplementedError

    def fold(self, dst, dst_cnt, *, topo, j):
        raise NotImplementedError

    # -- value-carrying fold (frontier programs, DESIGN.md sec. 8) -----------
    #
    # Same exchange pattern, but every travelling vertex carries an int32
    # value (its label / distance / source id).  The id-set goes on the wire
    # in THIS codec's format; the values are FRONT-PACKED into the tail of
    # the same message in the CANONICAL (ascending, front-packed) bucket
    # order, which callers must provide (repro.algos.program.pack_blocks
    # does).  Because the input is canonical and values are min-combined by
    # consumers, every codec delivers bit-identical results by construction.

    def wire_bytes_values(self, grid: Grid2D) -> int:
        """STATIC capacity of one fused value-fold message (ids + header +
        the S-slot value channel)."""
        return self.wire_bytes(grid) + grid.C * 4 * grid.S

    def wire_bytes_values_sent(self, grid: Grid2D, total_count) -> int:
        """Count-proportional bytes of one value-fold: the value channel is
        front-packed, so a count-aware transport (all_to_allv) ships only
        `total_count` value words beyond the set-fold message.  This is the
        figure BENCH_bfs tracks against the dense-channel baseline."""
        return self.wire_bytes(grid) + 4 * total_count

    def fold_values(self, ids, cnt, vals, *, topo, j):
        """ids: (C, S) local-row ids per owner bucket (bucket m holds ids
        m*S + t), ascending, front-packed, padded -1; vals: (C, S) int32
        aligned with ids.  Returns (recv_ids (C, S) owned rows j*S + t,
        ascending front-packed per sender, recv_cnt (C,), recv_vals (C, S)
        aligned)."""
        raise NotImplementedError


class ListFold(FoldCodec):
    """32-bit local indices, the paper's own wire format (sec. 3.3), with
    the count in the leading header word of each bucket."""
    name = "list"

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * (4 * grid.S + 4)

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        msg = jnp.concatenate([dst_cnt[:, None], dst], axis=1)
        recv = topo.col_all_to_all(msg).reshape(C, 1 + S)
        return recv[:, 1:], recv[:, 0]

    def fold_values(self, ids, cnt, vals, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        msg = jnp.concatenate([cnt[:, None], ids, vals], axis=1)
        recv = topo.col_all_to_all(msg).reshape(C, 1 + 2 * S)
        return recv[:, 1:1 + S], recv[:, 0], recv[:, 1 + S:]


class BitmapFold(FoldCodec):
    """1-bit-per-vertex block bitmap: 32x below `list` at identical
    semantics (beyond-paper; see EXPERIMENTS.md "fold compression").  No
    header word: counts are derivable from the received bit words."""
    name = "bitmap"

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * 4 * ((grid.S + 31) // 32)

    @staticmethod
    def encode(dst, dst_cnt, S: int, ops=None):
        """(C, S) id buckets -> (C, ceil(S/32)) uint32 bit words."""
        C = dst.shape[0]
        valid = dst >= 0
        rowsel = jnp.where(valid, jnp.arange(C, dtype=jnp.int32)[:, None], C)
        onehot = jnp.zeros((C, S), bool).at[
            rowsel.reshape(-1), jnp.where(valid, dst % S, 0).reshape(-1)
        ].set(True, mode="drop")
        if ops is not None:
            return ops.pack_bits(onehot)
        return F.pack_bitmap(onehot)

    @staticmethod
    def decode(words, j, S: int, ops=None):
        """(C, W) received words -> ascending owned rows j*S + t per sender."""
        if ops is not None:
            recv_mask = ops.unpack_bits(words, S)
            C = recv_mask.shape[0]
            rows = jnp.broadcast_to(
                j * S + jnp.arange(S, dtype=jnp.int32)[None, :], (C, S))
            (int_verts,), cnt = ops.compact_rows(recv_mask, (rows,), (-1,))
            return int_verts, cnt
        recv_mask = F.unpack_bitmap(words, S)          # [m, t]: from sender m
        C = recv_mask.shape[0]
        rows = jnp.broadcast_to(
            j * S + jnp.arange(S, dtype=jnp.int32)[None, :], (C, S))
        int_verts = jax.vmap(lambda r, m: F.append_padded(
            jnp.full((S,), -1, jnp.int32), jnp.int32(0), r, m)[0])(
                rows, recv_mask)
        return int_verts, recv_mask.sum(axis=1, dtype=jnp.int32)

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        words = topo.col_all_to_all(self.encode(dst, dst_cnt, S, self._ops))
        return self.decode(words.reshape(C, -1), j, S, self._ops)

    def fold_values(self, ids, cnt, vals, *, topo, j):
        # decode delivers ascending front-packed rows -- exactly the
        # canonical order the ids (and hence the values channel) arrived in
        C, S = topo.grid.C, topo.grid.S
        words = self.encode(ids, cnt, S, self._ops)
        W = words.shape[1]
        msg = jnp.concatenate(
            [words, jax.lax.bitcast_convert_type(vals, jnp.uint32)], axis=1)
        recv = topo.col_all_to_all(msg).reshape(C, W + S)
        ri, rc = self.decode(recv[:, :W], j, S, self._ops)
        rv = jax.lax.bitcast_convert_type(recv[:, W:], jnp.int32)
        return ri, rc, rv


class DeltaFold(FoldCodec):
    """Sort + delta + 16-bit narrowing (Romera & Froning 2017, sec. III):
    within one fold message all ids share the destination block, so after
    sorting, consecutive gaps are < S and fit a uint16 -- half the bytes of
    `list` independent of frontier density (unlike `bitmap`, which wins only
    once more than 1/16 of a block is discovered in one level).  The count
    rides a two-uint16 header (one 32-bit word) ahead of the gaps."""
    name = "delta"

    def __init__(self, grid: Grid2D = None, ops=None):
        if grid is not None and grid.S > (1 << 16):
            raise ValueError(
                f"delta fold needs S <= 65536 (16-bit gaps), got S={grid.S}")
        super().__init__(grid, ops)

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * (2 * grid.S + 4)

    @staticmethod
    def encode(dst, dst_cnt, S: int, ops=None):
        """(C, S) id buckets -> (C, S) uint16 ascending first-order gaps
        (slot 0 is the absolute first offset)."""
        C = dst.shape[0]
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < dst_cnt[:, None]
        t = jnp.where(valid, dst % S, F.I32_MAX)
        ts = jnp.sort(t, axis=1)                  # valid entries sort first
        if ops is not None:
            return ops.delta_gaps(ts, valid)
        prev = jnp.concatenate(
            [jnp.zeros((C, 1), jnp.int32), ts[:, :-1]], axis=1)
        return jnp.where(valid, ts - prev, 0).astype(jnp.uint16)

    @staticmethod
    def decode(gaps, cnt, j, S: int, ops=None):
        """(C, S) uint16 gaps + (C,) counts -> owned rows j*S + t."""
        if ops is not None:
            vals = ops.delta_positions(gaps)
        else:
            vals = jnp.cumsum(gaps.astype(jnp.int32), axis=1)
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < cnt[:, None]
        return jnp.where(valid, j * S + vals, -1), cnt

    @staticmethod
    def _header(cnt):
        """(C,) int32 counts -> (C, 2) uint16 [lo, hi] header words (count
        may be S = 65536, one past uint16, hence the pair)."""
        return _i32_to_u16(cnt[:, None])

    @staticmethod
    def _read_header(hdr):
        return _u16_to_i32(hdr)[:, 0]

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        msg = jnp.concatenate(
            [self._header(dst_cnt), self.encode(dst, dst_cnt, S, self._ops)],
            axis=1)
        recv = topo.col_all_to_all(msg).reshape(C, S + 2)
        cnt = self._read_header(recv[:, :2])
        return self.decode(recv[:, 2:], cnt, j, S, self._ops)

    def fold_values(self, ids, cnt, vals, *, topo, j):
        # encode sorts per bucket; canonical input is already sorted, so the
        # delivered order equals the sent order and the values align
        C, S = topo.grid.C, topo.grid.S
        msg = jnp.concatenate(
            [self._header(cnt), self.encode(ids, cnt, S, self._ops),
             _i32_to_u16(vals)], axis=1)
        recv = topo.col_all_to_all(msg).reshape(C, 2 + 3 * S)
        rc = self._read_header(recv[:, :2])
        ri, _ = self.decode(recv[:, 2:2 + S], rc, j, S, self._ops)
        rv = _u16_to_i32(recv[:, 2 + S:])
        return ri, rc, rv


FOLD_CODECS = {"list": ListFold, "bitmap": BitmapFold, "delta": DeltaFold}


def get_fold_codec(spec, grid: Grid2D, ops=None) -> FoldCodec:
    """Resolve "list" | "bitmap" | "delta" | FoldCodec instance.

    ops: optional fold-kernel bundle (`repro.kernels.fold.make_fold_ops`)
    threaded into the constructed codec's encode/decode stages; ignored for
    pre-built FoldCodec instances.  A codec that cannot operate at this
    grid's block size (delta needs S <= 65536) raises a ValueError naming
    the codecs that DO work -- surfaced unchanged through
    `GraphSession`/`BFSConfig`.
    """
    if isinstance(spec, FoldCodec):
        return spec
    try:
        cls = FOLD_CODECS[spec]
    except KeyError:
        raise ValueError(
            f"unknown fold codec {spec!r}; choose from {sorted(FOLD_CODECS)}")
    try:
        return cls(grid, ops)
    except ValueError as e:
        working = []
        for name, other in FOLD_CODECS.items():
            if name == spec:
                continue
            try:
                other(grid, ops)
            except ValueError:
                continue
            working.append(name)
        raise ValueError(
            f"fold_codec={spec!r} cannot run on this grid ({grid.R}x{grid.C},"
            f" block size S={grid.S}): {e}; codecs that do work at this "
            f"block size: {sorted(working)}") from e
