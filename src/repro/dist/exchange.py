"""Expand/fold exchanges with pluggable fold wire formats (DESIGN.md sec. 4).

The fold exchange routes every newly-discovered vertex to its owner column.
WHICH vertices travel is fixed by the algorithm; HOW they are encoded on the
wire is an independent, swappable concern (Buluc & Madduri 2011 separate the
exchange pattern from its payload; Romera & Froning 2017 compress it).  Three
codecs, per fold partner (S = owned block size):

  list    (S,) int32 local-row ids + count        4*S + 4   bytes
  bitmap  1 bit per owned vertex                  4*ceil(S/32) bytes
  delta   sort + delta-encode + 16-bit narrowing  2*S + 4   bytes

Delivery order per sender differs by codec (`list` keeps discovery order,
`bitmap`/`delta` deliver ascending) -- outputs are nonetheless bit-identical
across codecs because (a) a vertex appears at most once per sender, and the
update winner is the MINIMUM sender regardless of position within a message,
and (b) the engine keeps frontiers in canonical ascending order
(`engine.canonical_front`), fixing the next level's scan order.  Do not rely
on per-sender ordering in a decoder.  `delta` requires S <= 65536 so every
gap fits in a uint16; larger blocks would need an escape word, which this
repro does not implement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frontier as F
from repro.core.types import Grid2D


def expand_exchange(front, front_cnt, *, topo):
    """Gather the frontiers of the processor-column (paper line 13).

    Returns (all_front (n_cols_local,), front_total) -- valid entries first,
    grid-row order preserved.
    """
    R, S = topo.grid.R, topo.grid.S
    af = topo.row_gather(front).reshape(R, S)
    ac = topo.row_gather(front_cnt).reshape(R)
    return F.compact_blocks(af, ac)


def expand_exchange_values(front, front_cnt, payload, *, topo, fill=0):
    """`expand_exchange` with an aligned per-vertex payload channel
    (frontier programs: the vertex's label / distance / source id).

    Returns (all_front (n_cols_local,), all_payload aligned, front_total) --
    the same compaction order as `expand_exchange` (valid entries first,
    grid-row order preserved), applied to ids and payload in lockstep.
    """
    R, S = topo.grid.R, topo.grid.S
    af = topo.row_gather(front).reshape(R, S)
    ac = topo.row_gather(front_cnt).reshape(R)
    ap = topo.row_gather(payload).reshape(R, S)
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] < ac[:, None]
    flat_m = mask.reshape(-1)
    order = jnp.argsort(~flat_m, stable=True)
    valid = flat_m[order]
    fr = jnp.where(valid, af.reshape(-1)[order], -1)
    pl = jnp.where(valid, ap.reshape(-1)[order], fill)
    return fr, pl, jnp.sum(ac, dtype=jnp.int32)


def resolve_preds(pred, *, topo, j):
    """Final deferred-predecessor exchange (paper sec. 3.5 / contribution [2]).

    One all_to_all of the pred array (viewed as C blocks of S) within each
    grid row delivers, for every owned vertex, the parent recorded by the
    processor-column that folded it."""
    C, S = topo.grid.C, topo.grid.S
    pb = pred.reshape(C, S)
    recv = topo.col_all_to_all(pb).reshape(C, S)
    own = jnp.take(pb, j, axis=0)                     # (S,) my owned block
    deferred = own < -1
    sender = jnp.clip(-own - 2, 0, C - 1)
    from_sender = jnp.take_along_axis(recv, sender[None, :], axis=0)[0]
    return jnp.where(deferred, from_sender, own)


# ----------------------------------------------------------------------------
# Fold codecs
# ----------------------------------------------------------------------------

class FoldCodec:
    """Strategy for the fold exchange's wire format.

    fold() maps per-owner-column discovery buckets to received owned rows:
      dst:     (C, S) int32 local-row ids (bucket m holds rows of block m,
               i.e. ids m*S + t), padded -1, packed at the front;
      dst_cnt: (C,) int32;
    returns (int_verts (C, S) int32 -- MY owned rows j*S + t, one row per
    sender, padded -1 -- and int_cnt (C,)).  Order WITHIN a sender's row is
    codec-specific (see module docstring); consumers must not rely on it.
    """
    name = "?"

    def wire_bytes(self, grid: Grid2D) -> int:
        """Bytes this device SENDS on one fold exchange (payload + counts)."""
        raise NotImplementedError

    def fold(self, dst, dst_cnt, *, topo, j):
        raise NotImplementedError

    # -- value-carrying fold (frontier programs, DESIGN.md sec. 8) -----------
    #
    # Same exchange pattern, but every travelling vertex carries an int32
    # value (its label / distance / source id).  The id-set goes on the wire
    # in THIS codec's format; the values ride a dense int32 side channel
    # aligned to the CANONICAL (ascending, front-packed) bucket order, which
    # callers must provide (repro.algos.program.pack_blocks does).  Because
    # the input is canonical and values are min-combined by consumers, every
    # codec delivers bit-identical results by construction.

    def wire_bytes_values(self, grid: Grid2D) -> int:
        """Bytes SENT on one value-carrying fold (ids + values channel)."""
        return self.wire_bytes(grid) + grid.C * 4 * grid.S

    def fold_values(self, ids, cnt, vals, *, topo, j):
        """ids: (C, S) local-row ids per owner bucket (bucket m holds ids
        m*S + t), ascending, front-packed, padded -1; vals: (C, S) int32
        aligned with ids.  Returns (recv_ids (C, S) owned rows j*S + t,
        ascending front-packed per sender, recv_cnt (C,), recv_vals (C, S)
        aligned)."""
        raise NotImplementedError


class ListFold(FoldCodec):
    """32-bit local indices, the paper's own wire format (sec. 3.3)."""
    name = "list"

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * (4 * grid.S + 4)

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        int_verts = topo.col_all_to_all(dst).reshape(C, S)
        int_cnt = topo.col_all_to_all(dst_cnt).reshape(C)
        return int_verts, int_cnt

    def fold_values(self, ids, cnt, vals, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        ri = topo.col_all_to_all(ids).reshape(C, S)
        rc = topo.col_all_to_all(cnt).reshape(C)
        rv = topo.col_all_to_all(vals).reshape(C, S)
        return ri, rc, rv


class BitmapFold(FoldCodec):
    """1-bit-per-vertex block bitmap: 32x below `list` at identical
    semantics (beyond-paper; see EXPERIMENTS.md "fold compression")."""
    name = "bitmap"

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * 4 * ((grid.S + 31) // 32)

    @staticmethod
    def encode(dst, dst_cnt, S: int):
        """(C, S) id buckets -> (C, ceil(S/32)) uint32 bit words."""
        C = dst.shape[0]
        valid = dst >= 0
        rowsel = jnp.where(valid, jnp.arange(C, dtype=jnp.int32)[:, None], C)
        onehot = jnp.zeros((C, S), bool).at[
            rowsel.reshape(-1), jnp.where(valid, dst % S, 0).reshape(-1)
        ].set(True, mode="drop")
        return F.pack_bitmap(onehot)

    @staticmethod
    def decode(words, j, S: int):
        """(C, W) received words -> ascending owned rows j*S + t per sender."""
        recv_mask = F.unpack_bitmap(words, S)          # [m, t]: from sender m
        C = recv_mask.shape[0]
        rows = jnp.broadcast_to(
            j * S + jnp.arange(S, dtype=jnp.int32)[None, :], (C, S))
        int_verts = jax.vmap(lambda r, m: F.append_padded(
            jnp.full((S,), -1, jnp.int32), jnp.int32(0), r, m)[0])(
                rows, recv_mask)
        return int_verts, recv_mask.sum(axis=1, dtype=jnp.int32)

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        words = topo.col_all_to_all(self.encode(dst, dst_cnt, S))
        return self.decode(words.reshape(C, -1), j, S)

    def fold_values(self, ids, cnt, vals, *, topo, j):
        # decode delivers ascending front-packed rows -- exactly the
        # canonical order the ids (and hence the values channel) arrived in
        C, S = topo.grid.C, topo.grid.S
        words = topo.col_all_to_all(self.encode(ids, cnt, S))
        ri, rc = self.decode(words.reshape(C, -1), j, S)
        rv = topo.col_all_to_all(vals).reshape(C, S)
        return ri, rc, rv


class DeltaFold(FoldCodec):
    """Sort + delta + 16-bit narrowing (Romera & Froning 2017, sec. III):
    within one fold message all ids share the destination block, so after
    sorting, consecutive gaps are < S and fit a uint16 -- half the bytes of
    `list` independent of frontier density (unlike `bitmap`, which wins only
    once more than 1/16 of a block is discovered in one level)."""
    name = "delta"

    def __init__(self, grid: Grid2D):
        if grid.S > (1 << 16):
            raise ValueError(
                f"delta fold needs S <= 65536 (16-bit gaps), got S={grid.S}")

    def wire_bytes(self, grid: Grid2D) -> int:
        return grid.C * (2 * grid.S + 4)

    @staticmethod
    def encode(dst, dst_cnt, S: int):
        """(C, S) id buckets -> (C, S) uint16 ascending first-order gaps
        (slot 0 is the absolute first offset)."""
        C = dst.shape[0]
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < dst_cnt[:, None]
        t = jnp.where(valid, dst % S, F.I32_MAX)
        ts = jnp.sort(t, axis=1)                  # valid entries sort first
        prev = jnp.concatenate(
            [jnp.zeros((C, 1), jnp.int32), ts[:, :-1]], axis=1)
        return jnp.where(valid, ts - prev, 0).astype(jnp.uint16)

    @staticmethod
    def decode(gaps, cnt, j, S: int):
        """(C, S) uint16 gaps + (C,) counts -> owned rows j*S + t."""
        vals = jnp.cumsum(gaps.astype(jnp.int32), axis=1)
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < cnt[:, None]
        return jnp.where(valid, j * S + vals, -1), cnt

    def fold(self, dst, dst_cnt, *, topo, j):
        C, S = topo.grid.C, topo.grid.S
        gaps = topo.col_all_to_all(self.encode(dst, dst_cnt, S)).reshape(C, S)
        cnt = topo.col_all_to_all(dst_cnt).reshape(C)
        return self.decode(gaps, cnt, j, S)

    def fold_values(self, ids, cnt, vals, *, topo, j):
        # encode sorts per bucket; canonical input is already sorted, so the
        # delivered order equals the sent order and the values align
        C, S = topo.grid.C, topo.grid.S
        gaps = topo.col_all_to_all(self.encode(ids, cnt, S)).reshape(C, S)
        rc = topo.col_all_to_all(cnt).reshape(C)
        ri, _ = self.decode(gaps, rc, j, S)
        rv = topo.col_all_to_all(vals).reshape(C, S)
        return ri, rc, rv


FOLD_CODECS = {"list": ListFold, "bitmap": BitmapFold, "delta": DeltaFold}


def get_fold_codec(spec, grid: Grid2D) -> FoldCodec:
    """Resolve "list" | "bitmap" | "delta" | FoldCodec instance."""
    if isinstance(spec, FoldCodec):
        return spec
    try:
        cls = FOLD_CODECS[spec]
    except KeyError:
        raise ValueError(
            f"unknown fold codec {spec!r}; choose from {sorted(FOLD_CODECS)}")
    try:
        return cls(grid)
    except TypeError:
        return cls()
