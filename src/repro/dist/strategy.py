"""Pluggable exchange strategies for the fold all-to-all (DESIGN.md sec. 14).

Every fold (and the final `resolve_preds`) routes a (C, K) message array
within the processor-row: row d of the array on column j is the payload
j -> d.  HOW those C*(C-1) point-to-point payloads traverse the network is
an independent, swappable concern:

  flat       ONE `jax.lax.all_to_all` -- every column sends C-1 direct
             messages per exchange.  Minimal volume (each payload travels
             exactly one hop), O(C) messages per participant: the layout
             that stops scaling past a single host (ButterFly BFS, Green
             2103.13577).
  butterfly  log2(C) pairwise `ppermute` stages over the XOR hypercube.
             Payload (j -> d) carries the invariant label r = j XOR d and
             hops once per set bit of r, so each column sends exactly
             log2(C) messages of C/2 fused rows per exchange -- message
             count drops from C-1 to log2(C) at the price of volume
             ((C/2)*log2(C) vs C-1 row payloads): the classic latency /
             bandwidth trade a multi-host fold wants.

Both strategies deliver the IDENTICAL (C, K) received array, byte for byte:
the butterfly is store-and-forward (payload rows are re-fused into each
stage's message but never re-encoded), so every consumer -- codec decode,
`resolve_preds`, value channels -- is strategy-agnostic and the engine-wide
bit-identity contract holds by construction.

The strategy binds at the `Topology` level (`Topology.with_exchange`):
`topology.col_all_to_all` dispatches through it, so the fold codecs and the
predecessor resolution route automatically.  `BFSConfig(exchange=...)`
selects; "auto" resolves to butterfly on power-of-two column counts >= 4
(where it strictly reduces message count), flat otherwise.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.types import Grid2D


def _log2_exact(c: int) -> int:
    """log2(C) for power-of-two C (validated before use)."""
    return int(c).bit_length() - 1


def butterfly_stage_rows(C: int, s: int) -> np.ndarray:
    """The (C//2,) label rows that travel at stage s: every label with bit
    s set.  Static (host-side) -- the traced exchange gathers/scatters these
    fixed row index sets, never a data-dependent shape."""
    return np.asarray([r for r in range(C) if r & (1 << s)], np.int32)


def emulate_exchange(x_all: np.ndarray, name: str) -> np.ndarray:
    """Host-side emulation of one exchange over ALL columns at once.

    x_all: (C, C, K) -- x_all[j, d] is column j's payload for column d.
    Returns recv (C, C, K) with recv[j, m] = x_all[m, j] for BOTH
    strategies; the butterfly path replays the staged row swaps literally so
    tests can assert byte equality of the two routes without a mesh.
    """
    x_all = np.asarray(x_all)
    C = x_all.shape[0]
    if name == "flat":
        return np.swapaxes(x_all, 0, 1).copy()
    # butterfly: H[j, r] = x_all[j, j ^ r]; stage s swaps rows with bit s
    # set between partners j and j ^ 2^s; final recv[j, m] = H[j, m ^ j]
    r = np.arange(C)
    H = np.stack([x_all[j, j ^ r] for j in range(C)])
    for s in range(_log2_exact(C)):
        bit = 1 << s
        rows = butterfly_stage_rows(C, s)
        sent = H[:, rows].copy()
        for j in range(C):
            H[j, rows] = sent[j ^ bit]
    return np.stack([H[j, j ^ r] for j in range(C)])


class ExchangeStrategy:
    """Strategy for routing the fold's per-column message array.

    `all_to_all(x, topo)` runs INSIDE shard_map and must return exactly
    what `jax.lax.all_to_all(x, col_axis, 0, 0)` returns -- same values,
    same order, same bytes (the bit-identity contract every codec and the
    predecessor resolution rely on).  The accounting methods price one
    exchange for the telemetry trace and BENCH: `msgs_per_exchange` counts
    point-to-point messages one column sends, `wire_bytes` scales a codec's
    flat per-exchange byte figure to this route (set folds), and
    `value_extra_bytes` the count-proportional value-channel bytes beyond
    it (value folds; the flat figure is PR 5's `wire_bytes_values_sent`).
    """
    name = "?"

    def validate(self, grid: Grid2D, col_axes: tuple) -> None:
        """Raise ValueError when this strategy cannot run on the grid."""

    def all_to_all(self, x, topo):
        raise NotImplementedError

    def msgs_per_exchange(self, C: int) -> int:
        raise NotImplementedError

    def wire_bytes(self, flat_bytes: int, C: int) -> int:
        """Bytes one column sends per exchange, given the codec's flat
        figure (C equal per-destination buckets, own bucket included)."""
        raise NotImplementedError

    def value_extra_bytes(self, cnt, j, C: int):
        """Traced per-level value-channel bytes beyond `wire_bytes`:
        cnt (C,) int32 entries per destination bucket, j the calling
        column.  4 bytes per entry per hop."""
        raise NotImplementedError


class FlatExchange(ExchangeStrategy):
    """Today's single-collective route: one `jax.lax.all_to_all`."""
    name = "flat"

    def all_to_all(self, x, topo):
        return jax.lax.all_to_all(x, topo.col_collective, 0, 0)

    def msgs_per_exchange(self, C: int) -> int:
        return max(C - 1, 0)            # the own bucket never leaves

    def wire_bytes(self, flat_bytes: int, C: int) -> int:
        return flat_bytes               # the codec formulas ARE this route

    def value_extra_bytes(self, cnt, j, C: int):
        return 4 * jnp.sum(cnt, dtype=jnp.int32).astype(jnp.uint32)


class ButterflyExchange(ExchangeStrategy):
    """log2(C)-stage XOR-hypercube route (ButterFly BFS, Green 2103.13577).

    Column j stores payload (j -> d) at label row r = j XOR d; stage
    s = 0..log2(C)-1 ships the C/2 rows with bit s of r set to partner
    j XOR 2^s (one `ppermute` of one fused sub-array per stage).  A payload
    with label r therefore hops popcount(r) times and lands on
    j XOR r = d; the received array recv[m] = H[m XOR j] is byte-identical
    to the flat all_to_all's.
    """
    name = "butterfly"

    def validate(self, grid: Grid2D, col_axes: tuple) -> None:
        C = grid.C
        if C & (C - 1):
            raise ValueError(
                f"exchange='butterfly' needs a power-of-two column count, "
                f"got C={C} (grid {grid.R}x{grid.C}); exchange='flat' works "
                f"on any grid")
        if len(col_axes) > 1:
            raise ValueError(
                f"exchange='butterfly' routes over ONE column mesh axis, "
                f"got col_axes={col_axes}; exchange='flat' works on "
                f"multi-axis columns")

    def all_to_all(self, x, topo):
        axis = topo.col_collective
        C = topo.grid.C
        j = jax.lax.axis_index(axis).astype(jnp.int32)
        lab = jnp.arange(C, dtype=jnp.int32)
        H = jnp.take(x, j ^ lab, axis=0)          # H[r] = x[j ^ r]
        for s in range(_log2_exact(C)):
            bit = 1 << s
            rows = butterfly_stage_rows(C, s)     # static index set
            perm = [(t, t ^ bit) for t in range(C)]
            sent = jax.lax.ppermute(jnp.take(H, rows, axis=0), axis, perm)
            H = H.at[rows].set(sent)
        return jnp.take(H, j ^ lab, axis=0)       # recv[m] = H[m ^ j]

    def msgs_per_exchange(self, C: int) -> int:
        return _log2_exact(C)

    def wire_bytes(self, flat_bytes: int, C: int) -> int:
        # each of the log2(C) stages ships C/2 of the C per-destination
        # buckets: (C/2)*log2(C) bucket payloads vs the flat route's C-1
        return (flat_bytes // C) * (C // 2) * _log2_exact(C)

    def value_extra_bytes(self, cnt, j, C: int):
        # bucket d's value words hop popcount(j ^ d) times
        lab = (j ^ jnp.arange(C, dtype=jnp.int32)).astype(jnp.uint32)
        hops = jax.lax.population_count(lab).astype(jnp.uint32)
        return 4 * jnp.sum(cnt.astype(jnp.uint32) * hops)


EXCHANGES = {"flat": FlatExchange, "butterfly": ButterflyExchange}


def resolve_exchange_name(spec: str, grid: Grid2D, col_axes: tuple) -> str:
    """"auto" -> the strategy this grid runs best: butterfly when it
    strictly reduces messages (power-of-two C >= 4, single column axis),
    flat otherwise.  Explicit names pass through (validated at engine
    build)."""
    if spec != "auto":
        return spec
    C = grid.C
    if C >= 4 and not (C & (C - 1)) and len(col_axes) <= 1:
        return "butterfly"
    return "flat"


def get_exchange(spec, grid: Grid2D, col_axes: tuple = ("c",)
                 ) -> ExchangeStrategy:
    """Resolve "flat" | "butterfly" | "auto" | ExchangeStrategy instance,
    validated against the grid (a strategy that cannot run here raises a
    ValueError naming the one that does -- same UX as `get_fold_codec`)."""
    if isinstance(spec, ExchangeStrategy):
        spec.validate(grid, tuple(col_axes))
        return spec
    name = resolve_exchange_name(spec, grid, tuple(col_axes))
    try:
        cls = EXCHANGES[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange {spec!r}; choose from "
            f"{sorted(EXCHANGES)} or 'auto'")
    strat = cls()
    strat.validate(grid, tuple(col_axes))
    return strat
