"""Multi-host bootstrap and placement (DESIGN.md sec. 14).

One host stops at its PCIe root: scaling the processor grid past a single
machine needs (a) a process group whose devices form ONE global mesh and
(b) arrays placed as global `jax.Array`s so the engine's shard_map spans
every host.  This module is the whole multi-host surface:

  initialize()    `jax.distributed.initialize` plus the CPU-backend gloo
                  collectives switch (the CPU backend cannot run
                  multi-process collectives on its default implementation).
  global_mesh()   a mesh over `jax.devices()` -- ALL processes' devices in
                  process order, so every host constructs the identical
                  mesh deterministically.
  put_dev()       host (R, C, ...) array -> global array sharded over the
                  grid axes (each process materialises only its addressable
                  shards; the host copy must be identical on every process,
                  which the deterministic planner guarantees).
  put_replicated()  host scalar/vector -> global fully-replicated array
                  (search roots, source sets).
  fetch()         global array -> host numpy, `process_allgather`-ing the
                  non-addressable shards (identity in single-process runs).

Everything degrades to the single-process identity: `DistGraph` and the
engine call these helpers unconditionally, and a plain local run never pays
for them.  The two-process harness `tests/dist/run_multihost.py` drives a
real multi-host BFS/CC/SSSP through this module and asserts bit-identity
with the single-process reference.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat


def initialize(coordinator_address: str, num_processes: int,
               process_id: int, local_device_ids=None) -> None:
    """Join the process group (call ONCE, before any array lands on device).

    This flips the CPU-backend collectives implementation to gloo first:
    the default CPU collectives cannot run multi-process, and the switch
    must precede `jax.distributed.initialize`.  (Probing the backend here
    would itself initialize it -- too late -- so the flag is set blind; it
    only affects the CPU backend.)
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass                       # newer jaxlibs pick a working default
    kw = {}
    if local_device_ids is not None:
        kw["local_device_ids"] = local_device_ids
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id, **kw)


def is_multiprocess() -> bool:
    return jax.process_count() > 1


def global_mesh(axis_shapes, axis_names):
    """The deterministic global mesh: `jax.devices()` (all processes, in
    process order) reshaped to the grid axes.  Every process builds the
    same mesh, so NamedShardings agree across hosts by construction."""
    return compat.make_mesh(tuple(axis_shapes), tuple(axis_names),
                            devices=jax.devices())


def put_dev(x, mesh, spec: P):
    """Host array -> global array sharded by `spec` over `mesh`.

    Single-process: plain `jnp.asarray` (uncommitted, like before).  Multi-
    process: every process holds the identical host copy and materialises
    only its addressable shards, so no cross-host data movement happens.
    """
    if not is_multiprocess():
        return jnp.asarray(x)
    x = np.asarray(x)
    sharding = NamedSharding(mesh, spec)
    return jax.make_array_from_callback(x.shape, sharding,
                                        lambda idx: x[idx])


def put_replicated(x, mesh):
    """Host array -> globally replicated array (search args)."""
    if not is_multiprocess():
        return jnp.asarray(x)
    return put_dev(x, mesh, P())


def arg_aval(shape, dtype, mesh):
    """ShapeDtypeStruct for AOT-lowering a replicated search argument: in a
    process group the aval must carry its sharding or the lowered
    executable cannot bind the global argument arrays."""
    if not is_multiprocess():
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, P()))


def fetch(x):
    """Global array -> host value.  Identity when fully addressable (every
    single-process array); otherwise an all-gather of the remote shards so
    each process assembles the complete global output."""
    if getattr(x, "is_fully_addressable", True):
        return x
    from jax.experimental import multihost_utils
    return multihost_utils.process_allgather(x, tiled=True)


def fetch_all(xs) -> tuple:
    """`fetch` over a tuple of outputs (the engine's assemble funnel)."""
    return tuple(fetch(x) for x in xs)
