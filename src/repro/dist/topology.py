"""Processor-grid topology: mesh, axes, device coordinates (DESIGN.md sec. 5).

A `Topology` binds a `Grid2D` (the paper's R x C processor grid) to a JAX
mesh: the grid's ROWS span `row_axes` (e.g. ("r",) or ("pod", "data")) and
its COLUMNS span `col_axes` (e.g. ("c",) or ("model",)).  All collectives the
engine needs are expressed against it:

  expand (paper line 13) = all_gather along the row axes  -> `row_gather`
  fold   (paper line 17) = all_to_all along the col axes  -> `col_all_to_all`

The paper's original 1D code is the DEGENERATE 1 x P grid (`Topology.one_d`):
the expand gather spans a single processor (identity) while the fold
all_to_all spans all P -- which is exactly the O(P)-exchanges /
O(n)-map-per-device structure the 2D decomposition removes (paper sec. 2.1).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import Grid2D
from repro.dist import compat


def _axes(a) -> tuple:
    if a is None:
        return ()
    return tuple(a) if isinstance(a, (tuple, list)) else (a,)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static binding of a processor grid to mesh axes.

    `exchange` optionally binds an `ExchangeStrategy` (repro.dist.strategy,
    DESIGN.md sec. 14): `col_all_to_all` then routes through it, so every
    fold codec and the predecessor resolution pick the strategy up without
    knowing it exists.  None = the flat single-collective route.
    """
    grid: Grid2D
    mesh: object
    row_axes: tuple = ("r",)
    col_axes: tuple = ("c",)
    exchange: object = None

    def __post_init__(self):
        object.__setattr__(self, "row_axes", _axes(self.row_axes))
        object.__setattr__(self, "col_axes", _axes(self.col_axes))
        sizes = mesh_axis_sizes(self.mesh)
        R = C = 1
        for a in self.row_axes:
            R *= sizes[a]
        for a in self.col_axes:
            C *= sizes[a]
        if (R, C) != (self.grid.R, self.grid.C):
            raise ValueError(
                f"mesh axes give a {R}x{C} grid but Grid2D is "
                f"{self.grid.R}x{self.grid.C} (row_axes={self.row_axes}, "
                f"col_axes={self.col_axes})")

    @classmethod
    def for_grid(cls, grid: Grid2D, mesh=None, row_axes=("r",),
                 col_axes=("c",)) -> "Topology":
        """Bind a grid to `mesh`, or build a mesh honouring the given axes.

        This is the session API's planning entry point: with no mesh it
        creates a mesh whose axes are the REQUESTED row/col axis names (one
        per grid dimension; an empty axes tuple needs that dimension to be
        1, e.g. the degenerate 1 x P topology with row_axes=()); with a mesh
        it binds the given axes exactly like the constructor.
        """
        if mesh is None:
            row_axes, col_axes = _axes(row_axes), _axes(col_axes)
            if len(row_axes) > 1 or len(col_axes) > 1:
                raise ValueError(
                    "pass a mesh when grid rows/cols span multiple axes "
                    f"(row_axes={row_axes}, col_axes={col_axes})")
            names, sizes = [], []
            for axes, size, what in ((row_axes, grid.R, "rows"),
                                     (col_axes, grid.C, "cols")):
                if axes:
                    names.append(axes[0])
                    sizes.append(size)
                elif size != 1:
                    raise ValueError(
                        f"grid {what}={size} but no mesh axes span them")
            if not names:                       # 1 x 1 grid, no axes asked
                names, sizes = ["r", "c"], [1, 1]
                row_axes, col_axes = ("r",), ("c",)
            mesh = compat.make_mesh(tuple(sizes), tuple(names))
        return cls(grid, mesh, row_axes=row_axes, col_axes=col_axes)

    @classmethod
    def one_d(cls, n: int, mesh, axes=("p",)) -> "Topology":
        """The 1D baseline as the degenerate 1 x P grid (n padded to P)."""
        axes = _axes(axes)
        sizes = mesh_axis_sizes(mesh)
        Pn = 1
        for a in axes:
            Pn *= sizes[a]
        return cls(Grid2D.for_vertices(n, 1, Pn), mesh, row_axes=(),
                   col_axes=axes)

    # ------------------------------------------------------------------
    # build-time (outside shard_map)
    # ------------------------------------------------------------------

    @property
    def dev_spec(self) -> P:
        """Spec of (R, C, ...) per-device arrays (leading grid dims)."""
        return P(self.row_axes or None, self.col_axes or None)

    @property
    def out_block_spec(self) -> P:
        """Spec assembling per-device (1, 1, S) blocks into the global
        vertex-block order b = j*R + i (column-major over the grid)."""
        return P(tuple(self.col_axes + self.row_axes))

    def shard_map(self, fn, in_specs, out_specs):
        return compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    # ------------------------------------------------------------------
    # trace-time (inside shard_map)
    # ------------------------------------------------------------------

    @property
    def all_axes(self) -> tuple:
        return self.row_axes + self.col_axes

    @property
    def col_collective(self):
        """axis_name argument for collectives within the processor-row."""
        return self.col_axes if len(self.col_axes) > 1 else self.col_axes[0]

    @property
    def row_collective(self):
        return self.row_axes if len(self.row_axes) > 1 else self.row_axes[0]

    def device_coords(self):
        """(i, j) grid coordinates of the calling device, as traced int32."""
        i = (jax.lax.axis_index(self.row_collective).astype(jnp.int32)
             if self.row_axes else jnp.int32(0))
        j = (jax.lax.axis_index(self.col_collective).astype(jnp.int32)
             if self.col_axes else jnp.int32(0))
        return i, j

    def psum_all(self, x):
        """Sum over the whole grid (row + col axes)."""
        return jax.lax.psum(x, self.all_axes)

    def row_gather(self, x):
        """all_gather within the processor-column -> leading R axis.
        Identity (R=1) on the degenerate 1D topology."""
        if not self.row_axes:
            return x[None]
        return jax.lax.all_gather(x, self.row_axes, tiled=False)

    def col_all_to_all(self, x):
        """all_to_all within the processor-row over leading axis C, routed
        by the bound exchange strategy (flat when none is bound)."""
        if self.exchange is not None:
            return self.exchange.all_to_all(x, self)
        return jax.lax.all_to_all(x, self.col_collective, 0, 0)

    def with_exchange(self, strategy) -> "Topology":
        """This topology with an `ExchangeStrategy` bound (the engine binds
        its resolved strategy here so all collectives route through it)."""
        return dataclasses.replace(self, exchange=strategy)
