"""Distributed-engine layer shared by every BFS driver (DESIGN.md sec. 6).

Layering:
  compat    -- JAX version shim (shard_map / make_mesh / AxisType)
  topology  -- mesh + processor-grid geometry (1D = degenerate 1 x P grid)
  exchange  -- expand/fold collectives with pluggable fold wire codecs
  engine    -- the level loop / init / deferred-pred resolution / accounting
"""
from repro.dist.compat import shard_map, make_mesh, axis_types_kwargs
from repro.dist.topology import Topology
from repro.dist.exchange import FOLD_CODECS, get_fold_codec
from repro.dist.engine import DistBFSEngine
