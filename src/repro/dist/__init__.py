"""Distributed-engine layer shared by every BFS driver (DESIGN.md sec. 6).

Layering:
  compat    -- JAX version shim (shard_map / make_mesh / AxisType)
  topology  -- mesh + processor-grid geometry (1D = degenerate 1 x P grid)
  exchange  -- expand/fold collectives with pluggable fold wire codecs
  strategy  -- pluggable fold exchange routes (flat / butterfly)
  multihost -- process-group bootstrap + global-array placement
  engine    -- the level loop / init / deferred-pred resolution / accounting

Re-exports are PEP 562 LAZY: `jax.distributed.initialize` must run before
any JAX computation, and the engine chain materialises jnp constants at
import time -- so `from repro.dist import multihost` (the first thing a
multi-host worker does) must not drag the engine in eagerly.
"""
_EXPORTS = {
    "shard_map": "repro.dist.compat",
    "make_mesh": "repro.dist.compat",
    "axis_types_kwargs": "repro.dist.compat",
    "Topology": "repro.dist.topology",
    "FOLD_CODECS": "repro.dist.exchange",
    "get_fold_codec": "repro.dist.exchange",
    "DistBFSEngine": "repro.dist.engine",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)
