"""The shared distributed-BFS engine (DESIGN.md sec. 6).

Since the frontier-program subsystem (DESIGN.md sec. 8) the generic parts --
the `lax.while_loop` over levels, the scalar/batched device programs, the
64-bit (hi, lo)-uint32 edge accounting -- live in
`repro.algos.engine.FrontierEngine`, and BFS itself is ONE frontier program
(`repro.algos.bfs.BFSLevelsProgram`).  `DistBFSEngine` is that pair under
the historical constructor: init, the level loop, the deferred-predecessor
resolution and the per-search accounting behave exactly as before; drivers
remain thin configurations (topology + fold codec + optionally a custom
per-level step).

Per-level step contract (what `step_factory` must produce):

    step(st: BFSState, prev_total: int32) ->
        (new_st: BFSState, total: int32, scanned: uint32)

`prev_total` is the global size of the frontier entering the level (what the
direction-optimising driver's heuristic consumes); `scanned` is this level's
locally scanned edge count.  The default step is the engine's own top-down
expand -> scan -> fold -> update level.

Accounting is 64-bit: totals accumulate in a (hi, lo) uint32 pair because
int32 silently wraps at RMAT scale >= 26 (2*16*2^26 > 2^31 scanned edges per
search) and jnp.int64 is unavailable without jax_enable_x64.
"""
from __future__ import annotations

import jax.numpy as jnp

# Re-exports: these historically lived here and stay importable from here.
from repro.algos.engine import FrontierEngine, wide_add, wide_total  # noqa: F401
from repro.core.types import LocalGraph2D, BFSOutput
from repro.dist.topology import Topology

# The BFS building blocks now live in repro.algos.bfs, which imports
# repro.dist.exchange -- so pulling them in at module scope would re-enter
# this package's own __init__ mid-import.  PEP 562 keeps
# `from repro.dist.engine import canonical_front` (etc.) working lazily.
_BFS_REEXPORTS = ("BFSLevelsProgram", "canonical_front", "init_state",
                  "owned_level", "topdown_step")


def __getattr__(name):
    if name in _BFS_REEXPORTS:
        from repro.algos import bfs
        return getattr(bfs, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class DistBFSEngine(FrontierEngine):
    """Whole-search BFS program over a Topology (single lowering, jitted
    once) -- `BFSLevelsProgram` on the generalized driver.

    Parameters
    ----------
    topo:         Topology binding the processor grid to mesh axes.
    fold_codec:   "list" | "bitmap" | "delta" | FoldCodec instance.
    expand:       local-expand implementation ("reference" | "pallas" |
                  "pallas-interpret" | "auto"; DESIGN.md sec. 9) -- the
                  fused Pallas pipeline vs the inline jnp scan,
                  bit-identical either way.
    expand_fn:    explicit chunk-expansion override (wins over `expand`).
    fold:         fold-pipeline implementation (same spellings; DESIGN.md
                  sec. 10) -- codec encode/decode kernels + the prefix-sum
                  compaction, REPRO_FOLD override, bit-identical paths.
    dedup:        winner-selection method ("scatter" | "sort").
    exchange:     fold exchange strategy ("flat" | "butterfly" | "auto" |
                  an ExchangeStrategy instance; DESIGN.md sec. 14) -- how
                  fold messages route within the processor-row,
                  bit-identical either way.
    bottomup:     bottom-up kernel implementation for direction-optimised
                  programs (same spellings; DESIGN.md sec. 11) -- the fused
                  parent search, REPRO_BOTTOMUP override, bit-identical
                  paths.
    step_factory: optional `(engine, graph, extra, i, j, topdown) -> step`
                  hook replacing the default top-down per-level step.
    n_extra:      number of extra per-device (R, C, ...) graph arrays the
                  step consumes (e.g. the CSR twin for bottom-up).
    program:      optional BFS-shaped FrontierProgram overriding the default
                  `BFSLevelsProgram` (the session passes the
                  direction-optimising `DirectionProgram` wrapper here);
                  wins over step_factory/n_extra.
    """

    def __init__(self, topo: Topology, *, fold_codec="list",
                 edge_chunk: int = 8192, max_levels: int = 64,
                 expand: str = "auto", expand_fn=None, fold: str = "auto",
                 dedup: str = "scatter", bottomup: str = "auto",
                 exchange="flat", step_factory=None, n_extra: int = 0,
                 program=None, telemetry: bool = False,
                 fault_tolerance: bool = False, ckpt_every: int = 1):
        from repro.algos.bfs import BFSLevelsProgram

        if program is None:
            program = BFSLevelsProgram(step_factory=step_factory,
                                       n_extra=n_extra)
        self.step_factory = step_factory
        self.n_extra = program.n_extra
        super().__init__(
            topo, program,
            fold_codec=fold_codec, edge_chunk=edge_chunk,
            max_levels=max_levels, expand=expand, expand_fn=expand_fn,
            fold=fold, dedup=dedup, bottomup=bottomup, exchange=exchange,
            telemetry=telemetry, fault_tolerance=fault_tolerance,
            ckpt_every=ckpt_every)

    def topdown_step(self, graph: LocalGraph2D, st, *, i, j):
        """One top-down level (paper Alg. 2 lines 12-18)."""
        from repro.algos.bfs import topdown_step
        return topdown_step(self, graph, st, i=i, j=j)

    def run(self, graph: LocalGraph2D, root, *extra) -> BFSOutput:
        """Search from `root`; extra = the step_factory's per-device arrays.

        Returns global (n,) level/pred in vertex-block order (b = j*R + i,
        i.e. plain global vertex ids), plus the exact 64-bit scanned-edge
        count summed over devices and levels."""
        return super().run(graph, jnp.int32(root), *extra)

    def assemble_batch(self, outs, B: int) -> BFSOutput:
        """Gathered batched device outputs -> global (B, n) BFSOutput."""
        return self.assemble(outs, B)
