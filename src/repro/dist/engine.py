"""The shared distributed-BFS engine (DESIGN.md sec. 6).

One driver loop serves `BFS1D`, `BFS2D` and `BFS2DDirection`: init, the
`lax.while_loop` over levels, the deferred-predecessor resolution and the
per-search edge accounting live HERE; the drivers are thin configurations
(topology + fold codec + optionally a custom per-level step).

Per-level step contract (what `step_factory` must produce):

    step(st: BFSState, prev_total: int32) ->
        (new_st: BFSState, total: int32, scanned: uint32)

`prev_total` is the global size of the frontier entering the level (what the
direction-optimising driver's heuristic consumes); `scanned` is this level's
locally scanned edge count.  The default step is the engine's own top-down
expand -> scan -> fold -> update level.

Accounting is 64-bit: totals accumulate in a (hi, lo) uint32 pair because
int32 silently wraps at RMAT scale >= 26 (2*16*2^26 > 2^31 scanned edges per
search) and jnp.int64 is unavailable without jax_enable_x64.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import frontier as F
from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput
from repro.dist import exchange as X
from repro.dist.topology import Topology


# ----------------------------------------------------------------------------
# Wide (64-bit) accumulation without jax_enable_x64
# ----------------------------------------------------------------------------

def wide_add(hi, lo, delta):
    """(hi, lo) uint32 pair += delta (any non-negative integer dtype)."""
    new_lo = lo + delta.astype(jnp.uint32)
    return hi + (new_lo < lo).astype(jnp.uint32), new_lo


def wide_total(hi, lo) -> int:
    """Sum per-device (hi, lo) pairs into one exact Python int."""
    hi = np.asarray(hi).astype(np.int64)
    lo = np.asarray(lo).astype(np.int64)
    return (int(hi.sum()) << 32) + int(lo.sum())


# ----------------------------------------------------------------------------
# Level-loop building blocks
# ----------------------------------------------------------------------------

def init_state(root, *, grid: Grid2D, i, j) -> BFSState:
    S = grid.S
    nrl = grid.n_rows_local
    b = root // S
    oi, oj = b % grid.R, b // grid.R
    mine = (oi == i) & (oj == j)
    lr = (root // S // grid.R) * S + root % S
    lc = root % grid.n_cols_local
    level = jnp.full((nrl,), -1, jnp.int32)
    pred = jnp.full((nrl,), -1, jnp.int32)
    visited = jnp.zeros((nrl,), bool)
    front = jnp.full((S,), -1, jnp.int32)
    level = jnp.where(mine, level.at[lr].set(0), level)
    pred = jnp.where(mine, pred.at[lr].set(root), pred)
    visited = jnp.where(mine, visited.at[lr].set(True), visited)
    front = jnp.where(mine, front.at[0].set(lc), front)
    cnt = jnp.where(mine, jnp.int32(1), jnp.int32(0))
    return BFSState(level=level, pred=pred, visited=visited, front=front,
                    front_cnt=cnt, lvl=jnp.int32(1))


def owned_level(level, *, grid: Grid2D, j):
    return jax.lax.dynamic_slice_in_dim(level, j * grid.S, grid.S)


def canonical_front(front, cnt):
    """Sort the padded frontier ascending (pad -1 stays at the back).

    The frontier's order fixes the edge-scan order of the NEXT level, which
    fixes which parent wins each first-visit race -- so keeping it canonical
    makes levels AND predecessors bit-identical across fold codecs (whose
    natural delivery orders differ)."""
    key = jnp.where(front < 0, F.I32_MAX, front)
    s = jnp.sort(key)
    return jnp.where(s == F.I32_MAX, -1, s), cnt


# ----------------------------------------------------------------------------
# The engine
# ----------------------------------------------------------------------------

class DistBFSEngine:
    """Whole-search program over a Topology (single lowering, jitted once).

    Parameters
    ----------
    topo:         Topology binding the processor grid to mesh axes.
    fold_codec:   "list" | "bitmap" | "delta" | FoldCodec instance.
    expand_fn:    optional kernel override for the CSC scan (Pallas path).
    dedup:        winner-selection method ("scatter" | "sort").
    step_factory: optional `(engine, graph, extra, i, j, topdown) -> step`
                  hook replacing the default top-down per-level step.
    n_extra:      number of extra per-device (R, C, ...) graph arrays the
                  step consumes (e.g. the CSR twin for bottom-up).
    """

    def __init__(self, topo: Topology, *, fold_codec="list",
                 edge_chunk: int = 8192, max_levels: int = 64,
                 expand_fn=None, dedup: str = "scatter",
                 step_factory=None, n_extra: int = 0):
        self.topo = topo
        self.grid = topo.grid
        self.codec = X.get_fold_codec(fold_codec, topo.grid)
        self.edge_chunk = edge_chunk
        self.max_levels = max_levels
        self.expand_fn = expand_fn
        self.dedup = dedup
        self.step_factory = step_factory
        self.n_extra = n_extra
        # traces of the level loop (scalar or batched); jit/AOT cache hits do
        # not retrace, so tests can assert a 64-root sweep compiles once
        self.trace_count = 0
        self._run = jax.jit(self._build())
        self._run_batch = jax.jit(self._build(batched=True))

    # -- one top-down level (paper Alg. 2 lines 12-18) -----------------------
    def topdown_step(self, graph: LocalGraph2D, st: BFSState, *, i, j):
        topo, grid = self.topo, self.grid
        S = grid.S

        # expand exchange: gather frontiers within the processor-column
        all_front, front_total = X.expand_exchange(
            st.front, st.front_cnt, topo=topo)

        # frontier expansion (local CSC column scan)
        ex = F.expand_frontier(
            graph.col_off, graph.row_idx, st.visited, st.level, st.pred,
            all_front, front_total, st.lvl, grid=grid, i=i, j=j,
            edge_chunk=self.edge_chunk, expand_fn=self.expand_fn,
            dedup=self.dedup)

        # own-column vertices go straight to the frontier (lines 15-16)
        own_rows = jnp.take(ex.dst, j, axis=0)      # (S,) local rows, block j
        own_cnt = jnp.take(ex.dst_cnt, j)
        own_cols = (i * S + (own_rows - j * S)).astype(jnp.int32)  # ROW2COL
        own_valid = jnp.arange(S, dtype=jnp.int32) < own_cnt
        dst = ex.dst.at[j].set(-1)
        dst_cnt = ex.dst_cnt.at[j].set(0)

        # fold exchange: route discoveries to their owners (same grid row)
        int_verts, int_cnt = self.codec.fold(dst, dst_cnt, topo=topo, j=j)

        # frontier update (paper sec. 3.5)
        up = F.update_frontier(int_verts, int_cnt, ex.visited, ex.level,
                               ex.pred, st.lvl, grid=grid, i=i, j=j)

        nf = jnp.full((S,), -1, jnp.int32)
        nc = jnp.int32(0)
        nf, nc = F.append_padded(nf, nc, own_cols, own_valid)
        up_valid = jnp.arange(S, dtype=jnp.int32) < up.new_cnt
        nf, nc = F.append_padded(nf, nc, up.new_front, up_valid)
        nf, nc = canonical_front(nf, nc)

        st2 = BFSState(level=up.level, pred=up.pred, visited=up.visited,
                       front=nf, front_cnt=nc, lvl=st.lvl + 1)
        return st2, topo.psum_all(nc), ex.edges_scanned

    # -- whole-search program (lax.while_loop over levels) -------------------
    def _build(self, batched: bool = False):
        """Device program for one root (scalar) or a (B,) roots axis.

        The batched program runs the whole level loop per root under
        `lax.map` (a scan: per-root work stays proportional to that root's
        levels, unlike vmap which would pad every root to the slowest), so a
        multi-root sweep is ONE compiled executable.
        """
        topo, grid = self.topo, self.grid

        def device_fn(col_off, row_idx, nnz, *rest):
            extra, roots = rest[:-1], rest[-1]
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            extra = tuple(e[0, 0] for e in extra)
            i, j = topo.device_coords()

            def search(root):
                st = init_state(root, grid=grid, i=i, j=j)

                topdown = functools.partial(self.topdown_step, graph, i=i,
                                            j=j)
                if self.step_factory is None:
                    step = lambda st, prev_total: topdown(st)
                else:
                    step = self.step_factory(self, graph, extra, i, j,
                                             topdown)

                def cond(carry):
                    st, total, hi, lo = carry
                    return (total > 0) & (st.lvl <= self.max_levels)

                def body(carry):
                    st, total, hi, lo = carry
                    st2, total2, scanned = step(st, total)
                    hi, lo = wide_add(hi, lo, scanned)
                    return st2, total2, hi, lo

                init_total = topo.psum_all(st.front_cnt)
                st, _, hi, lo = jax.lax.while_loop(
                    cond, body,
                    (st, init_total, jnp.uint32(0), jnp.uint32(0)))

                pred = X.resolve_preds(st.pred, topo=topo, j=j)
                level = owned_level(st.level, grid=grid, j=j)
                return level, pred, st.lvl, hi, lo

            if batched:
                level, pred, lvl, hi, lo = jax.lax.map(search, roots)
            else:
                level, pred, lvl, hi, lo = search(roots)
            return (level[None, None], pred[None, None], lvl[None, None],
                    hi[None, None], lo[None, None])

        dev = topo.dev_spec
        out_g = topo.out_block_spec
        mapped = topo.shard_map(
            device_fn,
            in_specs=(dev,) * (3 + self.n_extra) + (P(),),
            out_specs=(out_g, out_g, dev, dev, dev))

        def counted(*args):
            # runs at TRACE time only (jit / .lower()); cache hits skip it
            self.trace_count += 1
            return mapped(*args)

        return counted

    def run(self, graph: LocalGraph2D, root, *extra) -> BFSOutput:
        """Search from `root`; extra = the step_factory's per-device arrays.

        Returns global (n,) level/pred in vertex-block order (b = j*R + i,
        i.e. plain global vertex ids), plus the exact 64-bit scanned-edge
        count summed over devices and levels."""
        level, pred, lvls, hi, lo = self._run(
            graph.col_off, graph.row_idx, graph.nnz, *extra, jnp.int32(root))
        return BFSOutput(level=level.reshape(-1), pred=pred.reshape(-1),
                         n_levels=lvls.max(), edges_scanned=wide_total(hi, lo))

    def assemble_batch(self, outs, B: int) -> BFSOutput:
        """Gathered batched device outputs -> global (B, n) BFSOutput."""
        level, pred, lvls, hi, lo = outs
        Pn, S = self.grid.P, self.grid.S
        level = jnp.swapaxes(level.reshape(Pn, B, S), 0, 1).reshape(B, -1)
        pred = jnp.swapaxes(pred.reshape(Pn, B, S), 0, 1).reshape(B, -1)
        n_levels = lvls.reshape(-1, B).max(axis=0)
        hi_s = np.asarray(hi).astype(np.int64).reshape(-1, B).sum(axis=0)
        lo_s = np.asarray(lo).astype(np.int64).reshape(-1, B).sum(axis=0)
        scanned = tuple((int(h) << 32) + int(l) for h, l in zip(hi_s, lo_s))
        return BFSOutput(level=level, pred=pred, n_levels=n_levels,
                         edges_scanned=scanned)
