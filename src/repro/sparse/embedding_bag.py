"""EmbeddingBag: JAX has no native nn.EmbeddingBag -- built here from
jnp.take + segment_sum (multi-hot bags with optional per-sample weights),
as the recsys substrate requires."""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparse.segment import segment_sum


def embedding_bag(table, indices, offsets=None, *, weights=None, mode="sum"):
    """table: (V, d); either
         indices (B, L) fixed-size bags (padded with -1), or
         flat indices (NNZ,) + offsets (B+1,) CSR-style ragged bags.
    Returns (B, d)."""
    if offsets is None:
        B, L = indices.shape
        valid = indices >= 0
        emb = jnp.take(table, jnp.clip(indices, 0, table.shape[0] - 1), axis=0)
        if weights is not None:
            emb = emb * weights[..., None]
        emb = jnp.where(valid[..., None], emb, 0)
        out = emb.sum(axis=1)
        if mode == "mean":
            out = out / jnp.maximum(valid.sum(axis=1), 1)[:, None]
        return out
    B = offsets.shape[0] - 1
    nnz = indices.shape[0]
    seg = jnp.searchsorted(offsets[1:], jnp.arange(nnz, dtype=jnp.int32),
                           side="right").astype(jnp.int32)
    emb = jnp.take(table, jnp.clip(indices, 0, table.shape[0] - 1), axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    out = segment_sum(emb, seg, B)
    if mode == "mean":
        cnt = jnp.maximum(jnp.diff(offsets), 1)
        out = out / cnt[:, None]
    return out
