"""Uniform k-hop neighbour sampler (GraphSAGE; required for minibatch_lg).

Host-side numpy over a CSR adjacency: sampling is data-pipeline work (the
paper's CPUs-as-coprocessors role), the sampled block is then a static-shape
device batch.  Sampling with replacement when deg > 0 (GraphSAGE standard);
isolated vertices self-loop.
"""
from __future__ import annotations

import numpy as np


class NeighborSampler:
    def __init__(self, row_off: np.ndarray, col_idx: np.ndarray, seed: int = 0):
        self.row_off = np.asarray(row_off)
        self.col_idx = np.asarray(col_idx)
        self.rng = np.random.default_rng(seed)

    def sample_hop(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """(B,) -> (B, fanout) sampled neighbour ids (self for isolated)."""
        lo = self.row_off[nodes]
        deg = self.row_off[nodes + 1] - lo
        r = self.rng.integers(0, 2**31, size=(nodes.size, fanout))
        pick = lo[:, None] + r % np.maximum(deg, 1)[:, None]
        nb = self.col_idx[pick]
        return np.where(deg[:, None] > 0, nb, nodes[:, None])

    def sample_block(self, seeds: np.ndarray, fanouts: list[int]) -> dict:
        """Layered block: returns dict with per-hop node sets + edges, all
        static shapes (B, prod(fanouts...)).

          nodes[0] = seeds (B,), nodes[k] (B * prod fanout_1..k,)
          edges[k] = (src=nodes[k], dst=repeat(nodes[k-1], fanout_k))
        """
        nodes = [np.asarray(seeds)]
        edges = []
        for f in fanouts:
            nb = self.sample_hop(nodes[-1], f)          # (cur, f)
            src = nb.reshape(-1)
            dst = np.repeat(np.arange(nodes[-1].size), f)
            edges.append((src, dst))
            nodes.append(src)
        return dict(nodes=nodes, edges=edges, fanouts=list(fanouts))
