from repro.sparse.segment import (segment_sum, segment_mean, segment_max,
                                  gather_scatter, degree_norm)
from repro.sparse.embedding_bag import embedding_bag
from repro.sparse.sampler import NeighborSampler
