"""Segment-based message passing (the JAX GNN primitive).

JAX sparse is BCOO-only, so neighbour aggregation is implemented as
gather -> transform -> segment-reduce over an edge index, exactly as the
taxonomy prescribes.  Padded edges use index = n_nodes and mode='drop'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(values, segment_ids, num_segments: int):
    return jax.ops.segment_sum(values, segment_ids, num_segments,
                               indices_are_sorted=False)


def segment_mean(values, segment_ids, num_segments: int):
    s = segment_sum(values, segment_ids, num_segments)
    c = segment_sum(jnp.ones((values.shape[0],), values.dtype), segment_ids,
                    num_segments)
    return s / jnp.maximum(c, 1)[..., None] if values.ndim > 1 else \
        s / jnp.maximum(c, 1)


def segment_max(values, segment_ids, num_segments: int):
    return jax.ops.segment_max(values, segment_ids, num_segments)


def degree_norm(edge_dst, edge_src, n: int, valid=None):
    """GCN symmetric normalisation 1/sqrt(d_i d_j) per edge."""
    ones = jnp.ones_like(edge_dst, jnp.float32)
    if valid is not None:
        ones = jnp.where(valid, ones, 0)
    deg = jnp.zeros((n,), jnp.float32).at[edge_dst].add(ones, mode="drop")
    deg = deg.at[edge_src].add(jnp.zeros_like(ones), mode="drop")  # shape use
    d = jnp.maximum(deg, 1.0)
    return jax.lax.rsqrt(d[jnp.clip(edge_dst, 0, n - 1)]) * \
        jax.lax.rsqrt(d[jnp.clip(edge_src, 0, n - 1)])


def gather_scatter(h, edge_src, edge_dst, n: int, *, reduce="sum",
                   edge_weight=None, valid=None):
    """y[i] = reduce_j over edges (j -> i) of w_e * h[j]."""
    msg = h[jnp.clip(edge_src, 0, n - 1)]
    if edge_weight is not None:
        msg = msg * edge_weight[:, None]
    if valid is not None:
        msg = jnp.where(valid[:, None], msg, 0 if reduce != "max" else -jnp.inf)
    dst = jnp.where(valid, edge_dst, n) if valid is not None else edge_dst
    if reduce == "sum":
        return jnp.zeros((n,) + h.shape[1:], h.dtype).at[dst].add(msg, mode="drop")
    if reduce == "mean":
        s = jnp.zeros((n,) + h.shape[1:], h.dtype).at[dst].add(msg, mode="drop")
        c = jnp.zeros((n,), h.dtype).at[dst].add(
            jnp.ones_like(dst, h.dtype), mode="drop")
        return s / jnp.maximum(c, 1)[:, None]
    if reduce == "max":
        init = jnp.full((n,) + h.shape[1:], -jnp.inf, h.dtype)
        out = init.at[dst].max(msg, mode="drop")
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(reduce)
