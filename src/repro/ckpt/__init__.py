from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.elastic import reshard_state, shrink_grid
