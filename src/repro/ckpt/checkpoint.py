"""Sharded checkpointing with atomic commit (fault tolerance, DESIGN.md 8).

Layout:  <dir>/step_<n>/shard_<host>.npz + manifest.json
  * each host dumps the leaves it owns (here: single-host, all leaves);
  * manifest records step, mesh shape, pytree structure, leaf shapes/dtypes
    and a monotone commit marker;
  * writes go to step_<n>.tmp and are renamed into place -> a crash never
    leaves a half checkpoint visible;
  * `restore` returns (pytree, meta) for ANY mesh: re-sharding is the
    loader's job (repro/ckpt/elastic.py), because the arrays are saved in
    GLOBAL layout;
  * async-writer failures are RECORDED, not swallowed: the next `save()` /
    `wait()` / `join()` re-raises the writer thread's exception, so a
    checkpoint that silently failed to land cannot masquerade as durable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _tree_paths(tree, prefix=""):
    """`/`-joined key paths for a pure nested-dict tree, in the SAME order
    `jax.tree.flatten` emits the leaves (sorted dict keys); None when the
    tree has non-dict interior nodes (path-keyed restore unavailable)."""
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            sub = _tree_paths(tree[k], f"{prefix}{k}/")
            if sub is None:
                return None
            out.extend(sub)
        return out
    if isinstance(tree, (list, tuple)):
        return None
    return [prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread = None
        self._error = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, extra_meta: dict | None = None):
        self._raise_pending()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        paths = _tree_paths(tree)
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            self._raise_pending()

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "n_leaves": len(host_leaves),
                "shapes": [list(a.shape) for a in host_leaves],
                "dtypes": [str(a.dtype) for a in host_leaves],
                "paths": paths,
                "meta": extra_meta or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        def guarded():
            try:
                write()
            except BaseException as e:     # noqa: BLE001 -- re-raised later
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=guarded)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    # `join` is the spelling recovery drivers use at end-of-query
    join = wait

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"))

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest(self):
        s = self.steps()
        return s[-1] if s else None

    def restore(self, treedef_example, step: int | None = None):
        """treedef_example: a pytree with the target structure (values are
        ignored).  Returns (tree, manifest) or (None, None)."""
        step = step if step is not None else self.latest()
        if step is None:
            return None, None
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        _, treedef = jax.tree.flatten(treedef_example)
        return jax.tree.unflatten(treedef, leaves), manifest

    def restore_tree(self, step: int | None = None):
        """Restore WITHOUT a structure example: rebuilds the nested dict
        from the manifest's leaf paths (recorded for pure-dict trees, which
        is what traversal snapshots are).  Returns (tree, manifest) or
        (None, None)."""
        step = step if step is not None else self.latest()
        if step is None:
            return None, None
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        paths = manifest.get("paths")
        if paths is None:
            raise ValueError(
                f"checkpoint step_{step} was not saved from a nested dict; "
                "use restore(treedef_example)")
        data = np.load(os.path.join(path, "shard_0.npz"))
        tree = {}
        for i, p in enumerate(paths):
            node, parts = tree, p.split("/")
            for k in parts[:-1]:
                node = node.setdefault(k, {})
            node[parts[-1]] = data[f"leaf_{i}"]
        return tree, manifest
