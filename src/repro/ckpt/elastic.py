"""Elastic re-sharding: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints hold GLOBAL arrays, so elasticity = re-placing each leaf with the
new mesh's NamedSharding.  For the BFS, the graph partition itself is a pure
function of (edge list, R, C), so a shrink/grow re-partitions and resumes
from the last completed root (BFS state between roots is just level/pred
outputs).  For training, optimizer state re-shards like params.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def reshard_state(tree, spec_tree, mesh):
    """Place a host pytree onto `mesh` with the given PartitionSpec pytree.
    Axes that no longer exist in the new mesh are dropped from the specs."""
    names = set(mesh.axis_names)

    def fix(spec):
        if not isinstance(spec, P):
            return P()
        parts = []
        for e in spec:
            if e is None:
                parts.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a in names)
                parts.append(kept if kept else None)
            else:
                parts.append(e if e in names else None)
        return P(*parts)

    def place(x, spec):
        return jax.device_put(x, NamedSharding(mesh, fix(spec)))

    return jax.tree.map(place, tree, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def shrink_grid(R: int, C: int, failed: int):
    """Pick the largest valid 2D grid after losing `failed` devices
    (prefers keeping the ORIGINAL grid's aspect ratio; the BFS re-partitions
    from the edge list).

    Maximality first: among all (r, c) with r*c <= R*C - failed, the largest
    device count wins.  Ties break by aspect-ratio distance to the original
    grid, |log(r/c) - log(R/C)| -- so shrinking a wide 2x4 prefers 2x3 over
    the squarer 3x2, and a square 4x4 losing one device picks 3x5/5x3 (the
    two are equidistant; the lower row count wins deterministically).
    """
    total = R * C - failed
    if total < 1:
        raise ValueError(f"no devices left: {R}x{C} minus {failed}")
    aspect = math.log(R / C)
    best = None
    for r in range(1, total + 1):
        c = total // r
        score = (r * c, -abs(math.log(r / c) - aspect))
        if best is None or score > best[0]:
            best = (score, (r, c))
    return best[1]
