"""Elastic re-sharding: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints hold GLOBAL arrays, so elasticity = re-placing each leaf with the
new mesh's NamedSharding.  For the BFS, the graph partition itself is a pure
function of (edge list, R, C), so a shrink/grow re-partitions and resumes
from the last completed root (BFS state between roots is just level/pred
outputs).  For training, optimizer state re-shards like params.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def reshard_state(tree, spec_tree, mesh):
    """Place a host pytree onto `mesh` with the given PartitionSpec pytree.
    Axes that no longer exist in the new mesh are dropped from the specs."""
    names = set(mesh.axis_names)

    def fix(spec):
        if not isinstance(spec, P):
            return P()
        parts = []
        for e in spec:
            if e is None:
                parts.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a in names)
                parts.append(kept if kept else None)
            else:
                parts.append(e if e in names else None)
        return P(*parts)

    def place(x, spec):
        return jax.device_put(x, NamedSharding(mesh, fix(spec)))

    return jax.tree.map(place, tree, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def shrink_grid(R: int, C: int, failed: int):
    """Pick the largest valid 2D grid after losing `failed` devices
    (prefers keeping the aspect ratio; the BFS re-partitions from the edge
    list)."""
    total = R * C - failed
    best = (1, 1)
    for r in range(1, total + 1):
        c = total // r
        if r * c <= total and r * c > best[0] * best[1]:
            best = (r, c)
        elif r * c == best[0] * best[1] and abs(r - c) < abs(best[0] - best[1]):
            best = (r, c)
    return best
