"""Thread->edge mapping kernel (paper sec. 3.4, Alg. 3 line 2).

GPU original: every thread runs an independent binary search of its global id
in the cumulative-degree array (log F divergent scalar gathers per lane).

TPU adaptation (DESIGN.md sec. 3): edge ids handled by one tile are
CONSECUTIVE, so their frontier indices k form a non-decreasing run
[k0, k_last] (the same monotonicity the paper's sec. 3.4.1 optimisation
exploits to amortise searches across a thread's edge group).  We therefore:
  1. find k0 for the tile's first id with ONE scalar binary search;
  2. count, per lane, the cumul entries in (k0, ...] that are <= gid, with
     W-wide windowed broadcast-compares -- dense (TILE x W) VPU ops;
  3. k = k0 + count.
The loop runs ceil((k_last - k0 + 1) / W) times: total work O(TILE * span/W)
vector ops instead of O(TILE log F) divergent scalar ops.

cumul must be CLIPPED by the caller: entries at index > front_total set to
I32_MAX (`clip_cumul` below) so the window loop terminates after the live
frontier prefix.

`map_workload_tile` is the kernel body on VALUES: it is the workload-mapping
STAGE of the fused local-expand pipeline (repro.kernels.expand) and the whole
kernel of the standalone `binsearch_map` op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def clip_cumul(cumul, front_total):
    """Entries past the live frontier -> I32_MAX (terminates the kernel's
    window loop right after the prefix; see module docstring)."""
    idx = jnp.arange(cumul.shape[0], dtype=jnp.int32)
    return jnp.where(idx <= front_total, cumul, I32_MAX)


def map_workload_tile(gid, cumul, *, window: int, n_cumul: int):
    """k[t] = max { l : cumul[l] <= gid[t] } for ONE tile of consecutive edge
    ids, as dense VPU work (the thread->edge mapping stage).

    Operates on values (not refs): callable both from a Pallas kernel body
    (the refs read once into values) and from the fused expand kernel."""
    g0 = gid[0]
    gmax = gid[-1]

    # --- 1. scalar binary search for k0 = max { l : cumul[l] <= g0 } ------
    def bcond(s):
        lo, hi = s
        return hi - lo > 1

    def bbody(s):
        lo, hi = s
        mid = (lo + hi) // 2
        cm = jax.lax.dynamic_slice(cumul, (mid,), (1,))[0]
        lo2 = jnp.where(cm <= g0, mid, lo)
        hi2 = jnp.where(cm <= g0, hi, mid)
        return lo2, hi2

    k0, _ = jax.lax.while_loop(
        bcond, bbody, (jnp.int32(0), jnp.int32(n_cumul)))

    # --- 2. windowed broadcast-compare count over (k0, ...] ---------------
    def wcond(s):
        start, _ = s
        probe = jax.lax.dynamic_slice(
            cumul, (jnp.minimum(start, n_cumul - 1),), (1,))[0]
        return (start < n_cumul) & (probe <= gmax)

    def wbody(s):
        start, count = s
        base = jnp.minimum(start, n_cumul - window)
        win = jax.lax.dynamic_slice(cumul, (base,), (window,))
        idx_ok = base + jax.lax.iota(jnp.int32, window) >= start
        hits = (win[None, :] <= gid[:, None]) & idx_ok[None, :]
        return start + window, count + jnp.sum(
            hits, axis=1, dtype=jnp.int32)

    _, count = jax.lax.while_loop(
        wcond, wbody, (k0 + 1, jnp.zeros_like(gid)))
    return k0 + count


def _kernel(gids_ref, cumul_ref, k_ref, *, window: int, n_cumul: int):
    # the cumul block sits whole in VMEM; read it ONCE into a value so the
    # while loops stay ref-free (JAX 0.4.x interpret mode cannot discharge
    # ref reads inside a while cond; on TPU the dynamic_slices lower to the
    # same VMEM accesses pl.load would)
    k_ref[...] = map_workload_tile(gids_ref[...], cumul_ref[...],
                                   window=window, n_cumul=n_cumul)


@functools.partial(jax.jit,
                   static_argnames=("tile", "window", "interpret"))
def binsearch_map(cumul, gids, *, tile: int = 512, window: int = 256,
                  interpret: bool = True):
    """k[t] = max { l : cumul[l] <= gids[t] }; gids must be sorted ascending
    (they are consecutive edge ids in the BFS).  cumul int32 non-decreasing.
    """
    n_cumul = cumul.shape[0]
    e = gids.shape[0]
    assert e % tile == 0, "pad gids to a multiple of tile"
    if n_cumul < window:  # tiny frontier: pad so the window load is legal
        cumul = jnp.concatenate(
            [cumul, jnp.full((window - n_cumul,), I32_MAX, jnp.int32)])
        n_cumul = window
    grid = (e // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, window=window, n_cumul=n_cumul),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),       # gid tile -> VMEM
            pl.BlockSpec((n_cumul,), lambda t: (0,)),    # cumul stays whole
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(gids, cumul)
