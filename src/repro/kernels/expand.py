"""Fused local-expand pipeline (paper sec. 3.4 end to end; DESIGN.md sec. 9).

The paper's per-node hot loop -- binary-search workload mapping, warp-level
neighbor gather and the atomicOr visited bitmap -- as ONE fused op over a
chunk of consecutive edge ids:

  stage 1  workload map    k[t] = max { l : cumul[l] <= gid[t] }
                           (repro.kernels._binsearch_map.map_workload_tile)
  stage 2  neighbor gather u = front[k]; v = row_idx[col_off[u] + gid -
                           cumul[k]] (the CSC column-scan addressing that the
                           old standalone gather_segments kernel DMA'd)
  stage 3  visited filter  bitmap test + per-tile first-occurrence dedup
                           (repro.kernels._visited_filter.filter_tile); the
                           SET half stays an XLA scatter outside the kernel
                           so it fuses with the level/pred updates
  stage 4  compaction      cross-tile winner selection + canonical packing
                           (`local_expand` driver; inside the engine this is
                           `repro.core.frontier.winner_dedup`/bucket append)

Three selectable implementations, bit-identical by construction:

  "pallas"            the fused Pallas kernel, compiled (GPU/TPU);
  "pallas-interpret"  the same kernel body in Pallas interpret mode -- this
                      is what CI drives on CPU runners via
                      REPRO_EXPAND=pallas-interpret;
  "reference"         the pure-jnp formulas (exactly the inline path of
                      `repro.core.frontier.expand_frontier` / `scan_relax`).

`resolve_expand_path` implements the `BFSConfig(expand=...)` selection rules:
"auto" picks "pallas" on GPU/TPU and "reference" on CPU, and honors the
REPRO_EXPAND environment variable so CI can force the interpret-mode kernel
path without touching configs.

Production note: the fused kernel holds `row_idx` whole in VMEM, which is
right for interpret mode and for local partitions up to a few MiB; the tuned
TPU variant would keep row_idx in ANY/HBM and double-buffer the stage-2
gather with pltpu.make_async_copy, with identical semantics.

This module needs jax.experimental.pallas; path SELECTION does not and lives
in `repro.kernels.select` so reference-path engines import clean without it.
Import this module only at top level (never lazily inside a traced
function): the stage modules cache jnp constants at import time, and an
import under an active trace would leak tracers into those globals.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.frontier import (I32_MAX, exclusive_cumsum, pack_bitmap,
                                 reference_expand_chunk, set_bits,
                                 winner_dedup)
from repro.kernels._binsearch_map import clip_cumul, map_workload_tile
from repro.kernels.select import (EXPAND_ENV, EXPAND_PATHS,  # noqa: F401
                                  resolve_expand_path)
from repro.kernels._visited_filter import filter_tile


def _pick_tile(e: int, tile: int) -> int:
    """Largest DIVISOR of the chunk length <= tile (the kernel grid needs
    tile | chunk length).  Never rounds UP to e: the stage-3 dedup is a
    dense (tile, tile) compare, so one e-wide tile on a big odd chunk
    would be quadratic in the chunk.  Both arguments are static (e is the
    engine's edge_chunk), so this runs at trace time."""
    t = min(tile, e)
    while e % t:
        t -= 1
    return t


# ----------------------------------------------------------------------------
# The fused kernels (stage 1 + 2 + 3 in one pallas_call)
# ----------------------------------------------------------------------------

def _expand_kernel(gids_ref, cumul_ref, total_ref, front_ref, col_off_ref,
                   row_idx_ref, words_ref, v_ref, u_ref, won_ref, *,
                   window: int, n_cumul: int, ncl: int, nnz_cap: int):
    gid = gids_ref[...]
    cumul = cumul_ref[...]          # clipped: entries > front_total = I32_MAX
    # stage 1: thread->edge workload mapping
    k = map_workload_tile(gid, cumul, window=window, n_cumul=n_cumul)
    k = jnp.clip(k, 0, ncl - 1)
    # stage 2: neighbor gather via CSC addressing (valid lanes read the same
    # cumul[k] as the unclipped scan: k <= front_total on the live prefix)
    u = jnp.clip(jnp.take(front_ref[...], k, axis=0), 0, ncl - 1)
    addr = jnp.take(col_off_ref[...], u, axis=0) + gid \
        - jnp.take(cumul, k, axis=0)
    valid = gid < total_ref[0]
    v = jnp.take(row_idx_ref[...], jnp.clip(addr, 0, nnz_cap - 1), axis=0)
    v = jnp.where(valid, v, 0)
    # stage 3: visited-bitmap test + per-tile first-occurrence dedup
    won = filter_tile(v, valid, words_ref[...])
    v_ref[...] = v
    u_ref[...] = u
    won_ref[...] = won


@functools.partial(jax.jit, static_argnames=("tile", "window", "interpret"))
def expand_chunk(gids, cumul, all_front, front_total, col_off, row_idx,
                 visited, words=None, *, tile: int = 512, window: int = 256,
                 interpret: bool = True):
    """The fused set-expand over one chunk of consecutive edge ids.

    Drop-in for `repro.core.frontier.expand_frontier(expand_fn=...)`:
    returns (v, eligible, u) where `eligible` are the unvisited candidates
    surviving the per-tile first-occurrence dedup -- a subset of the
    reference path's mask that provably elects the SAME cross-chunk winners
    under `winner_dedup` (the global first occurrence of any vertex is also
    the first in its tile).

    words: the packed visited bitmap, when the caller maintains it
    incrementally across chunks (`frontier.set_bits`); None packs from the
    bool mask here -- an O(n_rows) repack per chunk, fine for one-shot
    calls but not for the engines' level loops.
    """
    ncl = all_front.shape[0]
    e = gids.shape[0]
    tile = _pick_tile(e, tile)
    nnz_cap = row_idx.shape[0]
    cc = clip_cumul(cumul, front_total)
    total = cumul[front_total][None]
    n_cumul = cc.shape[0]
    if n_cumul < window:   # tiny frontier: pad so the window load is legal
        cc = jnp.concatenate(
            [cc, jnp.full((window - n_cumul,), I32_MAX, jnp.int32)])
        n_cumul = window
    if words is None:
        words = pack_bitmap(visited)
    nw = words.shape[0]
    v, u, won = pl.pallas_call(
        functools.partial(_expand_kernel, window=window, n_cumul=n_cumul,
                          ncl=ncl, nnz_cap=nnz_cap),
        grid=(e // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),        # gid tile
            pl.BlockSpec((n_cumul,), lambda t: (0,)),     # cumul whole
            pl.BlockSpec((1,), lambda t: (0,)),           # live-edge total
            pl.BlockSpec((ncl,), lambda t: (0,)),         # gathered frontier
            pl.BlockSpec((ncl + 1,), lambda t: (0,)),     # CSC col offsets
            pl.BlockSpec((nnz_cap,), lambda t: (0,)),     # CSC row indices
            pl.BlockSpec((nw,), lambda t: (0,)),          # visited bitmap
        ],
        out_specs=[pl.BlockSpec((tile,), lambda t: (t,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), bool)],
        interpret=interpret,
    )(gids, cc, total, all_front, col_off, row_idx, words)
    return v, won, u


def _value_expand_kernel(gids_ref, cumul_ref, total_ref, front_ref, pay_ref,
                         col_off_ref, row_idx_ref, v_ref, pv_ref, addr_ref,
                         valid_ref, *, window: int, n_cumul: int, ncl: int,
                         nnz_cap: int):
    gid = gids_ref[...]
    cumul = cumul_ref[...]
    k = map_workload_tile(gid, cumul, window=window, n_cumul=n_cumul)
    k = jnp.clip(k, 0, ncl - 1)
    u = jnp.clip(jnp.take(front_ref[...], k, axis=0), 0, ncl - 1)
    addr = jnp.clip(jnp.take(col_off_ref[...], u, axis=0) + gid
                    - jnp.take(cumul, k, axis=0), 0, nnz_cap - 1)
    valid = gid < total_ref[0]
    v = jnp.where(valid, jnp.take(row_idx_ref[...], addr, axis=0), 0)
    v_ref[...] = v
    pv_ref[...] = jnp.take(pay_ref[...], k, axis=0)   # the carried value
    addr_ref[...] = addr                              # for edge_vals outside
    valid_ref[...] = valid


@functools.partial(jax.jit, static_argnames=("tile", "window", "interpret"))
def expand_chunk_values(gids, cumul, all_front, all_payload, front_total,
                        col_off, row_idx, *, tile: int = 512,
                        window: int = 256, interpret: bool = True):
    """The fused VALUE-CARRYING expand over one chunk (CC / SSSP / multi-BFS).

    Returns (v, payload, addr, valid): candidate local rows, the frontier
    payload carried along each edge, the clipped CSC edge address (so the
    caller can gather per-edge values like SSSP weights), and the live-lane
    mask.  The caller applies its relax monoid and scatter-min combine --
    keeping the kernel algorithm-agnostic, exactly like the jnp scan in
    `repro.algos.program.scan_relax`.
    """
    ncl = all_front.shape[0]
    e = gids.shape[0]
    tile = _pick_tile(e, tile)
    nnz_cap = row_idx.shape[0]
    cc = clip_cumul(cumul, front_total)
    total = cumul[front_total][None]
    n_cumul = cc.shape[0]
    if n_cumul < window:
        cc = jnp.concatenate(
            [cc, jnp.full((window - n_cumul,), I32_MAX, jnp.int32)])
        n_cumul = window
    return pl.pallas_call(
        functools.partial(_value_expand_kernel, window=window,
                          n_cumul=n_cumul, ncl=ncl, nnz_cap=nnz_cap),
        grid=(e // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((n_cumul,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
            pl.BlockSpec((ncl,), lambda t: (0,)),
            pl.BlockSpec((ncl,), lambda t: (0,)),
            pl.BlockSpec((ncl + 1,), lambda t: (0,)),
            pl.BlockSpec((nnz_cap,), lambda t: (0,)),
        ],
        out_specs=[pl.BlockSpec((tile,), lambda t: (t,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), bool)],
        interpret=interpret,
    )(gids, cc, total, all_front, all_payload, col_off, row_idx)


# ----------------------------------------------------------------------------
# Engine hooks: the chunk closures FrontierEngine threads into the scans
# ----------------------------------------------------------------------------

def make_expand_fn(*, path: str = "pallas-interpret", tile: int = 512,
                   window: int = 256):
    """The kernel-backed chunk expansion for
    `repro.core.frontier.expand_frontier(expand_fn=...)`:

        (gids, cumul, all_front, front_total, col_off, row_idx, visited,
         words=None) -> (v, eligible_mask, u)

    The closure advertises `accepts_words`: `expand_frontier` then packs
    the visited bitmap ONCE per level and maintains it incrementally,
    instead of this chunk op repacking O(n_rows) bits every chunk.
    """
    interpret = path != "pallas"

    def expand_fn(gids, cumul, all_front, front_total, col_off, row_idx,
                  visited, words=None):
        return expand_chunk(gids, cumul, all_front, front_total, col_off,
                            row_idx, visited, words, tile=tile,
                            window=window, interpret=interpret)

    expand_fn.accepts_words = True
    return expand_fn


def make_value_expand_fn(*, path: str = "pallas-interpret", tile: int = 512,
                         window: int = 256):
    """The kernel-backed value-carrying chunk expansion for
    `repro.algos.program.scan_relax(expand_fn=...)`:

        (gids, cumul, all_front, all_payload, front_total, col_off, row_idx)
            -> (v, payload, addr, valid)
    """
    interpret = path != "pallas"

    def value_expand_fn(gids, cumul, all_front, all_payload, front_total,
                        col_off, row_idx):
        return expand_chunk_values(gids, cumul, all_front, all_payload,
                                   front_total, col_off, row_idx, tile=tile,
                                   window=window, interpret=interpret)

    return value_expand_fn


# ----------------------------------------------------------------------------
# The standalone fused op (stage 4 compaction included)
# ----------------------------------------------------------------------------

class LocalExpandOut(NamedTuple):
    verts: jax.Array          # (n_rows,) discovered local rows, canonical
                              # ascending, pad -1
    parents: jax.Array        # (n_rows,) winning parent's local col, pad -1
    count: jax.Array          # () int32 number of discoveries
    visited: jax.Array        # (n_rows,) bool mask with discoveries set
    edges_scanned: jax.Array  # () uint32 live edges in the frontier


@functools.partial(
    jax.jit, static_argnames=("path", "edge_chunk", "tile", "window",
                              "dedup"))
def _local_expand(front, front_total, col_off, row_idx, visited, *,
                  path: str, edge_chunk: int, tile: int, window: int,
                  dedup: str) -> LocalExpandOut:
    n_rows = visited.shape[0]
    ncl = col_off.shape[0] - 1
    u_safe = jnp.clip(front, 0, ncl - 1)
    deg = col_off[u_safe + 1] - col_off[u_safe]
    deg = jnp.where(jnp.arange(ncl) < front_total, deg, 0)
    cumul = exclusive_cumsum(deg)
    total = cumul[front_total]
    words = pack_bitmap(visited) if path != "reference" \
        else jnp.zeros((1,), jnp.uint32)               # pytree placeholder

    def chunk_body(state):
        start, visited, words, parent, new = state
        gids = start + jnp.arange(edge_chunk, dtype=jnp.int32)
        if path == "reference":
            # exactly expand_frontier's inline jnp formulas (one source of
            # truth: repro.core.frontier.reference_expand_chunk)
            v, u, _, _, valid = reference_expand_chunk(
                gids, cumul, front, front_total, col_off, row_idx)
            elig = valid & ~visited[v]
        else:
            v, elig, u = expand_chunk(
                gids, cumul, front, front_total, col_off, row_idx, visited,
                words, tile=tile, window=window,
                interpret=path != "pallas")
        win = winner_dedup(v, elig, n_rows, method=dedup)
        tgt = jnp.where(win, v, n_rows)
        visited = visited.at[tgt].set(True, mode="drop")
        if path != "reference":
            words = set_bits(words, v, win)
        parent = parent.at[tgt].set(jnp.where(win, u, 0), mode="drop")
        new = new.at[tgt].set(True, mode="drop")
        return start + edge_chunk, visited, words, parent, new

    init = (jnp.int32(0), visited, words,
            jnp.full((n_rows,), -1, jnp.int32), jnp.zeros((n_rows,), bool))
    _, visited, _, parent, new = jax.lax.while_loop(
        lambda s: s[0] < total, chunk_body, init)

    # stage 4: compaction, canonical ascending (the repo-wide frontier order)
    rows = jnp.arange(n_rows, dtype=jnp.int32)
    key = jnp.where(new, rows, I32_MAX)
    srt = jnp.sort(key)
    ok = srt < I32_MAX
    verts = jnp.where(ok, srt, -1)
    parents = jnp.where(ok, parent[jnp.clip(srt, 0, n_rows - 1)], -1)
    return LocalExpandOut(verts=verts, parents=parents,
                          count=new.sum(dtype=jnp.int32), visited=visited,
                          edges_scanned=total.astype(jnp.uint32))


def local_expand(frontier, csc, visited, *, path: str = "auto",
                 edge_chunk: int = 2048, tile: int = 512, window: int = 256,
                 dedup: str = "scatter") -> LocalExpandOut:
    """One fused local frontier expansion (the paper's column scan).

    frontier: padded (L,) int32 local col ids (pad -1), or a (front, count)
              pair when the live count is already known.
    csc:      (col_off, row_idx) pair or any object with those attributes
              (e.g. `repro.core.types.LocalGraph2D` device blocks).
    visited:  (n_rows,) bool mask; returned updated (test-AND-set).

    Returns discoveries compacted in canonical ascending order with their
    winning parents -- bit-identical across all three expand paths.
    """
    if isinstance(frontier, (tuple, list)):
        front, count = frontier
    else:
        front, count = frontier, (jnp.asarray(frontier) >= 0).sum()
    front = jnp.asarray(front, jnp.int32)
    if hasattr(csc, "col_off"):
        col_off, row_idx = csc.col_off, csc.row_idx
    else:
        col_off, row_idx = csc
    col_off = jnp.asarray(col_off, jnp.int32)
    row_idx = jnp.asarray(row_idx, jnp.int32)
    ncl = col_off.shape[0] - 1
    if front.shape[0] > ncl:
        raise ValueError(f"frontier length {front.shape[0]} exceeds the "
                         f"{ncl} CSC columns")
    if front.shape[0] < ncl:   # pad to the column count the kernels index
        front = jnp.concatenate(
            [front, jnp.full((ncl - front.shape[0],), -1, jnp.int32)])
    return _local_expand(
        front, jnp.asarray(count, jnp.int32), col_off, row_idx,
        jnp.asarray(visited, bool), path=resolve_expand_path(path),
        edge_chunk=edge_chunk, tile=tile, window=window, dedup=dedup)
