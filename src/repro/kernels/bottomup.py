"""Fused bottom-up parent-search pipeline (DESIGN.md sec. 11).

Direction-optimised BFS (Beamer et al.; Buluc & Madduri) flips dense levels:
instead of scanning the frontier's out-edges, every UNVISITED vertex scans
its own in-edges (the CSR twin) for any parent already in the frontier.  The
fused op covers the per-chunk hot path:

  stage 1  workload map    r[t] = max { l : cumul[l] <= gid[t] } over the
                           MASKED-degree cumsum (visited rows contribute 0
                           edges, so the scan walks only live rows' edges)
  stage 2  neighbor gather c = col_idx[row_off[r] + gid - cumul[r]] (CSR
                           row-scan addressing, the transpose of the
                           top-down CSC column scan)
  stage 3  frontier test   blocked-bitmap membership of c in the gathered
                           frontier words (repro.core.frontier
                           .test_bit_blocks addressing, in-kernel)

There is NO dedup stage: the combine outside the kernel is a scatter-min of
the parent col per row, which is order-independent -- duplicates are free.

Three selectable implementations, bit-identical by construction ("pallas",
"pallas-interpret", "reference" -- the pure-jnp
`repro.core.frontier.reference_bottomup_chunk`); `resolve_bottomup_path`
implements the `BFSConfig(bottomup=...)` rules with the REPRO_BOTTOMUP
environment override, mirroring the expand/fold knobs.

The kernel's cumul is clipped BY VALUE (entries >= total -> I32_MAX), not by
index as the top-down kernel's `clip_cumul`: the masked cumsum has no live
"prefix" -- visited rows pepper zero-width runs through the whole array --
but every entry that reaches `total` can never satisfy cumul[l] <= gid for a
valid gid < total, so the I32_MAX tail terminates `map_workload_tile`'s
window loop without disturbing the row mapping on live lanes.

This module needs jax.experimental.pallas; path SELECTION lives in
`repro.kernels.select` so reference-path engines import clean without it.
Import this module only at top level (never lazily inside a traced
function).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.frontier import I32_MAX
from repro.kernels._binsearch_map import map_workload_tile
from repro.kernels.expand import _pick_tile
from repro.kernels.select import (BOTTOMUP_ENV, BOTTOMUP_PATHS,  # noqa: F401
                                  resolve_bottomup_path)


def _clip_by_value(cumul, total):
    """Masked-cumsum analog of `clip_cumul` (see module docstring)."""
    return jnp.where(cumul < total, cumul, I32_MAX)


def _test_words(words, c, *, block: int):
    """In-kernel blocked-bitmap test (mirrors frontier.test_bit_blocks)."""
    W = (block + 31) // 32
    blk, off = c // block, c % block
    w = jnp.take(words, blk * W + (off >> 5), axis=0)
    return ((w >> (off & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0


# ----------------------------------------------------------------------------
# The fused kernels (stage 1 + 2 + 3 in one pallas_call)
# ----------------------------------------------------------------------------

def _bottomup_kernel(gids_ref, cumul_ref, total_ref, row_off_ref,
                     col_idx_ref, words_ref, r_ref, c_ref, hit_ref, *,
                     window: int, n_cumul: int, nrl: int, nnz_cap: int,
                     block: int):
    gid = gids_ref[...]
    cumul = cumul_ref[...]          # value-clipped: entries >= total = I32_MAX
    # stage 1: thread->edge workload mapping over the masked cumsum
    r = map_workload_tile(gid, cumul, window=window, n_cumul=n_cumul)
    r = jnp.clip(r, 0, nrl - 1)
    # stage 2: in-neighbor gather via CSR addressing (live lanes read the
    # same cumul[r] as the unclipped scan: cumul[r] <= gid < total there)
    addr = jnp.take(row_off_ref[...], r, axis=0) + gid \
        - jnp.take(cumul, r, axis=0)
    addr = jnp.clip(addr, 0, nnz_cap - 1)
    valid = gid < total_ref[0]
    c = jnp.where(valid, jnp.take(col_idx_ref[...], addr, axis=0), 0)
    # stage 3: frontier-bitmap membership (blocked layout)
    hit = valid & _test_words(words_ref[...], c, block=block)
    r_ref[...] = r
    c_ref[...] = c
    hit_ref[...] = hit


@functools.partial(jax.jit,
                   static_argnames=("block", "tile", "window", "interpret"))
def bottomup_chunk(gids, cumul, total, row_off, col_idx, words, *,
                   block: int, tile: int = 512, window: int = 256,
                   interpret: bool = True):
    """The fused parent search over one chunk of consecutive edge ids.

    cumul: (nrl + 1,) exclusive cumsum of MASKED degrees (visited rows 0);
    total: () live edge count (= cumul[-1]); words: (R * W,) row-gathered
    frontier bitmap in blocked layout (block = S bits per device).

    Returns (r, c, hit) exactly as
    `repro.core.frontier.reference_bottomup_chunk` -- the caller scatter-mins
    c into a per-row best-parent array.
    """
    e = gids.shape[0]
    tile = _pick_tile(e, tile)
    nrl = row_off.shape[0] - 1
    nnz_cap = col_idx.shape[0]
    cc = _clip_by_value(cumul, total)
    n_cumul = cc.shape[0]
    if n_cumul < window:   # tiny partition: pad so the window load is legal
        cc = jnp.concatenate(
            [cc, jnp.full((window - n_cumul,), I32_MAX, jnp.int32)])
        n_cumul = window
    nw = words.shape[0]
    return pl.pallas_call(
        functools.partial(_bottomup_kernel, window=window, n_cumul=n_cumul,
                          nrl=nrl, nnz_cap=nnz_cap, block=block),
        grid=(e // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),        # gid tile
            pl.BlockSpec((n_cumul,), lambda t: (0,)),     # masked cumsum
            pl.BlockSpec((1,), lambda t: (0,)),           # live-edge total
            pl.BlockSpec((nrl + 1,), lambda t: (0,)),     # CSR row offsets
            pl.BlockSpec((nnz_cap,), lambda t: (0,)),     # CSR col indices
            pl.BlockSpec((nw,), lambda t: (0,)),          # frontier bitmap
        ],
        out_specs=[pl.BlockSpec((tile,), lambda t: (t,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), bool)],
        interpret=interpret,
    )(gids, cc, total[None], row_off, col_idx, words)


def _value_bottomup_kernel(gids_ref, cumul_ref, total_ref, row_off_ref,
                           col_idx_ref, words_ref, pay_ref, r_ref, pv_ref,
                           addr_ref, hit_ref, *, window: int, n_cumul: int,
                           nrl: int, nnz_cap: int, block: int):
    gid = gids_ref[...]
    cumul = cumul_ref[...]
    r = map_workload_tile(gid, cumul, window=window, n_cumul=n_cumul)
    r = jnp.clip(r, 0, nrl - 1)
    addr = jnp.take(row_off_ref[...], r, axis=0) + gid \
        - jnp.take(cumul, r, axis=0)
    addr = jnp.clip(addr, 0, nnz_cap - 1)
    valid = gid < total_ref[0]
    c = jnp.where(valid, jnp.take(col_idx_ref[...], addr, axis=0), 0)
    hit = valid & _test_words(words_ref[...], c, block=block)
    r_ref[...] = r
    pv_ref[...] = jnp.take(pay_ref[...], c, axis=0)   # the pulled value
    addr_ref[...] = addr                              # for edge_vals outside
    hit_ref[...] = hit


@functools.partial(jax.jit,
                   static_argnames=("block", "tile", "window", "interpret"))
def bottomup_chunk_values(gids, cumul, total, row_off, col_idx, words,
                          dense_pay, *, block: int, tile: int = 512,
                          window: int = 256, interpret: bool = True):
    """The fused VALUE-PULLING parent search over one chunk (CC / SSSP /
    multi-BFS in bottom-up levels).

    dense_pay: (n_cols_local,) the frontier payload as a DENSE per-col
    channel (value programs pull the neighbour's label/distance).  Returns
    (r, pay, addr, hit) exactly as
    `repro.core.frontier.reference_bottomup_values_chunk`; the caller
    applies its relax monoid and scatter-min combine.
    """
    e = gids.shape[0]
    tile = _pick_tile(e, tile)
    nrl = row_off.shape[0] - 1
    nnz_cap = col_idx.shape[0]
    ncl = dense_pay.shape[0]
    cc = _clip_by_value(cumul, total)
    n_cumul = cc.shape[0]
    if n_cumul < window:
        cc = jnp.concatenate(
            [cc, jnp.full((window - n_cumul,), I32_MAX, jnp.int32)])
        n_cumul = window
    nw = words.shape[0]
    return pl.pallas_call(
        functools.partial(_value_bottomup_kernel, window=window,
                          n_cumul=n_cumul, nrl=nrl, nnz_cap=nnz_cap,
                          block=block),
        grid=(e // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((n_cumul,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
            pl.BlockSpec((nrl + 1,), lambda t: (0,)),
            pl.BlockSpec((nnz_cap,), lambda t: (0,)),
            pl.BlockSpec((nw,), lambda t: (0,)),
            pl.BlockSpec((ncl,), lambda t: (0,)),         # dense payload
        ],
        out_specs=[pl.BlockSpec((tile,), lambda t: (t,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), jnp.int32),
                   jax.ShapeDtypeStruct((e,), bool)],
        interpret=interpret,
    )(gids, cc, total[None], row_off, col_idx, words, dense_pay)


# ----------------------------------------------------------------------------
# Engine hooks: the chunk closures the bottom-up steps thread into their scans
# ----------------------------------------------------------------------------

def make_bottomup_fn(*, path: str = "pallas-interpret", tile: int = 512,
                     window: int = 256):
    """The kernel-backed chunk parent search for the bottom-up BFS step:

        (gids, cumul, total, row_off, col_idx, words, block=S) -> (r, c, hit)
    """
    interpret = path != "pallas"

    def bottomup_fn(gids, cumul, total, row_off, col_idx, words, *,
                    block: int):
        return bottomup_chunk(gids, cumul, total, row_off, col_idx, words,
                              block=block, tile=tile, window=window,
                              interpret=interpret)

    return bottomup_fn


def make_value_bottomup_fn(*, path: str = "pallas-interpret",
                           tile: int = 512, window: int = 256):
    """The kernel-backed value-pulling chunk parent search (value programs):

        (gids, cumul, total, row_off, col_idx, words, dense_pay, block=S)
            -> (r, pay, addr, hit)
    """
    interpret = path != "pallas"

    def value_bottomup_fn(gids, cumul, total, row_off, col_idx, words,
                          dense_pay, *, block: int):
        return bottomup_chunk_values(gids, cumul, total, row_off, col_idx,
                                     words, dense_pay, block=block,
                                     tile=tile, window=window,
                                     interpret=interpret)

    return value_bottomup_fn
