"""CSC-column concatenation kernel (the expand gather, paper sec. 3.4).

For every frontier vertex u_k the kernel copies its CSC column
row_idx[front_off[k] : front_off[k] + deg_k] into the contiguous edge buffer
at cumul[k].  This is the memory-movement half of the paper's column scan:
piecewise-contiguous segments, which on TPU are DMA-shaped (block copies)
rather than per-lane gathers.

Grid = one step per frontier slot; each step moves its segment in fixed
CHUNK-sized pieces.  A trailing partial chunk intentionally over-copies up to
CHUNK-1 elements: TPU (and interpret) grids execute steps sequentially on a
core, so segment k+1 simply overwrites k's overflow -- the same trick the
paper uses when a thread's 4-edge group overlaps the next column.  The output
carries CHUNK slack at the end for the final segment's overflow.

Production note: on real TPUs the pl.load/pl.store pair on ANY-space refs
lowers to VMEM round-trips; the tuned variant issues
pltpu.make_async_copy(src.at[...], dst.at[...]) HBM->HBM DMAs instead.  The
interpret-mode semantics are identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(front_off_ref, cumul_ref, row_idx_ref, out_ref, *, chunk: int):
    k = pl.program_id(0)
    src0 = pl.load(front_off_ref, (pl.ds(k, 1),))[0]
    c0 = pl.load(cumul_ref, (pl.ds(k, 1),))[0]
    c1 = pl.load(cumul_ref, (pl.ds(k + 1, 1),))[0]
    deg = c1 - c0

    def body(s):
        off = s
        piece = pl.load(row_idx_ref, (pl.ds(src0 + off, chunk),))
        pl.store(out_ref, (pl.ds(c0 + off, chunk),), piece)
        return off + chunk

    jax.lax.while_loop(lambda off: off < deg, body, jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("out_size", "chunk", "interpret"))
def gather_segments(front_off, cumul, row_idx, *, out_size: int,
                    chunk: int = 128, interpret: bool = True):
    """Returns (out_size + chunk,) int32 edge buffer (valid: first cumul[-1])."""
    F = front_off.shape[0]
    # slack so the last chunked load/store never runs off the arrays
    row_idx_p = jnp.concatenate(
        [row_idx, jnp.full((chunk,), -1, jnp.int32)])
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(F,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((out_size + chunk,), jnp.int32),
        interpret=interpret,
    )(front_off, cumul, row_idx_p)
