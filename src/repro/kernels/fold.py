"""Fused Pallas fold kernels (paper sec. 3.3; DESIGN.md sec. 10).

The fold half of Buluc & Madduri's expand/fold decomposition spends its
per-level device time in three places: packing discovery buckets into the
codec wire format (bitmap bit-packing, delta gap-encoding), unpacking the
received message, and COMPACTION -- front-packing valid entries of a padded
row, which the reference path does with an `argsort` per level in
`pack_blocks`, `owned_to_front`, `expand_exchange_values` and
`compact_blocks`.  This module implements those stages as Pallas kernels:

  compact_rows    the prefix-sum compaction primitive: an exclusive count
                  prefix-sum over the validity mask (host jnp, O(S) -- the
                  same role `cumul` plays for the expand scan) turns
                  front-packing into a per-lane rank-select, which the
                  kernel answers with an unrolled vectorised binary search
                  over the monotone prefix array (log2 S dense gathers per
                  row instead of an O(S log S) sort);
  pack_bits /     the bitmap codec's 1-bit-per-vertex pack/unpack as dense
  unpack_bits     VPU shift/weight ops over 32-lane groups;
  delta_gaps /    the delta codec's first-order gap encode (on sorted rows;
  delta_positions the sort itself stays XLA) and the cumsum decode.

Every kernel is bit-identical to the reference jnp path by construction:
compaction output (ascending, front-packed, fill-padded) is fully determined
by the mask, so rank-select and stable argsort produce the same arrays; the
bit/gap codecs compute the same formulas lane for lane.

`make_fold_ops(path=...)` bundles the kernels into the ops object the
engines thread through `repro.dist.exchange` and `repro.algos.program`
(`BFSConfig(fold=...)`, resolved by `repro.kernels.select.resolve_fold_path`
with the REPRO_FOLD override -- the exact mirror of the expand-path
plumbing, DESIGN.md sec. 9.2).

This module needs jax.experimental.pallas; path SELECTION does not and
lives in `repro.kernels.select` so reference-path engines import clean
without it.  Import this module only at top level (never lazily inside a
traced function): it caches jnp constants at import time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.select import (FOLD_ENV, FOLD_PATHS,  # noqa: F401
                                  resolve_fold_path)

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def _ceil_log2(n: int) -> int:
    """Iterations for a binary search over n+1 candidate indices."""
    return max(1, (n).bit_length())


# ----------------------------------------------------------------------------
# compact_rows: the prefix-sum compaction primitive
# ----------------------------------------------------------------------------

def _rank_select(ec, S: int, iters: int):
    """idx[s] = max { l : ec[l] <= s } for all output slots s in [0, S).

    ec is the (S+1,) exclusive count prefix-sum of the row's validity mask
    (monotone, ec[0] = 0): for s < ec[S], idx[s] is the source index of the
    s-th valid element -- rank-select as an unrolled per-lane binary search
    (log2(S+1) dense VPU gathers; `jnp.take` of int32 lanes is the same
    VMEM gather `filter_tile` uses)."""
    s = jax.lax.iota(jnp.int32, S)
    lo = jnp.zeros((S,), jnp.int32)       # invariant: ec[lo] <= s (ec[0]=0)
    hi = jnp.full((S,), S, jnp.int32)
    for _ in range(iters):
        mid = (lo + hi + jnp.int32(1)) >> 1
        go = jnp.take(ec, mid, axis=0) <= s
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    return lo


def _compact_kernel(ec_ref, *refs, n_arrays: int, fills: tuple, S: int,
                    iters: int):
    ec = ec_ref[0]
    idx = _rank_select(ec, S, iters)
    valid = jax.lax.iota(jnp.int32, S) < ec[S]
    src = jnp.clip(idx, 0, S - 1)
    for a in range(n_arrays):
        refs[n_arrays + a][0, :] = jnp.where(
            valid, jnp.take(refs[a][0], src, axis=0),
            jnp.int32(fills[a]))


@functools.partial(jax.jit, static_argnames=("fills", "interpret"))
def _compact_rows(mask, arrays, fills, *, interpret: bool):
    N, S = mask.shape
    inc = jnp.cumsum(mask.astype(jnp.int32), axis=1)
    ec = jnp.concatenate([jnp.zeros((N, 1), jnp.int32), inc], axis=1)
    n_arrays = len(arrays)
    packed = pl.pallas_call(
        functools.partial(_compact_kernel, n_arrays=n_arrays, fills=fills,
                          S=S, iters=_ceil_log2(S)),
        grid=(N,),
        in_specs=[pl.BlockSpec((1, S + 1), lambda r: (r, 0))]
        + [pl.BlockSpec((1, S), lambda r: (r, 0))] * n_arrays,
        out_specs=[pl.BlockSpec((1, S), lambda r: (r, 0))] * n_arrays,
        out_shape=[jax.ShapeDtypeStruct((N, S), jnp.int32)] * n_arrays,
        interpret=interpret,
    )(ec, *arrays)
    return tuple(packed), inc[:, -1]


def compact_rows(mask, arrays, fills, *, interpret: bool = True):
    """Front-pack each row's valid entries, preserving order (the argsort
    replacement shared by `pack_blocks`, `owned_to_front`,
    `expand_exchange_values`, `compact_blocks` and the bitmap decode).

    mask: (N, S) bool validity; arrays: aligned (N, S) int32 channels;
    fills: per-array pad value.  Returns (tuple of packed (N, S) arrays,
    (N,) int32 counts) -- bit-identical to compacting with a stable argsort
    of the mask.
    """
    arrays = tuple(jnp.asarray(a, jnp.int32) for a in arrays)
    return _compact_rows(jnp.asarray(mask, bool), arrays,
                         tuple(int(f) for f in fills), interpret=interpret)


# ----------------------------------------------------------------------------
# Bitmap pack/unpack
# ----------------------------------------------------------------------------

def _bit_weights():
    """(32,) uint32 [1, 2, 4, ...] built in-kernel (Pallas kernels cannot
    capture module-level array constants)."""
    return jnp.uint32(1) << jax.lax.iota(jnp.uint32, 32)


def _pack_kernel(mask_ref, words_ref, *, W: int):
    m = mask_ref[0].reshape(W, 32).astype(jnp.uint32)
    words_ref[0, :] = jnp.sum(m * _bit_weights()[None, :], axis=-1,
                              dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_bits(mask, *, interpret: bool = True):
    """(N, S) bool -> (N, ceil(S/32)) uint32 little-endian bit packing
    (the kernel twin of `repro.core.frontier.pack_bitmap`)."""
    N, S = mask.shape
    W = (S + 31) // 32
    pad = W * 32 - S
    if pad:
        mask = jnp.concatenate([mask, jnp.zeros((N, pad), bool)], axis=1)
    return pl.pallas_call(
        functools.partial(_pack_kernel, W=W),
        grid=(N,),
        in_specs=[pl.BlockSpec((1, W * 32), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, W), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W), jnp.uint32),
        interpret=interpret,
    )(mask)


def _unpack_kernel(words_ref, bits_ref, *, W: int):
    w = words_ref[0]
    bits = (w[:, None] >> jax.lax.iota(jnp.uint32, 32)[None, :]) \
        & jnp.uint32(1)
    bits_ref[0, :] = bits.reshape(W * 32).astype(jnp.bool_)


@functools.partial(jax.jit, static_argnames=("S", "interpret"))
def unpack_bits(words, S: int, *, interpret: bool = True):
    """(N, W) uint32 -> (N, S) bool (the kernel twin of `unpack_bitmap`)."""
    N, W = words.shape
    bits = pl.pallas_call(
        functools.partial(_unpack_kernel, W=W),
        grid=(N,),
        in_specs=[pl.BlockSpec((1, W), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, W * 32), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W * 32), jnp.bool_),
        interpret=interpret,
    )(words)
    return bits[:, :S]


# ----------------------------------------------------------------------------
# Delta gap encode / cumsum decode
# ----------------------------------------------------------------------------

def _gaps_kernel(ts_ref, valid_ref, gaps_ref, *, S: int):
    ts = ts_ref[0]
    pos = jax.lax.iota(jnp.int32, S)
    prev = jnp.where(pos > 0, jnp.take(ts, jnp.maximum(pos - 1, 0), axis=0),
                     0)
    gaps_ref[0, :] = jnp.where(valid_ref[0], ts - prev, 0) \
        .astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_gaps(ts, valid, *, interpret: bool = True):
    """Sorted per-row offsets -> uint16 first-order gaps (slot 0 absolute),
    the encode half of the delta codec on PRE-SORTED rows (the sort stays
    XLA; canonical value-fold buckets arrive already sorted)."""
    N, S = ts.shape
    return pl.pallas_call(
        functools.partial(_gaps_kernel, S=S),
        grid=(N,),
        in_specs=[pl.BlockSpec((1, S), lambda r: (r, 0))] * 2,
        out_specs=pl.BlockSpec((1, S), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N, S), jnp.uint16),
        interpret=interpret,
    )(ts, valid)


def _positions_kernel(gaps_ref, pos_ref):
    pos_ref[0, :] = jnp.cumsum(gaps_ref[0].astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_positions(gaps, *, interpret: bool = True):
    """(N, S) uint16 gaps -> (N, S) int32 absolute offsets (cumsum), the
    decode half of the delta codec."""
    N, S = gaps.shape
    return pl.pallas_call(
        _positions_kernel,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, S), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, S), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N, S), jnp.int32),
        interpret=interpret,
    )(gaps)


# ----------------------------------------------------------------------------
# The ops bundle the engines thread through exchange/program
# ----------------------------------------------------------------------------

class PallasFoldOps:
    """The fold-kernel surface (`engine.fold_ops`): one object bundling the
    compaction/pack/unpack/delta kernels with the interpret flag bound, so
    call sites stay ignorant of the path.  `None` in its place means the
    reference jnp formulas (exactly the pre-sec.-10 code)."""

    def __init__(self, path: str = "pallas-interpret"):
        if path not in ("pallas", "pallas-interpret"):
            raise ValueError(f"fold ops need a pallas path, got {path!r}")
        self.name = path
        self.interpret = path != "pallas"

    def __repr__(self):
        return f"PallasFoldOps({self.name!r})"

    def compact_rows(self, mask, arrays, fills):
        return compact_rows(mask, arrays, fills, interpret=self.interpret)

    def pack_bits(self, mask):
        return pack_bits(mask, interpret=self.interpret)

    def unpack_bits(self, words, S: int):
        return unpack_bits(words, S, interpret=self.interpret)

    def delta_gaps(self, ts, valid):
        return delta_gaps(ts, valid, interpret=self.interpret)

    def delta_positions(self, gaps):
        return delta_positions(gaps, interpret=self.interpret)


def make_fold_ops(*, path: str = "pallas-interpret") -> PallasFoldOps:
    """The kernel bundle for a resolved non-reference fold path."""
    return PallasFoldOps(path)
