"""Pallas kernels for the BFS hot spots (paper sec. 3.4/3.4.1).

The paper's column-scan CUDA kernel lives here as ONE fused op plus its
stages (DESIGN.md sec. 9):

  expand.local_expand  -- the fused local-expand pipeline: workload mapping,
                          neighbor gather, bitmap visited filter and output
                          compaction, with "pallas" / "pallas-interpret" /
                          "reference" implementations that are bit-identical;
  fold                 -- the fused fold pipeline (DESIGN.md sec. 10): the
                          prefix-sum compaction primitive (the per-level
                          argsort replacement), bitmap pack/unpack and delta
                          encode/decode kernels, bundled by `make_fold_ops`
                          and selected via `BFSConfig(fold=...)`;
  binsearch_map        -- the thread->edge mapping stage as a standalone op
                          (monotonic windowed broadcast-compare);
  visited_filter       -- the bitmap test + first-occurrence dedup stage as
                          a standalone op (the atomicOr analog);
  ref                  -- pure-jnp stage oracles for the parity tests.

The engines select a path with `BFSConfig(expand=...)` and thread the chunk
closures from `make_expand_fn` / `make_value_expand_fn` into their scans.

Everything is exported lazily (PEP 562) so `import repro` / `import
repro.kernels` works on installs without jax.experimental.pallas; only
touching a kernel symbol requires Pallas, and a missing Pallas surfaces as a
clear ImportError at that point.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # the fused op and its engine hooks (repro.kernels.expand)
    "local_expand": "repro.kernels.expand",
    "LocalExpandOut": "repro.kernels.expand",
    "expand_chunk": "repro.kernels.expand",
    "expand_chunk_values": "repro.kernels.expand",
    "make_expand_fn": "repro.kernels.expand",
    "make_value_expand_fn": "repro.kernels.expand",
    # the fused bottom-up parent search (repro.kernels.bottomup, sec. 11)
    "bottomup_chunk": "repro.kernels.bottomup",
    "bottomup_chunk_values": "repro.kernels.bottomup",
    "make_bottomup_fn": "repro.kernels.bottomup",
    "make_value_bottomup_fn": "repro.kernels.bottomup",
    # the fused fold pipeline (repro.kernels.fold, DESIGN.md sec. 10)
    "compact_rows": "repro.kernels.fold",
    "pack_bits": "repro.kernels.fold",
    "unpack_bits": "repro.kernels.fold",
    "delta_gaps": "repro.kernels.fold",
    "delta_positions": "repro.kernels.fold",
    "make_fold_ops": "repro.kernels.fold",
    "PallasFoldOps": "repro.kernels.fold",
    # selection is Pallas-free (repro.kernels.select): engines resolve paths
    # on every construction, including on installs without Pallas
    "resolve_expand_path": "repro.kernels.select",
    "resolve_fold_path": "repro.kernels.select",
    "resolve_bottomup_path": "repro.kernels.select",
    "EXPAND_PATHS": "repro.kernels.select",
    "EXPAND_ENV": "repro.kernels.select",
    "FOLD_PATHS": "repro.kernels.select",
    "FOLD_ENV": "repro.kernels.select",
    "BOTTOMUP_PATHS": "repro.kernels.select",
    "BOTTOMUP_ENV": "repro.kernels.select",
    # stage ops
    "binsearch_map": "repro.kernels._binsearch_map",
    "map_workload_tile": "repro.kernels._binsearch_map",
    "clip_cumul": "repro.kernels._binsearch_map",
    "visited_filter": "repro.kernels._visited_filter",
    "filter_tile": "repro.kernels._visited_filter",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    try:
        mod = importlib.import_module(module)
    except ImportError as e:   # Pallas (or its deps) unavailable
        raise ImportError(
            f"repro.kernels.{name} needs jax.experimental.pallas, which "
            f"failed to import; use BFSConfig(expand='reference') / "
            f"BFSConfig(fold='reference') / BFSConfig(bottomup='reference') "
            f"on this install ({e})") from e
    return getattr(mod, name)


def __dir__():
    return __all__
