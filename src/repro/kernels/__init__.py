"""Pallas TPU kernels for the BFS hot spots (paper sec. 3.4/3.4.1).

The paper's column-scan CUDA kernel decomposes on TPU into:
  binsearch_map   -- thread->edge mapping (scan + search) as a monotonic
                     windowed broadcast-compare (VPU-dense, no per-lane
                     divergent binary search);
  gather_segments -- concatenation of the frontier's CSC columns into a
                     contiguous edge buffer (chunked sequential-grid DMA);
  visited_filter  -- bitmap test + first-occurrence dedup (the atomicOr
                     analog; dense triangular compare replaces the race).

Each has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.
"""
from repro.kernels.ops import binsearch_map, gather_segments, visited_filter, \
    make_expand_fn
