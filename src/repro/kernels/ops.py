"""jit'd wrappers + the drop-in expand_fn for repro.core.frontier.

`interpret=True` everywhere by default: this container is CPU-only; on a TPU
runtime the same calls compile via Mosaic (interpret=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.binsearch_map import binsearch_map
from repro.kernels.gather_segments import gather_segments
from repro.kernels.visited_filter import visited_filter

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def clip_cumul(cumul, front_total):
    """Entries past the live frontier -> I32_MAX (terminates the kernel's
    window loop right after the prefix; see binsearch_map docstring)."""
    idx = jnp.arange(cumul.shape[0], dtype=jnp.int32)
    return jnp.where(idx <= front_total, cumul, I32_MAX)


def make_expand_fn(*, tile: int = 512, window: int = 256,
                   interpret: bool = True):
    """Returns the kernel-backed chunk expansion for
    `repro.core.frontier.expand_frontier(expand_fn=...)`:

        (gids, cumul, all_front, front_total, col_off, row_idx, visited)
            -> (v, unvisited_mask, u)
    """

    def expand_fn(gids, cumul, all_front, front_total, col_off, row_idx,
                  visited):
        ncl = all_front.shape[0]
        cc = clip_cumul(cumul, front_total)
        k = binsearch_map(cc, gids, tile=tile, window=window,
                          interpret=interpret)
        k = jnp.clip(k, 0, ncl - 1)
        u = jnp.clip(all_front[k], 0, ncl - 1)
        addr = col_off[u] + gids - cumul[k]
        total = cumul[front_total]
        valid = gids < total
        v = row_idx[jnp.clip(addr, 0, row_idx.shape[0] - 1)]
        v = jnp.where(valid, v, 0)
        unvis = valid & ~visited[v]
        return v, unvis, u

    return expand_fn


__all__ = ["binsearch_map", "gather_segments", "visited_filter",
           "make_expand_fn", "clip_cumul"]
