"""Visited-bitmap filter kernel (paper Alg. 3 lines 5-8, the atomicOr dedup).

Per edge tile: test each candidate vertex's bit in the visited bitmap and
keep only the FIRST slot carrying each vertex -- exactly the winner that the
Kepler atomicOr race would elect, but deterministic.

TPU adaptation: the race is replaced by a dense triangular self-compare of
the tile (TILE x TILE bool ops on the VPU), and the word lookup is a dynamic
gather over the bitmap held in VMEM (Mosaic lowers 1D int32 dynamic gathers
to the VPU; the bitmap for 2^20 local rows is 128 KiB).  Bit SETTING stays
outside (an XLA scatter): grid steps are sequential per core so a fused
in-kernel RMW is legal on TPU, but the scatter keeps the kernel read-only and
lets XLA fuse the set with the level/pred updates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def filter_tile(v, valid, words):
    """The kernel body on VALUES: bitmap test + first-occurrence dedup for
    ONE tile.  Also the visited-filter STAGE of the fused local-expand
    pipeline (repro.kernels.expand)."""
    n_words = words.shape[0]
    w = jnp.clip(v >> 5, 0, n_words - 1)
    old = jnp.take(words, w, axis=0)
    bit = (old >> (v & 31).astype(jnp.uint32)) & jnp.uint32(1)
    unvis = valid & (bit == 0)
    tile = v.shape[0]
    eq = (v[:, None] == v[None, :]) & valid[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1)
    dup = jnp.any(eq & (jj < ii), axis=1)
    return unvis & ~dup


def _kernel(v_ref, valid_ref, words_ref, won_ref):
    won_ref[...] = filter_tile(v_ref[...], valid_ref[...], words_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def visited_filter(v, valid, bitmap_words, *, tile: int = 256,
                   interpret: bool = True):
    """won (bool, same shape as v): first unvisited occurrence per vertex.

    NOTE: dedup is per-TILE (as the paper's dedup is per-race-window); the
    caller's scatter-min winner selection handles cross-tile duplicates.
    """
    e = v.shape[0]
    assert e % tile == 0
    nw = bitmap_words.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(e // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((nw,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((e,), bool),
        interpret=interpret,
    )(v, valid, bitmap_words)
