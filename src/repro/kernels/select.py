"""Expand-path selection (the `BFSConfig(expand=...)` rules; DESIGN.md
sec. 9).

Deliberately Pallas-free: the engines call `resolve_expand_path` on EVERY
construction -- including expand="reference" ones on installs without
jax.experimental.pallas -- so the selection logic must import without it.
The kernels themselves live in `repro.kernels.expand` and are only imported
once a non-reference path is selected.
"""
from __future__ import annotations

import os

EXPAND_PATHS = ("reference", "pallas", "pallas-interpret")
EXPAND_ENV = "REPRO_EXPAND"


def resolve_expand_path(spec="auto", *, platform: str | None = None) -> str:
    """Concretise an expand-path spelling.

    spec: "reference" | "pallas" | "pallas-interpret" are themselves;
    "auto" (or None) consults the REPRO_EXPAND environment variable first
    (so CI matrix legs force the kernel path process-wide) and otherwise
    picks "pallas" on GPU/TPU backends, "reference" on CPU.
    """
    if spec is None:
        spec = "auto"
    if spec == "auto":
        env = os.environ.get(EXPAND_ENV, "").strip().lower()
        if env and env != "auto":
            if env not in EXPAND_PATHS:
                raise ValueError(
                    f"{EXPAND_ENV}={env!r}: expected one of {EXPAND_PATHS} "
                    f"or 'auto'")
            return env
        if platform is None:
            import jax
            platform = jax.default_backend()
        return "pallas" if platform in ("gpu", "tpu", "cuda", "rocm") \
            else "reference"
    if spec not in EXPAND_PATHS:
        raise ValueError(
            f"expand={spec!r}: expected one of {EXPAND_PATHS + ('auto',)}")
    return spec
