"""Kernel-path selection (the `BFSConfig(expand=...)` / `BFSConfig(fold=...)`
rules; DESIGN.md sec. 9 + 10).

Deliberately Pallas-free: the engines call `resolve_expand_path` and
`resolve_fold_path` on EVERY construction -- including "reference" ones on
installs without jax.experimental.pallas -- so the selection logic must
import without it.  The kernels themselves live in `repro.kernels.expand` /
`repro.kernels.fold` and are only imported once a non-reference path is
selected.

All knobs share one spelling set ("reference" | "pallas" |
"pallas-interpret" | "auto") and one resolution rule; they differ only in
the environment override that CI matrix legs use to force a path
process-wide (REPRO_EXPAND for the expand scan, REPRO_FOLD for the fold
pipeline, REPRO_BOTTOMUP for the bottom-up parent search).
"""
from __future__ import annotations

import os

EXPAND_PATHS = ("reference", "pallas", "pallas-interpret")
EXPAND_ENV = "REPRO_EXPAND"

FOLD_PATHS = EXPAND_PATHS
FOLD_ENV = "REPRO_FOLD"

BOTTOMUP_PATHS = EXPAND_PATHS
BOTTOMUP_ENV = "REPRO_BOTTOMUP"


def _resolve(spec, *, env: str, knob: str, platform: str | None) -> str:
    if spec is None:
        spec = "auto"
    if spec == "auto":
        override = os.environ.get(env, "").strip().lower()
        if override and override != "auto":
            if override not in EXPAND_PATHS:
                raise ValueError(
                    f"{env}={override!r}: expected one of {EXPAND_PATHS} "
                    f"or 'auto'")
            return override
        if platform is None:
            import jax
            platform = jax.default_backend()
        return "pallas" if platform in ("gpu", "tpu", "cuda", "rocm") \
            else "reference"
    if spec not in EXPAND_PATHS:
        raise ValueError(
            f"{knob}={spec!r}: expected one of {EXPAND_PATHS + ('auto',)}")
    return spec


def resolve_expand_path(spec="auto", *, platform: str | None = None) -> str:
    """Concretise an expand-path spelling.

    spec: "reference" | "pallas" | "pallas-interpret" are themselves;
    "auto" (or None) consults the REPRO_EXPAND environment variable first
    (so CI matrix legs force the kernel path process-wide) and otherwise
    picks "pallas" on GPU/TPU backends, "reference" on CPU.
    """
    return _resolve(spec, env=EXPAND_ENV, knob="expand", platform=platform)


def resolve_fold_path(spec="auto", *, platform: str | None = None) -> str:
    """Concretise a fold-path spelling (same rules, REPRO_FOLD override)."""
    return _resolve(spec, env=FOLD_ENV, knob="fold", platform=platform)


def resolve_bottomup_path(spec="auto", *, platform: str | None = None) -> str:
    """Concretise a bottom-up-path spelling (same rules, REPRO_BOTTOMUP
    override)."""
    return _resolve(spec, env=BOTTOMUP_ENV, knob="bottomup",
                    platform=platform)
