"""Pure-jnp oracles for the Pallas kernel STAGES (repro.kernels.expand's
fused op additionally has a full reference path of its own: the
path="reference" branch of `local_expand`, which the parity tests pin
against these stage oracles and against the engines' inline scans)."""
from __future__ import annotations

import jax.numpy as jnp

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def binsearch_map_ref(cumul, gids):
    """k[t] = max { l : cumul[l] <= gids[t] } (paper's binsearch_maxle)."""
    return (jnp.searchsorted(cumul, gids, side="right").astype(jnp.int32) - 1)


def visited_filter_ref(v, valid, bitmap_words):
    """won[t] = valid[t] and bit v[t] unset and t is the first slot with v[t].

    Mirrors the paper's atomicOr(&bmap[v/32], m) first-thread-wins check
    (Alg. 3 lines 5-8), deterministically.
    """
    n = v.shape[0]
    w = jnp.clip(v >> 5, 0, bitmap_words.shape[0] - 1)
    bit = (bitmap_words[w] >> (v & 31).astype(jnp.uint32)) & 1
    unvis = valid & (bit == 0)
    eq = (v[:, None] == v[None, :]) & valid[None, :]
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    dup = jnp.any(eq & earlier, axis=1)
    return unvis & ~dup
