"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp

I32_MAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def binsearch_map_ref(cumul, gids):
    """k[t] = max { l : cumul[l] <= gids[t] } (paper's binsearch_maxle)."""
    return (jnp.searchsorted(cumul, gids, side="right").astype(jnp.int32) - 1)


def gather_segments_ref(front_off, cumul, row_idx, out_size: int):
    """Concatenate row_idx[front_off[k] : front_off[k] + deg_k] at cumul[k].

    front_off: (F,) segment starts in row_idx; cumul: (F+1,) exclusive scan
    of segment lengths (entries beyond the real frontier repeat the total).
    Returns (out_size,) with unused tail = -1.
    """
    slots = jnp.arange(out_size, dtype=jnp.int32)
    k = binsearch_map_ref(cumul, slots)
    k = jnp.clip(k, 0, front_off.shape[0] - 1)
    addr = front_off[k] + slots - cumul[k]
    valid = slots < cumul[-1]
    v = row_idx[jnp.clip(addr, 0, row_idx.shape[0] - 1)]
    return jnp.where(valid, v, -1)


def visited_filter_ref(v, valid, bitmap_words):
    """won[t] = valid[t] and bit v[t] unset and t is the first slot with v[t].

    Mirrors the paper's atomicOr(&bmap[v/32], m) first-thread-wins check
    (Alg. 3 lines 5-8), deterministically.
    """
    n = v.shape[0]
    w = jnp.clip(v >> 5, 0, bitmap_words.shape[0] - 1)
    bit = (bitmap_words[w] >> (v & 31).astype(jnp.uint32)) & 1
    unvis = valid & (bit == 0)
    eq = (v[:, None] == v[None, :]) & valid[None, :]
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    dup = jnp.any(eq & earlier, axis=1)
    return unvis & ~dup
