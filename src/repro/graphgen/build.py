"""CSR/CSC builders.

The paper (sec. 3.1) stores each local adjacency block in Compressed Sparse
Column form -- two arrays only (col offsets + row indices), since all
non-zeroes equal 1.  We build with a counting sort (degree histogram +
exclusive scan + stable scatter), the same scan-based construction the paper
uses via Thrust.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def degrees(ids: jax.Array, n: int) -> jax.Array:
    """Histogram of vertex ids (degree when fed edge endpoints)."""
    return jnp.zeros((n,), jnp.int32).at[ids].add(1)


def build_csc(edges, n_cols: int, n_rows: int | None = None):
    """CSC of the directed edge set: column u holds the rows v of edges u->v.

    edges: (2, E) int array [src(=col), dst(=row)].
    Returns (col_off[n_cols+1] int32, row_idx[E] int32); rows within a column
    are in input order (stable).
    """
    src, dst = edges[0], edges[1]
    deg = degrees(src, n_cols)
    col_off = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(deg, dtype=jnp.int32)])
    order = jnp.argsort(src, stable=True)
    row_idx = dst[order].astype(jnp.int32)
    return col_off, row_idx


def build_csr(edges, n_rows: int, n_cols: int | None = None):
    """CSR: row v holds the cols u of edges u->v (transpose access order)."""
    return build_csc(edges[::-1], n_rows)


def build_csc_np(edges: np.ndarray, n_cols: int):
    """numpy twin of build_csc for host-side partitioning of big graphs."""
    src = np.asarray(edges[0])
    dst = np.asarray(edges[1])
    deg = np.bincount(src, minlength=n_cols).astype(np.int64)
    col_off = np.zeros(n_cols + 1, np.int64)
    np.cumsum(deg, out=col_off[1:])
    order = np.argsort(src, kind="stable")
    return col_off.astype(np.int32), dst[order].astype(np.int32)
