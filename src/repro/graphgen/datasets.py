"""Reduced-scale analogs of the paper's real-world graphs (Table 3).

The container has no network access, so the four SNAP graphs are replaced by
R-MAT graphs matched to each dataset's (approximate) scale and edge factor as
reported in the paper's Table 3, reduced by `scale_reduction` so they fit/run
on one CPU.  The analog keeps the skew (power-law-ish degree distribution)
that makes these graphs interesting for BFS load balance.
"""
from __future__ import annotations

import jax

from repro.graphgen.rmat import rmat_edges

# name -> (paper_scale, paper_edge_factor)
REALWORLD_SPECS = {
    "com-LiveJournal": (22, 9),
    "soc-LiveJournal1": (22, 14),
    "com-Orkut": (22, 38),
    "com-Friendster": (25, 27),
}


def realworld_analog(name: str, key: jax.Array, scale_reduction: int = 6):
    """Return (edges, n, meta) for a reduced analog of a Table-3 graph."""
    paper_scale, ef = REALWORLD_SPECS[name]
    scale = max(8, paper_scale - scale_reduction)
    edges = rmat_edges(key, scale, ef)
    meta = dict(name=name, paper_scale=paper_scale, scale=scale, edge_factor=ef)
    return edges, 1 << scale, meta
