from repro.graphgen.rmat import rmat_edges, make_undirected, permute_labels
from repro.graphgen.build import build_csc, build_csr, degrees
from repro.graphgen.datasets import realworld_analog, REALWORLD_SPECS
