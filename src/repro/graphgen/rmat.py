"""Graph500-style R-MAT (Kronecker) edge-list generator.

Follows the recursive quadrant construction of Chakrabarti et al. [arXiv
R-MAT, CMU-CS-541] with the Graph500 reference parameters
(a, b, c, d) = (0.57, 0.19, 0.19, 0.05).  As in the paper (sec. 3), graphs are
generated directed and turned undirected by adding, for each edge, its
opposite; vertex labels are randomly permuted to destroy locality (the
Graph500 reference generator does the same).

The generator is pure JAX (jit-able, reproducible from a PRNG key).  Vertex
ids are int32: the paper itself stores local partitions with 32 bits and our
largest in-container graphs are scale <= 24.  (Generation at scale > 31 would
switch to int64, exactly as the paper generates with 64-bit ids and stores
with 32-bit local ids.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

A, B, C, D = 0.57, 0.19, 0.19, 0.05  # Graph500 defaults


@functools.partial(jax.jit, static_argnames=("scale", "n_edges"))
def _rmat_directed(key: jax.Array, scale: int, n_edges: int) -> jax.Array:
    """Return directed edges, shape (2, n_edges) int32."""
    kq, kn = jax.random.split(key)
    # One uniform draw per (edge, bit-level); quadrant per draw.
    u = jax.random.uniform(kq, (scale, n_edges))
    # Graph500 noise: per-level multiplicative jitter on `a` is omitted
    # (reference V2 generator also uses fixed probabilities per level).
    src_bit = (u >= A + B).astype(jnp.int32)  # rows c|d
    # conditional column probability within the chosen row half
    p_right_top = B / (A + B)
    p_right_bot = D / (C + D)
    u2 = jax.random.uniform(kn, (scale, n_edges))
    dst_bit = jnp.where(
        src_bit == 0, (u2 < p_right_top).astype(jnp.int32),
        (u2 < p_right_bot).astype(jnp.int32))
    weights = (1 << jnp.arange(scale - 1, -1, -1, dtype=jnp.int32))[:, None]
    src = jnp.sum(src_bit * weights, axis=0, dtype=jnp.int32)
    dst = jnp.sum(dst_bit * weights, axis=0, dtype=jnp.int32)
    return jnp.stack([src, dst])


def permute_labels(key: jax.Array, edges: jax.Array, n: int) -> jax.Array:
    """Apply a random vertex relabeling (Graph500 'scramble')."""
    perm = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))
    return perm[edges]


def make_undirected(edges: jax.Array) -> jax.Array:
    """Add the opposite of each edge (paper sec. 4)."""
    return jnp.concatenate([edges, edges[::-1]], axis=1)


def rmat_edges(key: jax.Array, scale: int, edge_factor: int = 16,
               permute: bool = True, undirected: bool = True) -> jax.Array:
    """Generate an R-MAT graph edge list.

    Returns (2, E) int32 with E = edge_factor * 2**scale directed input edges,
    doubled to 2*E directed edges if `undirected`.
    """
    n = 1 << scale
    n_edges = edge_factor * n
    k1, k2 = jax.random.split(jax.random.fold_in(key, scale))
    edges = _rmat_directed(k1, scale, n_edges)
    if permute:
        edges = permute_labels(k2, edges, n)
    if undirected:
        edges = make_undirected(edges)
    return edges
