"""BFS levels/preds as a `FrontierProgram` (DESIGN.md sec. 6 + 8).

This is the paper's algorithm -- expand exchange, CSC scan, fold, frontier
update, deferred-predecessor resolution -- expressed as ONE instance of the
generalized driver.  The monoid is first-visit-wins (the visited bitmap is
the suppression cache, the fold payload is the vertex set itself), which is
why plain set codecs suffice on the wire.  `repro.dist.engine.DistBFSEngine`
wraps this program to keep the historical constructor; outputs are
bit-identical to the pre-subsystem engine (same ops, same order).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.algos.program import FrontierProgram, rows_to_global
from repro.core import frontier as F
from repro.core.types import Grid2D, LocalGraph2D, BFSState, BFSOutput
from repro.dist import exchange as X


# ----------------------------------------------------------------------------
# Level-loop building blocks (shared with the direction-optimised step)
# ----------------------------------------------------------------------------

def init_state(root, *, grid: Grid2D, i, j) -> BFSState:
    S = grid.S
    nrl = grid.n_rows_local
    b = root // S
    oi, oj = b % grid.R, b // grid.R
    mine = (oi == i) & (oj == j)
    lr = (root // S // grid.R) * S + root % S
    lc = root % grid.n_cols_local
    level = jnp.full((nrl,), -1, jnp.int32)
    pred = jnp.full((nrl,), -1, jnp.int32)
    visited = jnp.zeros((nrl,), bool)
    front = jnp.full((S,), -1, jnp.int32)
    level = jnp.where(mine, level.at[lr].set(0), level)
    pred = jnp.where(mine, pred.at[lr].set(root), pred)
    visited = jnp.where(mine, visited.at[lr].set(True), visited)
    front = jnp.where(mine, front.at[0].set(lc), front)
    cnt = jnp.where(mine, jnp.int32(1), jnp.int32(0))
    return BFSState(level=level, pred=pred, visited=visited, front=front,
                    front_cnt=cnt, lvl=jnp.int32(1))


def owned_level(level, *, grid: Grid2D, j):
    return jax.lax.dynamic_slice_in_dim(level, j * grid.S, grid.S)


def canonical_front(front, cnt):
    """Sort the padded frontier ascending (pad -1 stays at the back).

    The frontier's order fixes the edge-scan order of the NEXT level, which
    fixes which parent wins each first-visit race -- so keeping it canonical
    makes levels AND predecessors bit-identical across fold codecs (whose
    natural delivery orders differ)."""
    key = jnp.where(front < 0, F.I32_MAX, front)
    s = jnp.sort(key)
    return jnp.where(s == F.I32_MAX, -1, s), cnt


def topdown_step(engine, graph: LocalGraph2D, st: BFSState, *, i, j):
    """One top-down level (paper Alg. 2 lines 12-18).

    Returns (state', total, scanned, aux); aux is the per-level telemetry
    channel (DESIGN.md sec. 13) -- a SET fold, so the wire stamp is the
    exchange strategy's scaling of the codec's static `wire_bytes(grid)`,
    `msgs` the strategy's per-exchange message count and `folded` the
    entries routed to remote owners (the own column never travels).
    """
    topo, grid = engine.topo, engine.grid
    S = grid.S

    with jax.named_scope("repro/expand"):
        # expand exchange: gather frontiers within the processor-column
        all_front, front_total = X.expand_exchange(
            st.front, st.front_cnt, topo=topo, ops=engine.fold_ops)

        # frontier expansion (local CSC column scan)
        ex = F.expand_frontier(
            graph.col_off, graph.row_idx, st.visited, st.level, st.pred,
            all_front, front_total, st.lvl, grid=grid, i=i, j=j,
            edge_chunk=engine.edge_chunk, expand_fn=engine.expand_fn,
            dedup=engine.dedup)

    # own-column vertices go straight to the frontier (lines 15-16)
    own_rows = jnp.take(ex.dst, j, axis=0)      # (S,) local rows, block j
    own_cnt = jnp.take(ex.dst_cnt, j)
    own_cols = (i * S + (own_rows - j * S)).astype(jnp.int32)  # ROW2COL
    own_valid = jnp.arange(S, dtype=jnp.int32) < own_cnt
    dst = ex.dst.at[j].set(-1)
    dst_cnt = ex.dst_cnt.at[j].set(0)

    with jax.named_scope("repro/fold"):
        # fold exchange: route discoveries to their owners (same grid row)
        int_verts, int_cnt = engine.codec.fold(dst, dst_cnt, topo=topo, j=j)

    with jax.named_scope("repro/update"):
        # frontier update (paper sec. 3.5)
        up = F.update_frontier(int_verts, int_cnt, ex.visited, ex.level,
                               ex.pred, st.lvl, grid=grid, i=i, j=j)

        nf = jnp.full((S,), -1, jnp.int32)
        nc = jnp.int32(0)
        nf, nc = F.append_padded(nf, nc, own_cols, own_valid)
        up_valid = jnp.arange(S, dtype=jnp.int32) < up.new_cnt
        nf, nc = F.append_padded(nf, nc, up.new_front, up_valid)
        nf, nc = canonical_front(nf, nc)

    st2 = BFSState(level=up.level, pred=up.pred, visited=up.visited,
                   front=nf, front_cnt=nc, lvl=st.lvl + 1)
    ex_strat = engine.exchange
    aux = {"folded": dst_cnt.sum(dtype=jnp.int32),
           "wire": jnp.uint32(ex_strat.wire_bytes(
               engine.codec.wire_bytes(grid), grid.C)),
           "msgs": jnp.int32(ex_strat.msgs_per_exchange(grid.C)),
           "dir": jnp.int32(0)}
    return st2, topo.psum_all(nc), ex.edges_scanned, aux


# ----------------------------------------------------------------------------
# The program
# ----------------------------------------------------------------------------

class BFSLevelsProgram(FrontierProgram):
    """The paper's BFS (levels + deferred predecessors) on the driver.

    step_factory: optional `(engine, graph, extra, i, j, topdown) -> step`
                  hook replacing the default top-down per-level step (the
                  direction-optimising driver injects its `lax.cond` here).
    n_extra:      extra per-device graph arrays the step consumes (the CSR
                  twin for bottom-up).
    """
    name = "bfs"
    codec_hint = "list"

    def __init__(self, step_factory=None, n_extra: int = 0):
        self.step_factory = step_factory
        self.n_extra = n_extra

    @property
    def key(self) -> tuple:
        return (self.name, self.step_factory, self.n_extra)

    def init(self, engine, graph, extra, root, i, j):
        return init_state(root, grid=engine.grid, i=i, j=j)

    def make_step(self, engine, graph, extra, i, j):
        topdown = functools.partial(topdown_step, engine, graph, i=i, j=j)
        if self.step_factory is None:
            return lambda st, prev_total: topdown(st)
        return self.step_factory(engine, graph, extra, i, j, topdown)

    def make_bottomup_step(self, engine, graph, extra, i, j):
        from repro.algos.direction import make_bfs_bottomup_step
        return make_bfs_bottomup_step(engine, graph, extra, i, j)

    def keep_going(self, engine, st, total):
        return (total > 0) & (st.lvl <= engine.max_levels)

    def init_total(self, engine, st):
        return engine.topo.psum_all(st.front_cnt)

    def finalize(self, engine, st, i, j):
        pred = X.resolve_preds(st.pred, topo=engine.topo, j=j)
        level = owned_level(st.level, grid=engine.grid, j=j)
        return level, pred, st.lvl

    def out_specs(self, engine):
        out_g = engine.topo.out_block_spec
        return (out_g, out_g, engine.topo.dev_spec)

    def level_count(self, st):
        return st.lvl

    def export_state(self, engine, st, n: int) -> dict:
        """(R, C, ...) BFSState -> global canonical snapshot.

        `level` and `pred` export from the owned blocks; deferred
        predecessor markers -(c+2) resolve at export time by reading the
        sender column's pred row (the same fetch `resolve_preds` performs
        with an all_to_all at finalize), so the snapshot is marker-free and
        grid-independent.  The frontier is DERIVED state -- exactly the
        vertices with level == lvl-1 -- and the visited bitmap is derivable
        as level >= 0, so neither is stored.
        """
        grid = engine.grid
        R, C, S = grid.R, grid.C, grid.S
        gl = np.full((grid.n,), -1, np.int32)
        gp = np.full((grid.n,), -1, np.int32)
        for i in range(R):
            for j in range(C):
                g0 = (j * R + i) * S
                sl = slice(j * S, (j + 1) * S)
                gl[g0:g0 + S] = st.level[i, j, sl]
                pr = np.asarray(st.pred[i, j, sl]).copy()
                dm = pr < -1
                if dm.any():
                    snd = -pr[dm] - 2                 # the sender column
                    t = np.flatnonzero(dm)
                    pr[dm] = st.pred[i, snd, j * S + t]
                gp[g0:g0 + S] = pr
        lvl = int(st.lvl[0, 0])
        return {"level": gl[:n], "pred": gp[:n],
                "lvl": np.asarray(lvl, np.int64),
                "levels_done": np.asarray(lvl - 1, np.int64)}

    def import_state(self, engine, snap: dict) -> BFSState:
        """Global snapshot -> (R, C, ...) BFSState on engine's grid.

        Every local row rebuilds `level` and `visited = level >= 0` from the
        global truth: for still-unvisited vertices no device suppresses, and
        for claimed vertices extra suppression only drops proposals the
        owner's `eligible &= ~visited` would discard anyway -- so a resumed
        trajectory (same grid) is bit-identical, predecessors included.
        `pred` is authoritative at the owned block only (resolve_preds is
        idempotent on resolved entries); the frontier re-derives from
        level == lvl-1, ascending -- the canonical-sort order the organic
        frontier carries.
        """
        grid = engine.grid
        R, C, S, nrl = grid.R, grid.C, grid.S, grid.n_rows_local
        n_raw = int(snap["level"].shape[0])
        gl = np.full((grid.n,), -1, np.int32)
        gl[:n_raw] = snap["level"]
        gp = np.full((grid.n,), -1, np.int32)
        gp[:n_raw] = snap["pred"]
        lvl = int(snap["lvl"])
        level = np.empty((R, C, nrl), np.int32)
        visited = np.empty((R, C, nrl), bool)
        pred = np.full((R, C, nrl), -1, np.int32)
        front = np.full((R, C, S), -1, np.int32)
        cnt = np.zeros((R, C), np.int32)
        for i in range(R):
            li = gl[rows_to_global(grid, i)]
            for j in range(C):
                level[i, j] = li
                visited[i, j] = li >= 0
                g0 = (j * R + i) * S
                pred[i, j, j * S:(j + 1) * S] = gp[g0:g0 + S]
                t = np.flatnonzero(gl[g0:g0 + S] == lvl - 1).astype(np.int32)
                front[i, j, :t.size] = i * S + t
                cnt[i, j] = t.size
        return BFSState(level=level, pred=pred, visited=visited, front=front,
                        front_cnt=cnt, lvl=np.full((R, C), lvl, np.int32))

    def assemble(self, engine, outs, B) -> BFSOutput:
        """Gathered device outputs -> global BFSOutput.

        Scalar (B=None): (n,) level/pred in vertex-block order (b = j*R + i,
        i.e. plain global vertex ids) + the exact 64-bit scanned-edge count.
        Batched: (B, n) level/pred, (B,) n_levels, tuple of B counts.
        """
        from repro.algos.engine import wide_total

        level, pred, lvls, hi, lo = outs
        if B is None:
            return BFSOutput(level=level.reshape(-1), pred=pred.reshape(-1),
                             n_levels=lvls.max(),
                             edges_scanned=wide_total(hi, lo))
        Pn, S = engine.grid.P, engine.grid.S
        level = jnp.swapaxes(level.reshape(Pn, B, S), 0, 1).reshape(B, -1)
        pred = jnp.swapaxes(pred.reshape(Pn, B, S), 0, 1).reshape(B, -1)
        n_levels = lvls.reshape(-1, B).max(axis=0)
        hi_s = np.asarray(hi).astype(np.int64).reshape(-1, B).sum(axis=0)
        lo_s = np.asarray(lo).astype(np.int64).reshape(-1, B).sum(axis=0)
        scanned = tuple((int(h) << 32) + int(l) for h, l in zip(hi_s, lo_s))
        return BFSOutput(level=level, pred=pred, n_levels=n_levels,
                         edges_scanned=scanned)
