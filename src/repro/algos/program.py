"""The `FrontierProgram` contract + shared value-propagation blocks
(DESIGN.md sec. 8).

A frontier program is a distributed graph algorithm expressed against the
2D-partitioned engine: per-vertex state that evolves under a commutative,
idempotent combine (a monoid -- min over labels for connected components,
min over distances for SSSP, first-wave-wins source ids for multi-source
BFS), a per-level `step` that expands the current frontier and folds an
outgoing payload to the owners, and a convergence predicate.  The engine
(`repro.algos.engine.FrontierEngine`) supplies the loop, the collectives and
the accounting; the fold wire format is the codec layer of
`repro.dist.exchange` (`codec_hint` picks a default, callers may override).

The helpers below implement the common "value propagation" level shape used
by CC / SSSP / multi-source BFS:

  gather frontier + payload  ->  chunked CSC scan min-combining relaxed
  payloads into a dense per-local-row candidate array  ->  pack improved
  rows into canonical per-owner buckets  ->  value-carrying fold
  (`FoldCodec.fold_values`)  ->  scatter-min merge into owned state  ->
  rebuild the frontier from changed owned rows.

Everything is min-combined, so results are independent of delivery order --
the reason every fold codec produces bit-identical outputs by construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import frontier as F
from repro.core.types import Grid2D, _dc

I32_MAX = F.I32_MAX


# ----------------------------------------------------------------------------
# The contract
# ----------------------------------------------------------------------------

class FrontierProgram:
    """What a distributed frontier algorithm implements.

    Attributes
    ----------
    name:        short program id; part of every engine/AOT cache key.
    codec_hint:  fold wire format used when the caller does not pin one.
    n_extra:     number of extra per-device (R, C, ...) graph arrays the
                 program consumes (e.g. per-edge weights).
    n_csr_extra: how many MORE extras the bottom-up twin of the step needs
                 appended after the regular ones -- (row_off, col_idx) of
                 the CSR twin for everyone, plus the CSR-ordered weights
                 for SSSP.  Only consumed via `DirectionProgram`.
    uses_bottomup: True when `make_step` may call into the bottom-up kernel
                 hooks (`engine.bottomup_fn` / `engine.value_bottomup_fn`);
                 the engine only constructs those hooks when set.

    The engine calls, in order: `init` (per search), `make_step` (once per
    trace), the loop (`keep_going` / the step), then `finalize`; host-side
    `assemble` turns gathered device outputs into the program's output
    object.  All methods receive the engine for access to the topology,
    grid, codec and knobs.
    """
    name = "?"
    codec_hint = "list"
    n_extra = 0
    n_csr_extra = 2
    uses_bottomup = False

    @property
    def key(self) -> tuple:
        """Hashable identity: programs with equal keys may share an engine
        (together with codec/chunking, see BFSConfig.algo_engine_key)."""
        return (self.name,)

    def init(self, engine, graph, extra, arg, i, j):
        """Per-device initial state pytree for one search argument."""
        raise NotImplementedError

    def make_step(self, engine, graph, extra, i, j):
        """Return step(state, prev_total) -> (state', total, scanned[, aux]).

        The optional 4th element is the per-level telemetry channel
        (DESIGN.md sec. 13): a dict with scalar entries `folded` (entries
        this device folded to owners), `wire` (fold wire bytes sent) and
        `dir` (0 top-down / 1 bottom-up).  Untraced engines drop it before
        the loop carry, so returning it costs nothing when telemetry is
        off; legacy 3-tuple steps remain valid (the trace records zeros).
        """
        raise NotImplementedError

    def make_bottomup_step(self, engine, graph, extra, i, j):
        """Bottom-up twin of `make_step` (same signature/return), consuming
        the `n_csr_extra` CSR arrays at the END of `extra`.  Must be
        bit-identical to the top-down step in its state trajectory, so the
        direction driver may mix directions level by level."""
        raise NotImplementedError(
            f"{self.name} has no bottom-up step; it cannot run under "
            f"direction optimisation")

    def front_count(self, st):
        """This device's own frontier count entering a level (the telemetry
        carry's `front_dev` channel).  Every state pytree in the repo
        carries `front_cnt`; wrappers delegate to their inner program."""
        return st.front_cnt

    def keep_going(self, engine, st, total):
        """Convergence predicate (True = run another level)."""
        raise NotImplementedError

    def init_total(self, engine, st):
        """Global size of the initial frontier (the loop's entry total)."""
        raise NotImplementedError

    def finalize(self, engine, st, i, j) -> tuple:
        """Per-device output arrays (engine appends the (hi, lo) counters)."""
        raise NotImplementedError

    def out_specs(self, engine) -> tuple:
        """PartitionSpecs matching `finalize`'s outputs."""
        raise NotImplementedError

    def assemble(self, engine, outs, B):
        """Host-side: gathered device outputs -> output object (B=None for a
        scalar search, else the leading batch size)."""
        raise NotImplementedError

    # -- mid-traversal checkpointing (DESIGN.md sec. 15) ---------------------

    def level_count(self, st):
        """The state's 1-based level/iteration counter (device array; the
        segmented driver's progress readout).  Works on host-fetched
        (R, C[, B]) state pytrees too -- it is plain attribute access."""
        raise NotImplementedError

    def export_state(self, engine, st, n: int) -> dict:
        """Host-fetched scalar-search state (leaves (R, C, ...) numpy) ->
        flat dict of numpy arrays in GLOBAL vertex-id order, sliced to the
        raw `n` -- the grid-independent half of the checkpoint schema.
        Must include a 0-d `levels_done` entry."""
        raise NotImplementedError(
            f"{self.name} does not support mid-traversal checkpointing")

    def import_state(self, engine, snap: dict):
        """Inverse of `export_state` onto ENGINE's grid (which need not be
        the grid that exported `snap`): a state pytree with (R, C, ...)
        numpy leaves, re-padded to the new grid and with per-device caches
        rebuilt from the authoritative global state."""
        raise NotImplementedError(
            f"{self.name} does not support mid-traversal checkpointing")


# ----------------------------------------------------------------------------
# Shared state pytree for min-monoid value programs (CC, SSSP)
# ----------------------------------------------------------------------------

@_dc
@dataclasses.dataclass
class ValueState:
    """Per-device state of a min-monoid value-propagation program.

    `val` spans ALL local rows (n/R), generalizing the BFS visited bitmap:
    the owned block is the authoritative value, remote rows are this
    device's send-suppression cache (the smallest value it has ever
    proposed/seen for that vertex -- proposing anything >= it is provably
    redundant, the exact role `visited` plays for BFS).
    """
    val: jax.Array        # (n_rows_local,) int32, I32_MAX = top
    front: jax.Array      # (S,) local col ids, canonical ascending, pad -1
    payload: jax.Array    # (S,) int32 values aligned with front
    front_cnt: jax.Array  # () int32
    it: jax.Array         # () int32, 1-based iteration counter


# ----------------------------------------------------------------------------
# Level building blocks
# ----------------------------------------------------------------------------

def scan_relax(col_off, row_idx, edge_vals, all_front, all_payload,
               front_total, relax, *, n_rows: int, grid: Grid2D,
               edge_chunk: int = 8192, expand_fn=None):
    """Chunked CSC scan of the gathered frontier, min-combining relaxed
    payloads into a dense per-local-row candidate array.

    For each edge u -> v of a frontier column u, proposes
    `relax(payload[u], edge_vals[edge])` for v; proposals for the same v
    combine by MIN (the monoid), so the result is independent of scan order.
    Same chunked searchsorted edge walk as `frontier.expand_frontier`
    (paper Alg. 3), same O(frontier edges + chunk) cost per level.

    expand_fn: optional value-carrying kernel override for one chunk (the
    fused Pallas path, `repro.kernels.expand.make_value_expand_fn`):
        (gids, cumul, all_front, all_payload, front_total, col_off, row_idx)
            -> (v, payload, addr, valid)
    Bit-identical to the inline scan: the kernel maps/gathers, the relax
    monoid and the scatter-min combine stay here.

    Returns (cand (n_rows,) int32, edges_scanned uint32).
    """
    ncl = grid.n_cols_local

    u_safe = jnp.clip(all_front, 0, ncl - 1)
    deg = (col_off[u_safe + 1] - col_off[u_safe])
    deg = jnp.where(jnp.arange(ncl) < front_total, deg, 0)
    cumul = F.exclusive_cumsum(deg)                    # (ncl + 1,)
    total = cumul[front_total]

    def chunk_body(state):
        start, cand = state
        gids = start + jnp.arange(edge_chunk, dtype=jnp.int32)
        if expand_fn is None:
            v, _, k, addr, valid = F.reference_expand_chunk(
                gids, cumul, all_front, front_total, col_off, row_idx)
            pay = all_payload[k]
        else:
            v, pay, addr, valid = expand_fn(gids, cumul, all_front,
                                            all_payload, front_total,
                                            col_off, row_idx)
        w = None if edge_vals is None else edge_vals[addr]
        val = jnp.where(valid, relax(pay, w), I32_MAX)
        cand = cand.at[jnp.where(valid, v, n_rows)].min(val, mode="drop")
        return start + edge_chunk, cand

    init = (jnp.int32(0), jnp.full((n_rows,), I32_MAX, jnp.int32))
    _, cand = jax.lax.while_loop(lambda s: s[0] < total, chunk_body, init)
    return cand, total.astype(jnp.uint32)


def pack_blocks(improved, vals, grid: Grid2D, fill_val=I32_MAX, ops=None):
    """Dense (n_rows_local,) improvements -> canonical fold buckets.

    Local row m*S + t of block m maps to bucket row m, so the dense array IS
    the bucket structure after a reshape; per bucket, improved entries are
    front-packed ascending (the canonical form `FoldCodec.fold_values`
    requires).  Returns (ids (C, S) local-row ids pad -1, cnt (C,),
    vals (C, S) aligned, pad `fill_val`).

    ops: optional fold-kernel bundle (`repro.kernels.fold`) whose prefix-sum
    compaction replaces the per-level argsort; bit-identical either way.
    """
    C, S = grid.C, grid.S
    imp = improved.reshape(C, S)
    vv = vals.reshape(C, S)
    t = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (C, S))
    m = jnp.arange(C, dtype=jnp.int32)[:, None]
    if ops is not None:
        # pads are -1 (not I32_MAX as on the reference path), so m*S + ts
        # cannot overflow and a single mask suffices
        (ts, vs), cnt = ops.compact_rows(imp, (t, vv), (-1, fill_val))
        ids = jnp.where(ts >= 0, m * S + ts, -1)
        return ids, cnt, vs
    key = jnp.where(imp, t, I32_MAX)
    order = jnp.argsort(key, axis=1)
    ts = jnp.take_along_axis(key, order, axis=1)
    vs = jnp.take_along_axis(vv, order, axis=1)
    ok = ts < I32_MAX
    ids = jnp.where(ok, m * S + jnp.where(ok, ts, 0), -1)
    vs = jnp.where(ok, vs, fill_val)
    return ids, imp.sum(axis=1, dtype=jnp.int32), vs


def scatter_min_received(recv_ids, recv_vals, j, S: int):
    """Fold-received (C, S) owned rows j*S + t + aligned values -> (S,)
    per-owned-row MIN over all senders (I32_MAX where nothing arrived)."""
    t = jnp.where(recv_ids >= 0, recv_ids - j * S, S)
    inc = jnp.full((S,), I32_MAX, jnp.int32)
    return inc.at[t.reshape(-1)].min(
        jnp.where(recv_ids >= 0, recv_vals, I32_MAX).reshape(-1), mode="drop")


def make_value_step(engine, graph, i, j, *, relax, edge_vals=None,
                    expand_fill=I32_MAX, scan=None):
    """The complete min-monoid level step shared by CC and SSSP.

    gather frontier+payload -> scan_relax -> suppress (strict improvements
    over the local cache only) -> pack_blocks -> codec fold_values ->
    scatter-min merge into the owned block -> rebuild the frontier from
    changed owned rows.  `relax(payload_u, w)` is the per-edge proposal
    (identity for label propagation, min-plus for SSSP); `edge_vals` is the
    per-device per-edge array `relax` consumes (or None); `expand_fill`
    pads the gathered payload channel (never read under the valid mask).

    scan: optional replacement for the gather + scan_relax prefix,
    `state -> (cand (n_rows_local,), edges_scanned uint32)` -- the bottom-up
    pull scan (`repro.algos.direction.make_pull_scan`) injects here; it must
    produce bit-identical candidates, so everything downstream is shared.
    """
    from repro.dist import exchange as X

    grid, topo = engine.grid, engine.topo
    S, nrl = grid.S, grid.n_rows_local
    fold_ops = engine.fold_ops

    # telemetry channel constants: pull scans are the bottom-up direction,
    # and a value fold's wire bytes are count-proportional (on the flat
    # route, PR 5's wire_bytes_values_sent = static header + 4 bytes per
    # folded entry; the exchange strategy scales header and hop count)
    step_dir = jnp.int32(1 if scan is not None else 0)
    ex_strat = engine.exchange
    wire_base = jnp.uint32(ex_strat.wire_bytes(
        engine.codec.wire_bytes(grid), grid.C))
    step_msgs = jnp.int32(ex_strat.msgs_per_exchange(grid.C))

    def step(st: ValueState, prev_total):
        with jax.named_scope("repro/expand"):
            if scan is not None:
                cand, scanned = scan(st)
            else:
                all_front, all_pay, ftot = X.expand_exchange_values(
                    st.front, st.front_cnt, st.payload, topo=topo,
                    fill=expand_fill, ops=fold_ops)
                cand, scanned = scan_relax(
                    graph.col_off, graph.row_idx, edge_vals, all_front,
                    all_pay, ftot, relax, n_rows=nrl, grid=grid,
                    edge_chunk=engine.edge_chunk,
                    expand_fn=engine.value_expand_fn)
        # propose only strict improvements over what we already know
        improved = cand < st.val
        val1 = jnp.minimum(st.val, cand)
        with jax.named_scope("repro/fold"):
            ids, cnt, vals = pack_blocks(improved, cand, grid, ops=fold_ops)
            ri, rc, rv = engine.codec.fold_values(ids, cnt, vals, topo=topo,
                                                  j=j)
        with jax.named_scope("repro/update"):
            inc = scatter_min_received(ri, rv, j, S)
            # merge against the PRE-scan owned block: this device's own
            # proposals travel through the self all_to_all block, so
            # comparing with val1 would mask them out of `changed`
            owned_prev = jax.lax.dynamic_slice_in_dim(st.val, j * S, S)
            new_owned = jnp.minimum(owned_prev, inc)
            changed = new_owned < owned_prev
            val2 = jax.lax.dynamic_update_slice(val1, new_owned, (j * S,))
            front, payload, nc = owned_to_front(changed, new_owned, i, S,
                                                ops=fold_ops)
        st2 = ValueState(val=val2, front=front, payload=payload,
                         front_cnt=nc, it=st.it + 1)
        folded = cnt.sum(dtype=jnp.int32)
        aux = {"folded": folded,
               "wire": wire_base + ex_strat.value_extra_bytes(cnt, j, grid.C),
               "msgs": step_msgs,
               "dir": step_dir}
        return st2, topo.psum_all(nc), scanned, aux

    return step


def owned_to_front(changed, vals, i, S: int, fill_val=I32_MAX, ops=None):
    """Changed owned rows -> next frontier, canonical ascending.

    Owned local row j*S + t converts to local col i*S + t (paper ROW2COL).
    Returns (front (S,) col ids pad -1, payload (S,) aligned, cnt).

    ops: optional fold-kernel bundle replacing the argsort (bit-identical).
    """
    t = jnp.arange(S, dtype=jnp.int32)
    if ops is not None:
        (ts, vs), cnt = ops.compact_rows(changed[None, :],
                                         (t[None, :], vals[None, :]),
                                         (-1, fill_val))
        ts, vs = ts[0], vs[0]
        front = jnp.where(ts >= 0, i * S + ts, -1)      # pads are -1
        return front, vs, cnt[0]
    key = jnp.where(changed, t, I32_MAX)
    order = jnp.argsort(key)
    ts = key[order]
    vs = vals[order]
    ok = ts < I32_MAX
    front = jnp.where(ok, i * S + jnp.where(ok, ts, 0), -1)
    payload = jnp.where(ok, vs, fill_val)
    return front, payload, changed.sum(dtype=jnp.int32)


# ----------------------------------------------------------------------------
# Checkpoint-schema helpers (DESIGN.md sec. 15)
#
# Export walks the (R, C, ...) host leaves into GLOBAL vertex-id order;
# import rebuilds a new grid's per-device layout from the global arrays.
# Both live on the partition identities of DESIGN.md sec. 2: device (i, j)'s
# owned block b = j*R + i covers global ids [(j*R + i)*S, (j*R + i + 1)*S),
# its local rows run over blocks m*R + i for m in 0..C-1, and owned local
# row j*S + t converts to local col i*S + t (ROW2COL).
# ----------------------------------------------------------------------------

def rows_to_global(grid: Grid2D, i: int) -> np.ndarray:
    """Global vertex ids of device-row i's local rows, in local-row order
    (identical for every device in grid row i -- the j-independence that
    lets import fill ALL local rows from one gather)."""
    R, C, S = grid.R, grid.C, grid.S
    return ((np.arange(C)[:, None] * R + i) * S
            + np.arange(S)[None, :]).reshape(-1)


def export_value_state(grid: Grid2D, st: ValueState, n: int) -> dict:
    """Host (R, C, ...) ValueState -> global snapshot.

    `val` exports the RAW owned blocks (I32_MAX = top; programs whose
    finalize remaps sentinels do so only at output time), `in_front` is the
    explicit frontier mask (value frontiers are not derivable from `val`
    alone), and the frontier payloads are NOT stored -- they equal the owned
    value at the frontier rows, which import re-reads.
    """
    R, C, S = grid.R, grid.C, grid.S
    val = np.full((grid.n,), I32_MAX, np.int32)
    in_front = np.zeros((grid.n,), bool)
    for i in range(R):
        for j in range(C):
            g0 = (j * R + i) * S
            val[g0:g0 + S] = st.val[i, j, j * S:(j + 1) * S]
            cnt = int(st.front_cnt[i, j])
            t = np.asarray(st.front[i, j, :cnt], np.int64) - i * S
            in_front[g0 + t] = True
    it = int(st.it[0, 0])
    return {"val": val[:n], "in_front": in_front[:n],
            "it": np.asarray(it, np.int64),
            "levels_done": np.asarray(it - 1, np.int64)}


def import_value_state(grid: Grid2D, snap: dict, pad: str = "max"
                       ) -> ValueState:
    """Global snapshot -> (R, C, ...) ValueState on `grid`.

    Every local row takes the authoritative global value: the owned block
    exactly, and remote rows get a send-suppression cache that is a SUPERSET
    of any organically-grown one -- suppressed proposals would have carried
    cand >= the owner's current value, invisible to the min-merge and the
    strict `changed` mask, so resumed trajectories stay bit-identical.

    pad: value for the new grid's padding vertices (>= the raw n): "max"
    (I32_MAX -- never-visited sentinel, SSSP/multi-BFS) or "gid" (own global
    id -- CC's converged self-label, what an uninterrupted run holds there
    after level 1).
    """
    R, C, S, nrl = grid.R, grid.C, grid.S, grid.n_rows_local
    n_raw = int(snap["val"].shape[0])
    gv = np.empty((grid.n,), np.int32)
    gv[:n_raw] = snap["val"]
    if pad == "gid":
        gv[n_raw:] = np.arange(n_raw, grid.n, dtype=np.int32)
    else:
        gv[n_raw:] = I32_MAX
    inf = np.zeros((grid.n,), bool)
    inf[:n_raw] = snap["in_front"]
    val = np.empty((R, C, nrl), np.int32)
    front = np.full((R, C, S), -1, np.int32)
    payload = np.full((R, C, S), I32_MAX, np.int32)
    cnt = np.zeros((R, C), np.int32)
    for i in range(R):
        vi = gv[rows_to_global(grid, i)]
        for j in range(C):
            val[i, j] = vi
            g0 = (j * R + i) * S
            t = np.flatnonzero(inf[g0:g0 + S]).astype(np.int32)
            front[i, j, :t.size] = i * S + t
            payload[i, j, :t.size] = gv[g0 + t]
            cnt[i, j] = t.size
    it = np.full((R, C), int(snap["it"]), np.int32)
    return ValueState(val=val, front=front, payload=payload, front_cnt=cnt,
                      it=it)
