"""Single-source shortest paths on per-edge uint8 weights (DESIGN.md sec. 8).

Frontier-driven Bellman-Ford relaxation -- the semiring swap Buluc & Madduri
describe (min-plus in place of BFS's boolean or-and): the frontier payload is
the vertex's current tentative distance; scanning edge u -> v proposes
`dist(u) + w(u, v)`; the owner keeps the minimum and re-activates a vertex
whenever its distance improves.  Non-negative weights guarantee convergence
in at most (longest shortest-path hop count) levels, so the engine's
`max_levels` must cover n for worst-case chains.

Weights live with the partition: `partition_edge_vals` lays the per-edge
uint8 array out in exactly the CSC order of `partition_2d`, and
`DistGraph.from_edges(..., weights=...)` makes it resident alongside the
graph.  The monoid is (min, +inf) over int32 distances; fold is
`FoldCodec.fold_values`, so all three wire codecs are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.algos import program as PR
from repro.algos.program import FrontierProgram, ValueState, I32_MAX
from repro.core.types import _dc


@_dc
@dataclasses.dataclass
class SSSPOutput:
    """Global shortest-path result (scalar root or (B,) batched roots)."""
    dist: jax.Array        # (n,) / (B, n) int32 distances, -1 = unreachable
    n_iters: jax.Array     # relaxation levels run
    edges_scanned: Any = None  # exact Python int(s), 64-bit safe
    directions: Any = None     # per-level direction trace when direction
                               # optimisation ran (see BFSOutput), else None
    trace: Any = None          # LevelTrace when telemetry ran (scalar: one
                               # LevelTrace; batched: tuple of B), else None


class SSSPProgram(FrontierProgram):
    """Bellman-Ford relaxation as a frontier program (arg = root)."""
    name = "sssp"
    codec_hint = "list"
    n_extra = 1            # the per-device (R, C, e_max) uint8 weight array
    n_csr_extra = 3        # CSR row_off + col_idx + the CSR-ordered weights

    def init(self, engine, graph, extra, root, i, j):
        grid = engine.grid
        S, nrl = grid.S, grid.n_rows_local
        b = root // S
        oi, oj = b % grid.R, b // grid.R
        mine = (oi == i) & (oj == j)
        lr = (root // S // grid.R) * S + root % S
        lc = root % grid.n_cols_local
        val = jnp.full((nrl,), I32_MAX, jnp.int32)
        val = jnp.where(mine, val.at[lr].set(0), val)
        front = jnp.full((S,), -1, jnp.int32)
        front = jnp.where(mine, front.at[0].set(lc), front)
        return ValueState(val=val, front=front,
                          payload=jnp.zeros((S,), jnp.int32),
                          front_cnt=jnp.where(mine, jnp.int32(1),
                                              jnp.int32(0)),
                          it=jnp.int32(1))

    def make_step(self, engine, graph, extra, i, j):
        # min-plus relaxation over the resident per-edge weights
        return PR.make_value_step(
            engine, graph, i, j, relax=lambda p, w: p + w.astype(jnp.int32),
            edge_vals=extra[0], expand_fill=0)

    def make_bottomup_step(self, engine, graph, extra, i, j):
        # the pull twin relaxes over the CSR-ordered weight copy (same edge
        # multiset as the CSC scan, min combine -> bit-identical candidates)
        from repro.algos.direction import make_pull_scan
        relax = lambda p, w: p + w.astype(jnp.int32)  # noqa: E731
        scan = make_pull_scan(engine, extra[-3], extra[-2], i, j,
                              relax=relax, csr_edge_vals=extra[-1])
        return PR.make_value_step(engine, graph, i, j, relax=relax,
                                  edge_vals=extra[0], expand_fill=0,
                                  scan=scan)

    def keep_going(self, engine, st, total):
        return (total > 0) & (st.it <= engine.max_levels)

    def init_total(self, engine, st):
        return engine.topo.psum_all(st.front_cnt)

    def finalize(self, engine, st, i, j):
        d = jax.lax.dynamic_slice_in_dim(st.val, j * engine.grid.S,
                                         engine.grid.S)
        return jnp.where(d == I32_MAX, -1, d), st.it

    def level_count(self, st):
        return st.it

    def export_state(self, engine, st, n: int) -> dict:
        # RAW distances (I32_MAX = unreached); finalize's -1 remap happens
        # only at output time, never in the carry
        return PR.export_value_state(engine.grid, st, n)

    def import_state(self, engine, snap: dict) -> ValueState:
        return PR.import_value_state(engine.grid, snap, pad="max")

    def out_specs(self, engine):
        return (engine.topo.out_block_spec, engine.topo.dev_spec)

    def assemble(self, engine, outs, B) -> SSSPOutput:
        from repro.algos.engine import wide_total

        dist, iters, hi, lo = outs
        if B is None:
            return SSSPOutput(dist=dist.reshape(-1), n_iters=iters.max(),
                              edges_scanned=wide_total(hi, lo))
        Pn, S = engine.grid.P, engine.grid.S
        dist = jnp.swapaxes(dist.reshape(Pn, B, S), 0, 1).reshape(B, -1)
        hi_s = np.asarray(hi).astype(np.int64).reshape(-1, B).sum(axis=0)
        lo_s = np.asarray(lo).astype(np.int64).reshape(-1, B).sum(axis=0)
        scanned = tuple((int(h) << 32) + int(l) for h, l in zip(hi_s, lo_s))
        return SSSPOutput(dist=dist, n_iters=iters.reshape(-1, B).max(axis=0),
                          edges_scanned=scanned)
