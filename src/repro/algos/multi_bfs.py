"""Batched multi-source BFS / reachability (DESIGN.md sec. 8).

ONE wave sweeps out from K sources simultaneously (Pan et al.'s frontier
loop with a source-id payload): every vertex records the level at which the
combined wave first reached it and the id (index into `sources`) of the
claiming source, with ties inside a wave broken by the minimum source id.
This is the k-hop-neighborhood primitive of the `models/gnn` stack -- run
with `max_levels=k` and `level >= 0` marks the union k-hop neighborhood of
the source set, `src` its nearest-source assignment.

Unlike `GraphSession.bfs(roots)` (K independent searches under `lax.map`),
the K sources here share a single frontier, so the whole sweep costs one
traversal of the reachable region.

The monoid is first-wave-wins with min-source-id inside a wave; like BFS,
a per-device visited bitmap over ALL local rows suppresses re-folds, and
the fold carries (vertex, source id) pairs via `FoldCodec.fold_values` --
bit-identical across wire codecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.algos import program as PR
from repro.algos.program import FrontierProgram, I32_MAX
from repro.core.types import _dc
from repro.dist import exchange as X


@_dc
@dataclasses.dataclass
class MultiBFSState:
    """Per-device multi-source BFS state.

    `visited` spans ALL local rows (the BFS suppression bitmap: each remote
    vertex is folded at most once per sweep); `level`/`src` are
    authoritative for the owned block only.
    """
    visited: jax.Array    # (n_rows_local,) bool
    level: jax.Array      # (n_rows_local,) int32, -1 = unreached
    src: jax.Array        # (n_rows_local,) int32 claiming source id
    front: jax.Array      # (S,) local col ids, canonical ascending
    payload: jax.Array    # (S,) source ids aligned with front
    front_cnt: jax.Array  # () int32
    lvl: jax.Array        # () int32 current wave


@_dc
@dataclasses.dataclass
class MultiBFSOutput:
    """Global multi-source BFS result."""
    level: jax.Array       # (n,) int32 hops to the nearest source, -1 = none
    src: jax.Array         # (n,) int32 claiming source id (index into
                           #   sources), -1 = unreached
    n_levels: jax.Array    # waves run
    edges_scanned: Any = None  # exact Python int (64-bit safe)
    directions: Any = None     # per-level direction trace when direction
                               # optimisation ran (see BFSOutput), else None
    trace: Any = None          # LevelTrace when telemetry ran, else None


class MultiSourceBFSProgram(FrontierProgram):
    """Simultaneous BFS from a (K,) sources vector (arg = sources)."""
    name = "multi_bfs"
    codec_hint = "list"

    def init(self, engine, graph, extra, sources, i, j):
        grid = engine.grid
        S, nrl, R = grid.S, grid.n_rows_local, grid.R
        K = sources.shape[0]
        b = sources // S
        mine = (b % R == i) & (b // R == j) & (sources >= 0)
        lr = (sources // S // R) * S + sources % S
        idx = jnp.arange(K, dtype=jnp.int32)
        # min source id per claimed row (duplicate sources: first index wins)
        src = jnp.full((nrl,), I32_MAX, jnp.int32).at[
            jnp.where(mine, lr, nrl)].min(jnp.where(mine, idx, I32_MAX),
                                          mode="drop")
        claimed = src < I32_MAX
        level = jnp.where(claimed, 0, -1).astype(jnp.int32)
        owned_src = jax.lax.dynamic_slice_in_dim(src, j * S, S)
        front, payload, cnt = PR.owned_to_front(owned_src < I32_MAX,
                                                owned_src, i, S,
                                                ops=engine.fold_ops)
        return MultiBFSState(visited=claimed, level=level, src=src,
                             front=front, payload=payload, front_cnt=cnt,
                             lvl=jnp.int32(1))

    def make_step(self, engine, graph, extra, i, j):
        return self._make_step(engine, graph, i, j)

    def make_bottomup_step(self, engine, graph, extra, i, j):
        # the pull twin additionally masks visited rows out of the workload:
        # their candidates are discarded by the visited discipline below
        # anyway, so skipping their in-edges changes nothing but the work
        from repro.algos.direction import make_pull_scan
        scan = make_pull_scan(engine, extra[-2], extra[-1], i, j,
                              relax=lambda p, w: p,
                              row_mask_fn=lambda st: ~st.visited)
        return self._make_step(engine, graph, i, j, scan=scan)

    def _make_step(self, engine, graph, i, j, scan=None):
        grid, topo = engine.grid, engine.topo
        S, nrl = grid.S, grid.n_rows_local
        fold_ops = engine.fold_ops
        step_dir = jnp.int32(1 if scan is not None else 0)
        wire_base = jnp.uint32(engine.codec.wire_bytes(grid))

        def step(st: MultiBFSState, prev_total):
            if scan is not None:
                cand, scanned = scan(st)
            else:
                all_front, all_pay, ftot = X.expand_exchange_values(
                    st.front, st.front_cnt, st.payload, topo=topo,
                    fill=I32_MAX, ops=fold_ops)
                cand, scanned = PR.scan_relax(
                    graph.col_off, graph.row_idx, None, all_front, all_pay,
                    ftot, lambda p, w: p, n_rows=nrl, grid=grid,
                    edge_chunk=engine.edge_chunk,
                    expand_fn=engine.value_expand_fn)
            # first fold per vertex per device (the BFS visited discipline)
            improved = (cand < I32_MAX) & ~st.visited
            vis1 = st.visited | improved
            ids, cnt, vals = PR.pack_blocks(improved, cand, grid,
                                            ops=fold_ops)
            ri, rc, rv = engine.codec.fold_values(ids, cnt, vals,
                                                  topo=topo, j=j)
            inc = PR.scatter_min_received(ri, rv, j, S)
            # claims merge against the PRE-scan owned state: this device's
            # own discoveries travel through the self all_to_all block, so
            # judging them here would shadow a smaller source id arriving
            # from a peer in the same wave
            vis_owned_prev = jax.lax.dynamic_slice_in_dim(st.visited,
                                                          j * S, S)
            changed = (inc < I32_MAX) & ~vis_owned_prev
            src_prev = jax.lax.dynamic_slice_in_dim(st.src, j * S, S)
            lvl_prev = jax.lax.dynamic_slice_in_dim(st.level, j * S, S)
            new_src = jnp.where(changed, inc, src_prev)
            new_lvl = jnp.where(changed, st.lvl, lvl_prev)
            src2 = jax.lax.dynamic_update_slice(st.src, new_src, (j * S,))
            lvl2 = jax.lax.dynamic_update_slice(st.level, new_lvl, (j * S,))
            vis_owned = jax.lax.dynamic_slice_in_dim(vis1, j * S, S)
            vis2 = jax.lax.dynamic_update_slice(vis1, vis_owned | changed,
                                                (j * S,))
            front, payload, nc = PR.owned_to_front(changed, new_src, i, S,
                                                   ops=fold_ops)
            st2 = MultiBFSState(visited=vis2, level=lvl2, src=src2,
                                front=front, payload=payload, front_cnt=nc,
                                lvl=st.lvl + 1)
            # per-level telemetry channel: value folds ship 4 extra payload
            # bytes per folded entry on top of the codec's static frame
            folded = cnt.sum(dtype=jnp.int32)
            aux = {"folded": folded,
                   "wire": wire_base + 4 * folded.astype(jnp.uint32),
                   "dir": step_dir}
            return st2, topo.psum_all(nc), scanned, aux

        return step

    def keep_going(self, engine, st, total):
        return (total > 0) & (st.lvl <= engine.max_levels)

    def init_total(self, engine, st):
        return engine.topo.psum_all(st.front_cnt)

    def finalize(self, engine, st, i, j):
        S = engine.grid.S
        level = jax.lax.dynamic_slice_in_dim(st.level, j * S, S)
        src = jax.lax.dynamic_slice_in_dim(st.src, j * S, S)
        return level, jnp.where(src == I32_MAX, -1, src), st.lvl

    def out_specs(self, engine):
        out_g = engine.topo.out_block_spec
        return (out_g, out_g, engine.topo.dev_spec)

    def level_count(self, st):
        return st.lvl

    def export_state(self, engine, st, n: int) -> dict:
        """(R, C, ...) MultiBFSState -> global canonical snapshot.

        `level`/`src` export RAW from the owned blocks (src keeps I32_MAX for
        unclaimed vertices; finalize's -1 remap is output-only).  The
        frontier derives from level == lvl-1 with the claiming source id as
        payload, and per-device `visited` is rebuilt as level >= 0 -- a
        superset of any one device's organic bitmap, which only suppresses
        proposals for already-claimed vertices (invisible to the owner's
        `~vis_owned_prev` merge), so a same-grid resume is bit-identical.
        """
        grid = engine.grid
        R, C, S = grid.R, grid.C, grid.S
        gl = np.full((grid.n,), -1, np.int32)
        gs = np.full((grid.n,), I32_MAX, np.int32)
        for i in range(R):
            for j in range(C):
                g0 = (j * R + i) * S
                sl = slice(j * S, (j + 1) * S)
                gl[g0:g0 + S] = st.level[i, j, sl]
                gs[g0:g0 + S] = st.src[i, j, sl]
        lvl = int(st.lvl[0, 0])
        return {"level": gl[:n], "src": gs[:n],
                "lvl": np.asarray(lvl, np.int64),
                "levels_done": np.asarray(lvl - 1, np.int64)}

    def import_state(self, engine, snap: dict) -> MultiBFSState:
        """Global snapshot -> (R, C, ...) MultiBFSState on engine's grid.

        `level`/`src` are authoritative at the owned block only (steps never
        read the non-owned rows after init, so those import as -1/I32_MAX);
        padding vertices of the new grid are unreached.
        """
        grid = engine.grid
        R, C, S, nrl = grid.R, grid.C, grid.S, grid.n_rows_local
        n_raw = int(snap["level"].shape[0])
        gl = np.full((grid.n,), -1, np.int32)
        gl[:n_raw] = snap["level"]
        gs = np.full((grid.n,), I32_MAX, np.int32)
        gs[:n_raw] = snap["src"]
        lvl = int(snap["lvl"])
        visited = np.empty((R, C, nrl), bool)
        level = np.full((R, C, nrl), -1, np.int32)
        src = np.full((R, C, nrl), I32_MAX, np.int32)
        front = np.full((R, C, S), -1, np.int32)
        payload = np.full((R, C, S), I32_MAX, np.int32)
        cnt = np.zeros((R, C), np.int32)
        for i in range(R):
            li = gl[PR.rows_to_global(grid, i)]
            for j in range(C):
                visited[i, j] = li >= 0
                g0 = (j * R + i) * S
                sl = slice(j * S, (j + 1) * S)
                level[i, j, sl] = gl[g0:g0 + S]
                src[i, j, sl] = gs[g0:g0 + S]
                t = np.flatnonzero(gl[g0:g0 + S] == lvl - 1).astype(np.int32)
                front[i, j, :t.size] = i * S + t
                payload[i, j, :t.size] = gs[g0 + t]
                cnt[i, j] = t.size
        return MultiBFSState(visited=visited, level=level, src=src,
                             front=front, payload=payload, front_cnt=cnt,
                             lvl=np.full((R, C), lvl, np.int32))

    def assemble(self, engine, outs, B) -> MultiBFSOutput:
        from repro.algos.engine import wide_total

        level, src, lvls, hi, lo = outs
        return MultiBFSOutput(level=level.reshape(-1), src=src.reshape(-1),
                              n_levels=lvls.max(),
                              edges_scanned=wide_total(hi, lo))
