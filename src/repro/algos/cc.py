"""Connected components by min-label propagation (DESIGN.md sec. 8).

Every vertex starts labelled with its own global id and in the frontier;
each level propagates labels along edges and keeps the minimum (the
Shiloach-Vishkin-style hooking step of Pan et al.'s frontier-centric operator
family, without the pointer jumping -- convergence is bounded by the
component diameter, which the engine's `max_levels` must cover).  At the
fixpoint a vertex's label is the smallest vertex id that can reach it; on a
symmetrised edge list (what the Graph500-style generator produces) that is
the smallest id of its connected component.

The per-vertex monoid is (min, +inf) over int32 labels; the fold carries
(vertex, label) pairs via `FoldCodec.fold_values`, so all three wire codecs
produce bit-identical labels.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.algos import program as PR
from repro.algos.program import FrontierProgram, ValueState, I32_MAX
from repro.core.types import _dc


@_dc
@dataclasses.dataclass
class CCOutput:
    """Global connected-components result."""
    labels: jax.Array      # (n,) int32: min vertex id reaching each vertex
    n_iters: jax.Array     # propagation levels run (scalar int32)
    edges_scanned: Any = None  # exact Python int (64-bit safe)
    directions: Any = None     # per-level direction trace when direction
                               # optimisation ran (see BFSOutput), else None
    trace: Any = None          # LevelTrace when telemetry ran, else None


class ConnectedComponentsProgram(FrontierProgram):
    """Min-label propagation as a frontier program (argument-free)."""
    name = "cc"
    codec_hint = "bitmap"      # early levels activate near-full blocks

    def init(self, engine, graph, extra, arg, i, j):
        grid = engine.grid
        S, nrl = grid.S, grid.n_rows_local
        t = jnp.arange(S, dtype=jnp.int32)
        gids = ((j * grid.R + i) * S + t).astype(jnp.int32)  # owned block ids
        val = jnp.full((nrl,), I32_MAX, jnp.int32)
        val = jax.lax.dynamic_update_slice(val, gids, (j * S,))
        # every owned vertex is initially active; ROW2COL of owned rows
        return ValueState(val=val, front=i * S + t, payload=gids,
                          front_cnt=jnp.int32(S), it=jnp.int32(1))

    def make_step(self, engine, graph, extra, i, j):
        # label propagation = the shared min-monoid step with identity relax
        return PR.make_value_step(engine, graph, i, j, relax=lambda p, w: p)

    def make_bottomup_step(self, engine, graph, extra, i, j):
        # the same step with the pull scan injected: every local row scans
        # its CSR in-edges for frontier labels (dense Bellman-Ford pull) --
        # candidates are bit-identical, everything downstream is shared
        from repro.algos.direction import make_pull_scan
        scan = make_pull_scan(engine, extra[-2], extra[-1], i, j,
                              relax=lambda p, w: p)
        return PR.make_value_step(engine, graph, i, j,
                                  relax=lambda p, w: p, scan=scan)

    def keep_going(self, engine, st, total):
        return (total > 0) & (st.it <= engine.max_levels)

    def init_total(self, engine, st):
        return engine.topo.psum_all(st.front_cnt)

    def finalize(self, engine, st, i, j):
        labels = jax.lax.dynamic_slice_in_dim(st.val, j * engine.grid.S,
                                              engine.grid.S)
        return labels, st.it

    def level_count(self, st):
        return st.it

    def export_state(self, engine, st, n: int) -> dict:
        return PR.export_value_state(engine.grid, st, n)

    def import_state(self, engine, snap: dict) -> ValueState:
        # padding vertices of the new grid are isolated self-labelled
        # components -- exactly what an uninterrupted run holds after level 1
        return PR.import_value_state(engine.grid, snap, pad="gid")

    def out_specs(self, engine):
        return (engine.topo.out_block_spec, engine.topo.dev_spec)

    def assemble(self, engine, outs, B) -> CCOutput:
        from repro.algos.engine import wide_total

        labels, iters, hi, lo = outs
        return CCOutput(labels=labels.reshape(-1), n_iters=iters.max(),
                        edges_scanned=wide_total(hi, lo))
