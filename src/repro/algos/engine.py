"""The generalized frontier-program driver (DESIGN.md sec. 8).

`FrontierEngine` is the `lax.while_loop` level loop extracted from the BFS
engine: init -> loop(step until converged) -> finalize, compiled ONCE per
(program, topology) as a single shard_map'd device program, with the same
64-bit (hi, lo)-uint32 edge accounting and the same scalar/batched (`lax.map`
over a leading arg axis) entry points the BFS engine always had.  What the
loop computes is a `FrontierProgram` (repro.algos.program): BFS levels/preds
is ONE instance (repro.algos.bfs); connected components, SSSP and
multi-source BFS are others.

Buluc & Madduri cast the BFS level loop as a semiring matrix-vector product
over the 2D partition; this module is that observation as code -- the
partition, the expand/fold collectives and the wire codecs are
algorithm-agnostic, only the per-vertex state monoid and the per-level step
change.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import LocalGraph2D

# NOTE: no module-level repro.dist imports here.  `repro.dist.engine` imports
# this module, and `repro.dist/__init__` imports `repro.dist.engine`, so a
# top-level `from repro.dist import ...` would re-enter a partially
# initialized package whenever repro.algos is imported first.  The one
# runtime dependency (the fold-codec registry) is imported inside __init__.


# ----------------------------------------------------------------------------
# Wide (64-bit) accumulation without jax_enable_x64
# ----------------------------------------------------------------------------

def wide_add(hi, lo, delta):
    """(hi, lo) uint32 pair += delta (any non-negative integer dtype)."""
    new_lo = lo + delta.astype(jnp.uint32)
    return hi + (new_lo < lo).astype(jnp.uint32), new_lo


def wide_total(hi, lo) -> int:
    """Sum per-device (hi, lo) pairs into one exact Python int."""
    hi = np.asarray(hi).astype(np.int64)
    lo = np.asarray(lo).astype(np.int64)
    return (int(hi.sum()) << 32) + int(lo.sum())


# ----------------------------------------------------------------------------
# The driver
# ----------------------------------------------------------------------------

class FrontierEngine:
    """Whole-search program for one `FrontierProgram` over a Topology.

    Parameters
    ----------
    topo:       Topology binding the processor grid to mesh axes.
    program:    the FrontierProgram to drive.
    fold_codec: "list" | "bitmap" | "delta" | FoldCodec instance | None
                (None defers to `program.codec_hint`).
    edge_chunk: CSC scan chunk size of the expand phase.
    max_levels: loop bound fed to `program.keep_going`.
    expand:     local-expand implementation: "reference" | "pallas" |
                "pallas-interpret" | "auto" (DESIGN.md sec. 9).  "auto"
                picks Pallas on GPU/TPU, reference on CPU, and honors
                REPRO_EXPAND=pallas-interpret for interpret-mode testing.
                All paths are bit-identical.
    expand_fn:  explicit chunk-expansion override for the CSC scan; when
                given it wins over `expand` (and value-carrying scans fall
                back to the reference path).
    fold:       fold-pipeline implementation: "reference" | "pallas" |
                "pallas-interpret" | "auto" (DESIGN.md sec. 10).  Selects
                the codec encode/decode kernels and the prefix-sum
                compaction that replaces the per-level argsorts; "auto"
                honors REPRO_FOLD and otherwise mirrors the expand rules.
                All paths are bit-identical.
    dedup:      winner-selection method for set-valued folds.
    exchange:   fold exchange strategy: "flat" (one all_to_all per fold) |
                "butterfly" (log2(C) pairwise ppermute stages over the XOR
                hypercube) | "auto" (butterfly when it strictly reduces
                message count: power-of-two C >= 4 on a single column
                axis) | an ExchangeStrategy instance (DESIGN.md sec. 14).
                The resolved strategy is bound into the engine's topology,
                so every codec and the predecessor resolution route through
                it; outputs are bit-identical across strategies.
    bottomup:   bottom-up parent-search implementation: "reference" |
                "pallas" | "pallas-interpret" | "auto" (DESIGN.md sec. 11).
                "auto" honors REPRO_BOTTOMUP and otherwise mirrors the
                expand rules.  Only consulted when the program declares
                `uses_bottomup` (the direction-optimising driver); all
                paths are bit-identical.
    telemetry:  when True, thread the per-level `repro.obs.trace` carry
                through the while_loop and return a `LevelTrace` with every
                search (DESIGN.md sec. 13).  Off by default; the flag is
                part of every engine/AOT cache key, so the off path
                compiles to exactly the untraced program.  Outputs are
                bit-identical either way.
    """

    def __init__(self, topo, program, *, fold_codec=None,
                 edge_chunk: int = 8192, max_levels: int = 64,
                 expand: str = "auto", expand_fn=None, fold: str = "auto",
                 dedup: str = "scatter", bottomup: str = "auto",
                 exchange="flat", telemetry: bool = False):
        from repro.dist.exchange import get_fold_codec
        from repro.dist.strategy import get_exchange
        from repro.kernels.select import (resolve_bottomup_path,
                                          resolve_expand_path,
                                          resolve_fold_path)

        # resolve + validate the exchange strategy and bind it into the
        # topology: codecs and resolve_preds call topo.col_all_to_all and
        # pick the route up without knowing strategies exist
        self.exchange = get_exchange(exchange, topo.grid, topo.col_axes)
        if topo.exchange is not self.exchange:
            topo = topo.with_exchange(self.exchange)
        self.topo = topo
        self.grid = topo.grid
        self.program = program
        self.edge_chunk = edge_chunk
        self.max_levels = max_levels
        self.expand = expand
        self.fold = fold
        self.fold_path = resolve_fold_path(fold)
        self.fold_ops = None
        if self.fold_path != "reference":
            # same import discipline as the expand kernels: through the
            # package surface, outside any trace (Pallas-less installs get
            # the guided ImportError naming fold='reference')
            from repro.kernels import make_fold_ops
            self.fold_ops = make_fold_ops(path=self.fold_path)
        spec = fold_codec if fold_codec is not None else program.codec_hint
        self.codec = get_fold_codec(spec, topo.grid, ops=self.fold_ops)
        # value_expand_fn is the value-carrying twin threaded into
        # `repro.algos.program.scan_relax` (CC / SSSP / multi-source BFS)
        self.value_expand_fn = None
        if expand_fn is not None:
            self.expand_path = "custom"
        else:
            self.expand_path = resolve_expand_path(expand)
            if self.expand_path != "reference":
                # import OUTSIDE any trace (the kernel modules cache jnp
                # constants at import time; see repro.kernels.expand), and
                # through the package surface so a Pallas-less install gets
                # the guided ImportError (expand='reference' remedy)
                from repro.kernels import (make_expand_fn,
                                           make_value_expand_fn)
                expand_fn = make_expand_fn(path=self.expand_path)
                self.value_expand_fn = make_value_expand_fn(
                    path=self.expand_path)
        self.expand_fn = expand_fn
        self.dedup = dedup
        # bottom-up kernel hooks (the direction-optimised steps' chunk
        # parent search); resolved for every engine so the path lands in
        # cache keys, constructed only when the program can use them
        self.bottomup = bottomup
        self.bottomup_path = resolve_bottomup_path(bottomup)
        self.bottomup_fn = None
        self.value_bottomup_fn = None
        if getattr(program, "uses_bottomup", False) \
                and self.bottomup_path != "reference":
            # same import discipline as the expand/fold kernels (package
            # surface, outside any trace; bottomup='reference' remedy)
            from repro.kernels import make_bottomup_fn, make_value_bottomup_fn
            self.bottomup_fn = make_bottomup_fn(path=self.bottomup_path)
            self.value_bottomup_fn = make_value_bottomup_fn(
                path=self.bottomup_path)
        self.telemetry = bool(telemetry)
        # last assembled LevelTrace (scalar) or tuple of traces (batched);
        # None until a telemetry-enabled search completes
        self.last_trace = None
        # traces of the level loop (scalar or batched); jit/AOT cache hits do
        # not retrace, so tests can assert a 64-root sweep compiles once
        self.trace_count = 0
        self._run = jax.jit(self._build())
        self._run_batch = jax.jit(self._build(batched=True))

    # -- whole-search program (lax.while_loop over levels) -------------------
    def _build(self, batched: bool = False):
        """Device program for one search arg (scalar) or a leading arg axis.

        The batched program runs the whole level loop per arg under
        `lax.map` (a scan: per-search work stays proportional to that
        search's levels, unlike vmap which would pad every search to the
        slowest), so a multi-root sweep is ONE compiled executable.
        """
        topo, prog = self.topo, self.program
        telemetry = self.telemetry
        from repro.obs import trace as T

        def device_fn(col_off, row_idx, nnz, *rest):
            extra, arg = rest[:-1], rest[-1]
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            extra = tuple(e[0, 0] for e in extra)
            i, j = topo.device_coords()

            def search(a):
                st = prog.init(self, graph, extra, a, i, j)
                step = prog.make_step(self, graph, extra, i, j)

                def cond(carry):
                    st, total = carry[0], carry[1]
                    return prog.keep_going(self, st, total)

                def run_step(st, total):
                    # steps return (st', total, scanned[, aux]); aux is the
                    # per-level telemetry channel (folded / wire / dir).
                    # Untraced engines drop it right here, so XLA dead-code
                    # eliminates the aux reductions and the off path
                    # compiles to exactly the pre-telemetry program.
                    res = step(st, total)
                    aux = res[3] if len(res) > 3 else None
                    return res[0], res[1], res[2], aux

                def body(carry):
                    st, total, hi, lo = carry[:4]
                    st2, total2, scanned, aux = run_step(st, total)
                    hi, lo = wide_add(hi, lo, scanned)
                    if not telemetry:
                        return st2, total2, hi, lo
                    tr = T.record_level(
                        carry[4], frontier=total,
                        front_dev=prog.front_count(st), scanned=scanned,
                        aux=T.normalize_aux(aux))
                    return st2, total2, hi, lo, tr

                init_total = prog.init_total(self, st)
                carry = (st, init_total, jnp.uint32(0), jnp.uint32(0))
                if telemetry:
                    carry += (T.init_trace(self.max_levels),)
                carry = jax.lax.while_loop(cond, body, carry)
                st, hi, lo = carry[0], carry[2], carry[3]
                outs = tuple(prog.finalize(self, st, i, j)) + (hi, lo)
                if telemetry:
                    outs += T.trace_outputs(carry[4])
                return outs

            if batched:
                outs = jax.lax.map(search, arg)
            else:
                outs = search(arg)
            return tuple(o[None, None] for o in outs)

        dev = topo.dev_spec
        out_specs = tuple(prog.out_specs(self)) + (dev, dev)
        if telemetry:
            out_specs += (dev,) * T.N_TRACE_OUTS
        mapped = topo.shard_map(
            device_fn,
            in_specs=(dev,) * (3 + prog.n_extra) + (P(),),
            out_specs=out_specs)

        def counted(*args):
            # runs at TRACE time only (jit / .lower()); cache hits skip it
            self.trace_count += 1
            return mapped(*args)

        return counted

    def assemble(self, outs, B):
        """Gathered device outputs -> output object, with telemetry split
        off, assembled into a host `LevelTrace`, attached to the output's
        `trace` field and kept as `self.last_trace`.

        This is the ONE funnel both invocation paths share: `run` /
        `run_batch` here, and the session layer's AOT executables (which
        call the compiled artifact directly and assemble through this).
        In a process group the device outputs are global arrays whose
        remote shards this process cannot read; fetch them first (identity
        for every fully-addressable, i.e. single-process, output).
        """
        from repro.dist import multihost
        outs = multihost.fetch_all(outs)
        trace = None
        if self.telemetry:
            from repro.obs import trace as T
            outs, traw = outs[:-T.N_TRACE_OUTS], outs[-T.N_TRACE_OUTS:]
            trace = T.assemble_traces(traw, B, grid=self.grid,
                                      program=self.program.name,
                                      codec=self.codec.name)
        out = self.program.assemble(self, tuple(outs), B)
        if trace is not None:
            import dataclasses
            out = dataclasses.replace(out, trace=trace)
            self.last_trace = trace
        return out

    def run(self, graph: LocalGraph2D, arg, *extra):
        """One search; extra = the program's per-device graph arrays.

        `arg` is the program's search argument (a root, a sources vector, a
        dummy scalar for argument-free programs like CC)."""
        outs = self._run(graph.col_off, graph.row_idx, graph.nnz, *extra, arg)
        return self.assemble(outs, None)

    def run_batch(self, graph: LocalGraph2D, args, *extra):
        """A leading-axis batch of searches as ONE compiled program."""
        outs = self._run_batch(graph.col_off, graph.row_idx, graph.nnz,
                               *extra, args)
        return self.assemble(outs, int(args.shape[0]))
