"""The generalized frontier-program driver (DESIGN.md sec. 8).

`FrontierEngine` is the `lax.while_loop` level loop extracted from the BFS
engine: init -> loop(step until converged) -> finalize, compiled ONCE per
(program, topology) as a single shard_map'd device program, with the same
64-bit (hi, lo)-uint32 edge accounting and the same scalar/batched (`lax.map`
over a leading arg axis) entry points the BFS engine always had.  What the
loop computes is a `FrontierProgram` (repro.algos.program): BFS levels/preds
is ONE instance (repro.algos.bfs); connected components, SSSP and
multi-source BFS are others.

Buluc & Madduri cast the BFS level loop as a semiring matrix-vector product
over the 2D partition; this module is that observation as code -- the
partition, the expand/fold collectives and the wire codecs are
algorithm-agnostic, only the per-vertex state monoid and the per-level step
change.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import LocalGraph2D

# NOTE: no module-level repro.dist imports here.  `repro.dist.engine` imports
# this module, and `repro.dist/__init__` imports `repro.dist.engine`, so a
# top-level `from repro.dist import ...` would re-enter a partially
# initialized package whenever repro.algos is imported first.  The one
# runtime dependency (the fold-codec registry) is imported inside __init__.


# ----------------------------------------------------------------------------
# Wide (64-bit) accumulation without jax_enable_x64
# ----------------------------------------------------------------------------

def wide_add(hi, lo, delta):
    """(hi, lo) uint32 pair += delta (any non-negative integer dtype)."""
    new_lo = lo + delta.astype(jnp.uint32)
    return hi + (new_lo < lo).astype(jnp.uint32), new_lo


def wide_total(hi, lo) -> int:
    """Sum per-device (hi, lo) pairs into one exact Python int."""
    hi = np.asarray(hi).astype(np.int64)
    lo = np.asarray(lo).astype(np.int64)
    return (int(hi.sum()) << 32) + int(lo.sum())


# ----------------------------------------------------------------------------
# The driver
# ----------------------------------------------------------------------------

class FrontierEngine:
    """Whole-search program for one `FrontierProgram` over a Topology.

    Parameters
    ----------
    topo:       Topology binding the processor grid to mesh axes.
    program:    the FrontierProgram to drive.
    fold_codec: "list" | "bitmap" | "delta" | FoldCodec instance | None
                (None defers to `program.codec_hint`).
    edge_chunk: CSC scan chunk size of the expand phase.
    max_levels: loop bound fed to `program.keep_going`.
    expand:     local-expand implementation: "reference" | "pallas" |
                "pallas-interpret" | "auto" (DESIGN.md sec. 9).  "auto"
                picks Pallas on GPU/TPU, reference on CPU, and honors
                REPRO_EXPAND=pallas-interpret for interpret-mode testing.
                All paths are bit-identical.
    expand_fn:  explicit chunk-expansion override for the CSC scan; when
                given it wins over `expand` (and value-carrying scans fall
                back to the reference path).
    fold:       fold-pipeline implementation: "reference" | "pallas" |
                "pallas-interpret" | "auto" (DESIGN.md sec. 10).  Selects
                the codec encode/decode kernels and the prefix-sum
                compaction that replaces the per-level argsorts; "auto"
                honors REPRO_FOLD and otherwise mirrors the expand rules.
                All paths are bit-identical.
    dedup:      winner-selection method for set-valued folds.
    exchange:   fold exchange strategy: "flat" (one all_to_all per fold) |
                "butterfly" (log2(C) pairwise ppermute stages over the XOR
                hypercube) | "auto" (butterfly when it strictly reduces
                message count: power-of-two C >= 4 on a single column
                axis) | an ExchangeStrategy instance (DESIGN.md sec. 14).
                The resolved strategy is bound into the engine's topology,
                so every codec and the predecessor resolution route through
                it; outputs are bit-identical across strategies.
    bottomup:   bottom-up parent-search implementation: "reference" |
                "pallas" | "pallas-interpret" | "auto" (DESIGN.md sec. 11).
                "auto" honors REPRO_BOTTOMUP and otherwise mirrors the
                expand rules.  Only consulted when the program declares
                `uses_bottomup` (the direction-optimising driver); all
                paths are bit-identical.
    telemetry:  when True, thread the per-level `repro.obs.trace` carry
                through the while_loop and return a `LevelTrace` with every
                search (DESIGN.md sec. 13).  Off by default; the flag is
                part of every engine/AOT cache key, so the off path
                compiles to exactly the untraced program.  Outputs are
                bit-identical either way.
    fault_tolerance:  when True, ALSO build the segmented level loop
                (DESIGN.md sec. 15): three extra jitted programs
                (`ft_start` / `ft_segment` / `ft_finish`) that run at most
                `ckpt_every` levels per call and hand the loop carry back
                to the host between segments, so a traversal can be
                checkpointed, interrupted and resumed mid-flight.  Off by
                default; the flags key every engine/AOT cache, the regular
                single-while_loop programs are built IDENTICALLY either
                way, and segmented outputs are bit-identical to them.
    ckpt_every: levels per resumable segment (the K of "checkpoint every
                K levels"); only consulted when fault_tolerance=True.
    """

    def __init__(self, topo, program, *, fold_codec=None,
                 edge_chunk: int = 8192, max_levels: int = 64,
                 expand: str = "auto", expand_fn=None, fold: str = "auto",
                 dedup: str = "scatter", bottomup: str = "auto",
                 exchange="flat", telemetry: bool = False,
                 fault_tolerance: bool = False, ckpt_every: int = 1):
        from repro.dist.exchange import get_fold_codec
        from repro.dist.strategy import get_exchange
        from repro.kernels.select import (resolve_bottomup_path,
                                          resolve_expand_path,
                                          resolve_fold_path)

        # resolve + validate the exchange strategy and bind it into the
        # topology: codecs and resolve_preds call topo.col_all_to_all and
        # pick the route up without knowing strategies exist
        self.exchange = get_exchange(exchange, topo.grid, topo.col_axes)
        if topo.exchange is not self.exchange:
            topo = topo.with_exchange(self.exchange)
        self.topo = topo
        self.grid = topo.grid
        self.program = program
        self.edge_chunk = edge_chunk
        self.max_levels = max_levels
        self.expand = expand
        self.fold = fold
        self.fold_path = resolve_fold_path(fold)
        self.fold_ops = None
        if self.fold_path != "reference":
            # same import discipline as the expand kernels: through the
            # package surface, outside any trace (Pallas-less installs get
            # the guided ImportError naming fold='reference')
            from repro.kernels import make_fold_ops
            self.fold_ops = make_fold_ops(path=self.fold_path)
        spec = fold_codec if fold_codec is not None else program.codec_hint
        self.codec = get_fold_codec(spec, topo.grid, ops=self.fold_ops)
        # value_expand_fn is the value-carrying twin threaded into
        # `repro.algos.program.scan_relax` (CC / SSSP / multi-source BFS)
        self.value_expand_fn = None
        if expand_fn is not None:
            self.expand_path = "custom"
        else:
            self.expand_path = resolve_expand_path(expand)
            if self.expand_path != "reference":
                # import OUTSIDE any trace (the kernel modules cache jnp
                # constants at import time; see repro.kernels.expand), and
                # through the package surface so a Pallas-less install gets
                # the guided ImportError (expand='reference' remedy)
                from repro.kernels import (make_expand_fn,
                                           make_value_expand_fn)
                expand_fn = make_expand_fn(path=self.expand_path)
                self.value_expand_fn = make_value_expand_fn(
                    path=self.expand_path)
        self.expand_fn = expand_fn
        self.dedup = dedup
        # bottom-up kernel hooks (the direction-optimised steps' chunk
        # parent search); resolved for every engine so the path lands in
        # cache keys, constructed only when the program can use them
        self.bottomup = bottomup
        self.bottomup_path = resolve_bottomup_path(bottomup)
        self.bottomup_fn = None
        self.value_bottomup_fn = None
        if getattr(program, "uses_bottomup", False) \
                and self.bottomup_path != "reference":
            # same import discipline as the expand/fold kernels (package
            # surface, outside any trace; bottomup='reference' remedy)
            from repro.kernels import make_bottomup_fn, make_value_bottomup_fn
            self.bottomup_fn = make_bottomup_fn(path=self.bottomup_path)
            self.value_bottomup_fn = make_value_bottomup_fn(
                path=self.bottomup_path)
        self.telemetry = bool(telemetry)
        self.fault_tolerance = bool(fault_tolerance)
        self.ckpt_every = max(1, int(ckpt_every))
        # segmented programs, built lazily and ONLY when fault_tolerance=True
        # -- an off-path engine never constructs (or traces) them, which is
        # the no-retrace guarantee tests assert
        self._ft_progs = {}
        # last assembled LevelTrace (scalar) or tuple of traces (batched);
        # None until a telemetry-enabled search completes
        self.last_trace = None
        # traces of the level loop (scalar or batched); jit/AOT cache hits do
        # not retrace, so tests can assert a 64-root sweep compiles once
        self.trace_count = 0
        self._run = jax.jit(self._build())
        self._run_batch = jax.jit(self._build(batched=True))

    # -- whole-search program (lax.while_loop over levels) -------------------
    def _build(self, batched: bool = False):
        """Device program for one search arg (scalar) or a leading arg axis.

        The batched program runs the whole level loop per arg under
        `lax.map` (a scan: per-search work stays proportional to that
        search's levels, unlike vmap which would pad every search to the
        slowest), so a multi-root sweep is ONE compiled executable.
        """
        topo, prog = self.topo, self.program
        telemetry = self.telemetry
        from repro.obs import trace as T

        def device_fn(col_off, row_idx, nnz, *rest):
            extra, arg = rest[:-1], rest[-1]
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            extra = tuple(e[0, 0] for e in extra)
            i, j = topo.device_coords()

            def search(a):
                st = prog.init(self, graph, extra, a, i, j)
                step = prog.make_step(self, graph, extra, i, j)

                def cond(carry):
                    st, total = carry[0], carry[1]
                    return prog.keep_going(self, st, total)

                def run_step(st, total):
                    # steps return (st', total, scanned[, aux]); aux is the
                    # per-level telemetry channel (folded / wire / dir).
                    # Untraced engines drop it right here, so XLA dead-code
                    # eliminates the aux reductions and the off path
                    # compiles to exactly the pre-telemetry program.
                    res = step(st, total)
                    aux = res[3] if len(res) > 3 else None
                    return res[0], res[1], res[2], aux

                def body(carry):
                    st, total, hi, lo = carry[:4]
                    st2, total2, scanned, aux = run_step(st, total)
                    hi, lo = wide_add(hi, lo, scanned)
                    if not telemetry:
                        return st2, total2, hi, lo
                    tr = T.record_level(
                        carry[4], frontier=total,
                        front_dev=prog.front_count(st), scanned=scanned,
                        aux=T.normalize_aux(aux))
                    return st2, total2, hi, lo, tr

                init_total = prog.init_total(self, st)
                carry = (st, init_total, jnp.uint32(0), jnp.uint32(0))
                if telemetry:
                    carry += (T.init_trace(self.max_levels),)
                carry = jax.lax.while_loop(cond, body, carry)
                st, hi, lo = carry[0], carry[2], carry[3]
                outs = tuple(prog.finalize(self, st, i, j)) + (hi, lo)
                if telemetry:
                    outs += T.trace_outputs(carry[4])
                return outs

            if batched:
                outs = jax.lax.map(search, arg)
            else:
                outs = search(arg)
            return tuple(o[None, None] for o in outs)

        dev = topo.dev_spec
        out_specs = tuple(prog.out_specs(self)) + (dev, dev)
        if telemetry:
            out_specs += (dev,) * T.N_TRACE_OUTS
        mapped = topo.shard_map(
            device_fn,
            in_specs=(dev,) * (3 + prog.n_extra) + (P(),),
            out_specs=out_specs)

        def counted(*args):
            # runs at TRACE time only (jit / .lower()); cache hits skip it
            self.trace_count += 1
            return mapped(*args)

        return counted

    def assemble(self, outs, B):
        """Gathered device outputs -> output object, with telemetry split
        off, assembled into a host `LevelTrace`, attached to the output's
        `trace` field and kept as `self.last_trace`.

        This is the ONE funnel both invocation paths share: `run` /
        `run_batch` here, and the session layer's AOT executables (which
        call the compiled artifact directly and assemble through this).
        In a process group the device outputs are global arrays whose
        remote shards this process cannot read; fetch them first (identity
        for every fully-addressable, i.e. single-process, output).
        """
        from repro.dist import multihost
        outs = multihost.fetch_all(outs)
        trace = None
        if self.telemetry:
            from repro.obs import trace as T
            outs, traw = outs[:-T.N_TRACE_OUTS], outs[-T.N_TRACE_OUTS:]
            trace = T.assemble_traces(traw, B, grid=self.grid,
                                      program=self.program.name,
                                      codec=self.codec.name)
        out = self.program.assemble(self, tuple(outs), B)
        if trace is not None:
            import dataclasses
            out = dataclasses.replace(out, trace=trace)
            self.last_trace = trace
        return out

    def run(self, graph: LocalGraph2D, arg, *extra):
        """One search; extra = the program's per-device graph arrays.

        `arg` is the program's search argument (a root, a sources vector, a
        dummy scalar for argument-free programs like CC)."""
        outs = self._run(graph.col_off, graph.row_idx, graph.nnz, *extra, arg)
        return self.assemble(outs, None)

    def run_batch(self, graph: LocalGraph2D, args, *extra):
        """A leading-axis batch of searches as ONE compiled program."""
        outs = self._run_batch(graph.col_off, graph.row_idx, graph.nnz,
                               *extra, args)
        return self.assemble(outs, int(args.shape[0]))

    # -- segmented level loop (DESIGN.md sec. 15) ----------------------------
    #
    # The same init / step / finalize as `_build`, split at checkpoint lines:
    # `ft_start` runs init, `ft_segment` runs AT MOST `ckpt_every` levels of
    # the while_loop, `ft_finish` runs finalize.  Between calls the loop
    # carry lives on the host side as a dict of (R, C[, B], ...) device
    # arrays -- the checkpoint schema IS the FrontierProgram carry -- so the
    # driver in repro.runtime.recovery can snapshot it, detect injected
    # device loss, and resume (same grid or shrunken via export/import).
    # Segment boundaries add no arithmetic: level k's inputs are exactly the
    # carry level k-1 produced, so segmented outputs are bit-identical to
    # the single-while_loop program for every K.

    def _ft(self, batched: bool):
        if not self.fault_tolerance:
            raise ValueError(
                "segmented traversal needs BFSConfig(fault_tolerance=True)")
        fns = self._ft_progs.get(bool(batched))
        if fns is None:
            fns = tuple(jax.jit(self._build_ft(kind, batched))
                        for kind in ("init", "segment", "finalize"))
            self._ft_progs[bool(batched)] = fns
        return fns

    def _build_ft(self, kind: str, batched: bool):
        topo, prog = self.topo, self.program
        telemetry = self.telemetry
        K = jnp.int32(self.ckpt_every)
        from repro.obs import trace as T
        dev = topo.dev_spec

        def init_fn(col_off, row_idx, nnz, *rest):
            extra, arg = rest[:-1], rest[-1]
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            extra = tuple(e[0, 0] for e in extra)
            i, j = topo.device_coords()

            def one(a):
                st = prog.init(self, graph, extra, a, i, j)
                total = prog.init_total(self, st)
                carry = {"st": st, "total": total,
                         "hi": jnp.uint32(0), "lo": jnp.uint32(0),
                         "active": prog.keep_going(self, st, total)}
                if telemetry:
                    carry["trace"] = T.init_trace(self.max_levels)
                return carry

            carry = jax.lax.map(one, arg) if batched else one(arg)
            return jax.tree_util.tree_map(lambda o: o[None, None], carry)

        def seg_fn(col_off, row_idx, nnz, *rest):
            extra, carry = rest[:-1], rest[-1]
            graph = LocalGraph2D(col_off=col_off[0, 0], row_idx=row_idx[0, 0],
                                 nnz=nnz[0, 0])
            extra = tuple(e[0, 0] for e in extra)
            i, j = topo.device_coords()

            def one(c):
                step = prog.make_step(self, graph, extra, i, j)

                def cond(t):
                    return prog.keep_going(self, t[0], t[1]) & (t[4] < K)

                def body(t):
                    st, total, hi, lo, k = t[:5]
                    res = step(st, total)
                    aux = res[3] if len(res) > 3 else None
                    st2, total2, scanned = res[0], res[1], res[2]
                    hi, lo = wide_add(hi, lo, scanned)
                    if not telemetry:
                        return st2, total2, hi, lo, k + 1
                    tr = T.record_level(
                        t[5], frontier=total,
                        front_dev=prog.front_count(st), scanned=scanned,
                        aux=T.normalize_aux(aux))
                    return st2, total2, hi, lo, k + 1, tr

                t = (c["st"], c["total"], c["hi"], c["lo"], jnp.int32(0))
                if telemetry:
                    t += (c["trace"],)
                t = jax.lax.while_loop(cond, body, t)
                out = {"st": t[0], "total": t[1], "hi": t[2], "lo": t[3],
                       "active": prog.keep_going(self, t[0], t[1])}
                if telemetry:
                    out["trace"] = t[5]
                return out

            c = jax.tree_util.tree_map(lambda x: x[0, 0], carry)
            carry = jax.lax.map(one, c) if batched else one(c)
            return jax.tree_util.tree_map(lambda o: o[None, None], carry)

        def fin_fn(carry):
            i, j = topo.device_coords()

            def one(c):
                outs = tuple(prog.finalize(self, c["st"], i, j)) \
                    + (c["hi"], c["lo"])
                if telemetry:
                    outs += T.trace_outputs(c["trace"])
                return outs

            c = jax.tree_util.tree_map(lambda x: x[0, 0], carry)
            outs = jax.lax.map(one, c) if batched else one(c)
            return tuple(o[None, None] for o in outs)

        if kind == "init":
            mapped = topo.shard_map(
                init_fn,
                in_specs=(dev,) * (3 + prog.n_extra) + (P(),),
                out_specs=dev)
        elif kind == "segment":
            mapped = topo.shard_map(
                seg_fn,
                in_specs=(dev,) * (3 + prog.n_extra) + (dev,),
                out_specs=dev)
        else:
            fin_specs = tuple(prog.out_specs(self)) + (dev, dev)
            if telemetry:
                fin_specs += (dev,) * T.N_TRACE_OUTS
            mapped = topo.shard_map(fin_fn, in_specs=(dev,),
                                    out_specs=fin_specs)

        def counted(*args):
            # runs at TRACE time only (jit cache hits skip it), so tests can
            # assert repeated segmented sweeps compile each piece once
            self.trace_count += 1
            return mapped(*args)

        return counted

    def ft_start(self, graph: LocalGraph2D, arg, *extra, batched=False):
        """Init carry for one search (scalar arg) or a leading-axis batch."""
        return self._ft(batched)[0](graph.col_off, graph.row_idx, graph.nnz,
                                    *extra, arg)

    def ft_segment(self, graph: LocalGraph2D, carry, *extra, batched=False):
        """Advance the carry by at most `ckpt_every` levels (pure function:
        the input carry is untouched, so a failed segment retries from it)."""
        return self._ft(batched)[1](graph.col_off, graph.row_idx, graph.nnz,
                                    *extra, carry)

    def ft_finish(self, carry, B=None):
        """Finalize a converged carry through the shared assemble funnel."""
        return self.assemble(self._ft(B is not None)[2](carry), B)

    def ft_active(self, carry) -> bool:
        """Host check: does any search in the carry still have work?"""
        from repro.dist import multihost
        return bool(np.asarray(multihost.fetch(carry["active"])).any())

    def ft_levels_done(self, carry) -> int:
        """Host readout: levels completed so far (max over a batch)."""
        from repro.dist import multihost
        cnt = self.program.level_count(carry["st"])
        return int(np.asarray(multihost.fetch(cnt))[0, 0].max()) - 1

    # -- carry export / import (the checkpoint schema; DESIGN.md sec. 15) ----

    def export_carry(self, carry, *, n=None, B=None) -> dict:
        """Segmented-loop carry -> grid-independent host snapshot.

        `arrays` is a nested dict of numpy arrays (what CheckpointManager
        persists); `meta` is the JSON-able identity the checkpointer keys
        on.  The per-vertex state is exported in GLOBAL vertex-id order and
        sliced to the raw `n`, so the snapshot can re-shard onto any grid
        (`import_carry` re-pads); totals/activity are replicated scalars and
        the (hi, lo) edge accounting exports as one exact integer.
        """
        from repro.dist import multihost
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(multihost.fetch(x)), carry)
        n = int(self.grid.n if n is None else n)
        prog = self.program
        if B is None:
            st_snap = prog.export_state(self, host["st"], n)
        else:
            st_snap = {
                f"b{b}": prog.export_state(
                    self,
                    jax.tree_util.tree_map(lambda x: x[:, :, b], host["st"]),
                    n)
                for b in range(B)}
        hi = host["hi"].astype(np.int64)
        lo = host["lo"].astype(np.int64)
        scanned = (hi.sum(axis=(0, 1)) << 32) + lo.sum(axis=(0, 1))
        arrays = {"st": st_snap,
                  "total": np.asarray(host["total"][0, 0], np.int64),
                  "active": np.asarray(host["active"][0, 0], bool),
                  "scanned": np.asarray(scanned, np.int64)}
        if self.telemetry:
            arrays["trace"] = {k: np.asarray(v)
                               for k, v in host["trace"].items()}
        if B is None:
            levels_done = int(st_snap["levels_done"])
        else:
            levels_done = max(int(st_snap[f"b{b}"]["levels_done"])
                              for b in range(B))
        meta = {"program": prog.name, "codec": self.codec.name,
                "grid": [self.grid.R, self.grid.C], "B": B, "n": n,
                "max_levels": int(self.max_levels),
                "levels_done": levels_done}
        return {"arrays": arrays, "meta": meta}

    def import_carry(self, snapshot: dict, *, B=None):
        """Host snapshot -> device carry on THIS engine's grid (the resume
        half of `export_carry`; the grids need not match -- elastic resume
        re-shards the global state onto the survivor mesh)."""
        arrays = snapshot["arrays"]
        prog = self.program
        if B is None:
            st = prog.import_state(self, arrays["st"])
        else:
            sts = [prog.import_state(self, arrays["st"][f"b{b}"])
                   for b in range(B)]
            st = jax.tree_util.tree_map(
                lambda *xs: np.stack(xs, axis=2), *sts)
        shp = (self.grid.R, self.grid.C) + (() if B is None else (B,))
        total = np.broadcast_to(
            np.asarray(arrays["total"], np.int32), shp).copy()
        active = np.broadcast_to(
            np.asarray(arrays["active"], bool), shp).copy()
        scanned = np.asarray(arrays["scanned"], np.int64)
        hi = np.zeros(shp, np.uint32)
        lo = np.zeros(shp, np.uint32)
        hi[0, 0] = (scanned >> np.int64(32)).astype(np.uint32)
        lo[0, 0] = (scanned & np.int64(0xFFFFFFFF)).astype(np.uint32)
        carry = {"st": st, "total": total, "hi": hi, "lo": lo,
                 "active": active}
        if self.telemetry:
            carry["trace"] = self._import_trace(
                arrays.get("trace"), B, snapshot["meta"]["levels_done"])
        return self._place_carry(carry)

    def _import_trace(self, traw, B, levels_done: int) -> dict:
        """Raw (R0, C0[, B], L) trace channels -> this grid's trace carry.

        Same grid: bit-exact reimport.  Shrunken grid: per-device work
        channels collapse onto device (0, 0) (sums -- global per-level
        figures survive exactly, per-device attribution does not) and the
        psum-replicated channels broadcast from device (0, 0).
        """
        from repro.obs import trace as T
        R, C = self.grid.R, self.grid.C
        shp = (R, C) + (() if B is None else (B,))
        L = int(self.max_levels)
        if traw is None:
            # resuming a snapshot taken without telemetry: blank history,
            # k advanced so post-resume levels land in the right slots
            tr = {c: np.zeros(shp + (L,),
                              np.uint32 if c in ("scanned", "wire")
                              else np.int32)
                  for c in T.TRACE_CHANNELS}
            tr["dir"] = np.full(shp + (L,), -1, np.int32)
            tr["k"] = np.full(shp, levels_done, np.int32)
            return tr
        src_grid = traw["k"].shape[:2]
        if src_grid == (R, C):
            return {k: np.asarray(v) for k, v in traw.items()}
        tr = {}
        for c in ("front_dev", "scanned", "folded", "wire", "msgs"):
            a = np.asarray(traw[c])
            out = np.zeros(shp + (L,), a.dtype)
            out[0, 0] = a.sum(axis=(0, 1), dtype=np.int64).astype(a.dtype)
            tr[c] = out
        for c in ("frontier", "dir"):
            a = np.asarray(traw[c])
            tr[c] = np.broadcast_to(a[0, 0], shp + (L,)).copy()
        tr["k"] = np.broadcast_to(
            np.asarray(traw["k"])[0, 0], shp).copy().astype(np.int32)
        return tr

    def _place_carry(self, carry):
        """Host (R, C[, B], ...) leaves -> device arrays on this topology's
        mesh (the `reshard_state` placement of elastic resume; in a process
        group, global-array construction via multihost.put_dev)."""
        from repro.dist import multihost
        mesh, dev = self.topo.mesh, self.topo.dev_spec
        if multihost.is_multiprocess():
            return jax.tree_util.tree_map(
                lambda x: multihost.put_dev(x, mesh, dev), carry)
        from repro.ckpt.elastic import reshard_state
        spec_tree = jax.tree_util.tree_map(lambda x: dev, carry)
        return reshard_state(carry, spec_tree, mesh)
