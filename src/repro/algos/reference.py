"""NumPy host references for the frontier programs (ground truth in tests).

Each matches the distributed program's semantics exactly:

  * `cc_reference`       -- fixpoint of min-label propagation along directed
                            edges (= component-min labels on a symmetrised
                            edge list);
  * `sssp_reference`     -- Dijkstra over non-negative integer weights;
  * `multi_bfs_reference`-- simultaneous wave from K sources, first wave
                            wins, min source INDEX breaks same-wave ties;
  * `k_hop_neighborhood` -- the union k-hop vertex set of a source set (the
                            models/gnn sampling primitive).
"""
from __future__ import annotations

import heapq

import numpy as np

_BIG = np.iinfo(np.int32).max


def cc_reference(edges, n: int) -> np.ndarray:
    """(n,) int32 labels: min vertex id with a directed path to each vertex
    (on a symmetrised edge list: the component's minimum id)."""
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    labels = np.arange(n, dtype=np.int32)
    while True:
        new = labels.copy()
        np.minimum.at(new, v, labels[u])
        if (new == labels).all():
            return labels
        labels = new


def sssp_reference(edges, weights, n: int, root: int) -> np.ndarray:
    """(n,) int32 shortest distances from root, -1 = unreachable."""
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    w = np.asarray(weights, dtype=np.int64)
    order = np.argsort(u, kind="stable")
    us, vs, ws = u[order], v[order], w[order]
    starts = np.searchsorted(us, np.arange(n + 1))
    dist = np.full(n, -1, np.int64)
    heap = [(0, int(root))]
    while heap:
        d, x = heapq.heappop(heap)
        if dist[x] >= 0:
            continue
        dist[x] = d
        for e in range(starts[x], starts[x + 1]):
            y = int(vs[e])
            if dist[y] < 0:
                heapq.heappush(heap, (d + int(ws[e]), y))
    return dist.astype(np.int32)


def multi_bfs_reference(edges, n: int, sources, max_levels: int | None = None):
    """Simultaneous BFS from `sources`; returns ((n,) level, (n,) src).

    level[v] = hops to the nearest source (-1 beyond `max_levels` or
    unreachable); src[v] = index into `sources` of the claiming source,
    same-wave ties broken by the minimum index.
    """
    u = np.asarray(edges[0], dtype=np.int64)
    v = np.asarray(edges[1], dtype=np.int64)
    order = np.argsort(u, kind="stable")
    us, vs = u[order], v[order]
    starts = np.searchsorted(us, np.arange(n + 1))
    level = np.full(n, -1, np.int32)
    src = np.full(n, -1, np.int32)
    for idx, s in enumerate(np.asarray(sources, dtype=np.int64)):
        if level[s] < 0:
            level[s], src[s] = 0, idx
    frontier = np.flatnonzero(level == 0)
    lvl = 1
    while frontier.size and (max_levels is None or lvl <= max_levels):
        cand: dict[int, int] = {}
        for x in frontier:
            for e in range(starts[x], starts[x + 1]):
                y = int(vs[e])
                if level[y] < 0:
                    c = cand.get(y, _BIG)
                    if src[x] < c:
                        cand[y] = int(src[x])
        for y, s in cand.items():
            level[y], src[y] = lvl, s
        frontier = np.fromiter(cand.keys(), dtype=np.int64,
                               count=len(cand))
        lvl += 1
    return level, src


def k_hop_neighborhood(edges, n: int, sources, k: int) -> np.ndarray:
    """Sorted vertex ids within k hops of any source (GNN sampling)."""
    level, _ = multi_bfs_reference(edges, n, sources, max_levels=k)
    return np.flatnonzero(level >= 0)
