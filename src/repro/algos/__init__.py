"""Frontier-program subsystem: distributed graph algorithms beyond BFS on
the shared engine (DESIGN.md sec. 8).

    from repro.algos import FrontierEngine, ConnectedComponentsProgram

    eng = FrontierEngine(topology, ConnectedComponentsProgram(),
                         fold_codec="bitmap")
    out = eng.run(csc, jnp.int32(0))          # -> CCOutput

Most callers go through the session instead: `GraphSession
.connected_components()`, `.sssp(root)`, `.multi_bfs(sources)`
(repro.api.session), which add residency, engine reuse and the AOT
executable cache.
"""
# Import order matters: program/engine first (no repro.dist dependency at
# import time), then the programs (whose repro.dist imports may re-enter a
# partially initialized repro.dist while its __init__ imports dist.engine).
from repro.algos.program import (
    FrontierProgram, ValueState, I32_MAX, scan_relax, pack_blocks,
    scatter_min_received, owned_to_front)
from repro.algos.engine import FrontierEngine, wide_add, wide_total
from repro.algos.bfs import BFSLevelsProgram
from repro.algos.direction import DirectionProgram, DirState
from repro.algos.cc import CCOutput, ConnectedComponentsProgram
from repro.algos.sssp import SSSPOutput, SSSPProgram
from repro.algos.multi_bfs import (
    MultiBFSOutput, MultiBFSState, MultiSourceBFSProgram)
from repro.algos.reference import (
    cc_reference, sssp_reference, multi_bfs_reference, k_hop_neighborhood)

PROGRAMS = {
    "bfs": BFSLevelsProgram,
    "cc": ConnectedComponentsProgram,
    "sssp": SSSPProgram,
    "multi_bfs": MultiSourceBFSProgram,
}

__all__ = [
    "FrontierProgram", "FrontierEngine", "ValueState", "I32_MAX",
    "scan_relax", "pack_blocks", "scatter_min_received", "owned_to_front",
    "wide_add", "wide_total", "BFSLevelsProgram", "DirectionProgram",
    "DirState",
    "ConnectedComponentsProgram", "CCOutput", "SSSPProgram", "SSSPOutput",
    "MultiSourceBFSProgram", "MultiBFSOutput", "MultiBFSState",
    "cc_reference", "sssp_reference", "multi_bfs_reference",
    "k_hop_neighborhood", "PROGRAMS",
]
