"""Direction-optimised traversal as a first-class frontier-program mode
(DESIGN.md sec. 11; Beamer et al., Buluc & Madduri 1104.4518).

`DirectionProgram` wraps ANY `FrontierProgram` whose per-level step has a
bottom-up twin (`make_bottomup_step`): instead of scanning the frontier's
out-edges (CSC), every unvisited/active vertex scans its own in-edges (the
CSR twin) for a parent in the frontier -- the win on dense levels, where the
frontier touches most edges but almost every candidate is already settled.
The per-level choice runs INSIDE the compiled `lax.while_loop` as a
`lax.cond` on the global frontier total the engine already threads through
every step, so an adaptive search traces exactly once.

Heuristic (the alpha/beta hysteresis of Beamer's hybrid): go bottom-up when
the global frontier exceeds n/alpha, return top-down once it falls below
n/beta (beta > alpha, so the exit threshold sits under the entry threshold
and a frontier hovering at the boundary does not thrash).  `mode="bottomup"`
pins every level bottom-up instead (the benchmark sweep's fixed arm).

Bit-identity (the repo-wide contract): for BFS the bottom-up merge gives the
owner's own column block priority and otherwise takes the minimum sender
column, each contributing its minimum frontier-neighbour column -- exactly
the winner the top-down visited-suppression + canonical-ascending scan order
elects, so levels, preds and n_levels match top-down bit for bit at ANY
per-level direction mix.  For the value programs the pull scan proposes the
same relaxed-value multiset per row (CSR and CSC hold the same local edges),
and the min-monoid combine is order-independent.  `edges_scanned` is the
honest per-direction work (bottom-up scans unvisited rows' in-edges), so it
legitimately differs from top-down -- Graph500 TEPS stays input-edge-based.

The frontier travels to the bottom-up scan as the BITMAP the fold codecs
already know how to pack (`frontier.pack_bitmap`), row-gathered in a blocked
layout (`frontier.test_bit_blocks`); discoveries return to their owners
through the regular `FoldCodec.fold_values` exchange, so every codec works
both directions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.algos import program as PR
from repro.algos.program import FrontierProgram, I32_MAX
from repro.core import frontier as F


from repro.core.types import _dc


# ----------------------------------------------------------------------------
# State: the wrapped program's state plus the direction trace
# ----------------------------------------------------------------------------

@_dc
@dataclasses.dataclass
class DirState:
    """Wrapped program state + per-level direction bookkeeping."""
    inner: Any            # the wrapped program's state pytree
    dir: jax.Array        # () int32: 1 while running bottom-up (hysteresis)
    dirs: jax.Array       # (max_levels,) int32: -1 unused / 0 TD / 1 BU
    k: jax.Array          # () int32 0-based level counter


# ----------------------------------------------------------------------------
# Frontier bitmap + pull-scan building blocks
# ----------------------------------------------------------------------------

def frontier_words(topo, front, i):
    """Own (S,) frontier col ids -> row-gathered blocked bitmap (R*W,).

    Device (i, j)'s frontier entries always lie in [i*S, (i+1)*S) (ROW2COL
    of owned rows), so the own block packs to exactly S bits; the gather
    stacks grid-row r's words at block r -- matching `test_bit_blocks`'s
    blocked addressing of local col c (block c // S, bit c % S)."""
    S = topo.grid.S
    fvalid = front >= 0
    t = jnp.where(fvalid, front - i * S, S)
    own_mask = jnp.zeros((S,), bool).at[t].set(True, mode="drop")
    return topo.row_gather(F.pack_bitmap(own_mask)).reshape(-1)


def make_pull_scan(engine, row_off, col_idx, i, j, *, relax,
                   csr_edge_vals=None, row_mask_fn=None):
    """Bottom-up twin of the `scan_relax` prefix of a value-program step.

    Pulls: every (row-mask selected) local row scans its CSR in-edges; an
    edge from frontier col c proposes `relax(dense_payload[c], w)`, min-
    combined per row.  CSR and CSC hold the same local edge multiset and the
    combine is order-independent, so the candidate array is bit-identical to
    the top-down push scan on every row the mask keeps.

    row_mask_fn: optional state -> (n_rows_local,) bool; rows masked out
    contribute no edges to the workload (multi-source BFS skips visited
    rows -- their candidates are discarded downstream anyway).
    Returns scan(state) -> (cand (n_rows_local,), edges_scanned uint32).
    """
    topo, grid = engine.topo, engine.grid
    S = grid.S
    nrl, ncl = grid.n_rows_local, grid.n_cols_local
    chunk = engine.edge_chunk
    bu_fn = engine.value_bottomup_fn

    def scan(st):
        fvalid = st.front >= 0
        t = jnp.where(fvalid, st.front - i * S, S)
        own_pay = jnp.zeros((S,), jnp.int32).at[t].set(
            jnp.where(fvalid, st.payload, 0), mode="drop")
        all_words = frontier_words(topo, st.front, i)
        dense_pay = topo.row_gather(own_pay).reshape(ncl)
        deg = jnp.diff(row_off)
        if row_mask_fn is not None:
            deg = jnp.where(row_mask_fn(st), deg, 0)
        cumul = F.exclusive_cumsum(deg)
        total = cumul[nrl]

        def chunk_body(state):
            start, cand = state
            gids = start + jnp.arange(chunk, dtype=jnp.int32)
            if bu_fn is None:
                r, pay, addr, hit = F.reference_bottomup_values_chunk(
                    gids, cumul, total, row_off, col_idx, all_words,
                    dense_pay, block=S)
            else:
                r, pay, addr, hit = bu_fn(gids, cumul, total, row_off,
                                          col_idx, all_words, dense_pay,
                                          block=S)
            w = None if csr_edge_vals is None else csr_edge_vals[addr]
            val = jnp.where(hit, relax(pay, w), I32_MAX)
            cand = cand.at[jnp.where(hit, r, nrl)].min(val, mode="drop")
            return start + chunk, cand

        _, cand = jax.lax.while_loop(
            lambda s: s[0] < total, chunk_body,
            (jnp.int32(0), jnp.full((nrl,), I32_MAX, jnp.int32)))
        return cand, total.astype(jnp.uint32)

    return scan


# ----------------------------------------------------------------------------
# The BFS bottom-up step
# ----------------------------------------------------------------------------

def make_bfs_bottomup_step(engine, graph, extra, i, j):
    """One bottom-up BFS level, bit-identical to `bfs.topdown_step`.

    Every unvisited local row (the masked-degree workload) scans its CSR
    in-edges for a frontier parent; the per-row minimum frontier col is this
    device's proposal, value-folded to the owner; the owner merges with
    own-column priority then minimum sender -- exactly the parent top-down's
    visited suppression + min-slot dedup elects (see module docstring).
    """
    from repro.algos.bfs import canonical_front
    from repro.core.types import BFSState

    row_off, col_idx = extra[-2], extra[-1]
    topo, grid = engine.topo, engine.grid
    S, C = grid.S, grid.C
    nrl, ncl = grid.n_rows_local, grid.n_cols_local
    chunk = engine.edge_chunk
    fold_ops = engine.fold_ops
    bu_fn = engine.bottomup_fn
    snd = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[:, None], (C, S))

    def step(st: BFSState, prev_total):
        with jax.named_scope("repro/expand"):
            all_words = frontier_words(topo, st.front, i)
            # masked-degree workload: only unvisited rows' in-edges are
            # scanned (the visited cache is consistent across the
            # processor-row, so these are exactly the globally-undiscovered
            # rows of this block)
            deg = jnp.where(~st.visited, jnp.diff(row_off), 0)
            cumul = F.exclusive_cumsum(deg)
            total = cumul[nrl]

            def chunk_body(state):
                start, best = state
                gids = start + jnp.arange(chunk, dtype=jnp.int32)
                if bu_fn is None:
                    r, c, hit = F.reference_bottomup_chunk(
                        gids, cumul, total, row_off, col_idx, all_words,
                        block=S)
                else:
                    r, c, hit = bu_fn(gids, cumul, total, row_off, col_idx,
                                      all_words, block=S)
                best = best.at[jnp.where(hit, r, nrl)].min(
                    jnp.where(hit, c, I32_MAX), mode="drop")
                return start + chunk, best

            _, best = jax.lax.while_loop(
                lambda s: s[0] < total, chunk_body,
                (jnp.int32(0), jnp.full((nrl,), I32_MAX, jnp.int32)))

        found = best < I32_MAX                 # rows with a frontier parent
        visited1 = st.visited | found          # the send-suppression cache
        parent_g = jnp.where(found, j * ncl + best, I32_MAX)

        # value-fold (vertex, encoded parent) to the owners -- the same
        # exchange the value programs use, so every codec works here
        with jax.named_scope("repro/fold"):
            ids, cnt, vals = PR.pack_blocks(found, parent_g, grid,
                                            ops=fold_ops)
            ri, rc, rv = engine.codec.fold_values(ids, cnt, vals, topo=topo,
                                                  j=j)

        # dense (C, S) per-sender parent table of my owned block (dump col S
        # swallows the pads; senders propose each row at most once)
        tt = jnp.where(ri >= 0, ri - j * S, S)
        dense = jnp.full((C, S + 1), I32_MAX, jnp.int32).at[
            snd.reshape(-1), tt.reshape(-1)].min(
            jnp.where(ri >= 0, rv, I32_MAX).reshape(-1))[:, :S]
        has = dense < I32_MAX
        own_row = jnp.take(dense, j, axis=0)
        own_has = own_row < I32_MAX
        first_m = jnp.min(jnp.where(has, snd, C), axis=0)       # min sender
        sel = jnp.where(own_has, j, jnp.clip(first_m, 0, C - 1))
        parent = jnp.take_along_axis(dense, sel[None, :], axis=0)[0]
        newly = own_has | (first_m < C)

        rows_owned = j * S + jnp.arange(S, dtype=jnp.int32)
        vis_owned_prev = jax.lax.dynamic_slice_in_dim(st.visited, j * S, S)
        new = newly & ~vis_owned_prev
        tgt = jnp.where(new, rows_owned, nrl)
        visited2 = visited1.at[tgt].set(True, mode="drop")
        level2 = st.level.at[tgt].set(jnp.where(new, st.lvl, 0), mode="drop")
        pred2 = st.pred.at[tgt].set(jnp.where(new, parent, 0), mode="drop")

        lc = i * S + jnp.arange(S, dtype=jnp.int32)   # ROW2COL of owned rows
        nf, nc = F.append_padded(jnp.full((S,), -1, jnp.int32),
                                 jnp.int32(0), lc, new)
        nf, nc = canonical_front(nf, nc)
        st2 = BFSState(level=level2, pred=pred2, visited=visited2, front=nf,
                       front_cnt=nc, lvl=st.lvl + 1)
        folded = cnt.sum(dtype=jnp.int32)   # value fold: count-proportional
        ex_strat = engine.exchange
        aux = {"folded": folded,
               "wire": jnp.uint32(ex_strat.wire_bytes(
                   engine.codec.wire_bytes(grid), grid.C))
               + ex_strat.value_extra_bytes(cnt, j, grid.C),
               "msgs": jnp.int32(ex_strat.msgs_per_exchange(grid.C)),
               "dir": jnp.int32(1)}
        return st2, topo.psum_all(nc), total.astype(jnp.uint32), aux

    return step


# ----------------------------------------------------------------------------
# The wrapper program
# ----------------------------------------------------------------------------

class DirectionProgram(FrontierProgram):
    """Direction-optimised wrapper around any bottom-up-capable program.

    mode:  "adaptive" (alpha/beta hysteresis per level) or "bottomup"
           (every level bottom-up -- the benchmark sweep's fixed arm).
    alpha: enter bottom-up when the global frontier exceeds n / alpha.
    beta:  leave it once the frontier falls below n / beta (beta > alpha).

    Outputs are the wrapped program's, bit-identical to its pure top-down
    run, plus a `directions` trace ((max_levels,) int32 per search: -1
    unused level / 0 top-down / 1 bottom-up).
    """
    uses_bottomup = True

    def __init__(self, inner: FrontierProgram, *, mode: str = "adaptive",
                 alpha: int = 24, beta: int = 64):
        if mode not in ("adaptive", "bottomup"):
            raise ValueError(
                f"mode={mode!r}: expected 'adaptive' or 'bottomup'")
        self.inner = inner
        self.mode = mode
        self.alpha = int(alpha)
        self.beta = int(beta)
        self.name = "dir+" + inner.name
        self.codec_hint = inner.codec_hint
        # inner extras first, then the CSR twin (row_off, col_idx[, w_csr])
        self.n_extra = inner.n_extra + inner.n_csr_extra

    @property
    def key(self) -> tuple:
        return ("dir",) + tuple(self.inner.key) + (self.mode, self.alpha,
                                                   self.beta)

    def init(self, engine, graph, extra, arg, i, j):
        inner_st = self.inner.init(engine, graph,
                                   extra[:self.inner.n_extra], arg, i, j)
        dirs = jnp.full((engine.max_levels,), -1, jnp.int32)
        return DirState(inner=inner_st, dir=jnp.int32(0), dirs=dirs,
                        k=jnp.int32(0))

    def make_step(self, engine, graph, extra, i, j):
        td = self.inner.make_step(engine, graph,
                                  extra[:self.inner.n_extra], i, j)
        bu = self.inner.make_bottomup_step(engine, graph, extra, i, j)
        n = engine.grid.n
        L = engine.max_levels
        hi_thr = jnp.int32(n // self.alpha)   # enter bottom-up above this
        lo_thr = jnp.int32(n // self.beta)    # leave it below this

        def step(st: DirState, prev_total):
            if self.mode == "bottomup":
                use_bu = jnp.bool_(True)
                inner2, total, scanned, aux = bu(st.inner, prev_total)
            else:
                use_bu = jnp.where(st.dir == 1, prev_total > lo_thr,
                                   prev_total > hi_thr)
                # both branches return (state, total, scanned, aux) with
                # identical aux structure, so telemetry rides the cond
                inner2, total, scanned, aux = jax.lax.cond(
                    use_bu, lambda s: bu(s, prev_total),
                    lambda s: td(s, prev_total), st.inner)
            dirs = st.dirs.at[jnp.minimum(st.k, L - 1)].set(
                use_bu.astype(jnp.int32))
            st2 = DirState(inner=inner2, dir=use_bu.astype(jnp.int32),
                           dirs=dirs, k=st.k + 1)
            return st2, total, scanned, aux

        return step

    def front_count(self, st):
        return self.inner.front_count(st.inner)

    def keep_going(self, engine, st, total):
        return self.inner.keep_going(engine, st.inner, total)

    def init_total(self, engine, st):
        return self.inner.init_total(engine, st.inner)

    def finalize(self, engine, st, i, j):
        return tuple(self.inner.finalize(engine, st.inner, i, j)) + (st.dirs,)

    def out_specs(self, engine):
        return tuple(self.inner.out_specs(engine)) + (engine.topo.dev_spec,)

    def level_count(self, st):
        return self.inner.level_count(st.inner)

    def export_state(self, engine, st, n: int) -> dict:
        """Inner snapshot nested under "inner" + the direction bookkeeping
        (replicated across devices, so device (0, 0) is authoritative)."""
        import numpy as np

        snap = {"inner": self.inner.export_state(engine, st.inner, n),
                "dir": np.asarray(int(st.dir[0, 0]), np.int32),
                "dirs": np.asarray(st.dirs[0, 0], np.int32),
                "k": np.asarray(int(st.k[0, 0]), np.int32)}
        snap["levels_done"] = snap["inner"]["levels_done"]
        return snap

    def import_state(self, engine, snap: dict) -> DirState:
        import numpy as np

        grid = engine.grid
        R, C, L = grid.R, grid.C, engine.max_levels
        dirs = np.full((L,), -1, np.int32)
        src = np.asarray(snap["dirs"], np.int32)
        m = min(L, src.shape[0])
        dirs[:m] = src[:m]
        return DirState(
            inner=self.inner.import_state(engine, snap["inner"]),
            dir=np.full((R, C), int(snap["dir"]), np.int32),
            dirs=np.broadcast_to(dirs, (R, C, L)).copy(),
            k=np.full((R, C), int(snap["k"]), np.int32))

    def assemble(self, engine, outs, B):
        # engine appends (hi, lo) after finalize's outputs, so the direction
        # trace sits third from the end
        inner_outs = tuple(outs[:-3]) + tuple(outs[-2:])
        out = self.inner.assemble(engine, inner_outs, B)
        L = engine.max_levels
        dirs = outs[-3]
        # every device records the identical (psum-replicated) decision
        directions = dirs.reshape(-1, L)[0] if B is None \
            else dirs.reshape(-1, B, L)[0]
        return dataclasses.replace(out, directions=directions)
