"""Mid-traversal fault tolerance (DESIGN.md sec. 15).

The segmented engine loop (`FrontierEngine.ft_start/ft_segment/ft_finish`)
turns one compiled whole-search `lax.while_loop` into checkpoint-bounded
segments of at most `ckpt_every` levels, with the level-loop carry living on
the host side between segments.  This module is the driver around it:

  DeviceLossInjector   simulated device loss, fired when a segment crosses a
                       scheduled level (the container has no real ICI errors
                       to observe, so the failure signal is injected -- same
                       stance as `FaultInjector`).
  run_segmented        the segment loop: StepRunner-wrapped retry of each
                       segment (a failed segment re-executes from its input
                       carry -- `ft_segment` is pure, so rollback is free),
                       a checkpoint after every successful segment, and
                       escalation of exhausted retries to UnrecoverableLoss
                       carrying the last good snapshot.
  TraversalCheckpointer  CheckpointManager glue: persists `export_carry`
                       snapshots keyed by (graph, arg batch, config) so a
                       restarted or re-gridded process resumes the query.
  ElasticCoordinator   shrink-and-resume: on UnrecoverableLoss drop the
                       failed devices, pick the survivor grid
                       (`shrink_grid`), re-plan the graph onto the new mesh,
                       re-shard the saved carry and resume from the last
                       completed level.

Bit-identity contract: segment boundaries add no arithmetic, so segmented
outputs equal the single-while_loop program for every ckpt_every; a
same-grid resume is bit-identical including BFS predecessors; a shrunken
resume keeps levels / labels / distances / n_levels / edges_scanned
bit-identical (BFS predecessors are grid-dependent -- the bottom-up merge
gives the own column block priority -- so they re-validate by the Graph500
rules instead; see DESIGN.md sec. 15).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.runtime.fault import RetryPolicy, StepRunner


class DeviceLoss(RuntimeError):
    """A (simulated) device dropped out mid-segment."""

    def __init__(self, msg: str, devices: int = 1):
        super().__init__(msg)
        self.devices = int(devices)


class UnrecoverableLoss(RuntimeError):
    """Retries exhausted: the query cannot continue on this mesh.

    Carries everything elastic resume needs: the last good snapshot (the
    carry BEFORE the failed segment -- segments are atomic, so no partial
    level is ever visible), the level it covers, and the failed device
    count.
    """

    def __init__(self, snapshot: dict, level: int, failed: int = 1):
        super().__init__(
            f"device loss unrecoverable at level {level} "
            f"({failed} device(s) down)")
        self.snapshot = snapshot
        self.level = int(level)
        self.failed = int(failed)


class DeviceLossInjector:
    """Deterministic device-loss schedule for drills and tests.

    Fires a DeviceLoss when a segment CROSSES `at_level` -- i.e. the segment
    advanced the traversal from below `at_level` to at/past it -- which is
    exactly when a real mid-level ICI failure would surface from the
    collective.  `phase` labels where in the level the loss lands ("level" |
    "fold" -- the segment is atomic either way, so the label only names the
    drill); `transient` losses fire once and stay quiet (a retry succeeds),
    persistent ones fire on every crossing attempt until the optional
    `fires` budget runs out (retries exhaust -> UnrecoverableLoss).
    """

    def __init__(self, at_level: int, *, devices: int = 1,
                 phase: str = "level", transient: bool = False,
                 fires: int | None = None):
        if phase not in ("level", "fold"):
            raise ValueError(f"phase={phase!r}: expected 'level' or 'fold'")
        self.at_level = int(at_level)
        self.devices = int(devices)
        self.phase = phase
        if fires is None:
            fires = 1 if transient else None
        self.fires = fires          # None = every crossing attempt
        self.count = 0              # losses actually fired

    def check(self, lv_before: int, lv_after: int) -> None:
        if not (lv_before < self.at_level <= lv_after):
            return
        if self.fires is not None and self.count >= self.fires:
            return
        self.count += 1
        raise DeviceLoss(
            f"injected loss of {self.devices} device(s) crossing level "
            f"{self.at_level} ({self.phase})", devices=self.devices)


class TraversalCheckpointer:
    """Persist `export_carry` snapshots through a CheckpointManager.

    One directory per query identity: `query_key` (whatever JSON-able string
    the caller derives from graph + arg batch + config, EXCLUDING the grid
    and exchange strategy -- the snapshot is grid-canonical, so an elastic
    resume on a different grid must still match) is stamped into every
    manifest and validated on load, so a directory accidentally shared
    between queries fails loudly instead of resuming the wrong search.
    """

    def __init__(self, directory: str, query_key: str, *, keep: int = 3,
                 async_write: bool = True):
        from repro.ckpt.checkpoint import CheckpointManager
        self.manager = CheckpointManager(directory, keep=keep,
                                         async_write=async_write)
        self.query_key = str(query_key)

    def save(self, snapshot: dict) -> None:
        meta = snapshot["meta"]
        self.manager.save(int(meta["levels_done"]), snapshot["arrays"],
                          extra_meta={**meta, "query_key": self.query_key})

    def load(self) -> dict | None:
        """Latest snapshot, or None when the directory holds none."""
        arrays, manifest = self.manager.restore_tree()
        if arrays is None:
            return None
        meta = dict(manifest["meta"])
        saved_key = meta.pop("query_key", None)
        if saved_key != self.query_key:
            raise ValueError(
                f"checkpoint directory holds query_key={saved_key!r} but "
                f"this query is {self.query_key!r}; refusing to resume a "
                "different search")
        return {"arrays": arrays, "meta": meta}

    def join(self) -> None:
        self.manager.join()


def _fresh_stats() -> dict:
    return {"resumes": 0, "segments": 0, "retries": 0, "delays": [],
            "resumed_from_level": None,
            "time_to_first_resumed_level_s": None}


@dataclasses.dataclass
class RecoveryPlan:
    """Everything one fault-tolerant query threads through the driver.

    checkpointer: persists a snapshot after every successful segment and is
                  the default resume source.  None = in-memory only (the
                  UnrecoverableLoss snapshot still enables elastic resume).
    injector:     simulated loss schedule (None in production).
    policy:       per-segment retry/backoff (the jittered RetryPolicy).
    resume:       explicit snapshot to resume from (wins over the
                  checkpointer's latest).
    stats:        filled by `run_segmented`: resumes, segments, retries,
                  the jittered delays actually slept, resumed_from_level and
                  time_to_first_resumed_level_s (the recovery-latency figure
                  the drill harness records; never a gate).
    """
    checkpointer: TraversalCheckpointer | None = None
    injector: DeviceLossInjector | None = None
    policy: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    resume: dict | None = None
    stats: dict = dataclasses.field(default_factory=_fresh_stats)


def run_segmented(engine, graph, arg, *extra, B=None, n=None, plan=None):
    """Drive one query through the segmented engine loop.

    engine/graph/arg/extra mirror the engine's compiled entry points (arg is
    the device-placed root / roots batch / sources vector); B is the batch
    size (None = scalar program), n the raw vertex count exported into
    snapshots.  Returns the program's assembled output, bit-identical to the
    unsegmented run.  Raises UnrecoverableLoss when a segment exhausts its
    retries; the caller (ElasticCoordinator, the serve drain path) decides
    whether to shrink, re-queue, or give up.
    """
    plan = plan if plan is not None else RecoveryPlan()
    for k, v in _fresh_stats().items():
        plan.stats.setdefault(k, v)
    batched = B is not None
    injector = plan.injector

    carry = None
    if plan.resume is not None:
        carry = engine.import_carry(plan.resume, B=B)
    elif plan.checkpointer is not None:
        snap = plan.checkpointer.load()
        if snap is not None:
            carry = engine.import_carry(snap, B=B)
    resumed = carry is not None
    if carry is None:
        carry = engine.ft_start(graph, arg, *extra, batched=batched)
    if resumed:
        plan.stats["resumes"] += 1
        plan.stats["resumed_from_level"] = engine.ft_levels_done(carry)
    # recovery latency reference: the coordinator stamps the moment of loss
    # (so re-plan + recompile count); a plain checkpointer resume counts
    # from here
    t_ref = plan.stats.pop("_t_loss", None)
    if t_ref is None:
        t_ref = time.perf_counter()
    awaiting_first = resumed

    def step_fn(c, _batch):
        lv0 = engine.ft_levels_done(c)
        c2 = engine.ft_segment(graph, c, *extra, batched=batched)
        lv1 = engine.ft_levels_done(c2)
        if injector is not None:
            # inside the step so a retry re-checks the same crossing; the
            # input carry is untouched by ft_segment, so the rollback to
            # the segment boundary is implicit
            injector.check(lv0, lv1)
        return c2, lv1

    runner = StepRunner(step_fn, policy=plan.policy)
    step_no = 0
    try:
        while engine.ft_active(carry):
            try:
                carry, _ = runner.run(carry, [None], start_step=step_no)
            except DeviceLoss as e:
                snap = engine.export_carry(carry, n=n, B=B)
                if plan.checkpointer is not None:
                    # make the last snapshot durable BEFORE handing off --
                    # the resuming process may open the directory instantly
                    plan.checkpointer.join()
                raise UnrecoverableLoss(snap, engine.ft_levels_done(carry),
                                        failed=e.devices) from e
            step_no += 1
            plan.stats["segments"] += 1
            if awaiting_first:
                plan.stats["time_to_first_resumed_level_s"] = (
                    time.perf_counter() - t_ref)
                awaiting_first = False
            if plan.checkpointer is not None:
                plan.checkpointer.save(engine.export_carry(carry, n=n, B=B))
    finally:
        plan.stats["retries"] += runner.retries
        plan.stats["delays"].extend(runner.delays)
    if plan.checkpointer is not None:
        plan.checkpointer.join()
    return engine.ft_finish(carry, B=B)


class ElasticCoordinator:
    """Shrink-and-resume driver: re-plan onto the survivors and continue.

    Owns the host edge list (re-partitioning needs it) and the query
    config; each UnrecoverableLoss drops the failed devices from the pool,
    picks the survivor grid via `shrink_grid`, re-plans the graph onto a
    sub-mesh and resumes the query from the loss snapshot.  `max_shrinks`
    bounds the repeated-loss drill.

    The session/graph are rebuilt per shrink (grids are baked into the
    compiled programs), so `run` takes the QUERY, not a session: the method
    name plus its argument.
    """

    def __init__(self, edges, config, *, weights=None, n=None,
                 max_shrinks: int = 2):
        import numpy as np
        self.edges = np.asarray(edges)
        self.config = config
        self.weights = weights
        self.n = n
        self.max_shrinks = int(max_shrinks)
        self.shrinks = 0            # shrinks performed by the last run()
        self.grids = []             # grid trajectory of the last run()

    def _plan(self, config):
        import jax

        from repro.api.session import DistGraph
        from repro.dist.compat import make_mesh

        R, C = config.grid
        mesh = make_mesh((R, C), ("r", "c"),
                         devices=jax.devices()[:R * C])
        graph = DistGraph.from_edges(self.edges, config, mesh=mesh,
                                     n=self.n, weights=self.weights)
        try:
            return graph.session()
        except ValueError:
            # the planned exchange strategy (e.g. butterfly) may not fit
            # the survivor grid's column count -- fall back to flat, which
            # is valid everywhere and bit-identical
            graph.config = dataclasses.replace(config, exchange="flat")
            return graph.session()

    def run(self, method: str, arg=None, plan: RecoveryPlan | None = None,
            **kw) -> Any:
        """Run `session.<method>(arg, recovery=plan)` with elastic retries.

        On UnrecoverableLoss: accumulate the failed devices, shrink the
        grid, re-plan, and resume from the loss snapshot.  Raises the final
        UnrecoverableLoss once `max_shrinks` is exhausted or the survivor
        set is empty.
        """
        from repro.ckpt.elastic import shrink_grid

        plan = plan if plan is not None else RecoveryPlan()
        config = self.config
        R0, C0 = config.grid
        failed_total = 0
        self.shrinks = 0
        self.grids = [tuple(config.grid)]
        while True:
            sess = self._plan(config)
            call = getattr(sess, method)
            args = () if arg is None else (arg,)
            try:
                return call(*args, recovery=plan, **kw)
            except UnrecoverableLoss as e:
                if self.shrinks >= self.max_shrinks:
                    raise
                failed_total += max(1, e.failed)
                plan.stats["_t_loss"] = time.perf_counter()
                R, C = shrink_grid(R0, C0, failed_total)  # ValueError when
                #                                           nobody survives
                config = dataclasses.replace(config, grid=(R, C))
                plan.resume = e.snapshot
                self.shrinks += 1
                self.grids.append((R, C))
