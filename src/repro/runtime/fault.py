"""Fault tolerance / straggler harness (DESIGN.md sec. 8).

On a real cluster the failure signals come from the runtime (XLA ICI errors,
host heartbeats); in this container they are injected (FaultInjector) so the
recovery logic is unit-testable:

  StepRunner: wraps a step fn with (1) retry w/ exponential backoff,
  (2) checkpoint-restore on unrecoverable error, (3) straggler statistics.

  StragglerWatchdog: per-step latency EWMA + p99 tracking; steps slower than
  `factor` x p99 are flagged (on a real deployment: drain + re-slice; the
  level-batching in the BFS while_loop amortises the sync points).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass


class FaultInjector:
    """Deterministic failure schedule: fail step k with exception cls."""

    def __init__(self, schedule: dict[int, type] | None = None):
        self.schedule = dict(schedule or {})
        self.calls = 0

    def check(self, step: int):
        if step in self.schedule:
            exc = self.schedule.pop(step)
            self.calls += 1
            raise exc(f"injected failure at step {step}")


@dataclass
class RetryPolicy:
    """Exponential backoff with deterministic, seedable jitter.

    `jitter_s` spreads the attempt delays uniformly over [0, jitter_s) so
    concurrent serve retries hitting the same execution lock do not
    stampede in lockstep; the offset is a pure function of
    (seed, step, attempt), so two runs with one seed sleep identically and
    distinct seeds (one per worker) de-correlate.
    """
    max_retries: int = 3
    backoff_s: float = 0.01
    backoff_mult: float = 2.0
    jitter_s: float = 0.0
    seed: int = 0

    def delay_for(self, step: int, attempt: int) -> float:
        """Backoff before retrying `step` after failed attempt `attempt`
        (0-based): backoff_s * mult^attempt plus the deterministic jitter."""
        d = self.backoff_s * self.backoff_mult ** attempt
        if self.jitter_s:
            # integer mix (no PYTHONHASHSEED dependence), so this is
            # reproducible across processes
            mix = (self.seed * 1_000_003 + step) * 1_000_003 + attempt
            d += self.jitter_s * random.Random(mix).random()
        return d


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 256):
        self.factor = factor
        self.lat = []
        self.window = window
        self.flagged = []

    def record(self, step: int, seconds: float):
        flagged = False
        if len(self.lat) >= 16:
            srt = sorted(self.lat)  # p99 of the PRIOR window
            p99 = srt[min(len(srt) - 1, int(0.99 * len(srt)))]
            if seconds > self.factor * p99 and seconds > 1e-4:
                self.flagged.append(step)
                flagged = True
        self.lat.append(seconds)
        self.lat = self.lat[-self.window:]
        return flagged


class StepRunner:
    """run(state, batch) -> state with retry/restore semantics.

    Accounting is label-aware (DESIGN.md sec. 13): `run(..., labels=)`
    attributes every retry / straggler flag of those batches to each label
    (the serve layer passes the batch's tenants), accumulated in
    `retries_by` / `straggler_by` and mirrored to the optional `on_retry` /
    `on_straggler` callbacks (what the GraphServer wires into its metrics
    registry + event log).  `reset_stats()` zeroes everything, so a load
    generator's per-point windows (and a fresh server over a long-lived
    graph) start clean.
    """

    def __init__(self, step_fn, *, policy: RetryPolicy = RetryPolicy(),
                 ckpt=None, ckpt_every: int = 50,
                 injector: FaultInjector | None = None,
                 watchdog: StragglerWatchdog | None = None,
                 on_retry=None, on_straggler=None):
        self.step_fn = step_fn
        self.policy = policy
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.injector = injector
        self.watchdog = watchdog or StragglerWatchdog()
        self.on_retry = on_retry        # called (labels) per retry
        self.on_straggler = on_straggler  # called (labels, seconds) per flag
        self.restores = 0
        self.retries = 0
        self.retries_by: dict = {}      # label -> retries attributed
        self.straggler_by: dict = {}    # label -> straggler flags attributed
        self.delays: list = []          # backoff actually slept, per retry

    def reset_stats(self) -> None:
        """Zero the retry/restore/straggler accounting (watchdog latency
        window included), leaving policy and hooks in place."""
        self.restores = 0
        self.retries = 0
        self.retries_by = {}
        self.straggler_by = {}
        self.delays = []
        self.watchdog.lat = []
        self.watchdog.flagged = []

    def _count_retry(self, labels):
        self.retries += 1
        for lab in labels:
            self.retries_by[lab] = self.retries_by.get(lab, 0) + 1
        if self.on_retry is not None:
            self.on_retry(labels)

    def _count_straggler(self, labels, seconds):
        for lab in labels:
            self.straggler_by[lab] = self.straggler_by.get(lab, 0) + 1
        if self.on_straggler is not None:
            self.on_straggler(labels, seconds)

    def run(self, state, batches, *, start_step: int = 0, labels=()):
        step = start_step
        infos = []
        for batch in batches:
            t0 = time.perf_counter()
            for attempt in range(self.policy.max_retries + 1):
                try:
                    if self.injector is not None:
                        self.injector.check(step)
                    state, info = self.step_fn(state, batch)
                    break
                except Exception:
                    if attempt == self.policy.max_retries:
                        # unrecoverable: restore from checkpoint if we can
                        if self.ckpt is not None:
                            restored, mani = self.ckpt.restore(state)
                            if restored is not None:
                                self.restores += 1
                                state = restored
                                break
                        raise
                    self._count_retry(labels)
                    delay = self.policy.delay_for(step, attempt)
                    self.delays.append(delay)
                    time.sleep(delay)
            else:
                pass
            seconds = time.perf_counter() - t0
            if self.watchdog.record(step, seconds):
                self._count_straggler(labels, seconds)
            if self.ckpt is not None and step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
            infos.append(info if "info" in dir() else None)
            step += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        return state, infos
