from repro.runtime.fault import RetryPolicy, StepRunner, StragglerWatchdog, \
    FaultInjector
from repro.runtime.recovery import (DeviceLoss, DeviceLossInjector,
                                    ElasticCoordinator, RecoveryPlan,
                                    TraversalCheckpointer, UnrecoverableLoss,
                                    run_segmented)
