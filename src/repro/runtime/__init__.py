from repro.runtime.fault import RetryPolicy, StepRunner, StragglerWatchdog, \
    FaultInjector
