"""Decoder-only LM family: dense (glm4, gemma2, h2o-danube) and MoE
(kimi-k2, qwen2-moe) variants from one implementation.

Pure-JAX (no flax): params are pytrees of jnp arrays; `param_shardings`
returns a matching pytree of PartitionSpec for GSPMD.  Layers are stacked and
scanned (compile time stays flat in depth); per-layer attention windows ride
along as scanned xs (gemma2's local/global alternation, danube's SWA).

Sharding scheme (DESIGN.md sec. 5): batch on ("pod","data"); tensor-parallel
on "model" (attention heads / d_ff / vocab); MoE experts on "model" (EP) with
the all_to_all dispatch implemented in models/moe.py on top of the same
bucket-and-fold machinery as the BFS.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_lib


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    # EP divisibility: expert arrays are allocated at this count (phantom
    # experts are masked out of routing) -- e.g. qwen2-moe 60 -> 64 on a
    # 16-wide model axis.  None = n_experts.
    n_experts_padded: int | None = None

    @property
    def e_alloc(self) -> int:
        return self.n_experts_padded or self.n_experts


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 1e4
    rope_fraction: float = 1.0          # glm4 uses 0.5 (partial rotary)
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    logit_softcap: Optional[float] = None  # gemma2: 30.0
    query_scale: Optional[float] = None    # gemma2: 1/sqrt(256)
    window_pattern: tuple = (0,)        # cycled over layers; 0 = global attn
    post_norms: bool = False            # gemma2 sandwich norms
    tie_embeddings: bool = False
    moe: Optional[MoESettings] = None
    dtype: Any = jnp.bfloat16
    remat: bool = True

    @property
    def windows(self):
        pat = self.window_pattern
        return tuple(pat[l % len(pat)] for l in range(self.n_layers))

    def param_count(self) -> int:
        c = self.vocab * self.d_model
        if not self.tie_embeddings:
            c += self.vocab * self.d_model
        per = (self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
               + self.n_heads * self.d_head * self.d_model)
        if self.moe:
            per += self.d_model * self.moe.n_experts
            per += 3 * self.moe.n_experts * self.d_model * self.moe.d_ff_expert
            per += 3 * self.d_model * self.moe.d_ff_expert * self.moe.n_shared
        else:
            per += 3 * self.d_model * self.d_ff
        return c + self.n_layers * per

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        per_active = (self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                      + self.n_heads * self.d_head * self.d_model
                      + self.d_model * self.moe.n_experts
                      + 3 * self.d_model * self.moe.d_ff_expert
                      * (self.moe.top_k + self.moe.n_shared))
        return 2 * self.vocab * self.d_model + self.n_layers * per_active


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> dict:
    L, d, H, KV, dh = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.d_head)
    ks = jax.random.split(key, 12)
    dt = cfg.dtype

    def nrm(k, *shape):
        scale = 1.0 / jnp.sqrt(jnp.asarray(shape[-2] if len(shape) > 1 else d,
                                           jnp.float32))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    p = {
        "embed": nrm(ks[0], cfg.vocab, d),
        "ln_f": jnp.ones((d,), dt),
        "attn": {
            "ln": jnp.ones((L, d), dt),
            "wq": nrm(ks[1], L, d, H * dh),
            "wk": nrm(ks[2], L, d, KV * dh),
            "wv": nrm(ks[3], L, d, KV * dh),
            "wo": nrm(ks[4], L, H * dh, d),
        },
    }
    if cfg.post_norms:
        p["attn"]["ln_post"] = jnp.ones((L, d), dt)
    if not cfg.tie_embeddings:
        p["unembed"] = nrm(ks[5], d, cfg.vocab)
    if cfg.moe:
        m = cfg.moe
        p["mlp"] = {
            "ln": jnp.ones((L, d), dt),
            "router": nrm(ks[6], L, d, m.e_alloc).astype(jnp.float32),
            "w1": nrm(ks[7], L, m.e_alloc, d, m.d_ff_expert),
            "w3": nrm(ks[8], L, m.e_alloc, d, m.d_ff_expert),
            "w2": nrm(ks[9], L, m.e_alloc, m.d_ff_expert, d),
        }
        if m.n_shared:
            ffs = m.n_shared * m.d_ff_expert
            p["mlp"]["sw1"] = nrm(ks[10], L, d, ffs)
            p["mlp"]["sw3"] = nrm(ks[10], L, d, ffs)
            p["mlp"]["sw2"] = nrm(ks[11], L, ffs, d)
    else:
        p["mlp"] = {
            "ln": jnp.ones((L, d), dt),
            "w1": nrm(ks[6], L, d, cfg.d_ff),
            "w3": nrm(ks[7], L, d, cfg.d_ff),
            "w2": nrm(ks[8], L, cfg.d_ff, d),
        }
    if cfg.post_norms:
        p["mlp"]["ln_post"] = jnp.ones((L, d), dt)
    return p


def param_shardings(cfg: LMConfig, *, data_axes=("data",), model_axis="model",
                    pod_axis=None) -> dict:
    """PartitionSpec pytree matching init_params.

    Weights: Megatron TP over `model_axis` (heads / ff / experts / vocab);
    ZeRO-style optimizer sharding adds `data_axes` on the largest dim where
    divisible (applied in repro/train).  Embedding is sharded on d_model so
    token lookup stays gather-free (DESIGN.md sec. 5).
    """
    M = model_axis
    s = {
        "embed": P(None, M),
        "ln_f": P(None),
        "attn": {
            "ln": P(None, None),
            "wq": P(None, None, M),
            "wk": P(None, None, M),
            "wv": P(None, None, M),
            "wo": P(None, M, None),
        },
    }
    if cfg.post_norms:
        s["attn"]["ln_post"] = P(None, None)
    if not cfg.tie_embeddings:
        s["unembed"] = P(None, None, ) if cfg.vocab % 8 else P(None, M)
        s["unembed"] = P(None, M)
    if cfg.moe:
        s["mlp"] = {
            "ln": P(None, None),
            "router": P(None, None, None),
            "w1": P(None, M, None, None),
            "w3": P(None, M, None, None),
            "w2": P(None, M, None, None),
        }
        if cfg.moe.n_shared:
            s["mlp"]["sw1"] = P(None, None, M)
            s["mlp"]["sw3"] = P(None, None, M)
            s["mlp"]["sw2"] = P(None, M, None)
    else:
        s["mlp"] = {
            "ln": P(None, None),
            "w1": P(None, None, M),
            "w3": P(None, None, M),
            "w2": P(None, M, None),
        }
    if cfg.post_norms:
        s["mlp"]["ln_post"] = P(None, None)
    return s


# ----------------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * scale


def rope(x, positions, theta, fraction):
    """x: (..., T, n, dh); positions: (..., T)."""
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:rot]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos,
                          x[..., rot:]], axis=-1)
    return xr.astype(x.dtype)


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def attention(q, k, v, q_pos, k_pos, *, window, softcap, scale, k_valid=None):
    """q: (B, Tq, H, dh); k/v: (B, Tk, KV, dh).  Causal + optional sliding
    window (window > 0) + optional logit softcap."""
    B, Tq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Tq, KV, G, dh)
    logits = jnp.einsum("btkgd,bskd->bktgs", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    causal = k_pos[:, None, :] <= q_pos[:, :, None]               # (B,Tq,Tk)
    if window is not None:
        inwin = jnp.where(window > 0,
                          q_pos[:, :, None] - k_pos[:, None, :] < window,
                          True)
        causal = causal & inwin
    if k_valid is not None:
        causal = causal & k_valid[:, None, :]
    mask = causal[:, None, :, None, :]                            # b1t1s
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bktgs,bskd->btkgd", w.astype(v.dtype), v)
    return o.reshape(B, Tq, H * dh)


def swiglu(x, w1, w3, w2):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


# ----------------------------------------------------------------------------
# forward passes
# ----------------------------------------------------------------------------

def _layer(cfg: LMConfig, x, layer_params, window, positions, mesh=None):
    ap, mp = layer_params["attn"], layer_params["mlp"]
    B, T, d = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rmsnorm(x, ap["ln"])
    q = (h @ ap["wq"]).reshape(B, T, H, dh)
    k = (h @ ap["wk"]).reshape(B, T, KV, dh)
    v = (h @ ap["wv"]).reshape(B, T, KV, dh)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    scale = cfg.query_scale if cfg.query_scale else dh ** -0.5
    o = attention(q, k, v, positions, positions, window=window,
                  softcap=cfg.attn_softcap, scale=scale)
    o = o @ ap["wo"]
    if cfg.post_norms:
        o = rmsnorm(o, ap["ln_post"])
    x = x + o
    h = rmsnorm(x, mp["ln"])
    if cfg.moe:
        y, aux = moe_lib.moe_apply(h.reshape(B * T, d), mp, cfg.moe, mesh=mesh)
        y = y.reshape(B, T, d)
        if cfg.moe.n_shared:
            y = y + swiglu(h, mp["sw1"], mp["sw3"], mp["sw2"])
    else:
        y, aux = swiglu(h, mp["w1"], mp["w3"], mp["w2"]), 0.0
    if cfg.post_norms:
        y = rmsnorm(y, mp["ln_post"])
    return x + y, aux


def forward(cfg: LMConfig, params, tokens, mesh=None):
    """tokens (B, T) -> logits (B, T, V)."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype) if cfg.tie_embeddings else x
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    windows = jnp.asarray(cfg.windows, jnp.int32)

    stacked = {"attn": {k: v for k, v in params["attn"].items()},
               "mlp": {k: v for k, v in params["mlp"].items()}}

    def body(x, xs):
        lp, w = xs
        fn = functools.partial(_layer, cfg, mesh=mesh)
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        x, aux = fn(x, lp, w, positions)
        return x, aux

    x, auxes = jax.lax.scan(body, x, (stacked, windows))
    x = rmsnorm(x, params["ln_f"])
    un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ un.astype(cfg.dtype)).astype(jnp.float32)
    logits = _softcap(logits, cfg.logit_softcap)
    return logits, jnp.sum(auxes)


def loss_fn(cfg: LMConfig, params, tokens, labels, mesh=None):
    logits, aux = forward(cfg, params, tokens, mesh=mesh)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.sum(jnp.where(mask, lse - ll, 0.0)) / jnp.maximum(
        jnp.sum(mask), 1)
    return nll + 1e-2 * aux


# ----------------------------------------------------------------------------
# decode (serving)
# ----------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int):
    """KV cache pytree.  For pure-SWA models (all windows > 0) the cache is a
    ring buffer of the window size -- this is what makes 500k-token decode
    feasible (DESIGN.md sec. 6)."""
    win = max(cfg.windows) if all(w > 0 for w in cfg.windows) else 0
    W = min(max_seq, win) if win else max_seq
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((L, batch, W, KV, dh), cfg.dtype),
        "v": jnp.zeros((L, batch, W, KV, dh), cfg.dtype),
        "pos": jnp.zeros((L, batch, W), jnp.int32) - 1,
    }


def decode_step(cfg: LMConfig, params, cache, tokens, pos, mesh=None):
    """One greedy decode step.  tokens (B,), pos scalar int32 (current index).
    Returns (next_tokens (B,), new_cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)
    windows = jnp.asarray(cfg.windows, jnp.int32)
    W = cache["k"].shape[2]
    slot = pos % W

    stacked = {"attn": params["attn"], "mlp": params["mlp"]}

    def body(x, xs):
        lp, w, kc, vc, pc = xs
        ap, mp = lp["attn"], lp["mlp"]
        H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        h = rmsnorm(x, ap["ln"])
        q = rope((h @ ap["wq"]).reshape(B, 1, H, dh), positions,
                 cfg.rope_theta, cfg.rope_fraction)
        k = rope((h @ ap["wk"]).reshape(B, 1, KV, dh), positions,
                 cfg.rope_theta, cfg.rope_fraction)
        v = (h @ ap["wv"]).reshape(B, 1, KV, dh)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        pc = jax.lax.dynamic_update_slice_in_dim(
            pc, positions[:, :1], slot, axis=1)
        scale = cfg.query_scale if cfg.query_scale else dh ** -0.5
        o = attention(q, kc, vc, positions, pc, window=w,
                      softcap=cfg.attn_softcap, scale=scale,
                      k_valid=pc >= 0)
        o = o @ ap["wo"]
        if cfg.post_norms:
            o = rmsnorm(o, ap["ln_post"])
        x = x + o
        h = rmsnorm(x, mp["ln"])
        if cfg.moe:
            y, _ = moe_lib.moe_apply(h.reshape(B, -1), mp, cfg.moe, mesh=mesh)
            y = y.reshape(B, 1, -1)
            if cfg.moe.n_shared:
                y = y + swiglu(h, mp["sw1"], mp["sw3"], mp["sw2"])
        else:
            y = swiglu(h, mp["w1"], mp["w3"], mp["w2"])
        if cfg.post_norms:
            y = rmsnorm(y, mp["ln_post"])
        return x + y, (kc, vc, pc)

    x, (kc, vc, pc) = jax.lax.scan(
        body, x, (stacked, windows, cache["k"], cache["v"], cache["pos"]))
    x = rmsnorm(x, params["ln_f"])
    un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = _softcap((x @ un.astype(cfg.dtype)).astype(jnp.float32),
                      cfg.logit_softcap)
    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    return nxt, {"k": kc, "v": vc, "pos": pc}
