"""Expert-parallel MoE layer (kimi-k2, qwen2-moe).

The dispatch is literally the paper's fold exchange applied to tokens
instead of vertices: bucket each (token, expert) copy by OWNER shard
(repro.core.frontier.bucket_append -- the same sort-based compaction that
replaces atomicInc in the BFS), all_to_all the buckets along the expert
axis, run the local grouped-GEMMs, and all_to_all back.

Capacity-based (GShard-style): copies beyond a bucket's capacity are dropped
and contribute zero output.  Router is fp32; aux load-balance loss follows
Switch (E * sum(f_e * p_e)).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.frontier import bucket_append
from repro.dist.compat import shard_map


@dataclasses.dataclass(frozen=True)
class MoEShard:
    """How to run the MoE: tokens sharded over token_axes, experts over
    expert_axis (EP), optional FSDP of expert weights over fsdp_axis, and
    optional int8 dispatch quantisation.  None mesh = reference path."""
    mesh: object = None
    token_axes: tuple = ()
    expert_axis: Optional[str] = None
    fsdp_axis: Optional[str] = None
    quant_dispatch: bool = False

    @property
    def ep(self) -> int:
        if self.mesh is None or self.expert_axis is None:
            return 1
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape))[self.expert_axis]


def _route(x, router_w, top_k, n_real=None):
    logits = x.astype(jnp.float32) @ router_w
    if n_real is not None and n_real < router_w.shape[-1]:
        # phantom padding experts (EP divisibility) never receive traffic
        mask = jnp.arange(router_w.shape[-1]) < n_real
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: fraction routed vs mean prob, per expert
    E = router_w.shape[-1]
    f = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    f = f / jnp.maximum(topi.size, 1)
    aux = E * jnp.sum(f * probs.mean(0))
    return topi.astype(jnp.int32), topv, aux


def _grouped_ffn(buf, mask, w1, w3, w2):
    """buf: (E_loc, cap_e, d); SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("ekd,edf->ekf", buf, w1)) * \
        jnp.einsum("ekd,edf->ekf", buf, w3)
    y = jnp.einsum("ekf,efd->ekd", h, w2)
    return jnp.where(mask[..., None], y, 0)


def _moe_local(x, router_w, w1, w3, w2, *, top_k: int, ep: int,
               capacity_factor: float, expert_axis=None, cap_e_mult: int = 4,
               n_real=None, quant_dispatch: bool = False, fsdp_axis=None):
    """Device-local body (EP=1 degenerates to the reference path)."""
    if fsdp_axis is not None:
        # ZeRO-3/FSDP: expert weights live sharded on d_model across the
        # data axis; gather just-in-time (freed after the layer)
        w1 = jax.lax.all_gather(w1, fsdp_axis, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp_axis, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axis, axis=2, tiled=True)
    N, d = x.shape
    E_loc = w1.shape[0]
    topi, topv, aux = _route(x, router_w, top_k, n_real)

    copies = N * top_k
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), top_k)
    e_flat = topi.reshape(-1)
    w_flat = topv.reshape(-1).astype(x.dtype)

    cap_s = max(8, int(math.ceil(copies / ep * capacity_factor)))
    peer = e_flat // E_loc
    idx0 = jnp.arange(copies, dtype=jnp.int32)
    dst = jnp.full((ep, cap_s), -1, jnp.int32)
    dst, _ = bucket_append(dst, jnp.zeros((ep,), jnp.int32), idx0, peer,
                           jnp.ones((copies,), bool), ep)
    s_valid = dst >= 0
    dsafe = jnp.where(s_valid, dst, 0)
    send_x = jnp.where(s_valid[..., None], x[tok[dsafe]], 0)
    send_e = jnp.where(s_valid, e_flat[dsafe] % E_loc, 0)

    if ep > 1:
        if quant_dispatch:
            # int8 a2a with per-copy scales: halves dispatch wire vs bf16
            sc = jnp.max(jnp.abs(send_x), axis=-1, keepdims=True) / 127.0
            q = jnp.round(send_x / jnp.maximum(sc, 1e-9)).astype(jnp.int8)
            q = jax.lax.all_to_all(q, expert_axis, 0, 0).reshape(ep, cap_s, d)
            sc = jax.lax.all_to_all(sc, expert_axis, 0, 0).reshape(ep, cap_s, 1)
            recv_x = (q.astype(x.dtype) * sc.astype(x.dtype))
        else:
            recv_x = jax.lax.all_to_all(send_x, expert_axis, 0, 0).reshape(
                ep, cap_s, d)
        recv_e = jax.lax.all_to_all(send_e, expert_axis, 0, 0).reshape(ep, cap_s)
        recv_v = jax.lax.all_to_all(s_valid, expert_axis, 0, 0).reshape(ep, cap_s)
    else:
        recv_x, recv_e, recv_v = send_x, send_e, s_valid

    # group received copies by local expert
    flat = ep * cap_s
    cap_e = min(flat, max(8, int(math.ceil(flat / E_loc)) * cap_e_mult))
    gidx = jnp.full((E_loc, cap_e), -1, jnp.int32)
    gidx, _ = bucket_append(gidx, jnp.zeros((E_loc,), jnp.int32),
                            jnp.arange(flat, dtype=jnp.int32),
                            recv_e.reshape(-1), recv_v.reshape(-1), E_loc)
    g_valid = gidx >= 0
    gsafe = jnp.where(g_valid, gidx, 0)
    buf = jnp.where(g_valid[..., None], recv_x.reshape(flat, d)[gsafe], 0)

    y = _grouped_ffn(buf, g_valid, w1, w3, w2)

    y_recv = jnp.zeros((flat, d), x.dtype).at[
        jnp.where(g_valid, gidx, flat).reshape(-1)].add(
            y.reshape(-1, d), mode="drop")
    y_recv = y_recv.reshape(ep, cap_s, d)

    if ep > 1:
        if quant_dispatch:
            sc = jnp.max(jnp.abs(y_recv), axis=-1, keepdims=True) / 127.0
            q = jnp.round(y_recv / jnp.maximum(sc, 1e-9)).astype(jnp.int8)
            q = jax.lax.all_to_all(q, expert_axis, 0, 0).reshape(ep, cap_s, d)
            sc = jax.lax.all_to_all(sc, expert_axis, 0, 0).reshape(ep, cap_s, 1)
            y_send = q.astype(x.dtype) * sc.astype(x.dtype)
        else:
            y_send = jax.lax.all_to_all(y_recv, expert_axis, 0, 0).reshape(
                ep, cap_s, d)
    else:
        y_send = y_recv

    contrib = jnp.where(s_valid[..., None],
                        y_send * w_flat[dsafe][..., None], 0)
    out = jnp.zeros((N, d), x.dtype).at[
        jnp.where(s_valid, tok[dsafe], N).reshape(-1)].add(
            contrib.reshape(-1, d), mode="drop")
    return out, aux


def moe_apply(x, mp, cfg, mesh: Optional[MoEShard] = None):
    """x: (N, d) global token activations.  Returns (y (N, d), aux scalar).

    mp holds router/w1/w3/w2 (global, sharded by param_shardings);
    mesh (MoEShard) selects the shard_map EP path.
    """
    if mesh is None or mesh.mesh is None or mesh.ep == 1:
        return _moe_local(x, mp["router"], mp["w1"], mp["w3"], mp["w2"],
                          top_k=cfg.top_k, ep=1,
                          capacity_factor=cfg.capacity_factor,
                          cap_e_mult=getattr(cfg, "cap_e_mult", 4),
                          n_real=cfg.n_experts)

    ep = mesh.ep
    n_shards = 1
    for a in mesh.token_axes:
        n_shards *= dict(zip(mesh.mesh.axis_names, mesh.mesh.devices.shape))[a]
    N, d = x.shape
    pad = (-N) % n_shards
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])

    def body(xl, router_w, w1, w3, w2):
        y, aux = _moe_local(xl, router_w, w1, w3, w2, top_k=cfg.top_k, ep=ep,
                            capacity_factor=cfg.capacity_factor,
                            expert_axis=mesh.expert_axis,
                            cap_e_mult=getattr(cfg, "cap_e_mult", 4),
                            n_real=cfg.n_experts,
                            quant_dispatch=mesh.quant_dispatch,
                            fsdp_axis=mesh.fsdp_axis)
        axes = tuple(dict.fromkeys(mesh.token_axes + (mesh.expert_axis,)))
        return y, jax.lax.pmean(aux, axes)

    tk = P(mesh.token_axes)
    fa = mesh.fsdp_axis
    w13 = P(mesh.expert_axis, fa, None)
    w2s = P(mesh.expert_axis, None, fa)
    # check_vma=True: the replication checker is what makes the transpose
    # (backward pass) insert the psums for the replicated router and the
    # (pod, data)-replicated expert weights.
    y, aux = shard_map(
        body, mesh=mesh.mesh,
        in_specs=(tk, P(None, None), w13, w13, w2s),
        out_specs=(tk, P()), check_vma=True)(
            x, mp["router"], mp["w1"], mp["w3"], mp["w2"])
    return y[:N], aux
