"""GraphSAGE [arXiv:1706.02216] -- mean aggregator, full-graph and sampled
(block) modes.  Full-graph aggregation can run distributed on the paper's 2D
expand/fold pattern via repro.core.spmm2d."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sparse.segment import gather_scatter


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int = 41
    aggregator: str = "mean"


def init_params(cfg: SAGEConfig, key):
    ks = iter(jax.random.split(key, 2 * cfg.n_layers + 1))
    dims = [cfg.d_in] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for l in range(cfg.n_layers):
        layers.append({
            "w_self": jax.random.normal(next(ks), (dims[l], dims[l + 1])) / jnp.sqrt(dims[l]),
            "w_neigh": jax.random.normal(next(ks), (dims[l], dims[l + 1])) / jnp.sqrt(dims[l]),
        })
    return {"layers": layers,
            "out": jax.random.normal(next(ks), (cfg.d_hidden, cfg.n_classes)) / jnp.sqrt(cfg.d_hidden)}


def apply_fullgraph(cfg: SAGEConfig, params, feats, edge_src, edge_dst,
                    edge_valid=None, spmm=None):
    """spmm: optional distributed aggregation fn h -> mean-agg(h)
    (the 2D expand/fold SpMM); defaults to local segment ops."""
    h = feats
    n = feats.shape[0]
    for lp in params["layers"]:
        if spmm is None:
            agg = gather_scatter(h, edge_src, edge_dst, n, reduce=cfg.aggregator,
                                 valid=edge_valid)
        else:
            agg = spmm(h)
        h = jax.nn.relu(h @ lp["w_self"] + agg @ lp["w_neigh"])
    return h @ params["out"]


def apply_block(cfg: SAGEConfig, params, block_feats, fanouts):
    """Sampled minibatch: block_feats[k] = features of hop-k nodes, hop k has
    B * prod(fanouts[:k]) rows.  Aggregates innermost-out."""
    hs = list(block_feats)
    for l, lp in enumerate(params["layers"]):
        nxt = []
        for k in range(len(hs) - 1):
            f = fanouts[k]
            neigh = hs[k + 1].reshape(hs[k].shape[0], f, -1).mean(axis=1)
            nxt.append(jax.nn.relu(hs[k] @ lp["w_self"] + neigh @ lp["w_neigh"]))
        hs = nxt
    return hs[0] @ params["out"]


def loss_fn(cfg, params, feats, edge_src, edge_dst, labels, edge_valid=None,
            label_mask=None):
    logits = apply_fullgraph(cfg, params, feats, edge_src, edge_dst, edge_valid)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = lse - ll
    if label_mask is not None:
        return jnp.sum(jnp.where(label_mask, nll, 0)) / jnp.maximum(
            label_mask.sum(), 1)
    return nll.mean()
