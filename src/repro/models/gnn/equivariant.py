"""NequIP and MACE on the Cartesian l<=2 algebra (repro.models.gnn.e3).

NequIP [arXiv:2101.03164]: per-edge tensor product of neighbour features with
edge harmonics, radial-MLP path weights, segment-sum aggregation, gated
nonlinearity, n_layers interaction blocks, per-atom scalar readout -> energy.

MACE [arXiv:2206.07697]: one/two interaction layers building the A-basis
(aggregated TP features), then higher-order B-basis via repeated
self-tensor-products (correlation order 3 = two quadratic couplings),
linear readout per layer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import e3


@dataclasses.dataclass(frozen=True)
class EquivConfig:
    name: str
    n_layers: int
    d_hidden: int          # channels per irrep
    n_rbf: int
    cutoff: float
    n_species: int = 8
    correlation_order: int = 1   # 1 = NequIP; 3 = MACE
    radial_hidden: int = 64


def init_params(cfg: EquivConfig, key):
    C, k = cfg.d_hidden, key
    ks = iter(jax.random.split(key, 12 * cfg.n_layers + 8))

    def dense(k, i, o, scale=None):
        s = scale if scale else (1.0 / jnp.sqrt(i))
        return jax.random.normal(k, (i, o), jnp.float32) * s

    layers = []
    for _ in range(cfg.n_layers):
        lp = {
            "radial1": dense(next(ks), cfg.n_rbf, cfg.radial_hidden),
            "radial2": dense(next(ks), cfg.radial_hidden,
                             C * e3.N_PATHS),
            "mix_s": dense(next(ks), C, C),
            "mix_v": dense(next(ks), C, C),
            "mix_t": dense(next(ks), C, C),
            "gate_v": dense(next(ks), C, C),
            "gate_t": dense(next(ks), C, C),
        }
        if cfg.correlation_order >= 2:
            lp["stp_w"] = jax.random.normal(next(ks), (1, C, 6)) * 0.3
        if cfg.correlation_order >= 3:
            lp["stp_w2"] = jax.random.normal(next(ks), (1, C, 6)) * 0.3
        layers.append(lp)
    return {
        "embed": dense(next(ks), cfg.n_species, C, scale=1.0),
        "layers": layers,
        "readout1": dense(next(ks), C, C),
        "readout2": dense(next(ks), C, 1),
    }


def apply(cfg: EquivConfig, params, species, positions, edge_src, edge_dst,
          edge_valid=None):
    """species (N,) int; positions (N, 3); edges j=src -> i=dst.
    Returns (energy scalar, per-node scalars)."""
    n = species.shape[0]
    C = cfg.d_hidden
    f = e3.zeros(n, C)
    f = {**f, "s": jnp.take(params["embed"], species, axis=0)}

    r = positions[jnp.clip(edge_src, 0, n - 1)] - \
        positions[jnp.clip(edge_dst, 0, n - 1)]
    rhat, y2, d = e3.sph(r)
    rbf, env = e3.bessel_basis(d, cfg.n_rbf, cfg.cutoff)
    if edge_valid is not None:
        rbf = jnp.where(edge_valid[:, None], rbf, 0)

    for lp in params["layers"]:
        w = jax.nn.silu(rbf @ lp["radial1"]) @ lp["radial2"]
        w = w.reshape(-1, C, e3.N_PATHS)
        fj = jax.tree.map(lambda x: x[jnp.clip(edge_src, 0, n - 1)], f)
        msg = e3.edge_tensor_product(fj, rhat, y2, w)
        agg = e3.scatter_nodes(msg, edge_dst, n, valid=edge_valid)
        agg = e3.linear_mix(agg, lp["mix_s"], lp["mix_v"], lp["mix_t"])
        if cfg.correlation_order >= 2:
            agg = e3.add(agg, e3.self_tensor_product(agg, lp["stp_w"]))
        if cfg.correlation_order >= 3:
            agg = e3.add(agg, e3.self_tensor_product(agg, lp["stp_w2"]))
        f = e3.add(f, e3.gate(agg, lp["gate_v"], lp["gate_t"]))

    h = jax.nn.silu(f["s"] @ params["readout1"]) @ params["readout2"]
    return jnp.sum(h), h[:, 0]


def energy_and_forces(cfg: EquivConfig, params, species, positions, edge_src,
                      edge_dst, edge_valid=None):
    e, grad = jax.value_and_grad(
        lambda pos: apply(cfg, params, species, pos, edge_src, edge_dst,
                          edge_valid)[0])(positions)
    return e, -grad
