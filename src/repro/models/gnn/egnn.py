"""EGNN [arXiv:2102.09844]: E(n)-equivariant message passing without
spherical harmonics -- scalar messages from invariant distances + coordinate
updates along relative displacements."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int = 8


def _mlp(ks, sizes):
    return [jax.random.normal(k, (i, o), jnp.float32) / jnp.sqrt(i)
            for k, i, o in zip(ks, sizes[:-1], sizes[1:])]


def _apply_mlp(ws, x):
    for i, w in enumerate(ws):
        x = x @ w
        if i < len(ws) - 1:
            x = jax.nn.silu(x)
    return x


def init_params(cfg: EGNNConfig, key):
    ks = iter(jax.random.split(key, 10 * cfg.n_layers + 2))
    d = cfg.d_hidden
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "msg": _mlp([next(ks)] * 3, [2 * d + 1, d, d]),
            "coord": _mlp([next(ks)] * 3, [d, d, 1]),
            "upd": _mlp([next(ks)] * 3, [2 * d, d, d]),
        })
    return {"embed": jax.random.normal(next(ks), (cfg.d_in, d)) / jnp.sqrt(cfg.d_in),
            "layers": layers,
            "readout": jax.random.normal(next(ks), (d, 1)) / jnp.sqrt(d)}


def apply(cfg: EGNNConfig, params, feats, positions, edge_src, edge_dst,
          edge_valid=None):
    n = feats.shape[0]
    h = feats @ params["embed"]
    x = positions
    src = jnp.clip(edge_src, 0, n - 1)
    dst = jnp.clip(edge_dst, 0, n - 1)
    for lp in params["layers"]:
        rij = x[src] - x[dst]
        d2 = jnp.sum(rij**2, axis=-1, keepdims=True)
        m = _apply_mlp(lp["msg"], jnp.concatenate([h[src], h[dst], d2], -1))
        if edge_valid is not None:
            m = jnp.where(edge_valid[:, None], m, 0)
        cw = _apply_mlp(lp["coord"], m)
        dx = jnp.zeros_like(x).at[dst].add(rij * cw, mode="drop")
        cnt = jnp.zeros((n,), x.dtype).at[dst].add(
            jnp.where(edge_valid, 1., 0.) if edge_valid is not None
            else jnp.ones_like(dst, x.dtype), mode="drop")
        x = x + dx / jnp.maximum(cnt, 1)[:, None]
        agg = jnp.zeros_like(h).at[dst].add(m, mode="drop")
        h = h + _apply_mlp(lp["upd"], jnp.concatenate([h, agg], -1))
    energy = jnp.sum(h @ params["readout"])
    return energy, h, x
